"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for Rust (L3).

Interchange format is HLO *text*, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each (preset, method[, quant]) bundle becomes artifacts/<tag>/ with:
  train_step.hlo.txt   (new_trainables + new_m + new_v + [loss])
  eval_loss.hlo.txt    (sum_nll, token_count)
  logits_last.hlo.txt  (vocab logits at position cur_len-1)
  manifest.json        the full input contract (names, shapes, dtypes,
                       init specs, quantized packing layout)

plus artifacts/micro/ — standalone kernels for the complexity/benchmark
sweeps (Fig. 1, §3.2 scaling, CNP ablations).

Usage:
  python -m compile.aot --out-root ../artifacts            # default set
  python -m compile.aot --out-root ../artifacts --bundle bench:oft_v2
  python -m compile.aot --out-root ../artifacts --micro-only
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import PRESETS, ModelCfg, param_count
from .kernels import awq as awq_k
from .kernels import cnp as cnp_k
from .kernels import nf4 as nf4_k
from .kernels import ref
from .kernels.rotate import block_rotate


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default elides big
    # constant payloads as `{...}`, which xla_extension 0.5.1's text
    # parser accepts silently and materializes as garbage (NaNs at
    # runtime). The Pallas kernels carry static gather-index/sign tables
    # as large constants.
    return comp.as_hlo_text(print_large_constants=True)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "u8": jnp.uint8, "i8": jnp.int8}


# ---------------------------------------------------------------------------
# Model bundles
# ---------------------------------------------------------------------------


def bundle_tag(preset: str, method: str, quant: str) -> str:
    return f"{preset}_{method}" + (f"_{quant}" if quant != "none" else "")


def build_manifest(preset: str, cfg: ModelCfg) -> dict:
    base_specs = M.base_param_specs(cfg)
    adapter_specs = M.adapter_param_specs(cfg)

    def entry(name, spec):
        (shape, (kind, std)) = spec
        return {"name": name, "shape": list(shape), "dtype": "f32", "init": [kind, std]}

    trainable = []
    for n in M.trainable_names(cfg):
        spec = adapter_specs.get(n) or base_specs[n]
        trainable.append(entry(n, spec))
    frozen = [entry(n, base_specs[n]) for n in M.frozen_names(cfg)]
    quantized = [
        {"name": qn, "base": base, "shape": list(shape), "dtype": dt}
        for qn, base, shape, dt in M.quantized_specs(cfg)
    ]
    b, t, v = cfg.batch, cfg.seq_len, cfg.vocab
    return {
        "tag": bundle_tag(preset, cfg.method, cfg.quant),
        "preset": preset,
        "method": cfg.method,
        "quant": cfg.quant,
        "model": {
            "vocab": v,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": t,
            "batch": b,
            "block_b": cfg.block_b,
            "neumann_k": cfg.neumann_k,
            "lora_r": cfg.lora_r,
            "lora_alpha": cfg.lora_alpha,
        },
        "params": param_count(cfg),
        "inputs": {
            "trainable": trainable,
            "frozen": frozen,
            "quantized": quantized,
            "data": [
                {"name": "tokens", "shape": [b, t + 1], "dtype": "i32"},
                {"name": "mask", "shape": [b, t], "dtype": "f32"},
                {"name": "lr", "shape": [], "dtype": "f32"},
                {"name": "t", "shape": [], "dtype": "f32"},
            ],
        },
        "artifacts": {
            "train_step": "train_step.hlo.txt",
            "eval_loss": "eval_loss.hlo.txt",
            "logits_last": "logits_last.hlo.txt",
        },
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
    }


def _sources_mtime() -> float:
    """Newest mtime across the compile package (bundle staleness check)."""
    root = os.path.dirname(os.path.abspath(__file__))
    latest = 0.0
    for dirpath, _, files in os.walk(root):
        for f in files:
            if f.endswith(".py"):
                latest = max(latest, os.path.getmtime(os.path.join(dirpath, f)))
    return latest


def _up_to_date(marker: str) -> bool:
    return os.path.exists(marker) and os.path.getmtime(marker) >= _sources_mtime()


def lower_bundle(preset: str, method: str, quant: str, out_root: str, force=False):
    cfg = PRESETS[preset].with_method(method, quant)
    tag = bundle_tag(preset, method, quant)
    outdir = os.path.join(out_root, tag)
    marker = os.path.join(outdir, "manifest.json")
    if not force and _up_to_date(marker):
        print(f"[aot] {tag}: up to date")
        return
    os.makedirs(outdir, exist_ok=True)
    man = build_manifest(preset, cfg)

    tr_specs = [_sds(e["shape"]) for e in man["inputs"]["trainable"]]
    fr_specs = [_sds(e["shape"]) for e in man["inputs"]["frozen"]]
    qt_specs = [_sds(e["shape"], _DTYPES[e["dtype"]]) for e in man["inputs"]["quantized"]]
    fixed = fr_specs + qt_specs
    b, t = cfg.batch, cfg.seq_len
    tokens = _sds((b, t + 1), jnp.int32)
    mask = _sds((b, t), jnp.float32)
    scalar = _sds((), jnp.float32)

    print(f"[aot] {tag}: lowering train_step ...", flush=True)
    step = M.make_train_step(cfg)
    hlo = to_hlo_text(
        jax.jit(step).lower(tr_specs, tr_specs, tr_specs, fixed, tokens, mask, scalar, scalar)
    )
    with open(os.path.join(outdir, "train_step.hlo.txt"), "w") as f:
        f.write(hlo)

    print(f"[aot] {tag}: lowering eval_loss ...", flush=True)
    ev = M.make_eval_loss(cfg)
    hlo = to_hlo_text(jax.jit(ev).lower(tr_specs, fixed, tokens, mask))
    with open(os.path.join(outdir, "eval_loss.hlo.txt"), "w") as f:
        f.write(hlo)

    print(f"[aot] {tag}: lowering logits_last ...", flush=True)
    ll = M.make_logits_last(cfg)
    tokens1 = _sds((1, t), jnp.int32)
    cur = _sds((), jnp.int32)
    hlo = to_hlo_text(jax.jit(ll).lower(tr_specs, fixed, tokens1, cur))
    with open(os.path.join(outdir, "logits_last.hlo.txt"), "w") as f:
        f.write(hlo)

    with open(marker, "w") as f:
        json.dump(man, f, indent=1)
    print(f"[aot] {tag}: done")


# ---------------------------------------------------------------------------
# Micro-kernel artifacts (complexity sweeps, ablations)
# ---------------------------------------------------------------------------

MICRO_ROWS = 128  # input rows for the linear-layer micro benches
MICRO_B = 32
MICRO_K = 5
MICRO_LORA_R = 16


def micro_defs(dims, cnp_bs, ks):
    """name -> (fn, [(input_name, shape, dtype)], meta). All f32 unless noted."""
    p_of = ref.packed_dim
    defs = {}

    for d in dims:
        nb = d // MICRO_B
        p = p_of(MICRO_B)
        x = ("x", (MICRO_ROWS, d), "f32")
        q = ("q", (nb, p), "f32")
        w = ("w", (d, d), "f32")

        def mk_rotate(d=d, nb=nb):
            def f(x, q):
                r = cnp_k.cnp_build(q, MICRO_B, MICRO_K)
                return (block_rotate(x, r),)

            return f

        def mk_rotate_w(d=d, nb=nb):
            def f(x, q, w):
                r = cnp_k.cnp_build(q, MICRO_B, MICRO_K)
                return (block_rotate(x, r) @ w,)

            return f

        def mk_merge_w(d=d, nb=nb):
            def f(x, q, w):
                r = ref.cayley_neumann(q, MICRO_B, MICRO_K)
                rd = ref.blockdiag_dense(r, d)
                return (x @ (rd @ w),)

            return f

        def mk_base_w():
            def f(x, w):
                return (x @ w,)

            return f

        def mk_lora_w(d=d):
            def f(x, a, bb, w):
                return (x @ w + ((x @ a) @ bb) * (16.0 / MICRO_LORA_R),)

            return f

        defs[f"rotate_d{d}"] = (mk_rotate(), [x, q], {"d": d})
        defs[f"rotate_w_d{d}"] = (mk_rotate_w(), [x, q, w], {"d": d})
        defs[f"merge_w_d{d}"] = (mk_merge_w(), [x, q, w], {"d": d})
        defs[f"base_w_d{d}"] = (mk_base_w(), [x, w], {"d": d})
        defs[f"lora_w_d{d}"] = (
            mk_lora_w(),
            [x, ("a", (d, MICRO_LORA_R), "f32"), ("b", (MICRO_LORA_R, d), "f32"), w],
            {"d": d},
        )

    for b in cnp_bs:
        q = ("q", (32, p_of(b)), "f32")

        def mk_cnp(b=b, k=MICRO_K):
            def f(q):
                return (cnp_k.cnp_build(q, b, k),)

            return f

        def mk_schulz(b=b):
            def f(q):
                return (M.cayley_schulz(q, b, 12),)

            return f

        defs[f"cnp_b{b}"] = (mk_cnp(), [q], {"b": b, "k": MICRO_K})
        defs[f"cayley_schulz_b{b}"] = (mk_schulz(), [q], {"b": b})

    for k in ks:
        q = ("q", (32, p_of(MICRO_B)), "f32")

        def mk_cnp_k(k=k):
            def f(q):
                return (cnp_k.cnp_build(q, MICRO_B, k),)

            return f

        defs[f"cnp_b{MICRO_B}_k{k}"] = (mk_cnp_k(), [q], {"b": MICRO_B, "k": k})

    # quant dequant kernels at a fixed realistic size
    n = 1024 * 1024
    nbytes, nblocks, ngroups = nf4_k.packed_sizes(n)
    defs["nf4_dequant_1m"] = (
        lambda c, aq, as_, off: (nf4_k.nf4_dequant_flat(c, aq, as_, off),),
        [
            ("codes", (nbytes,), "u8"),
            ("absmax_q", (nblocks,), "i8"),
            ("absmax_s", (ngroups,), "f32"),
            ("offset", (1,), "f32"),
        ],
        {"n": n},
    )
    dq = 1024
    defs["awq_dequant_1m"] = (
        lambda c, s, e: (awq_k.awq_dequant(c, s, e),),
        [
            ("codes", (dq // 2, dq), "u8"),
            ("scales", (dq // ref.AWQ_GROUP, dq), "f32"),
            ("eq", (dq,), "f32"),
        ],
        {"din": dq, "dout": dq},
    )
    return defs


def lower_micro(out_root: str, dims, force=False):
    outdir = os.path.join(out_root, "micro")
    marker = os.path.join(outdir, "manifest.json")
    if not force and _up_to_date(marker):
        print("[aot] micro: up to date")
        return
    os.makedirs(outdir, exist_ok=True)
    defs = micro_defs(dims, cnp_bs=(16, 32, 64), ks=(1, 2, 3, 4, 5, 6, 7, 8))
    man = {}
    for name, (fn, inputs, meta) in defs.items():
        specs = [_sds(shape, _DTYPES[dt]) for _, shape, dt in inputs]
        print(f"[aot] micro/{name} ...", flush=True)
        hlo = to_hlo_text(jax.jit(fn).lower(*specs))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(hlo)
        man[name] = {
            "artifact": fname,
            "inputs": [{"name": n, "shape": list(s), "dtype": dt} for n, s, dt in inputs],
            "meta": meta,
        }
    with open(marker, "w") as f:
        json.dump(man, f, indent=1)
    print("[aot] micro: done")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

DEFAULT_BUNDLES = [
    # pytest / cargo-test bundle: every method at minimal size
    ("tiny", "full", "none"),
    ("tiny", "none", "none"),
    ("tiny", "lora", "none"),
    ("tiny", "oft_merged", "none"),
    ("tiny", "oft_v2", "none"),
    ("tiny", "qlora", "nf4"),
    ("tiny", "qoft", "nf4"),
    ("tiny", "qlora", "awq"),
    ("tiny", "qoft", "awq"),
    # integration bundle
    ("small", "full", "none"),
    ("small", "lora", "none"),
    ("small", "oft_v2", "none"),
    ("small", "qlora", "nf4"),
    ("small", "qoft", "nf4"),
    # Fig.1 timing bundle (d > rows: the merge-dominated regime)
    ("fig1", "oft_merged", "none"),
    ("fig1", "oft_v2", "none"),
    ("fig1", "lora", "none"),
    # timing bundle (Tab.1 / Tab.2)
    ("bench", "lora", "none"),
    ("bench", "oft_merged", "none"),
    ("bench", "oft_v2", "none"),
    ("bench", "qlora", "nf4"),
    ("bench", "qoft", "nf4"),
    ("bench", "qlora", "awq"),
    ("bench", "qoft", "awq"),
    # end-to-end demo bundle
    ("e2e", "full", "none"),
    ("e2e", "lora", "none"),
    ("e2e", "oft_v2", "none"),
    ("e2e", "qoft", "nf4"),
]

MICRO_DIMS = (256, 512, 1024, 2048)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-root", default="../artifacts")
    ap.add_argument("--bundle", action="append", default=[],
                    help="preset:method[:quant] (repeatable; overrides default set)")
    ap.add_argument("--micro-only", action="store_true")
    ap.add_argument("--no-micro", action="store_true")
    ap.add_argument("--with-100m", action="store_true",
                    help="also lower the e2e100m bundles (slow)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_root, exist_ok=True)
    if not args.micro_only:
        bundles = DEFAULT_BUNDLES
        if args.bundle:
            bundles = []
            for spec in args.bundle:
                parts = spec.split(":")
                preset, method = parts[0], parts[1]
                quant = parts[2] if len(parts) > 2 else "none"
                bundles.append((preset, method, quant))
        elif args.with_100m:
            bundles = bundles + [
                ("e2e100m", "full", "none"),
                ("e2e100m", "oft_v2", "none"),
                ("e2e100m", "lora", "none"),
            ]
        for preset, method, quant in bundles:
            lower_bundle(preset, method, quant, args.out_root, force=args.force)
    if not args.no_micro:
        lower_micro(args.out_root, MICRO_DIMS, force=args.force)


if __name__ == "__main__":
    main()
