"""Model / finetuning configurations and named presets.

A (preset, method, quant) triple fully determines one artifact bundle
under artifacts/<preset>_<method>[_<quant>]/. The Rust coordinator reads
the bundle's manifest.json and never re-derives any of these numbers.
"""

from dataclasses import dataclass, field, replace

METHODS = ("full", "none", "lora", "oft_merged", "oft_v2", "qlora", "qoft")
QUANT_BACKENDS = ("none", "nf4", "awq")


@dataclass(frozen=True)
class ModelCfg:
    """Decoder-only transformer + PEFT-method configuration."""

    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 256
    seq_len: int = 32  # training context length T (batches are (B, T+1))
    batch: int = 4

    method: str = "oft_v2"
    quant: str = "none"  # weight backend for qlora/qoft: nf4 | awq

    # OFT family
    block_b: int = 16  # orthogonal block size b (must divide d_model, d_ff)
    neumann_k: int = 5  # Neumann series terms (CNP)
    cayley: str = "neumann"  # oft_merged parameterization: neumann | schulz
    schulz_iters: int = 12  # Newton-Schulz iterations for "exact" inverse

    # LoRA family
    lora_r: int = 4
    lora_alpha: float = 16.0

    def __post_init__(self):
        assert self.method in METHODS, self.method
        assert self.quant in QUANT_BACKENDS, self.quant
        assert self.d_model % self.n_heads == 0
        if self.method in ("oft_merged", "oft_v2", "qoft"):
            assert self.d_model % self.block_b == 0, (self.d_model, self.block_b)
            assert self.d_ff % self.block_b == 0, (self.d_ff, self.block_b)
        if self.method in ("qlora", "qoft"):
            assert self.quant != "none", "quantized methods need a quant backend"
        else:
            assert self.quant == "none", (self.method, self.quant)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def with_method(self, method: str, quant: str = "none") -> "ModelCfg":
        return replace(self, method=method, quant=quant)


# Named presets (model shape only; method/quant applied per artifact).
PRESETS = {
    # fast pytest / cargo-test bundle
    "tiny": ModelCfg(
        vocab=256, d_model=64, n_layers=2, n_heads=2, d_ff=256,
        seq_len=48, batch=4, block_b=16, lora_r=4,
    ),
    # unit/integration bundle with realistic block size
    "small": ModelCfg(
        vocab=512, d_model=128, n_layers=2, n_heads=4, d_ff=512,
        seq_len=64, batch=8, block_b=32, lora_r=8,
    ),
    # timing bundle for Tab.1 / Tab.2
    "bench": ModelCfg(
        vocab=512, d_model=256, n_layers=4, n_heads=8, d_ff=1024,
        seq_len=128, batch=8, block_b=32, lora_r=16,
    ),
    # Fig.1 regime: d > rows, where the weight-centric d^2·n merge
    # dominates the rows·d·n layer (the paper's 7B setting scaled down)
    "fig1": ModelCfg(
        vocab=512, d_model=1024, n_layers=2, n_heads=8, d_ff=2048,
        seq_len=32, batch=4, block_b=32, lora_r=16,
    ),
    # end-to-end finetuning demo (~23M params)
    "e2e": ModelCfg(
        vocab=4096, d_model=512, n_layers=6, n_heads=8, d_ff=2048,
        seq_len=256, batch=8, block_b=32, lora_r=16,
    ),
    # ~100M-parameter configuration for the headline end-to-end run
    "e2e100m": ModelCfg(
        vocab=8192, d_model=896, n_layers=8, n_heads=14, d_ff=3584,
        seq_len=256, batch=4, block_b=32, lora_r=16,
    ),
}


def param_count(cfg: ModelCfg) -> dict:
    """Base / trainable parameter counts (mirrors rust/src/peft counting)."""
    d, f, v, t = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    base = v * d + t * d  # embeddings
    base += cfg.n_layers * (2 * d + 4 * d * d + d * f + f * d)  # norms+attn+mlp
    base += d + d * v  # final norm + head
    linears = []
    for _ in range(cfg.n_layers):
        linears += [(d, d)] * 4 + [(d, f), (f, d)]
    if cfg.method in ("lora", "qlora"):
        trainable = sum(cfg.lora_r * (din + dout) for din, dout in linears)
    elif cfg.method in ("oft_merged", "oft_v2", "qoft"):
        b = cfg.block_b
        trainable = sum((din // b) * (b * (b - 1) // 2) for din, dout in linears)
    elif cfg.method == "full":
        trainable = base
    else:
        trainable = 0
    return {"base": base, "trainable": trainable}
