"""Generate cross-language golden fixtures for the Rust oracles.

Writes small JSON files under rust/tests/golden/ from the python
reference kernels (compile/kernels/ref.py): CNP builds at k in {2,4,8},
one block rotation, and an NF4 quantize->dequantize pass. The Rust test
rust/tests/golden.rs replays the same inputs through rust/src/peft and
rust/src/quant and asserts 1e-4 agreement — cross-language parity
without requiring JAX at cargo-test time.

Inputs are synthesized from an integer Weyl sequence so both languages
reconstruct bit-identical f32 inputs from three scalars (n, scale,
offset index) instead of shipping big arrays:

    h_i = (i * 2654435761) mod 2^32
    x_i = (f32(h_i) / 4294967296.0 - 0.5) * scale

Usage (from python/):  python -m compile.gen_golden [--out DIR]
"""

import argparse
import json
import os

import numpy as np

from .kernels import ref

MULT = np.uint64(2654435761)
MOD = np.uint64(1) << np.uint64(32)


def weyl_f32(n: int, scale: float, start: int = 0) -> np.ndarray:
    """Deterministic f32 inputs both languages can reproduce exactly."""
    i = np.arange(start, start + n, dtype=np.uint64)
    h = (i * MULT) % MOD
    return ((h.astype(np.float32) / np.float32(4294967296.0)) - np.float32(0.5)) * np.float32(
        scale
    )


def floats(a) -> list:
    return [float(x) for x in np.asarray(a, np.float32).reshape(-1)]


def gen_cnp(out_dir: str):
    b, nb = 8, 4
    p = ref.packed_dim(b)
    for k in (2, 4, 8):
        packed = weyl_f32(nb * p, 0.2, start=100 + k).reshape(nb, p)
        r = np.asarray(ref.cayley_neumann(packed, b, k), np.float32)
        doc = {
            "kernel": "cayley_neumann",
            "b": b,
            "nb": nb,
            "k": k,
            "input": {"n": nb * p, "scale": 0.2, "start": 100 + k},
            "output": floats(r),
            "tolerance": 1e-4,
        }
        path = os.path.join(out_dir, f"cnp_k{k}.json")
        with open(path, "w") as f:
            json.dump(doc, f)
        print(f"[golden] {path}: {len(doc['output'])} values")


def gen_rotate(out_dir: str):
    b, nb, rows, k = 8, 4, 8, 5
    d = b * nb
    p = ref.packed_dim(b)
    x = weyl_f32(rows * d, 2.0, start=7).reshape(rows, d)
    packed = weyl_f32(nb * p, 0.1, start=900).reshape(nb, p)
    blocks = np.asarray(ref.cayley_neumann(packed, b, k), np.float32)
    y = np.asarray(ref.block_rotate(x, blocks), np.float32)
    doc = {
        "kernel": "block_rotate",
        "b": b,
        "nb": nb,
        "rows": rows,
        "k": k,
        "x": {"n": rows * d, "scale": 2.0, "start": 7},
        "q": {"n": nb * p, "scale": 0.1, "start": 900},
        "output": floats(y),
        "tolerance": 1e-4,
    }
    path = os.path.join(out_dir, "rotate.json")
    with open(path, "w") as f:
        json.dump(doc, f)
    print(f"[golden] {path}: {len(doc['output'])} values")


def gen_nf4(out_dir: str):
    # one full double-quant tile (the smallest unpadded case)
    n = ref.NF4_TILE
    x = weyl_f32(n, 0.4, start=31)
    q = ref.nf4_quantize(x)
    deq = np.asarray(
        ref.nf4_dequant_ref(
            q["codes"], q["absmax_q"], q["absmax_s"], q["offset"], n, (n,)
        ),
        np.float32,
    )
    stride = 97
    samples = deq[::stride]
    rms = float(np.sqrt(((deq - x) ** 2).mean()))
    doc = {
        "kernel": "nf4_roundtrip",
        "input": {"n": n, "scale": 0.4, "start": 31},
        "offset": float(q["offset"][0]),
        "absmax_s": floats(q["absmax_s"]),
        "absmax_q": [int(v) for v in q["absmax_q"]],
        "sample_stride": stride,
        "dequant_samples": floats(samples),
        "roundtrip_rms": rms,
        # absmax path is float-exact; codes may differ by ties only
        "tolerance": 1e-4,
    }
    path = os.path.join(out_dir, "nf4.json")
    with open(path, "w") as f:
        json.dump(doc, f)
    print(f"[golden] {path}: {len(doc['dequant_samples'])} samples, rms {rms:.5f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join("..", "rust", "tests", "golden"))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    gen_cnp(args.out)
    gen_rotate(args.out)
    gen_nf4(args.out)


if __name__ == "__main__":
    main()
