"""L1: Pallas kernels for the paper's compute hot-spots.

- cnp: fused skew-unpack + Cayley-Neumann orthogonal-block build
  (the paper's custom CUDA kernel, rethought for TPU/VMEM).
- rotate: block-diagonal input rotation — the input-centric OFTv2 hot
  path, with a custom VJP so the train graph can differentiate it.
- nf4: NF4 (QLoRA) dequantization with double quantization.
- awq: AWQ-style groupwise int4 dequantization.
- ref: pure-jnp oracles for all of the above.

All kernels lower with interpret=True so they compile to plain HLO and run
on the CPU PJRT client driven by the Rust runtime (real-TPU lowering emits
Mosaic custom-calls the CPU plugin cannot execute).
"""

from . import awq, cnp, nf4, ref, rotate  # noqa: F401
