"""Pallas kernel: AWQ-style groupwise int4 dequantization.

Layout (see ref.awq_quantize / rust/src/quant/awq.rs):
  codes  (din/2, dout) uint8 — rows 2i in the hi nibble, 2i+1 in the lo
  scales (din/AWQ_GROUP, dout) f32 — symmetric per-(group, out-channel)

Grid: one program per (AWQ group, column tile). Each program expands a
(AWQ_GROUP/2, TC) byte tile into a (AWQ_GROUP, TC) float tile and scales
it by the (1, TC) scale row — contiguous VMEM tiles, no cross-program
traffic. Activation-aware equalization is folded into `scales` at
quantization time, so dequant is a single multiply (as in AutoAWQ).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import AWQ_GROUP


def _awq_kernel(codes_ref, scale_ref, eq_ref, o_ref):
    codes = codes_ref[...]  # (AWQ_GROUP//2, TC)
    hi = (codes >> 4).astype(jnp.int32) - 8
    lo = (codes & 0xF).astype(jnp.int32) - 8
    h2, tc = codes.shape
    q = jnp.stack([hi, lo], axis=1).reshape(h2 * 2, tc).astype(jnp.float32)
    o_ref[...] = q * scale_ref[...] / eq_ref[...][:, None]


def _pick_tc(dout: int) -> int:
    for tc in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if dout % tc == 0:
            return tc
    return 1


@jax.jit
def awq_dequant(codes, scales, eq):
    """codes (din/2, dout) u8, scales (g, dout) f32, eq (din,) f32
    -> (din, dout) f32."""
    din2, dout = codes.shape
    din = din2 * 2
    g = scales.shape[0]
    assert din % AWQ_GROUP == 0 and g == din // AWQ_GROUP
    tc = _pick_tc(dout)
    return pl.pallas_call(
        _awq_kernel,
        grid=(g, dout // tc),
        in_specs=[
            pl.BlockSpec((AWQ_GROUP // 2, tc), lambda i, j: (i, j)),
            pl.BlockSpec((1, tc), lambda i, j: (i, j)),
            pl.BlockSpec((AWQ_GROUP,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((AWQ_GROUP, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((din, dout), jnp.float32),
        interpret=True,
    )(codes, scales, eq)
