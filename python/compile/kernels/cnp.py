"""Pallas kernel: fused skew-unpack + Cayley-Neumann build.

This is the TPU adaptation of the paper's custom CUDA kernel (§3.3,
"Custom CUDA kernel for skew-symmetric matrices") plus the CNP build:

  packed upper triangle q (nb, p)  ->  orthogonal blocks R (nb, b, b)
      R_i = (I + Q_i)(I + sum_{j=1..k} Q_i^j)

CUDA -> Pallas rethink (see DESIGN.md §Hardware adaptation):
  * the CUDA scatter (one thread per element) becomes a *static gather*
    (`idx`/`sign` maps precomputed host-side) — TPU VPU-friendly;
  * the grid iterates over the nb blocks; each program keeps one packed
    vector and the (b, b) working set entirely in VMEM;
  * the k Neumann matmuls run back-to-back on the same VMEM tile — dense
    Q and the partial powers never round-trip to HBM.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same graph runs
under the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _cnp_kernel(qp_ref, idx_ref, sign_ref, o_ref, *, b: int, k: int):
    qp = qp_ref[0]  # (p + 1,) packed, padded with one trailing zero slot
    q = (jnp.take(qp, idx_ref[...], axis=0) * sign_ref[...]).reshape(b, b)
    eye = jnp.eye(b, dtype=q.dtype)
    acc = eye
    term = eye
    for _ in range(k):
        term = term @ q
        acc = acc + term
    o_ref[0] = (eye + q) @ acc


@functools.partial(jax.jit, static_argnames=("b", "k"))
def cnp_build(q_packed: jax.Array, b: int, k: int) -> jax.Array:
    """Build (nb, b, b) orthogonal blocks from packed skew params (nb, p).

    Matches ref.cayley_neumann to float32 accuracy.
    """
    nb, p = q_packed.shape
    assert p == ref.packed_dim(b), (p, b)
    idx, sign = ref.skew_index_maps(b)
    qpad = jnp.concatenate([q_packed, jnp.zeros((nb, 1), q_packed.dtype)], axis=1)
    return pl.pallas_call(
        functools.partial(_cnp_kernel, b=b, k=k),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, p + 1), lambda i: (i, 0)),
            pl.BlockSpec((b * b,), lambda i: (0,)),
            pl.BlockSpec((b * b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, b, b), q_packed.dtype),
        interpret=True,
    )(qpad, idx, sign)


def _skew_kernel(qp_ref, idx_ref, sign_ref, o_ref, *, b: int):
    qp = qp_ref[0]
    o_ref[0] = (jnp.take(qp, idx_ref[...], axis=0) * sign_ref[...]).reshape(b, b)


@functools.partial(jax.jit, static_argnames=("b",))
def skew_build(q_packed: jax.Array, b: int) -> jax.Array:
    """Packed -> dense skew-symmetric blocks only (the paper's CUDA kernel
    in isolation). (nb, p) -> (nb, b, b)."""
    nb, p = q_packed.shape
    assert p == ref.packed_dim(b), (p, b)
    idx, sign = ref.skew_index_maps(b)
    qpad = jnp.concatenate([q_packed, jnp.zeros((nb, 1), q_packed.dtype)], axis=1)
    return pl.pallas_call(
        functools.partial(_skew_kernel, b=b),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, p + 1), lambda i: (i, 0)),
            pl.BlockSpec((b * b,), lambda i: (0,)),
            pl.BlockSpec((b * b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, b, b), q_packed.dtype),
        interpret=True,
    )(qpad, idx, sign)


def vmem_bytes(b: int, k: int) -> int:
    """Static VMEM working-set estimate for one CNP program (f32):
    packed vector + gather maps + Q + two accumulators + output tile.
    Used by the perf notes in DESIGN.md / EXPERIMENTS.md §Perf."""
    p = ref.packed_dim(b) + 1
    words = p + 2 * b * b  # packed + idx/sign maps (idx i32 counts as word)
    words += 4 * b * b  # Q, term, acc, out
    return 4 * words
