"""Pallas kernel: NF4 dequantization (QLoRA-style, double-quantized).

Layout (see ref.nf4_quantize / rust/src/quant/nf4.rs — byte-identical):
  codes    (npad/2,)  uint8 — two 4-bit NF4 codes per byte (hi = even idx)
  absmax_q (nblocks,) int8  — per-64-element-block absmax, double-quantized
  absmax_s (ngroups,) f32   — per-256-block group scale for absmax_q
  offset   (1,)       f32   — double-quant offset (mean absmax)

One grid program dequantizes one double-quant group (NF4_TILE = 16384
elements = 8192 bytes = 256 blocks): the group boundary makes the scale a
per-program scalar, so the kernel touches exactly one absmax_s element and
one contiguous slab of codes — a clean HBM->VMEM stream with no gather
across programs. The 16-level codebook lives in VMEM as a constant.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NF4_BLOCK, NF4_CODE, NF4_GROUP, NF4_TILE


def _nf4_kernel(codes_ref, amq_ref, ams_ref, off_ref, lut_ref, o_ref):
    codes = codes_ref[...]
    hi = (codes >> 4).astype(jnp.int32)
    lo = (codes & 0xF).astype(jnp.int32)
    idx = jnp.stack([hi, lo], axis=1).reshape(-1)  # (TILE,)
    vals = jnp.take(lut_ref[...], idx, axis=0)
    am = (
        amq_ref[...].astype(jnp.float32) / 127.0 * ams_ref[0] + off_ref[0]
    )  # (NF4_GROUP,)
    o_ref[...] = (vals.reshape(NF4_GROUP, NF4_BLOCK) * am[:, None]).reshape(-1)


@jax.jit
def nf4_dequant_flat(codes, absmax_q, absmax_s, offset):
    """Dequantize to the padded flat float32 array (npad,)."""
    nbytes = codes.shape[0]
    npad = nbytes * 2
    assert npad % NF4_TILE == 0, npad
    ng = npad // NF4_TILE
    return pl.pallas_call(
        _nf4_kernel,
        grid=(ng,),
        in_specs=[
            pl.BlockSpec((NF4_TILE // 2,), lambda i: (i,)),
            pl.BlockSpec((NF4_GROUP,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((NF4_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(codes, absmax_q, absmax_s, offset, jnp.asarray(NF4_CODE))


@functools.partial(jax.jit, static_argnames=("n", "shape"))
def nf4_dequant(codes, absmax_q, absmax_s, offset, n: int, shape):
    """Dequantize to the original (unpadded) shape."""
    flat = nf4_dequant_flat(codes, absmax_q, absmax_s, offset)
    return flat[:n].reshape(shape)


def packed_sizes(n: int):
    """(nbytes, nblocks, ngroups) for an n-element tensor after padding."""
    npad = n + ((-n) % NF4_TILE)
    return npad // 2, npad // NF4_BLOCK, npad // NF4_TILE
