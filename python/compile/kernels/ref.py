"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (and hypothesis sweeps)
compare each Pallas kernel against the function of the same name here.
They are also used directly inside L2 graphs where a kernel is not the
right tool (e.g. the differentiable CNP build in the train step).
"""

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Skew-symmetric packing
# ---------------------------------------------------------------------------


def packed_dim(b: int) -> int:
    """Number of packed parameters for a b x b skew-symmetric matrix."""
    return b * (b - 1) // 2


def skew_index_maps(b: int):
    """Static gather index map + sign mask used to reconstruct a dense
    skew-symmetric matrix from its packed upper triangle.

    Returns (idx, sign) with shapes (b*b,). `idx[i*b+j]` indexes into the
    packed vector *padded with one trailing zero* (position `packed_dim(b)`),
    and `sign` is +1 above the diagonal, -1 below, 0 on it.

    This is the TPU-friendly replacement for the paper's CUDA scatter
    kernel: scatters become a static vectorized gather.
    """
    p = packed_dim(b)
    idx = np.full((b, b), p, dtype=np.int32)  # default: the zero pad slot
    sign = np.zeros((b, b), dtype=np.float32)
    k = 0
    for i in range(b):
        for j in range(i + 1, b):
            idx[i, j] = k
            idx[j, i] = k
            sign[i, j] = 1.0
            sign[j, i] = -1.0
            k += 1
    assert k == p
    return jnp.asarray(idx.reshape(-1)), jnp.asarray(sign.reshape(-1))


def skew_from_packed(q_packed: jax.Array, b: int) -> jax.Array:
    """(..., p) packed upper triangle -> (..., b, b) skew-symmetric."""
    idx, sign = skew_index_maps(b)
    qpad = jnp.concatenate(
        [q_packed, jnp.zeros(q_packed.shape[:-1] + (1,), q_packed.dtype)], axis=-1
    )
    flat = jnp.take(qpad, idx, axis=-1) * sign
    return flat.reshape(q_packed.shape[:-1] + (b, b))


def packed_from_skew(q: jax.Array) -> jax.Array:
    """(..., b, b) skew-symmetric -> (..., p) packed upper triangle."""
    b = q.shape[-1]
    iu = np.triu_indices(b, k=1)
    return q[..., iu[0], iu[1]]


# ---------------------------------------------------------------------------
# Cayley transforms
# ---------------------------------------------------------------------------


def cayley_exact(q_packed: jax.Array, b: int) -> jax.Array:
    """Exact Cayley transform R = (I+Q)(I-Q)^{-1} per block.

    q_packed: (nb, p). Returns (nb, b, b). This is the original OFT
    parameterization (with the matrix inverse the paper removes).
    """
    q = skew_from_packed(q_packed, b)
    eye = jnp.eye(b, dtype=q.dtype)
    # R (I-Q) = (I+Q)  =>  (I-Q)^T R^T = (I+Q)^T
    lhs = jnp.swapaxes(eye - q, -1, -2)
    rhs = jnp.swapaxes(eye + q, -1, -2)
    rt = jnp.linalg.solve(lhs, rhs)
    return jnp.swapaxes(rt, -1, -2)


def cayley_neumann(q_packed: jax.Array, b: int, k: int) -> jax.Array:
    """Cayley-Neumann parameterization (CNP, Qiu et al. 2025):

        R = (I+Q)(I-Q)^{-1} approx (I+Q)(I + sum_{i=1..k} Q^i)

    q_packed: (nb, p). Returns (nb, b, b). Differentiable; used in the
    train-step graph (and mirrored by the Pallas kernel in cnp.py).
    """
    q = skew_from_packed(q_packed, b)
    eye = jnp.broadcast_to(jnp.eye(b, dtype=q.dtype), q.shape)
    acc = eye
    term = eye
    for _ in range(k):
        term = term @ q
        acc = acc + term
    return (eye + q) @ acc


def orthogonality_error(r: jax.Array) -> jax.Array:
    """max_block ||R^T R - I||_F — the approximate-orthogonality metric."""
    b = r.shape[-1]
    eye = jnp.eye(b, dtype=r.dtype)
    g = jnp.swapaxes(r, -1, -2) @ r - eye
    return jnp.max(jnp.sqrt(jnp.sum(g * g, axis=(-1, -2))))


# ---------------------------------------------------------------------------
# Block-diagonal rotation (the input-centric OFTv2 hot path)
# ---------------------------------------------------------------------------


def block_rotate(x: jax.Array, r_blocks: jax.Array) -> jax.Array:
    """y[:, i*b:(i+1)*b] = x[:, i*b:(i+1)*b] @ R_i  (row convention).

    x: (m, d); r_blocks: (nb, b, b) with nb*b == d. Equivalent to the
    paper's input-side transform R^T x in column convention.
    """
    m, d = x.shape
    nb, b, _ = r_blocks.shape
    assert nb * b == d, (nb, b, d)
    xb = x.reshape(m, nb, b)
    yb = jnp.einsum("mnb,nbc->mnc", xb, r_blocks)
    return yb.reshape(m, d)


def block_rotate_grad_r(x: jax.Array, dy: jax.Array, nb: int, b: int) -> jax.Array:
    """dR_i = x_i^T @ dy_i summed over rows. Returns (nb, b, b)."""
    m, d = x.shape
    xb = x.reshape(m, nb, b)
    dyb = dy.reshape(m, nb, b)
    return jnp.einsum("mnb,mnc->nbc", xb, dyb)


def blockdiag_dense(r_blocks: jax.Array, d: int) -> jax.Array:
    """Materialize the dense (d, d) block-diagonal matrix (weight-centric
    baseline only — this is the thing OFTv2 avoids)."""
    nb, b, _ = r_blocks.shape
    eye = jnp.eye(nb, dtype=r_blocks.dtype)
    # (nb, nb, b, b) -> (nb*b, nb*b)
    dense = jnp.einsum("pq,pbc->pbqc", eye, r_blocks)
    return dense.reshape(d, d)


# ---------------------------------------------------------------------------
# NF4 quantization (QLoRA-style, with double quantization)
# ---------------------------------------------------------------------------

# The 16 NormalFloat4 levels from Dettmers et al. 2023 (bitsandbytes).
NF4_CODE = np.array(
    [
        -1.0,
        -0.6961928009986877,
        -0.5250730514526367,
        -0.39491748809814453,
        -0.28444138169288635,
        -0.18477343022823334,
        -0.09105003625154495,
        0.0,
        0.07958029955625534,
        0.16093020141124725,
        0.24611230194568634,
        0.33791524171829224,
        0.44070982933044434,
        0.5626170039176941,
        0.7229568362236023,
        1.0,
    ],
    dtype=np.float32,
)

NF4_BLOCK = 64  # elements per absmax block
NF4_GROUP = 256  # absmax values per double-quantization group
NF4_TILE = NF4_BLOCK * NF4_GROUP  # flat elements handled per kernel program


def nf4_quantize(w: np.ndarray):
    """Quantize a float array to NF4 with double quantization.

    Mirrors rust/src/quant/nf4.rs byte-for-byte. Returns a dict:
      codes      (npad/2,) uint8   two 4-bit codes per byte (hi = even idx)
      absmax_q   (nblocks,) int8   double-quantized per-block absmax
      absmax_s   (ngroups,) float32 per-group scale for absmax_q
      offset     (1,)       float32 mean absmax (double-quant offset)
      n, shape                     original element count / shape

    The flat length is padded to NF4_TILE so the Pallas dequant kernel can
    use one double-quant group per program.
    """
    shape = w.shape
    flat = np.asarray(w, dtype=np.float32).reshape(-1)
    n = flat.size
    pad = (-n) % NF4_TILE
    flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    nb = flat.size // NF4_BLOCK
    blocks = flat.reshape(nb, NF4_BLOCK)
    absmax = np.abs(blocks).max(axis=1)
    absmax = np.maximum(absmax, 1e-12)
    # double quantization of absmax: int8 with per-group scale around offset
    offset = np.float32(absmax.mean())
    ng = nb // NF4_GROUP
    am_groups = (absmax - offset).reshape(ng, NF4_GROUP)
    am_scale = np.abs(am_groups).max(axis=1)
    am_scale = np.maximum(am_scale, 1e-12).astype(np.float32)
    am_q = np.clip(np.round(am_groups / am_scale[:, None] * 127.0), -127, 127).astype(
        np.int8
    )
    # reconstructed absmax (what dequant will see) — quantize codes against it
    am_rec = am_q.astype(np.float32) / 127.0 * am_scale[:, None] + offset
    am_rec = am_rec.reshape(nb)
    am_rec = np.where(np.abs(am_rec) < 1e-12, 1e-12, am_rec)
    normed = blocks / am_rec[:, None]
    # nearest NF4 level
    dist = np.abs(normed.reshape(-1, 1) - NF4_CODE[None, :])
    codes = dist.argmin(axis=1).astype(np.uint8)
    hi = codes[0::2]
    lo = codes[1::2]
    packed = ((hi << 4) | lo).astype(np.uint8)
    return {
        "codes": packed,
        "absmax_q": am_q.reshape(-1),
        "absmax_s": am_scale,
        "offset": np.array([offset], np.float32),
        "n": n,
        "shape": shape,
    }


def nf4_dequant_ref(codes, absmax_q, absmax_s, offset, n, shape):
    """Reference dequantization (jnp). Returns float32 array of `shape`."""
    codes = jnp.asarray(codes)
    hi = (codes >> 4).astype(jnp.int32)
    lo = (codes & 0xF).astype(jnp.int32)
    idx = jnp.stack([hi, lo], axis=1).reshape(-1)
    lut = jnp.asarray(NF4_CODE)
    vals = lut[idx]
    nb = vals.shape[0] // NF4_BLOCK
    ng = nb // NF4_GROUP
    am = (
        jnp.asarray(absmax_q).astype(jnp.float32).reshape(ng, NF4_GROUP)
        / 127.0
        * jnp.asarray(absmax_s).reshape(ng, 1)
        + jnp.asarray(offset).reshape(1, 1)
    ).reshape(nb)
    out = vals.reshape(nb, NF4_BLOCK) * am[:, None]
    return out.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# AWQ-style groupwise int4 quantization
# ---------------------------------------------------------------------------

AWQ_GROUP = 64  # rows (input-dim) per scale group


def awq_quantize(w: np.ndarray, act_scale=None):
    """Groupwise symmetric int4 quantization with activation-aware
    per-input-channel equalization (the AWQ idea: scale salient channels
    up before quantization so they get a finer effective step; divide the
    equalization back out at dequant time).

    w: (din, dout) float. Returns dict:
      codes  (din//2, dout) uint8 — rows 2i (hi nibble) and 2i+1 (lo nibble)
      scales (din//AWQ_GROUP, dout) float32 — per-(group, out-channel)
      eq     (din,) float32 — per-input-channel equalization (sqrt act scale)
    Requires din % AWQ_GROUP == 0.
    """
    din, dout = w.shape
    assert din % AWQ_GROUP == 0, (din, AWQ_GROUP)
    w = np.asarray(w, np.float32)
    if act_scale is None:
        act_scale = np.ones(din, np.float32)
    s_eq = np.sqrt(np.maximum(np.asarray(act_scale, np.float32), 1e-6)).astype(np.float32)
    g = din // AWQ_GROUP
    weq = w * s_eq[:, None]
    wg = weq.reshape(g, AWQ_GROUP, dout)
    absmax = np.maximum(np.abs(wg).max(axis=1), 1e-12)  # (g, dout)
    scales = (absmax / 7.0).astype(np.float32)
    q = np.clip(np.round(wg / scales[:, None, :]), -8, 7).astype(np.int32)
    q = q.reshape(din, dout)
    u = (q + 8).astype(np.uint8)
    hi = u[0::2, :]
    lo = u[1::2, :]
    codes = ((hi << 4) | lo).astype(np.uint8)
    return {"codes": codes, "scales": scales, "eq": s_eq}


def awq_dequant_ref(codes, scales, eq):
    """Reference dequantization: w = q * scales[group] / eq[row]."""
    codes = jnp.asarray(codes)
    hi = (codes >> 4).astype(jnp.int32) - 8
    lo = (codes & 0xF).astype(jnp.int32) - 8
    din2, dout = codes.shape
    q = jnp.stack([hi, lo], axis=1).reshape(din2 * 2, dout).astype(jnp.float32)
    g = scales.shape[0]
    rep = (din2 * 2) // g
    s = jnp.repeat(jnp.asarray(scales), rep, axis=0)
    return q * s / jnp.asarray(eq)[:, None]
