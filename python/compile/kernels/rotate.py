"""Pallas kernel: block-diagonal input rotation — the OFTv2 hot path.

Input-centric OFT (§3.2 of the paper): instead of merging R into the
weight (a cubic matrix-matrix product), apply R to the *input*:

    y[:, i*b:(i+1)*b] = x[:, i*b:(i+1)*b] @ R_i

CUDA -> TPU rethink: the paper's threadblock tiling becomes a 2-D Pallas
grid (row tiles x blocks); each program multiplies a (TM, b) VMEM tile of
x by one (b, b) R block on the MXU. The BlockSpec index maps express the
HBM<->VMEM schedule.

The rotation is wrapped in jax.custom_vjp so the train-step graph can
differentiate through it: the backward pass reuses the same kernel with
R^T (for dx) and a per-block reduce kernel (for dR). Gradients w.r.t. the
packed skew parameters then flow through the (jnp, differentiable) CNP
build in ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rotate_kernel(x_ref, r_ref, o_ref):
    o_ref[...] = x_ref[...] @ r_ref[0]


def _pick_tm(m: int) -> int:
    for tm in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if m % tm == 0:
            return tm
    return 1


def _rotate_call(x: jax.Array, r_blocks: jax.Array) -> jax.Array:
    m, d = x.shape
    nb, b, _ = r_blocks.shape
    assert nb * b == d, (nb, b, d)
    tm = _pick_tm(m)
    return pl.pallas_call(
        _rotate_kernel,
        grid=(m // tm, nb),
        in_specs=[
            pl.BlockSpec((tm, b), lambda i, j: (i, j)),
            pl.BlockSpec((1, b, b), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=True,
    )(x, r_blocks)


def _grad_r_kernel(x_ref, dy_ref, o_ref):
    t = pl.program_id(1)  # row-tile (reduction) axis — fastest varying
    contrib = x_ref[...].T @ dy_ref[...]

    @pl.when(t == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    o_ref[0] += contrib


def _grad_r_call(x: jax.Array, dy: jax.Array, nb: int, b: int) -> jax.Array:
    """dR_j = sum_rows x[:, jb:jb+b]^T dy[:, jb:jb+b] via a row-tiled
    accumulation. The reduction (row-tile) axis is the *last* grid axis so
    revisits of the same output block are consecutive and the (b, b)
    accumulator stays resident in VMEM."""
    m, d = x.shape
    tm = _pick_tm(m)
    return pl.pallas_call(
        _grad_r_kernel,
        grid=(nb, m // tm),
        in_specs=[
            pl.BlockSpec((tm, b), lambda j, t: (t, j)),
            pl.BlockSpec((tm, b), lambda j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((1, b, b), lambda j, t: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, b, b), x.dtype),
        interpret=True,
    )(x, dy)


@jax.custom_vjp
def block_rotate(x: jax.Array, r_blocks: jax.Array) -> jax.Array:
    """y = blockdiag(R) applied to rows of x. x (m, d), r_blocks (nb, b, b)."""
    return _rotate_call(x, r_blocks)


def _fwd(x, r_blocks):
    return _rotate_call(x, r_blocks), (x, r_blocks)


def _bwd(res, dy):
    x, r_blocks = res
    nb, b, _ = r_blocks.shape
    rt = jnp.swapaxes(r_blocks, -1, -2)
    dx = _rotate_call(dy, rt)
    dr = _grad_r_call(x, dy, nb, b)
    return dx, dr


block_rotate.defvjp(_fwd, _bwd)


def rotate_nd(x: jax.Array, r_blocks: jax.Array) -> jax.Array:
    """block_rotate over the last axis of an arbitrarily-batched input."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    y = block_rotate(x.reshape(-1, d), r_blocks)
    return y.reshape(*lead, d)


def flops_per_row(d: int, b: int) -> int:
    """MACs per input row: d*b (vs d*d for a dense rotation, and the
    d*d*n *matrix-matrix* merge of weight-centric OFT)."""
    return d * b


def vmem_bytes(tm: int, b: int) -> int:
    """f32 VMEM working set per program: x tile + R block + out tile."""
    return 4 * (tm * b + b * b + tm * b)
