"""L2: decoder-only transformer with pluggable PEFT adapters (OFTv2 paper).

Every method from the paper is a first-class `method` on the same model:

  full        all parameters trainable ("pretraining" for the harness)
  none        frozen base (baseline evaluation)
  lora        W x + (alpha/r) B A x                      [Hu et al. 2022]
  oft_merged  (R W) x  — weight-centric OFT, cubic merge [Qiu et al. 2023]
  oft_v2      W (R^T x) — input-centric OFTv2, matrix-free (this paper)
  qlora       LoRA over NF4/AWQ-quantized frozen weights [Dettmers 2023]
  qoft        OFTv2 over NF4/AWQ-quantized frozen weights (this paper)

The train step differentiates through the Pallas block-rotate kernel via
its custom VJP; CNP (Cayley-Neumann) is built with the differentiable jnp
reference. Inference graphs (eval_loss / logits_last) run the full Pallas
path (cnp.cnp_build + rotate).

Parameters are name-keyed dicts; graph input order is the sorted name
order recorded in manifest.json (see aot.py) — the Rust coordinator
uploads buffers in exactly that order and never reorders.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelCfg
from .kernels import awq as awq_k
from .kernels import cnp as cnp_k
from .kernels import nf4 as nf4_k
from .kernels import ref
from .kernels.rotate import rotate_nd

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------------------
# Parameter inventory
# ---------------------------------------------------------------------------


def linear_names(cfg: ModelCfg):
    """(name, din, dout) for every adapted linear layer."""
    out = []
    d, f = cfg.d_model, cfg.d_ff
    for i in range(cfg.n_layers):
        for proj in ("wq", "wk", "wv", "wo"):
            out.append((f"layers.{i}.attn.{proj}", d, d))
        out.append((f"layers.{i}.mlp.up", d, f))
        out.append((f"layers.{i}.mlp.down", f, d))
    return out


def base_param_specs(cfg: ModelCfg):
    """name -> (shape, init) for the base (pretrained) parameters."""
    d, f, v, t = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    specs = {
        "embed.tok": ((v, d), ("normal", 0.02)),
        "embed.pos": ((t, d), ("normal", 0.01)),
        "final_norm": ((d,), ("ones", 0.0)),
        "lm_head": ((d, v), ("normal", 0.02)),
    }
    for i in range(cfg.n_layers):
        specs[f"layers.{i}.attn.norm"] = ((d,), ("ones", 0.0))
        specs[f"layers.{i}.mlp.norm"] = ((d,), ("ones", 0.0))
    for name, din, dout in linear_names(cfg):
        specs[name] = ((din, dout), ("normal", 0.02))
    return specs


def adapter_param_specs(cfg: ModelCfg):
    """name -> (shape, init) for the trainable adapter parameters."""
    specs = {}
    if cfg.method in ("lora", "qlora"):
        r = cfg.lora_r
        for name, din, dout in linear_names(cfg):
            specs[f"{name}.lora_a"] = ((din, r), ("normal", 0.01))
            specs[f"{name}.lora_b"] = ((r, dout), ("zeros", 0.0))
    elif cfg.method in ("oft_merged", "oft_v2", "qoft"):
        b = cfg.block_b
        p = ref.packed_dim(b)
        for name, din, dout in linear_names(cfg):
            specs[f"{name}.oft_q"] = ((din // b, p), ("zeros", 0.0))
    return specs


def trainable_names(cfg: ModelCfg):
    if cfg.method == "full":
        return sorted(base_param_specs(cfg).keys())
    if cfg.method == "none":
        return []
    return sorted(adapter_param_specs(cfg).keys())


def frozen_names(cfg: ModelCfg):
    """Base parameters kept in f32 as graph inputs (everything for
    full-precision methods; all *non-quantized* tensors for q-methods)."""
    if cfg.method == "full":
        return []
    base = sorted(base_param_specs(cfg).keys())
    if cfg.method in ("qlora", "qoft"):
        quantized = {name for name, _, _ in linear_names(cfg)}
        base = [n for n in base if n not in quantized]
    return base


def quantized_specs(cfg: ModelCfg):
    """Packed-tensor specs for quantized base weights, in graph order.

    Returns list of (input_name, base_name, shape, dtype) with dtype one of
    u8 | i8 | f32. Shapes follow the packing in kernels/ref.py (NF4) and
    kernels/awq.py, and are mirrored by rust/src/quant.
    """
    if cfg.method not in ("qlora", "qoft"):
        return []
    out = []
    for name, din, dout in linear_names(cfg):
        n = din * dout
        if cfg.quant == "nf4":
            nbytes, nblocks, ngroups = nf4_k.packed_sizes(n)
            out.append((f"{name}.nf4_codes", name, (nbytes,), "u8"))
            out.append((f"{name}.nf4_absmax_q", name, (nblocks,), "i8"))
            out.append((f"{name}.nf4_absmax_s", name, (ngroups,), "f32"))
            out.append((f"{name}.nf4_offset", name, (1,), "f32"))
        else:  # awq
            g = din // ref.AWQ_GROUP
            out.append((f"{name}.awq_codes", name, (din // 2, dout), "u8"))
            out.append((f"{name}.awq_scales", name, (g, dout), "f32"))
            out.append((f"{name}.awq_eq", name, (din,), "f32"))
    return out


# ---------------------------------------------------------------------------
# Orthogonal-matrix construction
# ---------------------------------------------------------------------------


def schulz_inverse(a: jax.Array, iters: int) -> jax.Array:
    """Newton-Schulz iteration X <- X(2I - A X) for A^{-1} (batched).

    Used for the *exact* Cayley baseline inside AOT graphs: LAPACK-backed
    jnp.linalg.solve lowers to custom-calls the standalone PJRT CPU plugin
    does not register, so we use a pure-matmul inverse instead. Converges
    quadratically for ||I - A|| < 1, which holds for A = I - Q in the OFT
    regime (Q starts at 0 and stays small — same argument as the paper's
    Neumann convergence note).
    """
    b = a.shape[-1]
    eye = jnp.broadcast_to(jnp.eye(b, dtype=a.dtype), a.shape)
    x = eye
    for _ in range(iters):
        x = x @ (2.0 * eye - a @ x)
    return x


def cayley_schulz(q_packed: jax.Array, b: int, iters: int) -> jax.Array:
    """Exact Cayley R = (I+Q)(I-Q)^{-1} with a Newton-Schulz inverse."""
    q = ref.skew_from_packed(q_packed, b)
    eye = jnp.broadcast_to(jnp.eye(b, dtype=q.dtype), q.shape)
    return (eye + q) @ schulz_inverse(eye - q, iters)


def build_r_blocks(cfg: ModelCfg, q_packed: jax.Array, *, trainable: bool):
    """(nb, p) packed -> (nb, b, b) orthogonal blocks, method-appropriate.

    trainable=True (train step) uses differentiable jnp builds; inference
    graphs use the fused Pallas CNP kernel.
    """
    b = cfg.block_b
    if cfg.method == "oft_merged" and cfg.cayley == "schulz":
        return cayley_schulz(q_packed, b, cfg.schulz_iters)
    if trainable:
        return ref.cayley_neumann(q_packed, b, cfg.neumann_k)
    return cnp_k.cnp_build(q_packed, b, cfg.neumann_k)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _base_weight(cfg: ModelCfg, params: dict, name: str) -> jax.Array:
    """Fetch a linear weight: f32 input, or dequantized NF4/AWQ packs."""
    if cfg.method in ("qlora", "qoft"):
        if cfg.quant == "nf4":
            din, dout = _linear_shape(cfg, name)
            return nf4_k.nf4_dequant(
                params[f"{name}.nf4_codes"],
                params[f"{name}.nf4_absmax_q"],
                params[f"{name}.nf4_absmax_s"],
                params[f"{name}.nf4_offset"],
                din * dout,
                (din, dout),
            )
        return awq_k.awq_dequant(
            params[f"{name}.awq_codes"],
            params[f"{name}.awq_scales"],
            params[f"{name}.awq_eq"],
        )
    return params[name]


@functools.lru_cache(maxsize=None)
def _linear_shapes_cached(cfg: ModelCfg):
    return {name: (din, dout) for name, din, dout in linear_names(cfg)}


def _linear_shape(cfg: ModelCfg, name: str):
    return _linear_shapes_cached(cfg)[name]


def adapted_linear(cfg: ModelCfg, params: dict, name: str, x: jax.Array, *, trainable: bool) -> jax.Array:
    """Apply one adapted linear layer to x (..., din) -> (..., dout)."""
    w = _base_weight(cfg, params, name)
    method = cfg.method
    if method in ("lora", "qlora"):
        a, bb = params[f"{name}.lora_a"], params[f"{name}.lora_b"]
        scale = cfg.lora_alpha / cfg.lora_r
        return x @ w + ((x @ a) @ bb) * scale
    if method in ("oft_v2", "qoft"):
        # Input-centric (the paper's contribution): z = W^T (R^T x).
        # Training graph: the differentiable jnp rotate — XLA fuses the
        # per-block einsum into batched GEMMs, the CPU analogue of the
        # cuBLAS path the paper benchmarks (Pallas interpret=True is a
        # serial emulation whose timing is not TPU-indicative; see
        # DESIGN.md §8). Inference graphs run the real Pallas kernel.
        r_blocks = build_r_blocks(cfg, params[f"{name}.oft_q"], trainable=trainable)
        if trainable:
            return _rotate_nd_ref(x, r_blocks) @ w
        return rotate_nd(x, r_blocks) @ w
    if method == "oft_merged":
        # Weight-centric baseline: materialize blockdiag(R) @ W each
        # forward — the cubic matrix-matrix product OFTv2 eliminates.
        r_blocks = build_r_blocks(cfg, params[f"{name}.oft_q"], trainable=trainable)
        din = w.shape[0]
        r_dense = ref.blockdiag_dense(r_blocks, din)
        return x @ (r_dense @ w)
    return x @ w  # full / none


def _rotate_nd_ref(x: jax.Array, r_blocks: jax.Array) -> jax.Array:
    """jnp block rotation over the last axis (differentiable train path)."""
    lead, d = x.shape[:-1], x.shape[-1]
    y = ref.block_rotate(x.reshape(-1, d), r_blocks)
    return y.reshape(*lead, d)


def rmsnorm(x: jax.Array, g: jax.Array) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def forward(cfg: ModelCfg, params: dict, tokens: jax.Array, *, trainable: bool) -> jax.Array:
    """tokens (B, T) int32 -> logits (B, T, V)."""
    bsz, t = tokens.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = jnp.take(params["embed.tok"], tokens, axis=0)
    x = x + params["embed.pos"][:t][None, :, :]
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    neg = jnp.float32(-1e9)
    for i in range(cfg.n_layers):
        pre = f"layers.{i}"
        xn = rmsnorm(x, params[f"{pre}.attn.norm"])
        q = adapted_linear(cfg, params, f"{pre}.attn.wq", xn, trainable=trainable)
        k = adapted_linear(cfg, params, f"{pre}.attn.wk", xn, trainable=trainable)
        v = adapted_linear(cfg, params, f"{pre}.attn.wv", xn, trainable=trainable)
        q = q.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)
        k = k.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)
        v = v.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(bsz, t, d)
        x = x + adapted_linear(cfg, params, f"{pre}.attn.wo", o, trainable=trainable)
        xn = rmsnorm(x, params[f"{pre}.mlp.norm"])
        hdn = adapted_linear(cfg, params, f"{pre}.mlp.up", xn, trainable=trainable)
        hdn = jax.nn.gelu(hdn)
        x = x + adapted_linear(cfg, params, f"{pre}.mlp.down", hdn, trainable=trainable)
    x = rmsnorm(x, params["final_norm"])
    return x @ params["lm_head"]


def loss_fn(cfg: ModelCfg, params: dict, tokens: jax.Array, mask: jax.Array):
    """tokens (B, T+1) i32, mask (B, T) f32 -> (mean_nll, token_count)."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = forward(cfg, params, inputs, trainable=True)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    total = jnp.sum(nll * mask)
    count = jnp.maximum(jnp.sum(mask), 1.0)
    return total / count, count


# ---------------------------------------------------------------------------
# Graphs exported by aot.py
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelCfg):
    """Returns f(trainables, m, v, fixed, tokens, mask, lr, t) ->
    (new_trainables + new_m + new_v + [loss]) as one flat tuple.

    `trainables`/`m`/`v` are lists ordered by trainable_names(cfg);
    `fixed` is frozen f32 params followed by quantized packs (graph order
    per manifest). Adam with bias correction; frozen tensors pass through
    untouched (they are *inputs*, so artifacts stay small and upload
    happens once — see DESIGN.md §7).
    """
    tn = trainable_names(cfg)
    fixed_names = frozen_names(cfg) + [q[0] for q in quantized_specs(cfg)]

    def step(trainables, m, v, fixed, tokens, mask, lr, t):
        params = dict(zip(tn, trainables))
        params.update(dict(zip(fixed_names, fixed)))

        def scalar_loss(tr_list):
            p = dict(params)
            p.update(dict(zip(tn, tr_list)))
            return loss_fn(cfg, p, tokens, mask)[0]

        loss, grads = jax.value_and_grad(scalar_loss)(list(trainables))
        b1, b2, eps = ADAM_B1, ADAM_B2, ADAM_EPS
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t
        new_p, new_m, new_v = [], [], []
        for p, mm, vv, g in zip(trainables, m, v, grads):
            mm = b1 * mm + (1.0 - b1) * g
            vv = b2 * vv + (1.0 - b2) * (g * g)
            mhat = mm / bc1
            vhat = vv / bc2
            new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mm)
            new_v.append(vv)
        return tuple(new_p + new_m + new_v + [loss])

    return step


def make_eval_loss(cfg: ModelCfg):
    """f(trainables, fixed, tokens, mask) -> (sum_nll, token_count)."""
    tn = trainable_names(cfg)
    fixed_names = frozen_names(cfg) + [q[0] for q in quantized_specs(cfg)]

    def eval_loss(trainables, fixed, tokens, mask):
        params = dict(zip(tn, trainables))
        params.update(dict(zip(fixed_names, fixed)))
        inputs = tokens[:, :-1]
        targets = tokens[:, 1:]
        logits = forward(cfg, params, inputs, trainable=False)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return (jnp.sum(nll * mask), jnp.sum(mask))

    return eval_loss


def make_logits_last(cfg: ModelCfg):
    """f(trainables, fixed, tokens (1, T) i32, cur_len i32) -> (logits (V,),).

    Greedy decoding driver: the Rust coordinator appends argmax(logits)
    and re-invokes. Causality makes padded positions > cur_len-1 inert.
    """
    tn = trainable_names(cfg)
    fixed_names = frozen_names(cfg) + [q[0] for q in quantized_specs(cfg)]

    def logits_last(trainables, fixed, tokens, cur_len):
        params = dict(zip(tn, trainables))
        params.update(dict(zip(fixed_names, fixed)))
        logits = forward(cfg, params, tokens, trainable=False)  # (1, T, V)
        idx = jnp.clip(cur_len - 1, 0, cfg.seq_len - 1)
        row = jax.lax.dynamic_slice(logits, (0, idx, 0), (1, 1, cfg.vocab))
        return (row.reshape(cfg.vocab),)

    return logits_last
