import os
import sys

import numpy as np
import pytest

_here = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_here, ".."))
sys.path.insert(0, _here)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
