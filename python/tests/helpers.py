"""Shared test helpers (importable module, unlike conftest)."""

import numpy as np


def init_array(shape, kind, std, rng):
    if kind == "zeros":
        return np.zeros(shape, np.float32)
    if kind == "ones":
        return np.ones(shape, np.float32)
    return (rng.standard_normal(shape) * std).astype(np.float32)


def init_params(specs, names, rng):
    """Initialize a list of arrays for `names` from a {name: (shape, init)}
    spec dict — the python mirror of the Rust coordinator's initializer."""
    out = []
    for n in names:
        shape, (kind, std) = specs[n]
        out.append(init_array(shape, kind, std, rng))
    return out
