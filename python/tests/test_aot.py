"""AOT lowering contract: manifests must exactly describe the HLO graphs."""

import json
import re

import pytest

from compile import aot
from compile import model as M
from compile.configs import PRESETS


@pytest.fixture(scope="module")
def tiny_bundle(tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    aot.lower_bundle("tiny", "oft_v2", "none", str(root))
    return root / "tiny_oft_v2"


def hlo_entry_param_count(path) -> int:
    """Count parameter instructions in the ENTRY computation of HLO text."""
    text = path.read_text()
    entry = text[text.index("ENTRY") :]
    return len(re.findall(r"=\s*\S+\s+parameter\(\d+\)", entry))


def test_manifest_schema(tiny_bundle):
    man = json.loads((tiny_bundle / "manifest.json").read_text())
    for key in ("tag", "method", "quant", "model", "inputs", "artifacts", "adam"):
        assert key in man
    assert man["method"] == "oft_v2"
    assert [d["name"] for d in man["inputs"]["data"]] == ["tokens", "mask", "lr", "t"]
    for e in man["inputs"]["trainable"]:
        assert e["init"][0] in ("normal", "zeros", "ones")


def test_train_step_input_count_matches_manifest(tiny_bundle):
    man = json.loads((tiny_bundle / "manifest.json").read_text())
    nt = len(man["inputs"]["trainable"])
    nf = len(man["inputs"]["frozen"])
    nq = len(man["inputs"]["quantized"])
    want = 3 * nt + nf + nq + 4  # params,m,v + fixed + tokens,mask,lr,t
    got = hlo_entry_param_count(tiny_bundle / "train_step.hlo.txt")
    assert got == want, (got, want)


def test_eval_and_logits_input_counts(tiny_bundle):
    man = json.loads((tiny_bundle / "manifest.json").read_text())
    nt = len(man["inputs"]["trainable"])
    nfq = len(man["inputs"]["frozen"]) + len(man["inputs"]["quantized"])
    assert hlo_entry_param_count(tiny_bundle / "eval_loss.hlo.txt") == nt + nfq + 2
    assert hlo_entry_param_count(tiny_bundle / "logits_last.hlo.txt") == nt + nfq + 2


def test_manifest_trainable_order_is_sorted(tiny_bundle):
    man = json.loads((tiny_bundle / "manifest.json").read_text())
    names = [e["name"] for e in man["inputs"]["trainable"]]
    assert names == sorted(names)
    assert names == M.trainable_names(PRESETS["tiny"].with_method("oft_v2"))


def test_quantized_manifest_shapes():
    cfg = PRESETS["tiny"].with_method("qoft", "nf4")
    specs = M.quantized_specs(cfg)
    # 4 packed tensors per adapted linear
    assert len(specs) == 4 * len(M.linear_names(cfg))
    by_kind = {}
    for name, base, shape, dt in specs:
        kind = name.split(".")[-1]
        by_kind.setdefault(kind, []).append((shape, dt))
    assert all(dt == "u8" for _, dt in by_kind["nf4_codes"])
    assert all(dt == "i8" for _, dt in by_kind["nf4_absmax_q"])
    assert all(dt == "f32" for _, dt in by_kind["nf4_absmax_s"])


def test_bundle_tags():
    assert aot.bundle_tag("tiny", "oft_v2", "none") == "tiny_oft_v2"
    assert aot.bundle_tag("bench", "qoft", "nf4") == "bench_qoft_nf4"
