"""Pallas CNP / skew kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model as M
from compile.kernels import cnp, ref

SET = settings(max_examples=20, deadline=None)


def rand_packed(nb, b, scale, seed):
    r = np.random.default_rng(seed)
    return (r.standard_normal((nb, ref.packed_dim(b))) * scale).astype(np.float32)


@SET
@given(
    b=st.sampled_from([2, 4, 8, 16, 32]),
    nb=st.integers(1, 6),
    k=st.integers(1, 8),
    scale=st.floats(0.0, 0.3),
    seed=st.integers(0, 2**31 - 1),
)
def test_cnp_kernel_matches_ref(b, nb, k, scale, seed):
    qp = rand_packed(nb, b, scale, seed)
    got = cnp.cnp_build(jnp.asarray(qp), b, k)
    want = ref.cayley_neumann(jnp.asarray(qp), b, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@SET
@given(
    b=st.sampled_from([2, 4, 8, 16, 32, 64]),
    nb=st.integers(1, 4),
    scale=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_skew_kernel_matches_ref(b, nb, scale, seed):
    qp = rand_packed(nb, b, scale, seed)
    got = cnp.skew_build(jnp.asarray(qp), b)
    want = ref.skew_from_packed(jnp.asarray(qp), b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


@SET
@given(
    b=st.sampled_from([4, 8, 16]),
    scale=st.floats(0.01, 0.6),
    seed=st.integers(0, 2**31 - 1),
)
def test_skew_is_skew_symmetric(b, scale, seed):
    qp = rand_packed(3, b, scale, seed)
    q = np.asarray(cnp.skew_build(jnp.asarray(qp), b))
    np.testing.assert_allclose(q, -np.swapaxes(q, -1, -2), atol=0)
    assert np.all(np.diagonal(q, axis1=-2, axis2=-1) == 0)


def test_packed_roundtrip():
    qp = rand_packed(5, 16, 0.5, 7)
    q = ref.skew_from_packed(jnp.asarray(qp), 16)
    back = ref.packed_from_skew(q)
    np.testing.assert_allclose(np.asarray(back), qp, atol=0)


def test_identity_at_zero():
    """Q=0 must give R=I exactly — OFT's 'start from the pretrained
    model' initialization (paper §3.3)."""
    for k in (1, 3, 8):
        r = np.asarray(cnp.cnp_build(jnp.zeros((4, ref.packed_dim(16)), jnp.float32), 16, k))
        np.testing.assert_array_equal(r, np.broadcast_to(np.eye(16, dtype=np.float32), (4, 16, 16)))


def test_orthogonality_error_decreases_with_k():
    """CNP error ||R^T R - I|| shrinks as Neumann terms are added — the
    paper's 'larger k leads to better approximation'. Because Q is
    skew-symmetric the truncation residual alternates in parity, so the
    error oscillates between odd and even k; the guarantee is monotone
    along each parity class (k vs k+2). The cnp_vs_cayley bench plots
    this parity effect."""
    qp = rand_packed(8, 16, 0.04, 3)
    errs = []
    for k in range(1, 9):
        r = cnp.cnp_build(jnp.asarray(qp), 16, k)
        errs.append(float(ref.orthogonality_error(r)))
    assert errs[-1] < errs[0] * 1e-2, errs
    assert all(errs[i + 2] <= errs[i] * 1.05 for i in range(len(errs) - 2)), errs


def test_exact_cayley_is_orthogonal():
    qp = rand_packed(6, 16, 0.9, 11)
    r = ref.cayley_exact(jnp.asarray(qp), 16)
    assert float(ref.orthogonality_error(r)) < 1e-4


def test_schulz_matches_solve():
    """The AOT-safe Newton-Schulz exact Cayley equals the LAPACK one
    (within the Schulz convergence radius ||Q||_2 < 1, which is the OFT
    operating regime — Q starts at 0 and stays small)."""
    qp = rand_packed(6, 16, 0.05, 13)
    a = M.cayley_schulz(jnp.asarray(qp), 16, 12)
    b_ = ref.cayley_exact(jnp.asarray(qp), 16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


def test_cnp_approaches_exact_cayley():
    qp = rand_packed(4, 8, 0.05, 17)
    exact = np.asarray(ref.cayley_exact(jnp.asarray(qp), 8))
    err_prev = np.inf
    for k in (1, 2, 4, 8):
        got = np.asarray(cnp.cnp_build(jnp.asarray(qp), 8, k))
        err = np.abs(got - exact).max()
        assert err < err_prev + 1e-7
        err_prev = err
    assert err_prev < 1e-5


def test_determinant_is_plus_one():
    """Cayley produces rotations (SO(b)), not reflections (paper §3.3)."""
    qp = rand_packed(5, 8, 0.4, 23)
    r = np.asarray(ref.cayley_exact(jnp.asarray(qp), 8))
    np.testing.assert_allclose(np.linalg.det(r), np.ones(5), atol=1e-5)


@pytest.mark.parametrize("b,k", [(16, 5), (32, 5), (64, 5), (32, 8)])
def test_vmem_estimate_under_budget(b, k):
    """Structural perf check: one CNP program's working set must stay far
    below a TPU core's ~16MB VMEM (DESIGN.md §Hardware adaptation)."""
    assert cnp.vmem_bytes(b, k) < 1 << 20
