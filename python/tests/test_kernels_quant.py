"""NF4 / AWQ quantization: Pallas kernels vs oracles + error invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import awq, nf4, ref

SET = settings(max_examples=15, deadline=None)


def nf4_roundtrip(w):
    qz = ref.nf4_quantize(w)
    wd = ref.nf4_dequant_ref(
        qz["codes"], qz["absmax_q"], qz["absmax_s"], qz["offset"], qz["n"], qz["shape"]
    )
    return np.asarray(wd), qz


@SET
@given(
    rows=st.sampled_from([1, 7, 64, 128]),
    cols=st.sampled_from([16, 64, 256]),
    scale=st.floats(0.01, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_nf4_kernel_matches_ref(rows, cols, scale, seed):
    w = (np.random.default_rng(seed).standard_normal((rows, cols)) * scale).astype(np.float32)
    qz = ref.nf4_quantize(w)
    want = ref.nf4_dequant_ref(
        qz["codes"], qz["absmax_q"], qz["absmax_s"], qz["offset"], qz["n"], qz["shape"]
    )
    got = nf4.nf4_dequant(
        jnp.asarray(qz["codes"]),
        jnp.asarray(qz["absmax_q"]),
        jnp.asarray(qz["absmax_s"]),
        jnp.asarray(qz["offset"]),
        qz["n"],
        tuple(qz["shape"]),
    )
    # rtol covers fp32 fma-order differences at large scales
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6, rtol=1e-6)


@SET
@given(scale=st.floats(0.01, 5.0), seed=st.integers(0, 2**31 - 1))
def test_nf4_error_bound(scale, seed):
    """Per-element |w - dq(q(w))| <= absmax * (max code gap / 2) + dq slack.
    The widest NF4 gap is |-1.0 - -0.696| ≈ 0.304."""
    w = (np.random.default_rng(seed).standard_normal((64, 64)) * scale).astype(np.float32)
    wd, _ = nf4_roundtrip(w)
    gap = np.max(np.diff(ref.NF4_CODE)) / 2
    blocks = np.abs(w.reshape(-1, ref.NF4_BLOCK)).max(axis=1)
    bound = np.repeat(blocks, ref.NF4_BLOCK).reshape(w.shape) * gap * 1.10 + 1e-4
    assert np.all(np.abs(wd - w) <= bound)


def test_nf4_preserves_dynamic_range():
    """Dequantized values never exceed the (reconstructed) block absmax —
    the property §4 leans on for QOFT's requantization argument."""
    w = np.random.default_rng(0).standard_normal((128, 128)).astype(np.float32)
    wd, qz = nf4_roundtrip(w)
    blocks = np.abs(w.reshape(-1, ref.NF4_BLOCK)).max(axis=1)
    # allow the double-quant absmax reconstruction slack
    assert np.all(np.abs(wd.reshape(-1, ref.NF4_BLOCK)).max(axis=1) <= blocks * 1.05 + 1e-5)


def test_nf4_codebook_pinned():
    """The 16 NormalFloat4 levels are bit-for-bit the bitsandbytes ones."""
    assert ref.NF4_CODE[0] == -1.0 and ref.NF4_CODE[-1] == 1.0 and ref.NF4_CODE[7] == 0.0
    assert np.all(np.diff(ref.NF4_CODE) > 0)
    assert abs(ref.NF4_CODE[8] - 0.07958029955625534) < 1e-12


def test_nf4_zero_input():
    wd, _ = nf4_roundtrip(np.zeros((64, 64), np.float32))
    np.testing.assert_allclose(wd, 0.0, atol=1e-6)


@SET
@given(
    din=st.sampled_from([64, 128, 256]),
    dout=st.sampled_from([16, 64, 96]),
    scale=st.floats(0.01, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_awq_kernel_matches_ref(din, dout, scale, seed):
    w = (np.random.default_rng(seed).standard_normal((din, dout)) * scale).astype(np.float32)
    qz = ref.awq_quantize(w)
    want = ref.awq_dequant_ref(qz["codes"], qz["scales"], qz["eq"])
    got = awq.awq_dequant(
        jnp.asarray(qz["codes"]), jnp.asarray(qz["scales"]), jnp.asarray(qz["eq"])
    )
    # rtol covers fp32 fma-order differences at large scales
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6, rtol=1e-6)


@SET
@given(scale=st.floats(0.05, 2.0), seed=st.integers(0, 2**31 - 1))
def test_awq_error_bound(scale, seed):
    """Symmetric int4: |err| <= group-absmax / 7 / 2 per element."""
    w = (np.random.default_rng(seed).standard_normal((128, 32)) * scale).astype(np.float32)
    qz = ref.awq_quantize(w)
    wd = np.asarray(ref.awq_dequant_ref(qz["codes"], qz["scales"], qz["eq"]))
    g = 128 // ref.AWQ_GROUP
    am = np.abs(w.reshape(g, ref.AWQ_GROUP, 32)).max(axis=1)
    bound = np.repeat(am / 7.0 / 2.0 * 1.01 + 1e-6, ref.AWQ_GROUP, axis=0)
    assert np.all(np.abs(wd - w) <= bound)


def test_awq_activation_aware_helps_salient_channels():
    """Scaling a salient input channel group up before quantization must
    reduce its reconstruction error (the AWQ premise)."""
    rng = np.random.default_rng(4)
    w = rng.standard_normal((128, 64)).astype(np.float32)
    w[:ref.AWQ_GROUP] *= 0.05  # salient-but-small rows get drowned by others
    act = np.ones(128, np.float32)
    plain = ref.awq_dequant_ref(**ref.awq_quantize(w))
    act_aware = act.copy()
    act_aware[:ref.AWQ_GROUP] = 16.0  # mark rows as salient
    tuned = ref.awq_dequant_ref(**ref.awq_quantize(w, act_scale=act_aware))
    err_plain = np.abs(np.asarray(plain)[:ref.AWQ_GROUP] - w[:ref.AWQ_GROUP]).mean()
    err_tuned = np.abs(np.asarray(tuned)[:ref.AWQ_GROUP] - w[:ref.AWQ_GROUP]).mean()
    assert err_tuned <= err_plain
