"""Pallas block-rotate kernel (OFTv2 hot path) vs oracle + VJP checks."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref, rotate

SET = settings(max_examples=20, deadline=None)


def rand_r(nb, b, seed, scale=None):
    r = np.random.default_rng(seed)
    # keep ||Q||_2 well inside the Neumann convergence radius (paper §3.3)
    scale = 0.2 / np.sqrt(b) if scale is None else scale
    qp = (r.standard_normal((nb, ref.packed_dim(b))) * scale).astype(np.float32)
    return ref.cayley_neumann(jnp.asarray(qp), b, 6), jnp.asarray(qp)


@SET
@given(
    m=st.sampled_from([1, 2, 3, 5, 8, 16, 64, 100]),
    b=st.sampled_from([2, 4, 8, 16, 32]),
    nb=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_rotate_matches_ref(m, b, nb, seed):
    r_blocks, _ = rand_r(nb, b, seed)
    x = np.random.default_rng(seed + 1).standard_normal((m, nb * b)).astype(np.float32)
    got = rotate.block_rotate(jnp.asarray(x), r_blocks)
    want = ref.block_rotate(jnp.asarray(x), r_blocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@SET
@given(
    m=st.sampled_from([2, 8, 32]),
    b=st.sampled_from([4, 8, 16]),
    nb=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_rotate_preserves_norm(m, b, nb, seed):
    """Orthogonal R must preserve per-row L2 norm — the hyperspherical-
    energy invariance OFT is built on."""
    r_blocks, qp = rand_r(nb, b, seed, scale=0.02)
    x = np.random.default_rng(seed + 1).standard_normal((m, nb * b)).astype(np.float32)
    y = np.asarray(rotate.block_rotate(jnp.asarray(x), r_blocks))
    np.testing.assert_allclose(
        np.linalg.norm(y, axis=1), np.linalg.norm(x, axis=1), rtol=1e-3
    )


def test_rotate_identity():
    eye = jnp.broadcast_to(jnp.eye(8, dtype=jnp.float32), (4, 8, 8))
    x = np.random.default_rng(0).standard_normal((16, 32)).astype(np.float32)
    y = rotate.block_rotate(jnp.asarray(x), eye)
    np.testing.assert_array_equal(np.asarray(y), x)


def test_rotate_equals_dense_blockdiag():
    r_blocks, _ = rand_r(4, 8, 5)
    d = 32
    x = np.random.default_rng(6).standard_normal((10, d)).astype(np.float32)
    dense = ref.blockdiag_dense(r_blocks, d)
    want = jnp.asarray(x) @ dense
    got = rotate.block_rotate(jnp.asarray(x), r_blocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@SET
@given(
    m=st.sampled_from([4, 16, 64]),
    b=st.sampled_from([4, 8, 16]),
    nb=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_vjp_matches_ref(m, b, nb, seed):
    """Custom VJP (Pallas bwd kernels) == autodiff of the jnp oracle,
    for both dx and the chained dq through CNP."""
    _, qp = rand_r(nb, b, seed)
    x = np.random.default_rng(seed + 1).standard_normal((m, nb * b)).astype(np.float32)

    def f_kernel(xx, qq):
        return jnp.sum(jnp.sin(rotate.block_rotate(xx, ref.cayley_neumann(qq, b, 4))))

    def f_ref(xx, qq):
        return jnp.sum(jnp.sin(ref.block_rotate(xx, ref.cayley_neumann(qq, b, 4))))

    gx_k, gq_k = jax.grad(f_kernel, argnums=(0, 1))(jnp.asarray(x), qp)
    gx_r, gq_r = jax.grad(f_ref, argnums=(0, 1))(jnp.asarray(x), qp)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gq_k), np.asarray(gq_r), atol=1e-4)


def test_grad_r_kernel_direct():
    """The per-block accumulation kernel computes dR = x^T dy per block."""
    nb, b, m = 3, 8, 40
    rng = np.random.default_rng(2)
    x = rng.standard_normal((m, nb * b)).astype(np.float32)
    dy = rng.standard_normal((m, nb * b)).astype(np.float32)
    got = rotate._grad_r_call(jnp.asarray(x), jnp.asarray(dy), nb, b)
    want = ref.block_rotate_grad_r(jnp.asarray(x), jnp.asarray(dy), nb, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_rotate_nd_batched():
    r_blocks, _ = rand_r(2, 8, 9)
    x = np.random.default_rng(3).standard_normal((2, 5, 16)).astype(np.float32)
    got = rotate.rotate_nd(jnp.asarray(x), r_blocks)
    want = ref.block_rotate(jnp.asarray(x.reshape(10, 16)), r_blocks).reshape(2, 5, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_flops_model():
    """Input-centric cost d*b per row — quadratic-in-d total, vs the
    d*d*n merge (paper §3.2). Pure arithmetic, but keep it pinned."""
    assert rotate.flops_per_row(1024, 32) == 1024 * 32
    assert rotate.flops_per_row(1024, 32) * 128 < 1024 * 1024 * 1024
