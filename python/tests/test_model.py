"""L2 model invariants across PEFT methods."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from compile import model as M
from compile.configs import PRESETS, param_count
from compile.kernels import ref
from helpers import init_array

CFG = PRESETS["tiny"]


def build_params(cfg, rng, scale_adapters=0.0):
    """name -> np array for every model input (f32 params only)."""
    base = M.base_param_specs(cfg)
    ad = M.adapter_param_specs(cfg)
    params = {}
    for n, (shape, (kind, std)) in {**base, **ad}.items():
        params[n] = init_array(shape, kind, std, rng)
    if scale_adapters:
        for n in ad:
            params[n] = (rng.standard_normal(ad[n][0]) * scale_adapters).astype(np.float32)
    return params


def quantize_params(cfg, params, quant):
    """Replace adapted linear weights with packed tensors (mirror of the
    Rust coordinator's quantization step)."""
    out = dict(params)
    for name, din, dout in M.linear_names(cfg):
        w = params[name]
        del out[name]
        if quant == "nf4":
            qz = ref.nf4_quantize(w)
            out[f"{name}.nf4_codes"] = qz["codes"]
            out[f"{name}.nf4_absmax_q"] = qz["absmax_q"]
            out[f"{name}.nf4_absmax_s"] = qz["absmax_s"]
            out[f"{name}.nf4_offset"] = qz["offset"]
        else:
            qz = ref.awq_quantize(w)
            out[f"{name}.awq_codes"] = qz["codes"]
            out[f"{name}.awq_scales"] = qz["scales"]
            out[f"{name}.awq_eq"] = qz["eq"]
    return out


def toks(cfg, rng, bsz=None):
    b = bsz or cfg.batch
    return rng.integers(0, cfg.vocab, size=(b, cfg.seq_len), dtype=np.int64).astype(np.int32)


@pytest.mark.parametrize("method,quant", [
    ("none", "none"), ("full", "none"), ("lora", "none"),
    ("oft_merged", "none"), ("oft_v2", "none"),
    ("qlora", "nf4"), ("qoft", "nf4"), ("qlora", "awq"), ("qoft", "awq"),
])
def test_forward_shapes(method, quant, rng):
    cfg = CFG.with_method(method, quant)
    params = build_params(cfg, rng)
    if quant != "none":
        params = quantize_params(cfg, params, quant)
    t = toks(cfg, rng)
    logits = M.forward(cfg, params, jnp.asarray(t), trainable=False)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("method,quant", [
    ("lora", "none"), ("oft_merged", "none"), ("oft_v2", "none"),
])
def test_adapters_identity_at_init(method, quant, rng):
    """LoRA (B=0) and OFT (Q=0 -> R=I) must reproduce the frozen base
    model exactly at initialization — 'start from the pretrained model'."""
    cfg_base = CFG.with_method("none")
    cfg = CFG.with_method(method, quant)
    params = build_params(cfg, rng)
    t = toks(cfg, rng)
    base_logits = M.forward(cfg_base, params, jnp.asarray(t), trainable=False)
    logits = M.forward(cfg, params, jnp.asarray(t), trainable=False)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(base_logits), atol=1e-5)


def test_oft_v2_equals_oft_merged(rng):
    """Input-centric and weight-centric OFT are the *same function*
    (eq. 1 vs eq. 2 of the paper) when parameterized identically."""
    cfg2 = CFG.with_method("oft_v2")
    cfgm = replace(CFG.with_method("oft_merged"), cayley="neumann")
    params = build_params(cfg2, rng, scale_adapters=0.05)
    t = toks(cfg2, rng)
    l2 = M.forward(cfg2, params, jnp.asarray(t), trainable=False)
    lm = M.forward(cfgm, params, jnp.asarray(t), trainable=False)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(lm), atol=2e-4)


def test_qoft_close_to_oft(rng):
    """QOFT == OFTv2 up to weight-quantization error (§4: the rotation is
    quantization-agnostic)."""
    cfg = CFG.with_method("oft_v2")
    cfgq = CFG.with_method("qoft", "nf4")
    params = build_params(cfg, rng, scale_adapters=0.05)
    qparams = quantize_params(cfg, params, "nf4")
    t = toks(cfg, rng)
    lf = np.asarray(M.forward(cfg, params, jnp.asarray(t), trainable=False))
    lq = np.asarray(M.forward(cfgq, qparams, jnp.asarray(t), trainable=False))
    # correlated but not equal: NF4 is lossy
    corr = np.corrcoef(lf.reshape(-1), lq.reshape(-1))[0, 1]
    assert corr > 0.98, corr
    assert not np.allclose(lf, lq)


def test_trainable_vs_frozen_path_consistency(rng):
    """The differentiable (train) and Pallas (inference) OFT paths must
    produce the same logits."""
    cfg = CFG.with_method("oft_v2")
    params = build_params(cfg, rng, scale_adapters=0.05)
    t = toks(cfg, rng)
    a = M.forward(cfg, params, jnp.asarray(t), trainable=True)
    b = M.forward(cfg, params, jnp.asarray(t), trainable=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_param_count_matches_specs():
    for method, quant in [("lora", "none"), ("oft_v2", "none"), ("full", "none")]:
        cfg = CFG.with_method(method, quant)
        counted = param_count(cfg)["trainable"]
        specs = (
            M.base_param_specs(cfg) if method == "full" else M.adapter_param_specs(cfg)
        )
        total = sum(int(np.prod(s)) for s, _ in specs.values())
        assert counted == total, (method, counted, total)


def test_oft_halves_lora_params():
    """Paper headline: OFTv2 uses ~half the trainable parameters of LoRA
    when b = 2r (e.g. r=16 vs b=32): LoRA row cost 2r=b vs OFT (b-1)/2."""
    cfg_l = replace(PRESETS["bench"], method="lora", lora_r=16)
    cfg_o = replace(PRESETS["bench"], method="oft_v2", block_b=32)
    nl = param_count(cfg_l)["trainable"]
    no = param_count(cfg_o)["trainable"]
    assert 0.35 < no / nl < 0.65, (no, nl)


def test_logits_last_matches_forward(rng):
    cfg = CFG.with_method("lora")
    params = build_params(cfg, rng, scale_adapters=0.05)
    tn = M.trainable_names(cfg)
    fz = M.frozen_names(cfg)
    ll = M.make_logits_last(cfg)
    t = toks(cfg, rng, bsz=1)
    cur = 7
    out = ll([params[n] for n in tn], [params[n] for n in fz], jnp.asarray(t), jnp.int32(cur))[0]
    full = M.forward(cfg, params, jnp.asarray(t), trainable=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full)[0, cur - 1], atol=1e-5)


def test_causality(rng):
    """Changing token at position j must not affect logits before j."""
    cfg = CFG.with_method("none")
    params = build_params(cfg, rng)
    t = toks(cfg, rng, bsz=1)
    l1 = np.asarray(M.forward(cfg, params, jnp.asarray(t), trainable=False))
    t2 = t.copy()
    t2[0, 20] = (t2[0, 20] + 1) % cfg.vocab
    l2 = np.asarray(M.forward(cfg, params, jnp.asarray(t2), trainable=False))
    np.testing.assert_allclose(l1[0, :20], l2[0, :20], atol=1e-5)
    assert np.abs(l1[0, 20:] - l2[0, 20:]).max() > 1e-6
