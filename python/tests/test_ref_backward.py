"""Lock the hand-derived backward formulas used by the Rust reference
backend (rust/src/runtime/refmodel.rs) against jax.grad of the L2 model.

The Rust crate executes train-step graphs natively (no JAX at runtime),
with a manually written backward pass. Each formula here is a 1:1 numpy
mirror of the Rust implementation; if these tests pass, the Rust code is
math-correct by transcription.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import ModelCfg
from compile.kernels import ref

CFG_KW = dict(
    vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64,
    seq_len=8, batch=2, block_b=8, lora_r=2, neumann_k=5,
)


def packed_dim(b):
    return b * (b - 1) // 2


# ---------------------------------------------------------------------------
# numpy mirrors of the Rust refmodel kernels
# ---------------------------------------------------------------------------


def skew_np(p, b):
    """(pd,) packed -> (b, b) skew-symmetric (single block)."""
    q = np.zeros((b, b), np.float32)
    k = 0
    for i in range(b):
        for j in range(i + 1, b):
            q[i, j] = p[k]
            q[j, i] = -p[k]
            k += 1
    return q


def cnp_fwd_np(p, b, k):
    """Single-block CNP: R = (I+Q)(I + Q + ... + Q^k)."""
    q = skew_np(p, b)
    eye = np.eye(b, dtype=np.float32)
    acc = eye.copy()
    term = eye.copy()
    for _ in range(k):
        term = term @ q
        acc = acc + term
    return (eye + q) @ acc


def cnp_bwd_np(p, b, k, g):
    """d(loss)/d(packed) for R = (I+Q)S, S = sum_{i=0..k} Q^i, given
    G = d(loss)/dR. This is the formula rust cnp_backward implements."""
    q = skew_np(p, b)
    eye = np.eye(b, dtype=np.float32)
    acc = eye.copy()
    term = eye.copy()
    for _ in range(k):
        term = term @ q
        acc = acc + term
    dq = g @ acc.T
    h = (eye + q).T @ g
    qt = q.T
    powers = [eye.copy()]
    for _ in range(max(k - 1, 0)):
        powers.append(powers[-1] @ qt)
    for i in range(1, k + 1):
        for j in range(i):
            dq = dq + powers[j] @ h @ powers[i - 1 - j]
    dp = np.zeros(packed_dim(b), np.float32)
    idx = 0
    for i in range(b):
        for j in range(i + 1, b):
            dp[idx] = dq[i, j] - dq[j, i]
            idx += 1
    return dp


def rmsnorm_fwd_np(x, g):
    r = 1.0 / np.sqrt((x * x).mean(-1, keepdims=True) + 1e-6)
    return x * r * g, r


def rmsnorm_bwd_np(x, g, r, dy):
    d = x.shape[-1]
    dg = (dy * x * r).sum(0)
    s = (dy * g * x).sum(-1, keepdims=True)
    dx = dy * g * r - x * (r ** 3 / d) * s
    return dx, dg


GELU_C = np.float32(np.sqrt(2.0 / np.pi))
GELU_A = np.float32(0.044715)


def gelu_np(x):
    return 0.5 * x * (1.0 + np.tanh(GELU_C * (x + GELU_A * x ** 3)))


def gelu_bwd_np(x, dy):
    u = GELU_C * (x + GELU_A * x ** 3)
    th = np.tanh(u)
    return dy * (0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * GELU_C * (1.0 + 3.0 * GELU_A * x * x))


def block_rotate_np(x, blocks):
    """x (M, d), blocks (nb, b, b): y[:, i*b:(i+1)*b] = x_i @ R_i."""
    m, d = x.shape
    nb, b, _ = blocks.shape
    xb = x.reshape(m, nb, b)
    return np.einsum("mnb,nbc->mnc", xb, blocks).reshape(m, d)


# ---------------------------------------------------------------------------
# numpy mirror of the full model forward/backward
# ---------------------------------------------------------------------------


class Mirror:
    """The numpy twin of rust refmodel: forward with caches + manual
    backward producing grads for every parameter (trainable-or-not)."""

    def __init__(self, cfg: ModelCfg):
        self.cfg = cfg

    def _weight(self, params, name):
        return np.asarray(params[name], np.float32)

    def linear_fwd(self, params, name, x):
        cfg = self.cfg
        w = self._weight(params, name)
        cache = {"x": x, "w": w, "name": name}
        if cfg.method in ("lora", "qlora"):
            a = self._weight(params, f"{name}.lora_a")
            bb = self._weight(params, f"{name}.lora_b")
            s = np.float32(cfg.lora_alpha / cfg.lora_r)
            xa = x @ a
            cache.update(a=a, b=bb, xa=xa, s=s)
            return x @ w + (xa @ bb) * s, cache
        if cfg.method in ("oft_v2", "qoft"):
            p = self._weight(params, f"{name}.oft_q")
            blocks = np.stack(
                [cnp_fwd_np(p[i], cfg.block_b, cfg.neumann_k) for i in range(p.shape[0])]
            )
            z = block_rotate_np(x, blocks)
            cache.update(packed=p, blocks=blocks, z=z)
            return z @ w, cache
        if cfg.method == "oft_merged":
            p = self._weight(params, f"{name}.oft_q")
            blocks = np.stack(
                [cnp_fwd_np(p[i], cfg.block_b, cfg.neumann_k) for i in range(p.shape[0])]
            )
            din = w.shape[0]
            rd = np.zeros((din, din), np.float32)
            b = cfg.block_b
            for i in range(p.shape[0]):
                rd[i * b:(i + 1) * b, i * b:(i + 1) * b] = blocks[i]
            rw = rd @ w
            cache.update(packed=p, blocks=blocks, rw=rw)
            return x @ rw, cache
        return x @ w, cache

    def linear_bwd(self, cache, dy, grads):
        cfg = self.cfg
        x, w, name = cache["x"], cache["w"], cache["name"]
        b = cfg.block_b
        if cfg.method == "full":
            grads[name] = grads.get(name, 0) + x.T @ dy
            return dy @ w.T
        if cfg.method in ("lora", "qlora"):
            s = cache["s"]
            dxa = (dy @ cache["b"].T) * s
            grads[f"{name}.lora_b"] = grads.get(f"{name}.lora_b", 0) + cache["xa"].T @ dy * s
            grads[f"{name}.lora_a"] = grads.get(f"{name}.lora_a", 0) + x.T @ dxa
            return dy @ w.T + dxa @ cache["a"].T
        if cfg.method in ("oft_v2", "qoft"):
            blocks, p = cache["blocks"], cache["packed"]
            dz = dy @ w.T
            m, d = x.shape
            nb = d // b
            xb = x.reshape(m, nb, b)
            dzb = dz.reshape(m, nb, b)
            dr = np.einsum("mnb,mnc->nbc", xb, dzb)
            dp = np.stack(
                [cnp_bwd_np(p[i], b, cfg.neumann_k, dr[i]) for i in range(nb)]
            )
            grads[f"{name}.oft_q"] = grads.get(f"{name}.oft_q", 0) + dp
            # dx: rotate dz by R^T per block
            dx = np.einsum("mnc,nbc->mnb", dzb, blocks).reshape(m, d)
            return dx
        if cfg.method == "oft_merged":
            blocks, p, rw = cache["blocks"], cache["packed"], cache["rw"]
            dm = x.T @ dy  # (din, dout)
            nb = w.shape[0] // b
            dr = np.stack(
                [dm[i * b:(i + 1) * b] @ w[i * b:(i + 1) * b].T for i in range(nb)]
            )
            dp = np.stack(
                [cnp_bwd_np(p[i], b, cfg.neumann_k, dr[i]) for i in range(nb)]
            )
            grads[f"{name}.oft_q"] = grads.get(f"{name}.oft_q", 0) + dp
            return dy @ rw.T
        return dy @ w.T  # none

    def loss_and_grads(self, params, tokens, mask):
        cfg = self.cfg
        bsz, t1 = tokens.shape
        t = t1 - 1
        d, h = cfg.d_model, cfg.n_heads
        hd = cfg.head_dim
        full = cfg.method == "full"
        inputs = tokens[:, :-1]
        targets = tokens[:, 1:]
        m = bsz * t

        tok_emb = self._weight(params, "embed.tok")
        pos_emb = self._weight(params, "embed.pos")
        x = tok_emb[inputs.reshape(-1)] + np.tile(pos_emb[:t], (bsz, 1))
        caches = []
        for i in range(cfg.n_layers):
            pre = f"layers.{i}"
            c = {"xin": x}
            g1 = self._weight(params, f"{pre}.attn.norm")
            xn1, r1 = rmsnorm_fwd_np(x, g1)
            c.update(g1=g1, xn1=xn1, r1=r1)
            q, c["cq"] = self.linear_fwd(params, f"{pre}.attn.wq", xn1)
            k, c["ck"] = self.linear_fwd(params, f"{pre}.attn.wk", xn1)
            v, c["cv"] = self.linear_fwd(params, f"{pre}.attn.wv", xn1)
            qh = q.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)
            kh = k.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)
            vh = v.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)
            scale = np.float32(1.0 / np.sqrt(hd))
            logits = np.einsum("bhqd,bhkd->bhqk", qh, kh) * scale
            tril = np.tril(np.ones((t, t), np.float32))
            logits = np.where(tril[None, None] > 0, logits, np.float32(-1e9))
            logits = logits - logits.max(-1, keepdims=True)
            e = np.exp(logits)
            att = e / e.sum(-1, keepdims=True)
            o = np.einsum("bhqk,bhkd->bhqd", att, vh)
            o = o.transpose(0, 2, 1, 3).reshape(m, d)
            c.update(qh=qh, kh=kh, vh=vh, att=att, o=o, scale=scale)
            ywo, c["co"] = self.linear_fwd(params, f"{pre}.attn.wo", o)
            x = x + ywo
            c["x_mid"] = x
            g2 = self._weight(params, f"{pre}.mlp.norm")
            xn2, r2 = rmsnorm_fwd_np(x, g2)
            c.update(g2=g2, xn2=xn2, r2=r2)
            up, c["cup"] = self.linear_fwd(params, f"{pre}.mlp.up", xn2)
            act = gelu_np(up)
            c.update(up=up, act=act)
            ydown, c["cdown"] = self.linear_fwd(params, f"{pre}.mlp.down", act)
            x = x + ydown
            caches.append(c)

        gf = self._weight(params, "final_norm")
        xf, rf = rmsnorm_fwd_np(x, gf)
        head = self._weight(params, "lm_head")
        logits = xf @ head  # (m, V)
        lmax = logits.max(-1, keepdims=True)
        lse = lmax + np.log(np.exp(logits - lmax).sum(-1, keepdims=True))
        logp = logits - lse
        tgt = targets.reshape(-1)
        nll = -logp[np.arange(m), tgt]
        mflat = mask.reshape(-1)
        count = max(mflat.sum(), 1.0)
        loss = (nll * mflat).sum() / count

        # ---- backward ----
        grads = {}
        soft = np.exp(logp)
        dlogits = soft.copy()
        dlogits[np.arange(m), tgt] -= 1.0
        dlogits *= (mflat / count)[:, None]
        if full:
            grads["lm_head"] = xf.T @ dlogits
        dxf = dlogits @ head.T
        dx, dgf = rmsnorm_bwd_np(x, gf, rf, dxf)
        if full:
            grads["final_norm"] = dgf

        for i in reversed(range(cfg.n_layers)):
            pre = f"layers.{i}"
            c = caches[i]
            dact = self.linear_bwd(c["cdown"], dx, grads)
            dup = gelu_bwd_np(c["up"], dact)
            dxn2 = self.linear_bwd(c["cup"], dup, grads)
            dxmid_n, dg2 = rmsnorm_bwd_np(c["x_mid"], c["g2"], c["r2"], dxn2)
            if full:
                grads[f"{pre}.mlp.norm"] = dg2
            dxmid = dx + dxmid_n
            do = self.linear_bwd(c["co"], dxmid, grads)
            doh = do.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3)
            att, qh, kh, vh, scale = c["att"], c["qh"], c["kh"], c["vh"], c["scale"]
            datt_post = np.einsum("bhqd,bhkd->bhqk", doh, vh)
            dvh = np.einsum("bhqk,bhqd->bhkd", att, doh)
            datt = att * (datt_post - (datt_post * att).sum(-1, keepdims=True))
            dqh = np.einsum("bhqk,bhkd->bhqd", datt, kh) * scale
            dkh = np.einsum("bhqk,bhqd->bhkd", datt, qh) * scale
            dq = dqh.transpose(0, 2, 1, 3).reshape(m, d)
            dk = dkh.transpose(0, 2, 1, 3).reshape(m, d)
            dv = dvh.transpose(0, 2, 1, 3).reshape(m, d)
            dxn1 = (
                self.linear_bwd(c["cq"], dq, grads)
                + self.linear_bwd(c["ck"], dk, grads)
                + self.linear_bwd(c["cv"], dv, grads)
            )
            dxin_n, dg1 = rmsnorm_bwd_np(c["xin"], c["g1"], c["r1"], dxn1)
            if full:
                grads[f"{pre}.attn.norm"] = dg1
            dx = dxmid + dxin_n

        if full:
            dtok = np.zeros_like(tok_emb)
            np.add.at(dtok, inputs.reshape(-1), dx)
            grads["embed.tok"] = dtok
            grads["embed.pos"] = dx.reshape(bsz, t, d).sum(0)
        return loss, grads


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------


def build_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    specs = dict(M.base_param_specs(cfg))
    specs.update(M.adapter_param_specs(cfg))
    params = {}
    for name, (shape, (kind, std)) in specs.items():
        if kind == "normal":
            # non-trivial adapters so gradients are generic (not the
            # zero-init special case)
            params[name] = rng.normal(0.0, max(std, 0.01), shape).astype(np.float32)
        elif kind == "ones":
            params[name] = np.ones(shape, np.float32)
        else:
            params[name] = rng.normal(0.0, 0.02, shape).astype(np.float32)
    return params


def batch(cfg, seed=1):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len + 1)).astype(np.int32)
    mask = (rng.random((cfg.batch, cfg.seq_len)) > 0.3).astype(np.float32)
    return toks, mask


def jax_grads(cfg, params, toks, mask):
    tn = M.trainable_names(cfg)

    def scalar(tr_list):
        p = dict(params)
        p.update({n: a for n, a in zip(tn, tr_list)})
        return M.loss_fn(cfg, p, toks, mask)[0]

    tr = [jnp.asarray(params[n]) for n in tn]
    loss, gr = jax.value_and_grad(scalar)(tr)
    return float(loss), {n: np.asarray(g) for n, g in zip(tn, gr)}


def rel_err(a, b):
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


# ---------------------------------------------------------------------------
# Tests
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,k", [(4, 2), (8, 5), (8, 8)])
def test_cnp_backward_matches_jax(b, k):
    rng = np.random.default_rng(5)
    nb = 3
    p = rng.normal(0, 0.1, (nb, packed_dim(b))).astype(np.float32)
    g = rng.normal(0, 1.0, (nb, b, b)).astype(np.float32)

    def scalar(pp):
        return (ref.cayley_neumann(pp, b, k) * g).sum()

    want = np.asarray(jax.grad(scalar)(jnp.asarray(p)))
    got = np.stack([cnp_bwd_np(p[i], b, k, g[i]) for i in range(nb)])
    assert rel_err(got, want) < 1e-4, rel_err(got, want)


def test_rmsnorm_backward_matches_jax():
    rng = np.random.default_rng(6)
    x = rng.normal(0, 1, (5, 16)).astype(np.float32)
    g = rng.normal(1, 0.1, (16,)).astype(np.float32)
    dy = rng.normal(0, 1, (5, 16)).astype(np.float32)

    def scalar_x(xx):
        return (M.rmsnorm(xx, g) * dy).sum()

    def scalar_g(gg):
        return (M.rmsnorm(jnp.asarray(x), gg) * dy).sum()

    _, r = rmsnorm_fwd_np(x, g)
    dx, dg = rmsnorm_bwd_np(x, g, r, dy)
    assert rel_err(dx, np.asarray(jax.grad(scalar_x)(jnp.asarray(x)))) < 1e-4
    assert rel_err(dg, np.asarray(jax.grad(scalar_g)(jnp.asarray(g)))) < 1e-4


def test_gelu_backward_matches_jax():
    rng = np.random.default_rng(7)
    x = rng.normal(0, 2, (64,)).astype(np.float32)
    dy = rng.normal(0, 1, (64,)).astype(np.float32)

    def scalar(xx):
        return (jax.nn.gelu(xx) * dy).sum()

    got = gelu_bwd_np(x, dy)
    want = np.asarray(jax.grad(scalar)(jnp.asarray(x)))
    assert rel_err(got, want) < 1e-3, rel_err(got, want)


@pytest.mark.parametrize("method", ["full", "lora", "oft_v2", "oft_merged"])
def test_model_grads_match_jax(method):
    cfg = ModelCfg(method=method, **CFG_KW)
    params = build_params(cfg, seed=3)
    toks, mask = batch(cfg, seed=4)
    want_loss, want = jax_grads(cfg, params, toks, mask)
    got_loss, got = Mirror(cfg).loss_and_grads(params, toks, mask)
    assert abs(got_loss - want_loss) < 1e-3 * max(1.0, abs(want_loss)), (got_loss, want_loss)
    for n in M.trainable_names(cfg):
        e = rel_err(got[n], want[n])
        assert e < 2e-3, f"{method} {n}: rel err {e}"


def test_eval_loss_mirror_matches_jax():
    cfg = ModelCfg(method="oft_v2", **CFG_KW)
    params = build_params(cfg, seed=8)
    toks, mask = batch(cfg, seed=9)
    want = float(M.loss_fn(cfg, params, jnp.asarray(toks), jnp.asarray(mask))[0])
    got, _ = Mirror(cfg).loss_and_grads(params, toks, mask)
    assert abs(got - want) < 1e-3 * max(1.0, abs(want))
