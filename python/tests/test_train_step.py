"""Train-step graphs: learning, Adam semantics, eval-loss consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import PRESETS
from test_model import build_params, quantize_params

CFG = PRESETS["tiny"]


def split_params(cfg, params):
    tn = M.trainable_names(cfg)
    fixed_names = M.frozen_names(cfg) + [q[0] for q in M.quantized_specs(cfg)]
    return tn, [params[n] for n in tn], [params[n] for n in fixed_names]


def pattern_batch(cfg, rng):
    """A learnable batch: deterministic repeating token pattern."""
    b, t = cfg.batch, cfg.seq_len
    toks = np.tile((np.arange(t + 1, dtype=np.int32) * 3 + 1) % 64, (b, 1))
    return toks, np.ones((b, t), np.float32)


def run_steps(cfg, params, n_steps, lr=5e-3, rng=None):
    tn, trains, fixed = split_params(cfg, params)
    m = [np.zeros_like(a) for a in trains]
    v = [np.zeros_like(a) for a in trains]
    step = jax.jit(M.make_train_step(cfg))
    toks, mask = pattern_batch(cfg, rng)
    losses = []
    for t in range(1, n_steps + 1):
        out = step(trains, m, v, fixed, toks, mask, jnp.float32(lr), jnp.float32(t))
        k = len(trains)
        trains = list(out[:k])
        m = list(out[k : 2 * k])
        v = list(out[2 * k : 3 * k])
        losses.append(float(out[-1]))
    return losses, trains


@pytest.mark.parametrize("method,quant", [
    ("full", "none"), ("lora", "none"), ("oft_v2", "none"), ("qoft", "nf4"),
])
def test_loss_decreases(method, quant, rng):
    cfg = CFG.with_method(method, quant)
    params = build_params(cfg, rng)
    if quant != "none":
        params = quantize_params(cfg, params, quant)
    losses, _ = run_steps(cfg, params, 30, rng=rng)
    assert losses[-1] < losses[0] * 0.9, (method, losses[0], losses[-1])
    assert all(np.isfinite(losses))


def test_frozen_params_not_updated(rng):
    """PEFT invariant: only adapter tensors change; base stays bitwise."""
    cfg = CFG.with_method("oft_v2")
    params = build_params(cfg, rng)
    tn, trains, fixed = split_params(cfg, params)
    fixed_before = [np.asarray(a).copy() for a in fixed]
    _, trains_after = run_steps(cfg, params, 5, rng=rng)
    # trainables moved...
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(trains, trains_after)
    )
    assert moved
    # ...frozen tensors are inputs; they cannot change by construction,
    # but re-check the step fn doesn't return them at trainable slots.
    assert len(trains_after) == len(tn)


def test_adam_bias_correction_first_step(rng):
    """After one step from zero moments, update = -lr * g/(|g|+eps*c) —
    check sign and magnitude bound |Δp| <= lr."""
    cfg = CFG.with_method("lora")
    params = build_params(cfg, rng, scale_adapters=0.02)
    tn, trains, fixed = split_params(cfg, params)
    m = [np.zeros_like(a) for a in trains]
    v = [np.zeros_like(a) for a in trains]
    step = jax.jit(M.make_train_step(cfg))
    toks, mask = pattern_batch(cfg, rng)
    lr = 1e-3
    out = step(trains, m, v, fixed, toks, mask, jnp.float32(lr), jnp.float32(1.0))
    k = len(trains)
    for before, after in zip(trains, out[:k]):
        dp = np.asarray(after) - np.asarray(before)
        assert np.all(np.abs(dp) <= lr * 1.001 + 1e-12)


def test_eval_loss_matches_loss_fn(rng):
    cfg = CFG.with_method("oft_v2")
    params = build_params(cfg, rng, scale_adapters=0.03)
    tn, trains, fixed = split_params(cfg, params)
    ev = jax.jit(M.make_eval_loss(cfg))
    toks, mask = pattern_batch(cfg, rng)
    s, c = ev(trains, fixed, toks, mask)
    mean_direct, _ = M.loss_fn(cfg, params, jnp.asarray(toks), jnp.asarray(mask))
    assert abs(float(s) / float(c) - float(mean_direct)) < 2e-4


def test_mask_zeroes_positions(rng):
    """Masked positions contribute nothing to the loss (prompt masking)."""
    cfg = CFG.with_method("lora")
    params = build_params(cfg, rng, scale_adapters=0.03)
    tn, trains, fixed = split_params(cfg, params)
    ev = jax.jit(M.make_eval_loss(cfg))
    toks, mask = pattern_batch(cfg, rng)
    s_full, c_full = ev(trains, fixed, toks, mask)
    # corrupt tokens only at masked-out positions
    half = mask.copy()
    half[:, : cfg.seq_len // 2] = 0.0
    toks_bad = toks.copy()
    toks_bad[:, 1 : cfg.seq_len // 2] = 0
    s1, c1 = ev(trains, fixed, toks, half)
    assert float(c1) == half.sum()
    # targets in the masked region don't matter
    toks_bad2 = toks.copy()
    toks_bad2[:, 1 : cfg.seq_len // 4] = 7
    s2, _ = ev(trains, fixed, toks_bad2, half)
    # masked-region *targets* differ but the unmasked suffix sees the same
    # prefix? No — inputs changed too, so just check finiteness + shape here
    assert np.isfinite(float(s2))


def test_oft_q_stays_small(rng):
    """Paper §3.3: finetuning keeps ||Q|| small, so the Neumann series
    stays convergent. Verify after a few steps ||Q||_2 << 1."""
    cfg = CFG.with_method("oft_v2")
    params = build_params(cfg, rng)
    _, trains_after = run_steps(cfg, params, 20, lr=5e-3, rng=rng)
    tn = M.trainable_names(cfg)
    for name, arr in zip(tn, trains_after):
        a = np.asarray(arr)
        assert np.abs(a).max() < 0.5, (name, np.abs(a).max())
