//! §3.3 — the Cayley–Neumann parameterization ablation:
//!   (a) build time of CNP vs the "exact" Cayley (Newton–Schulz
//!       inverse, the matmul-only stand-in for LAPACK `solve`) across
//!       block sizes b ∈ {16, 32, 64};
//!   (b) approximation error and orthogonality error of CNP vs the
//!       number of Neumann terms k ∈ {1..8}, against the exact host
//!       Cayley oracle.
//!
//! Shape targets: CNP builds faster than the inverse-based transform at
//! every b; error decreases monotonically in k and is ≈0 by k=8 in the
//! small-‖Q‖ finetuning regime.

use oftv2::bench::{fmt_ms, print_table, quick_mode, Bench, Report};
use oftv2::json::Json;
use oftv2::peft;
use oftv2::runtime::micro::MicroCatalog;
use oftv2::runtime::{lit_f32, Engine};
use oftv2::tensor::Tensor;
use oftv2::util::rng::Rng;
use oftv2::{artifacts_root, Result};

fn main() -> Result<()> {
    let iters = if quick_mode() { 5 } else { 20 };
    let engine = Engine::cpu()?;
    let cat = MicroCatalog::load_or_builtin(artifacts_root())?;
    let mut report = Report::new("cnp_vs_cayley");

    // ---- (a) build-time comparison --------------------------------------
    let mut rows = Vec::new();
    for b in [16usize, 32, 64] {
        let cnp = cat.compile(&engine, &format!("cnp_b{b}"))?;
        let exact = cat.compile(&engine, &format!("cayley_schulz_b{b}"))?;
        let inputs = cnp.random_inputs(3, 0.02)?;
        let t_cnp = Bench::new("cnp").warmup(2).iters(iters).run(|| {
            cnp.run(&inputs).unwrap();
        });
        let t_exact = Bench::new("exact").warmup(2).iters(iters).run(|| {
            exact.run(&inputs).unwrap();
        });
        rows.push(vec![
            format!("{b}"),
            fmt_ms(t_cnp.median),
            fmt_ms(t_exact.median),
            format!("{:.2}x", t_exact.median / t_cnp.median),
        ]);
        report.add_kv(vec![
            ("b", Json::num(b as f64)),
            ("cnp_secs", Json::num(t_cnp.median)),
            ("exact_secs", Json::num(t_exact.median)),
        ]);
        assert!(
            t_cnp.median < t_exact.median,
            "b={b}: CNP ({}) should beat the inverse-based build ({})",
            fmt_ms(t_cnp.median),
            fmt_ms(t_exact.median)
        );
    }
    print_table(
        "§3.3a: orthogonal-matrix build time (32 blocks per call)",
        &["block b", "CNP (k=5)", "exact Cayley (Schulz)", "speedup"],
        &rows,
    );

    // ---- (b) error vs k --------------------------------------------------
    let b = 32;
    let p = peft::packed_dim(b);
    let mut rng = Rng::new(oftv2::bench::bench_seed());
    let packed: Vec<f32> = rng.normal_vec(32 * p, 0.02);
    let exact0 = peft::cayley_exact(&packed[..p], b)?;
    let mut rows = Vec::new();
    let mut prev_err = f64::INFINITY;
    for k in 1..=8usize {
        let kern = cat.compile(&engine, &format!("cnp_b{b}_k{k}"))?;
        let out = kern.run(&[lit_f32(&[32, p], &packed)?])?[0].to_vec::<f32>()?;
        let r0 = Tensor::from_vec(&[b, b], out[..b * b].to_vec());
        let approx_err = r0.max_abs_diff(&exact0) as f64;
        let ortho_err = peft::orthogonality_error(&r0) as f64;
        rows.push(vec![
            format!("{k}"),
            format!("{approx_err:.2e}"),
            format!("{ortho_err:.2e}"),
        ]);
        report.add_kv(vec![
            ("k", Json::num(k as f64)),
            ("approx_err", Json::num(approx_err)),
            ("ortho_err", Json::num(ortho_err)),
        ]);
        assert!(
            approx_err <= prev_err * 1.2 + 1e-8,
            "k={k}: error should not grow ({approx_err} vs {prev_err})"
        );
        prev_err = approx_err;
    }
    print_table(
        "§3.3b: CNP error vs Neumann terms k (b=32, ||Q|| small)",
        &["k", "|CNP - exact|_max", "||R^T R - I||_F"],
        &rows,
    );
    println!("\n(paper: k=5 suffices; exact orthogonality is unnecessary in practice)");

    let path = report.save()?;
    println!("results -> {}", path.display());
    Ok(())
}
