//! Fig. 1 — "OFTv2 significantly reduces training time and GPU memory
//! usage": measured per-step training time (weight-centric OFT vs
//! input-centric OFTv2 vs LoRA) on the `bench` preset, plus the
//! analytic memory model at the paper's actual scale (Qwen2.5-7B).
//!
//!   cargo bench --bench fig1_time_memory [-- --quick]
//!
//! Shape target (DESIGN.md §3): OFTv2 is multiple-x faster than OFT and
//! within ~2x of LoRA; memory ratio OFT/OFTv2 ≈ 3x.

use oftv2::bench::{
    bench_seed, fmt_ms, fmt_ratio, print_table, quick_mode, write_bench_json, BenchRecord, Report,
};
use oftv2::config::RunCfg;
use oftv2::coordinator::{Manifest, Trainer};
use oftv2::json::Json;
use oftv2::memmodel::{finetune_gib, Method, Precision, TrainShape};
use oftv2::modelspec::ModelSpec;
use oftv2::quant::dequant_f32_count;
use oftv2::runtime::{CheckpointPolicy, Engine};
use oftv2::util::human_bytes;
use oftv2::{artifacts_root, Result};

/// Post-warmup per-step wall times for one bundle under a checkpoint
/// policy.
fn step_samples_ckpt(
    engine: &Engine,
    tag: &str,
    steps: usize,
    policy: CheckpointPolicy,
) -> Result<Vec<f64>> {
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.seed = bench_seed();
    cfg.data.seed = bench_seed();
    cfg.data.task = "wiki".into();
    cfg.data.documents = 300;
    cfg.train.grad_checkpoint = policy;
    let mut tr = Trainer::new(engine, &artifacts_root(), cfg)?;
    let hist = tr.train()?;
    Ok(hist.step_secs(steps / 5))
}

/// Post-warmup per-step wall times for one bundle.
fn step_samples(engine: &Engine, tag: &str, steps: usize) -> Result<Vec<f64>> {
    step_samples_ckpt(engine, tag, steps, CheckpointPolicy::None)
}

fn main() -> Result<()> {
    let steps = if quick_mode() { 8 } else { 25 };
    let engine = Engine::cpu()?;
    let mut report = Report::new("fig1_time_memory");
    let mut records: Vec<BenchRecord> = Vec::new();

    // -- measured training time (fig1 preset: d=1024 > rows=128, the merge-dominated regime) ---------
    let mut rows = Vec::new();
    let mut times = Vec::new();
    for (label, tag) in [
        ("OFT (weight-centric)", "fig1_oft_merged"),
        ("OFTv2 (input-centric)", "fig1_oft_v2"),
        ("LoRA", "fig1_lora"),
    ] {
        let samples = step_samples(&engine, tag, steps)?;
        let rec = BenchRecord::from_samples(format!("step_time_{tag}"), &samples)
            .with("method", Json::str(label));
        let s = rec.mean;
        times.push((label, s));
        report.add_kv(vec![
            ("kind", Json::str("step_time")),
            ("method", Json::str(label)),
            ("secs", Json::num(s)),
        ]);
        records.push(rec);
    }
    let oft = times[0].1;
    let v2 = times[1].1;
    let lora = times[2].1;
    for (label, s) in &times {
        rows.push(vec![
            label.to_string(),
            fmt_ms(*s),
            fmt_ratio(oft / s),
        ]);
    }
    print_table(
        "Fig. 1 (left): per-step training time (d=1024, 128 rows)",
        &["method", "ms/step", "speedup vs OFT"],
        &rows,
    );
    println!(
        "OFTv2 vs OFT speedup: {} (paper: >3x at d=3584/Qwen2.5-7B; the gap grows \
         with d — see kernel_scaling: 6.9x at d=2048 for the isolated layer). \
         LoRA/OFTv2: {}",
        fmt_ratio(oft / v2),
        fmt_ratio(v2 / lora)
    );
    // Shape: in the paper's d > rows regime the merge dominates the
    // step — OFTv2 must win by a clear multiple (paper: >3x).
    assert!(
        oft / v2 > 1.5,
        "OFTv2 should clearly beat weight-centric OFT (got {:.2}x)",
        oft / v2
    );

    // -- analytic memory at the paper's scale ----------------------------
    let spec = ModelSpec::qwen25("7b")?;
    let shape = TrainShape::default();
    let mem = |m: Method| finetune_gib(&spec, m, Precision::Bf16, shape);
    let m_oft = mem(Method::oft_weight_centric(32));
    let m_v2 = mem(Method::oft_input_centric(32));
    let m_lora = mem(Method::lora(16));
    print_table(
        "Fig. 1 (right): GPU memory, Qwen2.5-7B BF16 (analytic)",
        &["method", "GiB", "ratio vs OFTv2"],
        &[
            vec!["OFT".into(), format!("{m_oft:.1}"), fmt_ratio(m_oft / m_v2)],
            vec!["OFTv2".into(), format!("{m_v2:.1}"), fmt_ratio(1.0)],
            vec!["LoRA".into(), format!("{m_lora:.1}"), fmt_ratio(m_lora / m_v2)],
        ],
    );
    // Memory is a different unit than the step times, so it gets its
    // own BENCH file rather than polluting the secs-unit records.
    let mut mem_records: Vec<BenchRecord> = Vec::new();
    for (m, g) in [("OFT", m_oft), ("OFTv2", m_v2), ("LoRA", m_lora)] {
        report.add_kv(vec![
            ("kind", Json::str("memory_gib")),
            ("method", Json::str(m)),
            ("gib", Json::num(g)),
        ]);
        mem_records.push(
            BenchRecord::from_samples(format!("memory_gib_{m}"), &[g])
                .with("method", Json::str(m)),
        );
    }
    assert!(m_oft / m_v2 > 2.0 && m_oft / m_v2 < 4.5);

    // -- the checkpoint time/memory trade-off curve ----------------------
    // Measured step time (fig1 OFTv2 bundle) under each CheckpointPolicy
    // against the analytic activation memory at the paper's 7B scale:
    // recompute buys activation memory, and both axes are now real
    // numbers rather than a boolean.
    let mut ck_records: Vec<BenchRecord> = Vec::new();
    let mut ck_rows = Vec::new();
    let mut ck_base = 0.0f64;
    for policy in [
        CheckpointPolicy::None,
        CheckpointPolicy::EveryK(1),
        CheckpointPolicy::EveryK(2),
    ] {
        let samples = step_samples_ckpt(&engine, "fig1_oft_v2", steps, policy)?;
        let mem_shape = TrainShape {
            checkpoint: policy,
            ..TrainShape::default()
        };
        let gib = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Bf16, mem_shape);
        let rec = BenchRecord::from_samples(format!("ckpt_{}", policy.label()), &samples)
            .with("checkpoint", Json::str(policy.label()))
            .with("memory_gib_7b", Json::num(gib));
        if policy == CheckpointPolicy::None {
            ck_base = rec.mean;
        }
        ck_rows.push(vec![
            policy.label(),
            fmt_ms(rec.mean),
            fmt_ratio(rec.mean / ck_base.max(1e-12)),
            format!("{gib:.1}"),
        ]);
        report.add_kv(vec![
            ("kind", Json::str("ckpt_tradeoff")),
            ("policy", Json::str(policy.label())),
            ("secs", Json::num(rec.mean)),
            ("gib_7b", Json::num(gib)),
        ]);
        ck_records.push(rec);
    }
    print_table(
        "Gradient-checkpoint trade-off (fig1_oft_v2 step time vs 7B activation memory)",
        &["policy", "ms/step", "vs full tape", "GiB @7B"],
        &ck_rows,
    );
    records.extend(ck_records);

    // -- measured packed-base residency (QOFT over NF4) -------------------
    // The RSS-proxy proof that the f32 base copy is gone from the
    // compute path: a quantized train + eval + decode run uploads only
    // the packs (plus the frozen non-linear f32 tensors), and the
    // process-wide dequant probe stays flat — no pack is ever expanded
    // into a full f32 tensor. (BaseModel's load-time host master — the
    // quantization source — is the one f32 form that remains, never
    // uploaded and never read by a step.)
    let qman = Manifest::builtin("fig1_qoft_nf4")?;
    let frozen_bytes = qman.fixed_input_bytes() - qman.quantized_pack_bytes();
    let deq0 = dequant_f32_count();
    let bytes0 = engine.upload_bytes();
    let mut qcfg = RunCfg::default();
    qcfg.tag = "fig1_qoft_nf4".into();
    qcfg.steps = 2;
    qcfg.log_every = 0;
    qcfg.seed = bench_seed();
    qcfg.data.seed = bench_seed();
    qcfg.data.task = "wiki".into();
    qcfg.data.documents = 120;
    let mut qtr = Trainer::new(&engine, &artifacts_root(), qcfg)?;
    let fixed_bytes = engine.upload_bytes() - bytes0;
    qtr.train()?;
    qtr.evaluate()?;
    qtr.decode_greedy(&[1, 2, 3], 4)?;
    assert_eq!(
        dequant_f32_count(),
        deq0,
        "quantized run expanded a packed base weight to f32"
    );
    let packed = qman.quantized_pack_bytes();
    let f32_base = qman.dequantized_base_bytes()?;
    let measured_base = fixed_bytes.saturating_sub(frozen_bytes);
    assert!(
        measured_base <= packed + packed / 2,
        "base residency {measured_base} B exceeds 1.5x packed {packed} B"
    );
    print_table(
        "QOFT NF4 base residency (fig1 preset, measured engine uploads)",
        &["", "bytes"],
        &[
            vec!["packed (target)".into(), human_bytes(packed)],
            vec!["measured resident".into(), human_bytes(measured_base)],
            vec!["f32 copy (old path)".into(), human_bytes(f32_base)],
        ],
    );
    report.add_kv(vec![
        ("kind", Json::str("quant_residency")),
        ("tag", Json::str("fig1_qoft_nf4")),
        ("measured_bytes", Json::num(measured_base as f64)),
        ("packed_bytes", Json::num(packed as f64)),
        ("dequant_f32_bytes", Json::num(f32_base as f64)),
    ]);
    let resid_records = vec![BenchRecord::from_samples(
        "qoft_nf4_base_residency",
        &[measured_base as f64],
    )
    .with("packed_bytes", Json::num(packed as f64))
    .with("dequant_f32_bytes", Json::num(f32_base as f64))];
    let resid_path = write_bench_json("fig1_quant_residency", "bytes", &resid_records)?;
    println!("quant residency -> {}", resid_path.display());

    let path = report.save()?;
    let bench_path = write_bench_json("fig1_time_memory", "secs", &records)?;
    let mem_path = write_bench_json("fig1_memory", "gib", &mem_records)?;
    println!(
        "\nresults -> {}, {} and {}",
        path.display(),
        bench_path.display(),
        mem_path.display()
    );
    Ok(())
}
