//! Fig. 1 — "OFTv2 significantly reduces training time and GPU memory
//! usage": measured per-step training time (weight-centric OFT vs
//! input-centric OFTv2 vs LoRA) on the `bench` preset, plus the
//! analytic memory model at the paper's actual scale (Qwen2.5-7B).
//!
//!   cargo bench --bench fig1_time_memory [-- --quick]
//!
//! Shape target (DESIGN.md §3): OFTv2 is multiple-x faster than OFT and
//! within ~2x of LoRA; memory ratio OFT/OFTv2 ≈ 3x.

use oftv2::bench::{
    fmt_ms, fmt_ratio, print_table, quick_mode, write_bench_json, BenchRecord, Report,
};
use oftv2::config::RunCfg;
use oftv2::coordinator::Trainer;
use oftv2::json::Json;
use oftv2::memmodel::{finetune_gib, Method, Precision, TrainShape};
use oftv2::modelspec::ModelSpec;
use oftv2::runtime::Engine;
use oftv2::{artifacts_root, Result};

/// Post-warmup per-step wall times for one bundle.
fn step_samples(engine: &Engine, tag: &str, steps: usize) -> Result<Vec<f64>> {
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.data.task = "wiki".into();
    cfg.data.documents = 300;
    let mut tr = Trainer::new(engine, &artifacts_root(), cfg)?;
    let hist = tr.train()?;
    Ok(hist.step_secs(steps / 5))
}

fn main() -> Result<()> {
    let steps = if quick_mode() { 8 } else { 25 };
    let engine = Engine::cpu()?;
    let mut report = Report::new("fig1_time_memory");
    let mut records: Vec<BenchRecord> = Vec::new();

    // -- measured training time (fig1 preset: d=1024 > rows=128, the merge-dominated regime) ---------
    let mut rows = Vec::new();
    let mut times = Vec::new();
    for (label, tag) in [
        ("OFT (weight-centric)", "fig1_oft_merged"),
        ("OFTv2 (input-centric)", "fig1_oft_v2"),
        ("LoRA", "fig1_lora"),
    ] {
        let samples = step_samples(&engine, tag, steps)?;
        let rec = BenchRecord::from_samples(format!("step_time_{tag}"), &samples)
            .with("method", Json::str(label));
        let s = rec.mean;
        times.push((label, s));
        report.add_kv(vec![
            ("kind", Json::str("step_time")),
            ("method", Json::str(label)),
            ("secs", Json::num(s)),
        ]);
        records.push(rec);
    }
    let oft = times[0].1;
    let v2 = times[1].1;
    let lora = times[2].1;
    for (label, s) in &times {
        rows.push(vec![
            label.to_string(),
            fmt_ms(*s),
            fmt_ratio(oft / s),
        ]);
    }
    print_table(
        "Fig. 1 (left): per-step training time (d=1024, 128 rows)",
        &["method", "ms/step", "speedup vs OFT"],
        &rows,
    );
    println!(
        "OFTv2 vs OFT speedup: {} (paper: >3x at d=3584/Qwen2.5-7B; the gap grows \
         with d — see kernel_scaling: 6.9x at d=2048 for the isolated layer). \
         LoRA/OFTv2: {}",
        fmt_ratio(oft / v2),
        fmt_ratio(v2 / lora)
    );
    // Shape: in the paper's d > rows regime the merge dominates the
    // step — OFTv2 must win by a clear multiple (paper: >3x).
    assert!(
        oft / v2 > 1.5,
        "OFTv2 should clearly beat weight-centric OFT (got {:.2}x)",
        oft / v2
    );

    // -- analytic memory at the paper's scale ----------------------------
    let spec = ModelSpec::qwen25("7b");
    let shape = TrainShape::default();
    let mem = |m: Method| finetune_gib(&spec, m, Precision::Bf16, shape);
    let m_oft = mem(Method::OftWeightCentric { b: 32 });
    let m_v2 = mem(Method::OftInputCentric { b: 32 });
    let m_lora = mem(Method::Lora { r: 16 });
    print_table(
        "Fig. 1 (right): GPU memory, Qwen2.5-7B BF16 (analytic)",
        &["method", "GiB", "ratio vs OFTv2"],
        &[
            vec!["OFT".into(), format!("{m_oft:.1}"), fmt_ratio(m_oft / m_v2)],
            vec!["OFTv2".into(), format!("{m_v2:.1}"), fmt_ratio(1.0)],
            vec!["LoRA".into(), format!("{m_lora:.1}"), fmt_ratio(m_lora / m_v2)],
        ],
    );
    // Memory is a different unit than the step times, so it gets its
    // own BENCH file rather than polluting the secs-unit records.
    let mut mem_records: Vec<BenchRecord> = Vec::new();
    for (m, g) in [("OFT", m_oft), ("OFTv2", m_v2), ("LoRA", m_lora)] {
        report.add_kv(vec![
            ("kind", Json::str("memory_gib")),
            ("method", Json::str(m)),
            ("gib", Json::num(g)),
        ]);
        mem_records.push(
            BenchRecord::from_samples(format!("memory_gib_{m}"), &[g])
                .with("method", Json::str(m)),
        );
    }
    assert!(m_oft / m_v2 > 2.0 && m_oft / m_v2 < 4.5);
    let path = report.save()?;
    let bench_path = write_bench_json("fig1_time_memory", "secs", &records)?;
    let mem_path = write_bench_json("fig1_memory", "gib", &mem_records)?;
    println!(
        "\nresults -> {}, {} and {}",
        path.display(),
        bench_path.display(),
        mem_path.display()
    );
    Ok(())
}
