//! Fig. 4a/b/c — GPU memory across the Qwen2.5 family (0.5B–72B) for
//! (a) OFT / LoRA / OFTv2 at BF16, (b) QLoRA / QOFT at NF4,
//! (c) QLoRA / QOFT at AWQ. Analytic model (DESIGN.md §Substitutions),
//! plus *measured* packed-base residency on the reference engine: the
//! fused dequant-matmul kernels keep the base in its packs, so the
//! engine-resident base-weight bytes sit at the packed size (~0.52
//! B/param for NF4), not the f32 copy a dequantize-at-assembly engine
//! holds — the numbers land in `BENCH_fig4_memory_sweep.json`.
//!
//! Shape targets: OFTv2 within a few % of LoRA at every scale; OFT
//! diverges enormously with model size; quantized variants track each
//! other and cut memory ~3-4x at large scales.

use oftv2::bench::{print_table, write_bench_json, BenchRecord, Report};
use oftv2::coordinator::{BaseModel, Manifest};
use oftv2::json::Json;
use oftv2::memmodel::{finetune_gib, BaseResidency, Method, Precision, TrainShape};
use oftv2::modelspec::ModelSpec;
use oftv2::runtime::Engine;
use oftv2::util::human_bytes;
use oftv2::Result;

const SIZES: [&str; 7] = ["0.5b", "1.5b", "3b", "7b", "14b", "32b", "72b"];

fn main() -> Result<()> {
    let shape = TrainShape::default();
    let mut report = Report::new("fig4_memory_sweep");

    let sweep = |title: &str,
                 precision: Precision,
                 shape: TrainShape,
                 methods: &[(&str, Method)],
                 report: &mut Report| {
        let mut rows = Vec::new();
        for size in SIZES {
            let spec = ModelSpec::qwen25(size).expect("known qwen2.5 size");
            let mut row = vec![spec.name.clone()];
            for (label, m) in methods {
                let gib = finetune_gib(&spec, *m, precision, shape);
                row.push(format!("{gib:.1}"));
                report.add_kv(vec![
                    ("panel", Json::str(title)),
                    ("model", Json::str(spec.name.clone())),
                    ("method", Json::str(*label)),
                    ("gib", Json::num(gib)),
                ]);
            }
            rows.push(row);
        }
        let mut headers = vec!["model"];
        headers.extend(methods.iter().map(|(l, _)| *l));
        print_table(title, &headers, &rows);
    };

    sweep(
        "Fig. 4a: BF16 (GiB)",
        Precision::Bf16,
        shape,
        &[
            ("OFT", Method::oft_weight_centric(32)),
            ("LoRA", Method::lora(16)),
            ("OFTv2", Method::oft_input_centric(32)),
        ],
        &mut report,
    );
    sweep(
        "Fig. 4b: NF4 (GiB)",
        Precision::Nf4,
        shape,
        &[
            ("QLoRA", Method::lora(16)),
            ("QOFT", Method::oft_input_centric(32)),
        ],
        &mut report,
    );
    sweep(
        "Fig. 4c: AWQ (GiB)",
        Precision::Awq4,
        shape,
        &[
            ("QLoRA", Method::lora(16)),
            ("QOFT", Method::oft_input_centric(32)),
        ],
        &mut report,
    );
    // What the same NF4 sweep would cost if the engine dequantized the
    // base to f32 at parameter assembly — the path the fused kernels
    // removed. Kept as a panel so the delta is diffable.
    let dequant_shape = TrainShape {
        residency: BaseResidency::DequantF32,
        ..shape
    };
    sweep(
        "Fig. 4b (counterfactual): NF4 with a dequantized f32 base (GiB)",
        Precision::Nf4,
        dequant_shape,
        &[
            ("QLoRA", Method::lora(16)),
            ("QOFT", Method::oft_input_centric(32)),
        ],
        &mut report,
    );

    // -- measured packed residency on the reference engine ----------------
    // `bench`-preset linears are whole NF4 tiles, so the packed size is
    // the honest ~0.52 B/param, not padding-dominated. `fixed_for`
    // uploads exactly the packs (the frozen f32 buffers are already
    // resident from base construction), so the upload-bytes delta IS
    // the engine-resident base-weight footprint.
    let engine = Engine::reference();
    let mut records = Vec::new();
    let mut rows = Vec::new();
    for tag in [
        "bench_qlora_nf4",
        "bench_qoft_nf4",
        "bench_qlora_awq",
        "bench_qoft_awq",
    ] {
        let man = Manifest::builtin(tag)?;
        let base = BaseModel::from_manifest(&engine, &man, 7, None)?;
        let before = engine.upload_bytes();
        let _fixed = base.fixed_for(&engine, &man)?;
        let measured = engine.upload_bytes() - before;
        let packed = man.quantized_pack_bytes();
        let f32b = man.dequantized_base_bytes()?;
        assert!(
            measured <= packed + packed / 2,
            "{tag}: measured base residency {measured} B exceeds 1.5x packed {packed} B"
        );
        assert!(
            measured * 4 < f32b,
            "{tag}: packed residency {measured} B should be far below the f32 copy {f32b} B"
        );
        rows.push(vec![
            tag.to_string(),
            human_bytes(measured),
            human_bytes(packed),
            human_bytes(f32b),
            format!("{:.1}x", f32b as f64 / measured.max(1) as f64),
        ]);
        records.push(
            BenchRecord::from_samples(format!("base_residency_{tag}"), &[measured as f64])
                .with("packed_bytes", Json::num(packed as f64))
                .with("dequant_f32_bytes", Json::num(f32b as f64))
                .with(
                    "f32_over_measured",
                    Json::num(f32b as f64 / measured.max(1) as f64),
                ),
        );
        report.add_kv(vec![
            ("panel", Json::str("measured_residency")),
            ("tag", Json::str(tag)),
            ("measured_bytes", Json::num(measured as f64)),
            ("packed_bytes", Json::num(packed as f64)),
            ("dequant_f32_bytes", Json::num(f32b as f64)),
        ]);
    }
    print_table(
        "Measured base-weight residency (reference engine uploads, bench preset)",
        &["bundle", "measured", "packed", "f32 copy", "saved"],
        &rows,
    );

    // shape assertions
    for size in SIZES {
        let spec = ModelSpec::qwen25(size)?;
        let lora = finetune_gib(&spec, Method::lora(16), Precision::Bf16, shape);
        let v2 = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Bf16, shape);
        assert!(
            (v2 - lora).abs() / lora < 0.10,
            "{size}: OFTv2 {v2} vs LoRA {lora}"
        );
        for p in [Precision::Nf4, Precision::Awq4] {
            let ql = finetune_gib(&spec, Method::lora(16), p, shape);
            let qo = finetune_gib(&spec, Method::oft_input_centric(32), p, shape);
            assert!((qo - ql).abs() / ql < 0.10, "{size}: QOFT {qo} vs QLoRA {ql}");
            // Packed residency must beat the dequantize-at-assembly
            // counterfactual at every scale.
            let qo_deq =
                finetune_gib(&spec, Method::oft_input_centric(32), p, dequant_shape);
            assert!(qo < qo_deq, "{size}: packed {qo} !< dequant {qo_deq}");
        }
    }
    println!("\nshape checks OK: OFTv2/QOFT within 10% of LoRA/QLoRA at every scale");
    let path = report.save()?;
    let bench_path = write_bench_json("fig4_memory_sweep", "bytes", &records)?;
    println!("results -> {} and {}", path.display(), bench_path.display());
    Ok(())
}
