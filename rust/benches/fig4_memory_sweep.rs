//! Fig. 4a/b/c — GPU memory across the Qwen2.5 family (0.5B–72B) for
//! (a) OFT / LoRA / OFTv2 at BF16, (b) QLoRA / QOFT at NF4,
//! (c) QLoRA / QOFT at AWQ. Analytic model (DESIGN.md §Substitutions).
//!
//! Shape targets: OFTv2 within a few % of LoRA at every scale; OFT
//! diverges enormously with model size; quantized variants track each
//! other and cut memory ~3-4x at large scales.

use oftv2::bench::{print_table, Report};
use oftv2::json::Json;
use oftv2::memmodel::{finetune_gib, Method, Precision, TrainShape};
use oftv2::modelspec::ModelSpec;
use oftv2::Result;

const SIZES: [&str; 7] = ["0.5b", "1.5b", "3b", "7b", "14b", "32b", "72b"];

fn main() -> Result<()> {
    let shape = TrainShape::default();
    let mut report = Report::new("fig4_memory_sweep");

    let sweep = |title: &str,
                 precision: Precision,
                 methods: &[(&str, Method)],
                 report: &mut Report| {
        let mut rows = Vec::new();
        for size in SIZES {
            let spec = ModelSpec::qwen25(size);
            let mut row = vec![spec.name.clone()];
            for (label, m) in methods {
                let gib = finetune_gib(&spec, *m, precision, shape);
                row.push(format!("{gib:.1}"));
                report.add_kv(vec![
                    ("panel", Json::str(title)),
                    ("model", Json::str(spec.name.clone())),
                    ("method", Json::str(*label)),
                    ("gib", Json::num(gib)),
                ]);
            }
            rows.push(row);
        }
        let mut headers = vec!["model"];
        headers.extend(methods.iter().map(|(l, _)| *l));
        print_table(title, &headers, &rows);
    };

    sweep(
        "Fig. 4a: BF16 (GiB)",
        Precision::Bf16,
        &[
            ("OFT", Method::OftWeightCentric { b: 32 }),
            ("LoRA", Method::Lora { r: 16 }),
            ("OFTv2", Method::OftInputCentric { b: 32 }),
        ],
        &mut report,
    );
    sweep(
        "Fig. 4b: NF4 (GiB)",
        Precision::Nf4,
        &[
            ("QLoRA", Method::Lora { r: 16 }),
            ("QOFT", Method::OftInputCentric { b: 32 }),
        ],
        &mut report,
    );
    sweep(
        "Fig. 4c: AWQ (GiB)",
        Precision::Awq4,
        &[
            ("QLoRA", Method::Lora { r: 16 }),
            ("QOFT", Method::OftInputCentric { b: 32 }),
        ],
        &mut report,
    );

    // shape assertions
    for size in SIZES {
        let spec = ModelSpec::qwen25(size);
        let lora = finetune_gib(&spec, Method::Lora { r: 16 }, Precision::Bf16, shape);
        let v2 = finetune_gib(&spec, Method::OftInputCentric { b: 32 }, Precision::Bf16, shape);
        assert!(
            (v2 - lora).abs() / lora < 0.10,
            "{size}: OFTv2 {v2} vs LoRA {lora}"
        );
        for p in [Precision::Nf4, Precision::Awq4] {
            let ql = finetune_gib(&spec, Method::Lora { r: 16 }, p, shape);
            let qo = finetune_gib(&spec, Method::OftInputCentric { b: 32 }, p, shape);
            assert!((qo - ql).abs() / ql < 0.10, "{size}: QOFT {qo} vs QLoRA {ql}");
        }
    }
    println!("\nshape checks OK: OFTv2/QOFT within 10% of LoRA/QLoRA at every scale");
    let path = report.save()?;
    println!("results -> {}", path.display());
    Ok(())
}
