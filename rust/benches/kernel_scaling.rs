//! §3.2 — the cubic-to-quadratic complexity claim, measured: per-call
//! time of the weight-centric merge path (blockdiag(R)·W then x·(RW))
//! vs the input-centric rotate path ((Rᵀx)·W) over d ∈ {256..2048},
//! with the plain linear layer and LoRA as floors.
//!
//! Shape targets: the merge path's log-log slope ≈ 3 (cubic in d); the
//! rotate path's ≈ 2 (quadratic); rotate_w stays within a small factor
//! of base_w at every d, while merge_w blows up.

use oftv2::bench::{
    fmt_ms, print_table, quick_mode, write_bench_json, Bench, BenchRecord, Report,
};
use oftv2::json::Json;
use oftv2::runtime::micro::MicroCatalog;
use oftv2::runtime::Engine;
use oftv2::util::stats::loglog_slope;
use oftv2::{artifacts_root, Result};

const DIMS: [usize; 4] = [256, 512, 1024, 2048];

fn main() -> Result<()> {
    let iters = if quick_mode() { 5 } else { 15 };
    let engine = Engine::cpu()?;
    let cat = MicroCatalog::load_or_builtin(artifacts_root())?;
    let mut report = Report::new("kernel_scaling");
    let mut recs: Vec<BenchRecord> = Vec::new();

    let mut rows = Vec::new();
    let mut series: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for d in DIMS {
        let mut row = vec![format!("{d}")];
        for prefix in ["base_w", "lora_w", "rotate_w", "merge_w"] {
            let name = format!("{prefix}_d{d}");
            let k = cat.compile(&engine, &name)?;
            let inputs = k.random_inputs(11, 0.02)?;
            let s = Bench::new(&name)
                .warmup(2)
                .iters(iters)
                .max_secs(10.0)
                .run(|| {
                    k.run(&inputs).unwrap();
                });
            row.push(fmt_ms(s.median));
            series.entry(prefix).or_default().push(s.median);
            report.add_kv(vec![
                ("kernel", Json::str(prefix)),
                ("d", Json::num(d as f64)),
                ("median_secs", Json::num(s.median)),
            ]);
            recs.push(
                BenchRecord::from_summary(&name, &s)
                    .with("kernel", Json::str(prefix))
                    .with("d", Json::num(d as f64))
                    .with("dispatch", Json::str("default")),
            );
        }
        rows.push(row);
    }

    // When the SIMD kernels are live, re-measure the two matmul-bound
    // paths at the largest d with the scalar oracle forced, so the
    // BENCH json carries the end-to-end before/after delta — not just
    // the microbench numbers in BENCH_roofline.json.
    if oftv2::tensor::simd_kernels_active() {
        let d = DIMS[DIMS.len() - 1];
        for prefix in ["base_w", "rotate_w"] {
            let name = format!("{prefix}_d{d}");
            let k = cat.compile(&engine, &name)?;
            let inputs = k.random_inputs(11, 0.02)?;
            let prev = oftv2::tensor::force_scalar_kernels(true);
            let s = Bench::new(&name)
                .warmup(2)
                .iters(iters)
                .max_secs(10.0)
                .run(|| {
                    k.run(&inputs).unwrap();
                });
            oftv2::tensor::force_scalar_kernels(prev);
            let simd_median = *series[prefix].last().unwrap();
            println!(
                "{name}: scalar {} vs simd {} ({:.2}x)",
                fmt_ms(s.median),
                fmt_ms(simd_median),
                s.median / simd_median
            );
            recs.push(
                BenchRecord::from_summary(format!("{name}_scalar"), &s)
                    .with("kernel", Json::str(prefix))
                    .with("d", Json::num(d as f64))
                    .with("dispatch", Json::str("forced_scalar"))
                    .with("speedup_vs_scalar", Json::num(s.median / simd_median)),
            );
        }
    }
    print_table(
        "§3.2 kernel scaling: per-call time vs hidden size d (128 rows)",
        &["d", "base x@W", "LoRA", "OFTv2 rotate", "OFT merge"],
        &rows,
    );

    // Theory line: FLOPs per call (exact, machine-independent). The
    // rotate path adds rows·d·b MACs on top of the rows·d·n layer; the
    // merge path adds the d·d·n matrix-matrix product (eq. 1 vs eq. 2).
    let rows = 128.0;
    let b = 32.0;
    let flops_rotate: Vec<f64> = DIMS.iter().map(|&d| {
        let d = d as f64;
        rows * d * b + rows * d * d
    }).collect();
    let flops_merge: Vec<f64> = DIMS.iter().map(|&d| {
        let d = d as f64;
        d * d * d + rows * d * d
    }).collect();
    let xs: Vec<f64> = DIMS.iter().map(|&d| d as f64).collect();
    println!(
        "\nFLOP-count log-log slopes (theory): rotate {:.2} (quadratic), merge {:.2} (cubic)",
        loglog_slope(&xs, &flops_rotate),
        loglog_slope(&xs, &flops_merge),
    );
    let slope_rotate = loglog_slope(&xs, &series["rotate_w"]);
    let slope_merge = loglog_slope(&xs, &series["merge_w"]);
    println!(
        "measured log-log slopes:            rotate {slope_rotate:.2}, merge {slope_merge:.2} \
         (cache-level transitions inflate both on CPU)"
    );
    report.add_kv(vec![
        ("slope_rotate", Json::num(slope_rotate)),
        ("slope_merge", Json::num(slope_merge)),
    ]);

    // The paper-shape claims, robust to machine effects:
    //  (1) the merge/rotate gap *grows* with d,
    //  (2) at large d the merge dominates the layer cost while the
    //      rotate path stays within a small factor of the plain layer.
    let first = 0;
    let last = DIMS.len() - 1;
    let gap_small = series["merge_w"][first] / series["rotate_w"][first];
    let gap_large = series["merge_w"][last] / series["rotate_w"][last];
    println!(
        "merge/rotate gap: {gap_small:.2}x at d={} -> {gap_large:.2}x at d={} \
         (the paper's 10x-training-speedup driver)",
        DIMS[first], DIMS[last]
    );
    report.add_kv(vec![
        ("gap_small", Json::num(gap_small)),
        ("gap_large", Json::num(gap_large)),
    ]);
    assert!(
        gap_large > gap_small,
        "merge/rotate gap should grow with d ({gap_small:.2} -> {gap_large:.2})"
    );
    assert!(
        gap_large > 1.5,
        "merge should be clearly slower at d={} ({gap_large:.2}x)",
        DIMS[last]
    );

    let path = report.save()?;
    println!("results -> {}", path.display());
    let path = write_bench_json("kernel_scaling", "secs", &recs)?;
    println!("records -> {}", path.display());
    Ok(())
}
