//! Rank-scaling bench — ZeRO-1 sharded training swept over the rank
//! count: 1, 2, and 4 ranks (threads over the in-memory message mesh,
//! the same `RankGroup` collectives the TCP launcher runs).
//!
//!   cargo bench --bench rank_scaling [-- --quick]
//!
//! Every rank count replays the identical fixed-order reduction tree,
//! so the loss curves are bitwise identical from 1 rank to 4 (locked
//! by rust/tests/train_parallel.rs); what moves is the *per-rank* Adam
//! moment residency, which shards to `ceil(n/ranks)` elements. Shape
//! targets: per-rank moment bytes <= 0.6x @ 2 ranks and <= 0.35x @ 4
//! ranks vs the replicated baseline, and the analytic
//! `optimizer_shard_bytes` pricing within 1.5x of measurement.
//!
//! Emits `BENCH_rank_scaling.json` (shared config/mean/p50/p95 schema;
//! extra fields: method, ranks, moment_bytes_per_rank,
//! moment_bytes_frac, model_bytes_per_rank).

use std::sync::Arc;
use std::time::Duration;

use oftv2::bench::{
    bench_seed, fmt_ms, fmt_ratio, print_table, quick_mode, write_bench_json, BenchRecord,
};
use oftv2::comms::RankGroup;
use oftv2::config::RunCfg;
use oftv2::coordinator::Trainer;
use oftv2::json::Json;
use oftv2::memmodel::optimizer_shard_bytes;
use oftv2::runtime::Engine;
use oftv2::{artifacts_root, Result};

const TAG: &str = "small_oft_v2";

struct RankRun {
    losses: Vec<f64>,
    step_secs: Vec<f64>,
    moment_bytes: u64,
}

/// One rank's full training run (its own engine + trainer, connected
/// to the group when there is one).
fn run_rank(group: RankGroup, tag: &str, steps: usize) -> Result<RankRun> {
    let ranks = group.ranks();
    let engine = Engine::cpu()?;
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.seed = bench_seed();
    cfg.data.seed = bench_seed();
    cfg.data.task = "wiki".into();
    cfg.data.documents = 200;
    cfg.train.ranks = ranks;
    let mut tr = Trainer::new(&engine, &artifacts_root(), cfg)?;
    if ranks > 1 {
        tr.connect_ranks(Arc::new(group))?;
    }
    let hist = tr.train()?;
    Ok(RankRun {
        losses: hist.steps.iter().map(|s| s.loss).collect(),
        step_secs: hist.step_secs(steps / 4),
        moment_bytes: tr.moment_resident_bytes(),
    })
}

/// Run a whole rank group concurrently; returns the per-rank results
/// in rank order.
fn run_group(tag: &str, steps: usize, ranks: usize) -> Result<Vec<RankRun>> {
    let groups = RankGroup::mem_mesh(ranks, Duration::from_secs(120));
    std::thread::scope(|s| {
        let handles: Vec<_> = groups
            .into_iter()
            .map(|g| s.spawn(move || run_rank(g, tag, steps)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect()
    })
}

fn main() -> Result<()> {
    let steps = if quick_mode() { 6 } else { 16 };
    let rank_counts: [usize; 3] = [1, 2, 4];
    println!("rank_scaling: seed {}, {} steps per config, tag {TAG}", bench_seed(), steps);

    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rows = Vec::new();
    let mut full_bytes = 0u64; // replicated baseline (ranks = 1)
    let mut base_mean = 0.0f64;
    for ranks in rank_counts {
        let runs = run_group(TAG, steps, ranks)?;
        // The determinism contract, checked where it is cheapest: every
        // rank walked the same tree, so every loss curve is identical.
        for (r, run) in runs.iter().enumerate().skip(1) {
            assert_eq!(
                run.losses, runs[0].losses,
                "rank {r} loss curve diverged from rank 0 at ranks={ranks}"
            );
        }
        let max_bytes = runs.iter().map(|r| r.moment_bytes).max().unwrap_or(0);
        if ranks == 1 {
            full_bytes = max_bytes;
        }
        let frac = max_bytes as f64 / full_bytes.max(1) as f64;

        // Analytic pricing must track measurement (acceptance: 1.5x).
        let n_adapter = full_bytes as f64 / 8.0;
        let predicted = optimizer_shard_bytes(n_adapter, ranks);
        let model_ratio = predicted / (max_bytes as f64).max(1.0);
        assert!(
            (1.0 / 1.5..=1.5).contains(&model_ratio),
            "memmodel optimizer_shard_bytes off by >1.5x at ranks={ranks}: \
             predicted {predicted}, measured {max_bytes}"
        );

        let mut rec = BenchRecord::from_samples(format!("{TAG}_r{ranks}"), &runs[0].step_secs)
            .with("method", Json::str(TAG))
            .with("ranks", Json::num(ranks as f64))
            .with("moment_bytes_per_rank", Json::num(max_bytes as f64))
            .with("moment_bytes_frac", Json::num(frac))
            .with("model_bytes_per_rank", Json::num(predicted));
        if ranks == 1 {
            base_mean = rec.mean;
        }
        rec = rec.with("time_vs_r1", Json::num(rec.mean / base_mean.max(1e-12)));
        rows.push(vec![
            ranks.to_string(),
            fmt_ms(rec.mean),
            format!("{}", max_bytes),
            fmt_ratio(frac),
            fmt_ratio(model_ratio),
        ]);
        records.push(rec);
    }
    print_table(
        "rank_scaling: per-rank Adam residency vs rank count",
        &["ranks", "ms/step", "moment bytes/rank", "vs replicated", "model/measured"],
        &rows,
    );

    // ZeRO-1 shape targets: the moment shard must actually shrink.
    let frac_at = |ranks: usize| {
        records
            .iter()
            .find(|r| r.config == format!("{TAG}_r{ranks}"))
            .and_then(|r| match r.extra.iter().find(|(k, _)| k == "moment_bytes_frac") {
                Some((_, Json::Num(f))) => Some(*f),
                _ => None,
            })
            .expect("record just measured")
    };
    let f2 = frac_at(2);
    let f4 = frac_at(4);
    assert!(f2 <= 0.6, "2 ranks should hold <= 0.6x of the moments, got {f2:.3}x");
    assert!(f4 <= 0.35, "4 ranks should hold <= 0.35x of the moments, got {f4:.3}x");

    let path = write_bench_json("rank_scaling", "secs", &records)?;
    println!("\nresults -> {}", path.display());
    Ok(())
}
