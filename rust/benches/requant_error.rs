//! §4 — the merge→requantize analysis: after finetuning a quantized
//! model, merging the adapter back and re-quantizing costs QLoRA more
//! than QOFT because W + AB shifts the per-block dynamic range while
//! R·W preserves it (worst case differs by ‖AB‖∞).
//!
//! Sweeps adapter strength and reports requantization RMS error, range
//! inflation, and the ‖Δ‖∞ bound for both methods at matched ‖Δ‖_F.

use oftv2::bench::{print_table, Report};
use oftv2::coordinator::manifest::ModelDims;
use oftv2::json::Json;
use oftv2::peft::{LoraAdapter, OftAdapter};
use oftv2::quant::requant::{analysis_trainables, err_stats, merge_requant, QuantKind};
use oftv2::quant::Nf4Tensor;
use oftv2::tensor::Tensor;
use oftv2::util::rng::Rng;
use oftv2::Result;

fn main() -> Result<()> {
    let mut report = Report::new("requant_error");
    let (din, dout) = (512, 512);
    let n_seeds = 5;
    let base_seed = oftv2::bench::bench_seed();

    let mut rows = Vec::new();
    for strength in [0.01f32, 0.02, 0.05, 0.1] {
        let mut acc = [0.0f64; 6]; // [lora_rms, oft_rms, lora_infl, oft_infl, lora_dinf, oft_dinf]
        for seed in 0..n_seeds {
            // Offset so the unset-env default (base_seed = 7) collapses
            // to the pre-bench_seed literals and BENCH_*.json stays
            // comparable across the seed-plumbing change.
            let mut rng = Rng::new(993 + base_seed + seed);
            let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
            let lora = LoraAdapter::random(din, dout, 16, 32.0, strength, &mut rng);
            let oft = OftAdapter::random(din, 32, 6, strength, &mut rng);

            // match adaptation strength: rescale the LoRA delta to the
            // OFT delta's Frobenius norm before merging
            let d_oft = oft.merge(&w)?.sub(&w)?;
            let d_lora_raw = lora.delta()?.scale(lora.scale());
            let match_scale = d_oft.fro_norm() / d_lora_raw.fro_norm().max(1e-12);
            let d_lora = d_lora_raw.scale(match_scale);
            let merged_lora = w.add(&d_lora)?;
            let merged_oft = w.add(&d_oft)?;

            let rq = |m: &Tensor| err_stats(&Nf4Tensor::quantize(m).dequantize(), m);
            acc[0] += rq(&merged_lora).rms;
            acc[1] += rq(&merged_oft).rms;
            acc[2] += (merged_lora.linf_norm() / w.linf_norm()) as f64;
            acc[3] += (merged_oft.linf_norm() / w.linf_norm()) as f64;
            acc[4] += d_lora.linf_norm() as f64;
            acc[5] += d_oft.linf_norm() as f64;
        }
        for a in &mut acc {
            *a /= n_seeds as f64;
        }
        rows.push(vec![
            format!("{strength}"),
            format!("{:.5}", acc[0]),
            format!("{:.5}", acc[1]),
            format!("{:.3}", acc[2]),
            format!("{:.3}", acc[3]),
            format!("{:.4}", acc[4]),
            format!("{:.4}", acc[5]),
        ]);
        report.add_kv(vec![
            ("strength", Json::num(strength as f64)),
            ("qlora_rms", Json::num(acc[0])),
            ("qoft_rms", Json::num(acc[1])),
            ("qlora_inflation", Json::num(acc[2])),
            ("qoft_inflation", Json::num(acc[3])),
            ("qlora_delta_inf", Json::num(acc[4])),
            ("qoft_delta_inf", Json::num(acc[5])),
        ]);
        // the §4 ordering at matched ||Δ||_F: QOFT's requant error and
        // range inflation do not exceed QLoRA's (averaged over seeds)
        assert!(
            acc[1] <= acc[0] * 1.02,
            "strength {strength}: QOFT rms {} vs QLoRA {}",
            acc[1],
            acc[0]
        );
        assert!(
            acc[3] <= acc[2] + 0.02,
            "strength {strength}: QOFT inflation {} vs QLoRA {}",
            acc[3],
            acc[2]
        );
    }
    print_table(
        "§4: merge -> NF4 requantize at matched ||ΔW||_F (mean of 5 seeds)",
        &[
            "adapter std",
            "QLoRA rms",
            "QOFT rms",
            "QLoRA ∞-infl",
            "QOFT ∞-infl",
            "‖AB‖∞",
            "‖RW-W‖∞",
        ],
        &rows,
    );

    // unmatched (raw) reports too, for the record, now through the
    // registry's trait-driven merge path (70 + default 7 = the
    // pre-bench_seed literal 77)
    let mut rng = Rng::new(70 + base_seed);
    let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
    let dims = ModelDims::analysis(16, 32);
    let lora = oftv2::adapters::get("lora")?;
    let oft = oftv2::adapters::get("oft_v2")?;
    let tr_lora = analysis_trainables(lora, "w", din, dout, &dims, 0.05, &mut rng);
    let tr_oft = analysis_trainables(oft, "w", din, dout, &dims, 0.05, &mut rng);
    let (_, rl) = merge_requant(lora, "w", &w, &tr_lora, &dims, QuantKind::Nf4)?;
    let (_, ro) = merge_requant(oft, "w", &w, &tr_oft, &dims, QuantKind::Nf4)?;
    println!(
        "\nraw (unmatched) reports: QLoRA rms {:.5} infl {:.3} | QOFT rms {:.5} infl {:.3}",
        rl.merged.rms, rl.range_inflation, ro.merged.rms, ro.range_inflation
    );
    println!("(paper §4: worst-case requant error differs by ||AB||_inf; orthogonal merges preserve range)");

    let path = report.save()?;
    println!("results -> {}", path.display());
    Ok(())
}
