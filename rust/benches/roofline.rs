//! Roofline bench — makes "fast as the hardware allows" a measured
//! claim: GFLOP/s of each hot-path kernel (scalar oracle vs SIMD
//! dispatch) at the shapes `modelspec` actually emits, against the
//! measured per-core arithmetic peak
//! (`tensor::simd::arithmetic_peak_gflops`).
//!
//! Kernels: dense f32 matmul, fused NF4/AWQ matmuls (+ the NF4
//! transposed backward), the CNP block rotations, and the raw NF4 row
//! decode. Shapes: Qwen2.5-0.5B q_proj (896x896) always; Llama-2-7B
//! q_proj (4096x4096) unless `--quick`.
//!
//!   cargo bench --bench roofline --features simd [-- --quick]
//!
//! Emits `BENCH_roofline.json` (shared schema, unit = gflops). When the
//! SIMD kernels are live, asserts the acceptance floor: >= 2x over the
//! scalar oracle on the f32 matmul and the fused NF4 matmul.

use oftv2::bench::{bench_seed, print_table, quick_mode, write_bench_json, BenchRecord};
use oftv2::json::Json;
use oftv2::modelspec::ModelSpec;
use oftv2::peft;
use oftv2::quant::{AwqTensor, Nf4Tensor, QuantWeight};
use oftv2::runtime::layers::linear::{
    block_rotate_fast, block_rotate_transposed, build_cnp_blocks,
};
use oftv2::tensor::{force_scalar_kernels, simd_kernels_active, Tensor};
use oftv2::util::rng::Rng;
use oftv2::util::stats::Summary;
use oftv2::util::timer::Timer;
use oftv2::Result;

/// Raw per-call samples (seconds). `Bench::run` only returns a summary;
/// the roofline needs every sample to convert each to GFLOP/s.
fn time_samples<F: FnMut()>(warmup: usize, iters: usize, max_secs: f64, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    let budget = Timer::start();
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        f();
        out.push(t.secs());
        if max_secs > 0.0 && budget.secs() > max_secs {
            break;
        }
    }
    out
}

/// One kernel under measurement: a label, its FLOPs per call, and the
/// call itself (dispatch is controlled from outside via
/// `force_scalar_kernels`).
struct Kernel<'a> {
    name: String,
    shape: String,
    flops: f64,
    run: Box<dyn FnMut() + 'a>,
}

fn gflops(samples: &[f64], flops: f64) -> Vec<f64> {
    samples.iter().map(|s| flops / s.max(1e-12) / 1e9).collect()
}

fn main() -> Result<()> {
    let quick = quick_mode();
    let iters = if quick { 5 } else { 15 };
    let max_secs = if quick { 3.0 } else { 10.0 };
    let mut rng = Rng::new(bench_seed());
    let simd_on = simd_kernels_active();

    let peak = oftv2::tensor::simd::arithmetic_peak_gflops();
    println!(
        "arithmetic peak estimate: {peak:.1} GFLOP/s per core \
         (register-resident multiply-add loop)"
    );

    // ---- shapes: what modelspec actually emits -------------------------
    let qwen = ModelSpec::qwen25("0.5b")?;
    let q = qwen
        .linears_per_layer
        .iter()
        .find(|l| l.label == "q_proj")
        .expect("qwen2.5 has a q_proj");
    let mut shapes = vec![("q896", q.din, q.dout)];
    if !quick {
        let llama = ModelSpec::llama2_7b();
        let lq = llama
            .linears_per_layer
            .iter()
            .find(|l| l.label == "q_proj")
            .expect("llama2 has a q_proj");
        shapes.push(("l4096", lq.din, lq.dout));
    }
    let m = 64usize; // decode/train microbatch rows

    let mut kernels: Vec<Kernel> = Vec::new();
    for &(tag, din, dout) in &shapes {
        let x = Tensor::randn(&[m, din], 1.0, &mut rng);
        let g = Tensor::randn(&[m, dout], 1.0, &mut rng);
        let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
        let mm_flops = 2.0 * (m * din * dout) as f64;
        let shape = format!("({m},{din})@({din},{dout})");

        {
            let (x, w) = (x.clone(), w.clone());
            kernels.push(Kernel {
                name: format!("matmul_f32_{tag}"),
                shape: shape.clone(),
                flops: mm_flops,
                run: Box::new(move || {
                    std::hint::black_box(x.matmul(&w).unwrap());
                }),
            });
        }
        let nf4 = QuantWeight::nf4(Nf4Tensor::quantize(&w))?;
        {
            let (x, nf4) = (x.clone(), nf4.clone());
            kernels.push(Kernel {
                name: format!("fused_nf4_matmul_{tag}"),
                shape: shape.clone(),
                flops: mm_flops,
                run: Box::new(move || {
                    std::hint::black_box(nf4.matmul(&x).unwrap());
                }),
            });
        }
        {
            let (g, nf4) = (g.clone(), nf4.clone());
            kernels.push(Kernel {
                name: format!("fused_nf4_matmul_t_{tag}"),
                shape: format!("({m},{dout})@({din},{dout})^T"),
                flops: mm_flops,
                run: Box::new(move || {
                    std::hint::black_box(nf4.matmul_t(&g).unwrap());
                }),
            });
        }
        {
            // Pure decode rate: one multiply per element (code * absmax),
            // so "GFLOP/s" here is decoded Gelem/s.
            let n = din * dout;
            let mut panel = vec![0.0f32; n];
            kernels.push(Kernel {
                name: format!("nf4_decode_{tag}"),
                shape: format!("({din},{dout})"),
                flops: n as f64,
                run: Box::new(move || {
                    nf4.decode_rows(0, din, &mut panel);
                    std::hint::black_box(&panel);
                }),
            });
        }
        if tag == "q896" {
            let awq = QuantWeight::awq(AwqTensor::quantize(&w, None)?)?;
            let xa = x.clone();
            kernels.push(Kernel {
                name: format!("fused_awq_matmul_{tag}"),
                shape: shape.clone(),
                flops: mm_flops,
                run: Box::new(move || {
                    std::hint::black_box(awq.matmul(&xa).unwrap());
                }),
            });

            // CNP block rotations at the paper's operating point: b=32
            // blocks over the full hidden dim, k=4 Neumann terms.
            let b = 32usize;
            let nb = din / b;
            let packed = Tensor::randn(&[nb, peft::packed_dim(b)], 0.02, &mut rng);
            let blocks = build_cnp_blocks(&packed, b, 4)?;
            let rot_flops = 2.0 * (m * din * b) as f64;
            {
                let (x, blocks) = (x.clone(), blocks.clone());
                kernels.push(Kernel {
                    name: format!("block_rotate_fwd_{tag}"),
                    shape: format!("({m},{din}) b={b}"),
                    flops: rot_flops,
                    run: Box::new(move || {
                        std::hint::black_box(block_rotate_fast(&x, &blocks).unwrap());
                    }),
                });
            }
            kernels.push(Kernel {
                name: format!("block_rotate_bwd_{tag}"),
                shape: format!("({m},{din}) b={b}"),
                flops: rot_flops,
                run: Box::new(move || {
                    std::hint::black_box(block_rotate_transposed(&x, &blocks).unwrap());
                }),
            });
        }
    }

    // ---- measure: scalar oracle, then (if live) SIMD dispatch ----------
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for k in kernels.iter_mut() {
        let prev = force_scalar_kernels(true);
        let scalar_secs = time_samples(2, iters, max_secs, &mut k.run);
        force_scalar_kernels(prev);
        let scalar_gf = gflops(&scalar_secs, k.flops);
        let scalar_med = Summary::of(&scalar_gf).median;
        records.push(
            BenchRecord::from_samples(format!("{}_scalar", k.name), &scalar_gf)
                .with("kernel", Json::str(k.name.clone()))
                .with("shape", Json::str(k.shape.clone()))
                .with("dispatch", Json::str("scalar"))
                .with("flops_per_call", Json::num(k.flops))
                .with("peak_gflops", Json::num(peak))
                .with("frac_of_peak", Json::num(scalar_med / peak.max(1e-12))),
        );

        let (simd_med, speedup) = if simd_on {
            let simd_secs = time_samples(2, iters, max_secs, &mut k.run);
            let simd_gf = gflops(&simd_secs, k.flops);
            let med = Summary::of(&simd_gf).median;
            let speedup = med / scalar_med.max(1e-12);
            records.push(
                BenchRecord::from_samples(format!("{}_simd", k.name), &simd_gf)
                    .with("kernel", Json::str(k.name.clone()))
                    .with("shape", Json::str(k.shape.clone()))
                    .with("dispatch", Json::str("simd"))
                    .with("flops_per_call", Json::num(k.flops))
                    .with("peak_gflops", Json::num(peak))
                    .with("frac_of_peak", Json::num(med / peak.max(1e-12)))
                    .with("speedup_vs_scalar", Json::num(speedup)),
            );
            speedups.push((k.name.clone(), speedup));
            (Some(med), Some(speedup))
        } else {
            (None, None)
        };

        let simd_cell = match simd_med {
            Some(v) => format!("{v:.2}"),
            None => "-".to_string(),
        };
        let speedup_cell = match speedup {
            Some(v) => format!("{v:.2}x"),
            None => "-".to_string(),
        };
        rows.push(vec![
            k.name.clone(),
            k.shape.clone(),
            format!("{scalar_med:.2}"),
            simd_cell,
            speedup_cell,
            format!(
                "{:.0}%",
                100.0 * simd_med.unwrap_or(scalar_med) / peak.max(1e-12)
            ),
        ]);
    }

    print_table(
        &format!(
            "roofline: GFLOP/s per kernel (peak {peak:.1} GFLOP/s, simd {})",
            if simd_on { "on" } else { "off" }
        ),
        &["kernel", "shape", "scalar GF/s", "simd GF/s", "speedup", "% peak"],
        &rows,
    );

    let path = write_bench_json("roofline", "gflops", &records)?;
    println!("\nresults -> {}", path.display());

    // Acceptance floor: the SIMD microkernels must beat the scalar
    // oracle by >= 2x on the f32 matmul and the fused NF4 matmul at a
    // modelspec-realistic shape. Only meaningful when the dispatch is
    // actually live.
    if simd_on {
        for want in ["matmul_f32_q896", "fused_nf4_matmul_q896"] {
            let (_, s) = speedups
                .iter()
                .find(|(n, _)| n == want)
                .expect("acceptance kernel measured");
            assert!(
                *s >= 2.0,
                "{want}: simd speedup {s:.2}x < 2x over the scalar oracle"
            );
        }
        println!("acceptance: >= 2x over scalar on matmul_f32_q896 and fused_nf4_matmul_q896");
    }
    Ok(())
}
