//! Serving bench — the ROADMAP's "serve heavy traffic" scenario over
//! the BaseModel/AdapterState split:
//!
//! 1. Per-token decode cost: the old full re-forward path recomputes
//!    all T rows of the padded sequence for every generated token, so
//!    its per-token cost grows linearly with the model's seq_len T
//!    (O(T²) per sequence). The KV-cached incremental decoder touches
//!    one row per token — per-token cost flat in T (O(T) per
//!    sequence). Measured across presets of growing T, plus an
//!    early-vs-late flatness check within one sequence.
//! 2. Multi-tenant throughput: OFTv2 + QOFT adapters batched over ONE
//!    shared base, per-adapter latency/throughput.
//! 3. Load generator: 100+ concurrent adapters (every registered
//!    method) against the paged scheduler with a constrained decoder
//!    residency cap — asserts p95/p99 service-time SLOs, flat
//!    upload_count across hot-swaps, and a bounded KV block pool.
//!
//!   cargo bench --bench serving [-- --quick]
//!
//! Emits `BENCH_serving.json` (shared config/mean/p50/p95/p99 schema).

use oftv2::bench::{fmt_ms, print_table, quick_mode, write_bench_json, BenchRecord};
use oftv2::config::RunCfg;
use oftv2::coordinator::{BaseModel, Manifest, Trainer};
use oftv2::json::Json;
use oftv2::runtime::Engine;
use oftv2::serve::{ServeConfig, Server};
use oftv2::util::argmax;
use oftv2::util::stats::Summary;
use oftv2::util::timer::Timer;
use oftv2::{artifacts_root, Result};

fn trainer<'e>(engine: &'e Engine, tag: &str) -> Result<Trainer<'e>> {
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = 0;
    cfg.log_every = 0;
    cfg.data.task = "math".into();
    cfg.data.documents = 150;
    Trainer::new(engine, &artifacts_root(), cfg)
}

/// Mean per-token times of both decode paths for one bundle:
/// (kv_samples, reforward_samples), seconds per generated token.
fn decode_costs(tr: &mut Trainer, n_tokens: usize) -> Result<(Vec<f64>, Vec<f64>)> {
    let dec = tr.decoder()?;
    let t = tr.manifest.model.seq_len;
    let n = n_tokens.min(t - 2);

    let mut kv = Vec::with_capacity(n);
    let mut sess = dec.begin()?;
    let mut logits = sess.step(1)?;
    for _ in 0..n {
        let next = argmax(&logits) as i32;
        let t0 = Timer::start();
        logits = sess.step(next)?;
        kv.push(t0.secs());
    }

    let mut rf = Vec::new();
    // Warm the lazy logits_last graph so its build cost stays out of
    // the timed region, then sample: each re-forward token pays a full
    // T-row forward (variance is low, cost is high).
    tr.decode_greedy_reforward(&[1], 1)?;
    for rep in 0..3usize {
        let ids: Vec<i32> = vec![1, (rep + 2) as i32];
        let t0 = Timer::start();
        let gen = tr.decode_greedy_reforward(&ids, 4)?;
        rf.push(t0.secs() / gen.len().max(1) as f64);
    }
    Ok((kv, rf))
}

fn main() -> Result<()> {
    let quick = quick_mode();
    let engine = Engine::cpu()?;
    let mut records: Vec<BenchRecord> = Vec::new();

    // ---- 1. per-token decode cost vs model sequence length -------------
    let presets: &[&str] = if quick {
        &["tiny", "small"]
    } else {
        &["tiny", "small", "bench"]
    };
    let mut rows = Vec::new();
    let mut ratios = Vec::new();
    for preset in presets {
        let tag = format!("{preset}_oft_v2");
        let mut tr = trainer(&engine, &tag)?;
        let t = tr.manifest.model.seq_len;
        let d = tr.manifest.model.d_model;
        let (kv, rf) = decode_costs(&mut tr, 32)?;
        let (kv_mean, rf_mean) = (Summary::of(&kv).mean, Summary::of(&rf).mean);
        let ratio = rf_mean / kv_mean.max(1e-12);
        ratios.push((t, ratio));
        rows.push(vec![
            format!("{preset} (T={t}, d={d})"),
            fmt_ms(kv_mean),
            fmt_ms(rf_mean),
            format!("{ratio:.1}x"),
        ]);
        records.push(
            BenchRecord::from_samples(format!("decode_kv_{preset}"), &kv)
                .with("path", Json::str("kv"))
                .with("seq_len", Json::num(t as f64))
                .with("d_model", Json::num(d as f64)),
        );
        records.push(
            BenchRecord::from_samples(format!("decode_reforward_{preset}"), &rf)
                .with("path", Json::str("reforward"))
                .with("seq_len", Json::num(t as f64))
                .with("d_model", Json::num(d as f64)),
        );
    }
    print_table(
        "per-token decode cost (KV cache vs full re-forward)",
        &["preset", "KV ms/tok", "reforward ms/tok", "speedup"],
        &rows,
    );
    // Shape: the re-forward path recomputes all T rows per token; the
    // KV path touches one. The ratio's absolute size depends on how
    // well the T-row matmuls parallelize on this host, so assert a
    // conservative floor and report the trend.
    for (t, ratio) in &ratios {
        assert!(
            *ratio > 1.5,
            "KV decode should clearly beat re-forward at T={t} (got {ratio:.2}x)"
        );
    }
    let (t_small, r_small) = ratios[0];
    let (t_large, r_large) = *ratios.last().unwrap();
    println!(
        "re-forward/KV per-token ratio: {r_small:.1}x at T={t_small} -> {r_large:.1}x at \
         T={t_large} (re-forward pays all T rows per token; KV pays one)"
    );

    // Flatness within one sequence: KV per-token cost early vs late.
    let tag = if quick { "small_oft_v2" } else { "bench_oft_v2" };
    let mut tr = trainer(&engine, tag)?;
    let t = tr.manifest.model.seq_len;
    let dec = tr.decoder()?;
    let mut early = Vec::new();
    let mut late = Vec::new();
    for _rep in 0..2 {
        let mut sess = dec.begin()?;
        let mut logits = sess.step(1)?;
        for pos in 1..t {
            let next = argmax(&logits) as i32;
            let t0 = Timer::start();
            logits = sess.step(next)?;
            let secs = t0.secs();
            if pos < t / 4 {
                early.push(secs);
            } else if pos >= 3 * t / 4 {
                late.push(secs);
            }
        }
    }
    let (early_mean, late_mean) = (Summary::of(&early).mean, Summary::of(&late).mean);
    let growth = late_mean / early_mean.max(1e-12);
    println!(
        "KV per-token cost within a T={t} sequence: {} early -> {} late ({growth:.2}x; \
         attention is O(pos) but matmuls dominate)",
        fmt_ms(early_mean),
        fmt_ms(late_mean)
    );
    assert!(
        growth < 2.5,
        "KV per-token cost should stay near-flat across the sequence (got {growth:.2}x)"
    );
    records.push(
        BenchRecord::from_samples("decode_kv_flatness_early", &early)
            .with("seq_len", Json::num(t as f64)),
    );
    records.push(
        BenchRecord::from_samples("decode_kv_flatness_late", &late)
            .with("seq_len", Json::num(t as f64))
            .with("growth_vs_early", Json::num(growth)),
    );

    // SIMD-vs-scalar end-to-end delta: the same KV decode loop with the
    // scalar oracle forced, so BENCH_serving.json carries the serving-
    // path before/after — not just the microbench numbers in
    // BENCH_roofline.json.
    if oftv2::tensor::simd_kernels_active() {
        let mut sample = |n: usize| -> Result<Vec<f64>> {
            let mut sess = dec.begin()?;
            let mut logits = sess.step(1)?;
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                let next = argmax(&logits) as i32;
                let t0 = Timer::start();
                logits = sess.step(next)?;
                out.push(t0.secs());
            }
            Ok(out)
        };
        let n = (t - 2).min(48);
        let simd = sample(n)?;
        let prev = oftv2::tensor::force_scalar_kernels(true);
        let scalar = sample(n);
        oftv2::tensor::force_scalar_kernels(prev);
        let scalar = scalar?;
        let (sm, cm) = (Summary::of(&simd).mean, Summary::of(&scalar).mean);
        let speedup = cm / sm.max(1e-12);
        println!(
            "KV decode per-token: scalar {} vs simd {} ({speedup:.2}x)",
            fmt_ms(cm),
            fmt_ms(sm)
        );
        records.push(
            BenchRecord::from_samples("decode_kv_simd", &simd)
                .with("dispatch", Json::str("simd"))
                .with("seq_len", Json::num(t as f64)),
        );
        records.push(
            BenchRecord::from_samples("decode_kv_forced_scalar", &scalar)
                .with("dispatch", Json::str("forced_scalar"))
                .with("seq_len", Json::num(t as f64))
                .with("speedup_vs_scalar", Json::num(speedup)),
        );
    }

    // ---- 2. multi-tenant serving over one shared base ------------------
    let preset = if quick { "small" } else { "bench" };
    let seed = oftv2::bench::bench_seed();
    let base = BaseModel::for_preset(&engine, preset, seed, None)?;
    let uploads_before = engine.upload_count();
    let mut server = Server::new(&engine, base, 4);
    for (name, tag) in [
        ("oft_v2", format!("{preset}_oft_v2")),
        ("qoft", format!("{preset}_qoft_nf4")),
        ("boft", format!("{preset}_boft")),
        ("hoft", format!("{preset}_hoft")),
    ] {
        let man = Manifest::load_or_builtin(artifacts_root().join(&tag))?;
        server.add_adapter_init(name, man, seed, None)?;
    }
    let adapter_uploads = engine.upload_count() - uploads_before;

    let n_requests = if quick { 6 } else { 16 };
    let max_new = if quick { 8 } else { 16 };
    let names = server.adapter_names();
    for r in 0..n_requests {
        let prompt: Vec<i32> = vec![1, (r % 19 + 2) as i32, (r % 11 + 2) as i32];
        server.submit(&names[r % names.len()], prompt, max_new)?;
    }
    let responses = server.run_until_idle()?;
    assert_eq!(responses.len(), n_requests);

    let m = server.metrics().clone();
    let mut rows = Vec::new();
    for (name, a) in &m.per_adapter {
        rows.push(vec![
            name.clone(),
            a.requests.to_string(),
            a.tokens_out.to_string(),
            format!("{:.1}", a.mean_latency_secs() * 1e3),
            format!("{:.1}", a.tokens_per_sec()),
        ]);
        let lat: Vec<f64> = responses
            .iter()
            .filter(|r| &r.adapter == name)
            .map(|r| r.latency_secs)
            .collect();
        records.push(
            BenchRecord::from_samples(format!("serve_latency_{name}"), &lat)
                .with("tokens_per_sec", Json::num(a.tokens_per_sec()))
                .with("requests", Json::num(a.requests as f64)),
        );
    }
    print_table(
        &format!("multi-tenant serving ({preset}: OFTv2 + QOFT + BOFT + HOFT, one base, batch 4)"),
        &["adapter", "reqs", "tokens", "latency ms", "tok/s"],
        &rows,
    );
    println!(
        "shared base: {adapter_uploads} adapter-attach uploads (quant packs only), \
         {:.1} tok/s aggregate, peak batch {}",
        m.tokens_per_sec(),
        m.peak_active
    );
    records.push(
        BenchRecord::from_samples("serve_aggregate", &[m.wall_secs])
            .with("tokens_per_sec", Json::num(m.tokens_per_sec()))
            .with("total_tokens", Json::num(m.total_tokens as f64))
            .with("adapter_attach_uploads", Json::num(adapter_uploads as f64)),
    );

    // ---- 3. load generator: 100+ adapters, paged KV, SLO asserts -------
    // Every registered method, >= 100 named tenants over ONE tiny base,
    // a residency cap far below the tenant count (forcing constant
    // hot-swaps), and the paged scheduler's default bounded pool. SLOs
    // are asserted on *service* time (latency minus queue wait) so they
    // measure the scheduler + paging machinery, not queue depth.
    let n_adapters = if quick { 100 } else { 120 };
    let n_requests = if quick { 120 } else { 360 };
    let max_new = if quick { 4 } else { 8 };
    let tags = oftv2::adapters::bundle_tags("tiny");
    let base = BaseModel::for_preset(&engine, "tiny", seed, None)?;

    let mut cfg = ServeConfig::new(8);
    cfg.block_tokens = 8;
    cfg.max_queue = n_requests + 8;
    cfg.max_resident = Some(12);
    let mut server = Server::with_config(&engine, base, cfg);
    for i in 0..n_adapters {
        let tag = &tags[i % tags.len()];
        let name = format!("{tag}@{i}");
        server.add_adapter_init(&name, Manifest::builtin(tag)?, seed + i as u64, None)?;
    }
    let names = server.adapter_names();
    assert!(
        server.resident_adapters() <= 12,
        "residency cap must hold after attaching {n_adapters} adapters \
         (got {} resident)",
        server.resident_adapters()
    );

    // Per-request service-time baseline: a few solo requests through the
    // same server before load. Relative SLOs stay meaningful across
    // hosts of very different speed.
    let mut baseline = Vec::new();
    for name in names.iter().take(3) {
        server.submit(name, vec![1, 2, 3], max_new)?;
        let resp = server.run_until_idle()?;
        assert_eq!(resp.len(), 1);
        baseline.push(resp[0].latency_secs - resp[0].queued_secs);
    }
    let baseline_mean = Summary::of(&baseline).mean;

    let uploads_at_load = engine.upload_count();
    for r in 0..n_requests {
        let prompt: Vec<i32> = vec![1, (r % 19 + 2) as i32, (r % 11 + 2) as i32];
        server.submit(&names[r % names.len()], prompt, max_new)?;
    }
    let t0 = Timer::start();
    let responses = server.run_until_idle()?;
    let load_secs = t0.secs();
    assert_eq!(responses.len(), n_requests, "every admitted request must complete");
    assert_eq!(
        engine.upload_count(),
        uploads_at_load,
        "adapter hot-swaps must never re-upload the shared base or packs"
    );

    let latency: Vec<f64> = responses.iter().map(|r| r.latency_secs).collect();
    let service: Vec<f64> = responses
        .iter()
        .map(|r| r.latency_secs - r.queued_secs)
        .collect();
    let lat = Summary::of(&latency);
    let svc = Summary::of(&service);

    // SLOs: a request's service time is bounded by its share of a full
    // batch of decode work, plus paging. Multipliers are generous (CI
    // hosts jitter; p99 is 1-2 requests here) but still catch a paging
    // or scheduling path that degrades by an order of magnitude.
    let batch = server.config().max_batch as f64;
    let slo_p95 = (10.0 * batch * baseline_mean).max(0.025);
    let slo_p99 = (20.0 * batch * baseline_mean).max(0.05);
    assert!(
        svc.p95 <= slo_p95,
        "p95 service time SLO violated: {} > {} (baseline {})",
        fmt_ms(svc.p95),
        fmt_ms(slo_p95),
        fmt_ms(baseline_mean)
    );
    assert!(
        svc.p99 <= slo_p99,
        "p99 service time SLO violated: {} > {} (baseline {})",
        fmt_ms(svc.p99),
        fmt_ms(slo_p99),
        fmt_ms(baseline_mean)
    );

    let m = server.metrics().clone();
    assert!(
        m.adapter_page_ins > 0 && m.adapter_evictions > 0,
        "a 12-resident cap over {n_adapters} adapters must page \
         (page_ins {}, evictions {})",
        m.adapter_page_ins,
        m.adapter_evictions
    );
    assert_eq!(m.kv.in_use, 0, "all KV blocks must return to the free list");
    assert!(
        m.kv.peak_in_use <= m.kv.capacity_blocks && m.kv.slab_blocks <= m.kv.capacity_blocks,
        "KV stays bounded by the pool however many tenants come and go \
         (peak {}, slab {}, capacity {})",
        m.kv.peak_in_use,
        m.kv.slab_blocks,
        m.kv.capacity_blocks
    );

    print_table(
        &format!(
            "load generator ({n_adapters} adapters x {} methods, {n_requests} requests, \
             batch 8, 12 resident)",
            tags.len()
        ),
        &["metric", "p50", "p95", "p99", "SLO"],
        &[
            vec![
                "service time".into(),
                fmt_ms(svc.median),
                fmt_ms(svc.p95),
                fmt_ms(svc.p99),
                format!("{} / {}", fmt_ms(slo_p95), fmt_ms(slo_p99)),
            ],
            vec![
                "latency (incl. queue)".into(),
                fmt_ms(lat.median),
                fmt_ms(lat.p95),
                fmt_ms(lat.p99),
                "-".into(),
            ],
        ],
    );
    println!(
        "{n_requests} requests in {}: {:.1} tok/s aggregate, {} page-ins / {} evictions, \
         KV peak {}/{} blocks, 0 uploads during load",
        fmt_ms(load_secs),
        m.tokens_per_sec(),
        m.adapter_page_ins,
        m.adapter_evictions,
        m.kv.peak_in_use,
        m.kv.capacity_blocks
    );
    records.push(
        BenchRecord::from_samples("serve_load_latency", &latency)
            .with("adapters", Json::num(n_adapters as f64))
            .with("requests", Json::num(n_requests as f64))
            .with("max_batch", Json::num(batch)),
    );
    records.push(
        BenchRecord::from_samples("serve_load_service", &service)
            .with("adapters", Json::num(n_adapters as f64))
            .with("slo_p95_secs", Json::num(slo_p95))
            .with("slo_p99_secs", Json::num(slo_p99))
            .with("baseline_secs", Json::num(baseline_mean))
            .with("page_ins", Json::num(m.adapter_page_ins as f64))
            .with("evictions", Json::num(m.adapter_evictions as f64))
            .with("kv_peak_blocks", Json::num(m.kv.peak_in_use as f64))
            .with("kv_capacity_blocks", Json::num(m.kv.capacity_blocks as f64))
            .with("uploads_during_load", Json::num(0.0)),
    );

    // ---- 4. merged-artifact fleet mix ----------------------------------
    // The lifecycle's serving end: a fleet mixing live adapters (shared
    // base + trainables) with merged artifacts (zero-trainable residents
    // on private bases), under a residency cap that forces both kinds to
    // page. Hot-loads must stay upload-free after the initial attach.
    let n_live = if quick { 6 } else { 12 };
    let n_merged = if quick { 6 } else { 12 };
    let mix_requests = if quick { 60 } else { 180 };
    let base = BaseModel::for_preset(&engine, "tiny", seed, None)?;

    let merge_tags = ["tiny_oft_v2", "tiny_lora", "tiny_boft"];
    let mut merged_arts = Vec::new();
    for tag in merge_tags {
        let mut c = RunCfg::default();
        c.tag = tag.into();
        c.steps = 0;
        c.log_every = 0;
        c.seed = seed;
        c.data.task = "math".into();
        c.data.documents = 150;
        let tr = Trainer::with_base(
            &engine,
            Manifest::builtin(tag)?,
            c,
            None,
            std::sync::Arc::clone(&base),
        )?;
        merged_arts.push(oftv2::artifact::merge_checkpoint(
            &Manifest::builtin(tag)?,
            &tr.checkpoint()?,
            seed,
            oftv2::quant::requant::QuantKind::None,
        )?);
    }

    let mut cfg = ServeConfig::new(8);
    cfg.block_tokens = 8;
    cfg.max_queue = mix_requests + 8;
    cfg.max_resident = Some(6);
    let mut server = Server::with_config(&engine, base, cfg);
    for i in 0..n_live {
        let tag = &tags[i % tags.len()];
        server.add_adapter_init(&format!("live@{i}"), Manifest::builtin(tag)?, seed, None)?;
    }
    for i in 0..n_merged {
        server.add_artifact(&format!("merged@{i}"), &merged_arts[i % merged_arts.len()])?;
    }
    assert_eq!(server.merged_adapters(), n_merged);
    let names = server.adapter_names();

    let uploads_at_mix = engine.upload_count();
    for r in 0..mix_requests {
        let prompt: Vec<i32> = vec![1, (r % 19 + 2) as i32, (r % 11 + 2) as i32];
        server.submit(&names[r % names.len()], prompt, max_new)?;
    }
    let t0 = Timer::start();
    let responses = server.run_until_idle()?;
    let mix_secs = t0.secs();
    assert_eq!(responses.len(), mix_requests);
    assert_eq!(
        engine.upload_count(),
        uploads_at_mix,
        "merged-artifact and live-adapter page-ins must both be upload-free"
    );

    let svc_of = |pred: &dyn Fn(&str) -> bool| -> Vec<f64> {
        responses
            .iter()
            .filter(|r| pred(&r.adapter))
            .map(|r| r.latency_secs - r.queued_secs)
            .collect()
    };
    let svc_merged = svc_of(&|a: &str| a.starts_with("merged@"));
    let svc_live = svc_of(&|a: &str| a.starts_with("live@"));
    assert!(!svc_merged.is_empty() && !svc_live.is_empty());
    let m = server.metrics().clone();
    assert!(
        m.adapter_page_ins > 0,
        "a 6-resident cap over {} tenants must page",
        n_live + n_merged
    );
    print_table(
        &format!(
            "merged-artifact fleet mix ({n_live} live + {n_merged} merged over one tiny \
             base, {mix_requests} requests, 6 resident)"
        ),
        &["tenant kind", "reqs", "service p50", "service p95"],
        &[
            vec![
                "merged artifact".into(),
                svc_merged.len().to_string(),
                fmt_ms(Summary::of(&svc_merged).median),
                fmt_ms(Summary::of(&svc_merged).p95),
            ],
            vec![
                "live adapter".into(),
                svc_live.len().to_string(),
                fmt_ms(Summary::of(&svc_live).median),
                fmt_ms(Summary::of(&svc_live).p95),
            ],
        ],
    );
    println!(
        "{mix_requests} mixed requests in {}: {:.1} tok/s aggregate, {} page-ins, \
         0 uploads during load",
        fmt_ms(mix_secs),
        m.tokens_per_sec(),
        m.adapter_page_ins
    );
    records.push(
        BenchRecord::from_samples("serve_merged_mix", &svc_merged)
            .with("live_adapters", Json::num(n_live as f64))
            .with("merged_artifacts", Json::num(n_merged as f64))
            .with("requests", Json::num(mix_requests as f64))
            .with("live_service_p95_secs", Json::num(Summary::of(&svc_live).p95))
            .with("page_ins", Json::num(m.adapter_page_ins as f64))
            .with("uploads_during_load", Json::num(0.0)),
    );

    let path = write_bench_json("serving", "secs", &records)?;
    println!("\nresults -> {}", path.display());
    Ok(())
}

