//! Serving bench — the ROADMAP's "serve heavy traffic" scenario over
//! the BaseModel/AdapterState split:
//!
//! 1. Per-token decode cost: the old full re-forward path recomputes
//!    all T rows of the padded sequence for every generated token, so
//!    its per-token cost grows linearly with the model's seq_len T
//!    (O(T²) per sequence). The KV-cached incremental decoder touches
//!    one row per token — per-token cost flat in T (O(T) per
//!    sequence). Measured across presets of growing T, plus an
//!    early-vs-late flatness check within one sequence.
//! 2. Multi-tenant throughput: OFTv2 + QOFT adapters batched over ONE
//!    shared base, per-adapter latency/throughput.
//!
//!   cargo bench --bench serving [-- --quick]
//!
//! Emits `BENCH_serving.json` (shared config/mean/p50/p95 schema).

use oftv2::bench::{fmt_ms, print_table, quick_mode, write_bench_json, BenchRecord};
use oftv2::config::RunCfg;
use oftv2::coordinator::{BaseModel, Manifest, Trainer};
use oftv2::json::Json;
use oftv2::runtime::Engine;
use oftv2::serve::Server;
use oftv2::util::argmax;
use oftv2::util::stats::Summary;
use oftv2::util::timer::Timer;
use oftv2::{artifacts_root, Result};

fn trainer<'e>(engine: &'e Engine, tag: &str) -> Result<Trainer<'e>> {
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = 0;
    cfg.log_every = 0;
    cfg.data.task = "math".into();
    cfg.data.documents = 150;
    Trainer::new(engine, &artifacts_root(), cfg)
}

/// Mean per-token times of both decode paths for one bundle:
/// (kv_samples, reforward_samples), seconds per generated token.
fn decode_costs(tr: &mut Trainer, n_tokens: usize) -> Result<(Vec<f64>, Vec<f64>)> {
    let dec = tr.decoder()?;
    let t = tr.manifest.model.seq_len;
    let n = n_tokens.min(t - 2);

    let mut kv = Vec::with_capacity(n);
    let mut sess = dec.begin()?;
    let mut logits = sess.step(1)?;
    for _ in 0..n {
        let next = argmax(&logits) as i32;
        let t0 = Timer::start();
        logits = sess.step(next)?;
        kv.push(t0.secs());
    }

    let mut rf = Vec::new();
    // Warm the lazy logits_last graph so its build cost stays out of
    // the timed region, then sample: each re-forward token pays a full
    // T-row forward (variance is low, cost is high).
    tr.decode_greedy_reforward(&[1], 1)?;
    for rep in 0..3usize {
        let ids: Vec<i32> = vec![1, (rep + 2) as i32];
        let t0 = Timer::start();
        let gen = tr.decode_greedy_reforward(&ids, 4)?;
        rf.push(t0.secs() / gen.len().max(1) as f64);
    }
    Ok((kv, rf))
}

fn main() -> Result<()> {
    let quick = quick_mode();
    let engine = Engine::cpu()?;
    let mut records: Vec<BenchRecord> = Vec::new();

    // ---- 1. per-token decode cost vs model sequence length -------------
    let presets: &[&str] = if quick {
        &["tiny", "small"]
    } else {
        &["tiny", "small", "bench"]
    };
    let mut rows = Vec::new();
    let mut ratios = Vec::new();
    for preset in presets {
        let tag = format!("{preset}_oft_v2");
        let mut tr = trainer(&engine, &tag)?;
        let t = tr.manifest.model.seq_len;
        let d = tr.manifest.model.d_model;
        let (kv, rf) = decode_costs(&mut tr, 32)?;
        let (kv_mean, rf_mean) = (Summary::of(&kv).mean, Summary::of(&rf).mean);
        let ratio = rf_mean / kv_mean.max(1e-12);
        ratios.push((t, ratio));
        rows.push(vec![
            format!("{preset} (T={t}, d={d})"),
            fmt_ms(kv_mean),
            fmt_ms(rf_mean),
            format!("{ratio:.1}x"),
        ]);
        records.push(
            BenchRecord::from_samples(format!("decode_kv_{preset}"), &kv)
                .with("path", Json::str("kv"))
                .with("seq_len", Json::num(t as f64))
                .with("d_model", Json::num(d as f64)),
        );
        records.push(
            BenchRecord::from_samples(format!("decode_reforward_{preset}"), &rf)
                .with("path", Json::str("reforward"))
                .with("seq_len", Json::num(t as f64))
                .with("d_model", Json::num(d as f64)),
        );
    }
    print_table(
        "per-token decode cost (KV cache vs full re-forward)",
        &["preset", "KV ms/tok", "reforward ms/tok", "speedup"],
        &rows,
    );
    // Shape: the re-forward path recomputes all T rows per token; the
    // KV path touches one. The ratio's absolute size depends on how
    // well the T-row matmuls parallelize on this host, so assert a
    // conservative floor and report the trend.
    for (t, ratio) in &ratios {
        assert!(
            *ratio > 1.5,
            "KV decode should clearly beat re-forward at T={t} (got {ratio:.2}x)"
        );
    }
    let (t_small, r_small) = ratios[0];
    let (t_large, r_large) = *ratios.last().unwrap();
    println!(
        "re-forward/KV per-token ratio: {r_small:.1}x at T={t_small} -> {r_large:.1}x at \
         T={t_large} (re-forward pays all T rows per token; KV pays one)"
    );

    // Flatness within one sequence: KV per-token cost early vs late.
    let tag = if quick { "small_oft_v2" } else { "bench_oft_v2" };
    let mut tr = trainer(&engine, tag)?;
    let t = tr.manifest.model.seq_len;
    let dec = tr.decoder()?;
    let mut early = Vec::new();
    let mut late = Vec::new();
    for _rep in 0..2 {
        let mut sess = dec.begin()?;
        let mut logits = sess.step(1)?;
        for pos in 1..t {
            let next = argmax(&logits) as i32;
            let t0 = Timer::start();
            logits = sess.step(next)?;
            let secs = t0.secs();
            if pos < t / 4 {
                early.push(secs);
            } else if pos >= 3 * t / 4 {
                late.push(secs);
            }
        }
    }
    let (early_mean, late_mean) = (Summary::of(&early).mean, Summary::of(&late).mean);
    let growth = late_mean / early_mean.max(1e-12);
    println!(
        "KV per-token cost within a T={t} sequence: {} early -> {} late ({growth:.2}x; \
         attention is O(pos) but matmuls dominate)",
        fmt_ms(early_mean),
        fmt_ms(late_mean)
    );
    assert!(
        growth < 2.5,
        "KV per-token cost should stay near-flat across the sequence (got {growth:.2}x)"
    );
    records.push(
        BenchRecord::from_samples("decode_kv_flatness_early", &early)
            .with("seq_len", Json::num(t as f64)),
    );
    records.push(
        BenchRecord::from_samples("decode_kv_flatness_late", &late)
            .with("seq_len", Json::num(t as f64))
            .with("growth_vs_early", Json::num(growth)),
    );

    // ---- 2. multi-tenant serving over one shared base ------------------
    let preset = if quick { "small" } else { "bench" };
    let seed = oftv2::bench::bench_seed();
    let base = BaseModel::for_preset(&engine, preset, seed, None)?;
    let uploads_before = engine.upload_count();
    let mut server = Server::new(&engine, base, 4);
    for (name, tag) in [
        ("oft_v2", format!("{preset}_oft_v2")),
        ("qoft", format!("{preset}_qoft_nf4")),
        ("boft", format!("{preset}_boft")),
        ("hoft", format!("{preset}_hoft")),
    ] {
        let man = Manifest::load_or_builtin(artifacts_root().join(&tag))?;
        server.add_adapter_init(name, man, seed, None)?;
    }
    let adapter_uploads = engine.upload_count() - uploads_before;

    let n_requests = if quick { 6 } else { 16 };
    let max_new = if quick { 8 } else { 16 };
    let names = server.adapter_names();
    for r in 0..n_requests {
        let prompt: Vec<i32> = vec![1, (r % 19 + 2) as i32, (r % 11 + 2) as i32];
        server.submit(&names[r % names.len()], prompt, max_new)?;
    }
    let responses = server.run_until_idle()?;
    assert_eq!(responses.len(), n_requests);

    let m = server.metrics().clone();
    let mut rows = Vec::new();
    for (name, a) in &m.per_adapter {
        rows.push(vec![
            name.clone(),
            a.requests.to_string(),
            a.tokens_out.to_string(),
            format!("{:.1}", a.mean_latency_secs() * 1e3),
            format!("{:.1}", a.tokens_per_sec()),
        ]);
        let lat: Vec<f64> = responses
            .iter()
            .filter(|r| &r.adapter == name)
            .map(|r| r.latency_secs)
            .collect();
        records.push(
            BenchRecord::from_samples(format!("serve_latency_{name}"), &lat)
                .with("tokens_per_sec", Json::num(a.tokens_per_sec()))
                .with("requests", Json::num(a.requests as f64)),
        );
    }
    print_table(
        &format!("multi-tenant serving ({preset}: OFTv2 + QOFT + BOFT + HOFT, one base, batch 4)"),
        &["adapter", "reqs", "tokens", "latency ms", "tok/s"],
        &rows,
    );
    println!(
        "shared base: {adapter_uploads} adapter-attach uploads (quant packs only), \
         {:.1} tok/s aggregate, peak batch {}",
        m.tokens_per_sec(),
        m.peak_active
    );
    records.push(
        BenchRecord::from_samples("serve_aggregate", &[m.wall_secs])
            .with("tokens_per_sec", Json::num(m.tokens_per_sec()))
            .with("total_tokens", Json::num(m.total_tokens as f64))
            .with("adapter_attach_uploads", Json::num(adapter_uploads as f64)),
    );

    let path = write_bench_json("serving", "secs", &records)?;
    println!("\nresults -> {}", path.display());
    Ok(())
}

