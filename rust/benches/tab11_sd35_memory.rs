//! Table 11 (+ Figs. 5/7 memory captions) — Dreambooth-finetuning
//! memory for Stable Diffusion 3.5 Medium/Large: LoRA vs OFTv2 vs
//! QLoRA vs QOFT, from the analytic memory model over the MMDiT specs.
//!
//! Paper numbers: Medium — 38.00 / 38.02 / 35.03 / 35.02 GB;
//!                Large  — 52.33 / 52.32 / 41.60 / 41.53 GB.
//! Shape: LoRA ≈ OFTv2, QLoRA ≈ QOFT, quantized < full precision.

use oftv2::bench::{print_table, Report};
use oftv2::json::Json;
use oftv2::memmodel::{finetune_gib, BaseResidency, Method, Precision, TrainShape};
use oftv2::modelspec::ModelSpec;
use oftv2::runtime::CheckpointPolicy;
use oftv2::Result;

fn main() -> Result<()> {
    let shape = TrainShape {
        batch: 1,  // Dreambooth default
        seq: 4096, // 128x128 latent patches + text tokens
        act_bytes: 2.0,
        checkpoint: CheckpointPolicy::None, // Dreambooth scripts keep activations
        residency: BaseResidency::Packed,
        ranks: 1,
    };
    let mut report = Report::new("tab11_sd35_memory");

    let mut rows = Vec::new();
    let paper: [(&str, f64, f64); 4] = [
        ("LoRA", 38.00, 52.33),
        ("OFTv2", 38.02, 52.32),
        ("QLoRA", 35.03, 41.60),
        ("QOFT", 35.02, 41.53),
    ];
    let mut ours = std::collections::BTreeMap::new();
    for (size, col) in [("medium", 0usize), ("large", 1usize)] {
        let spec = ModelSpec::sd35(size)?;
        for (label, m, p) in [
            ("LoRA", Method::lora(16), Precision::Bf16),
            ("OFTv2", Method::oft_input_centric(32), Precision::Bf16),
            ("QLoRA", Method::lora(16), Precision::Nf4),
            ("QOFT", Method::oft_input_centric(32), Precision::Nf4),
        ] {
            let gib = finetune_gib(&spec, m, p, shape);
            ours.insert((label, size), gib);
            report.add_kv(vec![
                ("model", Json::str(spec.name.clone())),
                ("method", Json::str(label)),
                ("gib", Json::num(gib)),
                (
                    "paper_gib",
                    Json::num(paper.iter().find(|(l, _, _)| *l == label).map(|r| if col == 0 { r.1 } else { r.2 }).unwrap()),
                ),
            ]);
        }
    }
    for (label, p_med, p_lrg) in paper {
        rows.push(vec![
            label.to_string(),
            format!("{:.1}", ours[&(label, "medium")]),
            format!("{p_med:.2}"),
            format!("{:.1}", ours[&(label, "large")]),
            format!("{p_lrg:.2}"),
        ]);
    }
    print_table(
        "Table 11: SD3.5 Dreambooth finetuning memory (GiB)",
        &["method", "Medium (ours)", "Medium (paper)", "Large (ours)", "Large (paper)"],
        &rows,
    );

    // shape assertions
    for size in ["medium", "large"] {
        let lora = ours[&("LoRA", size)];
        let v2 = ours[&("OFTv2", size)];
        let ql = ours[&("QLoRA", size)];
        let qo = ours[&("QOFT", size)];
        assert!((v2 - lora).abs() / lora < 0.10, "{size}: OFTv2 vs LoRA");
        assert!((qo - ql).abs() / ql < 0.10, "{size}: QOFT vs QLoRA");
        assert!(qo < lora, "{size}: quantized must beat full precision");
    }
    println!("\nshape checks OK: LoRA ≈ OFTv2, QLoRA ≈ QOFT, quantized < full");
    let path = report.save()?;
    println!("results -> {}", path.display());
    Ok(())
}
