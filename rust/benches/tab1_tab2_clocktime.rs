//! Tables 1 & 2 — clock-time comparison LoRA vs OFTv2 (full precision)
//! and QLoRA vs QOFT (NF4), reported as HH:MM:SS for a fixed-step
//! "epoch" like the paper.
//!
//! Paper shape: full precision, LoRA is modestly *faster* than OFTv2
//! (Table 1: 12:10 vs 15:10 on 7B); quantized, QOFT is slightly faster
//! than QLoRA (Table 2: 3:25:00 vs 3:19:30 on 7B). We assert the same
//! orderings on per-step means, scaled to an epoch of EPOCH_STEPS.

use oftv2::bench::{fmt_ms, print_table, quick_mode, write_bench_json, BenchRecord, Report};
use oftv2::config::RunCfg;
use oftv2::coordinator::Trainer;
use oftv2::json::Json;
use oftv2::runtime::Engine;
use oftv2::util::human_clock;
use oftv2::{artifacts_root, Result};

/// Steps the "epoch" clock is extrapolated to (the paper's GSM8K run
/// is ~a few thousand steps on 8xH100).
const EPOCH_STEPS: f64 = 2000.0;

fn step_samples(engine: &Engine, tag: &str, steps: usize, task: &str) -> Result<Vec<f64>> {
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.data.task = task.into();
    cfg.data.documents = 300;
    let mut tr = Trainer::new(engine, &artifacts_root(), cfg)?;
    Ok(tr.train()?.step_secs(steps / 5))
}

fn main() -> Result<()> {
    let steps = if quick_mode() { 8 } else { 25 };
    let engine = Engine::cpu()?;
    let mut report = Report::new("tab1_tab2_clocktime");
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut mean_step = |engine: &Engine, tag: &str, steps: usize, task: &str| -> Result<f64> {
        let samples = step_samples(engine, tag, steps, task)?;
        let rec = BenchRecord::from_samples(format!("step_time_{tag}"), &samples);
        let mean = rec.mean;
        records.push(rec);
        Ok(mean)
    };

    // ---- Table 1: full precision (math reasoning data) -----------------
    let lora = mean_step(&engine, "bench_lora", steps, "math")?;
    let oftv2 = mean_step(&engine, "bench_oft_v2", steps, "math")?;
    print_table(
        "Table 1: full-precision clock time (scaled to a 2000-step epoch)",
        &["method", "ms/step", "epoch clock"],
        &[
            vec!["LoRA".into(), fmt_ms(lora), human_clock(lora * EPOCH_STEPS)],
            vec!["OFTv2".into(), fmt_ms(oftv2), human_clock(oftv2 * EPOCH_STEPS)],
        ],
    );
    println!(
        "paper Table 1 (Llama-2-7B): LoRA 00:12:10 vs OFTv2 00:15:10 — LoRA ahead by ~1.25x; here {:.2}x",
        oftv2 / lora
    );
    for (m, s) in [("LoRA", lora), ("OFTv2", oftv2)] {
        report.add_kv(vec![
            ("table", Json::str("tab1")),
            ("method", Json::str(m)),
            ("secs_per_step", Json::num(s)),
        ]);
    }
    // shape: the two are in the same ballpark (paper: within ~25%)
    assert!(
        oftv2 / lora < 2.5,
        "OFTv2 should stay near LoRA's speed, got {:.2}x",
        oftv2 / lora
    );

    // ---- Table 2: NF4-quantized (reasoning data) ------------------------
    let qlora = mean_step(&engine, "bench_qlora_nf4", steps, "math")?;
    let qoft = mean_step(&engine, "bench_qoft_nf4", steps, "math")?;
    print_table(
        "Table 2: NF4 clock time (scaled to a 2000-step epoch)",
        &["method", "ms/step", "epoch clock"],
        &[
            vec!["QLoRA".into(), fmt_ms(qlora), human_clock(qlora * EPOCH_STEPS)],
            vec!["QOFT".into(), fmt_ms(qoft), human_clock(qoft * EPOCH_STEPS)],
        ],
    );
    println!(
        "paper Table 2 (Qwen2.5-7B): QLoRA 03:25:00 vs QOFT 03:19:30 — QOFT ahead; here ratio {:.2}x",
        qoft / qlora
    );
    for (m, s) in [("QLoRA", qlora), ("QOFT", qoft)] {
        report.add_kv(vec![
            ("table", Json::str("tab2")),
            ("method", Json::str(m)),
            ("secs_per_step", Json::num(s)),
        ]);
    }
    // shape: quantized OFTv2 competitive with quantized LoRA (paper:
    // QOFT slightly faster; allow parity slack on the CPU backend)
    assert!(
        qoft / qlora < 1.35,
        "QOFT should be competitive with QLoRA, got {:.2}x",
        qoft / qlora
    );

    // AWQ variant (the quantization-agnostic claim, Table 2 extension)
    let qlora_awq = mean_step(&engine, "bench_qlora_awq", steps, "math")?;
    let qoft_awq = mean_step(&engine, "bench_qoft_awq", steps, "math")?;
    print_table(
        "Table 2 (AWQ backend)",
        &["method", "ms/step", "epoch clock"],
        &[
            vec!["QLoRA".into(), fmt_ms(qlora_awq), human_clock(qlora_awq * EPOCH_STEPS)],
            vec!["QOFT".into(), fmt_ms(qoft_awq), human_clock(qoft_awq * EPOCH_STEPS)],
        ],
    );

    let path = report.save()?;
    let bench_path = write_bench_json("tab1_tab2_clocktime", "secs", &records)?;
    println!("\nresults -> {} and {}", path.display(), bench_path.display());
    Ok(())
}
