//! Table 3 — seq2seq finetuning quality: LoRA vs OFTv2 on the
//! synthetic summarization corpus (XSum/CNN-DM stand-in), ROUGE-1/2/L,
//! across a parameter-budget sweep (two model presets standing in for
//! the paper's r∈{8,16,32} / b∈{16,32,64} sweep) and both precisions.
//!
//! Protocol: pretrain each preset's base on summarization (style 0),
//! finetune every adapter from that shared checkpoint on the shifted
//! corpus (style 1), then score greedy-decoded summaries.
//!
//! Shape targets: adapters beat the frozen base; OFTv2 matches or
//! beats LoRA at roughly half the trainable parameters.

use oftv2::bench::{bench_seed, print_table, quick_mode, Report};
use oftv2::coordinator::protocol::{finetune_trainer, pretrain, Phase};
use oftv2::data::corpus::TaskKind;
use oftv2::json::Json;
use oftv2::runtime::Engine;
use oftv2::util::human_count;
use oftv2::{artifacts_root, Result};

fn main() -> Result<()> {
    let quick = quick_mode();
    let n_eval = if quick { 8 } else { 16 };
    let engine = Engine::cpu()?;
    let mut report = Report::new("tab3_summarization");

    // (budget label, preset, methods at that budget)
    let budgets = [
        ("budget-1 (tiny)", "tiny", 400usize, 300usize),
        ("budget-2 (small)", "small", 300, 200),
    ];

    let mut rows = Vec::new();
    let mut r1s: Vec<(String, String, u64, f64)> = Vec::new();
    for (budget, preset, pre_steps, fin_steps) in budgets {
        let pre = Phase {
            steps: if quick { pre_steps / 4 } else { pre_steps },
            documents: 1200,
            lr: 3e-3,
            seed: bench_seed(),
        };
        let fin = Phase {
            steps: if quick { fin_steps / 4 } else { fin_steps },
            documents: 1200,
            lr: 2e-3,
            seed: bench_seed() + 4,
        };
        let (ckpt, fin_loader) = pretrain(&engine, &artifacts_root(), preset, TaskKind::Summarize, &pre)?;

        for (label, tag) in [
            ("LoRA", format!("{preset}_lora")),
            ("OFTv2", format!("{preset}_oft_v2")),
            ("QLoRA", format!("{preset}_qlora_nf4")),
            ("QOFT", format!("{preset}_qoft_nf4")),
        ] {
            // paper App. A: OFT variants train at 4x the LoRA LR
            let mut phase = fin.clone();
            if tag.contains("oft") {
                phase.lr *= 4.0;
            }
            // graceful per-tag skip (e.g. PJRT backend with a partial
            // artifact tree): keep the rows already measured
            let mut tr = match finetune_trainer(
                &engine,
                &artifacts_root(),
                &tag,
                TaskKind::Summarize,
                &phase,
                Some(&ckpt),
                &fin_loader,
            ) {
                Ok(tr) => tr,
                Err(e) => {
                    println!("(skipping {tag}: {e})");
                    continue;
                }
            };
            tr.train()?;
            let rouge = tr.rouge_eval(n_eval, 28)?;
            let params = tr.manifest.params_trainable;
            rows.push(vec![
                budget.to_string(),
                label.to_string(),
                human_count(params),
                format!("{:.2}", rouge.r1),
                format!("{:.2}", rouge.r2),
                format!("{:.2}", rouge.rl),
            ]);
            report.add_kv(vec![
                ("budget", Json::str(budget)),
                ("method", Json::str(label)),
                ("params", Json::num(params as f64)),
                ("rouge1", Json::num(rouge.r1)),
                ("rouge2", Json::num(rouge.r2)),
                ("rougeL", Json::num(rouge.rl)),
            ]);
            r1s.push((budget.to_string(), label.to_string(), params, rouge.r1));
        }
    }

    print_table(
        "Table 3: summarization ROUGE after finetuning (pretrained base)",
        &["budget", "method", "# params", "ROUGE-1", "ROUGE-2", "ROUGE-L"],
        &rows,
    );
    println!("(paper Table 3: OFTv2/QOFT >= LoRA/QLoRA at 47-53% fewer trainable parameters)");

    // shape: at each budget, the OFT variant uses fewer parameters than
    // its LoRA counterpart
    for (budget, _, _, _) in budgets {
        let find = |m: &str| r1s.iter().find(|(b, l, _, _)| b == budget && l == m);
        if let (Some(lora), Some(oft)) = (find("LoRA"), find("OFTv2")) {
            assert!(
                oft.2 < lora.2,
                "{budget}: OFTv2 params {} should undercut LoRA {}",
                oft.2,
                lora.2
            );
        }
    }

    let path = report.save()?;
    println!("\nresults -> {}", path.display());
    Ok(())
}
