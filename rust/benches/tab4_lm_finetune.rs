//! Table 4 — language-modeling perplexity (WikiText-2 stand-in) and
//! math accuracy (GSM8K stand-in) for LoRA vs OFTv2 in 16-bit and
//! QLoRA vs QOFT in 4-bit, at matched hyperparameters.
//!
//! Protocol (the paper's setting): pretrain the base model on the
//! task's distribution, then finetune each adapter from that shared
//! checkpoint on the shifted distribution.
//!
//! Shape targets: every adapter beats the frozen pretrained base;
//! OFTv2 tracks or beats LoRA at ~half the trainable parameters; NF4
//! quantization costs little.

use oftv2::bench::{bench_seed, print_table, quick_mode, Report};
use oftv2::coordinator::protocol::{finetune_trainer, pretrain, Phase};
use oftv2::data::corpus::TaskKind;
use oftv2::json::Json;
use oftv2::runtime::Engine;
use oftv2::util::human_count;
use oftv2::{artifacts_root, Result};

fn main() -> Result<()> {
    let quick = quick_mode();
    let pre = Phase {
        steps: if quick { 80 } else { 400 },
        documents: 2000,
        lr: 3e-3,
        seed: bench_seed(),
    };
    let fin = Phase {
        steps: if quick { 60 } else { 300 },
        documents: 2000,
        lr: 2e-3,
        seed: bench_seed() + 4,
    };
    let n_eval = if quick { 10 } else { 24 };
    let engine = Engine::cpu()?;
    let mut report = Report::new("tab4_lm_finetune");

    let methods = [
        ("Base (frozen)", "tiny_none", 0usize),
        ("LoRA", "tiny_lora", fin.steps),
        ("OFTv2", "tiny_oft_v2", fin.steps),
        ("QLoRA", "tiny_qlora_nf4", fin.steps),
        ("QOFT", "tiny_qoft_nf4", fin.steps),
    ];

    let mut rows = Vec::new();
    let mut ppls = std::collections::BTreeMap::new();
    let mut pass1s = std::collections::BTreeMap::new();

    // one pretraining checkpoint per task, shared by all methods
    for task in [TaskKind::Wiki, TaskKind::Math] {
        let (ckpt, fin_loader) = pretrain(&engine, &artifacts_root(), "tiny", task, &pre)?;
        for (label, tag, steps) in methods {
            let mut phase = fin.clone();
            phase.steps = steps;
            // paper App. A: OFT variants train at 4x the LoRA LR
            if tag.contains("oft") {
                phase.lr *= 4.0;
            }
            let mut tr = match finetune_trainer(
                &engine,
                &artifacts_root(),
                tag,
                task,
                &phase,
                Some(&ckpt),
                &fin_loader,
            ) {
                Ok(tr) => tr,
                Err(e) => {
                    println!("(skipping {tag}: {e})");
                    continue;
                }
            };
            if steps > 0 {
                tr.train()?;
            }
            match task {
                TaskKind::Wiki => {
                    let (_, ppl) = tr.evaluate()?;
                    ppls.insert(label, (tr.manifest.params_trainable, ppl));
                }
                TaskKind::Math => {
                    let p1 = tr.pass1_eval(n_eval, 28)?;
                    pass1s.insert(label, p1);
                }
                _ => unreachable!(),
            }
        }
    }

    for (label, _, _) in methods {
        // a label may be absent if its bundle was skipped above
        let Some(&(params, ppl)) = ppls.get(label) else { continue };
        let Some(&p1) = pass1s.get(label) else { continue };
        rows.push(vec![
            label.to_string(),
            if params == 0 { "-".into() } else { human_count(params) },
            format!("{ppl:.2}"),
            format!("{p1:.1}"),
        ]);
        report.add_kv(vec![
            ("method", Json::str(label)),
            ("params", Json::num(params as f64)),
            ("wikitext_ppl", Json::num(ppl)),
            ("math_pass1", Json::num(p1)),
        ]);
    }

    print_table(
        "Table 4: WikiText-style perplexity (down) / math pass@1 (up), pretrained base",
        &["method", "# params", "WikiText ppl", "Math pass@1 %"],
        &rows,
    );
    println!("(paper Table 4, Llama-2-7B: LoRA ppl 6.63 vs OFTv2 6.14; GSM8K 33.81 vs 34.65)");

    // shape: adapters improve on the frozen pretrained base (only
    // asserted for methods that actually ran)
    let ppl_of = |m: &str| ppls.get(m).map(|&(_, p)| p);
    if let Some(base) = ppl_of("Base (frozen)") {
        for m in ["LoRA", "OFTv2", "QLoRA", "QOFT"] {
            if let Some(p) = ppl_of(m) {
                assert!(p < base, "{m}: ppl {p} should beat the frozen base {base}");
            }
        }
    }
    // OFTv2 tracks LoRA with ~half the parameters
    if let (Some(oft), Some(lora)) = (ppl_of("OFTv2"), ppl_of("LoRA")) {
        assert!(oft < lora * 1.15, "OFTv2 ppl {oft} should track LoRA {lora}");
    }
    // quantization costs little
    let rel = |a: f64, b: f64| (a - b).abs() / b;
    if let (Some(q), Some(f)) = (ppl_of("QOFT"), ppl_of("OFTv2")) {
        assert!(rel(q, f) < 0.25);
    }
    if let (Some(q), Some(f)) = (ppl_of("QLoRA"), ppl_of("LoRA")) {
        assert!(rel(q, f) < 0.25);
    }

    let path = report.save()?;
    println!("\nresults -> {}", path.display());
    Ok(())
}
