//! Table 5 (and App. B Table 10) — pass@1 on math reasoning for the
//! quantized finetuning setting: pretrained-but-frozen baseline vs
//! QLoRA vs QOFT, at two model scales (tiny and small presets standing
//! in for the Qwen2.5 1.5B/7B/32B ladder).
//!
//! Protocol: pretrain `<preset>_full` on math (style 0), finetune the
//! quantized adapters from that checkpoint on the shifted corpus
//! (style 1), report pass@1 over held-out problems.
//!
//! Shape targets: finetuning beats the frozen baseline; QOFT >= QLoRA
//! at roughly half the trainable parameters.

use oftv2::bench::{bench_seed, print_table, quick_mode, Report};
use oftv2::coordinator::protocol::{finetune_trainer, pretrain, Phase};
use oftv2::data::corpus::TaskKind;
use oftv2::json::Json;
use oftv2::runtime::Engine;
use oftv2::util::human_count;
use oftv2::{artifacts_root, Result};

fn main() -> Result<()> {
    let quick = quick_mode();
    let n_eval = if quick { 10 } else { 24 };
    let engine = Engine::cpu()?;
    let mut report = Report::new("tab5_math_pass1");

    let scales = [
        ("scale-1 (tiny)", "tiny", 400usize, 300usize),
        ("scale-2 (small)", "small", 300, 200),
    ];
    let mut rows = Vec::new();
    let mut results: Vec<(String, String, f64)> = Vec::new();

    for (scale, preset, pre_steps, fin_steps) in scales {
        let pre = Phase {
            steps: if quick { pre_steps / 4 } else { pre_steps },
            documents: 2000,
            lr: 3e-3,
            seed: bench_seed(),
        };
        let fin = Phase {
            steps: if quick { fin_steps / 4 } else { fin_steps },
            documents: 2000,
            lr: 2e-3,
            seed: bench_seed() + 4,
        };
        let (ckpt, fin_loader) = pretrain(&engine, &artifacts_root(), preset, TaskKind::Math, &pre)?;

        let methods = [
            ("Baseline", format!("{preset}_none"), 0usize),
            ("QLoRA", format!("{preset}_qlora_nf4"), fin.steps),
            ("QOFT", format!("{preset}_qoft_nf4"), fin.steps),
        ];
        for (label, tag, steps) in methods {
            let mut phase = fin.clone();
            phase.steps = steps;
            // paper App. A: OFT variants train at 4x the LoRA LR
            if tag.contains("oft") {
                phase.lr *= 4.0;
            }
            let mut tr = match finetune_trainer(
                &engine,
                &artifacts_root(),
                &tag,
                TaskKind::Math,
                &phase,
                Some(&ckpt),
                &fin_loader,
            ) {
                Ok(tr) => tr,
                Err(e) => {
                    println!("(skipping {tag}: {e})");
                    continue;
                }
            };
            if steps > 0 {
                tr.train()?;
            }
            let p1 = tr.pass1_eval(n_eval, 28)?;
            let params = tr.manifest.params_trainable;
            rows.push(vec![
                scale.into(),
                label.into(),
                if steps == 0 { "-".into() } else { human_count(params) },
                format!("{p1:.1}"),
            ]);
            report.add_kv(vec![
                ("scale", Json::str(scale)),
                ("method", Json::str(label)),
                ("params", Json::num(params as f64)),
                ("pass1", Json::num(p1)),
            ]);
            results.push((scale.into(), label.into(), p1));
        }
    }

    print_table(
        "Table 5: math pass@1 after quantized finetuning (pretrained base)",
        &["scale", "method", "# params", "pass@1 %"],
        &rows,
    );
    println!("(paper Table 5, Qwen2.5-7B-it: baseline vs QLoRA vs QOFT SAT = 53.1 / 68.8 / 96.9)");

    // shape: QOFT >= baseline at each scale
    for (scale, _, _, _) in scales {
        let get = |m: &str| {
            results
                .iter()
                .find(|(s, l, _)| s == scale && l == m)
                .map(|(_, _, p)| *p)
        };
        if let (Some(base), Some(qoft)) = (get("Baseline"), get("QOFT")) {
            assert!(
                qoft >= base,
                "{scale}: QOFT pass@1 {qoft} below baseline {base}"
            );
        }
    }

    let path = report.save()?;
    println!("\nresults -> {}", path.display());
    Ok(())
}
