//! Training-scaling bench — the layer/tape decomposition's two knobs
//! swept against each other: data-parallel workers (`--workers`) ×
//! gradient-checkpoint policy (`--grad-checkpoint`), across every
//! registered PEFT method on the `small` preset.
//!
//!   cargo bench --bench train_scaling [-- --quick]
//!
//! Every (workers, policy) cell runs the *same* per-sequence
//! microbatch decomposition with a fixed-order tree all-reduce, so the
//! loss curves are bitwise identical across the whole sweep (locked by
//! rust/tests/train_parallel.rs); only time and activation memory
//! move. Shape target: on a 4+ core machine, 4 workers deliver >= 2x
//! step speedup over 1 worker; checkpointing trades a bounded slowdown
//! for the activation-memory curve `fig1_time_memory` reports.
//!
//! Emits `BENCH_train_scaling.json` (shared config/mean/p50/p95
//! schema; extra fields: method, workers, checkpoint, speedup_vs_w1).

use oftv2::bench::{
    bench_seed, fmt_ms, fmt_ratio, print_table, quick_mode, write_bench_json, BenchRecord,
};
use oftv2::config::RunCfg;
use oftv2::coordinator::Trainer;
use oftv2::json::Json;
use oftv2::runtime::{CheckpointPolicy, Engine};
use oftv2::{artifacts_root, Result};

/// One bundle per registered PEFT method (boft/hoft included) — the
/// sweep grows with the adapter registry instead of a hard-coded list.
fn method_tags() -> Vec<String> {
    oftv2::adapters::bundle_tags("small")
}

/// Post-warmup per-step wall times for one (bundle, workers, policy).
fn step_samples(
    engine: &Engine,
    tag: &str,
    steps: usize,
    workers: usize,
    policy: CheckpointPolicy,
) -> Result<Vec<f64>> {
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.seed = bench_seed();
    cfg.data.seed = bench_seed();
    cfg.data.task = "wiki".into();
    cfg.data.documents = 200;
    cfg.train.workers = workers;
    cfg.train.grad_checkpoint = policy;
    let mut tr = Trainer::new(engine, &artifacts_root(), cfg)?;
    let hist = tr.train()?;
    Ok(hist.step_secs(steps / 4))
}

fn main() -> Result<()> {
    let steps = if quick_mode() { 6 } else { 16 };
    let engine = Engine::cpu()?;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let worker_counts: [usize; 3] = [1, 2, 4];
    let policies = [
        CheckpointPolicy::None,
        CheckpointPolicy::EveryK(1),
        CheckpointPolicy::EveryK(2),
    ];
    println!(
        "train_scaling: {} cores, seed {}, {} steps per config",
        cores,
        bench_seed(),
        steps
    );

    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rows = Vec::new();
    let mut best_speedup_w4 = 0.0f64;
    for tag in &method_tags() {
        for policy in policies {
            let mut base_mean = 0.0f64;
            for workers in worker_counts {
                let samples = step_samples(&engine, tag, steps, workers, policy)?;
                let mut rec = BenchRecord::from_samples(
                    format!("{tag}_w{workers}_{}", policy.label()),
                    &samples,
                )
                .with("method", Json::str(tag))
                .with("workers", Json::num(workers as f64))
                .with("checkpoint", Json::str(policy.label()));
                if workers == 1 {
                    base_mean = rec.mean;
                }
                let speedup = base_mean / rec.mean.max(1e-12);
                rec = rec.with("speedup_vs_w1", Json::num(speedup));
                if workers == 4 && policy == CheckpointPolicy::None {
                    best_speedup_w4 = best_speedup_w4.max(speedup);
                }
                if policy == CheckpointPolicy::None {
                    rows.push(vec![
                        tag.to_string(),
                        workers.to_string(),
                        fmt_ms(rec.mean),
                        fmt_ratio(speedup),
                    ]);
                }
                records.push(rec);
            }
        }
    }
    print_table(
        "train_scaling: per-step time vs workers (checkpoint: none)",
        &["method", "workers", "ms/step", "speedup vs w1"],
        &rows,
    );

    // Checkpoint trade-off at one worker, on the OFTv2 hot path.
    let mean_of = |policy: CheckpointPolicy| {
        records
            .iter()
            .find(|r| r.config == format!("small_oft_v2_w1_{}", policy.label()))
            .expect("record just measured")
            .mean
    };
    let full_tape = mean_of(CheckpointPolicy::None);
    let mut ck_rows = Vec::new();
    for policy in policies {
        let mean = mean_of(policy);
        ck_rows.push(vec![
            policy.label(),
            fmt_ms(mean),
            fmt_ratio(mean / full_tape.max(1e-12)),
        ]);
    }
    print_table(
        "train_scaling: checkpoint policy cost (small_oft_v2, 1 worker)",
        &["policy", "ms/step", "vs full tape"],
        &ck_rows,
    );

    // Shape assertions. Worker speedup needs physical cores; only hold
    // the paper-style bar where the hardware can express it.
    if cores >= 4 {
        assert!(
            best_speedup_w4 >= 2.0,
            "4 workers should give >= 2x step speedup on a {cores}-core machine \
             (got {best_speedup_w4:.2}x)"
        );
    } else if cores >= 2 {
        assert!(
            best_speedup_w4 >= 1.2,
            "workers should still help on {cores} cores (got {best_speedup_w4:.2}x)"
        );
    }

    let path = write_bench_json("train_scaling", "secs", &records)?;
    println!("\nresults -> {}", path.display());
    Ok(())
}
