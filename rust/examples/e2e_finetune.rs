//! End-to-end driver: the full pretrain -> finetune protocol on a real
//! (small) workload, proving every layer composes — L1 Pallas kernels
//! inside L2 AOT graphs executed by the L3 Rust coordinator.
//!
//!   cargo run --release --example e2e_finetune -- [--steps N]
//!       [--pretrain-steps N] [--preset e2e|e2e100m] [--out-dir DIR]
//!
//! Protocol (mirrors the paper's adaptation setting):
//!   1. "Pretrain" the base transformer (`<preset>_full`) on the wiki
//!      corpus, distribution style 0. Checkpoint it.
//!   2. Finetune OFTv2 and LoRA adapters from that checkpoint on the
//!      *shifted* wiki distribution (style 1) — frozen base, adapters
//!      only — and compare loss curves / perplexity / step time.
//!
//! Histories land in `<out-dir>/<tag>_history.json`; the run summary is
//! recorded in EXPERIMENTS.md §E2E.

use oftv2::config::RunCfg;
use oftv2::coordinator::{Manifest, Trainer};
use oftv2::data::corpus::TaskKind;
use oftv2::data::loader::Loader;
use oftv2::runtime::Engine;
use oftv2::{artifacts_root, Result};

/// Corpus size for both phases (one tokenizer over the union).
const DOCUMENTS: usize = 4000;

struct Opts {
    preset: String,
    pretrain_steps: usize,
    steps: usize,
    out_dir: String,
}

fn parse_opts() -> Opts {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str, default: &str| -> String {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or_else(|| default.to_string())
    };
    Opts {
        preset: get("--preset", "e2e"),
        pretrain_steps: get("--pretrain-steps", "200").parse().unwrap(),
        steps: get("--steps", "300").parse().unwrap(),
        out_dir: get("--out-dir", "e2e_out"),
    }
}

fn main() -> Result<()> {
    let opts = parse_opts();
    let engine = Engine::cpu()?;
    println!("runtime platform: {}", engine.platform());
    std::fs::create_dir_all(&opts.out_dir)?;

    let root = artifacts_root();
    let full_tag = format!("{}_full", opts.preset);
    let man = Manifest::load_or_builtin(root.join(&full_tag))?;
    println!(
        "== {} :: {} base parameters, d={}, {} layers ==",
        opts.preset, man.params_base, man.model.d_model, man.model.n_layers
    );

    // ---- Phase 1: pretraining on wiki style-0 --------------------------
    let mut cfg = RunCfg::default();
    cfg.tag = full_tag.clone();
    cfg.steps = opts.pretrain_steps;
    cfg.log_every = 20;
    cfg.eval_every = 100;
    cfg.optim.lr = 1e-3;
    cfg.optim.warmup = 20;
    cfg.data.task = "wiki".into();
    cfg.data.documents = 4000;
    cfg.out_dir = Some(opts.out_dir.clone());

    // One tokenizer over both distributions: token ids must stay
    // aligned between the pretraining checkpoint and the finetune runs.
    let (pre_loader, fin_loader) = Loader::pretrain_finetune_pair(
        TaskKind::Wiki,
        DOCUMENTS,
        7,
        man.model.vocab,
        man.model.batch,
        man.model.seq_len,
    );

    let pretrain_cfg = cfg.clone();
    println!("\n-- pretraining {} for {} steps --", full_tag, cfg.steps);
    let mut pre = Trainer::new(&engine, &root, pretrain_cfg)?;
    pre.set_loader(pre_loader);
    let pre_hist = pre.train()?;
    let (pre_loss, pre_ppl) = pre.evaluate()?;
    println!(
        "pretrain: loss {:.3} -> {:.3}, eval {:.3}, ppl {:.1}",
        pre_hist.first_loss().unwrap(),
        pre_hist.final_loss().unwrap(),
        pre_loss,
        pre_ppl
    );
    let ckpt = pre.checkpoint()?;
    let ckpt_path = std::path::Path::new(&opts.out_dir).join("pretrained.ckpt");
    pre.save_checkpoint(&ckpt_path)?;
    println!("checkpoint -> {}", ckpt_path.display());
    drop(pre);

    // ---- Phase 2: adapter finetuning on the shifted corpus -------------
    let mut rows = Vec::new();
    for method_tag in [format!("{}_oft_v2", opts.preset), format!("{}_lora", opts.preset)] {
        println!("\n-- finetuning {method_tag} for {} steps --", opts.steps);
        let man = Manifest::load_or_builtin(root.join(&method_tag))?;
        let mut fcfg = cfg.clone();
        fcfg.tag = method_tag.clone();
        fcfg.steps = opts.steps;
        fcfg.eval_every = opts.steps / 3;
        fcfg.optim.lr = if method_tag.contains("oft") { 4e-3 } else { 1e-3 };
        let mut tr = Trainer::with_checkpoint(&engine, man, fcfg, Some(&ckpt))?;
        // shifted distribution (style 1), shared vocabulary
        tr.set_loader(fin_loader.clone());
        let (loss0, ppl0) = tr.evaluate()?;
        let hist = tr.train()?;
        let (loss1, ppl1) = tr.evaluate()?;
        println!(
            "{method_tag}: eval {loss0:.3} -> {loss1:.3} (ppl {ppl0:.1} -> {ppl1:.1}), \
             {:.0} ms/step, {} trainable params",
            hist.mean_step_secs(5) * 1e3,
            tr.manifest.params_trainable
        );
        rows.push((
            method_tag.clone(),
            tr.manifest.params_trainable,
            loss0,
            loss1,
            ppl1,
            hist.mean_step_secs(5) * 1e3,
        ));
        assert!(loss1 < loss0, "{method_tag}: finetuning did not improve eval loss");
    }

    println!("\n== E2E summary (pretrain ppl {:.1}) ==", pre_ppl);
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>9} {:>10}",
        "method", "params", "eval0", "eval1", "ppl", "ms/step"
    );
    for (tag, params, l0, l1, ppl, ms) in &rows {
        println!(
            "{:<16} {:>10} {:>10.3} {:>10.3} {:>9.1} {:>10.0}",
            tag, params, l0, l1, ppl, ms
        );
    }
    println!("\ne2e_finetune OK");
    Ok(())
}
