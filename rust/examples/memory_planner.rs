//! Memory planner: size a finetuning run before you rent the GPUs.
//!
//!   cargo run --release --example memory_planner
//!
//! Uses the analytic memory model (the same arithmetic behind the
//! paper's Fig. 1, Fig. 4 and Table 11) to answer: which (method,
//! precision) combinations fit which GPUs for each Qwen2.5 scale?

use oftv2::memmodel::{finetune_memory, BaseResidency, Method, Precision, TrainShape};
use oftv2::modelspec::ModelSpec;
use oftv2::runtime::CheckpointPolicy;
use oftv2::Result;

const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

fn main() -> Result<()> {
    let shape = TrainShape {
        batch: 1,
        seq: 2048,
        act_bytes: 2.0,
        checkpoint: CheckpointPolicy::EveryK(1),
        residency: BaseResidency::Packed,
        ranks: 1,
    };
    let gpus = [("A100-40G", 40.0), ("H100-80G", 80.0), ("H100-NVL", 94.0)];

    println!("Finetuning-memory planner (batch 1 x 2048 tokens, bf16 activations)\n");
    println!(
        "{:<14} {:<8} {:<6} {:>9}   {}",
        "model", "method", "prec", "total", "fits"
    );
    for size in ["0.5b", "1.5b", "3b", "7b", "14b", "32b", "72b"] {
        let spec = ModelSpec::qwen25(size)?;
        for (method, prec) in [
            (Method::oft_weight_centric(32), Precision::Bf16),
            (Method::oft_input_centric(32), Precision::Bf16),
            (Method::lora(16), Precision::Bf16),
            (Method::oft_input_centric(32), Precision::Nf4),
            (Method::lora(16), Precision::Nf4),
        ] {
            let total = finetune_memory(&spec, method, prec, shape).total() / GIB;
            let fits: Vec<&str> = gpus
                .iter()
                .filter(|(_, cap)| total < *cap)
                .map(|(n, _)| *n)
                .collect();
            println!(
                "{:<14} {:<8} {:<6} {:>8.1}G   {}",
                spec.name,
                method.label(prec != Precision::Bf16),
                prec.label(),
                total,
                if fits.is_empty() { "none".into() } else { fits.join(", ") }
            );
        }
        println!();
    }

    // The Fig. 1 headline: weight-centric OFT vs OFTv2 on Qwen2.5-7B.
    let spec = ModelSpec::qwen25("7b")?;
    let oft = finetune_memory(&spec, Method::oft_weight_centric(32), Precision::Bf16, shape);
    let v2 = finetune_memory(&spec, Method::oft_input_centric(32), Precision::Bf16, shape);
    println!("== Fig. 1 breakdown: Qwen2.5-7B, BF16 ==");
    println!("{:<16} {:>12} {:>12}", "", "OFT (GiB)", "OFTv2 (GiB)");
    for (label, a, b) in [
        ("base weights", oft.base_weights, v2.base_weights),
        ("adapter+grads", oft.adapter_params + oft.adapter_grads, v2.adapter_params + v2.adapter_grads),
        ("optimizer", oft.optimizer, v2.optimizer),
        ("activations", oft.activations, v2.activations),
        ("transient", oft.transient, v2.transient),
        ("overhead", oft.overhead, v2.overhead),
    ] {
        println!("{:<16} {:>12.2} {:>12.2}", label, a / GIB, b / GIB);
    }
    println!(
        "{:<16} {:>12.2} {:>12.2}   ({:.1}x reduction)",
        "TOTAL",
        oft.total() / GIB,
        v2.total() / GIB,
        oft.total() / v2.total()
    );
    Ok(())
}
