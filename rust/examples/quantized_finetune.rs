//! QOFT vs QLoRA: finetuning a quantized base model (§4 of the paper).
//!
//!   cargo run --release --example quantized_finetune -- [--steps N]
//!
//! 1. Quantizes the frozen base to NF4 (Rust packs, byte-identical to
//!    bitsandbytes-style double quantization) and trains QOFT and QLoRA
//!    adapters over the *same* quantized weights.
//! 2. Repeats QOFT over AWQ packs — the quantization-agnostic claim:
//!    the identical input-centric rotation runs against either backend.
//! 3. Runs the §4 merge->requantize analysis on the finetuned adapters:
//!    QOFT's merged weight R·W preserves the dynamic range; QLoRA's
//!    W + AB inflates it by up to ||AB||_inf.

use oftv2::config::RunCfg;
use oftv2::coordinator::Trainer;
use oftv2::peft::{LoraAdapter, OftAdapter};
use oftv2::quant::requant::{qlora_requant, qoft_requant};
use oftv2::runtime::Engine;
use oftv2::tensor::Tensor;
use oftv2::util::rng::Rng;
use oftv2::{artifacts_root, Result};

fn steps_arg() -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(80)
}

fn run_bundle(engine: &Engine, tag: &str, steps: usize) -> Result<(f64, f64, f64)> {
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = steps;
    cfg.log_every = steps / 4;
    cfg.data.task = "math".into();
    cfg.data.documents = 600;
    cfg.optim.lr = 3e-3;
    let mut tr = Trainer::new(engine, &artifacts_root(), cfg)?;
    let hist = tr.train()?;
    let (eval_loss, _ppl) = tr.evaluate()?;
    Ok((
        hist.first_loss().unwrap(),
        hist.tail_loss(8).unwrap(),
        eval_loss,
    ))
}

fn main() -> Result<()> {
    let steps = steps_arg();
    let engine = Engine::cpu()?;
    println!("runtime platform: {}", engine.platform());

    // ---- quantized finetuning across backends ---------------------------
    println!("\n== quantized finetuning ({steps} steps, synthetic math SFT) ==");
    println!(
        "{:<18} {:>10} {:>10} {:>10}",
        "bundle", "loss0", "loss_end", "eval"
    );
    let deq0 = oftv2::quant::dequant_f32_count();
    for tag in [
        "tiny_qoft_nf4",
        "tiny_qlora_nf4",
        "tiny_qoft_awq",
        "tiny_qlora_awq",
    ] {
        let (l0, l1, ev) = run_bundle(&engine, tag, steps)?;
        println!("{:<18} {:>10.3} {:>10.3} {:>10.3}", tag, l0, l1, ev);
        assert!(l1 < l0, "{tag}: loss did not decrease");
    }
    assert_eq!(
        oftv2::quant::dequant_f32_count(),
        deq0,
        "quantized finetuning must never expand the base to f32"
    );
    println!("(QOFT runs the identical rotate kernel against NF4 and AWQ packs,");
    println!(" and no pack was ever dequantized into a full f32 tensor: fused kernels only)");

    // ---- §4 requantization analysis -------------------------------------
    println!("\n== merge -> requantize analysis (§4) ==");
    let mut rng = Rng::new(11);
    let (din, dout) = (256, 256);
    let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
    println!(
        "{:<8} {:>14} {:>14} {:>12} {:>12}",
        "method", "requant_rms", "baseline_rms", "range_infl", "delta_inf"
    );
    for strength in [0.02f32, 0.05, 0.1] {
        let lora = LoraAdapter::random(din, dout, 16, 32.0, strength, &mut rng);
        let oft = OftAdapter::random(din, 32, 6, strength, &mut rng);
        let rl = qlora_requant(&w, &lora)?;
        let ro = qoft_requant(&w, &oft)?;
        println!(
            "{:<8} {:>14.5} {:>14.5} {:>12.3} {:>12.4}   (adapter std {strength})",
            "QLoRA", rl.merged.rms, rl.baseline.rms, rl.range_inflation, rl.delta_inf
        );
        println!(
            "{:<8} {:>14.5} {:>14.5} {:>12.3} {:>12.4}",
            "QOFT", ro.merged.rms, ro.baseline.rms, ro.range_inflation, ro.delta_inf
        );
        assert!(ro.range_inflation < 1.5);
    }
    println!("\nquantized_finetune OK");
    Ok(())
}
