//! Quickstart: finetune a tiny transformer with OFTv2 (the paper's
//! input-centric orthogonal finetuning) in under a minute on CPU.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the `tiny_oft_v2` AOT bundle (2-layer, d=64, block b=16),
//! trains on synthetic math word problems, and greedy-decodes one
//! prompt before and after so you can see the adapter learn.

use oftv2::config::RunCfg;
use oftv2::coordinator::Trainer;
use oftv2::runtime::Engine;
use oftv2::{artifacts_root, Result};

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    println!("runtime platform: {}", engine.platform());

    let mut cfg = RunCfg::default();
    cfg.tag = "tiny_oft_v2".into();
    cfg.steps = 60;
    cfg.log_every = 10;
    cfg.data.task = "math".into();
    cfg.data.documents = 400;
    cfg.optim.lr = 4e-3; // tiny model, aggressive schedule

    let mut trainer = Trainer::new(&engine, &artifacts_root(), cfg)?;
    println!(
        "bundle {}: {} trainable / {} base parameters",
        trainer.manifest.tag,
        trainer.manifest.params_trainable,
        trainer.manifest.params_base
    );

    let prompt = "question : ava has 3 apples and finds 4 more , then each of \
                  2 friends matches the total . how many apples in all ?";
    let before = trainer.complete(prompt, 24)?;

    let history = trainer.train()?;
    let (eval_loss, ppl) = trainer.evaluate()?;

    let after = trainer.complete(prompt, 24)?;
    println!(
        "\nloss: {:.3} -> {:.3} (eval {:.3}, ppl {:.1})",
        history.first_loss().unwrap(),
        history.final_loss().unwrap(),
        eval_loss,
        ppl
    );
    println!("decode before: {before}");
    println!("decode after:  {after}");

    assert!(
        history.tail_loss(10).unwrap() < history.first_loss().unwrap(),
        "training did not reduce the loss"
    );
    println!("\nquickstart OK");
    Ok(())
}
