//! BOFT: butterfly-factorized orthogonal finetuning (Liu et al. 2024)
//! as a first-class runtime method — the structured-sparsity extension
//! §5 of the OFTv2 paper calls out, promoted from the host-side
//! analysis in [`crate::peft::butterfly`] to a trainable adapter.
//!
//! Instead of one block-diagonal rotation, BOFT composes `m` butterfly
//! *factors*: factor `f` rotates coordinates gathered at stride
//! `b^f` into contiguous b-wide CNP blocks (a perfect-shuffle
//! permutation), so the product mixes `b^m` coordinates from any one —
//! global reach at block-diagonal cost. Depth adapts per linear:
//! `m(din)` is the largest power such that `b^m` divides `din`, so the
//! tiny preset's `d_model = 64, b = 16` attention linears get one
//! factor while its `d_ff = 256` MLP linears genuinely compose two.
//!
//! Everything stays input-centric: the factors rotate activations
//! (quadratic work), the frozen base matmul is untouched, and each
//! factor's blocks come from the same Cayley–Neumann parameterization
//! as OFTv2 — identity at `Q = 0`, orthogonal to the documented
//! Neumann-truncation tolerance.
//!
//! BOFT's rotate loops inherit the SIMD dispatch automatically: every
//! factor runs through the shared `block_rotate_fast` /
//! `block_rotate_transposed` / `block_rotate_grad_r` kernels in
//! [`crate::runtime::layers::linear`] (equivalence contract documented
//! there), while the perfect-shuffle `permute_cols` stays a scalar
//! gather — it moves bytes, not FLOPs.

use anyhow::{ensure, Context, Result};

use super::{ActExtra, Adapter, DecodeApply};
use crate::coordinator::manifest::{Init, ModelDims, ParamSpec};
use crate::peft::{invert_perm, packed_dim, stride_permutation};
use crate::peft::butterfly::permute_cols;
use crate::runtime::layers::linear::{
    block_rotate_fast, block_rotate_grad_r, block_rotate_transposed, build_cnp_blocks,
    cnp_backward_all,
};
use crate::runtime::layers::{accumulate, BaseWeight, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::scenario::Knob;
use crate::tensor::Tensor;

pub struct Boft;

/// Registry object.
pub static BOFT: Boft = Boft;

/// Butterfly depth for one linear: the largest `m >= 1` with
/// `b^m | din` (factor `f` strides by `b^f`, so factor `m-1` needs
/// `b^m` to divide the rotated dimension). Degenerate block sizes
/// (`b < 2`, where the "blocks" cannot rotate anything) clamp to one
/// factor instead of diverging.
pub fn depth(din: usize, b: usize) -> usize {
    if b < 2 {
        return 1;
    }
    let mut m = 0usize;
    let mut span = b;
    while span <= din && din % span == 0 {
        m += 1;
        span = match span.checked_mul(b) {
            Some(s) => s,
            None => break,
        };
    }
    m.max(1)
}

fn packed_name(linear: &str) -> String {
    format!("{linear}.boft_q")
}

/// One resolved butterfly factor: the stride permutation (and its
/// inverse) plus this factor's CNP rotation blocks.
struct BoftFactor {
    perm: Vec<usize>,
    inv: Vec<usize>,
    blocks: Vec<Tensor>,
}

/// Per-step plan entry: all factors of one linear, resolved once.
struct BoftPlan {
    factors: Vec<BoftFactor>,
}

/// Activation extras: the inputs to factors `1..m` (factor 0's input
/// is the linear's own input, already saved in the activation record's
/// `x`), plus the factors themselves when the step had no shared plan.
struct BoftAct {
    inputs: Vec<Tensor>,
    factors: Option<Vec<BoftFactor>>,
}

/// Resolve the packed parameter `(m*nb, p)` into per-factor blocks +
/// permutations for a linear of input width `din`.
fn build_factors(packed: &Tensor, din: usize, dims: &ModelDims) -> Result<Vec<BoftFactor>> {
    let b = dims.block_b;
    let nb = din / b;
    let m = depth(din, b);
    let p = packed_dim(b);
    ensure!(
        packed.shape.len() == 2 && packed.shape[0] == m * nb && packed.shape[1] == p,
        "packed BOFT parameter must be ({}, {p}) for din {din}, got {:?}",
        m * nb,
        packed.shape
    );
    let mut factors = Vec::with_capacity(m);
    let mut stride = 1usize;
    for f in 0..m {
        let rows = Tensor::from_vec(
            &[nb, p],
            packed.data[f * nb * p..(f + 1) * nb * p].to_vec(),
        );
        let blocks = build_cnp_blocks(&rows, b, dims.neumann_k)?;
        let perm = stride_permutation(din, b, stride);
        let inv = invert_perm(&perm);
        factors.push(BoftFactor { perm, inv, blocks });
        stride *= b;
    }
    Ok(factors)
}

/// One factor: group by stride, rotate the blocks, scatter back.
fn apply_factor(x: &Tensor, f: &BoftFactor) -> Result<Tensor> {
    let grouped = permute_cols(x, &f.perm);
    let rotated = block_rotate_fast(&grouped, &f.blocks)?;
    Ok(permute_cols(&rotated, &f.inv))
}

/// Apply the factor product to rows of `x`, returning the output and
/// the inputs to factors `1..m` (for the backward's dR terms; factor
/// 0 reads the activation record's saved `x`, so it is not duplicated
/// here).
fn rotate_forward(x: &Tensor, factors: &[BoftFactor]) -> Result<(Tensor, Vec<Tensor>)> {
    let Some((first, rest)) = factors.split_first() else {
        return Ok((x.clone(), Vec::new()));
    };
    let mut cur = apply_factor(x, first)?;
    let mut inputs = Vec::with_capacity(rest.len());
    for f in rest {
        inputs.push(cur.clone());
        cur = apply_factor(&cur, f)?;
    }
    Ok((cur, inputs))
}

/// As [`rotate_forward`] without saving intermediates — the per-token
/// decode path, where nothing flows backward.
fn rotate_only(x: &Tensor, factors: &[BoftFactor]) -> Result<Tensor> {
    let Some((first, rest)) = factors.split_first() else {
        return Ok(x.clone());
    };
    let mut cur = apply_factor(x, first)?;
    for f in rest {
        cur = apply_factor(&cur, f)?;
    }
    Ok(cur)
}

impl Adapter for Boft {
    fn name(&self) -> &'static str {
        "boft"
    }

    fn about(&self) -> &'static str {
        "butterfly-factorized OFT: m strided CNP factors, b^m mixing reach"
    }

    fn paper_label(&self, _quantized: bool) -> &'static str {
        "BOFT"
    }

    fn validate_dims(&self, dims: &ModelDims) -> Result<()> {
        ensure!(
            dims.block_b >= 2,
            "boft: block size {} cannot rotate anything (need b >= 2)",
            dims.block_b
        );
        super::oft_v2::ensure_blocks_divide("boft", dims)
    }

    /// The butterfly factorization fixes the block count per factor, so
    /// `r` and `block_share` do not apply; everything else does.
    fn supported_knobs(&self) -> &'static [Knob] {
        &[
            Knob::Coft,
            Knob::Eps,
            Knob::ModuleDropout,
            Knob::BlockSize,
            Knob::Target,
            Knob::Exclude,
        ]
    }

    fn linear_trainables(
        &self,
        linear: &str,
        din: usize,
        _dout: usize,
        dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        let b = dims.block_b;
        let m = depth(din, b);
        vec![ParamSpec {
            name: packed_name(linear),
            shape: vec![m * (din / b), b * (b - 1) / 2],
            init: Init::Zeros,
        }]
    }

    fn plan_linear(
        &self,
        linear: &str,
        params: &Params,
        dims: &ModelDims,
    ) -> Result<Option<super::PlanEntry>> {
        let packed = params.get(&packed_name(linear))?;
        let (din, _) = params.weight(linear)?.shape2();
        Ok(Some(Box::new(BoftPlan {
            factors: build_factors(packed, din, dims)?,
        })))
    }

    fn linear_forward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        let (din, _) = w.shape2();
        let (rotated, inputs, inline) =
            match ctx.plan.and_then(|p| p.get::<BoftPlan>(linear)) {
                Some(plan) => {
                    let (rot, inputs) = rotate_forward(x, &plan.factors)?;
                    (rot, inputs, None)
                }
                None => {
                    let packed = ctx.params.get(&packed_name(linear))?;
                    let factors = build_factors(packed, din, ctx.dims)?;
                    let (rot, inputs) = rotate_forward(x, &factors)?;
                    (rot, inputs, Some(factors))
                }
            };
        let y = w.matmul(&rotated)?;
        Ok((
            y,
            Some(Box::new(BoftAct {
                inputs,
                factors: inline,
            })),
        ))
    }

    fn linear_backward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let b = ctx.dims.block_b;
        let k = ctx.dims.neumann_k;
        let (din, _) = w.shape2();
        let nb = din / b;
        let p = packed_dim(b);
        let record: &BoftAct = act.extra()?;
        let factors: &[BoftFactor] = match ctx.plan.and_then(|pl| pl.get::<BoftPlan>(linear)) {
            Some(plan) => plan.factors.as_slice(),
            None => record
                .factors
                .as_deref()
                .context("missing boft factor record")?,
        };
        let m = factors.len();
        ensure!(
            record.inputs.len() + 1 == m,
            "boft record has {} factor inputs, expected {}",
            record.inputs.len(),
            m.saturating_sub(1)
        );
        let packed = ctx.params.get(&packed_name(linear))?;

        // Cotangent of the rotated activations, walked back factor by
        // factor. Each factor's dR is the standard block-rotation
        // gradient taken in that factor's grouped (permuted) space;
        // factor 0's input is the record's saved x.
        let mut dz = w.matmul_t(dy)?;
        let mut dpack = vec![0f32; m * nb * p];
        for (f, fac) in factors.iter().enumerate().rev() {
            let x_f = if f == 0 { &act.x } else { &record.inputs[f - 1] };
            let grouped_x = permute_cols(x_f, &fac.perm);
            let d_rot = permute_cols(&dz, &fac.perm);
            let dr = block_rotate_grad_r(&grouped_x, &d_rot, b);
            let rows = Tensor::from_vec(
                &[nb, p],
                packed.data[f * nb * p..(f + 1) * nb * p].to_vec(),
            );
            let dp = cnp_backward_all(&rows, b, k, &dr)?;
            dpack[f * nb * p..(f + 1) * nb * p].copy_from_slice(&dp.data);
            let d_grouped = block_rotate_transposed(&d_rot, &fac.blocks)?;
            dz = permute_cols(&d_grouped, &fac.inv);
        }
        accumulate(
            grads,
            &packed_name(linear),
            Tensor::from_vec(&[m * nb, p], dpack),
        );
        Ok(dz)
    }

    fn resolve_decode(
        &self,
        params: &Params,
        dims: &ModelDims,
        linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        let packed = params.get(&packed_name(linear))?;
        let (din, _) = w.shape2();
        Ok(Box::new(BoftDecode {
            w: w.cloned(),
            factors: build_factors(packed, din, dims)?,
        }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// Fold the factor product: the dense rotation is the product
    /// applied to the identity's rows (`rotate(x) = x M`, so
    /// `M = rotate(I)`), then `W' = M W` — the same expression the
    /// orthogonality tests' `dense_rotation` helper evaluates.
    fn merge_linear(
        &self,
        linear: &str,
        w: &Tensor,
        trainables: &Params,
        dims: &ModelDims,
    ) -> Result<Tensor> {
        let packed = trainables.get(&packed_name(linear))?;
        let din = w.shape[0];
        let factors = build_factors(packed, din, dims)?;
        let (rot, _) = rotate_forward(&Tensor::eye(din), &factors)?;
        rot.matmul(w)
    }

    /// Each factor's output is saved for the next factor's dR, so BOFT
    /// keeps `m - 1` extra activation copies per adapted linear beyond
    /// the generic input saves.
    fn mem_transient(
        &self,
        spec: &crate::modelspec::ModelSpec,
        dims: &ModelDims,
        tokens: f64,
        act_bytes: f64,
        input_saves: f64,
    ) -> f64 {
        input_saves
            + spec
                .adapted_linears()
                .map(|li| {
                    (depth(li.din, dims.block_b).saturating_sub(1)) as f64
                        * tokens
                        * li.din as f64
                        * act_bytes
                })
                .sum::<f64>()
    }
}

struct BoftDecode {
    w: BaseWeight,
    factors: Vec<BoftFactor>,
}

impl DecodeApply for BoftDecode {
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        self.w.matmul(&rotate_only(x, &self.factors)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::orthogonality_error;
    use crate::util::rng::Rng;

    #[test]
    fn depth_adapts_to_linear_width() {
        assert_eq!(depth(64, 16), 1); // tiny attention linears
        assert_eq!(depth(256, 16), 2); // tiny MLP linears
        assert_eq!(depth(4096, 32), 2);
        assert_eq!(depth(64, 4), 3);
        assert_eq!(depth(48, 16), 1); // non-dividing widths clamp to 1
        assert_eq!(depth(64, 1), 1); // degenerate b clamps, never loops
        assert_eq!(depth(64, 0), 1);
    }

    fn dims(b: usize, k: usize) -> ModelDims {
        let mut d = ModelDims::analysis(4, b);
        d.neumann_k = k;
        d
    }

    fn random_packed(din: usize, b: usize, std: f32, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let rows = depth(din, b) * (din / b);
        Tensor::randn(&[rows, packed_dim(b)], std, &mut rng)
    }

    /// The factor product applied to the identity: the dense rotation.
    fn dense_rotation(din: usize, b: usize, k: usize, std: f32, seed: u64) -> Tensor {
        let packed = random_packed(din, b, std, seed);
        let factors = build_factors(&packed, din, &dims(b, k)).unwrap();
        let (r, _) = rotate_forward(&Tensor::eye(din), &factors).unwrap();
        r
    }

    #[test]
    fn butterfly_product_is_orthogonal() {
        // Orthogonality of the composed factors inherits the CNP
        // truncation error: at the documented operating point
        // (small Q, k >= 6) the product's ||R^T R - I||_F stays below
        // 5e-3 — the same tolerance the host-side butterfly oracle
        // locks (peft::butterfly::tests::product_is_orthogonal).
        for &(din, b) in &[(64usize, 16usize), (256, 16), (64, 4)] {
            for seed in 0..3u64 {
                let r = dense_rotation(din, b, 8, 0.05, 100 + seed);
                let err = orthogonality_error(&r);
                assert!(err < 5e-3, "din={din} b={b} seed={seed}: err {err}");
            }
        }
    }

    #[test]
    fn identity_at_zero_parameters() {
        let din = 256;
        let packed = Tensor::zeros(&[depth(din, 16) * (din / 16), packed_dim(16)]);
        let factors = build_factors(&packed, din, &dims(16, 5)).unwrap();
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&[3, din], 1.0, &mut rng);
        let (y, inputs) = rotate_forward(&x, &factors).unwrap();
        // inputs to factors 1.. only — factor 0's input is the saved x
        assert_eq!(inputs.len(), 1);
        assert!(y.max_abs_diff(&x) < 1e-6);
        assert!(rotate_only(&x, &factors).unwrap().max_abs_diff(&y) < 1e-7);
    }

    #[test]
    fn multi_factor_mixing_exceeds_one_block() {
        // One coordinate must reach b^2 coordinates through 2 factors —
        // the whole point of promoting BOFT over plain block-diagonal.
        let (din, b) = (256usize, 16usize);
        let packed = random_packed(din, b, 0.1, 5);
        let factors = build_factors(&packed, din, &dims(b, 6)).unwrap();
        let mut probe = Tensor::zeros(&[1, din]);
        probe.data[0] = 1.0;
        let (y, _) = rotate_forward(&probe, &factors).unwrap();
        let touched = y.data.iter().filter(|v| v.abs() > 1e-9).count();
        assert_eq!(touched, b * b, "mixing reach should be b^2 = {}", b * b);
    }

    #[test]
    fn bad_packed_shape_is_an_error() {
        let packed = Tensor::zeros(&[3, packed_dim(16)]);
        assert!(build_factors(&packed, 64, &dims(16, 5)).is_err());
    }
}
