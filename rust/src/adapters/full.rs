//! Full finetuning: every base parameter is trainable. The adapted
//! linear is the plain base matmul, and (uniquely) its weight gradient
//! is accumulated.

use anyhow::Result;

use super::{ActExtra, Adapter, DecodeApply, PlainDecode};
use crate::coordinator::manifest::{ModelDims, ParamSpec};
use crate::runtime::layers::{accumulate, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::tensor::Tensor;

pub struct Full;

/// Registry object.
pub static FULL: Full = Full;

impl Adapter for Full {
    fn name(&self) -> &'static str {
        "full"
    }

    fn about(&self) -> &'static str {
        "full finetuning: every base parameter trains"
    }

    fn paper_label(&self, _quantized: bool) -> &'static str {
        "Full"
    }

    fn trains_base(&self) -> bool {
        true
    }

    /// No per-linear adapter parameters: manifest synthesis moves the
    /// whole base into the trainables instead (see `trains_base`).
    fn linear_trainables(
        &self,
        _linear: &str,
        _din: usize,
        _dout: usize,
        _dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        Vec::new()
    }

    fn linear_forward(
        &self,
        _ctx: &Ctx,
        _linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        Ok((w.matmul(x)?, None))
    }

    fn linear_backward(
        &self,
        _ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        accumulate(grads, linear, act.x.transpose2().matmul(dy)?);
        w.matmul_t(dy)
    }

    fn resolve_decode(
        &self,
        _params: &Params,
        _dims: &ModelDims,
        _linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        Ok(Box::new(PlainDecode { w: w.cloned() }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// Full finetuning trains the base in place: the trained linear
    /// weight *is* the deployable weight.
    fn merge_linear(
        &self,
        _linear: &str,
        w: &Tensor,
        _trainables: &Params,
        _dims: &ModelDims,
    ) -> Result<Tensor> {
        Ok(w.clone())
    }
}
