//! GOFT: quasi-orthogonal finetuning via Givens rotations (Ma et al.
//! 2024, "Parameter Efficient Quasi-Orthogonal Fine-Tuning via Givens
//! Rotation", per PAPERS.md) as a runtime method. The per-linear
//! rotation is a product of `k` *stages*; each stage applies `din/2`
//! disjoint plane (Givens) rotations
//!
//! ```text
//!   y_a =  cos(t) x_a - sin(t) x_b
//!   y_b =  sin(t) x_a + cos(t) x_b
//! ```
//!
//! so a stage is exactly orthogonal for any angles, costs `O(din)`
//! per row, and carries `din/2` trainable angles. Stages alternate
//! between adjacent pairing `(2j, 2j+1)` and the wrap-around offset
//! pairing `(2j+1, 2j+2 mod din)` — the brick-wall pattern that lets
//! `k` stages mix coordinates up to distance `k` apart, the paper's
//! answer to block-diagonal locality.
//!
//! **Identity at init.** All angles start at zero (`Init::Zeros`), and
//! a zero-angle plane rotation is the identity — the adapted model
//! starts exactly at the pretrained base, like `Q = 0` does for the
//! Cayley methods. No anchors, no series truncation: orthogonality is
//! exact at every point of training.

use anyhow::{ensure, Context, Result};

use super::{ActExtra, Adapter, DecodeApply};
use crate::coordinator::manifest::{Init, ModelDims, ParamSpec};
use crate::runtime::layers::{accumulate, BaseWeight, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::scenario::Knob;
use crate::tensor::Tensor;

pub struct Goft;

/// Registry object.
pub static GOFT: Goft = Goft;

/// Givens stages per adapted linear: the bundle's LoRA rank, at
/// least 1 (each stage is din/2 angles, so parameters total
/// `k * din / 2` — half a HOFT reflection set at equal rank).
pub fn stages(dims: &ModelDims) -> usize {
    dims.lora_r.max(1)
}

fn param_name(linear: &str) -> String {
    format!("{linear}.goft_theta")
}

/// The disjoint index pairs of stage `s` over `din` (even) coordinates:
/// even stages rotate adjacent pairs, odd stages the offset pairs with
/// a wrap-around — uniformly `din/2` pairs either way.
fn stage_pairs(s: usize, din: usize) -> Vec<(usize, usize)> {
    let half = din / 2;
    (0..half)
        .map(|j| {
            if s % 2 == 0 {
                (2 * j, 2 * j + 1)
            } else {
                (2 * j + 1, (2 * j + 2) % din)
            }
        })
        .collect()
}

/// One resolved stage: its pairing plus the angles' cos/sin tables.
struct Stage {
    pairs: Vec<(usize, usize)>,
    cos: Vec<f32>,
    sin: Vec<f32>,
}

/// Per-step plan entry: all stages of one linear, resolved once.
struct GoftPlan {
    stages: Vec<Stage>,
}

/// Activation extras: the inputs to stages `1..k` (stage 0's input is
/// the linear's own input, already saved in the activation record's
/// `x`), plus the resolved stages when the step had no shared plan.
struct GoftAct {
    inputs: Vec<Tensor>,
    stages: Option<Vec<Stage>>,
}

/// Resolve the trainable `(k, din/2)` angles into stages.
fn build_stages(theta: &Tensor, linear: &str, din: usize) -> Result<Vec<Stage>> {
    ensure!(
        din % 2 == 0,
        "GOFT pairs coordinates, so '{linear}' needs an even input width, got {din}"
    );
    let half = din / 2;
    ensure!(
        theta.shape.len() == 2 && theta.shape[1] == half && theta.shape[0] > 0,
        "GOFT parameter of '{linear}' must be (k, {half}), got {:?}",
        theta.shape
    );
    let k = theta.shape[0];
    let mut out = Vec::with_capacity(k);
    for s in 0..k {
        let angles = &theta.data[s * half..(s + 1) * half];
        out.push(Stage {
            pairs: stage_pairs(s, din),
            cos: angles.iter().map(|t| t.cos()).collect(),
            sin: angles.iter().map(|t| t.sin()).collect(),
        });
    }
    Ok(out)
}

/// Apply one stage to every row. The pairs are disjoint, so each
/// coordinate is written exactly once.
fn apply_stage(x: &Tensor, st: &Stage) -> Tensor {
    let (m, d) = (x.shape[0], x.shape[1]);
    let mut out = vec![0f32; m * d];
    for row in 0..m {
        let src = &x.data[row * d..(row + 1) * d];
        let dst = &mut out[row * d..(row + 1) * d];
        for (p, &(a, b)) in st.pairs.iter().enumerate() {
            let (c, s) = (st.cos[p], st.sin[p]);
            dst[a] = c * src[a] - s * src[b];
            dst[b] = s * src[a] + c * src[b];
        }
    }
    Tensor::from_vec(&[m, d], out)
}

/// Apply all stages in index order; returns the output and the inputs
/// to stages `1..k` (for the backward — stage 0 reads the activation
/// record's saved `x`, so it is not duplicated here).
fn rotate_forward(x: &Tensor, stages: &[Stage]) -> (Tensor, Vec<Tensor>) {
    let Some((first, rest)) = stages.split_first() else {
        return (x.clone(), Vec::new());
    };
    let mut cur = apply_stage(x, first);
    let mut inputs = Vec::with_capacity(rest.len());
    for st in rest {
        inputs.push(cur.clone());
        cur = apply_stage(&cur, st);
    }
    (cur, inputs)
}

/// As [`rotate_forward`] without saving intermediates — the per-token
/// decode path, where nothing flows backward.
fn rotate_only(x: &Tensor, stages: &[Stage]) -> Tensor {
    let Some((first, rest)) = stages.split_first() else {
        return x.clone();
    };
    let mut cur = apply_stage(x, first);
    for st in rest {
        cur = apply_stage(&cur, st);
    }
    cur
}

/// Backward through one stage. Per pair `(a, b)` with angle `t`
/// (`c = cos t`, `s = sin t`):
///
///   dL/dt   = sum_rows dy_a (-s x_a - c x_b) + dy_b (c x_a - s x_b)
///   dL/dx_a =  c dy_a + s dy_b        (dx = dy R^T)
///   dL/dx_b = -s dy_a + c dy_b
///
/// Locked by the finite-difference train-step check in
/// `tests/scenario.rs`.
fn stage_backward(x: &Tensor, dy: &Tensor, st: &Stage) -> (Vec<f32>, Tensor) {
    let (m, d) = (x.shape[0], x.shape[1]);
    let mut dtheta = vec![0f32; st.pairs.len()];
    let mut dx = vec![0f32; m * d];
    for row in 0..m {
        let xr = &x.data[row * d..(row + 1) * d];
        let dyr = &dy.data[row * d..(row + 1) * d];
        let dst = &mut dx[row * d..(row + 1) * d];
        for (p, &(a, b)) in st.pairs.iter().enumerate() {
            let (c, s) = (st.cos[p], st.sin[p]);
            dtheta[p] += dyr[a] * (-s * xr[a] - c * xr[b]) + dyr[b] * (c * xr[a] - s * xr[b]);
            dst[a] = c * dyr[a] + s * dyr[b];
            dst[b] = -s * dyr[a] + c * dyr[b];
        }
    }
    (dtheta, Tensor::from_vec(&[m, d], dx))
}

impl Adapter for Goft {
    fn name(&self) -> &'static str {
        "goft"
    }

    fn about(&self) -> &'static str {
        "Givens-rotation quasi-orthogonal finetuning: k brick-wall plane-rotation stages"
    }

    fn paper_label(&self, _quantized: bool) -> &'static str {
        "GOFT"
    }

    fn validate_dims(&self, dims: &ModelDims) -> Result<()> {
        ensure!(
            dims.d_model % 2 == 0 && dims.d_ff % 2 == 0,
            "goft pairs coordinates: d_model {} and d_ff {} must be even",
            dims.d_model,
            dims.d_ff
        );
        Ok(())
    }

    /// Plane rotations have no block structure (`r`/`block`/
    /// `block_share` do not apply); angles are zero at identity, so
    /// COFT's deviation clamp and module dropout compose naturally.
    fn supported_knobs(&self) -> &'static [Knob] {
        &[
            Knob::Coft,
            Knob::Eps,
            Knob::ModuleDropout,
            Knob::Target,
            Knob::Exclude,
        ]
    }

    fn linear_trainables(
        &self,
        linear: &str,
        din: usize,
        _dout: usize,
        dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        vec![ParamSpec {
            name: param_name(linear),
            shape: vec![stages(dims), din / 2],
            init: Init::Zeros,
        }]
    }

    fn plan_linear(
        &self,
        linear: &str,
        params: &Params,
        dims: &ModelDims,
    ) -> Result<Option<super::PlanEntry>> {
        let theta = params.get(&param_name(linear))?;
        let (din, _) = params.weight(linear)?.shape2();
        let _ = dims;
        Ok(Some(Box::new(GoftPlan {
            stages: build_stages(theta, linear, din)?,
        })))
    }

    fn linear_forward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        let (din, _) = w.shape2();
        let (rotated, inputs, inline) = match ctx.plan.and_then(|p| p.get::<GoftPlan>(linear)) {
            Some(plan) => {
                let (rot, inputs) = rotate_forward(x, &plan.stages);
                (rot, inputs, None)
            }
            None => {
                let theta = ctx.params.get(&param_name(linear))?;
                let stages = build_stages(theta, linear, din)?;
                let (rot, inputs) = rotate_forward(x, &stages);
                (rot, inputs, Some(stages))
            }
        };
        let y = w.matmul(&rotated)?;
        Ok((
            y,
            Some(Box::new(GoftAct {
                inputs,
                stages: inline,
            })),
        ))
    }

    fn linear_backward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let (din, _) = w.shape2();
        let half = din / 2;
        let record: &GoftAct = act.extra()?;
        let stages: &[Stage] = match ctx.plan.and_then(|p| p.get::<GoftPlan>(linear)) {
            Some(plan) => plan.stages.as_slice(),
            None => record
                .stages
                .as_deref()
                .context("missing goft stage record")?,
        };
        let k = stages.len();
        ensure!(
            record.inputs.len() + 1 == k,
            "goft record has {} stage inputs, expected {}",
            record.inputs.len(),
            k.saturating_sub(1)
        );
        let mut dz = w.matmul_t(dy)?;
        let mut dtheta = vec![0f32; k * half];
        for i in (0..k).rev() {
            // stage 0's input is the record's saved x
            let x_i = if i == 0 { &act.x } else { &record.inputs[i - 1] };
            let (dt, dx) = stage_backward(x_i, &dz, &stages[i]);
            dtheta[i * half..(i + 1) * half].copy_from_slice(&dt);
            dz = dx;
        }
        accumulate(
            grads,
            &param_name(linear),
            Tensor::from_vec(&[k, half], dtheta),
        );
        Ok(dz)
    }

    fn resolve_decode(
        &self,
        params: &Params,
        _dims: &ModelDims,
        linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        let theta = params.get(&param_name(linear))?;
        let (din, _) = w.shape2();
        Ok(Box::new(GoftDecode {
            w: w.cloned(),
            stages: build_stages(theta, linear, din)?,
        }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// Fold the stage product: `rotate(x) = x M` with `M = rotate(I)`
    /// (each stage is linear on rows), then `W' = M W`. Exactly
    /// orthogonal — no series truncation.
    fn merge_linear(
        &self,
        linear: &str,
        w: &Tensor,
        trainables: &Params,
        dims: &ModelDims,
    ) -> Result<Tensor> {
        let _ = dims;
        let theta = trainables.get(&param_name(linear))?;
        let din = w.shape[0];
        let stages = build_stages(theta, linear, din)?;
        let (rot, _) = rotate_forward(&Tensor::eye(din), &stages);
        rot.matmul(w)
    }

    /// Each stage's output feeds the next, so GOFT keeps `k - 1` extra
    /// activation copies per adapted linear alive for backward.
    fn mem_transient(
        &self,
        spec: &crate::modelspec::ModelSpec,
        dims: &ModelDims,
        tokens: f64,
        act_bytes: f64,
        input_saves: f64,
    ) -> f64 {
        let k = stages(dims) as f64;
        input_saves
            + spec
                .adapted_linears()
                .map(|li| (k - 1.0) * tokens * li.din as f64 * act_bytes)
                .sum::<f64>()
    }
}

struct GoftDecode {
    w: BaseWeight,
    stages: Vec<Stage>,
}

impl DecodeApply for GoftDecode {
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        self.w.matmul(&rotate_only(x, &self.stages))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::orthogonality_error;
    use crate::util::rng::Rng;

    fn random_theta(k: usize, din: usize, std: f32, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(&[k, din / 2], std, &mut rng)
    }

    fn dense_rotation(theta: &Tensor, din: usize) -> Tensor {
        let st = build_stages(theta, "layers.0.attn.wq", din).unwrap();
        let (r, _) = rotate_forward(&Tensor::eye(din), &st);
        r
    }

    #[test]
    fn stage_product_is_orthogonal() {
        // Plane rotations are exactly orthogonal, even at large
        // angles: only f32 rounding remains.
        for &din in &[16usize, 64] {
            for seed in 0..3u64 {
                let theta = random_theta(4, din, 1.0, seed);
                let err = orthogonality_error(&dense_rotation(&theta, din));
                assert!(err < 1e-4, "din={din} seed={seed}: err {err}");
            }
        }
    }

    #[test]
    fn identity_at_zero_angles() {
        let din = 64;
        let theta = Tensor::zeros(&[3, din / 2]);
        let st = build_stages(&theta, "layers.1.mlp.up", din).unwrap();
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[5, din], 1.0, &mut rng);
        let (y, _) = rotate_forward(&x, &st);
        assert!(y.max_abs_diff(&x) < 1e-7);
        assert!(rotate_only(&x, &st).max_abs_diff(&y) < 1e-7);
    }

    #[test]
    fn brick_wall_pairing_mixes_beyond_one_pair() {
        // With >= 2 stages a single coordinate must spread past its
        // adjacent partner — the offset stage's wrap-around at work.
        let din = 8;
        let theta = random_theta(4, din, 0.7, 11);
        let st = build_stages(&theta, "layers.0.attn.wq", din).unwrap();
        let mut probe = Tensor::zeros(&[1, din]);
        probe.data[0] = 1.0;
        let (y, _) = rotate_forward(&probe, &st);
        let touched = y.data.iter().filter(|v| v.abs() > 1e-9).count();
        assert!(touched > 2, "reach {touched} should exceed one pair");
    }

    #[test]
    fn pairs_are_disjoint_and_cover() {
        for s in 0..4 {
            for &din in &[8usize, 64] {
                let pairs = stage_pairs(s, din);
                assert_eq!(pairs.len(), din / 2);
                let mut seen = vec![false; din];
                for (a, b) in pairs {
                    assert!(!seen[a] && !seen[b], "stage {s} reuses a coordinate");
                    seen[a] = true;
                    seen[b] = true;
                }
                assert!(seen.iter().all(|&v| v), "stage {s} must cover all coords");
            }
        }
    }

    #[test]
    fn bad_shapes_are_errors() {
        // odd width
        assert!(build_stages(&Tensor::zeros(&[2, 3]), "x", 7).is_err());
        // wrong angle count
        assert!(build_stages(&Tensor::zeros(&[2, 3]), "x", 16).is_err());
        // zero stages
        assert!(build_stages(&Tensor::zeros(&[0, 8]), "x", 16).is_err());
    }
}
