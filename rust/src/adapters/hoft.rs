//! HOFT: Householder orthogonal finetuning (Moreno Arcas et al. 2025,
//! per PAPERS.md) as a runtime method. The per-linear rotation is a
//! product of `k` Householder reflections
//! `H(w) = I - 2 w w^T / (w^T w)` applied to the *input* activations
//! (input-centric, like OFTv2): exactly orthogonal for any `w != 0`,
//! `O(din)` work per reflection per row, `k * din` trainable
//! parameters per linear.
//!
//! **Identity at init.** A reflection is never the identity, so HOFT
//! parameterizes each direction as `w_i = a_i + v_i` with a fixed unit
//! anchor `a_i` (deterministically derived from the linear's name) and
//! the trainable offset `v_i` initialized to zero — and anchors come
//! in equal *pairs* (`a_{2j} == a_{2j+1}`). Reflections are
//! involutions, so at `v = 0` each pair collapses to
//! `H(a) H(a) = I`: the adapted model starts exactly at the
//! pretrained base, like Q = 0 does for the Cayley methods, while the
//! two halves of a pair still receive distinct (order-dependent)
//! gradients.

use anyhow::{ensure, Context, Result};

use super::{ActExtra, Adapter, DecodeApply};
use crate::coordinator::manifest::{Init, ModelDims, ParamSpec};
use crate::runtime::layers::{accumulate, BaseWeight, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::scenario::Knob;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct Hoft;

/// Registry object.
pub static HOFT: Hoft = Hoft;

/// Reflections per adapted linear: the bundle's LoRA rank rounded up
/// to an even count (anchors pair up), at least 2.
pub fn reflections(dims: &ModelDims) -> usize {
    let k = dims.lora_r.max(2);
    k + (k & 1)
}

fn param_name(linear: &str) -> String {
    format!("{linear}.hoft_v")
}

/// FNV-1a over the linear's name: gives every linear an independent,
/// order-free anchor stream (same scheme as parameter init).
fn name_seed(linear: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for byte in linear.as_bytes() {
        h ^= *byte as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// The fixed unit anchor of reflection `i` (pairs share: index `i/2`).
/// Deterministic in (linear, pair, din) — every worker, checkpoint
/// resume, and decode session reconstructs identical anchors.
fn anchor(linear: &str, i: usize, din: usize) -> Vec<f32> {
    let mut rng = Rng::new(
        0x480F_7EC7 ^ name_seed(linear) ^ ((i / 2) as u64).wrapping_mul(0x9E37_79B9_97F4_A7C1),
    );
    let mut a = rng.normal_vec(din, 1.0);
    let norm = a.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
    for x in &mut a {
        *x /= norm;
    }
    a
}

/// One resolved reflection: direction `w = a + v` and `s = w . w`.
struct Refl {
    w: Vec<f32>,
    s: f32,
}

/// Per-step plan entry: all reflections of one linear.
struct HoftPlan {
    refl: Vec<Refl>,
}

/// Activation extras: the inputs to reflections `1..k` (reflection 0's
/// input is the linear's own input, already saved in the activation
/// record's `x`), plus the resolved reflections when the step had no
/// shared plan.
struct HoftAct {
    inputs: Vec<Tensor>,
    refl: Option<Vec<Refl>>,
}

/// Resolve the trainable `(k, din)` offsets into reflections.
fn build_reflections(vt: &Tensor, linear: &str, din: usize) -> Result<Vec<Refl>> {
    ensure!(
        vt.shape.len() == 2 && vt.shape[1] == din,
        "HOFT parameter of '{linear}' must be (k, {din}), got {:?}",
        vt.shape
    );
    // Anchors pair up (see module doc): an odd count would leave one
    // unpaired reflection applied at v = 0, silently shifting the
    // model away from the pretrained base before training starts.
    ensure!(
        vt.shape[0] > 0 && vt.shape[0] % 2 == 0,
        "HOFT parameter of '{linear}' must hold an even, nonzero reflection count \
         (anchor pairs make the adapter the identity at init); got {}",
        vt.shape[0]
    );
    let k = vt.shape[0];
    let mut refl = Vec::with_capacity(k);
    for i in 0..k {
        let a = anchor(linear, i, din);
        let w: Vec<f32> = a
            .iter()
            .zip(&vt.data[i * din..(i + 1) * din])
            .map(|(ai, vi)| ai + vi)
            .collect();
        let s = w.iter().map(|x| x * x).sum::<f32>();
        ensure!(
            s > 1e-12,
            "HOFT reflection {i} of '{linear}' collapsed to the zero vector \
             (offset cancels its anchor); reduce the learning rate"
        );
        refl.push(Refl { w, s });
    }
    Ok(refl)
}

/// `y = x H(w)` row-wise: `y_r = x_r - (2 (x_r . w) / s) w`.
///
/// The `x_r . w` contraction dispatches to [`crate::tensor::simd::dot`]
/// when SIMD kernels are active (equivalence contract: <= 1e-5 rel vs
/// the scalar sum — lane blocking reassociates the reduction); the
/// rank-1 update is a branch-free axpy the compiler vectorizes either
/// way.
fn reflect(x: &Tensor, r: &Refl) -> Tensor {
    let (m, d) = (x.shape[0], x.shape[1]);
    let fast = crate::tensor::simd_kernels_active();
    let mut out = vec![0f32; m * d];
    for row in 0..m {
        let src = &x.data[row * d..(row + 1) * d];
        let dst = &mut out[row * d..(row + 1) * d];
        let c = if fast {
            crate::tensor::simd::dot(src, &r.w)
        } else {
            let mut c = 0f32;
            for j in 0..d {
                c += src[j] * r.w[j];
            }
            c
        };
        let c = 2.0 * c / r.s;
        for j in 0..d {
            dst[j] = src[j] - c * r.w[j];
        }
    }
    Tensor::from_vec(&[m, d], out)
}

/// Apply all reflections in index order; returns the output and the
/// inputs to reflections `1..k` (for the backward — reflection 0 reads
/// the activation record's saved `x`, so it is not duplicated here).
fn rotate_forward(x: &Tensor, refl: &[Refl]) -> (Tensor, Vec<Tensor>) {
    let Some((first, rest)) = refl.split_first() else {
        return (x.clone(), Vec::new());
    };
    let mut cur = reflect(x, first);
    let mut inputs = Vec::with_capacity(rest.len());
    for r in rest {
        inputs.push(cur.clone());
        cur = reflect(&cur, r);
    }
    (cur, inputs)
}

/// As [`rotate_forward`] without saving intermediates — the per-token
/// decode path, where nothing flows backward.
fn rotate_only(x: &Tensor, refl: &[Refl]) -> Tensor {
    let Some((first, rest)) = refl.split_first() else {
        return x.clone();
    };
    let mut cur = reflect(x, first);
    for r in rest {
        cur = reflect(&cur, r);
    }
    cur
}

/// Backward through one reflection. With `p_r = x_r . w`,
/// `q_r = dy_r . w`, `alpha = sum_r p_r q_r`:
///
///   dL/dx = dy H(w)                    (H is symmetric)
///   dL/dw_j = -(2/s) sum_r (p_r dy_rj + q_r x_rj) + (4 alpha / s^2) w_j
///
/// and `dL/dv = dL/dw` since `w = a + v` with `a` fixed. Locked by the
/// finite-difference train-step check in `runtime::refmodel::tests`.
fn reflect_backward(x: &Tensor, dy: &Tensor, r: &Refl) -> (Vec<f32>, Tensor) {
    let (m, d) = (x.shape[0], x.shape[1]);
    let fast = crate::tensor::simd_kernels_active();
    let mut dw = vec![0f32; d];
    let mut alpha = 0f32;
    for row in 0..m {
        let xr = &x.data[row * d..(row + 1) * d];
        let dyr = &dy.data[row * d..(row + 1) * d];
        let (p, q) = if fast {
            (
                crate::tensor::simd::dot(xr, &r.w),
                crate::tensor::simd::dot(dyr, &r.w),
            )
        } else {
            let mut p = 0f32;
            let mut q = 0f32;
            for j in 0..d {
                p += xr[j] * r.w[j];
                q += dyr[j] * r.w[j];
            }
            (p, q)
        };
        alpha += p * q;
        let f = 2.0 / r.s;
        for j in 0..d {
            dw[j] -= f * (p * dyr[j] + q * xr[j]);
        }
    }
    let g = 4.0 * alpha / (r.s * r.s);
    for j in 0..d {
        dw[j] += g * r.w[j];
    }
    (dw, reflect(dy, r))
}

impl Adapter for Hoft {
    fn name(&self) -> &'static str {
        "hoft"
    }

    fn about(&self) -> &'static str {
        "Householder orthogonal finetuning: k exact reflections per linear"
    }

    fn paper_label(&self, _quantized: bool) -> &'static str {
        "HOFT"
    }

    /// Reflections have no block structure (`r`/`block`/`block_share`
    /// do not apply); the offsets are zero at identity, so COFT's
    /// deviation clamp and module dropout compose naturally.
    fn supported_knobs(&self) -> &'static [Knob] {
        &[
            Knob::Coft,
            Knob::Eps,
            Knob::ModuleDropout,
            Knob::Target,
            Knob::Exclude,
        ]
    }

    fn linear_trainables(
        &self,
        linear: &str,
        din: usize,
        _dout: usize,
        dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        vec![ParamSpec {
            name: param_name(linear),
            shape: vec![reflections(dims), din],
            init: Init::Zeros,
        }]
    }

    fn plan_linear(
        &self,
        linear: &str,
        params: &Params,
        dims: &ModelDims,
    ) -> Result<Option<super::PlanEntry>> {
        let vt = params.get(&param_name(linear))?;
        let (din, _) = params.weight(linear)?.shape2();
        let _ = dims;
        Ok(Some(Box::new(HoftPlan {
            refl: build_reflections(vt, linear, din)?,
        })))
    }

    fn linear_forward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        let (din, _) = w.shape2();
        let (rotated, inputs, inline) =
            match ctx.plan.and_then(|p| p.get::<HoftPlan>(linear)) {
                Some(plan) => {
                    let (rot, inputs) = rotate_forward(x, &plan.refl);
                    (rot, inputs, None)
                }
                None => {
                    let vt = ctx.params.get(&param_name(linear))?;
                    let refl = build_reflections(vt, linear, din)?;
                    let (rot, inputs) = rotate_forward(x, &refl);
                    (rot, inputs, Some(refl))
                }
            };
        let y = w.matmul(&rotated)?;
        Ok((
            y,
            Some(Box::new(HoftAct {
                inputs,
                refl: inline,
            })),
        ))
    }

    fn linear_backward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let (din, _) = w.shape2();
        let record: &HoftAct = act.extra()?;
        let refl: &[Refl] = match ctx.plan.and_then(|p| p.get::<HoftPlan>(linear)) {
            Some(plan) => plan.refl.as_slice(),
            None => record
                .refl
                .as_deref()
                .context("missing hoft reflection record")?,
        };
        let k = refl.len();
        ensure!(
            record.inputs.len() + 1 == k,
            "hoft record has {} reflection inputs, expected {}",
            record.inputs.len(),
            k.saturating_sub(1)
        );
        let mut dz = w.matmul_t(dy)?;
        let mut dv = vec![0f32; k * din];
        for i in (0..k).rev() {
            // reflection 0's input is the record's saved x
            let x_i = if i == 0 { &act.x } else { &record.inputs[i - 1] };
            let (dw, dx) = reflect_backward(x_i, &dz, &refl[i]);
            dv[i * din..(i + 1) * din].copy_from_slice(&dw);
            dz = dx;
        }
        accumulate(
            grads,
            &param_name(linear),
            Tensor::from_vec(&[k, din], dv),
        );
        Ok(dz)
    }

    fn resolve_decode(
        &self,
        params: &Params,
        _dims: &ModelDims,
        linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        let vt = params.get(&param_name(linear))?;
        let (din, _) = w.shape2();
        Ok(Box::new(HoftDecode {
            w: w.cloned(),
            refl: build_reflections(vt, linear, din)?,
        }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// Fold the reflection product: `rotate(x) = x M` with
    /// `M = rotate(I)` (each reflection is linear on rows), then
    /// `W' = M W`. Exactly orthogonal — no series truncation.
    fn merge_linear(
        &self,
        linear: &str,
        w: &Tensor,
        trainables: &Params,
        dims: &ModelDims,
    ) -> Result<Tensor> {
        let _ = dims;
        let vt = trainables.get(&param_name(linear))?;
        let din = w.shape[0];
        let refl = build_reflections(vt, linear, din)?;
        let (rot, _) = rotate_forward(&Tensor::eye(din), &refl);
        rot.matmul(w)
    }

    /// Each reflection's output feeds the next, so HOFT keeps `k - 1`
    /// extra activation copies per adapted linear alive for backward.
    fn mem_transient(
        &self,
        spec: &crate::modelspec::ModelSpec,
        dims: &ModelDims,
        tokens: f64,
        act_bytes: f64,
        input_saves: f64,
    ) -> f64 {
        let k = reflections(dims) as f64;
        input_saves
            + spec
                .adapted_linears()
                .map(|li| (k - 1.0) * tokens * li.din as f64 * act_bytes)
                .sum::<f64>()
    }
}

struct HoftDecode {
    w: BaseWeight,
    refl: Vec<Refl>,
}

impl DecodeApply for HoftDecode {
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        self.w.matmul(&rotate_only(x, &self.refl))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::orthogonality_error;
    use crate::util::rng::Rng;

    fn random_offsets(k: usize, din: usize, std: f32, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(&[k, din], std, &mut rng)
    }

    fn dense_rotation(linear: &str, vt: &Tensor, din: usize) -> Tensor {
        let refl = build_reflections(vt, linear, din).unwrap();
        let (r, _) = rotate_forward(&Tensor::eye(din), &refl);
        r
    }

    #[test]
    fn reflection_product_is_orthogonal() {
        // Householder reflections are exactly orthogonal — unlike the
        // Cayley–Neumann methods there is no series truncation, so the
        // documented tolerance is pure f32 rounding: 1e-4 in
        // Frobenius norm even for large offsets.
        for &din in &[16usize, 64] {
            for seed in 0..3u64 {
                let vt = random_offsets(4, din, 0.5, seed);
                let r = dense_rotation("layers.0.attn.wq", &vt, din);
                let err = orthogonality_error(&r);
                assert!(err < 1e-4, "din={din} seed={seed}: err {err}");
            }
        }
    }

    #[test]
    fn identity_at_zero_offsets() {
        // The paired-anchor init: at v = 0 each anchor pair cancels
        // (H(a) H(a) = I), so the adapted model is exactly the base.
        let din = 64;
        let vt = Tensor::zeros(&[4, din]);
        let refl = build_reflections(&vt, "layers.1.mlp.up", din).unwrap();
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[5, din], 1.0, &mut rng);
        let (y, _) = rotate_forward(&x, &refl);
        assert!(y.max_abs_diff(&x) < 1e-5, "{}", y.max_abs_diff(&x));
    }

    #[test]
    fn rotation_preserves_row_norms() {
        let din = 32;
        let vt = random_offsets(6, din, 0.3, 7);
        let refl = build_reflections(&vt, "layers.0.attn.wo", din).unwrap();
        let mut rng = Rng::new(11);
        let x = Tensor::randn(&[4, din], 1.0, &mut rng);
        let (y, _) = rotate_forward(&x, &refl);
        for row in 0..4 {
            let nx: f32 = x.data[row * din..(row + 1) * din].iter().map(|v| v * v).sum();
            let ny: f32 = y.data[row * din..(row + 1) * din].iter().map(|v| v * v).sum();
            assert!(
                (nx.sqrt() - ny.sqrt()).abs() < 1e-3 * nx.sqrt().max(1.0),
                "row {row}: {} vs {}",
                nx.sqrt(),
                ny.sqrt()
            );
        }
    }

    #[test]
    fn anchors_are_deterministic_and_paired() {
        let a0 = anchor("layers.0.attn.wq", 0, 64);
        let a1 = anchor("layers.0.attn.wq", 1, 64);
        assert_eq!(a0, a1, "pair halves must share an anchor");
        let a2 = anchor("layers.0.attn.wq", 2, 64);
        assert_ne!(a0, a2, "different pairs get different anchors");
        assert_eq!(a0, anchor("layers.0.attn.wq", 0, 64), "deterministic");
        assert_ne!(a0, anchor("layers.0.attn.wk", 0, 64), "per-linear streams");
        let norm: f32 = a0.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn reflection_count_is_even_and_tracks_rank() {
        let mut d = ModelDims::analysis(4, 32);
        assert_eq!(reflections(&d), 4);
        d.lora_r = 5;
        assert_eq!(reflections(&d), 6);
        d.lora_r = 1;
        assert_eq!(reflections(&d), 2);
    }

    #[test]
    fn odd_reflection_count_is_rejected() {
        // An unpaired anchor would break identity-at-init silently; a
        // hand-edited (3, din) parameter must error, not load.
        let vt = Tensor::zeros(&[3, 16]);
        assert!(build_reflections(&vt, "layers.0.attn.wq", 16).is_err());
        let empty = Tensor::zeros(&[0, 16]);
        assert!(build_reflections(&empty, "layers.0.attn.wq", 16).is_err());
    }

    #[test]
    fn zero_direction_is_an_error_not_a_panic() {
        // An offset that exactly cancels its anchor must surface as an
        // error naming the reflection.
        let din = 16;
        let a = anchor("layers.0.attn.wq", 0, din);
        let mut data = vec![0f32; 2 * din];
        for (j, aj) in a.iter().enumerate() {
            data[j] = -aj;
        }
        let vt = Tensor::from_vec(&[2, din], data);
        let err = build_reflections(&vt, "layers.0.attn.wq", din);
        assert!(err.is_err());
    }
}
