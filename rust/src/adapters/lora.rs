//! LoRA: the additive low-rank adapter `y = x W + (alpha/r) (x A) B`.
//! One struct serves both the full-precision (`lora`) and quantized
//! (`qlora`) registrations — the base weight arrives as a [`WeightRef`]
//! and stays packed on the quantized path (fused transposed matmul in
//! the backward).

use anyhow::Result;

use super::{ActExtra, Adapter, DecodeApply};
use crate::coordinator::manifest::{Init, ModelDims, ParamSpec};
use crate::modelspec::ModelSpec;
use crate::runtime::layers::{accumulate, BaseWeight, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::scenario::Knob;
use crate::tensor::Tensor;

pub struct Lora {
    pub name: &'static str,
    pub quantized: bool,
}

/// Registry object (full-precision base).
pub static LORA: Lora = Lora {
    name: "lora",
    quantized: false,
};

/// Activation extras of one LoRA linear: the saved low-rank activation
/// `x A` and the `alpha/r` scale.
struct LoraAct {
    xa: Tensor,
    scale: f32,
}

fn scale_of(dims: &ModelDims) -> f32 {
    (dims.lora_alpha / dims.lora_r as f64) as f32
}

impl Adapter for Lora {
    fn name(&self) -> &'static str {
        self.name
    }

    fn about(&self) -> &'static str {
        if self.quantized {
            "LoRA over an NF4/AWQ-packed frozen base (QLoRA)"
        } else {
            "additive low-rank adapter W + (alpha/r) A B"
        }
    }

    fn paper_label(&self, quantized: bool) -> &'static str {
        if self.quantized || quantized {
            "QLoRA"
        } else {
            "LoRA"
        }
    }

    fn quantized_base(&self) -> bool {
        self.quantized
    }

    /// LoRA is additive, not orthogonal: the rotation knobs (COFT,
    /// `r`/`block`/`block_share`) do not apply — only dropout and
    /// module targeting carry over (covers `qlora` too).
    fn supported_knobs(&self) -> &'static [Knob] {
        &[Knob::ModuleDropout, Knob::Target, Knob::Exclude]
    }

    fn linear_trainables(
        &self,
        linear: &str,
        din: usize,
        dout: usize,
        dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        vec![
            ParamSpec {
                name: format!("{linear}.lora_a"),
                shape: vec![din, dims.lora_r],
                init: Init::Normal(0.01),
            },
            ParamSpec {
                name: format!("{linear}.lora_b"),
                shape: vec![dims.lora_r, dout],
                init: Init::Zeros,
            },
        ]
    }

    fn linear_forward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        let a = ctx.params.get(&format!("{linear}.lora_a"))?;
        let b = ctx.params.get(&format!("{linear}.lora_b"))?;
        let scale = scale_of(ctx.dims);
        let xa = x.matmul(a)?;
        let y = w.matmul(x)?.add(&xa.matmul(b)?.scale(scale))?;
        Ok((y, Some(Box::new(LoraAct { xa, scale }))))
    }

    fn linear_backward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let lc: &LoraAct = act.extra()?;
        let a = ctx.params.get(&format!("{linear}.lora_a"))?;
        let b = ctx.params.get(&format!("{linear}.lora_b"))?;
        let dxa = dy.matmul(&b.transpose2())?.scale(lc.scale);
        accumulate(
            grads,
            &format!("{linear}.lora_b"),
            lc.xa.transpose2().matmul(dy)?.scale(lc.scale),
        );
        accumulate(
            grads,
            &format!("{linear}.lora_a"),
            act.x.transpose2().matmul(&dxa)?,
        );
        // dL/dx = dy @ W^T + scaled low-rank path — W stays packed for
        // QLoRA (fused transposed matmul).
        w.matmul_t(dy)?.add(&dxa.matmul(&a.transpose2())?)
    }

    fn resolve_decode(
        &self,
        params: &Params,
        dims: &ModelDims,
        linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        Ok(Box::new(LoraDecode {
            a: params.get(&format!("{linear}.lora_a"))?.clone(),
            b: params.get(&format!("{linear}.lora_b"))?.clone(),
            scale: scale_of(dims),
            w: w.cloned(),
        }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// Additive fold: `W' = W + (alpha/r) A B`. `x @ W'` equals the
    /// adapted forward exactly up to f32 summation order.
    fn merge_linear(
        &self,
        linear: &str,
        w: &Tensor,
        trainables: &Params,
        dims: &ModelDims,
    ) -> Result<Tensor> {
        let a = trainables.get(&format!("{linear}.lora_a"))?;
        let b = trainables.get(&format!("{linear}.lora_b"))?;
        w.add(&a.matmul(b)?.scale(scale_of(dims)))
    }

    /// LoRA additionally keeps the low-rank activations `x A` per
    /// adapted linear alive for the backward.
    fn mem_transient(
        &self,
        spec: &ModelSpec,
        dims: &ModelDims,
        tokens: f64,
        act_bytes: f64,
        input_saves: f64,
    ) -> f64 {
        input_saves
            + tokens * dims.lora_r as f64 * spec.adapted_linears().count() as f64 * act_bytes
    }
}

struct LoraDecode {
    w: BaseWeight,
    a: Tensor,
    b: Tensor,
    scale: f32,
}

impl DecodeApply for LoraDecode {
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        let xa = x.matmul(&self.a)?;
        self.w.matmul(x)?.add(&xa.matmul(&self.b)?.scale(self.scale))
    }
}
