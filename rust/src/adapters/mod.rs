//! The open PEFT-adapter registry: one [`Adapter`] object per method.
//!
//! Method dispatch used to be a closed `Method` enum matched in eight
//! files (the runtime linear, the manifest synthesizer, the decode
//! resolver, the counting tables, the memory model, ...). This module
//! inverts that: each method is one self-contained module owning its
//!
//! * **parameter declaration** — the trainable [`ParamSpec`]s it adds
//!   per adapted linear ([`Adapter::linear_trainables`]), which drives
//!   both bundle synthesis and the paper's exact parameter counts;
//! * **runtime hooks** — per-linear forward/backward
//!   ([`Adapter::linear_forward`] / [`Adapter::linear_backward`]) and
//!   the per-step shared plan ([`Adapter::plan_linear`]);
//! * **decode resolution** — [`Adapter::resolve_decode`] builds the
//!   per-linear applier the KV-cached decoder and `serve` loop run;
//! * **memory pricing** — [`Adapter::mem_transient`] supplies the
//!   method-specific transient term of the analytic memory model.
//!
//! Adding a method is one new module plus one line in [`REGISTRY`]:
//! `Method::parse`-style spellings, manifest synthesis, CLI error
//! messages, bench tag lists, trainable-parameter counting, and the
//! memory tables all derive from the registry. BOFT and HOFT (this
//! PR) were added exactly that way — see README "Adding a PEFT
//! method".

pub mod boft;
pub mod full;
pub mod goft;
pub mod hoft;
pub mod lora;
pub mod none;
pub mod oft_merged;
pub mod oft_v2;
pub mod poft;
pub mod qlora;
pub mod qoft;

use std::any::Any;

use anyhow::{bail, Result};

use crate::coordinator::manifest::{ModelDims, ParamSpec};
use crate::modelspec::ModelSpec;
use crate::runtime::layers::{BaseWeight, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::scenario::{Knob, ScenarioCfg};
use crate::tensor::Tensor;

/// One per-linear entry of the per-step shared [`AdapterPlan`]
/// (adapter-defined payload, downcast by the owning module).
pub type PlanEntry = Box<dyn Any + Send + Sync>;

/// Adapter-defined extras of one linear's activation record.
pub type ActExtra = Box<dyn Any + Send>;

/// A resolved adapted linear for incremental decoding: built once per
/// adapter load ([`Adapter::resolve_decode`]), applied once per token
/// row. Implementations keep quantized bases packed.
pub trait DecodeApply: Send + Sync {
    /// Apply to a `(1, din)` activation row; must mirror the training
    /// forward's operation order so decode logits match bit for bit.
    fn apply(&self, x: &Tensor) -> Result<Tensor>;
}

/// One PEFT method. Implementations are stateless `'static` objects
/// registered in [`REGISTRY`]; everything per-run lives in the
/// parameter map, the activation records, and the per-step plan.
pub trait Adapter: Sync {
    /// Registry name — what bundle tags, manifests, and `--method`
    /// spellings use.
    fn name(&self) -> &'static str;

    /// One-line description for `repro methods` and the README table.
    fn about(&self) -> &'static str;

    /// Display label in the paper's tables (`quantized` selects the
    /// 4-bit sibling name where one exists, e.g. LoRA -> QLoRA).
    fn paper_label(&self, quantized: bool) -> &'static str;

    /// Every base parameter is trainable (full finetuning): manifest
    /// synthesis moves the whole base into the trainables, and the
    /// embedding/norm/head layers accumulate gradients.
    fn trains_base(&self) -> bool {
        false
    }

    /// The adapted base linears live behind quantized packs (NF4/AWQ),
    /// so bundles require a quant backend and the frozen f32 inputs
    /// exclude those linears.
    fn quantized_base(&self) -> bool {
        false
    }

    /// Validate model dims at manifest-synthesis time (e.g. block-size
    /// divisibility). Errors here name the constraint, not an index.
    fn validate_dims(&self, dims: &ModelDims) -> Result<()> {
        let _ = dims;
        Ok(())
    }

    /// The scenario knobs this method honors ([`crate::scenario::Knob`]).
    /// Drives the `repro methods` knob column and the default
    /// [`Adapter::configure`] validation; the default is none.
    fn supported_knobs(&self) -> &'static [Knob] {
        &[]
    }

    /// Accept or reject a [`ScenarioCfg`] at manifest-synthesis time.
    /// The default rejects any knob absent from
    /// [`Adapter::supported_knobs`] with a typed error naming the
    /// valid options; methods override to add cross-knob checks.
    fn configure(&self, sc: &ScenarioCfg) -> Result<()> {
        sc.validate_for(self.name(), self.supported_knobs())
    }

    /// Trainable parameter specs this method adds for one adapted
    /// linear of shape `(din, dout)`. The same declaration drives
    /// bundle synthesis AND exact parameter counting (Tables 3-5).
    fn linear_trainables(
        &self,
        linear: &str,
        din: usize,
        dout: usize,
        dims: &ModelDims,
    ) -> Vec<ParamSpec>;

    /// Per-step shared state for one adapted linear (CNP blocks,
    /// merged weights, normalized reflection vectors, ...), resolved
    /// once per step and read by every microbatch and worker.
    fn plan_linear(
        &self,
        linear: &str,
        params: &Params,
        dims: &ModelDims,
    ) -> Result<Option<PlanEntry>> {
        let _ = (linear, params, dims);
        Ok(None)
    }

    /// Forward through one adapted linear: `x (m, din) -> y (m, dout)`
    /// plus this method's activation extras (consumed by
    /// [`Adapter::linear_backward`]).
    fn linear_forward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)>;

    /// Backward through one adapted linear: accumulate this method's
    /// parameter gradients into `grads` and return `dL/dx`.
    fn linear_backward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor>;

    /// Resolve one adapted linear for KV-cached decoding (adapter
    /// state merged once at decoder build, applied per token).
    fn resolve_decode(
        &self,
        params: &Params,
        dims: &ModelDims,
        linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>>;

    /// Whether this method's adapter folds into the base weight as a
    /// plain dense matrix ([`Adapter::merge_linear`]). Drives the
    /// `repro methods` merge column and the `repro merge` lifecycle.
    fn can_merge(&self) -> bool {
        false
    }

    /// Fold the trained adapter of one linear into its base weight:
    /// returns the merged dense `(din, dout)` weight `W'` such that a
    /// plain `x @ W'` matmul reproduces this method's adapted forward.
    /// Orthogonal methods fold by rotation (`W' = R W`, `R` the dense
    /// input rotation), LoRA by addition (`W' = W + (alpha/r) A B`),
    /// `full`/`none` trivially (`W' = W`). `trainables` is the run's
    /// parameter map holding this method's per-linear tensors.
    fn merge_linear(
        &self,
        linear: &str,
        w: &Tensor,
        trainables: &Params,
        dims: &ModelDims,
    ) -> Result<Tensor> {
        let _ = (linear, w, trainables, dims);
        bail!(
            "method '{}' does not support merging (can_merge() is false)",
            self.name()
        )
    }

    /// Method-specific transient term of the analytic memory model
    /// (bytes): what training keeps alive beyond base/adapter/optimizer
    /// state. `input_saves` is the generic saved-input term every PEFT
    /// method pays for its adapter gradients; the default models an
    /// input-centric method that needs nothing else.
    fn mem_transient(
        &self,
        spec: &ModelSpec,
        dims: &ModelDims,
        tokens: f64,
        act_bytes: f64,
        input_saves: f64,
    ) -> f64 {
        let _ = (spec, dims, tokens, act_bytes);
        input_saves
    }
}

/// Every registered method, in manifest/tag order. Adding a method is
/// one module plus one line here.
pub static REGISTRY: [&dyn Adapter; 11] = [
    &full::FULL,
    &none::NONE,
    &lora::LORA,
    &oft_merged::OFT_MERGED,
    &oft_v2::OFT_V2,
    &qlora::QLORA,
    &qoft::QOFT,
    &boft::BOFT,
    &hoft::HOFT,
    &goft::GOFT,
    &poft::POFT,
];

/// All registered adapters.
pub fn all() -> &'static [&'static dyn Adapter] {
    &REGISTRY
}

/// Registered method names, in registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|a| a.name()).collect()
}

/// Look a method up by name; unknown names list the whole registry.
pub fn get(name: &str) -> Result<&'static dyn Adapter> {
    for a in REGISTRY {
        if a.name() == name {
            return Ok(a);
        }
    }
    bail!(
        "unknown method '{name}'; registered methods: {}",
        names().join(", ")
    )
}

/// The default bundle tag of `method` on `preset` (quantized methods
/// get the NF4 backend).
pub fn bundle_tag(preset: &str, adapter: &dyn Adapter) -> String {
    if adapter.quantized_base() {
        format!("{preset}_{}_nf4", adapter.name())
    } else {
        format!("{preset}_{}", adapter.name())
    }
}

/// One default bundle tag per registered method — what the
/// all-methods tests and benches iterate instead of hard-coded lists.
pub fn bundle_tags(preset: &str) -> Vec<String> {
    REGISTRY.iter().map(|a| bundle_tag(preset, *a)).collect()
}

// ---------------------------------------------------------------------------
// Shared building blocks for the method modules
// ---------------------------------------------------------------------------

/// The no-adapter decode path shared by `full` / `none`: the (possibly
/// packed) base matmul alone.
pub(crate) struct PlainDecode {
    pub w: BaseWeight,
}

impl DecodeApply for PlainDecode {
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        self.w.matmul(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_resolvable() {
        let names = names();
        for (i, n) in names.iter().enumerate() {
            assert!(!names[..i].contains(n), "duplicate method name '{n}'");
            assert_eq!(get(n).unwrap().name(), *n);
        }
        assert!(names.contains(&"boft") && names.contains(&"hoft"));
        assert!(names.contains(&"goft") && names.contains(&"poft"));
    }

    #[test]
    fn unknown_method_error_lists_registry() {
        let err = match get("bogus") {
            Err(e) => format!("{e:#}"),
            Ok(a) => panic!("bogus resolved to '{}'", a.name()),
        };
        for n in names() {
            assert!(err.contains(n), "error should list '{n}': {err}");
        }
    }

    #[test]
    fn bundle_tags_use_nf4_for_quantized_methods() {
        let tags = bundle_tags("tiny");
        assert!(tags.contains(&"tiny_oft_v2".to_string()));
        assert!(tags.contains(&"tiny_qoft_nf4".to_string()));
        assert!(tags.contains(&"tiny_boft".to_string()));
        assert!(tags.contains(&"tiny_hoft".to_string()));
        assert_eq!(tags.len(), REGISTRY.len());
    }
}
