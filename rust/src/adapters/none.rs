//! The frozen baseline: no trainable parameters at all. Useful as the
//! base-contract bundle (`<preset>_none` lists every base parameter as
//! frozen) and as the eval-only control.

use anyhow::Result;

use super::{ActExtra, Adapter, DecodeApply, PlainDecode};
use crate::coordinator::manifest::{ModelDims, ParamSpec};
use crate::runtime::layers::{Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::tensor::Tensor;

pub struct NoneMethod;

/// Registry object.
pub static NONE: NoneMethod = NoneMethod;

impl Adapter for NoneMethod {
    fn name(&self) -> &'static str {
        "none"
    }

    fn about(&self) -> &'static str {
        "frozen base: no trainable parameters (eval-only control)"
    }

    fn paper_label(&self, _quantized: bool) -> &'static str {
        "Frozen"
    }

    fn linear_trainables(
        &self,
        _linear: &str,
        _din: usize,
        _dout: usize,
        _dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        Vec::new()
    }

    fn linear_forward(
        &self,
        _ctx: &Ctx,
        _linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        Ok((w.matmul(x)?, None))
    }

    fn linear_backward(
        &self,
        _ctx: &Ctx,
        _linear: &str,
        w: WeightRef,
        _act: &LinearAct,
        dy: &Tensor,
        _grads: &mut Gradients,
    ) -> Result<Tensor> {
        w.matmul_t(dy)
    }

    fn resolve_decode(
        &self,
        _params: &Params,
        _dims: &ModelDims,
        _linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        Ok(Box::new(PlainDecode { w: w.cloned() }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// Nothing to fold: the frozen base is already the deployed weight.
    fn merge_linear(
        &self,
        _linear: &str,
        w: &Tensor,
        _trainables: &Params,
        _dims: &ModelDims,
    ) -> Result<Tensor> {
        Ok(w.clone())
    }
}
