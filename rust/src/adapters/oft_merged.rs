//! Weight-centric OFT — the paper's baseline (eq. 1): materialize
//! `blockdiag(R)` and pay the cubic merge `R W` per adapted linear per
//! step. Kept deliberately expensive so the timing and memory
//! comparisons against the input-centric reformulation stay honest.
//! Never quantized by construction (the merge needs the dense base).

use anyhow::Result;

use super::oft_v2::{
    cnp_blocks_for, eff_block, ensure_blocks_divide, packed_grad, packed_name, packed_spec,
    CNP_KNOBS,
};
use super::{ActExtra, Adapter, DecodeApply};
use crate::coordinator::manifest::{ModelDims, ParamSpec};
use crate::peft;
use crate::runtime::layers::{accumulate, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::modelspec::ModelSpec;
use crate::scenario::Knob;
use crate::tensor::Tensor;

pub struct WeightCentricOft;

/// Registry object.
pub static OFT_MERGED: WeightCentricOft = WeightCentricOft;

/// Per-step plan entry: the merged `blockdiag(R) @ W` (built once per
/// step, shared read-only).
struct MergedPlan {
    rw: Tensor,
}

/// Merged weight built inline (no shared plan).
struct MergedAct {
    rw: Tensor,
}

fn merge(params: &Params, dims: &ModelDims, linear: &str, w: &Tensor) -> Result<Tensor> {
    let packed = params.get(&packed_name(linear))?;
    let blocks = cnp_blocks_for(packed, w.shape[0], dims)?;
    let rd = peft::blockdiag_dense(&blocks, w.shape[0]);
    rd.matmul(w)
}

impl Adapter for WeightCentricOft {
    fn name(&self) -> &'static str {
        "oft_merged"
    }

    fn about(&self) -> &'static str {
        "weight-centric OFT baseline: cubic blockdiag(R) @ W merge per step"
    }

    fn paper_label(&self, _quantized: bool) -> &'static str {
        "OFT"
    }

    fn validate_dims(&self, dims: &ModelDims) -> Result<()> {
        ensure_blocks_divide("oft_merged", dims)
    }

    fn supported_knobs(&self) -> &'static [Knob] {
        &CNP_KNOBS
    }

    fn linear_trainables(
        &self,
        linear: &str,
        din: usize,
        _dout: usize,
        dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        vec![packed_spec(linear, din, dims)]
    }

    fn plan_linear(
        &self,
        linear: &str,
        params: &Params,
        dims: &ModelDims,
    ) -> Result<Option<super::PlanEntry>> {
        let w = params.get(linear)?;
        Ok(Some(Box::new(MergedPlan {
            rw: merge(params, dims, linear, w)?,
        })))
    }

    fn linear_forward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        match ctx.plan.and_then(|p| p.get::<MergedPlan>(linear)) {
            Some(plan) => Ok((x.matmul(&plan.rw)?, None)),
            None => {
                let rw = merge(ctx.params, ctx.dims, linear, w.dense()?)?;
                let y = x.matmul(&rw)?;
                Ok((y, Some(Box::new(MergedAct { rw }))))
            }
        }
    }

    fn linear_backward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let w = w.dense()?;
        let din = w.shape[0];
        let blk = eff_block(din, ctx.dims);
        let packed = ctx.params.get(&packed_name(linear))?;
        let rw = match ctx.plan.and_then(|p| p.get::<MergedPlan>(linear)) {
            Some(plan) => &plan.rw,
            None => &act.extra::<MergedAct>()?.rw,
        };
        let dm = act.x.transpose2().matmul(dy)?; // (din, dout)
        let nb = din / blk;
        let dout = w.shape[1];
        let mut dr = Vec::with_capacity(nb);
        for bi in 0..nb {
            let dm_b = Tensor::from_vec(
                &[blk, dout],
                dm.data[bi * blk * dout..(bi + 1) * blk * dout].to_vec(),
            );
            let w_b = Tensor::from_vec(
                &[blk, dout],
                w.data[bi * blk * dout..(bi + 1) * blk * dout].to_vec(),
            );
            dr.push(dm_b.matmul(&w_b.transpose2())?);
        }
        let dp = packed_grad(packed, din, ctx.dims, dr)?;
        accumulate(grads, &packed_name(linear), dp);
        dy.matmul(&rw.transpose2())
    }

    fn resolve_decode(
        &self,
        params: &Params,
        dims: &ModelDims,
        linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        // Decoding re-pays the merge per adapter, not per token.
        Ok(Box::new(MergedDecode {
            rw: merge(params, dims, linear, w.dense()?)?,
        }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// The method's own per-step merge, exported: `W' = blockdiag(R) W`.
    fn merge_linear(
        &self,
        linear: &str,
        w: &Tensor,
        trainables: &Params,
        dims: &ModelDims,
    ) -> Result<Tensor> {
        merge(trainables, dims, linear, w)
    }

    /// The paper's memory cliff: the materialized `blockdiag(R)`
    /// (din x din) plus the merged weight `R W` (din x dout) per
    /// adapted linear, kept alive by autograd for the backward.
    fn mem_transient(
        &self,
        spec: &ModelSpec,
        _dims: &ModelDims,
        _tokens: f64,
        act_bytes: f64,
        input_saves: f64,
    ) -> f64 {
        input_saves
            + spec
                .adapted_linears()
                .map(|li| (li.din * li.din + li.din * li.dout) as f64 * act_bytes)
                .sum::<f64>()
    }
}

struct MergedDecode {
    rw: Tensor,
}

impl DecodeApply for MergedDecode {
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        x.matmul(&self.rw)
    }
}
