//! Input-centric orthogonal finetuning (OFTv2, §3 of the paper): the
//! token activations are rotated block-by-block through Cayley–Neumann
//! orthogonal blocks before the frozen base matmul — quadratic work,
//! no merged weight ever materialized. One struct serves both the
//! full-precision (`oft_v2`) and quantized (`qoft`) registrations.

use anyhow::{ensure, Result};

use super::{ActExtra, Adapter, DecodeApply};
use crate::coordinator::manifest::{Init, ModelDims, ParamSpec};
use crate::runtime::layers::linear::{
    block_rotate_fast, block_rotate_grad_r, block_rotate_transposed, build_cnp_blocks,
    cnp_backward_all,
};
use crate::runtime::layers::{accumulate, BaseWeight, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::scenario::Knob;
use crate::tensor::Tensor;

pub struct InputCentricOft {
    pub name: &'static str,
    pub quantized: bool,
}

/// Registry object (full-precision base).
pub static OFT_V2: InputCentricOft = InputCentricOft {
    name: "oft_v2",
    quantized: false,
};

/// Per-step plan entry: this linear's CNP rotation blocks, built once
/// and shared read-only by every microbatch and worker.
pub(crate) struct CnpPlan {
    pub blocks: Vec<Tensor>,
}

/// Activation extras when the step has no shared plan: the blocks
/// built inline by the forward.
struct OftAct {
    blocks: Vec<Tensor>,
}

pub(crate) fn packed_name(linear: &str) -> String {
    format!("{linear}.oft_q")
}

/// Effective rotation-block size for a linear of input width `din`:
/// the scenario's `r` knob fixes the *number* of blocks per linear
/// (PEFT's `OFTConfig.r`, so `b = din / r` varies with the linear),
/// otherwise the preset / `block`-knob block size applies uniformly.
pub(crate) fn eff_block(din: usize, dims: &ModelDims) -> usize {
    if dims.scenario.oft_r > 0 {
        din / dims.scenario.oft_r
    } else {
        dims.block_b
    }
}

/// The one trainable tensor of an OFT-family linear: packed
/// skew-symmetric rows, one per b-wide input block (§3.3 storage) —
/// or a single shared row under the `block_share` scenario knob.
pub(crate) fn packed_spec(linear: &str, din: usize, dims: &ModelDims) -> ParamSpec {
    let b = eff_block(din, dims);
    let rows = if dims.scenario.block_share { 1 } else { din / b };
    ParamSpec {
        name: packed_name(linear),
        shape: vec![rows, b * (b - 1) / 2],
        init: Init::Zeros,
    }
}

/// Resolve a linear's packed parameter into its CNP rotation blocks,
/// honoring the scenario's `r`/`block_share` knobs: under block_share
/// the single stored block is reused for every b-wide input span.
pub(crate) fn cnp_blocks_for(packed: &Tensor, din: usize, dims: &ModelDims) -> Result<Vec<Tensor>> {
    let b = eff_block(din, dims);
    let blocks = build_cnp_blocks(packed, b, dims.neumann_k)?;
    let nb = din / b;
    if dims.scenario.block_share && nb > 1 {
        ensure!(
            blocks.len() == 1,
            "block_share expects one shared block row, got {}",
            blocks.len()
        );
        let shared = blocks.into_iter().next().unwrap();
        return Ok(vec![shared; nb]);
    }
    Ok(blocks)
}

/// Turn per-block rotation cotangents into the packed-parameter
/// gradient: under `block_share` every block reads the same stored
/// row, so the per-block `dR`s sum before the CNP backward.
pub(crate) fn packed_grad(
    packed: &Tensor,
    din: usize,
    dims: &ModelDims,
    dr: Vec<Tensor>,
) -> Result<Tensor> {
    let b = eff_block(din, dims);
    if dims.scenario.block_share && dr.len() > 1 {
        let mut sum = dr[0].clone();
        for t in &dr[1..] {
            for (a, v) in sum.data.iter_mut().zip(&t.data) {
                *a += v;
            }
        }
        return cnp_backward_all(packed, b, dims.neumann_k, &[sum]);
    }
    cnp_backward_all(packed, b, dims.neumann_k, &dr)
}

pub(crate) fn ensure_blocks_divide(name: &str, dims: &ModelDims) -> Result<()> {
    if dims.scenario.oft_r > 0 {
        let r = dims.scenario.oft_r;
        ensure!(
            dims.d_model % r == 0 && dims.d_ff % r == 0,
            "{name}: scenario 'r' = {r} rotation blocks must divide d_model {} and d_ff {}",
            dims.d_model,
            dims.d_ff
        );
        ensure!(
            dims.d_model / r >= 2 && dims.d_ff / r >= 2,
            "{name}: scenario 'r' = {r} leaves rotation blocks narrower than 2 \
             (d_model {}, d_ff {})",
            dims.d_model,
            dims.d_ff
        );
        return Ok(());
    }
    ensure!(
        dims.d_model % dims.block_b == 0 && dims.d_ff % dims.block_b == 0,
        "{name}: block size {} must divide d_model {} and d_ff {}",
        dims.block_b,
        dims.d_model,
        dims.d_ff
    );
    Ok(())
}

/// The full scenario surface of the Cayley–Neumann block-rotation
/// family (shared by `oft_v2`, `qoft`, and `oft_merged`).
pub(crate) const CNP_KNOBS: [Knob; 8] = [
    Knob::Coft,
    Knob::Eps,
    Knob::ModuleDropout,
    Knob::BlockShare,
    Knob::R,
    Knob::BlockSize,
    Knob::Target,
    Knob::Exclude,
];

impl Adapter for InputCentricOft {
    fn name(&self) -> &'static str {
        self.name
    }

    fn about(&self) -> &'static str {
        if self.quantized {
            "input-centric OFTv2 over an NF4/AWQ-packed frozen base (QOFT)"
        } else {
            "input-centric OFTv2: matrix-free CNP block rotation"
        }
    }

    fn paper_label(&self, quantized: bool) -> &'static str {
        if self.quantized || quantized {
            "QOFT"
        } else {
            "OFTv2"
        }
    }

    fn quantized_base(&self) -> bool {
        self.quantized
    }

    fn validate_dims(&self, dims: &ModelDims) -> Result<()> {
        ensure_blocks_divide(self.name, dims)
    }

    fn supported_knobs(&self) -> &'static [Knob] {
        &CNP_KNOBS
    }

    fn linear_trainables(
        &self,
        linear: &str,
        din: usize,
        _dout: usize,
        dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        vec![packed_spec(linear, din, dims)]
    }

    fn plan_linear(
        &self,
        linear: &str,
        params: &Params,
        dims: &ModelDims,
    ) -> Result<Option<super::PlanEntry>> {
        let packed = params.get(&packed_name(linear))?;
        let (din, _) = params.weight(linear)?.shape2();
        let blocks = cnp_blocks_for(packed, din, dims)?;
        Ok(Some(Box::new(CnpPlan { blocks })))
    }

    fn linear_forward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        match ctx.plan.and_then(|p| p.get::<CnpPlan>(linear)) {
            Some(plan) => Ok((w.matmul(&block_rotate_fast(x, &plan.blocks)?)?, None)),
            None => {
                let packed = ctx.params.get(&packed_name(linear))?;
                let (din, _) = w.shape2();
                let blocks = cnp_blocks_for(packed, din, ctx.dims)?;
                let y = w.matmul(&block_rotate_fast(x, &blocks)?)?;
                Ok((y, Some(Box::new(OftAct { blocks }))))
            }
        }
    }

    fn linear_backward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let (din, _) = w.shape2();
        let blk = eff_block(din, ctx.dims);
        let packed = ctx.params.get(&packed_name(linear))?;
        let blocks = match ctx.plan.and_then(|p| p.get::<CnpPlan>(linear)) {
            Some(plan) => &plan.blocks,
            None => &act.extra::<OftAct>()?.blocks,
        };
        let dz = w.matmul_t(dy)?;
        let dr = block_rotate_grad_r(&act.x, &dz, blk);
        let dp = packed_grad(packed, din, ctx.dims, dr)?;
        accumulate(grads, &packed_name(linear), dp);
        block_rotate_transposed(&dz, blocks)
    }

    fn resolve_decode(
        &self,
        params: &Params,
        dims: &ModelDims,
        linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        let packed = params.get(&packed_name(linear))?;
        let (din, _) = w.shape2();
        let blocks = cnp_blocks_for(packed, din, dims)?;
        Ok(Box::new(RotateDecode { w: w.cloned(), blocks }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// Fold by rotation: `W' = blockdiag(R) W`, so a plain `x @ W'`
    /// equals `block_rotate(x) @ W` (`block_rotate(x) = x blockdiag(R)`
    /// — the input-centric rotation is linear on rows). The spectrum of
    /// `W` is preserved (orthogonal left factor), the §4 requant story.
    fn merge_linear(
        &self,
        linear: &str,
        w: &Tensor,
        trainables: &Params,
        dims: &ModelDims,
    ) -> Result<Tensor> {
        let packed = trainables.get(&packed_name(linear))?;
        let blocks = cnp_blocks_for(packed, w.shape[0], dims)?;
        crate::peft::blockdiag_dense(&blocks, w.shape[0]).matmul(w)
    }
}

/// Decode applier: rotate the token's activations block-by-block, then
/// the frozen (possibly packed) matmul — matrix-free, §3.
struct RotateDecode {
    w: BaseWeight,
    blocks: Vec<Tensor>,
}

impl DecodeApply for RotateDecode {
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        self.w.matmul(&block_rotate_fast(x, &self.blocks)?)
    }
}
