//! Input-centric orthogonal finetuning (OFTv2, §3 of the paper): the
//! token activations are rotated block-by-block through Cayley–Neumann
//! orthogonal blocks before the frozen base matmul — quadratic work,
//! no merged weight ever materialized. One struct serves both the
//! full-precision (`oft_v2`) and quantized (`qoft`) registrations.

use anyhow::{ensure, Result};

use super::{ActExtra, Adapter, DecodeApply};
use crate::coordinator::manifest::{Init, ModelDims, ParamSpec};
use crate::runtime::layers::linear::{
    block_rotate_fast, block_rotate_grad_r, block_rotate_transposed, build_cnp_blocks,
    cnp_backward_all,
};
use crate::runtime::layers::{accumulate, BaseWeight, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::tensor::Tensor;

pub struct InputCentricOft {
    pub name: &'static str,
    pub quantized: bool,
}

/// Registry object (full-precision base).
pub static OFT_V2: InputCentricOft = InputCentricOft {
    name: "oft_v2",
    quantized: false,
};

/// Per-step plan entry: this linear's CNP rotation blocks, built once
/// and shared read-only by every microbatch and worker.
pub(crate) struct CnpPlan {
    pub blocks: Vec<Tensor>,
}

/// Activation extras when the step has no shared plan: the blocks
/// built inline by the forward.
struct OftAct {
    blocks: Vec<Tensor>,
}

pub(crate) fn packed_name(linear: &str) -> String {
    format!("{linear}.oft_q")
}

/// The one trainable tensor of an OFT-family linear: packed
/// skew-symmetric rows, one per b-wide input block (§3.3 storage).
pub(crate) fn packed_spec(linear: &str, din: usize, dims: &ModelDims) -> ParamSpec {
    let b = dims.block_b;
    ParamSpec {
        name: packed_name(linear),
        shape: vec![din / b, b * (b - 1) / 2],
        init: Init::Zeros,
    }
}

pub(crate) fn ensure_blocks_divide(name: &str, dims: &ModelDims) -> Result<()> {
    ensure!(
        dims.d_model % dims.block_b == 0 && dims.d_ff % dims.block_b == 0,
        "{name}: block size {} must divide d_model {} and d_ff {}",
        dims.block_b,
        dims.d_model,
        dims.d_ff
    );
    Ok(())
}

impl Adapter for InputCentricOft {
    fn name(&self) -> &'static str {
        self.name
    }

    fn about(&self) -> &'static str {
        if self.quantized {
            "input-centric OFTv2 over an NF4/AWQ-packed frozen base (QOFT)"
        } else {
            "input-centric OFTv2: matrix-free CNP block rotation"
        }
    }

    fn paper_label(&self, quantized: bool) -> &'static str {
        if self.quantized || quantized {
            "QOFT"
        } else {
            "OFTv2"
        }
    }

    fn quantized_base(&self) -> bool {
        self.quantized
    }

    fn validate_dims(&self, dims: &ModelDims) -> Result<()> {
        ensure_blocks_divide(self.name, dims)
    }

    fn linear_trainables(
        &self,
        linear: &str,
        din: usize,
        _dout: usize,
        dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        vec![packed_spec(linear, din, dims)]
    }

    fn plan_linear(
        &self,
        linear: &str,
        params: &Params,
        dims: &ModelDims,
    ) -> Result<Option<super::PlanEntry>> {
        let packed = params.get(&packed_name(linear))?;
        let blocks = build_cnp_blocks(packed, dims.block_b, dims.neumann_k)?;
        Ok(Some(Box::new(CnpPlan { blocks })))
    }

    fn linear_forward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        match ctx.plan.and_then(|p| p.get::<CnpPlan>(linear)) {
            Some(plan) => Ok((w.matmul(&block_rotate_fast(x, &plan.blocks)?)?, None)),
            None => {
                let packed = ctx.params.get(&packed_name(linear))?;
                let blocks = build_cnp_blocks(packed, ctx.dims.block_b, ctx.dims.neumann_k)?;
                let y = w.matmul(&block_rotate_fast(x, &blocks)?)?;
                Ok((y, Some(Box::new(OftAct { blocks }))))
            }
        }
    }

    fn linear_backward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let blk = ctx.dims.block_b;
        let packed = ctx.params.get(&packed_name(linear))?;
        let blocks = match ctx.plan.and_then(|p| p.get::<CnpPlan>(linear)) {
            Some(plan) => &plan.blocks,
            None => &act.extra::<OftAct>()?.blocks,
        };
        let dz = w.matmul_t(dy)?;
        let dr = block_rotate_grad_r(&act.x, &dz, blk);
        let dp = cnp_backward_all(packed, blk, ctx.dims.neumann_k, &dr)?;
        accumulate(grads, &packed_name(linear), dp);
        block_rotate_transposed(&dz, blocks)
    }

    fn resolve_decode(
        &self,
        params: &Params,
        dims: &ModelDims,
        linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        let packed = params.get(&packed_name(linear))?;
        let blocks = build_cnp_blocks(packed, dims.block_b, dims.neumann_k)?;
        Ok(Box::new(RotateDecode { w: w.cloned(), blocks }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// Fold by rotation: `W' = blockdiag(R) W`, so a plain `x @ W'`
    /// equals `block_rotate(x) @ W` (`block_rotate(x) = x blockdiag(R)`
    /// — the input-centric rotation is linear on rows). The spectrum of
    /// `W` is preserved (orthogonal left factor), the §4 requant story.
    fn merge_linear(
        &self,
        linear: &str,
        w: &Tensor,
        trainables: &Params,
        dims: &ModelDims,
    ) -> Result<Tensor> {
        let packed = trainables.get(&packed_name(linear))?;
        let blocks = build_cnp_blocks(packed, dims.block_b, dims.neumann_k)?;
        crate::peft::blockdiag_dense(&blocks, w.shape[0]).matmul(w)
    }
}

/// Decode applier: rotate the token's activations block-by-block, then
/// the frozen (possibly packed) matmul — matrix-free, §3.
struct RotateDecode {
    w: BaseWeight,
    blocks: Vec<Tensor>,
}

impl DecodeApply for RotateDecode {
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        self.w.matmul(&block_rotate_fast(x, &self.blocks)?)
    }
}
