//! POFT: principal-subspace orthogonal adaptation (PSOA, per
//! PAPERS.md "Efficient Orthogonal Fine-Tuning with Principal Subspace
//! Adaptation") as a runtime method. Instead of rotating all `din`
//! input coordinates, POFT rotates only a fixed `k`-dimensional
//! subspace:
//!
//! ```text
//!   A = I + U (C - I) U^T
//! ```
//!
//! with `U` a frozen `din x k` orthonormal basis (deterministically
//! derived from the linear's name — every worker, checkpoint resume,
//! and decode session reconstructs the same subspace) and `C` a `k x k`
//! Cayley–Neumann rotation from `k(k-1)/2` trainable packed skew
//! parameters. On the subspace `A` acts as `C`; on its orthogonal
//! complement `A` is the identity, so `A` is orthogonal exactly as far
//! as `C` is (the documented CNP truncation tolerance) at a parameter
//! cost independent of `din`.
//!
//! **Identity at init.** `Q = 0` gives `C = I`, hence `A = I`: the
//! adapted model starts exactly at the pretrained base.

use anyhow::{ensure, Result};

use super::{ActExtra, Adapter, DecodeApply};
use crate::coordinator::manifest::{Init, ModelDims, ParamSpec};
use crate::runtime::layers::linear::{build_cnp_blocks, cnp_backward_all};
use crate::runtime::layers::{accumulate, BaseWeight, Ctx, Gradients, LinearAct, Params, WeightRef};
use crate::scenario::Knob;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct Poft;

/// Registry object.
pub static POFT: Poft = Poft;

/// Subspace rank per adapted linear: the bundle's LoRA rank, at least
/// 2 (a 1-dimensional rotation has no skew parameters).
pub fn rank(dims: &ModelDims) -> usize {
    dims.lora_r.max(2)
}

fn param_name(linear: &str) -> String {
    format!("{linear}.poft_q")
}

/// FNV-1a over the linear's name: gives every linear an independent,
/// order-free subspace stream (same scheme as parameter init).
fn name_seed(linear: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for byte in linear.as_bytes() {
        h ^= *byte as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// The frozen orthonormal basis `U (din, k)` of one linear:
/// name-seeded Gaussian columns, modified Gram–Schmidt. Deterministic
/// in (linear, din, k).
fn subspace(linear: &str, din: usize, k: usize) -> Tensor {
    let mut rng = Rng::new(0x905F_7A57 ^ name_seed(linear));
    let mut cols: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(din, 1.0)).collect();
    for i in 0..k {
        for j in 0..i {
            let prev = cols[j].clone();
            let dot: f32 = cols[i].iter().zip(&prev).map(|(a, b)| a * b).sum();
            for (xi, pj) in cols[i].iter_mut().zip(&prev) {
                *xi -= dot * pj;
            }
        }
        let norm = cols[i].iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
        for x in &mut cols[i] {
            *x /= norm;
        }
    }
    let mut u = vec![0f32; din * k];
    for (i, col) in cols.iter().enumerate() {
        for (t, v) in col.iter().enumerate() {
            u[t * k + i] = *v;
        }
    }
    Tensor::from_vec(&[din, k], u)
}

/// One linear's resolved adapter: the basis, its transpose, and
/// `D = C - I`.
struct Resolved {
    u: Tensor,
    ut: Tensor,
    d: Tensor,
}

/// Per-step plan entry (also rebuilt inline when the step has no
/// shared plan — deterministic, so the rebuild is bitwise identical).
struct PoftPlan {
    r: Resolved,
}

fn resolve(packed: &Tensor, linear: &str, din: usize, dims: &ModelDims) -> Result<Resolved> {
    let k = rank(dims);
    ensure!(
        k <= din,
        "POFT rank {k} exceeds the input width {din} of '{linear}'"
    );
    ensure!(
        packed.shape.len() == 2 && packed.shape[0] == 1 && packed.shape[1] == k * (k - 1) / 2,
        "POFT parameter of '{linear}' must be (1, {}), got {:?}",
        k * (k - 1) / 2,
        packed.shape
    );
    let blocks = build_cnp_blocks(packed, k, dims.neumann_k)?;
    let c = blocks.into_iter().next().expect("one packed row, one block");
    let d = c.add(&Tensor::eye(k).scale(-1.0))?;
    let u = subspace(linear, din, k);
    let ut = u.transpose2();
    Ok(Resolved { u, ut, d })
}

/// `rot(x) = x + ((x U) D) U^T` — rows pass through except for their
/// subspace component, which `C` rotates.
fn rotate(x: &Tensor, r: &Resolved) -> Result<Tensor> {
    x.add(&x.matmul(&r.u)?.matmul(&r.d)?.matmul(&r.ut)?)
}

impl Adapter for Poft {
    fn name(&self) -> &'static str {
        "poft"
    }

    fn about(&self) -> &'static str {
        "principal-subspace orthogonal adaptation: k-dim CNP rotation in a frozen basis"
    }

    fn paper_label(&self, _quantized: bool) -> &'static str {
        "POFT"
    }

    fn validate_dims(&self, dims: &ModelDims) -> Result<()> {
        let k = rank(dims);
        ensure!(
            k <= dims.d_model && k <= dims.d_ff,
            "poft: subspace rank {k} must fit every linear (d_model {}, d_ff {})",
            dims.d_model,
            dims.d_ff
        );
        Ok(())
    }

    /// The subspace rank is fixed by the bundle's LoRA rank
    /// (`r`/`block`/`block_share` are block-rotation knobs); the packed
    /// skew is zero at identity, so COFT and dropout compose naturally.
    fn supported_knobs(&self) -> &'static [Knob] {
        &[
            Knob::Coft,
            Knob::Eps,
            Knob::ModuleDropout,
            Knob::Target,
            Knob::Exclude,
        ]
    }

    fn linear_trainables(
        &self,
        linear: &str,
        _din: usize,
        _dout: usize,
        dims: &ModelDims,
    ) -> Vec<ParamSpec> {
        let k = rank(dims);
        vec![ParamSpec {
            name: param_name(linear),
            shape: vec![1, k * (k - 1) / 2],
            init: Init::Zeros,
        }]
    }

    fn plan_linear(
        &self,
        linear: &str,
        params: &Params,
        dims: &ModelDims,
    ) -> Result<Option<super::PlanEntry>> {
        let packed = params.get(&param_name(linear))?;
        let (din, _) = params.weight(linear)?.shape2();
        Ok(Some(Box::new(PoftPlan {
            r: resolve(packed, linear, din, dims)?,
        })))
    }

    fn linear_forward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        x: &Tensor,
    ) -> Result<(Tensor, Option<ActExtra>)> {
        let rotated = match ctx.plan.and_then(|p| p.get::<PoftPlan>(linear)) {
            Some(plan) => rotate(x, &plan.r)?,
            None => {
                let packed = ctx.params.get(&param_name(linear))?;
                let (din, _) = w.shape2();
                rotate(x, &resolve(packed, linear, din, ctx.dims)?)?
            }
        };
        Ok((w.matmul(&rotated)?, None))
    }

    fn linear_backward(
        &self,
        ctx: &Ctx,
        linear: &str,
        w: WeightRef,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let packed = ctx.params.get(&param_name(linear))?;
        let (din, _) = w.shape2();
        let k = rank(ctx.dims);
        // The resolve is deterministic, so rebuilding when no shared
        // plan exists reproduces the forward's values bit for bit.
        let rebuilt;
        let r: &Resolved = match ctx.plan.and_then(|p| p.get::<PoftPlan>(linear)) {
            Some(plan) => &plan.r,
            None => {
                rebuilt = resolve(packed, linear, din, ctx.dims)?;
                &rebuilt
            }
        };
        let dz = w.matmul_t(dy)?;
        // dC = (x U)^T (dz U); dQ through the shared CNP backward.
        let p = act.x.matmul(&r.u)?;
        let dzu = dz.matmul(&r.u)?;
        let dc = p.transpose2().matmul(&dzu)?;
        let dq = cnp_backward_all(packed, k, ctx.dims.neumann_k, &[dc])?;
        accumulate(grads, &param_name(linear), dq);
        // dx = dz + (dz U) D^T U^T
        dz.add(&dzu.matmul(&r.d.transpose2())?.matmul(&r.ut)?)
    }

    fn resolve_decode(
        &self,
        params: &Params,
        dims: &ModelDims,
        linear: &str,
        w: WeightRef,
    ) -> Result<Box<dyn DecodeApply>> {
        let packed = params.get(&param_name(linear))?;
        let (din, _) = w.shape2();
        Ok(Box::new(PoftDecode {
            w: w.cloned(),
            r: resolve(packed, linear, din, dims)?,
        }))
    }

    fn can_merge(&self) -> bool {
        true
    }

    /// Fold the subspace rotation: `rot(x) = x (I + U D U^T)`, so
    /// `W' = (I + U D U^T) W`.
    fn merge_linear(
        &self,
        linear: &str,
        w: &Tensor,
        trainables: &Params,
        dims: &ModelDims,
    ) -> Result<Tensor> {
        let packed = trainables.get(&param_name(linear))?;
        let din = w.shape[0];
        let r = resolve(packed, linear, din, dims)?;
        let m = Tensor::eye(din).add(&r.u.matmul(&r.d)?.matmul(&r.ut)?)?;
        m.matmul(w)
    }
}

struct PoftDecode {
    w: BaseWeight,
    r: Resolved,
}

impl DecodeApply for PoftDecode {
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        self.w.matmul(&rotate(x, &self.r)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::orthogonality_error;
    use crate::util::rng::Rng;

    fn dims(k: usize, neumann: usize) -> ModelDims {
        let mut d = ModelDims::analysis(k, 16);
        d.neumann_k = neumann;
        d
    }

    fn random_packed(k: usize, std: f32, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(&[1, k * (k - 1) / 2], std, &mut rng)
    }

    fn dense_rotation(linear: &str, packed: &Tensor, din: usize, d: &ModelDims) -> Tensor {
        let r = resolve(packed, linear, din, d).unwrap();
        rotate(&Tensor::eye(din), &r).unwrap()
    }

    #[test]
    fn subspace_is_orthonormal_and_deterministic() {
        let u = subspace("layers.0.attn.wq", 64, 4);
        assert_eq!(u.shape, vec![64, 4]);
        let gram = u.transpose2().matmul(&u).unwrap();
        assert!(gram.max_abs_diff(&Tensor::eye(4)) < 1e-5);
        assert!(u.max_abs_diff(&subspace("layers.0.attn.wq", 64, 4)) == 0.0);
        assert!(u.max_abs_diff(&subspace("layers.0.attn.wk", 64, 4)) > 1e-3);
    }

    #[test]
    fn adapter_is_orthogonal_to_cnp_tolerance() {
        // A = I + U(C-I)U^T is orthogonal exactly as far as C is: at
        // the documented operating point (small Q, k >= 6 Neumann
        // terms) ||A^T A - I||_F stays below 5e-3.
        let d = dims(4, 8);
        for seed in 0..3u64 {
            let packed = random_packed(4, 0.05, seed);
            let a = dense_rotation("layers.0.attn.wq", &packed, 64, &d);
            let err = orthogonality_error(&a);
            assert!(err < 5e-3, "seed={seed}: err {err}");
        }
    }

    #[test]
    fn identity_at_zero_parameters() {
        let d = dims(4, 5);
        let packed = Tensor::zeros(&[1, 6]);
        let r = resolve(&packed, "layers.1.mlp.up", 64, &d).unwrap();
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[5, 64], 1.0, &mut rng);
        let y = rotate(&x, &r).unwrap();
        assert!(y.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn complement_passes_through_untouched() {
        // A row orthogonal to the subspace must be a fixed point of the
        // rotation even at large parameters.
        let d = dims(2, 8);
        let packed = random_packed(2, 0.5, 7);
        let r = resolve(&packed, "layers.0.attn.wo", 16, &d).unwrap();
        // build a vector orthogonal to both basis columns
        let mut rng = Rng::new(5);
        let v = Tensor::randn(&[1, 16], 1.0, &mut rng);
        let coeff = v.matmul(&r.u).unwrap(); // (1, k)
        let proj = coeff.matmul(&r.ut).unwrap();
        let perp = v.add(&proj.scale(-1.0)).unwrap();
        let y = rotate(&perp, &r).unwrap();
        assert!(y.max_abs_diff(&perp) < 1e-5);
    }

    #[test]
    fn bad_shapes_are_errors() {
        let d = dims(4, 5);
        // wrong packed width
        assert!(resolve(&Tensor::zeros(&[1, 5]), "x", 64, &d).is_err());
        // multiple rows
        assert!(resolve(&Tensor::zeros(&[2, 6]), "x", 64, &d).is_err());
        // rank exceeding the input width
        assert!(resolve(&Tensor::zeros(&[1, 6]), "x", 3, &d).is_err());
    }
}
