//! QLoRA: LoRA over an NF4/AWQ-packed frozen base. The whole method is
//! the shared [`super::lora::Lora`] implementation with the
//! quantized-base flag set — base matmuls run the fused block-dequant
//! kernels, so the f32 base never materializes.

use super::lora::Lora;

/// Registry object.
pub static QLORA: Lora = Lora {
    name: "qlora",
    quantized: true,
};
