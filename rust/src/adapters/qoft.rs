//! QOFT: input-centric OFTv2 over an NF4/AWQ-packed frozen base — the
//! paper's headline combination. The whole method is the shared
//! [`super::oft_v2::InputCentricOft`] implementation with the
//! quantized-base flag set; rotations touch only activations, so the
//! packs never leave their fused-kernel form.

use super::oft_v2::InputCentricOft;

/// Registry object.
pub static QOFT: InputCentricOft = InputCentricOft {
    name: "qoft",
    quantized: true,
};
