//! Versioned deployable artifacts: the output of the adapter lifecycle
//! `merge → requantize → deploy` (`repro merge`).
//!
//! A merged artifact is a *base-shaped* object: every base parameter of
//! the preset's `<preset>_none` contract, with the adapted linears
//! replaced by their trait-driven merges
//! ([`crate::adapters::Adapter::merge_linear`]), optionally round-tripped
//! through NF4/AWQ requantization. Serving hot-loads it as a
//! zero-trainable resident ([`crate::serve::Server::add_artifact`]):
//! the decode path is a plain `x @ W'` per linear — no adapter state,
//! no rotation work per token.
//!
//! On disk: magic prefix + format-version byte (a future version errors
//! as "unsupported vN", not "bad magic"), a hand-rolled JSON header
//! carrying provenance (preset, method, source tag, quant kind, seed)
//! and the per-linear [`LinearStats`] requant report, then the raw f32
//! little-endian payload — the same binary style as
//! [`crate::coordinator::checkpoint`]. Save → load → save is
//! byte-stable.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::adapters;
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::manifest::{adapted_linear_dims, Manifest};
use crate::json::{self, Json};
use crate::quant::requant::{merge_requant, QuantKind};
use crate::runtime::layers::Params;
use crate::tensor::Tensor;

/// File magic of merged artifacts, version byte excluded.
pub const MAGIC_PREFIX: &[u8; 7] = b"OFTMERG";
/// Current artifact format version (ASCII digit after the prefix).
pub const FORMAT_VERSION: u8 = b'1';

/// Per-linear merge → requantize statistics, recorded in the artifact
/// header (the deployment-time requant tolerance evidence).
#[derive(Clone, Debug)]
pub struct LinearStats {
    pub linear: String,
    /// RMS error of re-quantizing the merged weight.
    pub merged_rms: f64,
    /// Max-abs error of re-quantizing the merged weight.
    pub merged_max: f64,
    /// RMS error of quantizing the pre-merge weight (the floor).
    pub baseline_rms: f64,
    /// `||merged||_inf / ||W||_inf`.
    pub range_inflation: f64,
    /// `||merged - W||_inf`.
    pub delta_inf: f64,
}

/// A merged deployable: provenance + requant stats + the full
/// base-shaped parameter payload.
#[derive(Clone, Debug)]
pub struct Artifact {
    /// Model preset the merged base belongs to (`tiny`, `small`, ...).
    pub preset: String,
    /// Registry method that was folded in.
    pub method: String,
    /// Bundle tag of the source run.
    pub source_tag: String,
    /// Requantization the merged linears were round-tripped through.
    pub quant: QuantKind,
    /// Base seed of the source run. Provenance only: every parameter
    /// value ships in the payload, so loading never re-initializes.
    pub seed: u64,
    /// One entry per adapted linear, in graph order.
    pub stats: Vec<LinearStats>,
    /// Every base parameter of the `<preset>_none` contract, adapted
    /// linears holding their merged (and round-tripped) weights.
    pub params: Checkpoint,
}

/// Fold a finetuned checkpoint into a deployable artifact.
///
/// `ckpt` must be a full export (`Trainer::checkpoint()`): base
/// parameters + trainables (+ quantized-base host masters). For
/// quantized-base bundles the merge runs against the quantize→dequantize
/// round trip of the host master — the values the fused kernels
/// actually decoded with — so the artifact reproduces what the live
/// adapter served, not what it was initialized from.
pub fn merge_checkpoint(
    man: &Manifest,
    ckpt: &Checkpoint,
    seed: u64,
    quant: QuantKind,
) -> Result<Artifact> {
    let adapter = adapters::get(&man.method)?;
    ensure!(
        adapter.can_merge(),
        "method '{}' does not support merging (can_merge() is false)",
        man.method
    );
    let none_man = Manifest::builtin(&format!("{}_none", man.preset))
        .with_context(|| format!("preset '{}' has no builtin base contract", man.preset))?;

    // The adapter's view of the run state: every checkpoint tensor by
    // name (trainables, and for `full` the trained base itself).
    let trainables = Params {
        map: ckpt.iter().map(|(n, t)| (n.clone(), t.clone())).collect(),
        quant: BTreeMap::new(),
    };

    let mut params = Checkpoint::new();
    for spec in &none_man.frozen {
        let t = ckpt.get(&spec.name).with_context(|| {
            format!(
                "source checkpoint lacks base parameter '{}' — export the full \
                 state (Trainer::checkpoint), not a trainables-only file",
                spec.name
            )
        })?;
        ensure!(
            t.shape == spec.shape,
            "checkpoint '{}' has shape {:?}, base contract wants {:?}",
            spec.name,
            t.shape,
            spec.shape
        );
        params.insert(spec.name.clone(), t.clone());
    }

    let quantized_bases = man.quantized_bases();
    let mut stats = Vec::new();
    for (linear, din, dout) in adapted_linear_dims(&man.model) {
        let w0 = params
            .get(&linear)
            .expect("adapted linears are base parameters (inserted above)");
        let w = if quantized_bases.iter().any(|b| b == &linear) {
            QuantKind::parse(&man.quant)?.roundtrip(w0)?
        } else {
            w0.clone()
        };
        // Scenario-targeting-deselected linears carry no adapter state:
        // merge them through the identity ("none") adapter so the
        // artifact agrees with what the bundle trained and served.
        let lin_adapter = if man.skipped.iter().any(|s| s == &linear) {
            adapters::get("none")?
        } else {
            adapter
        };
        let (deployed, rep) =
            merge_requant(lin_adapter, &linear, &w, &trainables, &man.model, quant)?;
        ensure!(
            deployed.shape == vec![din, dout],
            "merged '{linear}' has shape {:?}, expected ({din}, {dout})",
            deployed.shape
        );
        stats.push(LinearStats {
            linear: linear.clone(),
            merged_rms: rep.merged.rms,
            merged_max: rep.merged.max,
            baseline_rms: rep.baseline.rms,
            range_inflation: rep.range_inflation,
            delta_inf: rep.delta_inf,
        });
        params.insert(linear, deployed);
    }

    Ok(Artifact {
        preset: man.preset.clone(),
        method: man.method.clone(),
        source_tag: man.tag.clone(),
        quant,
        seed,
        stats,
        params,
    })
}

/// Write an artifact file (byte-stable: saving a loaded artifact
/// reproduces the input bytes exactly).
pub fn save(path: impl AsRef<Path>, art: &Artifact) -> Result<()> {
    let mut entries = Vec::new();
    let mut offset = 0usize;
    for (name, t) in &art.params {
        entries.push(Json::obj(vec![
            ("name", Json::str(name.clone())),
            (
                "shape",
                Json::arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("offset", Json::num(offset as f64)),
        ]));
        offset += t.numel();
    }
    let stats = art
        .stats
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("linear", Json::str(s.linear.clone())),
                ("merged_rms", Json::num(s.merged_rms)),
                ("merged_max", Json::num(s.merged_max)),
                ("baseline_rms", Json::num(s.baseline_rms)),
                ("range_inflation", Json::num(s.range_inflation)),
                ("delta_inf", Json::num(s.delta_inf)),
            ])
        })
        .collect();
    let header = Json::obj(vec![
        ("preset", Json::str(art.preset.clone())),
        ("method", Json::str(art.method.clone())),
        ("source_tag", Json::str(art.source_tag.clone())),
        ("quant", Json::str(art.quant.name())),
        ("seed", Json::num(art.seed as f64)),
        ("stats", Json::arr(stats)),
        ("entries", Json::arr(entries)),
        ("total", Json::num(offset as f64)),
    ])
    .to_string();

    let file = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(MAGIC_PREFIX)?;
    w.write_all(&[FORMAT_VERSION])?;
    w.write_all(&(header.len() as u32).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    for t in art.params.values() {
        for x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read an artifact file.
pub fn load(path: impl AsRef<Path>) -> Result<Artifact> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening artifact {}", path.as_ref().display()))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic[..7] != MAGIC_PREFIX || !magic[7].is_ascii_digit() {
        bail!("not an OFT merged artifact: bad magic");
    }
    if magic[7] != FORMAT_VERSION {
        bail!(
            "artifact format v{} unsupported (max {})",
            (magic[7] - b'0'),
            (FORMAT_VERSION - b'0')
        );
    }
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    let mut hbytes = vec![0u8; hlen];
    r.read_exact(&mut hbytes)?;
    let header = json::parse(std::str::from_utf8(&hbytes)?)?;

    let total = header.get("total")?.as_usize()?;
    let mut payload = vec![0u8; total * 4];
    r.read_exact(&mut payload)?;
    let floats: Vec<f32> = payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let mut params = Checkpoint::new();
    for e in header.get("entries")?.as_arr()? {
        let name = e.get("name")?.as_str()?.to_string();
        let shape = e.get("shape")?.as_shape()?;
        let offset = e.get("offset")?.as_usize()?;
        let n: usize = shape.iter().product();
        if offset + n > floats.len() {
            bail!("artifact entry '{name}' overruns payload");
        }
        params.insert(name, Tensor::from_vec(&shape, floats[offset..offset + n].to_vec()));
    }

    let mut stats = Vec::new();
    for s in header.get("stats")?.as_arr()? {
        stats.push(LinearStats {
            linear: s.get("linear")?.as_str()?.to_string(),
            merged_rms: s.get("merged_rms")?.as_f64()?,
            merged_max: s.get("merged_max")?.as_f64()?,
            baseline_rms: s.get("baseline_rms")?.as_f64()?,
            range_inflation: s.get("range_inflation")?.as_f64()?,
            delta_inf: s.get("delta_inf")?.as_f64()?,
        });
    }

    Ok(Artifact {
        preset: header.get("preset")?.as_str()?.to_string(),
        method: header.get("method")?.as_str()?.to_string(),
        source_tag: header.get("source_tag")?.as_str()?.to_string(),
        quant: QuantKind::parse(header.get("quant")?.as_str()?)?,
        seed: header.get("seed")?.as_usize()? as u64,
        stats,
        params,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::init_param;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("oft_artifact_{}_{name}", std::process::id()))
    }

    /// A full-state checkpoint of `tag` at init (base + trainables) —
    /// the shape `Trainer::checkpoint()` exports before any training.
    fn init_checkpoint(man: &Manifest, seed: u64) -> Checkpoint {
        let none_man = Manifest::builtin(&format!("{}_none", man.preset)).unwrap();
        let mut ck = Checkpoint::new();
        for spec in &none_man.frozen {
            ck.insert(spec.name.clone(), init_param(spec, seed, None).unwrap());
        }
        for spec in &man.trainable {
            ck.insert(spec.name.clone(), init_param(spec, seed, None).unwrap());
        }
        ck
    }

    #[test]
    fn merge_at_identity_init_is_the_base() {
        // Zero-initialized adapters are exact identities, so the merged
        // linears equal the base weights bitwise (quant = none).
        let man = Manifest::builtin("tiny_oft_v2").unwrap();
        let ck = init_checkpoint(&man, 7);
        let art = merge_checkpoint(&man, &ck, 7, QuantKind::None).unwrap();
        assert_eq!(art.preset, "tiny");
        assert_eq!(art.method, "oft_v2");
        assert_eq!(art.stats.len(), adapted_linear_dims(&man.model).len());
        for s in &art.stats {
            assert_eq!(s.merged_rms, 0.0, "{}", s.linear);
            assert_eq!(s.delta_inf, 0.0, "{}", s.linear);
            assert_eq!(art.params.get(&s.linear).unwrap(), ck.get(&s.linear).unwrap());
        }
        // every base parameter of the `_none` contract is present
        let none_man = Manifest::builtin("tiny_none").unwrap();
        for spec in &none_man.frozen {
            assert!(art.params.contains_key(&spec.name), "{}", spec.name);
        }
    }

    #[test]
    fn save_load_roundtrip_is_byte_stable() {
        let man = Manifest::builtin("tiny_lora").unwrap();
        let ck = init_checkpoint(&man, 11);
        let art = merge_checkpoint(&man, &ck, 11, QuantKind::Nf4).unwrap();
        let p1 = tmp("roundtrip1");
        let p2 = tmp("roundtrip2");
        save(&p1, &art).unwrap();
        let back = load(&p1).unwrap();
        assert_eq!(back.preset, art.preset);
        assert_eq!(back.method, art.method);
        assert_eq!(back.source_tag, art.source_tag);
        assert_eq!(back.quant, art.quant);
        assert_eq!(back.seed, art.seed);
        assert_eq!(back.params, art.params);
        assert_eq!(back.stats.len(), art.stats.len());
        save(&p2, &back).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "save(load(x)) must reproduce x byte for byte"
        );
        let _ = std::fs::remove_file(p1);
        let _ = std::fs::remove_file(p2);
    }

    #[test]
    fn future_format_version_names_itself() {
        let man = Manifest::builtin("tiny_none").unwrap();
        let ck = init_checkpoint(&man, 3);
        let art = merge_checkpoint(&man, &ck, 3, QuantKind::None).unwrap();
        let p = tmp("future_version");
        save(&p, &art).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..7], MAGIC_PREFIX);
        assert_eq!(bytes[7], b'1');
        bytes[7] = b'3';
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("artifact format v3 unsupported (max 1)"), "{err}");
        bytes[7] = b'?';
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn trainables_only_checkpoint_is_rejected() {
        let man = Manifest::builtin("tiny_oft_v2").unwrap();
        let full = init_checkpoint(&man, 5);
        let mut trainables_only = Checkpoint::new();
        for spec in &man.trainable {
            trainables_only.insert(spec.name.clone(), full.get(&spec.name).unwrap().clone());
        }
        let err = merge_checkpoint(&man, &trainables_only, 5, QuantKind::None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("lacks base parameter"), "{err}");
    }

    #[test]
    fn quantized_bundle_merges_the_roundtripped_base() {
        // For a quantized-base bundle the artifact must hold the values
        // the fused kernels decoded with — the NF4 round trip of the
        // host master — not the f32 master itself.
        let man = Manifest::builtin("tiny_qoft_nf4").unwrap();
        let ck = init_checkpoint(&man, 9);
        let art = merge_checkpoint(&man, &ck, 9, QuantKind::None).unwrap();
        for base in man.quantized_bases() {
            let expect = QuantKind::Nf4.roundtrip(ck.get(&base).unwrap()).unwrap();
            assert_eq!(
                art.params.get(&base).unwrap(),
                &expect,
                "identity merge of packed '{base}' must equal its round trip"
            );
        }
    }
}
