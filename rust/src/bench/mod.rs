//! Benchmark harness (offline substitute for `criterion`): warmup +
//! timed iterations + summary stats, paper-style table printing, and a
//! JSON results file per bench so EXPERIMENTS.md numbers are
//! regenerable.
//!
//! Every `cargo bench` target (one per paper table/figure) builds on
//! this module; see DESIGN.md §3 for the experiment index.

use std::path::PathBuf;

use crate::json::Json;
use crate::util::stats::Summary;
use crate::util::timer::Timer;

/// A configured micro/macro benchmark.
pub struct Bench {
    pub name: String,
    pub warmup: usize,
    pub iters: usize,
    /// Stop early once this much measurement time has accumulated (0 =
    /// always run all `iters`).
    pub max_secs: f64,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench {
            name: name.into(),
            warmup: 3,
            iters: 10,
            max_secs: 0.0,
        }
    }

    pub fn warmup(mut self, n: usize) -> Bench {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Bench {
        self.iters = n;
        self
    }

    pub fn max_secs(mut self, s: f64) -> Bench {
        self.max_secs = s;
        self
    }

    /// Run the benchmark; `f` is invoked warmup+iters times, with each
    /// post-warmup call timed individually.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        let budget = Timer::start();
        for _ in 0..self.iters.max(1) {
            let t = Timer::start();
            f();
            samples.push(t.secs());
            if self.max_secs > 0.0 && budget.secs() > self.max_secs && !samples.is_empty() {
                break;
            }
        }
        Summary::of(&samples)
    }
}

// ---------------------------------------------------------------------------
// Table printing (the "same rows the paper reports" contract)
// ---------------------------------------------------------------------------

/// Print an aligned ASCII table with a header rule.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    println!(
        "{}",
        widths
            .iter()
            .map(|w| "-".repeat(*w))
            .collect::<Vec<_>>()
            .join("  ")
    );
    for row in rows {
        line(row);
    }
}

/// Format seconds as ms with sensible precision.
pub fn fmt_ms(secs: f64) -> String {
    let ms = secs * 1e3;
    if ms >= 100.0 {
        format!("{ms:.0} ms")
    } else if ms >= 1.0 {
        format!("{ms:.2} ms")
    } else {
        format!("{:.1} µs", ms * 1e3)
    }
}

/// Format a unitless ratio (speedups, memory factors).
pub fn fmt_ratio(x: f64) -> String {
    format!("{x:.2}x")
}

// ---------------------------------------------------------------------------
// Result persistence
// ---------------------------------------------------------------------------

/// Collects result rows and writes `bench_results/<name>.json`.
pub struct Report {
    name: String,
    rows: Vec<Json>,
}

impl Report {
    pub fn new(name: impl Into<String>) -> Report {
        Report {
            name: name.into(),
            rows: Vec::new(),
        }
    }

    pub fn add(&mut self, row: Json) {
        self.rows.push(row);
    }

    /// Convenience: a row of (key, value) pairs.
    pub fn add_kv(&mut self, pairs: Vec<(&str, Json)>) {
        self.rows.push(Json::obj(pairs));
    }

    pub fn rows(&self) -> &[Json] {
        &self.rows
    }

    /// Write to `bench_results/<name>.json` (path overridable with
    /// `OFT_BENCH_OUT`); returns the path written.
    pub fn save(&self) -> crate::Result<PathBuf> {
        let dir = std::env::var("OFT_BENCH_OUT").unwrap_or_else(|_| "bench_results".into());
        std::fs::create_dir_all(&dir)?;
        let path = PathBuf::from(dir).join(format!("{}.json", self.name));
        let doc = Json::obj(vec![
            ("bench", Json::str(self.name.clone())),
            ("config", config_json()),
            ("rows", Json::arr(self.rows.clone())),
        ]);
        std::fs::write(&path, doc.to_string())?;
        Ok(path)
    }
}

/// Standard bench entrypoint boilerplate: honor `--quick` (fewer iters)
/// from argv so `cargo bench` stays fast in CI.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("OFT_BENCH_QUICK").is_ok()
}

/// Cargo features compiled into this binary that change what a bench
/// measures. Recorded in every result file's `config` block so perf
/// trajectories across commits are attributable to the build, not just
/// the code.
pub fn enabled_features() -> Vec<&'static str> {
    let mut fs = Vec::new();
    if cfg!(feature = "simd") {
        fs.push("simd");
    }
    if cfg!(feature = "pjrt") {
        fs.push("pjrt");
    }
    fs
}

/// The scenario knobs active for this bench process:
/// `OFT_BENCH_SCENARIO` holds a tag-suffix string (e.g.
/// `coft+eps=1e-3+target=wq|wv`); unset means every knob at its
/// default. Stamped into every result file's `config` block so
/// scenario-sensitive runs stay attributable across commits.
pub fn bench_scenario() -> crate::scenario::ScenarioCfg {
    match std::env::var("OFT_BENCH_SCENARIO") {
        Ok(s) if !s.is_empty() => {
            crate::scenario::ScenarioCfg::parse_suffix(&s).unwrap_or_default()
        }
        _ => crate::scenario::ScenarioCfg::default(),
    }
}

/// The `scenario` object inside every `config` block: one key per
/// scenario knob, always present (CI greps for them).
pub fn scenario_json(sc: &crate::scenario::ScenarioCfg) -> Json {
    Json::obj(vec![
        ("suffix", Json::str(sc.suffix())),
        ("coft", Json::Bool(sc.coft)),
        ("eps", Json::num(sc.eps as f64)),
        ("module_dropout", Json::num(sc.module_dropout as f64)),
        ("block_share", Json::Bool(sc.block_share)),
        ("r", Json::num(sc.oft_r as f64)),
        ("block", Json::num(sc.block as f64)),
        (
            "target",
            sc.target.clone().map(Json::Str).unwrap_or(Json::Null),
        ),
        (
            "exclude",
            sc.exclude.clone().map(Json::Str).unwrap_or(Json::Null),
        ),
    ])
}

/// The `config` block stamped into every bench result file: enabled
/// feature flags, whether the SIMD kernels are actually live (the
/// feature can be compiled in but forced off via
/// `tensor::force_scalar_kernels`), and the active scenario knobs.
fn config_json() -> Json {
    Json::obj(vec![
        (
            "features",
            Json::arr(enabled_features().iter().map(|f| Json::str(*f)).collect()),
        ),
        (
            "simd_kernels_active",
            Json::Bool(crate::tensor::simd_kernels_active()),
        ),
        ("scenario", scenario_json(&bench_scenario())),
    ])
}

/// The default master seed benches feed every `Rng`, trainer, and
/// synthetic-prompt stream.
pub const DEFAULT_BENCH_SEED: u64 = 7;

/// Master seed for bench randomness: `OFT_BENCH_SEED` env override,
/// else [`DEFAULT_BENCH_SEED`]. Benches derive every `Rng` from this
/// one value (offset per use site) instead of ad-hoc literals, so a
/// whole `BENCH_*.json` run is reproducible — and re-seedable — from
/// one knob.
pub fn bench_seed() -> u64 {
    seed_from(std::env::var("OFT_BENCH_SEED").ok())
}

fn seed_from(env: Option<String>) -> u64 {
    env.and_then(|s| s.parse().ok()).unwrap_or(DEFAULT_BENCH_SEED)
}

// ---------------------------------------------------------------------------
// Machine-readable bench records (the perf-trajectory contract)
// ---------------------------------------------------------------------------

/// One measured configuration in the shared `BENCH_<name>.json` schema:
/// a config label plus mean/p50/p95/p99 of its samples, with free-form
/// extra fields (method, dimension, ratio, ...).
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub config: String,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub n: usize,
    pub extra: Vec<(String, Json)>,
}

impl BenchRecord {
    /// Record from raw samples (seconds or any consistent unit).
    pub fn from_samples(config: impl Into<String>, samples: &[f64]) -> BenchRecord {
        BenchRecord::from_summary(config, &Summary::of(samples))
    }

    pub fn from_summary(config: impl Into<String>, s: &Summary) -> BenchRecord {
        BenchRecord {
            config: config.into(),
            mean: s.mean,
            p50: s.median,
            p95: s.p95,
            p99: s.p99,
            n: s.n,
            extra: Vec::new(),
        }
    }

    /// Attach an extra field.
    pub fn with(mut self, key: impl Into<String>, value: Json) -> BenchRecord {
        self.extra.push((key.into(), value));
        self
    }

    fn to_json(&self) -> Json {
        let mut obj: Vec<(&str, Json)> = vec![
            ("config", Json::str(self.config.clone())),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50)),
            ("p95", Json::num(self.p95)),
            ("p99", Json::num(self.p99)),
            ("n", Json::num(self.n as f64)),
        ];
        for (k, v) in &self.extra {
            obj.push((k.as_str(), v.clone()));
        }
        Json::obj(obj)
    }
}

/// Write `BENCH_<name>.json` under the bench output directory
/// (`OFT_BENCH_OUT`, default `bench_results`): the machine-readable
/// record every bench emits so the perf trajectory is diffable across
/// commits. `unit` names what mean/p50/p95 measure (e.g. "secs",
/// "secs_per_token").
pub fn write_bench_json(
    name: &str,
    unit: &str,
    records: &[BenchRecord],
) -> crate::Result<PathBuf> {
    let dir = std::env::var("OFT_BENCH_OUT").unwrap_or_else(|_| "bench_results".into());
    write_bench_json_to(dir, name, unit, records)
}

/// As [`write_bench_json`] with an explicit output directory (no
/// process-global env read — use this from tests).
pub fn write_bench_json_to(
    dir: impl Into<PathBuf>,
    name: &str,
    unit: &str,
    records: &[BenchRecord],
) -> crate::Result<PathBuf> {
    let dir = dir.into();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("BENCH_{name}.json"));
    let doc = Json::obj(vec![
        ("bench", Json::str(name.to_string())),
        ("unit", Json::str(unit.to_string())),
        ("schema", Json::str("config/mean/p50/p95/p99/n".to_string())),
        ("config", config_json()),
        ("records", Json::arr(records.iter().map(|r| r.to_json()).collect())),
    ]);
    std::fs::write(&path, doc.to_string())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_expected_iterations() {
        let counter = std::cell::Cell::new(0usize);
        let s = Bench::new("x").warmup(2).iters(5).run(|| {
            counter.set(counter.get() + 1);
        });
        assert_eq!(counter.get(), 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn bench_budget_stops_early() {
        let s = Bench::new("slow")
            .warmup(0)
            .iters(1000)
            .max_secs(0.02)
            .run(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(s.n < 1000);
    }

    #[test]
    fn report_saves_json() {
        let dir = std::env::temp_dir().join(format!("oft_bench_{}", std::process::id()));
        std::env::set_var("OFT_BENCH_OUT", &dir);
        let mut r = Report::new("unit_test");
        r.add_kv(vec![("d", Json::num(256.0)), ("ms", Json::num(1.5))]);
        let path = r.save().unwrap();
        let parsed = crate::json::parse_file(&path).unwrap();
        assert_eq!(
            parsed.get("rows").unwrap().as_arr().unwrap()[0]
                .get("d")
                .unwrap()
                .as_usize()
                .unwrap(),
            256
        );
        std::env::remove_var("OFT_BENCH_OUT");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn bench_json_schema() {
        let dir = std::env::temp_dir().join(format!("oft_benchjson_{}", std::process::id()));
        let rec = BenchRecord::from_samples("kv_d256", &[0.1, 0.2, 0.3])
            .with("method", Json::str("oft_v2"));
        let path = write_bench_json_to(dir.clone(), "unit_serving", "secs", &[rec]).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap() == "BENCH_unit_serving.json");
        let doc = crate::json::parse_file(&path).unwrap();
        assert_eq!(doc.get("unit").unwrap().as_str().unwrap(), "secs");
        let r = &doc.get("records").unwrap().as_arr().unwrap()[0];
        assert_eq!(r.get("config").unwrap().as_str().unwrap(), "kv_d256");
        assert!((r.get("mean").unwrap().as_f64().unwrap() - 0.2).abs() < 1e-12);
        assert!(r.get("p50").unwrap().as_f64().is_ok());
        assert!(r.get("p95").unwrap().as_f64().is_ok());
        assert!(r.get("p99").unwrap().as_f64().is_ok());
        assert_eq!(r.get("method").unwrap().as_str().unwrap(), "oft_v2");
        // Every emitter stamps the build config so perf trajectories
        // are attributable to feature flags.
        let cfg = doc.get("config").unwrap();
        let feats = cfg.get("features").unwrap().as_arr().unwrap();
        for f in feats {
            assert!(f.as_str().is_ok(), "features must be strings");
        }
        assert_eq!(
            cfg.get("simd_kernels_active"),
            Some(&Json::Bool(crate::tensor::simd_kernels_active()))
        );
        // ... and the scenario knobs, one key per knob (CI greps these).
        let sc = cfg.get("scenario").unwrap();
        for key in [
            "suffix", "coft", "eps", "module_dropout", "block_share", "r", "block", "target",
            "exclude",
        ] {
            assert!(sc.opt(key).is_some(), "config.scenario must stamp '{key}'");
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn bench_seed_parsing() {
        // Pure parse logic — no process-global env mutation (tests run
        // in parallel, and a user-set OFT_BENCH_SEED must not break
        // the suite).
        assert_eq!(seed_from(None), DEFAULT_BENCH_SEED);
        assert_eq!(seed_from(Some("123".into())), 123);
        assert_eq!(seed_from(Some("not-a-number".into())), DEFAULT_BENCH_SEED);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ms(0.1234), "123 ms");
        assert_eq!(fmt_ms(0.00123), "1.23 ms");
        assert_eq!(fmt_ms(0.0000005), "0.5 µs");
        assert_eq!(fmt_ratio(3.04), "3.04x");
    }
}
