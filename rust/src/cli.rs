//! Command-line argument parsing (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Declarative option spec for help text + validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// A command parser with declared options (for validation + help).
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = o
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\t{}{def}\n", o.name, o.help));
        }
        s
    }

    /// Parse argv (without program name / subcommand). Unknown options
    /// are rejected; declared defaults are filled in.
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = parse_raw(argv, /*expect_subcommand=*/ false)?;
        for o in &self.opts {
            if o.is_flag {
                if args.options.contains_key(o.name) {
                    bail!("--{} is a flag and takes no value", o.name);
                }
            } else if args.flags.iter().any(|f| f == o.name) {
                bail!("--{} expects a value", o.name);
            }
        }
        let known: Vec<&str> = self.opts.iter().map(|o| o.name).collect();
        for k in args.options.keys().chain(args.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k}\n\n{}", self.help_text());
            }
        }
        for o in &self.opts {
            if let Some(d) = o.default {
                args.options.entry(o.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(args)
    }
}

/// Raw tokenizer: `--k=v`, `--k v`, `--flag` (followed by another option
/// or end), positionals. If `expect_subcommand`, the first positional is
/// the subcommand.
pub fn parse_raw(argv: &[String], expect_subcommand: bool) -> Result<Args> {
    let mut args = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(rest) = tok.strip_prefix("--") {
            if rest.is_empty() {
                // `--` ends option parsing
                args.positional.extend(argv[i + 1..].iter().cloned());
                break;
            }
            if let Some(eq) = rest.find('=') {
                args.options
                    .insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                args.options.insert(rest.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                args.flags.push(rest.to_string());
            }
        } else if expect_subcommand && args.subcommand.is_none() {
            args.subcommand = Some(tok.clone());
        } else {
            args.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse_raw(&v(&["train", "--tag", "tiny_oft_v2", "--steps=100", "--quiet"]), true).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("tag"), Some("tiny_oft_v2"));
        assert_eq!(a.get("steps"), Some("100"));
        assert!(a.has_flag("quiet"));
    }

    #[test]
    fn command_defaults_and_validation() {
        let cmd = Command::new("train", "run finetuning")
            .opt("steps", "number of steps", Some("50"))
            .flag("quiet", "suppress logs");
        let a = cmd.parse(&v(&["--quiet"])).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 50);
        assert!(a.has_flag("quiet"));
        assert!(cmd.parse(&v(&["--bogus", "1"])).is_err());
        assert!(cmd.parse(&v(&["--steps"])).is_err()); // flag-used-as-value
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse_raw(&v(&["--a", "1", "--", "--not-an-opt"]), false).unwrap();
        assert_eq!(a.get("a"), Some("1"));
        assert_eq!(a.positional, vec!["--not-an-opt"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse_raw(&v(&["--lr", "0.004", "--n", "7"]), false).unwrap();
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.004);
        assert_eq!(a.get_usize("n", 0).unwrap(), 7);
        assert!(a.get_usize("lr", 0).is_err());
    }

    #[test]
    fn help_text_lists_options() {
        let cmd = Command::new("x", "y").opt("steps", "s", Some("5")).flag("q", "z");
        let h = cmd.help_text();
        assert!(h.contains("--steps") && h.contains("default: 5") && h.contains("--q"));
    }
}
