//! L4 comms — shared-nothing, message-passing training collectives.
//!
//! `repro train --ranks N` runs N processes that each own a contiguous
//! shard of the Adam moments (ZeRO-1) and exchange gradients over this
//! module: a typed length-prefixed wire protocol ([`Frame`]) on
//! localhost TCP (or an in-process channel mesh for tests/benches), a
//! full-mesh [`RankGroup`] built from a rank-0 rendezvous, and the
//! collectives the sharded train step needs — tree all-reduce,
//! rank-ordered all-gather, broadcast, barrier.
//!
//! **Determinism contract.** [`RankGroup::tree_all_reduce`] walks the
//! exact pairwise reduction schedule of `refmodel::tree_reduce` over
//! the global leaf index, with leaves owned per
//! [`crate::runtime::shard_range`] (the same `div_ceil` chunking
//! `run_sharded` uses for worker threads). Cross-rank pairs move the
//! right operand to the left owner; every combine therefore executes
//! the identical float expressions on the identical operands as the
//! single-process tree, and f32 payloads travel as raw little-endian
//! bits — so loss, gradients, and updated params are bitwise identical
//! from 1 thread to N processes.
//!
//! **Robustness.** Connect/accept retries are bounded by
//! [`CommsCfg`] deadlines, and every mid-step receive carries an I/O
//! timeout: a dead peer surfaces as a typed [`CommsError`] naming the
//! rank instead of hanging the tree reduction.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::layers::Gradients;
use crate::runtime::{combine_microbatches, shard_range, GradReducer};
use crate::tensor::Tensor;

/// Hard ceiling on `--ranks` (localhost full mesh: N^2/2 sockets).
pub const MAX_RANKS: usize = 64;

/// Frames larger than this are a protocol violation (corrupt length
/// prefix), not an allocation request.
const MAX_FRAME: usize = 1 << 30;

// Frame kinds. A frame of the wrong kind for the collective in
// progress is a typed protocol error, not a misread payload.
const KIND_HELLO: u8 = 1;
const KIND_ROSTER: u8 = 2;
const KIND_REDUCE: u8 = 3;
const KIND_GATHER: u8 = 4;
const KIND_BCAST: u8 = 5;
const KIND_CHECK: u8 = 6;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Typed communication failures, each naming the peer rank involved.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CommsError {
    /// The connection to `rank` died (EOF / reset / closed channel).
    PeerDead {
        rank: usize,
        during: &'static str,
        detail: String,
    },
    /// No frame from `rank` within the I/O deadline.
    Timeout {
        rank: usize,
        during: &'static str,
        after: Duration,
    },
    /// A frame arrived but violates the collective's schedule.
    Protocol { rank: usize, detail: String },
    /// Rendezvous / topology setup failed before the mesh existed.
    Setup { detail: String },
}

impl std::fmt::Display for CommsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommsError::PeerDead { rank, during, detail } => {
                write!(f, "rank {rank} died during {during}: {detail}")
            }
            CommsError::Timeout { rank, during, after } => write!(
                f,
                "rank {rank} unresponsive during {during} (no frame within {:.1}s)",
                after.as_secs_f64()
            ),
            CommsError::Protocol { rank, detail } => {
                write!(f, "protocol violation involving rank {rank}: {detail}")
            }
            CommsError::Setup { detail } => write!(f, "rank rendezvous failed: {detail}"),
        }
    }
}

impl std::error::Error for CommsError {}

/// Transport-level failure, before the peer rank is attached.
#[derive(Debug)]
pub enum TransportError {
    Dead(String),
    Timeout(Duration),
    Protocol(String),
}

impl TransportError {
    fn into_comms(self, rank: usize, during: &'static str) -> CommsError {
        match self {
            TransportError::Dead(detail) => CommsError::PeerDead { rank, during, detail },
            TransportError::Timeout(after) => CommsError::Timeout { rank, during, after },
            TransportError::Protocol(detail) => CommsError::Protocol { rank, detail },
        }
    }
}

// ---------------------------------------------------------------------------
// Topology / address validation (Method/QuantKind parse-error style)
// ---------------------------------------------------------------------------

/// Validate a `(rank, ranks)` pair, erroring with the valid range.
pub fn validate_topology(rank: usize, ranks: usize) -> Result<()> {
    ensure!(
        (1..=MAX_RANKS).contains(&ranks),
        "--ranks must be in 1..={MAX_RANKS}, got {ranks}"
    );
    ensure!(
        rank < ranks,
        "--rank must be in 0..={} for --ranks {ranks}, got {rank}",
        ranks - 1
    );
    Ok(())
}

/// Parse a rendezvous address (`host:port`; port 0 lets rank 0 pick a
/// free port).
pub fn parse_rendezvous(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .ok()
        .and_then(|mut it| it.next())
        .with_context(|| {
            format!(
                "malformed rendezvous address '{addr}'; expected host:port \
                 (e.g. 127.0.0.1:29400, or 127.0.0.1:0 to let rank 0 pick a free port)"
            )
        })
}

/// FNV-1a over a byte string — the per-step batch fingerprint ranks
/// cross-check so diverged data loaders fail loudly, not silently.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Transports
// ---------------------------------------------------------------------------

/// One length-prefixed typed frame: `[len u32][kind u8][seq u64][payload]`
/// (all integers little-endian). `seq` is a per-link monotone counter;
/// a gap means the two ranks disagree on the collective schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub kind: u8,
    pub seq: u64,
    pub payload: Vec<u8>,
}

/// A reliable, ordered frame link to one peer. Implementations must
/// deliver whole frames or fail typed — never block forever.
pub trait Transport: Send {
    fn send(&mut self, kind: u8, seq: u64, payload: &[u8]) -> Result<(), TransportError>;
    fn recv(&mut self) -> Result<Frame, TransportError>;
    /// Switch from the (long) handshake deadline to the steady-state
    /// per-frame I/O deadline.
    fn set_io_timeout(&mut self, timeout: Duration) -> Result<(), TransportError>;
}

/// Localhost TCP transport (`TCP_NODELAY`, read/write deadlines).
pub struct TcpTransport {
    stream: TcpStream,
    timeout: Duration,
}

impl TcpTransport {
    pub fn new(stream: TcpStream, timeout: Duration) -> Result<TcpTransport> {
        stream.set_nodelay(true).context("set_nodelay")?;
        stream
            .set_read_timeout(Some(timeout))
            .context("set_read_timeout")?;
        stream
            .set_write_timeout(Some(timeout))
            .context("set_write_timeout")?;
        Ok(TcpTransport { stream, timeout })
    }

    fn map_io(&self, e: std::io::Error) -> TransportError {
        use std::io::ErrorKind::*;
        match e.kind() {
            WouldBlock | TimedOut => TransportError::Timeout(self.timeout),
            UnexpectedEof | ConnectionReset | ConnectionAborted | BrokenPipe => {
                TransportError::Dead(format!("connection lost ({e})"))
            }
            _ => TransportError::Dead(format!("socket error ({e})")),
        }
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, kind: u8, seq: u64, payload: &[u8]) -> Result<(), TransportError> {
        let len = (1 + 8 + payload.len()) as u32;
        let mut head = [0u8; 13];
        head[..4].copy_from_slice(&len.to_le_bytes());
        head[4] = kind;
        head[5..13].copy_from_slice(&seq.to_le_bytes());
        self.stream.write_all(&head).map_err(|e| self.map_io(e))?;
        self.stream
            .write_all(payload)
            .map_err(|e| self.map_io(e))?;
        self.stream.flush().map_err(|e| self.map_io(e))
    }

    fn recv(&mut self) -> Result<Frame, TransportError> {
        let mut len4 = [0u8; 4];
        self.stream
            .read_exact(&mut len4)
            .map_err(|e| self.map_io(e))?;
        let len = u32::from_le_bytes(len4) as usize;
        if !(9..=MAX_FRAME).contains(&len) {
            return Err(TransportError::Protocol(format!(
                "frame length {len} outside 9..={MAX_FRAME} (corrupt length prefix?)"
            )));
        }
        let mut body = vec![0u8; len];
        self.stream
            .read_exact(&mut body)
            .map_err(|e| self.map_io(e))?;
        let kind = body[0];
        let seq = u64::from_le_bytes(body[1..9].try_into().expect("8-byte seq"));
        body.drain(..9);
        Ok(Frame { kind, seq, payload: body })
    }

    fn set_io_timeout(&mut self, timeout: Duration) -> Result<(), TransportError> {
        self.timeout = timeout;
        self.stream
            .set_read_timeout(Some(timeout))
            .and_then(|_| self.stream.set_write_timeout(Some(timeout)))
            .map_err(|e| TransportError::Dead(format!("set timeout ({e})")))
    }
}

/// In-process channel transport: the same frames over `mpsc`, used by
/// the channel mesh ([`RankGroup::mem_mesh`]) in unit tests.
pub struct MemTransport {
    tx: Sender<Frame>,
    rx: Receiver<Frame>,
    timeout: Duration,
}

impl Transport for MemTransport {
    fn send(&mut self, kind: u8, seq: u64, payload: &[u8]) -> Result<(), TransportError> {
        self.tx
            .send(Frame { kind, seq, payload: payload.to_vec() })
            .map_err(|_| TransportError::Dead("channel closed (peer dropped)".into()))
    }

    fn recv(&mut self) -> Result<Frame, TransportError> {
        match self.rx.recv_timeout(self.timeout) {
            Ok(f) => Ok(f),
            Err(RecvTimeoutError::Timeout) => Err(TransportError::Timeout(self.timeout)),
            Err(RecvTimeoutError::Disconnected) => {
                Err(TransportError::Dead("channel closed (peer dropped)".into()))
            }
        }
    }

    fn set_io_timeout(&mut self, timeout: Duration) -> Result<(), TransportError> {
        self.timeout = timeout;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Rank group
// ---------------------------------------------------------------------------

/// Connect/retry policy for rendezvous and steady-state I/O.
#[derive(Clone, Copy, Debug)]
pub struct CommsCfg {
    /// Total budget for dialing one peer (bounded retry).
    pub connect_timeout: Duration,
    /// Pause between dial attempts / accept polls.
    pub retry_every: Duration,
    /// Budget for the whole handshake on each link (accept + roster).
    pub accept_timeout: Duration,
    /// Steady-state per-frame deadline mid-step.
    pub io_timeout: Duration,
}

impl Default for CommsCfg {
    fn default() -> Self {
        CommsCfg {
            connect_timeout: Duration::from_secs(30),
            retry_every: Duration::from_millis(50),
            accept_timeout: Duration::from_secs(60),
            io_timeout: Duration::from_secs(120),
        }
    }
}

impl CommsCfg {
    /// Short deadlines for tests (fail in seconds, not minutes).
    pub fn fast() -> CommsCfg {
        CommsCfg {
            connect_timeout: Duration::from_secs(10),
            retry_every: Duration::from_millis(10),
            accept_timeout: Duration::from_secs(20),
            io_timeout: Duration::from_secs(20),
        }
    }
}

/// One live link, with per-link frame sequence counters.
struct Peer {
    transport: Box<dyn Transport>,
    send_seq: u64,
    recv_seq: u64,
}

impl Peer {
    fn new(transport: Box<dyn Transport>) -> Peer {
        Peer { transport, send_seq: 0, recv_seq: 0 }
    }

    fn send(&mut self, kind: u8, payload: &[u8]) -> Result<(), TransportError> {
        let seq = self.send_seq;
        self.send_seq += 1;
        self.transport.send(kind, seq, payload)
    }

    /// Receive one frame, enforcing the per-link sequence and the
    /// expected kind.
    fn recv(&mut self, kind: u8) -> Result<Vec<u8>, TransportError> {
        let frame = self.transport.recv()?;
        if frame.seq != self.recv_seq {
            return Err(TransportError::Protocol(format!(
                "frame out of sequence: got seq {}, expected {} — \
                 ranks disagree on the collective schedule",
                frame.seq, self.recv_seq
            )));
        }
        self.recv_seq += 1;
        if frame.kind != kind {
            return Err(TransportError::Protocol(format!(
                "expected frame kind {kind}, got {} — \
                 ranks disagree on the collective schedule",
                frame.kind
            )));
        }
        Ok(frame.payload)
    }
}

/// The full-mesh communicator for one rank of a training group.
pub struct RankGroup {
    rank: usize,
    ranks: usize,
    /// `links[r]` = link to rank `r` (`None` at `r == rank`).
    links: Vec<Option<Mutex<Peer>>>,
}

impl RankGroup {
    /// The trivial single-rank group (no links, all collectives local).
    pub fn solo() -> RankGroup {
        RankGroup { rank: 0, ranks: 1, links: vec![None] }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Build the TCP mesh for `rank` of `ranks`. Rank 0 binds
    /// `rendezvous` and accepts; other ranks dial it (bounded retry),
    /// advertise their own listener, receive the roster, then complete
    /// the mesh (higher ranks dial lower ranks).
    pub fn tcp(rank: usize, ranks: usize, rendezvous: &str, cfg: CommsCfg) -> Result<RankGroup> {
        validate_topology(rank, ranks)?;
        if ranks == 1 {
            return Ok(RankGroup::solo());
        }
        if rank == 0 {
            let addr = parse_rendezvous(rendezvous)?;
            let listener = TcpListener::bind(addr).map_err(|e| CommsError::Setup {
                detail: format!("rank 0 could not bind rendezvous {addr}: {e}"),
            })?;
            RankGroup::tcp_leader(listener, ranks, cfg)
        } else {
            RankGroup::tcp_join(rank, ranks, rendezvous, cfg)
        }
    }

    /// Rank 0 over an already-bound listener — used by the launcher,
    /// which binds `host:0` first so it can pass the real port to the
    /// child processes it spawns.
    pub fn tcp_leader(listener: TcpListener, ranks: usize, cfg: CommsCfg) -> Result<RankGroup> {
        validate_topology(0, ranks)?;
        if ranks == 1 {
            return Ok(RankGroup::solo());
        }
        listener.set_nonblocking(true).context("listener nonblocking")?;
        let deadline = Instant::now() + cfg.accept_timeout;
        let mut peers: Vec<Option<(Peer, String)>> = (0..ranks).map(|_| None).collect();
        let mut joined = 0usize;
        while joined < ranks - 1 {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).context("stream blocking")?;
                    let t = TcpTransport::new(stream, cfg.accept_timeout)?;
                    let mut peer = Peer::new(Box::new(t));
                    let payload = peer
                        .recv(KIND_HELLO)
                        .map_err(|e| e.into_comms(usize::MAX, "rendezvous hello"))?;
                    let hello = Hello::decode(&payload)?;
                    if hello.ranks != ranks {
                        bail!(CommsError::Setup {
                            detail: format!(
                                "rank {} was launched with --ranks {}, leader expects {ranks}",
                                hello.rank, hello.ranks
                            ),
                        });
                    }
                    ensure!(
                        (1..ranks).contains(&hello.rank),
                        CommsError::Setup {
                            detail: format!(
                                "hello from rank {} outside 1..={}",
                                hello.rank,
                                ranks - 1
                            ),
                        }
                    );
                    ensure!(
                        peers[hello.rank].is_none(),
                        CommsError::Setup {
                            detail: format!("two processes claimed rank {}", hello.rank),
                        }
                    );
                    peers[hello.rank] = Some((peer, hello.addr));
                    joined += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(CommsError::Setup {
                            detail: format!(
                                "timed out after {:.0?} waiting for {} of {} peer rank(s) \
                                 to join the rendezvous",
                                cfg.accept_timeout,
                                ranks - 1 - joined,
                                ranks - 1
                            ),
                        });
                    }
                    std::thread::sleep(cfg.retry_every);
                }
                Err(e) => bail!(CommsError::Setup { detail: format!("accept failed: {e}") }),
            }
        }
        // Everyone is in: publish the roster of advertised addresses.
        let addrs: Vec<String> = (1..ranks)
            .map(|r| peers[r].as_ref().expect("joined").1.clone())
            .collect();
        let roster = encode_roster(&addrs);
        let mut links: Vec<Option<Mutex<Peer>>> = (0..ranks).map(|_| None).collect();
        for (r, slot) in peers.into_iter().enumerate() {
            if let Some((mut peer, _)) = slot {
                peer.send(KIND_ROSTER, &roster)
                    .map_err(|e| e.into_comms(r, "roster send"))?;
                peer.transport
                    .set_io_timeout(cfg.io_timeout)
                    .map_err(|e| e.into_comms(r, "roster send"))?;
                links[r] = Some(Mutex::new(peer));
            }
        }
        Ok(RankGroup { rank: 0, ranks, links })
    }

    /// Join an existing rendezvous as `rank` (>= 1).
    fn tcp_join(rank: usize, ranks: usize, rendezvous: &str, cfg: CommsCfg) -> Result<RankGroup> {
        let rdv = parse_rendezvous(rendezvous)?;
        // Bind our own listener first so the advertised address is live
        // before the roster goes out.
        let listener = TcpListener::bind(("127.0.0.1", 0)).context("bind rank listener")?;
        let my_addr = listener.local_addr().context("rank listener addr")?.to_string();

        let mut leader = Peer::new(Box::new(TcpTransport::new(
            dial(rdv, 0, &cfg)?,
            cfg.accept_timeout,
        )?));
        leader
            .send(KIND_HELLO, &Hello { rank, ranks, addr: my_addr }.encode())
            .map_err(|e| e.into_comms(0, "rendezvous hello"))?;
        let roster = leader
            .recv(KIND_ROSTER)
            .map_err(|e| e.into_comms(0, "roster wait"))?;
        let addrs = decode_roster(&roster, ranks)?;

        let mut links: Vec<Option<Mutex<Peer>>> = (0..ranks).map(|_| None).collect();
        // Dial every lower rank (they are accepting after the roster).
        for (j, addr) in addrs.iter().enumerate().take(rank).skip(1) {
            let peer_addr = parse_rendezvous(addr)?;
            let t = TcpTransport::new(dial(peer_addr, j, &cfg)?, cfg.accept_timeout)?;
            let mut peer = Peer::new(Box::new(t));
            peer.send(KIND_HELLO, &Hello { rank, ranks, addr: String::new() }.encode())
                .map_err(|e| e.into_comms(j, "mesh hello"))?;
            links[j] = Some(Mutex::new(peer));
        }
        // Accept every higher rank (they dial us).
        listener.set_nonblocking(true).context("listener nonblocking")?;
        let deadline = Instant::now() + cfg.accept_timeout;
        let mut expected = ranks - rank - 1;
        while expected > 0 {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).context("stream blocking")?;
                    let t = TcpTransport::new(stream, cfg.accept_timeout)?;
                    let mut peer = Peer::new(Box::new(t));
                    let payload = peer
                        .recv(KIND_HELLO)
                        .map_err(|e| e.into_comms(usize::MAX, "mesh hello"))?;
                    let hello = Hello::decode(&payload)?;
                    ensure!(
                        hello.rank > rank && hello.rank < ranks,
                        CommsError::Setup {
                            detail: format!(
                                "rank {rank} got a mesh hello from rank {} (expected {}..={})",
                                hello.rank,
                                rank + 1,
                                ranks - 1
                            ),
                        }
                    );
                    ensure!(
                        links[hello.rank].is_none(),
                        CommsError::Setup {
                            detail: format!("two processes claimed rank {}", hello.rank),
                        }
                    );
                    links[hello.rank] = Some(Mutex::new(peer));
                    expected -= 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(CommsError::Setup {
                            detail: format!(
                                "rank {rank} timed out after {:.0?} waiting for {expected} \
                                 higher rank(s) to complete the mesh",
                                cfg.accept_timeout
                            ),
                        });
                    }
                    std::thread::sleep(cfg.retry_every);
                }
                Err(e) => bail!(CommsError::Setup { detail: format!("accept failed: {e}") }),
            }
        }
        links[0] = Some(Mutex::new(leader));
        for (r, link) in links.iter_mut().enumerate() {
            if let Some(l) = link {
                l.get_mut()
                    .expect("fresh lock")
                    .transport
                    .set_io_timeout(cfg.io_timeout)
                    .map_err(|e| e.into_comms(r, "mesh setup"))?;
            }
        }
        Ok(RankGroup { rank, ranks, links })
    }

    /// An in-process full mesh over channels — every group is a
    /// shared-nothing peer exchanging the same frames as the TCP path.
    pub fn mem_mesh(ranks: usize, io_timeout: Duration) -> Vec<RankGroup> {
        let mut txs: Vec<Vec<Option<Sender<Frame>>>> =
            (0..ranks).map(|_| (0..ranks).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Frame>>>> =
            (0..ranks).map(|_| (0..ranks).map(|_| None).collect()).collect();
        for i in 0..ranks {
            for j in 0..ranks {
                if i != j {
                    let (tx, rx) = channel();
                    txs[i][j] = Some(tx); // i -> j sender
                    rxs[j][i] = Some(rx); // j's receiver from i
                }
            }
        }
        (0..ranks)
            .map(|i| {
                let links = (0..ranks)
                    .map(|j| {
                        if i == j {
                            return None;
                        }
                        let tx = txs[i][j].take().expect("sender built");
                        let rx = rxs[i][j].take().expect("receiver built");
                        Some(Mutex::new(Peer::new(Box::new(MemTransport {
                            tx,
                            rx,
                            timeout: io_timeout,
                        }))))
                    })
                    .collect();
                RankGroup { rank: i, ranks, links }
            })
            .collect()
    }

    fn link(&self, peer: usize) -> Result<&Mutex<Peer>, CommsError> {
        if peer == self.rank || peer >= self.ranks {
            return Err(CommsError::Protocol {
                rank: peer,
                detail: format!(
                    "rank {} has no link to rank {peer} (of {})",
                    self.rank, self.ranks
                ),
            });
        }
        self.links[peer].as_ref().ok_or(CommsError::Protocol {
            rank: peer,
            detail: "link missing from mesh".into(),
        })
    }

    fn send_to(
        &self,
        to: usize,
        kind: u8,
        payload: &[u8],
        during: &'static str,
    ) -> Result<(), CommsError> {
        let mut peer = self.link(to)?.lock().expect("link lock poisoned");
        peer.send(kind, payload).map_err(|e| e.into_comms(to, during))
    }

    fn recv_from(
        &self,
        from: usize,
        kind: u8,
        during: &'static str,
    ) -> Result<Vec<u8>, CommsError> {
        let mut peer = self.link(from)?.lock().expect("link lock poisoned");
        peer.recv(kind).map_err(|e| e.into_comms(from, during))
    }

    /// Broadcast `mine` (required on `root`) to every rank; returns the
    /// root's payload everywhere.
    pub fn broadcast(
        &self,
        root: usize,
        mine: Option<&[u8]>,
        during: &'static str,
    ) -> Result<Vec<u8>> {
        if self.rank == root {
            let payload = mine.context("broadcast root must supply a payload")?;
            for r in (0..self.ranks).filter(|&r| r != root) {
                self.send_to(r, KIND_BCAST, payload, during)?;
            }
            Ok(payload.to_vec())
        } else {
            Ok(self.recv_from(root, KIND_BCAST, during)?)
        }
    }

    /// Rank-ordered all-gather: returns every rank's payload, indexed
    /// by rank. Serialized in rank-order rounds (round r: rank r sends
    /// to everyone), so no two ranks ever wait on each other.
    pub fn all_gather(&self, mine: &[u8], during: &'static str) -> Result<Vec<Vec<u8>>> {
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); self.ranks];
        for r in 0..self.ranks {
            if r == self.rank {
                for t in (0..self.ranks).filter(|&t| t != r) {
                    self.send_to(t, KIND_GATHER, mine, during)?;
                }
                out[r] = mine.to_vec();
            } else {
                out[r] = self.recv_from(r, KIND_GATHER, during)?;
            }
        }
        Ok(out)
    }

    /// Every rank waits until every other rank has arrived here.
    pub fn barrier(&self) -> Result<()> {
        self.all_gather(&[], "barrier")?;
        Ok(())
    }

    /// Cross-check a per-step fingerprint (batch hash, step counter)
    /// against rank 0: a mismatch means the ranks' deterministic data
    /// loaders diverged, which would silently break the bitwise
    /// contract — so it fails loudly instead.
    pub fn assert_uniform(&self, label: &str, value: u64) -> Result<()> {
        if self.ranks == 1 {
            return Ok(());
        }
        if self.rank == 0 {
            for r in 1..self.ranks {
                self.send_to(r, KIND_CHECK, &value.to_le_bytes(), "uniformity check")?;
            }
            Ok(())
        } else {
            let bytes = self.recv_from(0, KIND_CHECK, "uniformity check")?;
            ensure!(
                bytes.len() == 8,
                CommsError::Protocol {
                    rank: 0,
                    detail: format!("uniformity check payload has {} bytes, want 8", bytes.len()),
                }
            );
            let v0 = u64::from_le_bytes(bytes[..8].try_into().expect("8 bytes"));
            ensure!(
                v0 == value,
                CommsError::Protocol {
                    rank: self.rank,
                    detail: format!(
                        "{label} diverged: rank 0 has {v0:#018x}, rank {} has {value:#018x} \
                         — per-rank data loaders out of sync?",
                        self.rank
                    ),
                }
            );
            Ok(())
        }
    }

    /// Distributed fixed-order pairwise tree all-reduce.
    ///
    /// Walks the exact schedule of `refmodel::tree_reduce` over
    /// `n_leaves` slots, with leaf ownership given by
    /// [`shard_range`]`(n_leaves, rank, ranks)`. When a pair spans two
    /// ranks, the right owner ships its value to the left owner, who
    /// combines — `combine(left, right)` therefore executes on
    /// identical operands in identical order as the local tree. The
    /// root value (always on the rank owning leaf 0, i.e. rank 0 for
    /// `n_leaves > 0`) is broadcast to every rank.
    pub fn tree_all_reduce<T>(
        &self,
        n_leaves: usize,
        mine: Vec<T>,
        combine: impl Fn(T, T) -> T,
        encode: impl Fn(&T) -> Vec<u8>,
        decode: impl Fn(&[u8]) -> Result<T>,
    ) -> Result<T> {
        let (lo, hi) = shard_range(n_leaves, self.rank, self.ranks);
        ensure!(
            mine.len() == hi - lo,
            "rank {} of {} owns leaves {lo}..{hi} but got {}",
            self.rank,
            self.ranks,
            mine.len()
        );
        let mut slots: Vec<(usize, Option<T>)> = Vec::with_capacity(n_leaves);
        for r in 0..self.ranks {
            let (a, b) = shard_range(n_leaves, r, self.ranks);
            slots.extend((a..b).map(|_| (r, None)));
        }
        for (slot, v) in slots[lo..hi].iter_mut().zip(mine) {
            slot.1 = Some(v);
        }
        while slots.len() > 1 {
            let mut next = Vec::with_capacity(slots.len().div_ceil(2));
            let mut it = slots.into_iter();
            while let Some((oa, va)) = it.next() {
                match it.next() {
                    None => next.push((oa, va)),
                    Some((ob, vb)) => {
                        let combined = if oa == ob {
                            // Local pair (or somebody else's): no traffic.
                            match (va, vb) {
                                (Some(a), Some(b)) => Some(combine(a, b)),
                                _ => None,
                            }
                        } else if oa == self.rank {
                            let bytes = self.recv_from(ob, KIND_REDUCE, "tree reduce")?;
                            let b = decode(&bytes)?;
                            Some(combine(va.expect("own slot filled"), b))
                        } else if ob == self.rank {
                            let b = vb.expect("own slot filled");
                            self.send_to(oa, KIND_REDUCE, &encode(&b), "tree reduce")?;
                            None
                        } else {
                            None
                        };
                        next.push((oa, combined));
                    }
                }
            }
            slots = next;
        }
        let (owner, root) = slots.pop().context("tree reduce over zero leaves")?;
        if self.rank == owner {
            let v = root.expect("root owner holds the value");
            let bytes = encode(&v);
            for r in (0..self.ranks).filter(|&r| r != self.rank) {
                self.send_to(r, KIND_BCAST, &bytes, "reduce broadcast")?;
            }
            Ok(v)
        } else {
            decode(&self.recv_from(owner, KIND_BCAST, "reduce broadcast")?)
        }
    }

    /// Rank-ordered all-gather of f32 slices as raw LE bits.
    pub fn all_gather_f32(&self, mine: &[f32], during: &'static str) -> Result<Vec<Vec<f32>>> {
        let rows = self.all_gather(&f32s_to_le(mine), during)?;
        rows.iter().map(|b| le_to_f32s(b)).collect()
    }
}

/// Dial `addr` with bounded retry: the peer may not be listening yet
/// (process spawn order is unconstrained), so refused connections are
/// retried until `connect_timeout` elapses.
fn dial(addr: SocketAddr, peer: usize, cfg: &CommsCfg) -> Result<TcpStream, CommsError> {
    let deadline = Instant::now() + cfg.connect_timeout;
    let attempt = cfg.retry_every.max(Duration::from_millis(250));
    loop {
        match TcpStream::connect_timeout(&addr, attempt) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(CommsError::Setup {
                        detail: format!(
                            "could not connect to rank {peer} at {addr} within {:.0?}: {e}",
                            cfg.connect_timeout
                        ),
                    });
                }
                std::thread::sleep(cfg.retry_every);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Handshake payloads
// ---------------------------------------------------------------------------

struct Hello {
    rank: usize,
    ranks: usize,
    addr: String,
}

impl Hello {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(10 + self.addr.len());
        out.extend_from_slice(&(self.rank as u32).to_le_bytes());
        out.extend_from_slice(&(self.ranks as u32).to_le_bytes());
        out.extend_from_slice(&(self.addr.len() as u16).to_le_bytes());
        out.extend_from_slice(self.addr.as_bytes());
        out
    }

    fn decode(b: &[u8]) -> Result<Hello> {
        let mut cur = Cursor::new(b);
        let rank = cur.u32()? as usize;
        let ranks = cur.u32()? as usize;
        let len = cur.u16()? as usize;
        let addr = String::from_utf8(cur.bytes(len)?.to_vec()).context("hello addr utf8")?;
        cur.done()?;
        Ok(Hello { rank, ranks, addr })
    }
}

fn encode_roster(addrs: &[String]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(addrs.len() as u16).to_le_bytes());
    for a in addrs {
        out.extend_from_slice(&(a.len() as u16).to_le_bytes());
        out.extend_from_slice(a.as_bytes());
    }
    out
}

/// Roster for `ranks` total ranks: the advertised addresses of ranks
/// `1..ranks`, indexed so `addrs[r]` is rank r's address (`addrs[0]`
/// is empty — rank 0 is the rendezvous itself).
fn decode_roster(b: &[u8], ranks: usize) -> Result<Vec<String>> {
    let mut cur = Cursor::new(b);
    let count = cur.u16()? as usize;
    ensure!(
        count == ranks - 1,
        "roster lists {count} peer ranks, expected {}",
        ranks - 1
    );
    let mut addrs = vec![String::new()];
    for _ in 0..count {
        let len = cur.u16()? as usize;
        addrs.push(String::from_utf8(cur.bytes(len)?.to_vec()).context("roster addr utf8")?);
    }
    cur.done()?;
    Ok(addrs)
}

// ---------------------------------------------------------------------------
// Payload codecs
// ---------------------------------------------------------------------------

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, i: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.i + n <= self.b.len(),
            "payload truncated at byte {} (wanted {n} more of {})",
            self.i,
            self.b.len()
        );
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.bytes(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().expect("4 bytes")))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().expect("4 bytes")))
    }

    fn done(&self) -> Result<()> {
        ensure!(
            self.i == self.b.len(),
            "payload has {} trailing bytes",
            self.b.len() - self.i
        );
        Ok(())
    }
}

fn f32s_to_le(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn le_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    ensure!(b.len() % 4 == 0, "f32 payload has {} bytes (not /4)", b.len());
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect())
}

/// Serialize one microbatch partial `(sum_nll, grads)`. Gradients ride
/// in `BTreeMap` order (sorted by name) with raw LE f32 data.
fn encode_part(part: &(f32, Gradients)) -> Vec<u8> {
    let (nll, grads) = part;
    let mut out = Vec::new();
    out.extend_from_slice(&nll.to_le_bytes());
    out.extend_from_slice(&(grads.len() as u32).to_le_bytes());
    for (name, t) in grads {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        out.extend_from_slice(&f32s_to_le(&t.data));
    }
    out
}

fn decode_part(b: &[u8]) -> Result<(f32, Gradients)> {
    let mut cur = Cursor::new(b);
    let nll = cur.f32()?;
    let n = cur.u32()? as usize;
    let mut grads = Gradients::new();
    for _ in 0..n {
        let name_len = cur.u32()? as usize;
        let name = String::from_utf8(cur.bytes(name_len)?.to_vec()).context("grad name utf8")?;
        let ndims = cur.u32()? as usize;
        let mut shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            shape.push(cur.u32()? as usize);
        }
        let numel: usize = shape.iter().product();
        let data = le_to_f32s(cur.bytes(numel * 4)?)?;
        grads.insert(name, Tensor::from_vec(&shape, data));
    }
    cur.done()?;
    Ok((nll, grads))
}

// ---------------------------------------------------------------------------
// The socket reducer
// ---------------------------------------------------------------------------

/// [`GradReducer`] over a [`RankGroup`]: the distributed leg of the
/// fixed-order pairwise tree (gradient partials as typed frames, f32
/// data as raw LE bits) plus the rank-ordered param all-gather.
pub struct SocketReducer {
    group: Arc<RankGroup>,
}

impl SocketReducer {
    pub fn new(group: Arc<RankGroup>) -> SocketReducer {
        SocketReducer { group }
    }
}

impl GradReducer for SocketReducer {
    fn rank(&self) -> usize {
        self.group.rank()
    }

    fn ranks(&self) -> usize {
        self.group.ranks()
    }

    fn reduce(
        &self,
        n_leaves: usize,
        mine: Vec<(f32, Gradients)>,
    ) -> Result<(f32, Gradients)> {
        ensure!(n_leaves > 0, "batch has no sequences");
        self.group
            .tree_all_reduce(n_leaves, mine, combine_microbatches, encode_part, decode_part)
    }

    fn all_gather_f32(&self, mine: &[f32]) -> Result<Vec<Vec<f32>>> {
        self.group.all_gather_f32(mine, "param all-gather")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The local oracle: refmodel's tree over all leaves at once.
    fn local_tree(n: usize) -> String {
        let leaves: Vec<String> = (0..n).map(|i| i.to_string()).collect();
        crate::runtime::refmodel::tree_reduce(leaves, |a, b| format!("({a}+{b})"))
            .expect("n > 0")
    }

    fn str_codec() -> (
        impl Fn(&String) -> Vec<u8>,
        impl Fn(&[u8]) -> Result<String>,
    ) {
        (
            |s: &String| s.as_bytes().to_vec(),
            |b: &[u8]| Ok(String::from_utf8(b.to_vec()).unwrap()),
        )
    }

    #[test]
    fn topology_validation_messages() {
        assert!(validate_topology(0, 1).is_ok());
        assert!(validate_topology(3, 4).is_ok());
        let e = validate_topology(4, 4).unwrap_err().to_string();
        assert!(e.contains("0..=3"), "{e}");
        let e = validate_topology(0, 0).unwrap_err().to_string();
        assert!(e.contains("1..=64"), "{e}");
        let e = validate_topology(0, MAX_RANKS + 1).unwrap_err().to_string();
        assert!(e.contains("1..=64"), "{e}");
    }

    #[test]
    fn rendezvous_parse_errors_name_the_format() {
        assert!(parse_rendezvous("127.0.0.1:0").is_ok());
        assert!(parse_rendezvous("127.0.0.1:29400").is_ok());
        let e = parse_rendezvous("not-an-address").unwrap_err().to_string();
        assert!(e.contains("host:port"), "{e}");
        let e = parse_rendezvous("127.0.0.1:notaport").unwrap_err().to_string();
        assert!(e.contains("malformed rendezvous"), "{e}");
    }

    #[test]
    fn mem_mesh_tree_reduce_matches_local_tree() {
        // The distributed schedule must reproduce the local pairwise
        // tree bit-for-bit — proven on a non-commutative combine, for
        // every (ranks, leaves) shape including empty-chunk ranks.
        for ranks in 1..=5usize {
            for n_leaves in 1..=9usize {
                let want = local_tree(n_leaves);
                let groups = RankGroup::mem_mesh(ranks, Duration::from_secs(10));
                let results: Vec<String> = std::thread::scope(|s| {
                    let handles: Vec<_> = groups
                        .iter()
                        .map(|g| {
                            s.spawn(move || {
                                let (lo, hi) = shard_range(n_leaves, g.rank(), ranks);
                                let mine: Vec<String> =
                                    (lo..hi).map(|i| i.to_string()).collect();
                                let (enc, dec) = str_codec();
                                g.tree_all_reduce(
                                    n_leaves,
                                    mine,
                                    |a, b| format!("({a}+{b})"),
                                    enc,
                                    dec,
                                )
                                .unwrap()
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                for (r, got) in results.iter().enumerate() {
                    assert_eq!(
                        got, &want,
                        "ranks={ranks} leaves={n_leaves} rank={r}: schedule diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn all_gather_is_rank_ordered() {
        let ranks = 4;
        let groups = RankGroup::mem_mesh(ranks, Duration::from_secs(10));
        let results: Vec<Vec<Vec<u8>>> = std::thread::scope(|s| {
            let handles: Vec<_> = groups
                .iter()
                .map(|g| {
                    s.spawn(move || {
                        let mine = vec![g.rank() as u8; g.rank() + 1];
                        g.all_gather(&mine, "test").unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for rows in results {
            for (r, row) in rows.iter().enumerate() {
                assert_eq!(row, &vec![r as u8; r + 1]);
            }
        }
    }

    #[test]
    fn uniformity_check_names_the_divergence() {
        let groups = RankGroup::mem_mesh(2, Duration::from_secs(10));
        let errs: Vec<Option<String>> = std::thread::scope(|s| {
            let handles: Vec<_> = groups
                .iter()
                .map(|g| {
                    s.spawn(move || {
                        let v = if g.rank() == 0 { 7u64 } else { 8u64 };
                        g.assert_uniform("batch fingerprint", v)
                            .err()
                            .map(|e| e.to_string())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(errs[0].is_none(), "rank 0 only sends");
        let msg = errs[1].as_ref().expect("rank 1 must detect divergence");
        assert!(msg.contains("batch fingerprint"), "{msg}");
        assert!(msg.contains("out of sync"), "{msg}");
    }

    #[test]
    fn dead_peer_is_typed_and_named() {
        // Drop rank 2's group entirely; rank 0's next collective that
        // needs rank 2 must fail with a typed error naming rank 2 —
        // never hang the tree.
        let mut groups = RankGroup::mem_mesh(3, Duration::from_millis(300));
        let g2 = groups.pop().unwrap();
        drop(g2);
        let g0 = &groups[0];
        let msg = g0.all_gather(b"x", "test").unwrap_err().to_string();
        assert!(msg.contains("rank 2"), "error must name the dead rank: {msg}");
        assert!(
            msg.contains("died") || msg.contains("unresponsive"),
            "expected a PeerDead/Timeout message, got: {msg}"
        );
    }

    #[test]
    fn silent_peer_times_out_with_rank() {
        // Both groups alive, but rank 1 never participates: rank 0's
        // receive must time out (bounded) and name rank 1.
        let groups = RankGroup::mem_mesh(2, Duration::from_millis(200));
        let g0 = &groups[0];
        let t0 = Instant::now();
        let msg = g0.all_gather(b"x", "test").unwrap_err().to_string();
        assert!(t0.elapsed() < Duration::from_secs(5), "must be bounded");
        assert!(
            msg.contains("rank 1") && msg.contains("unresponsive"),
            "expected a timeout naming rank 1, got: {msg}"
        );
    }

    #[test]
    fn grad_part_codec_roundtrips_bitwise() {
        let mut grads = Gradients::new();
        grads.insert(
            "layers.0.wq".into(),
            Tensor::from_vec(&[2, 3], vec![1.5, -2.25, f32::MIN_POSITIVE, 0.0, -0.0, 3e38]),
        );
        grads.insert("embed".into(), Tensor::from_vec(&[4], vec![0.1, 0.2, 0.3, 0.4]));
        let part = (0.625f32, grads);
        let back = decode_part(&encode_part(&part)).unwrap();
        assert_eq!(back.0.to_bits(), part.0.to_bits());
        assert_eq!(back.1.len(), part.1.len());
        for ((na, ta), (nb, tb)) in back.1.iter().zip(&part.1) {
            assert_eq!(na, nb);
            assert_eq!(ta.shape, tb.shape);
            let bits_a: Vec<u32> = ta.data.iter().map(|x| x.to_bits()).collect();
            let bits_b: Vec<u32> = tb.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(bits_a, bits_b);
        }
    }

    #[test]
    fn tcp_mesh_smoke() {
        // Real loopback sockets end-to-end: rendezvous, roster, mesh,
        // then a reduce + gather + barrier.
        let ranks = 3usize;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let results: Vec<String> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            {
                let cfg = CommsCfg::fast();
                handles.push(s.spawn(move || {
                    let g = RankGroup::tcp_leader(listener, ranks, cfg).unwrap();
                    run_rank(&g)
                }));
            }
            for rank in 1..ranks {
                let addr = addr.clone();
                let cfg = CommsCfg::fast();
                handles.push(s.spawn(move || {
                    let g = RankGroup::tcp(rank, ranks, &addr, cfg).unwrap();
                    run_rank(&g)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let want = local_tree(5);
        for got in &results {
            assert_eq!(got, &want);
        }

        fn run_rank(g: &RankGroup) -> String {
            let n_leaves = 5;
            let (lo, hi) = shard_range(n_leaves, g.rank(), g.ranks());
            let mine: Vec<String> = (lo..hi).map(|i| i.to_string()).collect();
            let reduced = g
                .tree_all_reduce(
                    n_leaves,
                    mine,
                    |a, b| format!("({a}+{b})"),
                    |s: &String| s.as_bytes().to_vec(),
                    |b: &[u8]| Ok(String::from_utf8(b.to_vec()).unwrap()),
                )
                .unwrap();
            let rows = g
                .all_gather_f32(&[g.rank() as f32], "test")
                .unwrap();
            for (r, row) in rows.iter().enumerate() {
                assert_eq!(row, &vec![r as f32]);
            }
            g.assert_uniform("step", 42).unwrap();
            g.barrier().unwrap();
            reduced
        }
    }

    #[test]
    fn solo_group_is_fully_local() {
        let g = RankGroup::solo();
        assert_eq!((g.rank(), g.ranks()), (0, 1));
        let rows = g.all_gather(b"abc", "test").unwrap();
        assert_eq!(rows, vec![b"abc".to_vec()]);
        g.barrier().unwrap();
        g.assert_uniform("x", 1).unwrap();
        let red = SocketReducer::new(Arc::new(RankGroup::solo()));
        let mut grads = Gradients::new();
        grads.insert("w".into(), Tensor::from_vec(&[1], vec![2.0]));
        let (nll, g2) = red.reduce(1, vec![(1.0, grads)]).unwrap();
        assert_eq!(nll, 1.0);
        assert_eq!(g2["w"].data, vec![2.0]);
    }
}
