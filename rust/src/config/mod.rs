//! Configuration system: a TOML-subset parser + typed run configs.
//!
//! The launcher accepts `--config run.toml` files like:
//!
//! ```toml
//! [run]
//! tag = "e2e_oft_v2"          # artifact bundle to execute
//! steps = 300
//! seed = 42
//!
//! [optim]
//! lr = 4e-4
//! warmup = 20
//! schedule = "cosine"
//! min_lr_frac = 0.1           # paper App. B: cosine to 10% of peak
//!
//! [data]
//! task = "wiki"               # wiki | math | summarize
//! documents = 2000
//!
//! [scenario]
//! coft = true                 # COFT constraint projection
//! eps = 1e-3
//! dropout = 0.1               # module dropout probability
//! target = "wq|wv"            # only matching linears are adapted
//! ```
//!
//! plus CLI overrides `--set optim.lr=1e-4`. The `[scenario]` keys are
//! the same knob spellings the tag-suffix grammar uses
//! ([`crate::scenario::ScenarioCfg`]); they overlay any suffix already
//! on the tag, and the launcher re-canonicalizes the tag so every
//! downstream consumer sees one carrier.

pub mod toml;

use anyhow::{bail, Context, Result};

use crate::runtime::{CheckpointPolicy, TrainOpts};

pub use self::toml::TomlDoc;

/// Learning-rate schedule shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    Constant,
    Cosine,
}

/// Optimizer / schedule settings (Adam hyperparameters live in the AOT
/// graph; the coordinator owns the schedule — paper App. A/B).
#[derive(Clone, Debug)]
pub struct OptimCfg {
    pub lr: f64,
    pub warmup: usize,
    pub schedule: Schedule,
    /// Cosine floor as a fraction of peak LR (paper: 10%).
    pub min_lr_frac: f64,
}

impl Default for OptimCfg {
    fn default() -> Self {
        OptimCfg {
            lr: 4e-4,
            warmup: 20,
            schedule: Schedule::Cosine,
            min_lr_frac: 0.1,
        }
    }
}

impl OptimCfg {
    /// LR at 1-based step `t` out of `total`.
    pub fn lr_at(&self, t: usize, total: usize) -> f64 {
        let t = t.max(1);
        if t <= self.warmup {
            return self.lr * t as f64 / self.warmup.max(1) as f64;
        }
        match self.schedule {
            Schedule::Constant => self.lr,
            Schedule::Cosine => {
                let span = (total.saturating_sub(self.warmup)).max(1) as f64;
                let prog = ((t - self.warmup) as f64 / span).min(1.0);
                let floor = self.lr * self.min_lr_frac;
                floor + 0.5 * (self.lr - floor) * (1.0 + (std::f64::consts::PI * prog).cos())
            }
        }
    }
}

/// Synthetic-data settings.
#[derive(Clone, Debug)]
pub struct DataCfg {
    pub task: String,
    pub documents: usize,
    pub seed: u64,
}

impl Default for DataCfg {
    fn default() -> Self {
        DataCfg {
            task: "wiki".into(),
            documents: 2000,
            seed: 7,
        }
    }
}

/// Training-execution settings: the gradient-checkpoint policy, the
/// data-parallel worker count (`--grad-checkpoint` / `--workers`), and
/// the multi-process rank count (`--ranks`). Defaults reproduce the
/// classic single-process, single-worker, full-tape step; every
/// combination yields a bitwise-identical loss curve on the reference
/// engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrainCfg {
    pub grad_checkpoint: CheckpointPolicy,
    pub workers: usize,
    /// Total rank count of the training group (1 = single-process).
    /// The per-process `--rank` is launcher state, not run config: it
    /// must differ across the group while this struct must not.
    pub ranks: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            grad_checkpoint: CheckpointPolicy::None,
            workers: 1,
            ranks: 1,
        }
    }
}

impl TrainCfg {
    /// The runtime-level options this config selects (rank 0's view;
    /// the trainer swaps in the live rank once the group connects).
    pub fn to_opts(self) -> TrainOpts {
        TrainOpts {
            checkpoint: self.grad_checkpoint,
            workers: self.workers,
            rank: 0,
            ranks: self.ranks,
        }
    }
}

/// A full run configuration.
#[derive(Clone, Debug)]
pub struct RunCfg {
    pub tag: String,
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    pub init_from: Option<String>,
    pub out_dir: Option<String>,
    pub optim: OptimCfg,
    pub data: DataCfg,
    pub train: TrainCfg,
    /// Scenario-knob overrides, overlaid onto the tag's suffix (the
    /// canonical carrier) by the launcher via
    /// [`crate::scenario::apply_to_tag`].
    pub scenario: crate::scenario::ScenarioCfg,
}

impl Default for RunCfg {
    fn default() -> Self {
        RunCfg {
            tag: "tiny_oft_v2".into(),
            steps: 50,
            seed: 42,
            log_every: 10,
            eval_every: 0,
            init_from: None,
            out_dir: None,
            optim: OptimCfg::default(),
            data: DataCfg::default(),
            train: TrainCfg::default(),
            scenario: crate::scenario::ScenarioCfg::default(),
        }
    }
}

impl RunCfg {
    /// Load from a TOML document (missing keys keep defaults).
    pub fn from_toml(doc: &TomlDoc) -> Result<RunCfg> {
        let mut cfg = RunCfg::default();
        cfg.apply_doc(doc)?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<RunCfg> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_toml(&toml::parse(&text)?)
    }

    fn apply_doc(&mut self, doc: &TomlDoc) -> Result<()> {
        for (section, key, value) in doc.entries() {
            self.set(&format!("{section}.{key}"), value)?;
        }
        Ok(())
    }

    /// Apply one dotted-path override (CLI `--set a.b=v`, TOML entries).
    pub fn set(&mut self, path: &str, value: &str) -> Result<()> {
        match path {
            "run.tag" => self.tag = value.into(),
            "run.steps" => self.steps = value.parse()?,
            "run.seed" => self.seed = value.parse()?,
            "run.log_every" => self.log_every = value.parse()?,
            "run.eval_every" => self.eval_every = value.parse()?,
            "run.init_from" => self.init_from = Some(value.into()),
            "run.out_dir" => self.out_dir = Some(value.into()),
            "optim.lr" => self.optim.lr = value.parse()?,
            "optim.warmup" => self.optim.warmup = value.parse()?,
            "optim.min_lr_frac" => self.optim.min_lr_frac = value.parse()?,
            "optim.schedule" => {
                self.optim.schedule = match value {
                    "constant" => Schedule::Constant,
                    "cosine" => Schedule::Cosine,
                    _ => bail!("unknown schedule '{value}'"),
                }
            }
            "data.task" => self.data.task = value.into(),
            "data.documents" => self.data.documents = value.parse()?,
            "data.seed" => self.data.seed = value.parse()?,
            "train.grad_checkpoint" => self.train.grad_checkpoint = CheckpointPolicy::parse(value)?,
            "train.workers" => {
                let n: usize = value.parse().with_context(|| format!("train.workers '{value}'"))?;
                if n == 0 {
                    bail!("--workers must be in 1..=1024, got 0");
                }
                if n > 1024 {
                    bail!("--workers must be in 1..=1024, got {n}");
                }
                self.train.workers = n;
            }
            "train.ranks" => {
                let n: usize = value.parse().with_context(|| format!("train.ranks '{value}'"))?;
                if !(1..=crate::comms::MAX_RANKS).contains(&n) {
                    bail!("--ranks must be in 1..={}, got {n}", crate::comms::MAX_RANKS);
                }
                self.train.ranks = n;
            }
            _ if path.starts_with("scenario.") => {
                // `[scenario]` keys share the tag-suffix knob grammar, so
                // one parser owns the spellings and the error messages.
                let key = &path["scenario.".len()..];
                let part = match (key, value) {
                    ("coft", "true") | ("block_share", "true") => key.to_string(),
                    ("coft", "false") => {
                        self.scenario.coft = false;
                        return Ok(());
                    }
                    ("block_share", "false") => {
                        self.scenario.block_share = false;
                        return Ok(());
                    }
                    _ => format!("{key}={value}"),
                };
                let one = crate::scenario::ScenarioCfg::parse_suffix(&part)
                    .with_context(|| format!("config key '{path}'"))?;
                self.scenario.overlay(&one);
                self.scenario.validate()?;
            }
            _ => bail!("unknown config key '{path}'"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_toml_then_override() {
        let doc = toml::parse(
            "[run]\ntag = \"bench_lora\"\nsteps = 120\n\n[optim]\nlr = 1e-4\nschedule = \"constant\"\n",
        )
        .unwrap();
        let mut cfg = RunCfg::from_toml(&doc).unwrap();
        assert_eq!(cfg.tag, "bench_lora");
        assert_eq!(cfg.steps, 120);
        assert_eq!(cfg.optim.lr, 1e-4);
        assert_eq!(cfg.optim.schedule, Schedule::Constant);
        cfg.set("optim.lr", "5e-5").unwrap();
        assert_eq!(cfg.optim.lr, 5e-5);
        assert!(cfg.set("nope.x", "1").is_err());
    }

    #[test]
    fn train_cfg_keys_and_opts() {
        let mut cfg = RunCfg::default();
        assert_eq!(cfg.train, TrainCfg::default());
        assert_eq!(cfg.train.to_opts(), TrainOpts::default());
        cfg.set("train.grad_checkpoint", "every-2").unwrap();
        cfg.set("train.workers", "4").unwrap();
        cfg.set("train.ranks", "2").unwrap();
        assert_eq!(cfg.train.grad_checkpoint, CheckpointPolicy::EveryK(2));
        assert_eq!(cfg.train.workers, 4);
        assert_eq!(cfg.train.ranks, 2);
        let opts = cfg.train.to_opts();
        assert_eq!(opts.checkpoint, CheckpointPolicy::EveryK(2));
        assert_eq!(opts.workers, 4);
        assert_eq!((opts.rank, opts.ranks), (0, 2));
        assert!(cfg.set("train.grad_checkpoint", "sometimes").is_err());
        // out-of-range topology values error with the valid range
        let e = cfg.set("train.workers", "0").unwrap_err().to_string();
        assert!(e.contains("1..=1024"), "{e}");
        let e = cfg.set("train.ranks", "0").unwrap_err().to_string();
        assert!(e.contains("1..=64"), "{e}");
        let e = cfg.set("train.ranks", "65").unwrap_err().to_string();
        assert!(e.contains("1..=64"), "{e}");
    }

    #[test]
    fn scenario_section_keys() {
        let doc = toml::parse(
            "[scenario]\ncoft = true\neps = 1e-3\ndropout = 0.1\ntarget = \"wq|wv\"\n",
        )
        .unwrap();
        let cfg = RunCfg::from_toml(&doc).unwrap();
        assert!(cfg.scenario.coft);
        assert_eq!(cfg.scenario.eps, 1e-3);
        assert_eq!(cfg.scenario.module_dropout, 0.1);
        assert_eq!(cfg.scenario.target.as_deref(), Some("wq|wv"));
        // flags can be reset, and knobs share the suffix-grammar errors
        let mut cfg = cfg;
        cfg.set("scenario.coft", "false").unwrap();
        assert!(!cfg.scenario.coft);
        let e = format!("{:#}", cfg.set("scenario.warp", "1").unwrap_err());
        assert!(e.contains("valid knobs"), "{e}");
        assert!(e.contains("block_share"), "{e}");
        let e = format!("{:#}", cfg.set("scenario.dropout", "1.5").unwrap_err());
        assert!(e.contains("[0, 1)"), "{e}");
        assert!(cfg.set("scenario.target", "(wq").is_err());
        // r and block stay mutually exclusive across separate sets
        cfg.set("scenario.r", "4").unwrap();
        assert!(cfg.set("scenario.block", "8").is_err());
    }

    #[test]
    fn cosine_schedule_shape() {
        let o = OptimCfg {
            lr: 1.0,
            warmup: 10,
            schedule: Schedule::Cosine,
            min_lr_frac: 0.1,
        };
        // warmup ramps linearly
        assert!((o.lr_at(5, 100) - 0.5).abs() < 1e-12);
        assert!((o.lr_at(10, 100) - 1.0).abs() < 1e-12);
        // decays monotonically to the 10% floor (paper App. B)
        let mut prev = f64::INFINITY;
        for t in 10..=100 {
            let lr = o.lr_at(t, 100);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
        assert!((o.lr_at(100, 100) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn constant_schedule() {
        let o = OptimCfg {
            lr: 0.5,
            warmup: 0,
            schedule: Schedule::Constant,
            min_lr_frac: 0.1,
        };
        assert_eq!(o.lr_at(1, 10), 0.5);
        assert_eq!(o.lr_at(10, 10), 0.5);
    }
}
