//! TOML-subset parser (offline substitute for the `toml` crate).
//!
//! Supports: `[section]` headers, `key = value` with string / number /
//! boolean values, `#` comments, blank lines. Values are kept as raw
//! strings; typed parsing happens in the config layer.

use anyhow::{bail, Result};

/// A parsed document: ordered (section, key, value) triples.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: Vec<(String, String, String)>,
}

impl TomlDoc {
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &str)> {
        self.entries
            .iter()
            .map(|(s, k, v)| (s.as_str(), k.as_str(), v.as_str()))
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.entries
            .iter()
            .rev()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v.as_str())
    }
}

pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: malformed section header '{raw}'", lineno + 1);
            };
            section = name.trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.entries
            .push((section.clone(), key.to_string(), value));
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<String> {
    if v.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = v.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            bail!("unterminated string '{v}'");
        };
        return Ok(inner.replace("\\\"", "\"").replace("\\\\", "\\"));
    }
    Ok(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            "# top comment\n[run]\ntag = \"x_y\"  # trailing\nsteps = 50\n\n[optim]\nlr = 4e-4\nflagish = true\n",
        )
        .unwrap();
        assert_eq!(doc.get("run", "tag"), Some("x_y"));
        assert_eq!(doc.get("run", "steps"), Some("50"));
        assert_eq!(doc.get("optim", "lr"), Some("4e-4"));
        assert_eq!(doc.get("optim", "flagish"), Some("true"));
        assert_eq!(doc.get("nope", "x"), None);
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = parse("[a]\nk = \"x # y\"\n").unwrap();
        assert_eq!(doc.get("a", "k"), Some("x # y"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[broken\n").is_err());
        assert!(parse("[a]\nnovalue\n").is_err());
        assert!(parse("[a]\nk = \"unterminated\n").is_err());
    }

    #[test]
    fn last_write_wins() {
        let doc = parse("[a]\nk = 1\nk = 2\n").unwrap();
        assert_eq!(doc.get("a", "k"), Some("2"));
    }
}
