//! Checkpoints: a name->tensor map in one file (JSON header + raw f32
//! little-endian payload). Used for the pretrain→finetune protocol
//! (`run.init_from`), for saving finetuned adapters, and — under
//! `--ranks N` — for per-rank *shard* files that
//! [`reassemble_sharded`] stitches back into a byte-identical
//! full-state checkpoint.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use super::manifest::Manifest;
use super::state::{ShardInfo, ADAM_M_PREFIX, ADAM_V_PREFIX, STEP_KEY};
use crate::json::{self, Json};
use crate::runtime::shard_range;
use crate::tensor::Tensor;

/// File magic, split from the format version so a future version is
/// reported as "unsupported", not "bad magic". The on-disk bytes of a
/// current-format file are unchanged: `OFTCKPT` + ASCII `1`.
const MAGIC_PREFIX: &[u8; 7] = b"OFTCKPT";
/// Current checkpoint format version, stored as an ASCII digit in the
/// byte after the magic prefix.
const FORMAT_VERSION: u8 = b'1';

/// Key holding one rank's flat first-moment shard.
pub const SHARD_M_KEY: &str = "__adam_shard.m";
/// Key holding one rank's flat second-moment shard.
pub const SHARD_V_KEY: &str = "__adam_shard.v";
/// Key holding the shard topology ([`shard_meta`]).
pub const SHARD_META_KEY: &str = "__adam_shard.meta";

/// An ordered name -> tensor map.
pub type Checkpoint = BTreeMap<String, Tensor>;

/// Write a checkpoint file.
pub fn save(path: impl AsRef<Path>, ckpt: &Checkpoint) -> Result<()> {
    let mut header_entries = Vec::new();
    let mut offset = 0usize;
    for (name, t) in ckpt {
        header_entries.push(Json::obj(vec![
            ("name", Json::str(name.clone())),
            (
                "shape",
                Json::arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("offset", Json::num(offset as f64)),
        ]));
        offset += t.numel();
    }
    let header = Json::obj(vec![
        ("entries", Json::arr(header_entries)),
        ("total", Json::num(offset as f64)),
    ])
    .to_string();

    let file = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(MAGIC_PREFIX)?;
    w.write_all(&[FORMAT_VERSION])?;
    w.write_all(&(header.len() as u32).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    for t in ckpt.values() {
        for x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a checkpoint file.
pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic[..7] != MAGIC_PREFIX || !magic[7].is_ascii_digit() {
        bail!("not an OFT checkpoint: bad magic");
    }
    if magic[7] != FORMAT_VERSION {
        bail!(
            "checkpoint format v{} unsupported (max {})",
            (magic[7] - b'0'),
            (FORMAT_VERSION - b'0')
        );
    }
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    let mut hbytes = vec![0u8; hlen];
    r.read_exact(&mut hbytes)?;
    let header = json::parse(std::str::from_utf8(&hbytes)?)?;

    let total = header.get("total")?.as_usize()?;
    let mut payload = vec![0u8; total * 4];
    r.read_exact(&mut payload)?;
    let floats: Vec<f32> = payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let mut ckpt = Checkpoint::new();
    for e in header.get("entries")?.as_arr()? {
        let name = e.get("name")?.as_str()?.to_string();
        let shape = e.get("shape")?.as_shape()?;
        let offset = e.get("offset")?.as_usize()?;
        let n: usize = shape.iter().product();
        if offset + n > floats.len() {
            bail!("checkpoint entry '{name}' overruns payload");
        }
        ckpt.insert(name, Tensor::from_vec(&shape, floats[offset..offset + n].to_vec()));
    }
    Ok(ckpt)
}

/// Path of rank `rank`'s shard file for a run saving to `path`:
/// `<path>.rank<r>of<R>` (rank 0's shard rides next to — not inside —
/// the full-format file name, so `load(path)` semantics never change).
pub fn shard_checkpoint_path(path: impl AsRef<Path>, rank: usize, ranks: usize) -> PathBuf {
    let p = path.as_ref();
    let mut s = p.as_os_str().to_os_string();
    s.push(format!(".rank{rank}of{ranks}"));
    PathBuf::from(s)
}

/// Encode a shard topology as six integers exact in f32 (payloads are
/// f32-only): `[rank, ranks, lo & 0xffff, lo >> 16, hi & 0xffff,
/// hi >> 16]` — 16-bit halves keep element offsets exact up to 2^32.
pub fn shard_meta(info: ShardInfo) -> Tensor {
    Tensor::from_vec(
        &[6],
        vec![
            info.rank as f32,
            info.ranks as f32,
            (info.lo & 0xffff) as f32,
            (info.lo >> 16) as f32,
            (info.hi & 0xffff) as f32,
            (info.hi >> 16) as f32,
        ],
    )
}

/// Decode [`shard_meta`].
pub fn parse_shard_meta(t: &Tensor) -> Result<ShardInfo> {
    ensure!(
        t.data.len() == 6,
        "'{SHARD_META_KEY}' holds {} values, expected 6",
        t.data.len()
    );
    let u = |x: f32| x as usize;
    let d = &t.data;
    Ok(ShardInfo {
        rank: u(d[0]),
        ranks: u(d[1]),
        lo: u(d[2]) | (u(d[3]) << 16),
        hi: u(d[4]) | (u(d[5]) << 16),
    })
}

/// Reassemble a full-state checkpoint from the per-rank shard files of
/// one `--ranks N` run (`parts`: one [`Checkpoint`] per rank, any
/// order). Validates that the shards tile `man`'s flat trainable space
/// exactly and agree on the step counter, then emits rank 0's weight
/// entries plus the re-concatenated `__adam_m.*` / `__adam_v.*`
/// moments — byte-identical (through [`save`]) to the
/// `checkpoint_full()` a single-process run would have written.
pub fn reassemble_sharded(man: &Manifest, parts: &[Checkpoint]) -> Result<Checkpoint> {
    ensure!(!parts.is_empty(), "no shard checkpoints given");
    let ranks = parts.len();
    let total: usize = man.trainable.iter().map(|s| s.numel()).sum();
    let mut by_rank: Vec<Option<&Checkpoint>> = vec![None; ranks];
    for part in parts {
        let meta = part.get(SHARD_META_KEY).with_context(|| {
            format!("checkpoint lacks '{SHARD_META_KEY}' — not a rank shard file?")
        })?;
        let info = parse_shard_meta(meta)?;
        ensure!(
            info.ranks == ranks,
            "shard file says the run had {} ranks, but {ranks} shard file(s) were given",
            info.ranks
        );
        ensure!(
            info.rank < ranks,
            "shard file claims rank {} of {ranks}",
            info.rank
        );
        ensure!(
            by_rank[info.rank].is_none(),
            "two shard files claim rank {}",
            info.rank
        );
        let (lo, hi) = shard_range(total, info.rank, ranks);
        ensure!(
            (info.lo, info.hi) == (lo, hi),
            "rank {} shard covers elements {}..{}, but manifest '{}' shards as {lo}..{hi}",
            info.rank,
            info.lo,
            info.hi,
            man.tag
        );
        by_rank[info.rank] = Some(part);
    }
    let mut m_flat = Vec::with_capacity(total);
    let mut v_flat = Vec::with_capacity(total);
    let mut step: Option<f32> = None;
    for (r, slot) in by_rank.iter().enumerate() {
        let part = slot.expect("every rank present (validated above)");
        let (lo, hi) = shard_range(total, r, ranks);
        let m = part
            .get(SHARD_M_KEY)
            .with_context(|| format!("rank {r} shard lacks '{SHARD_M_KEY}'"))?;
        let v = part
            .get(SHARD_V_KEY)
            .with_context(|| format!("rank {r} shard lacks '{SHARD_V_KEY}'"))?;
        ensure!(
            m.data.len() == hi - lo && v.data.len() == hi - lo,
            "rank {r} shard holds {} moment elements, expected {}",
            m.data.len(),
            hi - lo
        );
        m_flat.extend_from_slice(&m.data);
        v_flat.extend_from_slice(&v.data);
        let s = part
            .get(STEP_KEY)
            .with_context(|| format!("rank {r} shard lacks '{STEP_KEY}'"))?
            .data
            .first()
            .copied()
            .unwrap_or(0.0);
        match step {
            None => step = Some(s),
            Some(prev) => ensure!(
                prev == s,
                "shard files disagree on the step counter ({prev} vs {s}) — \
                 shards from different runs?"
            ),
        }
    }
    // Rank 0's shard carries the full weight checkpoint; keep all of it
    // except the shard-local keys, then splice the gathered moments in.
    let mut out = Checkpoint::new();
    for (name, t) in by_rank[0].expect("rank 0 present") {
        if name == SHARD_M_KEY || name == SHARD_V_KEY || name == SHARD_META_KEY {
            continue;
        }
        out.insert(name.clone(), t.clone());
    }
    let mut off = 0usize;
    for spec in &man.trainable {
        let n = spec.numel();
        out.insert(
            format!("{ADAM_M_PREFIX}{}", spec.name),
            Tensor::from_vec(&spec.shape, m_flat[off..off + n].to_vec()),
        );
        out.insert(
            format!("{ADAM_V_PREFIX}{}", spec.name),
            Tensor::from_vec(&spec.shape, v_flat[off..off + n].to_vec()),
        );
        off += n;
    }
    ensure!(
        out.contains_key(STEP_KEY),
        "rank 0 shard lacks the '{STEP_KEY}' entry"
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("oft_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let mut ck = Checkpoint::new();
        ck.insert("embed.tok".into(), Tensor::randn(&[16, 8], 0.1, &mut rng));
        ck.insert("final_norm".into(), Tensor::ones(&[8]));
        ck.insert("layers.0.attn.wq".into(), Tensor::randn(&[8, 8], 0.02, &mut rng));
        let p = tmp("roundtrip");
        save(&p, &ck).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(load(&p).is_err());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn future_format_version_names_itself() {
        // A bumped format-version byte is "unsupported vN", not "bad
        // magic" — the forward-compat contract of the magic/version
        // split.
        let mut rng = Rng::new(2);
        let mut ck = Checkpoint::new();
        ck.insert("w".into(), Tensor::randn(&[4, 4], 0.1, &mut rng));
        let p = tmp("future_version");
        save(&p, &ck).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        assert_eq!(bytes[7], b'1');
        bytes[7] = b'2';
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(
            err.contains("checkpoint format v2 unsupported (max 1)"),
            "{err}"
        );
        // a non-digit version byte is still plain bad magic
        bytes[7] = b'X';
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn empty_checkpoint() {
        let p = tmp("empty");
        save(&p, &Checkpoint::new()).unwrap();
        assert!(load(&p).unwrap().is_empty());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn shard_meta_roundtrips_exactly() {
        for info in [
            ShardInfo { rank: 0, ranks: 1, lo: 0, hi: 10 },
            ShardInfo { rank: 3, ranks: 4, lo: 100_000, hi: 133_333 },
            ShardInfo { rank: 1, ranks: 2, lo: 70_000, hi: 140_000 },
        ] {
            assert_eq!(parse_shard_meta(&shard_meta(info)).unwrap(), info);
        }
        assert!(parse_shard_meta(&Tensor::zeros(&[3])).is_err());
    }

    #[test]
    fn shard_path_suffix() {
        let p = shard_checkpoint_path("run.ckpt", 2, 4);
        assert_eq!(p.to_str().unwrap(), "run.ckpt.rank2of4");
    }

    #[test]
    fn reassemble_validates_and_tiles() {
        let man =
            Manifest::load_or_builtin(crate::artifacts_root().join("tiny_oft_v2")).unwrap();
        let total: usize = man.trainable.iter().map(|s| s.numel()).sum();
        let ranks = 2usize;
        let mut parts = Vec::new();
        for rank in 0..ranks {
            let (lo, hi) = shard_range(total, rank, ranks);
            let mut ck = Checkpoint::new();
            ck.insert(
                SHARD_M_KEY.into(),
                Tensor::from_vec(&[hi - lo], (lo..hi).map(|i| i as f32).collect()),
            );
            ck.insert(
                SHARD_V_KEY.into(),
                Tensor::from_vec(&[hi - lo], (lo..hi).map(|i| -(i as f32)).collect()),
            );
            ck.insert(
                SHARD_META_KEY.into(),
                shard_meta(ShardInfo { rank, ranks, lo, hi }),
            );
            ck.insert(STEP_KEY.into(), Tensor::from_vec(&[1], vec![5.0]));
            if rank == 0 {
                ck.insert("some_weight".into(), Tensor::ones(&[2]));
            }
            parts.push(ck);
        }
        parts.reverse(); // file discovery order must not matter
        let full = reassemble_sharded(&man, &parts).unwrap();
        assert!(full.contains_key("some_weight"));
        assert!(!full.contains_key(SHARD_M_KEY));
        assert!(!full.contains_key(SHARD_META_KEY));
        assert_eq!(full.get(STEP_KEY).unwrap().data, vec![5.0]);
        // moments re-tile flat values back into manifest shapes
        let mut off = 0usize;
        for spec in &man.trainable {
            let m = full.get(&format!("{ADAM_M_PREFIX}{}", spec.name)).unwrap();
            assert_eq!(m.shape, spec.shape);
            assert_eq!(
                m.data,
                (off..off + spec.numel()).map(|i| i as f32).collect::<Vec<_>>()
            );
            off += spec.numel();
        }
        assert_eq!(off, total);
        // wrong shard-file count is rejected, not silently truncated
        assert!(reassemble_sharded(&man, &parts[..1]).is_err());
    }
}
