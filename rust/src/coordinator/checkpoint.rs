//! Checkpoints: a name->tensor map in one file (JSON header + raw f32
//! little-endian payload). Used for the pretrain→finetune protocol
//! (`run.init_from`) and for saving finetuned adapters.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::{self, Json};
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"OFTCKPT1";

/// An ordered name -> tensor map.
pub type Checkpoint = BTreeMap<String, Tensor>;

/// Write a checkpoint file.
pub fn save(path: impl AsRef<Path>, ckpt: &Checkpoint) -> Result<()> {
    let mut header_entries = Vec::new();
    let mut offset = 0usize;
    for (name, t) in ckpt {
        header_entries.push(Json::obj(vec![
            ("name", Json::str(name.clone())),
            (
                "shape",
                Json::arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("offset", Json::num(offset as f64)),
        ]));
        offset += t.numel();
    }
    let header = Json::obj(vec![
        ("entries", Json::arr(header_entries)),
        ("total", Json::num(offset as f64)),
    ])
    .to_string();

    let file = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(header.len() as u32).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    for t in ckpt.values() {
        for x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a checkpoint file.
pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an OFT checkpoint: bad magic");
    }
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    let mut hbytes = vec![0u8; hlen];
    r.read_exact(&mut hbytes)?;
    let header = json::parse(std::str::from_utf8(&hbytes)?)?;

    let total = header.get("total")?.as_usize()?;
    let mut payload = vec![0u8; total * 4];
    r.read_exact(&mut payload)?;
    let floats: Vec<f32> = payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let mut ckpt = Checkpoint::new();
    for e in header.get("entries")?.as_arr()? {
        let name = e.get("name")?.as_str()?.to_string();
        let shape = e.get("shape")?.as_shape()?;
        let offset = e.get("offset")?.as_usize()?;
        let n: usize = shape.iter().product();
        if offset + n > floats.len() {
            bail!("checkpoint entry '{name}' overruns payload");
        }
        ckpt.insert(name, Tensor::from_vec(&shape, floats[offset..offset + n].to_vec()));
    }
    Ok(ckpt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("oft_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let mut ck = Checkpoint::new();
        ck.insert("embed.tok".into(), Tensor::randn(&[16, 8], 0.1, &mut rng));
        ck.insert("final_norm".into(), Tensor::ones(&[8]));
        ck.insert("layers.0.attn.wq".into(), Tensor::randn(&[8, 8], 0.02, &mut rng));
        let p = tmp("roundtrip");
        save(&p, &ck).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(load(&p).is_err());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn empty_checkpoint() {
        let p = tmp("empty");
        save(&p, &Checkpoint::new()).unwrap();
        assert!(load(&p).unwrap().is_empty());
        let _ = std::fs::remove_file(p);
    }
}
