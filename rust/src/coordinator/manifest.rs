//! `manifest.json` — the L2→L3 contract for one artifact bundle.
//!
//! The manifest lists every graph input *in graph order* (trainables,
//! then frozen, then quantized packs, then data), with shapes, dtypes
//! and init specs. The coordinator never re-derives these numbers; it
//! uploads buffers in exactly the recorded order.
//!
//! Bundles come from two equivalent sources:
//!
//! * [`Manifest::load`] — parse `<dir>/manifest.json` written by
//!   `python -m compile.aot` (required for the PJRT backend, which
//!   also needs the HLO files it names);
//! * [`Manifest::builtin`] — synthesize the identical contract from a
//!   bundle tag (`<preset>_<method>[_<quant>]`), mirroring
//!   `aot.build_manifest` field-for-field, so the reference engine
//!   needs no artifact tree at all. [`Manifest::load_or_builtin`]
//!   picks whichever is available.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::json::{self, Json};
use crate::runtime::Dtype;
use crate::scenario::{self, ScenarioCfg, ScenarioDims};

/// Parameter initialization spec (`init` field).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Init {
    Normal(f32),
    Zeros,
    Ones,
}

impl Init {
    fn parse(j: &Json) -> Result<Init> {
        let arr = j.as_arr()?;
        let kind = arr[0].as_str()?;
        let std = arr[1].as_f64()? as f32;
        Ok(match kind {
            "normal" => Init::Normal(std),
            "zeros" => Init::Zeros,
            "ones" => Init::Ones,
            _ => bail!("unknown init kind '{kind}'"),
        })
    }
}

/// One f32 parameter input (trainable or frozen).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One quantized-pack input (codes / scales / metadata tensor).
#[derive(Clone, Debug)]
pub struct QuantSpec {
    /// Graph input name, e.g. `layers.0.attn.wq.nf4_codes`.
    pub name: String,
    /// The base weight it packs, e.g. `layers.0.attn.wq`.
    pub base: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// Model dimensions recorded by the manifest.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub block_b: usize,
    pub neumann_k: usize,
    pub lora_r: usize,
    pub lora_alpha: f64,
    /// The numeric scenario knobs (COFT, module dropout, block_share,
    /// `r`), `Copy` so every adapter hook sees them without new
    /// arguments. Targeting regexes live on the [`Manifest`].
    pub scenario: ScenarioDims,
}

impl ModelDims {
    /// A dims carrier for paper-scale counting/memory analyses, where
    /// only the adapter hyperparameters (rank, block size) matter: the
    /// adapted-linear shapes come from a [`crate::modelspec::ModelSpec`]
    /// instead of these transformer dims.
    pub fn analysis(lora_r: usize, block_b: usize) -> ModelDims {
        ModelDims {
            vocab: 0,
            d_model: 0,
            n_layers: 0,
            n_heads: 1,
            d_ff: 0,
            seq_len: 0,
            batch: 0,
            block_b,
            neumann_k: 5,
            lora_r,
            lora_alpha: 2.0 * lora_r as f64,
            scenario: ScenarioDims::default(),
        }
    }
}

/// `(name, din, dout)` of every adapted linear of `dims`, in graph
/// order — the one list bundle synthesis, the per-step adapter plan,
/// and the decode resolver all share (mirrors `linear_names()` in
/// python/compile/model.py).
pub fn adapted_linear_dims(dims: &ModelDims) -> Vec<(String, usize, usize)> {
    let (d, f) = (dims.d_model, dims.d_ff);
    let mut linears = Vec::with_capacity(6 * dims.n_layers);
    for i in 0..dims.n_layers {
        for proj in ["wq", "wk", "wv", "wo"] {
            linears.push((format!("layers.{i}.attn.{proj}"), d, d));
        }
        linears.push((format!("layers.{i}.mlp.up"), d, f));
        linears.push((format!("layers.{i}.mlp.down"), f, d));
    }
    linears
}

/// A parsed artifact-bundle manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tag: String,
    pub preset: String,
    pub method: String,
    pub quant: String,
    pub model: ModelDims,
    pub params_base: u64,
    pub params_trainable: u64,
    pub trainable: Vec<ParamSpec>,
    pub frozen: Vec<ParamSpec>,
    pub quantized: Vec<QuantSpec>,
    /// The full typed scenario (the tag suffix, parsed and validated
    /// against the method's supported knobs).
    pub scenario: ScenarioCfg,
    /// Adapted linears the targeting regexes deselected, sorted. These
    /// carry no trainables and run the frozen base path everywhere
    /// (train, decode, serve, merge, counting, memory pricing).
    pub skipped: Vec<String>,
    pub adam: (f64, f64, f64),
    pub train_step_file: String,
    pub eval_loss_file: String,
    pub logits_last_file: String,
}

/// Model-shape presets mirrored from `python/compile/configs.PRESETS`:
/// (vocab, d_model, n_layers, n_heads, d_ff, seq_len, batch, block_b,
/// lora_r).
const PRESETS: [(&str, [usize; 9]); 6] = [
    ("tiny", [256, 64, 2, 2, 256, 48, 4, 16, 4]),
    ("small", [512, 128, 2, 4, 512, 64, 8, 32, 8]),
    ("bench", [512, 256, 4, 8, 1024, 128, 8, 32, 16]),
    ("fig1", [512, 1024, 2, 8, 2048, 32, 4, 32, 16]),
    ("e2e", [4096, 512, 6, 8, 2048, 256, 8, 32, 16]),
    ("e2e100m", [8192, 896, 8, 14, 3584, 256, 4, 32, 16]),
];

/// Split a bundle tag into (preset, method, quant), ignoring any
/// scenario suffix. Method spellings come from the adapter registry,
/// so a newly registered method is a valid tag with no list to update
/// here.
pub fn parse_tag(tag: &str) -> Result<(String, String, String)> {
    let (preset, method, quant, _) = parse_tag_full(tag)?;
    Ok((preset, method, quant))
}

/// As [`parse_tag`], also parsing the tag's scenario suffix:
/// `<preset>_<method>[_<quant>][+knob[=value]...]`.
pub fn parse_tag_full(tag: &str) -> Result<(String, String, String, ScenarioCfg)> {
    let (base, sc) = scenario::split_tag(tag)?;
    let (preset, rest) = base
        .split_once('_')
        .with_context(|| format!("bundle tag '{tag}' is not <preset>_<method>[_<quant>][+knobs]"))?;
    for method in crate::adapters::names() {
        if rest == method {
            return Ok((preset.to_string(), method.to_string(), "none".to_string(), sc));
        }
        for quant in ["nf4", "awq"] {
            if rest == format!("{method}_{quant}") {
                return Ok((preset.to_string(), method.to_string(), quant.to_string(), sc));
            }
        }
    }
    bail!(
        "bundle tag '{tag}' names no known method; registered methods: {}",
        crate::adapters::names().join(", ")
    )
}

/// NF4 pack sizes for a flat tensor of `n` elements (mirrors
/// `python/compile/kernels/nf4.packed_sizes`): (code bytes, absmax
/// blocks, double-quant groups) after padding to whole tiles.
fn nf4_packed_sizes(n: usize) -> (usize, usize, usize) {
    let tile = crate::quant::NF4_TILE;
    let npad = n.div_ceil(tile) * tile;
    let nblocks = npad / crate::quant::NF4_BLOCK;
    (npad / 2, nblocks, nblocks / crate::quant::NF4_GROUP)
}

impl Manifest {
    /// Synthesize the bundle contract for `tag` without an artifact
    /// tree — the reference engine's path. Field-for-field identical to
    /// what `aot.build_manifest` writes to manifest.json.
    pub fn builtin(tag: &str) -> Result<Manifest> {
        let (preset, method, quant, sc) = parse_tag_full(tag)?;
        let dims = PRESETS
            .iter()
            .find(|(name, _)| *name == preset)
            .map(|(_, d)| *d)
            .with_context(|| format!("unknown preset '{preset}'"))?;
        let [vocab, d_model, n_layers, n_heads, d_ff, seq_len, batch, block_b, lora_r] = dims;
        let model = ModelDims {
            vocab,
            d_model,
            n_layers,
            n_heads,
            d_ff,
            seq_len,
            batch,
            // the 'block' knob overrides the preset's block size
            block_b: if sc.block > 0 { sc.block } else { block_b },
            neumann_k: 5,
            lora_r,
            lora_alpha: 16.0,
            scenario: sc.dims(),
        };
        let adapter = crate::adapters::get(&method)?;
        // The method accepts or rejects the scenario (typed errors
        // naming its supported knobs) before anything is synthesized.
        adapter.configure(&sc)?;
        let is_quantized = adapter.quantized_base();
        ensure!(
            is_quantized == (quant != "none"),
            "method '{method}' is inconsistent with quant '{quant}'"
        );
        adapter.validate_dims(&model)?;
        let d = d_model;

        // (name, din, dout) for every adapted linear, in graph order.
        let linears = adapted_linear_dims(&model);

        // Resolve the targeting regexes once, here: the skipped set
        // drives trainable synthesis, runtime fallback, decode, merge,
        // counting, and memory pricing from this single answer.
        let linear_names: Vec<String> = linears.iter().map(|(n, _, _)| n.clone()).collect();
        let skipped = sc.resolve_skipped(&linear_names)?;

        // Base (pretrained) parameter specs.
        let mut base: Vec<ParamSpec> = vec![
            ParamSpec {
                name: "embed.tok".into(),
                shape: vec![vocab, d],
                init: Init::Normal(0.02),
            },
            ParamSpec {
                name: "embed.pos".into(),
                shape: vec![seq_len, d],
                init: Init::Normal(0.01),
            },
            ParamSpec {
                name: "final_norm".into(),
                shape: vec![d],
                init: Init::Ones,
            },
            ParamSpec {
                name: "lm_head".into(),
                shape: vec![d, vocab],
                init: Init::Normal(0.02),
            },
        ];
        for i in 0..n_layers {
            for norm in ["attn.norm", "mlp.norm"] {
                base.push(ParamSpec {
                    name: format!("layers.{i}.{norm}"),
                    shape: vec![d],
                    init: Init::Ones,
                });
            }
        }
        for (name, din, dout) in &linears {
            base.push(ParamSpec {
                name: name.clone(),
                shape: vec![*din, *dout],
                init: Init::Normal(0.02),
            });
        }
        base.sort_by(|a, b| a.name.cmp(&b.name));

        // Trainable specs, declared by the adapter itself (sorted by
        // name, like aot.py): the whole base for base-training methods,
        // else the method's per-linear adapter parameters.
        let mut trainable: Vec<ParamSpec> = if adapter.trains_base() {
            base.clone()
        } else {
            linears
                .iter()
                .filter(|(name, _, _)| !skipped.contains(name))
                .flat_map(|(name, din, dout)| adapter.linear_trainables(name, *din, *dout, &model))
                .collect()
        };
        trainable.sort_by(|a, b| a.name.cmp(&b.name));

        // Frozen base inputs: everything for full-precision adapter
        // methods, non-linear tensors for quantized ones, none for
        // base-training methods (their base lives in the trainables).
        let frozen: Vec<ParamSpec> = if adapter.trains_base() {
            Vec::new()
        } else if is_quantized {
            base.iter()
                .filter(|s| !linears.iter().any(|(n, _, _)| n == &s.name))
                .cloned()
                .collect()
        } else {
            base.clone()
        };

        // Quantized packs, in linear order (not sorted — graph order).
        let mut quantized: Vec<QuantSpec> = Vec::new();
        if is_quantized {
            for (name, din, dout) in &linears {
                let n = din * dout;
                if quant == "nf4" {
                    let (nbytes, nblocks, ngroups) = nf4_packed_sizes(n);
                    let packs = [
                        ("nf4_codes", vec![nbytes], Dtype::U8),
                        ("nf4_absmax_q", vec![nblocks], Dtype::I8),
                        ("nf4_absmax_s", vec![ngroups], Dtype::F32),
                        ("nf4_offset", vec![1], Dtype::F32),
                    ];
                    for (suffix, shape, dtype) in packs {
                        quantized.push(QuantSpec {
                            name: format!("{name}.{suffix}"),
                            base: name.clone(),
                            shape,
                            dtype,
                        });
                    }
                } else {
                    let g = din / crate::quant::AWQ_GROUP;
                    let packs = [
                        ("awq_codes", vec![din / 2, *dout], Dtype::U8),
                        ("awq_scales", vec![g, *dout], Dtype::F32),
                        ("awq_eq", vec![*din], Dtype::F32),
                    ];
                    for (suffix, shape, dtype) in packs {
                        quantized.push(QuantSpec {
                            name: format!("{name}.{suffix}"),
                            base: name.clone(),
                            shape,
                            dtype,
                        });
                    }
                }
            }
        }

        // Parameter counts (mirrors configs.param_count).
        let params_base: u64 = base.iter().map(|s| s.numel() as u64).sum();
        let params_trainable: u64 = trainable.iter().map(|s| s.numel() as u64).sum();

        Ok(Manifest {
            dir: crate::artifacts_root().join(tag),
            tag: tag.to_string(),
            preset,
            method,
            quant,
            model,
            params_base,
            params_trainable,
            trainable,
            frozen,
            quantized,
            scenario: sc,
            skipped,
            adam: (0.9, 0.999, 1e-8),
            train_step_file: "train_step.hlo.txt".to_string(),
            eval_loss_file: "eval_loss.hlo.txt".to_string(),
            logits_last_file: "logits_last.hlo.txt".to_string(),
        })
    }

    /// `load` when `<dir>/manifest.json` exists, else [`Manifest::builtin`]
    /// derived from the directory name.
    pub fn load_or_builtin(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        if dir.join("manifest.json").exists() {
            return Manifest::load(dir);
        }
        let tag = dir
            .file_name()
            .and_then(|s| s.to_str())
            .with_context(|| format!("bundle path '{}' has no tag name", dir.display()))?;
        Manifest::builtin(tag).with_context(|| {
            format!(
                "no manifest.json under {} and tag is not a builtin bundle",
                dir.display()
            )
        })
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let j = json::parse_file(dir.join("manifest.json")).with_context(|| {
            format!(
                "loading bundle manifest {} (run `make artifacts`)",
                dir.display()
            )
        })?;

        // The tag's scenario suffix is authoritative for loaded bundles
        // too (manifest.json predates the scenario subsystem).
        let tag = j.get("tag")?.as_str()?.to_string();
        let sc = scenario::split_tag(&tag).map(|(_, s)| s).unwrap_or_default();

        let m = j.get("model")?;
        let model = ModelDims {
            vocab: m.get("vocab")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            seq_len: m.get("seq_len")?.as_usize()?,
            batch: m.get("batch")?.as_usize()?,
            block_b: m.get("block_b")?.as_usize()?,
            neumann_k: m.get("neumann_k")?.as_usize()?,
            lora_r: m.get("lora_r")?.as_usize()?,
            lora_alpha: m.get("lora_alpha")?.as_f64()?,
            scenario: sc.dims(),
        };
        let linear_names: Vec<String> = adapted_linear_dims(&model)
            .into_iter()
            .map(|(n, _, _)| n)
            .collect();
        let skipped = sc.resolve_skipped(&linear_names)?;

        let param_spec = |e: &Json| -> Result<ParamSpec> {
            Ok(ParamSpec {
                name: e.get("name")?.as_str()?.to_string(),
                shape: e.get("shape")?.as_shape()?,
                init: Init::parse(e.get("init")?)?,
            })
        };
        let inputs = j.get("inputs")?;
        let trainable = inputs
            .get("trainable")?
            .as_arr()?
            .iter()
            .map(param_spec)
            .collect::<Result<Vec<_>>>()?;
        let frozen = inputs
            .get("frozen")?
            .as_arr()?
            .iter()
            .map(param_spec)
            .collect::<Result<Vec<_>>>()?;
        let quantized = inputs
            .get("quantized")?
            .as_arr()?
            .iter()
            .map(|e| -> Result<QuantSpec> {
                Ok(QuantSpec {
                    name: e.get("name")?.as_str()?.to_string(),
                    base: e.get("base")?.as_str()?.to_string(),
                    shape: e.get("shape")?.as_shape()?,
                    dtype: Dtype::parse(e.get("dtype")?.as_str()?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let adam = j.get("adam")?;
        let art = j.get("artifacts")?;
        let params = j.get("params")?;
        Ok(Manifest {
            dir,
            tag,
            preset: j.get("preset")?.as_str()?.to_string(),
            method: j.get("method")?.as_str()?.to_string(),
            quant: j.get("quant")?.as_str()?.to_string(),
            model,
            params_base: params.get("base")?.as_usize()? as u64,
            params_trainable: params.get("trainable")?.as_usize()? as u64,
            trainable,
            frozen,
            quantized,
            scenario: sc,
            skipped,
            adam: (
                adam.get("b1")?.as_f64()?,
                adam.get("b2")?.as_f64()?,
                adam.get("eps")?.as_f64()?,
            ),
            train_step_file: art.get("train_step")?.as_str()?.to_string(),
            eval_loss_file: art.get("eval_loss")?.as_str()?.to_string(),
            logits_last_file: art.get("logits_last")?.as_str()?.to_string(),
        })
    }

    /// Path of one artifact file.
    pub fn artifact(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Unique base weights behind the quantized packs, in first-seen
    /// (graph) order.
    pub fn quantized_bases(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for q in &self.quantized {
            if !seen.contains(&q.base) {
                seen.push(q.base.clone());
            }
        }
        seen
    }

    /// The (din, dout) of a base linear weight referenced by a quantized
    /// pack — mirrors `linear_names()` in python/compile/model.py.
    pub fn linear_shape(&self, base: &str) -> Result<(usize, usize)> {
        let (d, f) = (self.model.d_model, self.model.d_ff);
        if base.ends_with(".mlp.up") {
            Ok((d, f))
        } else if base.ends_with(".mlp.down") {
            Ok((f, d))
        } else if base.contains(".attn.w") {
            Ok((d, d))
        } else {
            bail!("'{base}' is not an adapted linear weight");
        }
    }

    /// Whether `linear` is adapted under this bundle's targeting
    /// (skipped linears run the frozen base path everywhere).
    pub fn adapts(&self, linear: &str) -> bool {
        !self.skipped.iter().any(|s| s == linear)
    }

    /// Total trainable elements (must equal `params_trainable`).
    pub fn trainable_numel(&self) -> u64 {
        self.trainable.iter().map(|p| p.numel() as u64).sum()
    }

    /// Bytes of the quantized packs alone (codes + scales + metadata) —
    /// the *entire* engine residency of the quantized base linears on
    /// the fused compute path.
    pub fn quantized_pack_bytes(&self) -> u64 {
        self.quantized
            .iter()
            .map(|q| (q.dtype.size_bytes() * q.shape.iter().product::<usize>()) as u64)
            .sum()
    }

    /// Bytes of all fixed graph inputs (frozen f32 tensors + quantized
    /// packs) — the engine-resident base footprint of this bundle.
    pub fn fixed_input_bytes(&self) -> u64 {
        let frozen: u64 = self.frozen.iter().map(|s| 4 * s.numel() as u64).sum();
        frozen + self.quantized_pack_bytes()
    }

    /// Bytes the quantized base linears would occupy expanded to f32 —
    /// the extra residency a dequantize-at-assembly engine pays on top
    /// of the packs (zero for full-precision bundles).
    pub fn dequantized_base_bytes(&self) -> Result<u64> {
        let mut total = 0u64;
        for base in self.quantized_bases() {
            let (din, dout) = self.linear_shape(&base)?;
            total += 4 * (din as u64) * (dout as u64);
        }
        Ok(total)
    }

    /// Bytes a full train-step state (params + 2 Adam moments) occupies.
    pub fn state_bytes(&self) -> u64 {
        3 * 4 * self.trainable_numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_root;

    fn tiny(tag: &str) -> Manifest {
        Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap()
    }

    #[test]
    fn loads_tiny_bundle() {
        let m = tiny("tiny_oft_v2");
        assert_eq!(m.method, "oft_v2");
        assert_eq!(m.model.d_model, 64);
        assert_eq!(m.model.block_b, 16);
        assert!(!m.trainable.is_empty());
        assert!(!m.frozen.is_empty());
        assert!(m.quantized.is_empty());
        assert_eq!(m.trainable_numel(), m.params_trainable);
        // every adapted linear contributes one packed-q tensor
        assert_eq!(m.trainable.len(), 6 * m.model.n_layers);
    }

    #[test]
    fn quantized_bundle_has_packs() {
        let m = tiny("tiny_qoft_nf4");
        assert_eq!(m.quant, "nf4");
        assert_eq!(m.quantized.len(), 4 * 6 * m.model.n_layers);
        let bases = m.quantized_bases();
        assert_eq!(bases.len(), 6 * m.model.n_layers);
        // base weights are excluded from the frozen f32 inputs
        for b in &bases {
            assert!(!m.frozen.iter().any(|f| &f.name == b));
            let (din, dout) = m.linear_shape(b).unwrap();
            assert!(din >= 64 && dout >= 64);
        }
    }

    #[test]
    fn linear_shapes_match_dims() {
        let m = tiny("tiny_qoft_nf4");
        assert_eq!(m.linear_shape("layers.0.attn.wq").unwrap(), (64, 64));
        assert_eq!(m.linear_shape("layers.1.mlp.up").unwrap(), (64, 256));
        assert_eq!(m.linear_shape("layers.1.mlp.down").unwrap(), (256, 64));
        assert!(m.linear_shape("embed.tok").is_err());
    }

    #[test]
    fn tag_parsing() {
        assert_eq!(
            parse_tag("tiny_oft_v2").unwrap(),
            ("tiny".into(), "oft_v2".into(), "none".into())
        );
        assert_eq!(
            parse_tag("bench_qlora_nf4").unwrap(),
            ("bench".into(), "qlora".into(), "nf4".into())
        );
        assert_eq!(
            parse_tag("e2e100m_full").unwrap(),
            ("e2e100m".into(), "full".into(), "none".into())
        );
        assert!(parse_tag("tiny").is_err());
        assert!(parse_tag("tiny_warp").is_err());
    }

    #[test]
    fn builtin_tiny_oft_v2_matches_aot_contract() {
        let m = Manifest::builtin("tiny_oft_v2").unwrap();
        assert_eq!(m.method, "oft_v2");
        assert_eq!(m.model.d_model, 64);
        assert_eq!(m.model.block_b, 16);
        assert!(!m.trainable.is_empty());
        assert!(!m.frozen.is_empty());
        assert!(m.quantized.is_empty());
        assert_eq!(m.trainable_numel(), m.params_trainable);
        // every adapted linear contributes one packed-Q tensor
        assert_eq!(m.trainable.len(), 6 * m.model.n_layers);
        // packed dim b(b-1)/2 for b=16, over d/b blocks per d-input linear
        let wq = m
            .trainable
            .iter()
            .find(|s| s.name == "layers.0.attn.wq.oft_q")
            .unwrap();
        assert_eq!(wq.shape, vec![4, 120]);
        // trainables sorted by name (graph order)
        let names: Vec<&str> = m.trainable.iter().map(|s| s.name.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn builtin_quantized_bundle_has_packs() {
        let m = Manifest::builtin("tiny_qoft_nf4").unwrap();
        assert_eq!(m.quant, "nf4");
        assert_eq!(m.quantized.len(), 4 * 6 * m.model.n_layers);
        let bases = m.quantized_bases();
        assert_eq!(bases.len(), 6 * m.model.n_layers);
        for b in &bases {
            assert!(!m.frozen.iter().any(|f| &f.name == b));
            let (din, dout) = m.linear_shape(b).unwrap();
            assert!(din >= 64 && dout >= 64);
        }
        // NF4 pads 64*64 = 4096 elements up to one 16384 tile
        let codes = m
            .quantized
            .iter()
            .find(|q| q.name == "layers.0.attn.wq.nf4_codes")
            .unwrap();
        assert_eq!(codes.shape, vec![8192]);
        assert_eq!(codes.dtype, Dtype::U8);
        let awq = Manifest::builtin("tiny_qlora_awq").unwrap();
        assert_eq!(awq.quantized.len(), 3 * 6 * awq.model.n_layers);
    }

    #[test]
    fn builtin_full_and_none_bundles() {
        let full = Manifest::builtin("tiny_full").unwrap();
        assert!(full.frozen.is_empty());
        assert_eq!(full.params_base, full.params_trainable);
        let none = Manifest::builtin("tiny_none").unwrap();
        assert!(none.trainable.is_empty());
        assert_eq!(none.params_trainable, 0);
        assert_eq!(none.frozen.len(), full.trainable.len());
    }

    #[test]
    fn builtin_every_default_bundle_synthesizes() {
        for tag in [
            "tiny_full",
            "tiny_none",
            "tiny_lora",
            "tiny_oft_merged",
            "tiny_oft_v2",
            "tiny_qlora_nf4",
            "tiny_qoft_nf4",
            "tiny_qlora_awq",
            "tiny_qoft_awq",
            "tiny_boft",
            "tiny_hoft",
            "small_oft_v2",
            "small_boft",
            "small_hoft",
            "bench_oft_v2",
            "fig1_oft_merged",
            "e2e_oft_v2",
        ] {
            let m = Manifest::builtin(tag).unwrap();
            assert_eq!(m.tag, tag);
            assert_eq!(m.trainable_numel(), m.params_trainable, "{tag}");
        }
        assert!(Manifest::builtin("mystery_oft_v2").is_err());
        // qlora without a quant suffix is inconsistent
        assert!(Manifest::builtin("tiny_qlora").is_err());
    }

    #[test]
    fn pack_bytes_far_below_f32_base() {
        // The `bench` preset's linears are whole NF4 tiles, so packed
        // bytes sit at the honest ~0.52 B/param — ~7.7x below the f32
        // copy the old dequantize-at-assembly path materialized.
        let m = Manifest::builtin("bench_qoft_nf4").unwrap();
        let packs = m.quantized_pack_bytes();
        let f32b = m.dequantized_base_bytes().unwrap();
        assert!(packs * 6 < f32b, "packed {packs} B vs f32 {f32b} B");
        let frozen: u64 = m.frozen.iter().map(|s| 4 * s.numel() as u64).sum();
        assert_eq!(m.fixed_input_bytes(), frozen + packs);
        // Full-precision bundles have no quantized residency at all.
        let fp = Manifest::builtin("bench_oft_v2").unwrap();
        assert_eq!(fp.quantized_pack_bytes(), 0);
        assert_eq!(fp.dequantized_base_bytes().unwrap(), 0);
    }

    #[test]
    fn builtin_registry_methods_synthesize_their_own_specs() {
        // BOFT: depth adapts per linear — tiny has b=16, so d=64
        // attention linears carry one factor (4 blocks) and d_ff=256
        // MLP-down linears carry two (2*16 blocks).
        let m = Manifest::builtin("tiny_boft").unwrap();
        let wq = m
            .trainable
            .iter()
            .find(|s| s.name == "layers.0.attn.wq.boft_q")
            .unwrap();
        assert_eq!(wq.shape, vec![4, 120]);
        let down = m
            .trainable
            .iter()
            .find(|s| s.name == "layers.0.mlp.down.boft_q")
            .unwrap();
        assert_eq!(down.shape, vec![2 * 16, 120]);
        assert_eq!(m.trainable_numel(), m.params_trainable);

        // HOFT: k = lora_r (tiny: 4) reflections of din parameters.
        let h = Manifest::builtin("tiny_hoft").unwrap();
        let wq = h
            .trainable
            .iter()
            .find(|s| s.name == "layers.0.attn.wq.hoft_v")
            .unwrap();
        assert_eq!(wq.shape, vec![4, 64]);
        let up = h
            .trainable
            .iter()
            .find(|s| s.name == "layers.1.mlp.up.hoft_v")
            .unwrap();
        assert_eq!(up.shape, vec![4, 64]);
        assert_eq!(h.trainable_numel(), h.params_trainable);
    }

    #[test]
    fn scenario_suffix_flows_into_builtin() {
        let m = Manifest::builtin("tiny_oft_v2+coft+eps=0.001+dropout=0.1").unwrap();
        assert!(m.model.scenario.coft);
        assert_eq!(m.model.scenario.eps, 0.001);
        assert_eq!(m.model.scenario.module_dropout, 0.1);
        assert!(m.skipped.is_empty());
        // plain parse_tag ignores the suffix
        assert_eq!(
            parse_tag("tiny_oft_v2+coft").unwrap(),
            ("tiny".into(), "oft_v2".into(), "none".into())
        );
        // unknown knobs and unsupported knobs are typed errors
        let err = format!("{:#}", Manifest::builtin("tiny_oft_v2+warp=1").unwrap_err());
        assert!(err.contains("valid knobs"), "{err}");
        let err = format!("{:#}", Manifest::builtin("tiny_lora+coft").unwrap_err());
        assert!(err.contains("does not support scenario knob 'coft'"), "{err}");
    }

    #[test]
    fn scenario_targeting_prunes_trainables() {
        let all = Manifest::builtin("tiny_oft_v2").unwrap();
        let sub = Manifest::builtin("tiny_oft_v2+target=wq|wv").unwrap();
        assert_eq!(sub.skipped.len(), 4 * sub.model.n_layers);
        assert_eq!(sub.trainable.len(), 2 * sub.model.n_layers);
        assert!(sub.adapts("layers.0.attn.wq"));
        assert!(!sub.adapts("layers.0.mlp.up"));
        assert!(sub.params_trainable < all.params_trainable);
        // the frozen base inputs are untouched by targeting
        assert_eq!(sub.frozen.len(), all.frozen.len());
        let exc = Manifest::builtin("tiny_oft_v2+exclude=mlp").unwrap();
        assert_eq!(exc.skipped.len(), 2 * exc.model.n_layers);
        // a target matching nothing names the linears
        let err = format!("{:#}", Manifest::builtin("tiny_oft_v2+target=zzz").unwrap_err());
        assert!(err.contains("matches none"), "{err}");
    }

    #[test]
    fn scenario_block_knobs_resize_params() {
        // block=8: tiny's d=64 linears get 8 blocks of 8(8-1)/2 = 28
        let m = Manifest::builtin("tiny_oft_v2+block=8").unwrap();
        let wq = m
            .trainable
            .iter()
            .find(|s| s.name == "layers.0.attn.wq.oft_q")
            .unwrap();
        assert_eq!(wq.shape, vec![8, 28]);
        // r=4: every linear gets 4 blocks (wq: b=16; mlp.down: b=64)
        let m = Manifest::builtin("tiny_oft_v2+r=4").unwrap();
        let wq = m
            .trainable
            .iter()
            .find(|s| s.name == "layers.0.attn.wq.oft_q")
            .unwrap();
        assert_eq!(wq.shape, vec![4, 120]);
        let down = m
            .trainable
            .iter()
            .find(|s| s.name == "layers.0.mlp.down.oft_q")
            .unwrap();
        assert_eq!(down.shape, vec![4, 64 * 63 / 2]);
        // block_share collapses every linear to one shared block row
        let m = Manifest::builtin("tiny_oft_v2+block_share").unwrap();
        let wq = m
            .trainable
            .iter()
            .find(|s| s.name == "layers.0.attn.wq.oft_q")
            .unwrap();
        assert_eq!(wq.shape, vec![1, 120]);
    }

    #[test]
    fn adapted_linear_dims_match_linear_shape() {
        let m = Manifest::builtin("tiny_oft_v2").unwrap();
        let linears = adapted_linear_dims(&m.model);
        assert_eq!(linears.len(), 6 * m.model.n_layers);
        for (name, din, dout) in &linears {
            assert_eq!(m.linear_shape(name).unwrap(), (*din, *dout), "{name}");
        }
    }

    #[test]
    fn load_or_builtin_falls_back() {
        let dir = std::env::temp_dir().join("no_artifacts_here/tiny_oft_v2");
        let m = Manifest::load_or_builtin(&dir).unwrap();
        assert_eq!(m.tag, "tiny_oft_v2");
        let bad = std::env::temp_dir().join("no_artifacts_here/not_a_tag");
        assert!(Manifest::load_or_builtin(&bad).is_err());
    }

    #[test]
    fn init_parsing() {
        let j = json::parse(r#"["normal", 0.02]"#).unwrap();
        assert_eq!(Init::parse(&j).unwrap(), Init::Normal(0.02));
        let j = json::parse(r#"["zeros", 0.0]"#).unwrap();
        assert_eq!(Init::parse(&j).unwrap(), Init::Zeros);
        let j = json::parse(r#"["bogus", 0.0]"#).unwrap();
        assert!(Init::parse(&j).is_err());
    }
}
