//! `manifest.json` — the L2→L3 contract for one artifact bundle.
//!
//! The manifest lists every graph input *in graph order* (trainables,
//! then frozen, then quantized packs, then data), with shapes, dtypes
//! and init specs. The coordinator never re-derives these numbers; it
//! uploads buffers in exactly the recorded order.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::{self, Json};
use crate::runtime::Dtype;

/// Parameter initialization spec (`init` field).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Init {
    Normal(f32),
    Zeros,
    Ones,
}

impl Init {
    fn parse(j: &Json) -> Result<Init> {
        let arr = j.as_arr()?;
        let kind = arr[0].as_str()?;
        let std = arr[1].as_f64()? as f32;
        Ok(match kind {
            "normal" => Init::Normal(std),
            "zeros" => Init::Zeros,
            "ones" => Init::Ones,
            _ => bail!("unknown init kind '{kind}'"),
        })
    }
}

/// One f32 parameter input (trainable or frozen).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One quantized-pack input (codes / scales / metadata tensor).
#[derive(Clone, Debug)]
pub struct QuantSpec {
    /// Graph input name, e.g. `layers.0.attn.wq.nf4_codes`.
    pub name: String,
    /// The base weight it packs, e.g. `layers.0.attn.wq`.
    pub base: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// Model dimensions recorded by the manifest.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub block_b: usize,
    pub neumann_k: usize,
    pub lora_r: usize,
    pub lora_alpha: f64,
}

/// A parsed artifact-bundle manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tag: String,
    pub preset: String,
    pub method: String,
    pub quant: String,
    pub model: ModelDims,
    pub params_base: u64,
    pub params_trainable: u64,
    pub trainable: Vec<ParamSpec>,
    pub frozen: Vec<ParamSpec>,
    pub quantized: Vec<QuantSpec>,
    pub adam: (f64, f64, f64),
    pub train_step_file: String,
    pub eval_loss_file: String,
    pub logits_last_file: String,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let j = json::parse_file(dir.join("manifest.json")).with_context(|| {
            format!(
                "loading bundle manifest {} (run `make artifacts`)",
                dir.display()
            )
        })?;

        let m = j.get("model")?;
        let model = ModelDims {
            vocab: m.get("vocab")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            seq_len: m.get("seq_len")?.as_usize()?,
            batch: m.get("batch")?.as_usize()?,
            block_b: m.get("block_b")?.as_usize()?,
            neumann_k: m.get("neumann_k")?.as_usize()?,
            lora_r: m.get("lora_r")?.as_usize()?,
            lora_alpha: m.get("lora_alpha")?.as_f64()?,
        };

        let param_spec = |e: &Json| -> Result<ParamSpec> {
            Ok(ParamSpec {
                name: e.get("name")?.as_str()?.to_string(),
                shape: e.get("shape")?.as_shape()?,
                init: Init::parse(e.get("init")?)?,
            })
        };
        let inputs = j.get("inputs")?;
        let trainable = inputs
            .get("trainable")?
            .as_arr()?
            .iter()
            .map(param_spec)
            .collect::<Result<Vec<_>>>()?;
        let frozen = inputs
            .get("frozen")?
            .as_arr()?
            .iter()
            .map(param_spec)
            .collect::<Result<Vec<_>>>()?;
        let quantized = inputs
            .get("quantized")?
            .as_arr()?
            .iter()
            .map(|e| -> Result<QuantSpec> {
                Ok(QuantSpec {
                    name: e.get("name")?.as_str()?.to_string(),
                    base: e.get("base")?.as_str()?.to_string(),
                    shape: e.get("shape")?.as_shape()?,
                    dtype: Dtype::parse(e.get("dtype")?.as_str()?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let adam = j.get("adam")?;
        let art = j.get("artifacts")?;
        let params = j.get("params")?;
        Ok(Manifest {
            dir,
            tag: j.get("tag")?.as_str()?.to_string(),
            preset: j.get("preset")?.as_str()?.to_string(),
            method: j.get("method")?.as_str()?.to_string(),
            quant: j.get("quant")?.as_str()?.to_string(),
            model,
            params_base: params.get("base")?.as_usize()? as u64,
            params_trainable: params.get("trainable")?.as_usize()? as u64,
            trainable,
            frozen,
            quantized,
            adam: (
                adam.get("b1")?.as_f64()?,
                adam.get("b2")?.as_f64()?,
                adam.get("eps")?.as_f64()?,
            ),
            train_step_file: art.get("train_step")?.as_str()?.to_string(),
            eval_loss_file: art.get("eval_loss")?.as_str()?.to_string(),
            logits_last_file: art.get("logits_last")?.as_str()?.to_string(),
        })
    }

    /// Path of one artifact file.
    pub fn artifact(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Unique base weights behind the quantized packs, in first-seen
    /// (graph) order.
    pub fn quantized_bases(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for q in &self.quantized {
            if !seen.contains(&q.base) {
                seen.push(q.base.clone());
            }
        }
        seen
    }

    /// The (din, dout) of a base linear weight referenced by a quantized
    /// pack — mirrors `linear_names()` in python/compile/model.py.
    pub fn linear_shape(&self, base: &str) -> Result<(usize, usize)> {
        let (d, f) = (self.model.d_model, self.model.d_ff);
        if base.ends_with(".mlp.up") {
            Ok((d, f))
        } else if base.ends_with(".mlp.down") {
            Ok((f, d))
        } else if base.contains(".attn.w") {
            Ok((d, d))
        } else {
            bail!("'{base}' is not an adapted linear weight");
        }
    }

    /// Total trainable elements (must equal `params_trainable`).
    pub fn trainable_numel(&self) -> u64 {
        self.trainable.iter().map(|p| p.numel() as u64).sum()
    }

    /// Bytes a full train-step state (params + 2 Adam moments) occupies.
    pub fn state_bytes(&self) -> u64 {
        3 * 4 * self.trainable_numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_root;

    fn tiny(tag: &str) -> Option<Manifest> {
        let dir = artifacts_root().join(tag);
        dir.exists().then(|| Manifest::load(dir).unwrap())
    }

    #[test]
    fn loads_tiny_bundle() {
        let Some(m) = tiny("tiny_oft_v2") else { return };
        assert_eq!(m.method, "oft_v2");
        assert_eq!(m.model.d_model, 64);
        assert_eq!(m.model.block_b, 16);
        assert!(!m.trainable.is_empty());
        assert!(!m.frozen.is_empty());
        assert!(m.quantized.is_empty());
        assert_eq!(m.trainable_numel(), m.params_trainable);
        // every adapted linear contributes one packed-q tensor
        assert_eq!(m.trainable.len(), 6 * m.model.n_layers);
    }

    #[test]
    fn quantized_bundle_has_packs() {
        let Some(m) = tiny("tiny_qoft_nf4") else { return };
        assert_eq!(m.quant, "nf4");
        assert_eq!(m.quantized.len(), 4 * 6 * m.model.n_layers);
        let bases = m.quantized_bases();
        assert_eq!(bases.len(), 6 * m.model.n_layers);
        // base weights are excluded from the frozen f32 inputs
        for b in &bases {
            assert!(!m.frozen.iter().any(|f| &f.name == b));
            let (din, dout) = m.linear_shape(b).unwrap();
            assert!(din >= 64 && dout >= 64);
        }
    }

    #[test]
    fn linear_shapes_match_dims() {
        let Some(m) = tiny("tiny_qoft_nf4") else { return };
        assert_eq!(m.linear_shape("layers.0.attn.wq").unwrap(), (64, 64));
        assert_eq!(m.linear_shape("layers.1.mlp.up").unwrap(), (64, 256));
        assert_eq!(m.linear_shape("layers.1.mlp.down").unwrap(), (256, 64));
        assert!(m.linear_shape("embed.tok").is_err());
    }

    #[test]
    fn init_parsing() {
        let j = json::parse(r#"["normal", 0.02]"#).unwrap();
        assert_eq!(Init::parse(&j).unwrap(), Init::Normal(0.02));
        let j = json::parse(r#"["zeros", 0.0]"#).unwrap();
        assert_eq!(Init::parse(&j).unwrap(), Init::Zeros);
        let j = json::parse(r#"["bogus", 0.0]"#).unwrap();
        assert!(Init::parse(&j).is_err());
    }
}
