//! Training metrics: per-step records, eval points, and JSON export
//! (the loss curves EXPERIMENTS.md plots come from these files).

use std::path::Path;

use anyhow::Result;

use crate::json::Json;

/// One optimizer step's record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
    /// Wall-clock seconds for this step (upload + execute + fetch).
    pub secs: f64,
}

/// One evaluation point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalRecord {
    pub step: usize,
    pub eval_loss: f64,
    pub perplexity: f64,
}

/// A run's full metric history.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
}

impl History {
    pub fn push_step(&mut self, r: StepRecord) {
        self.steps.push(r);
    }

    pub fn push_eval(&mut self, r: EvalRecord) {
        self.evals.push(r);
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.steps.last().map(|r| r.loss)
    }

    pub fn first_loss(&self) -> Option<f64> {
        self.steps.first().map(|r| r.loss)
    }

    /// Mean step time over the (post-warmup) tail.
    pub fn mean_step_secs(&self, skip: usize) -> f64 {
        let tail: Vec<f64> = self.steps.iter().skip(skip).map(|r| r.secs).collect();
        if tail.is_empty() {
            return 0.0;
        }
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    /// Per-step wall times after skipping `skip` warmup steps (bench
    /// sample sets for mean/p50/p95 records).
    pub fn step_secs(&self, skip: usize) -> Vec<f64> {
        self.steps.iter().skip(skip).map(|r| r.secs).collect()
    }

    /// Mean loss over the last `n` steps (noise-robust convergence
    /// check for the paper-shape assertions).
    pub fn tail_loss(&self, n: usize) -> Option<f64> {
        if self.steps.is_empty() {
            return None;
        }
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "steps",
                Json::arr(
                    self.steps
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("step", Json::num(r.step as f64)),
                                ("loss", Json::num(r.loss)),
                                ("lr", Json::num(r.lr)),
                                ("secs", Json::num(r.secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Json::arr(
                    self.evals
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("step", Json::num(r.step as f64)),
                                ("eval_loss", Json::num(r.eval_loss)),
                                ("perplexity", Json::num(r.perplexity)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path.as_ref(), self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist() -> History {
        let mut h = History::default();
        for i in 1..=10 {
            h.push_step(StepRecord {
                step: i,
                loss: 10.0 / i as f64,
                lr: 1e-3,
                secs: 0.01,
            });
        }
        h.push_eval(EvalRecord {
            step: 10,
            eval_loss: 1.5,
            perplexity: 1.5f64.exp(),
        });
        h
    }

    #[test]
    fn aggregates() {
        let h = hist();
        assert_eq!(h.first_loss(), Some(10.0));
        assert_eq!(h.final_loss(), Some(1.0));
        assert!((h.mean_step_secs(2) - 0.01).abs() < 1e-12);
        assert!(h.tail_loss(3).unwrap() < 2.0);
    }

    #[test]
    fn json_roundtrip() {
        let h = hist();
        let j = h.to_json();
        let steps = j.get("steps").unwrap().as_arr().unwrap();
        assert_eq!(steps.len(), 10);
        assert_eq!(steps[0].get("step").unwrap().as_usize().unwrap(), 1);
        let evals = j.get("evals").unwrap().as_arr().unwrap();
        assert_eq!(evals.len(), 1);
    }

    #[test]
    fn save_creates_parent_dirs() {
        let dir = std::env::temp_dir().join(format!("oft_metrics_{}", std::process::id()));
        let path = dir.join("nested/history.json");
        hist().save(&path).unwrap();
        assert!(path.exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn empty_history() {
        let h = History::default();
        assert_eq!(h.final_loss(), None);
        assert_eq!(h.tail_loss(5), None);
        assert_eq!(h.mean_step_secs(0), 0.0);
    }
}
