//! L3 coordinator — the finetuning framework around the AOT graphs.
//!
//! * [`manifest`]   — the L2→L3 input contract (`manifest.json`)
//! * [`state`]      — deterministic init + base-weight quantization
//! * [`trainer`]    — train loop, LR schedule, eval, greedy decode
//! * [`metrics`]    — step/eval records + JSON export
//! * [`checkpoint`] — name→tensor files for the pretrain→finetune protocol
//!
//! The coordinator's job mirrors what HF PEFT + TRL + Accelerate do in
//! the paper's stack: own the run lifecycle while the compute graphs —
//! including the paper's contribution, the OFTv2 input-centric rotation
//! and CNP (L1/L2) — execute through [`crate::runtime`].

pub mod checkpoint;
pub mod manifest;
pub mod metrics;
pub mod protocol;
pub mod state;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use manifest::{Init, Manifest, ModelDims, ParamSpec, QuantSpec};
pub use metrics::{EvalRecord, History, StepRecord};
pub use state::{AdapterState, BaseModel, BundleState, ShardInfo};
pub use trainer::Trainer;
