//! The pretrain→finetune protocol (the paper's adaptation setting):
//! pretrain the base model on a task's distribution, checkpoint it,
//! then attach PEFT adapters and finetune on the shifted distribution
//! with the base frozen (or quantized).
//!
//! Used by the quality benches (Tables 3–5) and `examples/e2e_finetune`.

use anyhow::Result;

use super::checkpoint::Checkpoint;
use super::manifest::Manifest;
use super::trainer::Trainer;
use crate::config::RunCfg;
use crate::data::corpus::TaskKind;
use crate::data::loader::Loader;
use crate::runtime::Engine;

/// Settings for one pretrain or finetune phase.
#[derive(Clone, Debug)]
pub struct Phase {
    pub steps: usize,
    pub documents: usize,
    pub lr: f64,
    pub seed: u64,
}

impl Default for Phase {
    fn default() -> Self {
        Phase {
            steps: 150,
            documents: 1500,
            lr: 2e-3,
            seed: 7,
        }
    }
}

fn run_cfg(tag: &str, phase: &Phase, task: TaskKind) -> RunCfg {
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = phase.steps;
    cfg.seed = phase.seed;
    cfg.log_every = 0;
    cfg.optim.lr = phase.lr;
    cfg.data.task = match task {
        TaskKind::Wiki => "wiki",
        TaskKind::Math => "math",
        TaskKind::Summarize => "summarize",
    }
    .into();
    cfg.data.documents = phase.documents;
    cfg
}

/// Pretrain `<preset>_full` on `task` (distribution style 0). Returns
/// the full-model checkpoint AND the style-1 finetuning loader that
/// shares the pretraining tokenizer — token ids must stay aligned
/// across phases or the checkpointed embeddings are useless.
pub fn pretrain(
    engine: &Engine,
    artifacts_root: &std::path::Path,
    preset: &str,
    task: TaskKind,
    phase: &Phase,
) -> Result<(Checkpoint, Loader)> {
    let tag = format!("{preset}_full");
    let man = Manifest::load_or_builtin(artifacts_root.join(&tag))?;
    let (pre_loader, fin_loader) = Loader::pretrain_finetune_pair(
        task,
        phase.documents,
        phase.seed,
        man.model.vocab,
        man.model.batch,
        man.model.seq_len,
    );
    let cfg = run_cfg(&tag, phase, task);
    let mut tr = Trainer::with_checkpoint(engine, man, cfg, None)?;
    tr.set_loader(pre_loader);
    tr.train()?;
    Ok((tr.checkpoint()?, fin_loader))
}

/// Build a finetuning trainer for `tag`, initialized from `ckpt`, over
/// the shared-vocabulary shifted-distribution loader from [`pretrain`].
pub fn finetune_trainer<'e>(
    engine: &'e Engine,
    artifacts_root: &std::path::Path,
    tag: &str,
    task: TaskKind,
    phase: &Phase,
    ckpt: Option<&Checkpoint>,
    fin_loader: &Loader,
) -> Result<Trainer<'e>> {
    let man = Manifest::load_or_builtin(artifacts_root.join(tag))?;
    let cfg = run_cfg(tag, phase, task);
    let mut tr = Trainer::with_checkpoint(engine, man, cfg, ckpt)?;
    tr.set_loader(fin_loader.clone());
    Ok(tr)
}

/// Pretrain once, then finetune `tag` and return the trainer after
/// training (ready for evaluation/decoding).
pub fn pretrain_then_finetune<'e>(
    engine: &'e Engine,
    artifacts_root: &std::path::Path,
    preset: &str,
    tag: &str,
    task: TaskKind,
    pretrain_phase: &Phase,
    finetune_phase: &Phase,
) -> Result<Trainer<'e>> {
    let (ckpt, fin_loader) = pretrain(engine, artifacts_root, preset, task, pretrain_phase)?;
    let mut tr = finetune_trainer(
        engine,
        artifacts_root,
        tag,
        task,
        finetune_phase,
        Some(&ckpt),
        &fin_loader,
    )?;
    if finetune_phase.steps > 0 {
        tr.train()?;
    }
    Ok(tr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_defaults_sane() {
        let p = Phase::default();
        assert!(p.steps > 0 && p.documents > 0 && p.lr > 0.0);
    }

    #[test]
    fn run_cfg_maps_tasks() {
        let p = Phase::default();
        for (task, name) in [
            (TaskKind::Wiki, "wiki"),
            (TaskKind::Math, "math"),
            (TaskKind::Summarize, "summarize"),
        ] {
            let cfg = run_cfg("tiny_oft_v2", &p, task);
            assert_eq!(cfg.data.task, name);
            assert_eq!(cfg.steps, p.steps);
        }
    }

    // End-to-end protocol coverage lives in rust/tests/trainer.rs
    // (pretrain_then_finetune_protocol) and the quality benches.
}
