//! Parameter-state construction: deterministic initialization from the
//! manifest's init specs, checkpoint overrides, and quantization of the
//! frozen base weights into the exact packed layouts the graphs expect.
//!
//! Rust owns *quantization* (model-load time); the AOT graphs own
//! *dequantization* (Pallas kernels) — DESIGN.md §4.

use anyhow::{bail, ensure, Context, Result};

use super::checkpoint::Checkpoint;
use super::manifest::{Init, Manifest, ParamSpec};
use crate::quant::{AwqTensor, Nf4Tensor};
use crate::runtime::{lit_f32, lit_i8, lit_u8, Value};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// FNV-1a over a parameter name — gives each parameter an independent,
/// order-free random stream.
fn name_hash(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Initialize one parameter per its spec (checkpoint value wins).
pub fn init_param(spec: &ParamSpec, seed: u64, ckpt: Option<&Checkpoint>) -> Result<Tensor> {
    if let Some(c) = ckpt {
        if let Some(t) = c.get(&spec.name) {
            ensure!(
                t.shape == spec.shape,
                "checkpoint '{}' has shape {:?}, manifest wants {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
            return Ok(t.clone());
        }
    }
    let mut rng = Rng::new(seed ^ name_hash(&spec.name));
    Ok(match spec.init {
        Init::Normal(std) => Tensor::randn(&spec.shape, std, &mut rng),
        Init::Zeros => Tensor::zeros(&spec.shape),
        Init::Ones => Tensor::ones(&spec.shape),
    })
}

/// Initialize a *base* linear weight that exists only behind quantized
/// packs (not in the manifest's f32 inputs): N(0, 0.02), the same init
/// model.py uses for linears.
pub fn init_quantized_base(
    man: &Manifest,
    base: &str,
    seed: u64,
    ckpt: Option<&Checkpoint>,
) -> Result<Tensor> {
    let (din, dout) = man.linear_shape(base)?;
    if let Some(c) = ckpt {
        if let Some(t) = c.get(base) {
            ensure!(t.shape == vec![din, dout], "checkpoint '{base}' shape mismatch");
            return Ok(t.clone());
        }
    }
    let mut rng = Rng::new(seed ^ name_hash(base));
    Ok(Tensor::randn(&[din, dout], 0.02, &mut rng))
}

/// Packed quantized tensors for one base weight, as (input-name, literal)
/// in the manifest's graph order.
pub fn quantize_base(
    man: &Manifest,
    base: &str,
    weight: &Tensor,
) -> Result<Vec<(String, Value)>> {
    let specs: Vec<_> = man.quantized.iter().filter(|q| q.base == base).collect();
    ensure!(!specs.is_empty(), "no quantized specs for '{base}'");
    let mut out = Vec::new();
    match man.quant.as_str() {
        "nf4" => {
            let q = Nf4Tensor::quantize(weight);
            for s in specs {
                let lit = match s.name.rsplit('.').next().unwrap() {
                    "nf4_codes" => lit_u8(&s.shape, &q.codes)?,
                    "nf4_absmax_q" => lit_i8(&s.shape, &q.absmax_q)?,
                    "nf4_absmax_s" => lit_f32(&s.shape, &q.absmax_s)?,
                    "nf4_offset" => lit_f32(&s.shape, &[q.offset])?,
                    other => bail!("unknown NF4 pack field '{other}'"),
                };
                out.push((s.name.clone(), lit));
            }
        }
        "awq" => {
            let q = AwqTensor::quantize(weight, None)?;
            for s in specs {
                let lit = match s.name.rsplit('.').next().unwrap() {
                    "awq_codes" => lit_u8(&s.shape, &q.codes)?,
                    "awq_scales" => lit_f32(&s.shape, &q.scales)?,
                    "awq_eq" => lit_f32(&s.shape, &q.eq)?,
                    other => bail!("unknown AWQ pack field '{other}'"),
                };
                out.push((s.name.clone(), lit));
            }
        }
        other => bail!("bundle '{}' has unknown quant backend '{other}'", man.tag),
    }
    Ok(out)
}

/// The full input state for a bundle: trainables (+ Adam moments) as
/// host tensors, fixed inputs (frozen f32 + quantized packs) as
/// literals ready for a one-time device upload.
pub struct BundleState {
    /// Trainable tensors, manifest order.
    pub trainable: Vec<Tensor>,
    /// Frozen + quantized literals, graph order.
    pub fixed: Vec<Value>,
    /// Host copies of the quantized base weights (for §4 requantization
    /// analyses and oracle checks); empty for full-precision bundles.
    pub quantized_bases: Vec<(String, Tensor)>,
}

impl BundleState {
    /// Build the initial state for `man` with master seed `seed`,
    /// overriding initialization with `ckpt` values where names match.
    pub fn init(man: &Manifest, seed: u64, ckpt: Option<&Checkpoint>) -> Result<BundleState> {
        let trainable = man
            .trainable
            .iter()
            .map(|s| init_param(s, seed, ckpt))
            .collect::<Result<Vec<_>>>()?;

        let mut fixed = Vec::new();
        for s in &man.frozen {
            let t = init_param(s, seed, ckpt)?;
            fixed.push(lit_f32(&s.shape, &t.data)?);
        }

        let mut quantized_bases = Vec::new();
        if !man.quantized.is_empty() {
            // Quantize each base once, then emit packs in manifest order.
            let mut packs: Vec<(String, Value)> = Vec::new();
            for base in man.quantized_bases() {
                let w = init_quantized_base(man, &base, seed, ckpt)?;
                packs.extend(quantize_base(man, &base, &w)?);
                quantized_bases.push((base, w));
            }
            for s in &man.quantized {
                let idx = packs
                    .iter()
                    .position(|(n, _)| n == &s.name)
                    .with_context(|| format!("missing pack '{}'", s.name))?;
                fixed.push(packs.remove(idx).1);
            }
        }

        Ok(BundleState {
            trainable,
            fixed,
            quantized_bases,
        })
    }

    /// Trainable tensors as literals (manifest order).
    pub fn trainable_literals(&self, man: &Manifest) -> Result<Vec<Value>> {
        man.trainable
            .iter()
            .zip(&self.trainable)
            .map(|(s, t)| lit_f32(&s.shape, &t.data))
            .collect()
    }

    /// Zero-filled Adam-moment literals (manifest order).
    pub fn zero_moments(&self, man: &Manifest) -> Result<Vec<Value>> {
        man.trainable
            .iter()
            .map(|s| lit_f32(&s.shape, &vec![0.0; s.numel()]))
            .collect()
    }
}

/// Sanity check a quantized-pack literal count: NF4 has 4 packs per
/// base, AWQ has 3.
pub fn packs_per_base(quant: &str) -> Result<usize> {
    Ok(match quant {
        "nf4" => 4,
        "awq" => 3,
        other => bail!("unknown quant backend '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_root;
    use crate::coordinator::manifest::Manifest;

    fn man(tag: &str) -> Manifest {
        Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_order_free() {
        let spec = ParamSpec {
            name: "layers.0.attn.wq".into(),
            shape: vec![8, 8],
            init: Init::Normal(0.02),
        };
        let a = init_param(&spec, 42, None).unwrap();
        let b = init_param(&spec, 42, None).unwrap();
        assert_eq!(a, b);
        let c = init_param(&spec, 43, None).unwrap();
        assert_ne!(a, c);
        // different names, same seed -> different values
        let spec2 = ParamSpec {
            name: "layers.0.attn.wk".into(),
            ..spec.clone()
        };
        assert_ne!(init_param(&spec2, 42, None).unwrap(), a);
    }

    #[test]
    fn checkpoint_overrides_init() {
        let spec = ParamSpec {
            name: "final_norm".into(),
            shape: vec![4],
            init: Init::Ones,
        };
        let mut ck = Checkpoint::new();
        ck.insert("final_norm".into(), Tensor::from_vec(&[4], vec![9.0; 4]));
        let t = init_param(&spec, 0, Some(&ck)).unwrap();
        assert_eq!(t.data, vec![9.0; 4]);
        // shape mismatch is an error, not silent fallback
        ck.insert("final_norm".into(), Tensor::zeros(&[5]));
        assert!(init_param(&spec, 0, Some(&ck)).is_err());
    }

    #[test]
    fn zeros_and_ones_inits() {
        let z = ParamSpec {
            name: "q".into(),
            shape: vec![3],
            init: Init::Zeros,
        };
        assert_eq!(init_param(&z, 1, None).unwrap().data, vec![0.0; 3]);
        let o = ParamSpec {
            name: "g".into(),
            shape: vec![2],
            init: Init::Ones,
        };
        assert_eq!(init_param(&o, 1, None).unwrap().data, vec![1.0; 2]);
    }

    #[test]
    fn full_precision_bundle_state() {
        let m = man("tiny_oft_v2");
        let st = BundleState::init(&m, 7, None).unwrap();
        assert_eq!(st.trainable.len(), m.trainable.len());
        assert_eq!(st.fixed.len(), m.frozen.len());
        assert!(st.quantized_bases.is_empty());
        // adapters start at identity (Q = 0)
        for t in &st.trainable {
            assert!(t.data.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn quantized_bundle_state_pack_counts() {
        for (tag, per_base) in [("tiny_qoft_nf4", 4usize), ("tiny_qoft_awq", 3usize)] {
            let m = man(tag);
            let st = BundleState::init(&m, 7, None).unwrap();
            let n_base = st.quantized_bases.len();
            assert_eq!(m.quantized.len(), n_base * per_base);
            assert_eq!(st.fixed.len(), m.frozen.len() + m.quantized.len());
            assert_eq!(packs_per_base(&m.quant).unwrap(), per_base);
            // pack literal shapes match the manifest
            for (lit, spec) in st.fixed[m.frozen.len()..].iter().zip(&m.quantized) {
                assert_eq!(lit.element_count(), spec.shape.iter().product::<usize>());
            }
        }
    }

    #[test]
    fn nf4_pack_layout_matches_quant_module() {
        let m = man("tiny_qoft_nf4");
        let base = &m.quantized_bases()[0];
        let w = init_quantized_base(&m, base, 7, None).unwrap();
        let packs = quantize_base(&m, base, &w).unwrap();
        let q = crate::quant::Nf4Tensor::quantize(&w);
        let codes = &packs
            .iter()
            .find(|(n, _)| n.ends_with("nf4_codes"))
            .unwrap()
            .1;
        assert_eq!(codes.to_vec::<u8>().unwrap(), q.codes);
    }
}
