//! Parameter-state construction, split along the paper's central
//! property: the (quantized) base is frozen, so it is a *shared*
//! resource, while each adapter owns only adapter-sized state.
//!
//! * [`BaseModel`] — the frozen f32 weights and lazily-built NF4/AWQ
//!   packs of one preset, engine-resident (`Arc`-shared, uploaded
//!   once). Any number of trainers, evaluators, and decoders attach.
//! * [`AdapterState`] — trainables + Adam moments + step counter for
//!   one adapter (the only state that round-trips per step).
//! * [`BundleState`] — the older all-host view, kept for graph-level
//!   tests that feed every input by value.
//!
//! Rust owns *quantization* (model-load time); the AOT graphs own
//! *dequantization* (Pallas kernels) — DESIGN.md §4.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use super::checkpoint::Checkpoint;
use super::manifest::{Init, Manifest, ModelDims, ParamSpec};
use crate::quant::{AwqTensor, Nf4Tensor};
use crate::runtime::{lit_f32, lit_i8, lit_u8, Buffer, Engine, Value};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// FNV-1a over a parameter name — gives each parameter an independent,
/// order-free random stream.
fn name_hash(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Initialize one parameter per its spec (checkpoint value wins).
pub fn init_param(spec: &ParamSpec, seed: u64, ckpt: Option<&Checkpoint>) -> Result<Tensor> {
    if let Some(c) = ckpt {
        if let Some(t) = c.get(&spec.name) {
            ensure!(
                t.shape == spec.shape,
                "checkpoint '{}' has shape {:?}, manifest wants {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
            return Ok(t.clone());
        }
    }
    let mut rng = Rng::new(seed ^ name_hash(&spec.name));
    Ok(match spec.init {
        Init::Normal(std) => Tensor::randn(&spec.shape, std, &mut rng),
        Init::Zeros => Tensor::zeros(&spec.shape),
        Init::Ones => Tensor::ones(&spec.shape),
    })
}

/// Initialize a *base* linear weight that exists only behind quantized
/// packs (not in the manifest's f32 inputs): N(0, 0.02), the same init
/// model.py uses for linears.
pub fn init_quantized_base(
    man: &Manifest,
    base: &str,
    seed: u64,
    ckpt: Option<&Checkpoint>,
) -> Result<Tensor> {
    let (din, dout) = man.linear_shape(base)?;
    if let Some(c) = ckpt {
        if let Some(t) = c.get(base) {
            ensure!(t.shape == vec![din, dout], "checkpoint '{base}' shape mismatch");
            return Ok(t.clone());
        }
    }
    let mut rng = Rng::new(seed ^ name_hash(base));
    Ok(Tensor::randn(&[din, dout], 0.02, &mut rng))
}

/// Packed quantized tensors for one base weight, as (input-name, literal)
/// in the manifest's graph order.
pub fn quantize_base(
    man: &Manifest,
    base: &str,
    weight: &Tensor,
) -> Result<Vec<(String, Value)>> {
    let specs: Vec<_> = man.quantized.iter().filter(|q| q.base == base).collect();
    ensure!(!specs.is_empty(), "no quantized specs for '{base}'");
    let mut out = Vec::new();
    match man.quant.as_str() {
        "nf4" => {
            let q = Nf4Tensor::quantize(weight);
            for s in specs {
                let lit = match s.name.rsplit('.').next().unwrap() {
                    "nf4_codes" => lit_u8(&s.shape, &q.codes)?,
                    "nf4_absmax_q" => lit_i8(&s.shape, &q.absmax_q)?,
                    "nf4_absmax_s" => lit_f32(&s.shape, &q.absmax_s)?,
                    "nf4_offset" => lit_f32(&s.shape, &[q.offset])?,
                    other => bail!("unknown NF4 pack field '{other}'"),
                };
                out.push((s.name.clone(), lit));
            }
        }
        "awq" => {
            let q = AwqTensor::quantize(weight, None)?;
            for s in specs {
                let lit = match s.name.rsplit('.').next().unwrap() {
                    "awq_codes" => lit_u8(&s.shape, &q.codes)?,
                    "awq_scales" => lit_f32(&s.shape, &q.scales)?,
                    "awq_eq" => lit_f32(&s.shape, &q.eq)?,
                    other => bail!("unknown AWQ pack field '{other}'"),
                };
                out.push((s.name.clone(), lit));
            }
        }
        other => bail!("bundle '{}' has unknown quant backend '{other}'", man.tag),
    }
    Ok(out)
}

/// The full input state for a bundle: trainables (+ Adam moments) as
/// host tensors, fixed inputs (frozen f32 + quantized packs) as
/// literals ready for a one-time device upload.
pub struct BundleState {
    /// Trainable tensors, manifest order.
    pub trainable: Vec<Tensor>,
    /// Frozen + quantized literals, graph order.
    pub fixed: Vec<Value>,
    /// Host copies of the quantized base weights (for §4 requantization
    /// analyses and oracle checks); empty for full-precision bundles.
    pub quantized_bases: Vec<(String, Tensor)>,
}

impl BundleState {
    /// Build the initial state for `man` with master seed `seed`,
    /// overriding initialization with `ckpt` values where names match.
    pub fn init(man: &Manifest, seed: u64, ckpt: Option<&Checkpoint>) -> Result<BundleState> {
        let trainable = man
            .trainable
            .iter()
            .map(|s| init_param(s, seed, ckpt))
            .collect::<Result<Vec<_>>>()?;

        let mut fixed = Vec::new();
        for s in &man.frozen {
            let t = init_param(s, seed, ckpt)?;
            fixed.push(lit_f32(&s.shape, &t.data)?);
        }

        let mut quantized_bases = Vec::new();
        if !man.quantized.is_empty() {
            // Quantize each base once, then emit packs in manifest order.
            let mut packs: Vec<(String, Value)> = Vec::new();
            for base in man.quantized_bases() {
                let w = init_quantized_base(man, &base, seed, ckpt)?;
                packs.extend(quantize_base(man, &base, &w)?);
                quantized_bases.push((base, w));
            }
            for s in &man.quantized {
                let idx = packs
                    .iter()
                    .position(|(n, _)| n == &s.name)
                    .with_context(|| format!("missing pack '{}'", s.name))?;
                fixed.push(packs.remove(idx).1);
            }
        }

        Ok(BundleState {
            trainable,
            fixed,
            quantized_bases,
        })
    }

    /// Trainable tensors as literals (manifest order).
    pub fn trainable_literals(&self, man: &Manifest) -> Result<Vec<Value>> {
        man.trainable
            .iter()
            .zip(&self.trainable)
            .map(|(s, t)| lit_f32(&s.shape, &t.data))
            .collect()
    }

    /// Zero-filled Adam-moment literals (manifest order).
    pub fn zero_moments(&self, man: &Manifest) -> Result<Vec<Value>> {
        man.trainable
            .iter()
            .map(|s| lit_f32(&s.shape, &vec![0.0; s.numel()]))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// BaseModel: the shared, engine-resident frozen base
// ---------------------------------------------------------------------------

/// Checkpoint key prefix for first Adam moments (`__adam_m.<param>`).
pub const ADAM_M_PREFIX: &str = "__adam_m.";
/// Checkpoint key prefix for second Adam moments (`__adam_v.<param>`).
pub const ADAM_V_PREFIX: &str = "__adam_v.";
/// Checkpoint key holding the optimizer step counter (1-element tensor).
pub const STEP_KEY: &str = "__step";

/// The frozen base of one model preset as a first-class shared object:
/// every base parameter initialized deterministically (checkpoint
/// values win), uploaded to the engine exactly once, plus quantized
/// packs built lazily per quant backend. Trainers, evaluators, and the
/// `serve` loop attach via `Arc<BaseModel>` and share the buffers.
pub struct BaseModel {
    pub preset: String,
    pub seed: u64,
    pub dims: ModelDims,
    /// Host copies of every base parameter (checkpoint export and the
    /// quantization source of truth).
    host: BTreeMap<String, Tensor>,
    /// Engine-resident f32 buffers, one per base parameter.
    bufs: BTreeMap<String, Arc<Buffer>>,
    /// quant backend name -> pack input name -> engine buffer.
    packs: Mutex<BTreeMap<String, BTreeMap<String, Arc<Buffer>>>>,
}

impl BaseModel {
    /// Build the shared base of `preset` and upload it once. The
    /// `<preset>_none` manifest lists every base parameter as frozen,
    /// so it serves as the preset's base contract.
    pub fn for_preset(
        engine: &Engine,
        preset: &str,
        seed: u64,
        ckpt: Option<&Checkpoint>,
    ) -> Result<Arc<BaseModel>> {
        let man = Manifest::builtin(&format!("{preset}_none"))
            .with_context(|| format!("preset '{preset}' has no builtin base contract"))?;
        Self::from_manifest(engine, &man, seed, ckpt)
    }

    /// Build a shared base from any manifest: its frozen specs plus the
    /// base linears behind its quantized packs. (`full` bundles have no
    /// frozen inputs — their base lives in the trainables — so their
    /// private BaseModel is empty rather than a dead second copy.)
    pub fn from_manifest(
        engine: &Engine,
        man: &Manifest,
        seed: u64,
        ckpt: Option<&Checkpoint>,
    ) -> Result<Arc<BaseModel>> {
        let mut host = BTreeMap::new();
        let mut bufs = BTreeMap::new();
        for spec in &man.frozen {
            let t = init_param(spec, seed, ckpt)?;
            let buf = engine.upload(&lit_f32(&spec.shape, &t.data)?)?;
            host.insert(spec.name.clone(), t);
            bufs.insert(spec.name.clone(), Arc::new(buf));
        }
        for base in man.quantized_bases() {
            // Host copy only: quantized graphs read packs, never the
            // raw f32 linear, so no engine buffer is uploaded for it.
            // This host master is the *load-time* quantization source
            // and checkpoint export — the role the pre-quantization
            // checkpoint plays in a real QLoRA loader. It never enters
            // the compute path: every train/eval/decode/serve matmul
            // reads the packs through the fused kernels (asserted by
            // tests/quantized_no_f32.rs via quant::dequant_f32_count).
            // (The `_none` base of `for_preset` lists every base weight
            // as frozen, so mixed fleets still get f32 buffers there.)
            let t = init_quantized_base(man, &base, seed, ckpt)?;
            host.insert(base, t);
        }
        Ok(Arc::new(BaseModel {
            preset: man.preset.clone(),
            seed,
            dims: man.model,
            host,
            bufs,
            packs: Mutex::new(BTreeMap::new()),
        }))
    }

    /// Host tensor of one base parameter.
    pub fn host(&self, name: &str) -> Result<&Tensor> {
        self.host
            .get(name)
            .with_context(|| format!("base model '{}' has no parameter '{name}'", self.preset))
    }

    /// Reject a checkpoint whose base-weight entries disagree with the
    /// weights `man` actually draws from this base (its frozen inputs
    /// and quantized base linears): adapter state would otherwise
    /// silently decode against the wrong frozen weights. Only those
    /// names are checked — a `full` bundle reads nothing from the base,
    /// so its trained weights (which shadow base parameter names) never
    /// conflict. A checkpoint carrying different base weights needs a
    /// base *built from it* (`from_manifest` / `for_preset` with the
    /// checkpoint), not an attach.
    pub fn ensure_checkpoint_matches(&self, man: &Manifest, ckpt: &Checkpoint) -> Result<()> {
        let names = man
            .frozen
            .iter()
            .map(|s| s.name.clone())
            .chain(man.quantized_bases());
        for name in names {
            if let (Some(h), Some(t)) = (self.host.get(&name), ckpt.get(&name)) {
                ensure!(
                    h == t,
                    "checkpoint base weight '{name}' differs from the shared '{}' base — \
                     build the BaseModel from this checkpoint instead of attaching to it",
                    self.preset
                );
            }
        }
        Ok(())
    }

    /// Number of engine-resident f32 base buffers.
    pub fn n_buffers(&self) -> usize {
        self.bufs.len()
    }

    /// Bytes of engine-resident f32 base buffers — the shared cost
    /// every attached adapter amortizes (uploaded once at build).
    pub fn resident_base_bytes(&self) -> u64 {
        self.bufs
            .values()
            .map(|b| buffer_bytes(b))
            .sum()
    }

    /// Bytes of engine-resident quantized packs across all quant
    /// backends built so far (lazy: zero until a quantized adapter
    /// attaches, then flat however many adapters share the backend).
    pub fn resident_pack_bytes(&self) -> u64 {
        let packs = self.packs.lock().expect("pack cache poisoned");
        packs
            .values()
            .flat_map(|by_name| by_name.values())
            .map(|b| buffer_bytes(b))
            .sum()
    }

    /// The fixed graph inputs (frozen f32 + quantized packs) for `man`,
    /// in manifest order, as shared buffer handles. f32 buffers are the
    /// ones uploaded at construction; packs are quantized from the host
    /// base weights and uploaded once per quant backend, then reused by
    /// every adapter on that backend.
    pub fn fixed_for(&self, engine: &Engine, man: &Manifest) -> Result<Vec<Arc<Buffer>>> {
        ensure!(
            man.preset == self.preset,
            "adapter bundle '{}' (preset '{}') cannot attach to the '{}' base",
            man.tag,
            man.preset,
            self.preset
        );
        let mut out = Vec::with_capacity(man.frozen.len() + man.quantized.len());
        for spec in &man.frozen {
            let buf = self.bufs.get(&spec.name).with_context(|| {
                format!(
                    "base model '{}' lacks frozen input '{}' required by '{}'",
                    self.preset, spec.name, man.tag
                )
            })?;
            out.push(Arc::clone(buf));
        }
        if !man.quantized.is_empty() {
            self.ensure_packs(engine, man)?;
            let packs = self.packs.lock().expect("pack cache poisoned");
            let by_name = packs.get(&man.quant).expect("packs just built");
            for spec in &man.quantized {
                let buf = by_name
                    .get(&spec.name)
                    .with_context(|| format!("missing quantized pack '{}'", spec.name))?;
                out.push(Arc::clone(buf));
            }
        }
        Ok(out)
    }

    /// Quantize + upload any of `man.quant`'s packs not yet resident
    /// (a one-time cost per quant backend and base weight — manifests
    /// quantizing different base subsets on the same backend compose).
    fn ensure_packs(&self, engine: &Engine, man: &Manifest) -> Result<()> {
        let mut packs = self.packs.lock().expect("pack cache poisoned");
        let by_name = packs.entry(man.quant.clone()).or_default();
        for base in man.quantized_bases() {
            let missing = man
                .quantized
                .iter()
                .any(|q| q.base == base && !by_name.contains_key(&q.name));
            if !missing {
                continue;
            }
            let w = self.host(&base)?;
            for (name, lit) in quantize_base(man, &base, w)? {
                if !by_name.contains_key(&name) {
                    by_name.insert(name, Arc::new(engine.upload(&lit)?));
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// AdapterState: the adapter-sized working state
// ---------------------------------------------------------------------------

/// The contiguous element window of the flat (manifest-order
/// concatenated) trainable space one rank owns under ZeRO-1 moment
/// sharding — always `crate::runtime::shard_range(total, rank, ranks)`,
/// the same chunking rule the microbatch tree uses for its leaves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardInfo {
    pub rank: usize,
    pub ranks: usize,
    /// First flat element this rank owns.
    pub lo: usize,
    /// One past the last flat element this rank owns.
    pub hi: usize,
}

impl ShardInfo {
    /// Elements this rank owns.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// Trainables + Adam moments + step counter for one adapter — all the
/// per-tenant state a [`BaseModel`] attachment carries.
pub struct AdapterState {
    /// Trainable literals, manifest order.
    pub tr: Vec<Value>,
    /// First Adam moments: manifest order when full, or a single flat
    /// `[lo..hi)` shard after [`AdapterState::shard_moments`].
    pub m: Vec<Value>,
    /// Second Adam moments (same layout as `m`).
    pub v: Vec<Value>,
    /// Optimizer steps taken.
    pub step: usize,
    /// `Some` once the moments have been re-laid-out as this rank's
    /// ZeRO-1 shard.
    pub shard: Option<ShardInfo>,
}

impl AdapterState {
    /// Initialize from the manifest (checkpoint values win). Moments
    /// and the step counter restore from `__adam_m.*` / `__adam_v.*` /
    /// `__step` entries when present (a full-state resume checkpoint),
    /// else start at zero (a weights-only init checkpoint).
    pub fn init(man: &Manifest, seed: u64, ckpt: Option<&Checkpoint>) -> Result<AdapterState> {
        // A resume checkpoint that recorded its scenario config must be
        // resumed under the same knobs — COFT projection, module
        // dropout and targeting all change the training trajectory, so
        // a silent mismatch would break the bitwise-resume contract.
        if let Some(t) = ckpt.and_then(|c| c.get(crate::scenario::CKPT_KEY)) {
            let saved = crate::scenario::ScenarioCfg::from_checkpoint_tensor(t)
                .context("checkpoint '__scenario' entry is corrupt")?;
            ensure!(
                saved == man.scenario,
                "checkpoint was trained under scenario '{}' but bundle '{}' \
                 resumes under '{}' — resume with the same scenario knobs \
                 (tag suffix / --coft / --module-dropout / targeting)",
                display_suffix(&saved),
                man.tag,
                display_suffix(&man.scenario),
            );
        }
        let mut tr = Vec::with_capacity(man.trainable.len());
        let mut m = Vec::with_capacity(man.trainable.len());
        let mut v = Vec::with_capacity(man.trainable.len());
        for spec in &man.trainable {
            let t = init_param(spec, seed, ckpt)?;
            tr.push(lit_f32(&spec.shape, &t.data)?);
            m.push(moment_literal(spec, ADAM_M_PREFIX, ckpt)?);
            v.push(moment_literal(spec, ADAM_V_PREFIX, ckpt)?);
        }
        let step = match ckpt.and_then(|c| c.get(STEP_KEY)) {
            Some(t) => t.data.first().copied().unwrap_or(0.0) as usize,
            None => 0,
        };
        Ok(AdapterState {
            tr,
            m,
            v,
            step,
            shard: None,
        })
    }

    /// Drop the full Adam moments in favor of this rank's contiguous
    /// element shard (ZeRO-1): after this, `m`/`v` each hold one flat
    /// `[hi - lo]` value and the rank prices ~`2/ranks` of the full
    /// optimizer state. The window is [`crate::runtime::shard_range`]
    /// over the flat manifest-order concatenation, so re-gathering all
    /// ranks' shards in rank order reproduces the full moments exactly.
    pub fn shard_moments(
        &mut self,
        man: &Manifest,
        rank: usize,
        ranks: usize,
    ) -> Result<ShardInfo> {
        ensure!(self.shard.is_none(), "Adam moments are already sharded");
        ensure!(ranks >= 1 && rank < ranks, "rank {rank} out of 0..{ranks}");
        let total: usize = man.trainable.iter().map(|s| s.numel()).sum();
        ensure!(
            ranks <= total,
            "--ranks {ranks} exceeds the {total} trainable elements of '{}'",
            man.tag
        );
        let (lo, hi) = crate::runtime::shard_range(total, rank, ranks);
        let flatten = |vals: &[Value]| -> Result<Vec<f32>> {
            let mut flat = Vec::with_capacity(total);
            for val in vals {
                flat.extend(val.f32s()?);
            }
            ensure!(flat.len() == total, "moments hold {} of {total} elements", flat.len());
            Ok(flat)
        };
        let m_flat = flatten(&self.m)?;
        let v_flat = flatten(&self.v)?;
        self.m = vec![lit_f32(&[hi - lo], &m_flat[lo..hi])?];
        self.v = vec![lit_f32(&[hi - lo], &v_flat[lo..hi])?];
        let info = ShardInfo { rank, ranks, lo, hi };
        self.shard = Some(info);
        Ok(info)
    }
}

/// Bytes one engine buffer holds (0 for device-resident buffers whose
/// host view is unavailable — the engine's `upload_bytes()` counter
/// still covers those).
fn buffer_bytes(b: &Buffer) -> u64 {
    b.as_host()
        .map(|v| (v.element_count() * v.dtype().size_bytes()) as u64)
        .unwrap_or(0)
}

/// Human-readable form of a scenario for mismatch errors: the canonical
/// tag suffix, or "(default)" when no knob is set.
fn display_suffix(sc: &crate::scenario::ScenarioCfg) -> String {
    if sc.is_default() {
        "(default)".to_string()
    } else {
        sc.suffix()
    }
}

fn moment_literal(spec: &ParamSpec, prefix: &str, ckpt: Option<&Checkpoint>) -> Result<Value> {
    if let Some(t) = ckpt.and_then(|c| c.get(&format!("{prefix}{}", spec.name))) {
        ensure!(
            t.shape == spec.shape,
            "checkpoint moment '{prefix}{}' has shape {:?}, manifest wants {:?}",
            spec.name,
            t.shape,
            spec.shape
        );
        return lit_f32(&spec.shape, &t.data);
    }
    lit_f32(&spec.shape, &vec![0.0; spec.numel()])
}

/// Sanity check a quantized-pack literal count: NF4 has 4 packs per
/// base, AWQ has 3.
pub fn packs_per_base(quant: &str) -> Result<usize> {
    Ok(match quant {
        "nf4" => 4,
        "awq" => 3,
        other => bail!("unknown quant backend '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_root;
    use crate::coordinator::manifest::Manifest;

    fn man(tag: &str) -> Manifest {
        Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_order_free() {
        let spec = ParamSpec {
            name: "layers.0.attn.wq".into(),
            shape: vec![8, 8],
            init: Init::Normal(0.02),
        };
        let a = init_param(&spec, 42, None).unwrap();
        let b = init_param(&spec, 42, None).unwrap();
        assert_eq!(a, b);
        let c = init_param(&spec, 43, None).unwrap();
        assert_ne!(a, c);
        // different names, same seed -> different values
        let spec2 = ParamSpec {
            name: "layers.0.attn.wk".into(),
            ..spec.clone()
        };
        assert_ne!(init_param(&spec2, 42, None).unwrap(), a);
    }

    #[test]
    fn checkpoint_overrides_init() {
        let spec = ParamSpec {
            name: "final_norm".into(),
            shape: vec![4],
            init: Init::Ones,
        };
        let mut ck = Checkpoint::new();
        ck.insert("final_norm".into(), Tensor::from_vec(&[4], vec![9.0; 4]));
        let t = init_param(&spec, 0, Some(&ck)).unwrap();
        assert_eq!(t.data, vec![9.0; 4]);
        // shape mismatch is an error, not silent fallback
        ck.insert("final_norm".into(), Tensor::zeros(&[5]));
        assert!(init_param(&spec, 0, Some(&ck)).is_err());
    }

    #[test]
    fn zeros_and_ones_inits() {
        let z = ParamSpec {
            name: "q".into(),
            shape: vec![3],
            init: Init::Zeros,
        };
        assert_eq!(init_param(&z, 1, None).unwrap().data, vec![0.0; 3]);
        let o = ParamSpec {
            name: "g".into(),
            shape: vec![2],
            init: Init::Ones,
        };
        assert_eq!(init_param(&o, 1, None).unwrap().data, vec![1.0; 2]);
    }

    #[test]
    fn full_precision_bundle_state() {
        let m = man("tiny_oft_v2");
        let st = BundleState::init(&m, 7, None).unwrap();
        assert_eq!(st.trainable.len(), m.trainable.len());
        assert_eq!(st.fixed.len(), m.frozen.len());
        assert!(st.quantized_bases.is_empty());
        // adapters start at identity (Q = 0)
        for t in &st.trainable {
            assert!(t.data.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn quantized_bundle_state_pack_counts() {
        for (tag, per_base) in [("tiny_qoft_nf4", 4usize), ("tiny_qoft_awq", 3usize)] {
            let m = man(tag);
            let st = BundleState::init(&m, 7, None).unwrap();
            let n_base = st.quantized_bases.len();
            assert_eq!(m.quantized.len(), n_base * per_base);
            assert_eq!(st.fixed.len(), m.frozen.len() + m.quantized.len());
            assert_eq!(packs_per_base(&m.quant).unwrap(), per_base);
            // pack literal shapes match the manifest
            for (lit, spec) in st.fixed[m.frozen.len()..].iter().zip(&m.quantized) {
                assert_eq!(lit.element_count(), spec.shape.iter().product::<usize>());
            }
        }
    }

    #[test]
    fn base_model_serves_mixed_methods_from_one_upload() {
        let e = crate::runtime::Engine::reference();
        let base = BaseModel::for_preset(&e, "tiny", 7, None).unwrap();
        let n_base = e.upload_count();
        assert_eq!(n_base as usize, base.n_buffers());

        // full-precision adapter: all fixed inputs resolve, no uploads
        let v2 = man("tiny_oft_v2");
        let fixed = base.fixed_for(&e, &v2).unwrap();
        assert_eq!(fixed.len(), v2.frozen.len());
        assert_eq!(e.upload_count(), n_base);

        // quantized adapter: packs built + uploaded once, then reused
        let q = man("tiny_qoft_nf4");
        let fixed_q = base.fixed_for(&e, &q).unwrap();
        assert_eq!(fixed_q.len(), q.frozen.len() + q.quantized.len());
        let after_packs = e.upload_count();
        assert_eq!(after_packs, n_base + q.quantized.len() as u64);
        let again = base.fixed_for(&e, &q).unwrap();
        assert_eq!(again.len(), fixed_q.len());
        assert_eq!(e.upload_count(), after_packs, "packs must be cached");

        // pack literals match what BundleState would have produced
        let st = BundleState::init(&q, 7, None).unwrap();
        for ((arc, lit), spec) in fixed_q[q.frozen.len()..]
            .iter()
            .zip(&st.fixed[q.frozen.len()..])
            .zip(&q.quantized)
        {
            let host = arc.as_host().unwrap();
            assert_eq!(host, lit, "pack '{}' differs from BundleState", spec.name);
        }

        // wrong-preset attachment is rejected
        let other = Manifest::builtin("small_oft_v2").unwrap();
        assert!(base.fixed_for(&e, &other).is_err());
    }

    #[test]
    fn adapter_state_restores_moments_and_step() {
        let m = man("tiny_oft_v2");
        let fresh = AdapterState::init(&m, 7, None).unwrap();
        assert_eq!(fresh.step, 0);
        assert_eq!(fresh.tr.len(), m.trainable.len());
        assert!(fresh.m.iter().all(|v| v.f32s().unwrap().iter().all(|&x| x == 0.0)));

        let mut ck = Checkpoint::new();
        let spec = &m.trainable[0];
        ck.insert(
            format!("{ADAM_M_PREFIX}{}", spec.name),
            Tensor::ones(&spec.shape),
        );
        ck.insert(STEP_KEY.into(), Tensor::from_vec(&[1], vec![9.0]));
        let resumed = AdapterState::init(&m, 7, Some(&ck)).unwrap();
        assert_eq!(resumed.step, 9);
        assert!(resumed.m[0].f32s().unwrap().iter().all(|&x| x == 1.0));
        assert!(resumed.v[0].f32s().unwrap().iter().all(|&x| x == 0.0));

        // shape-mismatched moment is an error, not silent fallback
        let mut bad = Checkpoint::new();
        bad.insert(format!("{ADAM_V_PREFIX}{}", spec.name), Tensor::zeros(&[3]));
        assert!(AdapterState::init(&m, 7, Some(&bad)).is_err());
    }

    #[test]
    fn residency_accounting_tracks_uploads() {
        let e = crate::runtime::Engine::reference();
        let base = BaseModel::for_preset(&e, "tiny", 7, None).unwrap();
        // Base bytes equal what the engine counted at construction.
        assert_eq!(base.resident_base_bytes(), e.upload_bytes());
        assert_eq!(base.resident_pack_bytes(), 0, "packs are lazy");

        let before = e.upload_bytes();
        let q = man("tiny_qoft_nf4");
        base.fixed_for(&e, &q).unwrap();
        let pack_bytes = base.resident_pack_bytes();
        assert!(pack_bytes > 0);
        assert_eq!(e.upload_bytes() - before, pack_bytes);

        // A second adapter on the same backend adds no resident bytes.
        base.fixed_for(&e, &man("tiny_qlora_nf4")).unwrap();
        assert_eq!(base.resident_pack_bytes(), pack_bytes);
        assert_eq!(base.resident_base_bytes() + pack_bytes, e.upload_bytes());
    }

    #[test]
    fn shard_moments_tiles_the_flat_space() {
        let m = man("tiny_oft_v2");
        let total: usize = m.trainable.iter().map(|s| s.numel()).sum();
        // Seed distinct moment values through a resume checkpoint so
        // the tiling is observable.
        let mut ck = Checkpoint::new();
        let mut x = 0.0f32;
        for spec in &m.trainable {
            let data: Vec<f32> = (0..spec.numel())
                .map(|_| {
                    x += 1.0;
                    x
                })
                .collect();
            ck.insert(
                format!("{ADAM_M_PREFIX}{}", spec.name),
                Tensor::from_vec(&spec.shape, data.clone()),
            );
            ck.insert(
                format!("{ADAM_V_PREFIX}{}", spec.name),
                Tensor::from_vec(&spec.shape, data.iter().map(|d| d * 0.5).collect()),
            );
        }
        let full: Vec<f32> = AdapterState::init(&m, 7, Some(&ck))
            .unwrap()
            .m
            .iter()
            .flat_map(|v| v.f32s().unwrap())
            .collect();
        assert_eq!(full.len(), total);

        let ranks = 3;
        let mut cat = Vec::new();
        for rank in 0..ranks {
            let mut st = AdapterState::init(&m, 7, Some(&ck)).unwrap();
            let info = st.shard_moments(&m, rank, ranks).unwrap();
            assert_eq!(
                (info.lo, info.hi),
                crate::runtime::shard_range(total, rank, ranks)
            );
            assert_eq!(st.m.len(), 1);
            assert_eq!(st.m[0].f32s().unwrap().len(), info.len());
            cat.extend(st.m[0].f32s().unwrap());
            assert!(
                st.shard_moments(&m, rank, ranks).is_err(),
                "double shard must fail"
            );
        }
        assert_eq!(cat, full, "rank-order shards must tile the flat moments");

        // more ranks than trainable elements is rejected
        let mut st = AdapterState::init(&m, 7, None).unwrap();
        assert!(st.shard_moments(&m, 0, total + 1).is_err());
    }

    #[test]
    fn nf4_pack_layout_matches_quant_module() {
        let m = man("tiny_qoft_nf4");
        let base = &m.quantized_bases()[0];
        let w = init_quantized_base(&m, base, 7, None).unwrap();
        let packs = quantize_base(&m, base, &w).unwrap();
        let q = crate::quant::Nf4Tensor::quantize(&w);
        let codes = &packs
            .iter()
            .find(|(n, _)| n.ends_with("nf4_codes"))
            .unwrap()
            .1;
        assert_eq!(codes.to_vec::<u8>().unwrap(), q.codes);
    }
}
