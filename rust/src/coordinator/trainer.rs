//! The finetuning trainer: drives the train-step/eval/decode graphs
//! with engine-resident fixed inputs, the LR schedule, metric logging,
//! checkpointing, and greedy decoding.
//!
//! Step anatomy (all graph I/O in manifest order):
//!
//! ```text
//! inputs  = trainables + adam_m + adam_v        (state, re-uploaded)
//!         + frozen f32 + quantized packs        (uploaded ONCE)
//!         + tokens + mask + lr + t              (per-batch data)
//! outputs = new_trainables + new_m + new_v + [loss]
//! ```
//!
//! The frozen/quantized buffers — the bulk of the bytes — live in a
//! shared [`BaseModel`]: one upload serves every trainer, evaluator,
//! and decoder attached to the same base (the multi-adapter property
//! the paper's input-centric design buys). The (small, adapter-sized)
//! [`AdapterState`] round-trips as host values; on both the reference
//! engine and the CPU PJRT backend this is a host-memory copy, uniform
//! across methods, so the paper's *relative* timing claims are
//! preserved.

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::checkpoint::{self, Checkpoint, SHARD_M_KEY, SHARD_META_KEY, SHARD_V_KEY};
use super::manifest::Manifest;
use super::metrics::{EvalRecord, History, StepRecord};
use super::state::{AdapterState, BaseModel, ADAM_M_PREFIX, ADAM_V_PREFIX, STEP_KEY};
use crate::comms::{fnv1a64, RankGroup, SocketReducer};
use crate::config::RunCfg;
use crate::data::corpus::TaskKind;
use crate::data::loader::{Batch, Loader};
use crate::data::tokenizer::EOS;
use crate::runtime::{
    lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, scalar_f32, Buffer, BundleRole, Decoder,
    Engine, Graph, Value,
};
use crate::tensor::Tensor;
use crate::util::argmax;
use crate::util::timer::Timer;
use crate::{log_debug, log_info};

/// A live finetuning run over one artifact bundle.
pub struct Trainer<'e> {
    engine: &'e Engine,
    pub manifest: Manifest,
    pub cfg: RunCfg,
    /// Loaded on first train step — eval/decode-only flows never touch
    /// it, so train-only options (`--workers`, `--grad-checkpoint`)
    /// can't fail a run that never trains.
    train_step: Option<Graph>,
    eval_loss: Graph,
    logits_last: Option<Graph>,
    /// Cached incremental decoder over the current trainables; dropped
    /// whenever a train step changes them (rebuilding re-resolves the
    /// base packs and rotation blocks — too costly per prompt).
    decoder: Option<Decoder>,
    /// The shared frozen base this adapter is attached to.
    base: Arc<BaseModel>,
    /// Frozen f32 weights + quantized packs (manifest order), shared
    /// handles into the base model's engine-resident buffers.
    fixed_bufs: Vec<Arc<Buffer>>,
    /// Trainables / Adam moments / step counter.
    state: AdapterState,
    /// The rank group of a `--ranks N` run ([`Trainer::connect_ranks`]);
    /// `None` for classic single-process training.
    comm: Option<Arc<RankGroup>>,
    pub loader: Loader,
}

impl<'e> Trainer<'e> {
    /// Load bundle `cfg.tag` from `artifacts_root` (or synthesize the
    /// builtin bundle of the same tag), compile its graphs, initialize
    /// state (optionally from `cfg.init_from`), and build the data
    /// pipeline.
    pub fn new(engine: &'e Engine, artifacts_root: &std::path::Path, cfg: RunCfg) -> Result<Self> {
        let manifest = Manifest::load_or_builtin(artifacts_root.join(&cfg.tag))?;
        let ckpt = match &cfg.init_from {
            Some(p) => Some(checkpoint::load(p)?),
            None => None,
        };
        Self::with_checkpoint(engine, manifest, cfg, ckpt.as_ref())
    }

    /// As [`Trainer::new`] but with an in-memory checkpoint (the
    /// pretrain→finetune protocol without touching disk). Builds a
    /// private [`BaseModel`]; use [`Trainer::with_base`] to share one.
    pub fn with_checkpoint(
        engine: &'e Engine,
        manifest: Manifest,
        cfg: RunCfg,
        ckpt: Option<&Checkpoint>,
    ) -> Result<Self> {
        let base = BaseModel::from_manifest(engine, &manifest, cfg.seed, ckpt)?;
        Self::with_base(engine, manifest, cfg, ckpt, base)
    }

    /// Attach a new trainer to an existing shared base: only the
    /// adapter-sized state is created; the frozen/quantized buffers are
    /// the base model's (uploaded once, however many tenants attach).
    pub fn with_base(
        engine: &'e Engine,
        manifest: Manifest,
        cfg: RunCfg,
        ckpt: Option<&Checkpoint>,
        base: Arc<BaseModel>,
    ) -> Result<Self> {
        let t0 = Timer::start();
        let eval_loss = engine.load_bundle_graph(&manifest, BundleRole::EvalLoss)?;
        log_debug!(
            "{}: loaded eval_loss in {:.2}s",
            manifest.tag,
            t0.secs()
        );

        if let Some(c) = ckpt {
            base.ensure_checkpoint_matches(&manifest, c)?;
        }
        let fixed_bufs = base.fixed_for(engine, &manifest)?;
        let state = AdapterState::init(&manifest, cfg.seed, ckpt)?;

        let task = TaskKind::parse(&cfg.data.task)
            .with_context(|| format!("unknown data.task '{}'", cfg.data.task))?;
        let loader = Loader::new(
            task,
            cfg.data.documents,
            cfg.data.seed,
            /*style=*/ 1, // finetuning distribution
            manifest.model.vocab,
            manifest.model.batch,
            manifest.model.seq_len,
        );

        Ok(Trainer {
            engine,
            manifest,
            cfg,
            train_step: None,
            eval_loss,
            logits_last: None,
            decoder: None,
            base,
            fixed_bufs,
            state,
            comm: None,
            loader,
        })
    }

    /// Join a multi-process training group: every rank of a `--ranks N`
    /// run calls this with its connected [`RankGroup`] *before the
    /// first train step*. The Adam moments are re-laid-out as this
    /// rank's ZeRO-1 shard (the `shard_range` window of the flat
    /// trainable space), and subsequent steps run the sharded train
    /// step: full gradients everywhere via the fixed-order tree
    /// all-reduce, the Adam update only on the owned window, updated
    /// params re-assembled by all-gather — bitwise identical to the
    /// single-process step.
    pub fn connect_ranks(&mut self, comm: Arc<RankGroup>) -> Result<()> {
        ensure!(
            self.train_step.is_none(),
            "connect_ranks must be called before the first train step"
        );
        ensure!(
            self.comm.is_none(),
            "trainer is already connected to a rank group"
        );
        ensure!(
            self.cfg.train.ranks == comm.ranks(),
            "config says train.ranks = {}, but the rank group has {} ranks",
            self.cfg.train.ranks,
            comm.ranks()
        );
        self.state
            .shard_moments(&self.manifest, comm.rank(), comm.ranks())?;
        self.comm = Some(comm);
        Ok(())
    }

    /// The rank group this trainer is connected to, if any.
    pub fn rank_group(&self) -> Option<&Arc<RankGroup>> {
        self.comm.as_ref()
    }

    /// Replace the loader (e.g. to reuse a pretraining vocabulary or a
    /// different document budget).
    pub fn set_loader(&mut self, loader: Loader) {
        self.loader = loader;
    }

    /// The shared base this trainer is attached to.
    pub fn base(&self) -> Arc<BaseModel> {
        Arc::clone(&self.base)
    }

    pub fn step_count(&self) -> usize {
        self.state.step
    }

    /// Run one optimizer step on `batch`; returns the (pre-update) loss.
    pub fn train_on(&mut self, batch: &Batch) -> Result<f32> {
        let b = self.manifest.model.batch;
        let t = self.manifest.model.seq_len;
        let n = self.state.tr.len();
        ensure!(batch.batch == b && batch.seq == t, "batch shape mismatch");
        if self.train_step.is_none() {
            // The train step carries the run's gradient-checkpoint
            // policy, worker count, and rank topology; on the reference
            // engine every combination is bitwise identical
            // (per-sequence microbatches + fixed-order tree reduction),
            // so --workers/--grad-checkpoint/--ranks change speed and
            // memory, never the loss curve. Backends without native
            // support reject non-default options here, on the first
            // step.
            let graph = match &self.comm {
                Some(comm) => {
                    let mut opts = self.cfg.train.to_opts();
                    opts.rank = comm.rank();
                    opts.ranks = comm.ranks();
                    let reducer: Arc<dyn crate::runtime::GradReducer> =
                        Arc::new(SocketReducer::new(Arc::clone(comm)));
                    self.engine
                        .load_train_step_sharded(&self.manifest, opts, reducer)?
                }
                None => {
                    ensure!(
                        self.cfg.train.ranks <= 1,
                        "train.ranks = {} but no rank group is connected — \
                         call Trainer::connect_ranks before the first step",
                        self.cfg.train.ranks
                    );
                    self.engine
                        .load_train_step(&self.manifest, self.cfg.train.to_opts())?
                }
            };
            self.train_step = Some(graph);
        }
        // The step is about to change the trainables; any cached
        // decoder would serve stale adapter weights.
        self.decoder = None;
        self.state.step += 1;
        let step = self.state.step;
        let lr = self.cfg.optim.lr_at(step, self.cfg.steps) as f32;

        if let Some(comm) = &self.comm {
            // Data parallelism here is scatter-free: every rank builds
            // the identical deterministic Loader and must therefore see
            // the identical batch. Cross-check a fingerprint against
            // rank 0 so a diverged loader fails loudly instead of
            // silently breaking the bitwise contract.
            let mut bytes =
                Vec::with_capacity(4 * (batch.tokens.len() + batch.mask.len()) + 8);
            for &tk in &batch.tokens {
                bytes.extend_from_slice(&tk.to_le_bytes());
            }
            for &mk in &batch.mask {
                bytes.extend_from_slice(&mk.to_le_bytes());
            }
            bytes.extend_from_slice(&(step as u64).to_le_bytes());
            comm.assert_uniform("training batch", fnv1a64(&bytes))?;
        }

        let tokens = lit_i32(&[b, t + 1], &batch.tokens)?;
        let mask = lit_f32(&[b, t], &batch.mask)?;
        let data = [tokens, mask, lit_scalar_f32(lr), lit_scalar_f32(step as f32)];

        // Upload state + data; fixed buffers are already engine-resident.
        // Sharded runs carry one flat moment value per kind instead of
        // n per-param values, so count the state inputs, don't assume.
        let n_state = self.state.tr.len() + self.state.m.len() + self.state.v.len();
        let mut bufs: Vec<Buffer> = Vec::with_capacity(n_state + 4);
        for lit in self
            .state
            .tr
            .iter()
            .chain(&self.state.m)
            .chain(&self.state.v)
            .chain(&data)
        {
            bufs.push(self.engine.upload(lit)?);
        }
        let mut args: Vec<&Buffer> = Vec::with_capacity(bufs.len() + self.fixed_bufs.len());
        args.extend(bufs[..n_state].iter());
        args.extend(self.fixed_bufs.iter().map(|a| a.as_ref()));
        args.extend(bufs[n_state..].iter());

        let mut outs = self
            .train_step
            .as_ref()
            .expect("train_step loaded above")
            .run_b(&args)?;
        ensure!(
            outs.len() == n_state + 1,
            "train_step returned {} outputs, expected {}",
            outs.len(),
            n_state + 1
        );
        let loss = scalar_f32(&outs[n_state])?;
        ensure!(loss.is_finite(), "loss diverged to {loss} at step {step}");
        outs.truncate(n_state);
        // Restore manifest shapes (PJRT returns flat buffers); sharded
        // moments keep their flat [hi - lo] shard shape.
        let shapes: Vec<Vec<usize>> = self
            .manifest
            .trainable
            .iter()
            .map(|s| s.shape.clone())
            .collect();
        let moment_shapes: Vec<Vec<usize>> = match self.state.shard {
            Some(info) => vec![vec![info.len()]],
            None => shapes.clone(),
        };
        let mut it = outs.into_iter();
        let mut take = |shapes: &[Vec<usize>]| -> Result<Vec<Value>> {
            shapes
                .iter()
                .map(|s| {
                    it.next()
                        .context("train_step output truncated")?
                        .with_shape(s)
                })
                .collect()
        };
        self.state.tr = take(&shapes)?;
        self.state.m = take(&moment_shapes)?;
        self.state.v = take(&moment_shapes)?;
        if self.manifest.model.scenario.coft {
            self.coft_project()?;
        }
        Ok(loss)
    }

    /// COFT's constrained step: after the unconstrained Adam update,
    /// project every identity-at-zero adapter parameter back inside the
    /// eps-ball around the identity (`‖p‖_F <= eps`, uniform scaling).
    /// Runs on the host over the FULL trainables — which are identical
    /// on every rank after the sharded step's all-gather — so the
    /// projected parameters stay bitwise identical across `--workers`
    /// and `--ranks`. Adam moments are deliberately untouched (the
    /// projection is a constraint on the iterate, not the optimizer).
    fn coft_project(&mut self) -> Result<()> {
        let eps = self.manifest.model.scenario.eps;
        for (spec, lit) in self.manifest.trainable.iter().zip(&mut self.state.tr) {
            if spec.init != crate::coordinator::manifest::Init::Zeros {
                continue; // zero ⇔ identity only for the rotation params
            }
            let mut data = lit.to_vec::<f32>()?;
            if crate::scenario::coft_project(&mut data, eps) {
                *lit = lit_f32(&spec.shape, &data)?;
            }
        }
        Ok(())
    }

    /// Run the configured number of steps with logging and periodic
    /// evaluation; returns the metric history.
    pub fn train(&mut self) -> Result<History> {
        let mut history = History::default();
        log_info!(
            "[{}] training {} steps (method={}, quant={}, {} trainable params)",
            self.manifest.tag,
            self.cfg.steps,
            self.manifest.method,
            self.manifest.quant,
            crate::util::human_count(self.manifest.params_trainable)
        );
        for _ in 0..self.cfg.steps {
            let batch = self.loader.next_batch();
            let timer = Timer::start();
            let loss = self.train_on(&batch)?;
            let secs = timer.secs();
            let step = self.state.step;
            let lr = self.cfg.optim.lr_at(step, self.cfg.steps);
            history.push_step(StepRecord {
                step,
                loss: loss as f64,
                lr,
                secs,
            });
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                log_info!(
                    "[{}] step {:>5}  loss {:.4}  lr {:.2e}  {:.1} ms/step",
                    self.manifest.tag,
                    step,
                    loss,
                    lr,
                    secs * 1e3
                );
            }
            if self.cfg.eval_every > 0 && step % self.cfg.eval_every == 0 {
                let (eval_loss, ppl) = self.evaluate()?;
                history.push_eval(EvalRecord {
                    step,
                    eval_loss,
                    perplexity: ppl,
                });
                log_info!(
                    "[{}] step {:>5}  eval_loss {:.4}  ppl {:.2}",
                    self.manifest.tag,
                    step,
                    eval_loss,
                    ppl
                );
            }
        }
        if let Some(dir) = &self.cfg.out_dir {
            let path = std::path::Path::new(dir).join(format!("{}_history.json", self.manifest.tag));
            history.save(&path)?;
            log_info!("[{}] history -> {}", self.manifest.tag, path.display());
        }
        Ok(history)
    }

    /// Mean eval loss + perplexity over the held-out split.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let b = self.manifest.model.batch;
        let t = self.manifest.model.seq_len;
        let n = self.state.tr.len();
        let mut sum_nll = 0.0f64;
        let mut count = 0.0f64;
        for batch in self.loader.eval_batches() {
            let tokens = lit_i32(&[b, t + 1], &batch.tokens)?;
            let mask = lit_f32(&[b, t], &batch.mask)?;
            let mut bufs = Vec::with_capacity(n + 2);
            for lit in self.state.tr.iter() {
                bufs.push(self.engine.upload(lit)?);
            }
            bufs.push(self.engine.upload(&tokens)?);
            bufs.push(self.engine.upload(&mask)?);
            let mut args: Vec<&Buffer> = Vec::new();
            args.extend(bufs[..n].iter());
            args.extend(self.fixed_bufs.iter().map(|a| a.as_ref()));
            args.extend(bufs[n..].iter());
            let outs = self.eval_loss.run_b(&args)?;
            ensure!(outs.len() == 2, "eval_loss returned {} outputs", outs.len());
            sum_nll += scalar_f32(&outs[0])? as f64;
            count += scalar_f32(&outs[1])? as f64;
        }
        let mean = if count > 0.0 { sum_nll / count } else { f64::INFINITY };
        Ok((mean, crate::eval::perplexity(sum_nll, count)))
    }

    /// Build an incremental decoder over the *current* trainables (call
    /// again after further training to pick up new adapter weights).
    pub fn decoder(&self) -> Result<Decoder> {
        let tr: Vec<&Value> = self.state.tr.iter().collect();
        let fixed: Vec<&Buffer> = self.fixed_bufs.iter().map(|a| a.as_ref()).collect();
        self.engine.load_decoder(&self.manifest, &tr, &fixed)
    }

    /// Greedy decoding from `prompt_ids` (BOS included), up to
    /// `max_new` tokens or EOS, via the KV-cached incremental decoder —
    /// O(T) work per generated token. The decoder is cached across
    /// calls until the next train step. Backends without an incremental
    /// decoder (PJRT) fall back to the full re-forward path, which
    /// emits identical tokens. Returns only the generated ids.
    pub fn decode_greedy(&mut self, prompt_ids: &[i32], max_new: usize) -> Result<Vec<i32>> {
        if self.decoder.is_none() {
            match self.decoder() {
                Ok(dec) => self.decoder = Some(dec),
                Err(e) => {
                    log_debug!(
                        "[{}] incremental decoder unavailable ({e:#}); \
                         using the full re-forward decode path",
                        self.manifest.tag
                    );
                    return self.decode_greedy_reforward(prompt_ids, max_new);
                }
            }
        }
        let dec = self.decoder.as_ref().with_context(|| {
            format!(
                "[{}] decode session was never initialized — decode_greedy() builds it \
                 on demand from the current trainables, or call Trainer::decoder() first",
                self.manifest.tag
            )
        })?;
        decode_greedy_session(dec, prompt_ids, max_new)
    }

    /// The pre-KV-cache decode path: re-runs the whole `logits_last`
    /// forward over the padded sequence for every generated token
    /// (O(T²) total). Kept as the correctness oracle the KV path is
    /// tested token-for-token against, and as the bench baseline.
    pub fn decode_greedy_reforward(
        &mut self,
        prompt_ids: &[i32],
        max_new: usize,
    ) -> Result<Vec<i32>> {
        if self.logits_last.is_none() {
            let g = self
                .engine
                .load_bundle_graph(&self.manifest, BundleRole::LogitsLast)?;
            self.logits_last = Some(g);
        }
        let graph = self.logits_last.as_ref().with_context(|| {
            format!(
                "[{}] logits_last graph was never loaded — decode_greedy_reforward() \
                 loads it on demand via Engine::load_bundle_graph(BundleRole::LogitsLast)",
                self.manifest.tag
            )
        })?;
        let t = self.manifest.model.seq_len;
        let vocab = self.manifest.model.vocab;
        let n = self.state.tr.len();

        let mut ids: Vec<i32> = prompt_ids.to_vec();
        ids.truncate(t);
        if ids.is_empty() {
            // Same contract as the KV path: nothing to condition on,
            // nothing generated.
            return Ok(Vec::new());
        }
        let mut generated = Vec::new();
        while generated.len() < max_new && ids.len() < t {
            let mut padded = ids.clone();
            padded.resize(t, 0);
            let tokens = lit_i32(&[1, t], &padded)?;
            let cur = lit_scalar_i32(ids.len() as i32);
            let mut bufs = Vec::with_capacity(n + 2);
            for lit in self.state.tr.iter() {
                bufs.push(self.engine.upload(lit)?);
            }
            bufs.push(self.engine.upload(&tokens)?);
            bufs.push(self.engine.upload(&cur)?);
            let mut args: Vec<&Buffer> = Vec::new();
            args.extend(bufs[..n].iter());
            args.extend(self.fixed_bufs.iter().map(|a| a.as_ref()));
            args.extend(bufs[n..].iter());
            let outs = graph.run_b(&args)?;
            ensure!(outs.len() == 1, "logits_last returned {} outputs", outs.len());
            let logits = outs[0].to_vec::<f32>()?;
            ensure!(logits.len() == vocab, "logits length {}", logits.len());
            let next = argmax(&logits) as i32;
            ids.push(next);
            generated.push(next);
            if next == EOS {
                break;
            }
        }
        Ok(generated)
    }

    /// Decode a text prompt and return the generated text.
    pub fn complete(&mut self, prompt: &str, max_new: usize) -> Result<String> {
        let ids = self.loader.encode_prompt(prompt);
        let gen = self.decode_greedy(&ids, max_new)?;
        Ok(self.loader.tokenizer().decode(&gen))
    }

    /// ROUGE-1/2/L over up to `max_examples` held-out summarization
    /// examples (greedy decode, `max_new` tokens each) — the Table 3
    /// metric.
    pub fn rouge_eval(&mut self, max_examples: usize, max_new: usize) -> Result<crate::eval::Rouge> {
        let examples: Vec<_> = self
            .loader
            .eval_examples()
            .iter()
            .take(max_examples)
            .cloned()
            .collect();
        let mut pairs = Vec::new();
        for ex in examples {
            let out = self.complete(&ex.prompt, max_new)?;
            pairs.push((out, ex.completion));
        }
        ensure!(!pairs.is_empty(), "no eval examples");
        Ok(crate::eval::rouge_corpus(&pairs))
    }

    /// pass@1 (percent) over up to `max_examples` held-out math
    /// problems (greedy decode, answer extracted after `####`) — the
    /// Tables 4/5 metric.
    pub fn pass1_eval(&mut self, max_examples: usize, max_new: usize) -> Result<f64> {
        // Examples without a reference answer (e.g. prose rows mixed
        // into a math corpus) are skipped with a counted warning rather
        // than crashing the eval on an `unwrap`. Examples are cloned
        // one at a time (decoding needs `&mut self`), so stopping at
        // `max_examples` never copies the rest of the eval split.
        let mut pairs = Vec::new();
        let mut skipped = 0usize;
        for i in 0..self.loader.eval_examples().len() {
            if pairs.len() >= max_examples {
                break;
            }
            let ex = self.loader.eval_examples()[i].clone();
            let Some(answer) = ex.answer else {
                skipped += 1;
                continue;
            };
            let out = self.complete(&ex.prompt, max_new)?;
            pairs.push((out, answer));
        }
        if skipped > 0 {
            log_info!(
                "[{}] pass@1: skipped {skipped} eval examples without reference answers",
                self.manifest.tag
            );
        }
        ensure!(!pairs.is_empty(), "no answerable eval examples");
        Ok(crate::eval::pass_at_1(&pairs))
    }

    /// Current trainable tensors (fetched from the working values).
    pub fn trainable_tensors(&self) -> Result<Vec<(String, Tensor)>> {
        self.manifest
            .trainable
            .iter()
            .zip(&self.state.tr)
            .map(|(s, lit)| {
                Ok((
                    s.name.clone(),
                    Tensor::from_vec(&s.shape, lit.to_vec::<f32>()?),
                ))
            })
            .collect()
    }

    /// Current Adam moments as (name, m, v) tensors.
    ///
    /// On a `--ranks N` trainer this is a **collective**: the moments
    /// live sharded, so every rank must call it in the same step (it
    /// all-gathers the shards over the rank group). The gathered result
    /// is identical on every rank and bitwise equal to what a
    /// single-process run would hold.
    pub fn adam_moments(&self) -> Result<Vec<(String, Tensor, Tensor)>> {
        if let (Some(comm), Some(info)) = (&self.comm, self.state.shard) {
            let total: usize = self.manifest.trainable.iter().map(|s| s.numel()).sum();
            let gather = |vals: &[Value], what: &str| -> Result<Vec<f32>> {
                ensure!(vals.len() == 1, "sharded {what} must be one flat value");
                let mine = vals[0].to_vec::<f32>()?;
                ensure!(
                    mine.len() == info.len(),
                    "rank {} holds {} {what} elements, owns {}",
                    info.rank,
                    mine.len(),
                    info.len()
                );
                let rows = comm.all_gather_f32(&mine, "moment gather")?;
                for (r, row) in rows.iter().enumerate() {
                    let (lo, hi) = crate::runtime::shard_range(total, r, info.ranks);
                    ensure!(
                        row.len() == hi - lo,
                        "rank {r} sent {} {what} elements, owns {}",
                        row.len(),
                        hi - lo
                    );
                }
                Ok(rows.concat())
            };
            let m_flat = gather(&self.state.m, "first moments")?;
            let v_flat = gather(&self.state.v, "second moments")?;
            let mut out = Vec::with_capacity(self.manifest.trainable.len());
            let mut off = 0usize;
            for s in &self.manifest.trainable {
                let numel = s.numel();
                out.push((
                    s.name.clone(),
                    Tensor::from_vec(&s.shape, m_flat[off..off + numel].to_vec()),
                    Tensor::from_vec(&s.shape, v_flat[off..off + numel].to_vec()),
                ));
                off += numel;
            }
            return Ok(out);
        }
        self.manifest
            .trainable
            .iter()
            .zip(self.state.m.iter().zip(&self.state.v))
            .map(|(s, (m, v))| {
                Ok((
                    s.name.clone(),
                    Tensor::from_vec(&s.shape, m.to_vec::<f32>()?),
                    Tensor::from_vec(&s.shape, v.to_vec::<f32>()?),
                ))
            })
            .collect()
    }

    /// Engine-resident optimizer-moment bytes this process carries:
    /// `8 * total` single-process, `~8 * total / ranks` under ZeRO-1
    /// sharding (the residency the memory model prices with
    /// `optimizer_shard_bytes`).
    pub fn moment_resident_bytes(&self) -> u64 {
        let elems: usize = self
            .state
            .m
            .iter()
            .chain(&self.state.v)
            .map(|v| v.element_count())
            .sum();
        4 * elems as u64
    }

    /// This rank's shard-checkpoint content for a `--ranks N` run: the
    /// rank's flat Adam-moment shard plus its topology (and, on rank 0
    /// only, the full weight checkpoint). Rank-local — no collectives —
    /// so each rank can write its own file independently;
    /// `checkpoint::reassemble_sharded` stitches the files back into a
    /// byte-identical full-state checkpoint.
    pub fn checkpoint_shard(&self) -> Result<Checkpoint> {
        let info = self
            .state
            .shard
            .context("checkpoint_shard needs sharded moments — connect_ranks first")?;
        let mut ck = if info.rank == 0 {
            self.checkpoint()?
        } else {
            Checkpoint::new()
        };
        ck.insert(
            SHARD_M_KEY.to_string(),
            Tensor::from_vec(&[info.len()], self.state.m[0].to_vec::<f32>()?),
        );
        ck.insert(
            SHARD_V_KEY.to_string(),
            Tensor::from_vec(&[info.len()], self.state.v[0].to_vec::<f32>()?),
        );
        ck.insert(SHARD_META_KEY.to_string(), checkpoint::shard_meta(info));
        ck.insert(
            STEP_KEY.to_string(),
            Tensor::from_vec(&[1], vec![self.state.step as f32]),
        );
        ck.insert(
            crate::scenario::CKPT_KEY.to_string(),
            self.manifest.scenario.to_checkpoint_tensor(),
        );
        Ok(ck)
    }

    /// Export a checkpoint of the current trainables, merged over the
    /// base weights (so a `full` pretraining run exports every base
    /// weight a later PEFT run can `init_from`).
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        let mut ck = Checkpoint::new();
        // frozen weights as initialized (unchanged by training)
        for s in &self.manifest.frozen {
            ck.insert(s.name.clone(), self.base.host(&s.name)?.clone());
        }
        for base in self.manifest.quantized_bases() {
            ck.insert(base.clone(), self.base.host(&base)?.clone());
        }
        for (name, t) in self.trainable_tensors()? {
            ck.insert(name, t);
        }
        Ok(ck)
    }

    /// As [`Trainer::checkpoint`] plus the full optimizer state (Adam
    /// moments under `__adam_m.*` / `__adam_v.*`, the step counter
    /// under `__step`): restoring through [`Trainer::with_checkpoint`]
    /// resumes training bit-for-bit. On a `--ranks N` trainer this is a
    /// collective (it gathers the moment shards via
    /// [`Trainer::adam_moments`]) — every rank must call it together;
    /// use [`Trainer::checkpoint_shard`] for rank-local saves.
    pub fn checkpoint_full(&self) -> Result<Checkpoint> {
        let mut ck = self.checkpoint()?;
        for (name, m, v) in self.adam_moments()? {
            ck.insert(format!("{ADAM_M_PREFIX}{name}"), m);
            ck.insert(format!("{ADAM_V_PREFIX}{name}"), v);
        }
        ck.insert(
            STEP_KEY.to_string(),
            Tensor::from_vec(&[1], vec![self.state.step as f32]),
        );
        // The scenario config (COFT/eps, module-dropout probability and
        // seed, block_share/r, targeting regexes) rides along under
        // `__scenario`, so resuming validates the run is continued under
        // the SAME knobs — the dropout stream in particular is a pure
        // function of (seed, step, name), so persisting seed + step is
        // the whole RNG state and resume replays it bitwise.
        ck.insert(
            crate::scenario::CKPT_KEY.to_string(),
            self.manifest.scenario.to_checkpoint_tensor(),
        );
        Ok(ck)
    }

    /// Save the checkpoint to disk.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        checkpoint::save(path, &self.checkpoint()?)
    }
}

/// Greedy-decode through a KV session: prefill the prompt once, then
/// each generated token costs one incremental step.
pub fn decode_greedy_session(dec: &Decoder, prompt_ids: &[i32], max_new: usize) -> Result<Vec<i32>> {
    let t = dec.max_positions();
    let mut ids: Vec<i32> = prompt_ids.to_vec();
    ids.truncate(t);
    if ids.is_empty() {
        return Ok(Vec::new());
    }
    let mut sess = dec.begin()?;
    let mut logits = Vec::new();
    for &id in &ids {
        logits = sess.step(id)?;
    }
    let mut generated = Vec::new();
    while generated.len() < max_new && ids.len() < t {
        let next = argmax(&logits) as i32;
        ids.push(next);
        generated.push(next);
        if next == EOS {
            break;
        }
        if generated.len() < max_new && ids.len() < t {
            logits = sess.step(next)?;
        }
    }
    Ok(generated)
}

// Full trainer integration tests live in rust/tests/trainer.rs and
// rust/tests/serving.rs; with the reference engine they run without
// artifacts.
