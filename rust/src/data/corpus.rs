//! Synthetic corpora standing in for the paper's datasets.
//!
//! Each generator produces (prompt, completion) [`Example`]s; for pure
//! language modeling the prompt is empty. Generators take a *style*
//! parameter so the harness can pretrain on one distribution and
//! finetune on a shifted one (the pretrain->finetune protocol).

use crate::util::rng::Rng;

/// One training/eval example.
#[derive(Clone, Debug, PartialEq)]
pub struct Example {
    /// Conditioning text (loss-masked during SFT), may be empty.
    pub prompt: String,
    /// Target text (loss-bearing).
    pub completion: String,
    /// Reference answer for exact-match tasks (e.g. "42" for math).
    pub answer: Option<String>,
}

/// Which synthetic task to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// WikiText-like prose LM.
    Wiki,
    /// Arithmetic word problems with CoT + `#### n` answers.
    Math,
    /// Document -> summary pairs.
    Summarize,
}

impl TaskKind {
    pub fn parse(s: &str) -> Option<TaskKind> {
        match s {
            "wiki" => Some(TaskKind::Wiki),
            "math" => Some(TaskKind::Math),
            "summarize" => Some(TaskKind::Summarize),
            _ => None,
        }
    }
}

/// Generate `n` examples of `task` with a seeded RNG. `style` shifts the
/// distribution (0 = pretraining corpus, 1 = finetuning corpus, ...).
pub fn generate(task: TaskKind, n: usize, seed: u64, style: u32) -> Vec<Example> {
    let mut rng = Rng::new(seed ^ ((style as u64) << 32));
    (0..n)
        .map(|_| match task {
            TaskKind::Wiki => wiki_example(&mut rng, style),
            TaskKind::Math => math_example(&mut rng, style),
            TaskKind::Summarize => summarize_example(&mut rng, style),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Wiki-like prose
// ---------------------------------------------------------------------------

const SUBJECTS: &[&str] = &[
    "the river", "the empire", "the composer", "the festival", "the theorem",
    "the village", "the engine", "the treaty", "the comet", "the cathedral",
    "the archive", "the glacier", "the railway", "the senate", "the harbor",
];
const VERBS: &[&str] = &[
    "was founded in", "was described by", "flows through", "was composed during",
    "collapsed after", "expanded across", "was restored in", "was observed near",
    "was signed at", "was excavated from",
];
const OBJECTS: &[&str] = &[
    "the northern province", "the early dynasty", "the industrial era",
    "the coastal region", "the winter campaign", "the old quarter",
    "the great survey", "the second council", "the silk route", "the high plateau",
];
const CONNECTIVES: &[&str] = &["and", "while", "although", "because", "whereas"];
// style-1 (finetuning) vocabulary shift: domain-specific jargon
const SHIFT_OBJECTS: &[&str] = &[
    "the orbital station", "the quantum archive", "the fusion grid",
    "the lunar colony", "the neural lattice",
];

fn wiki_sentence(rng: &mut Rng, style: u32) -> String {
    let s = SUBJECTS[rng.zipf(SUBJECTS.len(), 1.1)];
    let v = VERBS[rng.zipf(VERBS.len(), 1.1)];
    let objs: &[&str] = if style > 0 && rng.next_f64() < 0.5 {
        SHIFT_OBJECTS
    } else {
        OBJECTS
    };
    let o = objs[rng.zipf(objs.len(), 1.1)];
    let year = 1400 + rng.below(600);
    if rng.next_f64() < 0.35 {
        let c = CONNECTIVES[rng.below(CONNECTIVES.len())];
        let s2 = SUBJECTS[rng.zipf(SUBJECTS.len(), 1.1)];
        let v2 = VERBS[rng.zipf(VERBS.len(), 1.1)];
        let o2 = objs[rng.zipf(objs.len(), 1.1)];
        format!("{s} {v} {o} in {year} {c} {s2} {v2} {o2} .")
    } else {
        format!("{s} {v} {o} in {year} .")
    }
}

fn wiki_example(rng: &mut Rng, style: u32) -> Example {
    let n_sent = rng.range(2, 6);
    let text = (0..n_sent)
        .map(|_| wiki_sentence(rng, style))
        .collect::<Vec<_>>()
        .join(" ");
    Example {
        prompt: String::new(),
        completion: text,
        answer: None,
    }
}

// ---------------------------------------------------------------------------
// GSM8K-style arithmetic with chain of thought
// ---------------------------------------------------------------------------

const NAMES: &[&str] = &["ava", "liam", "mia", "noah", "zoe", "eli", "ida", "max"];
const ITEMS: &[&str] = &["apples", "coins", "books", "stones", "cards", "shells"];
// style-1 (finetuning) distribution shift: new entities, same arithmetic
// (keeps the numeric vocabulary identical so small-vocab tokenizers can
// still emit every answer).
const SHIFT_NAMES: &[&str] = &["kira", "omar", "tess", "remy", "june", "axel"];
const SHIFT_ITEMS: &[&str] = &["gears", "seeds", "tiles", "pins"];

fn math_example(rng: &mut Rng, style: u32) -> Example {
    let (names, items): (&[&str], &[&str]) = if style == 0 {
        (NAMES, ITEMS)
    } else {
        (SHIFT_NAMES, SHIFT_ITEMS)
    };
    let name = names[rng.below(names.len())];
    let item = items[rng.below(items.len())];
    let hi = 10;
    let a = rng.range(2, hi);
    let b = rng.range(2, hi);
    let c = rng.range(2, 6);
    // two templates: (a + b) * c and a * c + b
    if rng.next_f64() < 0.5 {
        let ans = (a + b) * c;
        Example {
            prompt: format!(
                "question : {name} has {a} {item} and finds {b} more , each of {c} friends matches the total . how many in all ?"
            ),
            completion: format!(
                "answer : first {a} + {b} = {} . then {} * {c} = {ans} . #### {ans}",
                a + b,
                a + b
            ),
            answer: Some(ans.to_string()),
        }
    } else {
        let ans = a * c + b;
        Example {
            prompt: format!(
                "question : {name} packs {c} boxes of {a} {item} and keeps {b} aside . how many in all ?"
            ),
            completion: format!(
                "answer : first {a} * {c} = {} . then {} + {b} = {ans} . #### {ans}",
                a * c,
                a * c
            ),
            answer: Some(ans.to_string()),
        }
    }
}

// ---------------------------------------------------------------------------
// Summarization pairs
// ---------------------------------------------------------------------------

fn summarize_example(rng: &mut Rng, style: u32) -> Example {
    // A document of topic sentences + filler noise; summary = topic
    // sentences in order. Learnable signal: topic sentences start with a
    // marker word and the model must copy them.
    // Kept short so document + summary fit the small presets' context
    // windows (truncated prompts destroy the copy signal).
    let n_topics = 1;
    let n_noise = rng.range(1, 3);
    let mut sentences: Vec<(bool, String)> = Vec::new();
    for _ in 0..n_topics {
        sentences.push((true, format!("topic {}", wiki_sentence(rng, style))));
    }
    for _ in 0..n_noise {
        sentences.push((false, wiki_sentence(rng, style)));
    }
    rng.shuffle(&mut sentences);
    let doc = sentences
        .iter()
        .map(|(_, s)| s.as_str())
        .collect::<Vec<_>>()
        .join(" ");
    let summary = sentences
        .iter()
        .filter(|(t, _)| *t)
        .map(|(_, s)| s.trim_start_matches("topic ").to_string())
        .collect::<Vec<_>>()
        .join(" ");
    Example {
        prompt: format!("document : {doc} summary :"),
        completion: summary,
        answer: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = generate(TaskKind::Wiki, 10, 7, 0);
        let b = generate(TaskKind::Wiki, 10, 7, 0);
        assert_eq!(a, b);
        let c = generate(TaskKind::Wiki, 10, 8, 0);
        assert_ne!(a, c);
    }

    #[test]
    fn style_shifts_distribution() {
        let pre = generate(TaskKind::Wiki, 200, 7, 0);
        let fin = generate(TaskKind::Wiki, 200, 7, 1);
        let has_shift = |ex: &[Example]| {
            ex.iter()
                .any(|e| SHIFT_OBJECTS.iter().any(|o| e.completion.contains(o)))
        };
        assert!(!has_shift(&pre));
        assert!(has_shift(&fin));
    }

    #[test]
    fn math_answers_are_consistent() {
        for ex in generate(TaskKind::Math, 100, 3, 1) {
            // Fail with the offending example, not a bare unwrap panic.
            let Some(ans) = ex.answer.clone() else {
                panic!("math example missing reference answer: {:?}", ex.prompt)
            };
            assert!(
                ex.completion.trim_end().ends_with(&format!("#### {ans}")),
                "{}",
                ex.completion
            );
            // recompute from the prompt numbers via the CoT line
            assert!(ex.completion.contains('='));
        }
    }

    #[test]
    fn math_cot_arithmetic_is_correct() {
        for ex in generate(TaskKind::Math, 50, 11, 0) {
            // every "x OP y = z" step in the CoT must be true
            for step in ex.completion.split('.') {
                let toks: Vec<&str> = step.split_whitespace().collect();
                for w in toks.windows(5) {
                    if w[3] == "=" {
                        if let (Ok(x), Ok(y), Ok(z)) =
                            (w[0].parse::<i64>(), w[2].parse::<i64>(), w[4].parse::<i64>())
                        {
                            match w[1] {
                                "+" => assert_eq!(x + y, z, "{step}"),
                                "*" => assert_eq!(x * y, z, "{step}"),
                                _ => {}
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn summaries_are_subsets_of_documents() {
        for ex in generate(TaskKind::Summarize, 50, 5, 0) {
            assert!(ex.prompt.starts_with("document :"));
            // each summary sentence appears in the document (after the
            // "topic" marker is stripped)
            for sent in ex.completion.split(" . ") {
                let key = sent.split_whitespace().take(3).collect::<Vec<_>>().join(" ");
                assert!(ex.prompt.contains(&key), "missing '{key}' in doc");
            }
        }
    }

    #[test]
    fn wiki_prompt_is_empty() {
        for ex in generate(TaskKind::Wiki, 5, 1, 0) {
            assert!(ex.prompt.is_empty());
            assert!(!ex.completion.is_empty());
        }
    }
}
