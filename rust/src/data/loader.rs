//! Batching: examples -> fixed-shape (tokens, mask) arrays for the AOT
//! graphs, with loss masking for SFT tasks and stream packing for LM
//! tasks, plus a bounded-channel prefetch thread (the backpressure
//! design DESIGN.md §7 calls out).
//!
//! Graph contract (manifest `inputs.data`):
//!   tokens  (B, T+1) i32 — input row t, target row t+1
//!   mask    (B, T)   f32 — 1.0 where target position t+1 bears loss

use std::sync::mpsc;

use crate::data::corpus::{Example, TaskKind};
use crate::data::tokenizer::{Tokenizer, BOS, EOS, PAD};
use crate::util::rng::Rng;

/// One fixed-shape training/eval batch (row-major flat storage).
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    /// (batch, seq+1) i32.
    pub tokens: Vec<i32>,
    /// (batch, seq) f32.
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    /// Number of loss-bearing target tokens.
    pub fn loss_tokens(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count()
    }
}

/// One tokenized example: full id sequence + index of the first
/// loss-bearing *target* token (prompt tokens are loss-masked).
#[derive(Clone, Debug)]
struct Encoded {
    ids: Vec<i32>,
    loss_start: usize,
}

/// Deterministic train/eval batcher over a synthetic corpus.
#[derive(Clone)]
pub struct Loader {
    tok: Tokenizer,
    task: TaskKind,
    train: Vec<Encoded>,
    eval: Vec<Encoded>,
    /// Raw eval examples (decode-time answer checking, ROUGE refs).
    eval_examples: Vec<Example>,
    batch: usize,
    seq: usize,
    rng: Rng,
    order: Vec<usize>,
    cursor: usize,
}

/// Fraction of examples held out for evaluation.
const EVAL_FRAC: f64 = 0.1;

impl Loader {
    /// Generate `documents` examples of `task` (distribution `style`),
    /// build a tokenizer over them, and split train/eval.
    pub fn new(
        task: TaskKind,
        documents: usize,
        seed: u64,
        style: u32,
        vocab: usize,
        batch: usize,
        seq: usize,
    ) -> Loader {
        let examples = crate::data::corpus::generate(task, documents, seed, style);
        let texts: Vec<String> = examples
            .iter()
            .map(|e| format!("{} {}", e.prompt, e.completion))
            .collect();
        let tok = Tokenizer::build(texts.iter().map(|s| s.as_str()), vocab);
        Self::from_examples(task, examples, tok, seed, batch, seq)
    }

    /// The pretrain→finetune pair: one tokenizer built over the union
    /// of both distributions, so token ids stay aligned across phases
    /// (a finetuning run must see the pretrained embedding rows it
    /// expects). Returns (style-0 pretrain loader, style-1 finetune
    /// loader).
    pub fn pretrain_finetune_pair(
        task: TaskKind,
        documents: usize,
        seed: u64,
        vocab: usize,
        batch: usize,
        seq: usize,
    ) -> (Loader, Loader) {
        let pre = crate::data::corpus::generate(task, documents, seed, 0);
        let fin = crate::data::corpus::generate(task, documents, seed ^ 0x5EED, 1);
        let texts: Vec<String> = pre
            .iter()
            .chain(fin.iter())
            .map(|e| format!("{} {}", e.prompt, e.completion))
            .collect();
        let tok = Tokenizer::build(texts.iter().map(|s| s.as_str()), vocab);
        (
            Self::from_examples(task, pre, tok.clone(), seed, batch, seq),
            Self::from_examples(task, fin, tok, seed.wrapping_add(1), batch, seq),
        )
    }

    /// Build from pre-generated examples and an existing tokenizer (so a
    /// finetuning run can reuse the pretraining vocabulary).
    pub fn from_examples(
        task: TaskKind,
        examples: Vec<Example>,
        tok: Tokenizer,
        seed: u64,
        batch: usize,
        seq: usize,
    ) -> Loader {
        assert!(!examples.is_empty());
        let n_eval = ((examples.len() as f64 * EVAL_FRAC) as usize).clamp(1, examples.len() - 1);
        let (eval_ex, train_ex) = examples.split_at(n_eval);

        let encode = |exs: &[Example]| -> Vec<Encoded> {
            match task {
                // LM: pack the document stream into full-length rows so
                // every position bears loss (WikiText protocol).
                TaskKind::Wiki => pack_stream(exs, &tok, seq),
                // SFT: one example per row, loss only on the completion.
                _ => exs.iter().map(|e| encode_sft(e, &tok)).collect(),
            }
        };
        let train = encode(train_ex);
        let eval = encode(eval_ex);
        let order: Vec<usize> = (0..train.len()).collect();
        Loader {
            tok,
            task,
            train,
            eval,
            eval_examples: eval_ex.to_vec(),
            batch,
            seq,
            rng: Rng::new(seed ^ 0xBA7C4),
            order,
            cursor: 0,
        }
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    pub fn task(&self) -> TaskKind {
        self.task
    }

    pub fn num_train(&self) -> usize {
        self.train.len()
    }

    pub fn num_eval(&self) -> usize {
        self.eval.len()
    }

    /// Raw held-out examples (prompts + reference answers).
    pub fn eval_examples(&self) -> &[Example] {
        &self.eval_examples
    }

    /// Next training batch; reshuffles at each epoch boundary.
    pub fn next_batch(&mut self) -> Batch {
        let mut rows = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor == 0 {
                self.rng.shuffle(&mut self.order);
            }
            rows.push(&self.train[self.order[self.cursor]]);
            self.cursor = (self.cursor + 1) % self.order.len();
        }
        build_batch(&rows, self.batch, self.seq)
    }

    /// Deterministic eval batches covering the held-out split once.
    pub fn eval_batches(&self) -> Vec<Batch> {
        self.eval
            .chunks(self.batch)
            .map(|chunk| {
                // Repeat the last row to fill the fixed batch dimension;
                // padding rows carry zero mask so they are loss-inert.
                let mut rows: Vec<&Encoded> = chunk.iter().collect();
                let pad = Encoded {
                    ids: vec![],
                    loss_start: 0,
                };
                let padded: Vec<Encoded> =
                    (rows.len()..self.batch).map(|_| pad.clone()).collect();
                rows.extend(padded.iter());
                build_batch(&rows, self.batch, self.seq)
            })
            .collect()
    }

    /// Encode a raw prompt for the greedy-decode driver: [BOS] + prompt.
    pub fn encode_prompt(&self, prompt: &str) -> Vec<i32> {
        let mut ids = vec![BOS];
        ids.extend(self.tok.encode(prompt));
        ids
    }

    /// Move the loader onto a prefetch thread with a bounded queue.
    pub fn prefetch(self, capacity: usize) -> Prefetcher {
        Prefetcher::spawn(self, capacity)
    }
}

/// SFT encoding: [BOS] prompt completion [EOS]; loss starts at the first
/// completion *target*.
fn encode_sft(e: &Example, tok: &Tokenizer) -> Encoded {
    let mut ids = vec![BOS];
    ids.extend(tok.encode(&e.prompt));
    let loss_start = ids.len().saturating_sub(1); // target index of first completion token
    ids.extend(tok.encode(&e.completion));
    ids.push(EOS);
    Encoded { ids, loss_start }
}

/// LM packing: concatenate `[BOS] doc [EOS]` streams into rows of
/// exactly seq+1 ids; every target position bears loss.
fn pack_stream(exs: &[Example], tok: &Tokenizer, seq: usize) -> Vec<Encoded> {
    let mut stream: Vec<i32> = Vec::new();
    for e in exs {
        stream.push(BOS);
        stream.extend(tok.encode(&e.completion));
        stream.push(EOS);
    }
    let row_len = seq + 1;
    let mut rows = Vec::new();
    let mut i = 0;
    while i + row_len <= stream.len() {
        rows.push(Encoded {
            ids: stream[i..i + row_len].to_vec(),
            loss_start: 0,
        });
        i += seq; // overlap by one so no target is skipped between rows
    }
    if rows.is_empty() {
        // Tiny corpora still produce one (padded) row.
        rows.push(Encoded {
            ids: stream,
            loss_start: 0,
        });
    }
    rows
}

/// Assemble fixed-shape arrays from encoded rows (truncate/pad to T+1).
fn build_batch(rows: &[&Encoded], batch: usize, seq: usize) -> Batch {
    assert_eq!(rows.len(), batch);
    let row_len = seq + 1;
    let mut tokens = vec![PAD; batch * row_len];
    let mut mask = vec![0.0f32; batch * seq];
    for (r, enc) in rows.iter().enumerate() {
        let n = enc.ids.len().min(row_len);
        tokens[r * row_len..r * row_len + n].copy_from_slice(&enc.ids[..n]);
        // target position t predicts tokens[t+1]; it bears loss iff the
        // target is real (not padding) and at/after loss_start.
        for t in enc.loss_start..seq {
            if t + 1 < n {
                mask[r * seq + t] = 1.0;
            }
        }
    }
    Batch {
        tokens,
        mask,
        batch,
        seq,
    }
}

/// A bounded-queue prefetch thread wrapping a [`Loader`].
///
/// The channel capacity bounds in-flight batches, so a slow consumer
/// (the device) applies backpressure to the producer thread.
pub struct Prefetcher {
    rx: mpsc::Receiver<Batch>,
    handle: Option<std::thread::JoinHandle<Loader>>,
    stop_tx: mpsc::Sender<()>,
}

impl Prefetcher {
    fn spawn(mut loader: Loader, capacity: usize) -> Prefetcher {
        let (tx, rx) = mpsc::sync_channel(capacity.max(1));
        let (stop_tx, stop_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            loop {
                if stop_rx.try_recv().is_ok() {
                    break;
                }
                let b = loader.next_batch();
                if tx.send(b).is_err() {
                    break;
                }
            }
            loader
        });
        Prefetcher {
            rx,
            handle: Some(handle),
            stop_tx,
        }
    }

    /// Blocking receive of the next batch.
    pub fn next_batch(&self) -> Batch {
        self.rx
            .recv()
            .expect("prefetch thread terminated unexpectedly")
    }

    /// The one shutdown path (used by [`Prefetcher::stop`] and `Drop`):
    /// signal the thread, drain the queue until it exits, join.
    /// Idempotent — a second shutdown (or a drop after `stop`) finds
    /// the handle already taken and is a no-op instead of a panic.
    /// Returns `None` when already shut down or the thread panicked.
    fn shutdown(&mut self) -> Option<Loader> {
        let _ = self.stop_tx.send(());
        // Drain so a blocked send unblocks.
        while self.rx.try_recv().is_ok() {}
        let handle = self.handle.take()?;
        // Keep draining until the thread observes the stop signal.
        loop {
            match self.rx.recv_timeout(std::time::Duration::from_millis(10)) {
                Ok(_) => continue,
                Err(_) if handle.is_finished() => break,
                Err(_) => continue,
            }
        }
        handle.join().ok()
    }

    /// Stop the thread and recover the loader (`None` if the thread had
    /// already shut down or panicked — no longer a crash path).
    pub fn stop(mut self) -> Option<Loader> {
        self.shutdown()
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loader(task: TaskKind) -> Loader {
        Loader::new(task, 100, 7, 0, 512, 4, 32)
    }

    #[test]
    fn batch_shapes_fixed() {
        let mut l = loader(TaskKind::Math);
        for _ in 0..5 {
            let b = l.next_batch();
            assert_eq!(b.tokens.len(), 4 * 33);
            assert_eq!(b.mask.len(), 4 * 32);
        }
    }

    #[test]
    fn sft_masks_prompt() {
        let mut l = loader(TaskKind::Math);
        let b = l.next_batch();
        for r in 0..b.batch {
            // the prompt region has zero mask: first few targets masked out
            assert_eq!(b.mask[r * b.seq], 0.0, "row {r} leaks prompt loss");
            // some completion positions bear loss
            assert!(b.mask[r * b.seq..(r + 1) * b.seq].iter().any(|&m| m > 0.0));
        }
    }

    #[test]
    fn wiki_packs_full_rows() {
        let mut l = loader(TaskKind::Wiki);
        let b = l.next_batch();
        // packed LM rows: every target position bears loss
        assert!(b.loss_tokens() >= 4 * 31, "{}", b.loss_tokens());
    }

    #[test]
    fn mask_never_covers_padding() {
        let mut l = loader(TaskKind::Summarize);
        for _ in 0..10 {
            let b = l.next_batch();
            for r in 0..b.batch {
                for t in 0..b.seq {
                    if b.mask[r * b.seq + t] > 0.0 {
                        assert_ne!(b.tokens[r * (b.seq + 1) + t + 1], PAD);
                    }
                }
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = loader(TaskKind::Math);
        let mut b = loader(TaskKind::Math);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn eval_batches_cover_split_once() {
        let l = loader(TaskKind::Math);
        let evs = l.eval_batches();
        assert_eq!(evs.len(), l.num_eval().div_ceil(4));
        // deterministic
        assert_eq!(l.eval_batches(), evs);
    }

    #[test]
    fn epoch_reshuffles() {
        let mut l = Loader::new(TaskKind::Math, 40, 3, 0, 512, 4, 32);
        let epoch_batches = l.num_train() / 4;
        let first: Vec<Batch> = (0..epoch_batches).map(|_| l.next_batch()).collect();
        let second: Vec<Batch> = (0..epoch_batches).map(|_| l.next_batch()).collect();
        assert_ne!(first, second, "epochs should differ in order");
    }

    #[test]
    fn prefetcher_delivers_same_stream() {
        let mut plain = loader(TaskKind::Wiki);
        let expected: Vec<Batch> = (0..6).map(|_| plain.next_batch()).collect();
        let pf = loader(TaskKind::Wiki).prefetch(2);
        for e in &expected {
            assert_eq!(&pf.next_batch(), e);
        }
        assert!(pf.stop().is_some());
    }

    #[test]
    fn prefetcher_stop_recovers_loader_once() {
        let pf = loader(TaskKind::Math).prefetch(2);
        let _ = pf.next_batch();
        // stop() recovers the loader; the drop that follows inside
        // stop() re-enters shutdown and must be a no-op (the old code
        // panicked on the second `handle.take().unwrap()` pattern).
        let mut recovered = pf.stop().expect("first stop recovers the loader");
        let b = recovered.next_batch();
        assert_eq!(b.tokens.len(), 4 * 33);
    }

    #[test]
    fn encode_prompt_starts_with_bos() {
        let l = loader(TaskKind::Math);
        let ids = l.encode_prompt("question : ava has 2 apples");
        assert_eq!(ids[0], BOS);
        assert!(ids.len() > 1);
    }
}
