//! Synthetic-data pipeline: corpora, tokenizer, batching, prefetching.
//!
//! Substitutes for the paper's datasets (DESIGN.md §Substitutions):
//!   * [`wiki`]    — WikiText-2 stand-in: Zipf-vocabulary templated prose
//!                   (perplexity finetuning, Table 4).
//!   * [`math`]    — GSM8K / OpenR1 stand-in: arithmetic word problems
//!                   with chain-of-thought and `#### <answer>` finals
//!                   (exact-match / pass@1, Tables 4, 5, 10).
//!   * [`summarize`] — XSum/CNN-DM stand-in: noisy documents with topic
//!                   sentences; target = the topic sentences (ROUGE,
//!                   Table 3).
//!
//! All generators are deterministic in their seed, so the "pretrain on
//! corpus A, finetune on shifted corpus B" protocol is reproducible.

pub mod corpus;
pub mod loader;
pub mod tokenizer;

pub use corpus::{Example, TaskKind};
pub use loader::{Batch, Loader};
pub use tokenizer::Tokenizer;
