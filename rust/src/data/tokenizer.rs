//! Word-level tokenizer with byte fallback.
//!
//! The synthetic corpora are word-generated, so a word vocabulary built
//! from the generator's lexicon covers them exactly; rare/unknown
//! strings fall back to byte tokens, so *any* text round-trips.
//!
//! Token-id layout (vocab_size >= 512, the byte-fallback layout):
//!   0            PAD
//!   1            BOS
//!   2            EOS
//!   3..3+256    byte fallback tokens
//!   259..       word tokens (most frequent first)
//!
//! For small vocabularies (< 512, e.g. the `tiny` test preset) byte
//! fallback cannot fit; unknown words collapse to a single UNK token:
//!   0 PAD, 1 BOS, 2 EOS, 3 UNK, 4.. word tokens.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
/// UNK id in the compact (small-vocab) layout.
pub const UNK: i32 = 3;
const BYTE_BASE: i32 = 3;
const WORD_BASE: i32 = 259;
/// Smallest vocab that uses the byte-fallback layout.
const BYTE_LAYOUT_MIN: usize = 512;

/// A frozen word-level vocabulary.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    vocab_size: usize,
    byte_fallback: bool,
    word_to_id: HashMap<String, i32>,
    id_to_word: Vec<String>,
}

impl Tokenizer {
    /// Build from a corpus iterator, keeping the most frequent words that
    /// fit into `vocab_size` (ties broken lexicographically for
    /// determinism).
    pub fn build<'a>(texts: impl Iterator<Item = &'a str>, vocab_size: usize) -> Tokenizer {
        let byte_fallback = vocab_size >= BYTE_LAYOUT_MIN;
        let word_base = if byte_fallback { WORD_BASE } else { UNK + 1 };
        assert!(vocab_size as i32 > word_base, "vocab too small");
        let mut counts: HashMap<String, u64> = HashMap::new();
        for t in texts {
            for w in t.split_whitespace() {
                *counts.entry(w.to_string()).or_insert(0) += 1;
            }
        }
        let mut words: Vec<(String, u64)> = counts.into_iter().collect();
        words.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        words.truncate(vocab_size - word_base as usize);
        let mut word_to_id = HashMap::new();
        let mut id_to_word = Vec::new();
        for (i, (w, _)) in words.iter().enumerate() {
            word_to_id.insert(w.clone(), word_base + i as i32);
            id_to_word.push(w.clone());
        }
        Tokenizer {
            vocab_size,
            byte_fallback,
            word_to_id,
            id_to_word,
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    /// Number of known words.
    pub fn num_words(&self) -> usize {
        self.id_to_word.len()
    }

    /// Encode text (whitespace-split words; unknown words become byte
    /// tokens). No BOS/EOS — callers add framing.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for w in text.split_whitespace() {
            match self.word_to_id.get(w) {
                Some(&id) => out.push(id),
                None if self.byte_fallback => {
                    out.extend(w.bytes().map(|b| BYTE_BASE + b as i32))
                }
                None => out.push(UNK),
            }
        }
        out
    }

    /// Decode ids back to a string (byte tokens are merged per run).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut parts: Vec<String> = Vec::new();
        let mut byte_run: Vec<u8> = Vec::new();
        let flush = |run: &mut Vec<u8>, parts: &mut Vec<String>| {
            if !run.is_empty() {
                parts.push(String::from_utf8_lossy(run).into_owned());
                run.clear();
            }
        };
        let word_base = if self.byte_fallback { WORD_BASE } else { UNK + 1 };
        for &id in ids {
            if id == PAD || id == BOS {
                continue;
            }
            if id == EOS {
                break;
            }
            if self.byte_fallback && (BYTE_BASE..BYTE_BASE + 256).contains(&id) {
                byte_run.push((id - BYTE_BASE) as u8);
            } else if !self.byte_fallback && id == UNK {
                flush(&mut byte_run, &mut parts);
                parts.push("<unk>".to_string());
            } else {
                flush(&mut byte_run, &mut parts);
                let wi = (id - word_base) as usize;
                if wi < self.id_to_word.len() {
                    parts.push(self.id_to_word[wi].clone());
                }
            }
        }
        flush(&mut byte_run, &mut parts);
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        let texts = ["the cat sat on the mat", "the dog sat too"];
        Tokenizer::build(texts.iter().copied(), 512)
    }

    #[test]
    fn roundtrip_known_words() {
        let t = tok();
        let ids = t.encode("the cat sat");
        assert_eq!(t.decode(&ids), "the cat sat");
        assert_eq!(ids.len(), 3);
    }

    #[test]
    fn unknown_words_fall_back_to_bytes() {
        let t = tok();
        let ids = t.encode("zebra");
        assert_eq!(ids.len(), 5); // 5 bytes
        assert_eq!(t.decode(&ids), "zebra");
    }

    #[test]
    fn frequency_ordering() {
        let t = tok();
        // "the" (3x) must have the smallest word id
        let the_id = t.encode("the")[0];
        let dog_id = t.encode("dog")[0];
        assert!(the_id < dog_id);
    }

    #[test]
    fn special_tokens_respected() {
        let t = tok();
        assert_eq!(t.decode(&[BOS, PAD]), "");
        let mut ids = t.encode("the cat");
        ids.push(EOS);
        ids.extend(t.encode("dog")); // after EOS: ignored
        assert_eq!(t.decode(&ids), "the cat");
    }

    #[test]
    fn compact_layout_for_small_vocab() {
        let texts = ["the cat sat on the mat"];
        let t = Tokenizer::build(texts.iter().copied(), 256);
        let ids = t.encode("the cat sat");
        assert_eq!(ids.len(), 3);
        assert_eq!(t.decode(&ids), "the cat sat");
        // unknown words become UNK, not bytes
        let unk = t.encode("zebra");
        assert_eq!(unk, vec![UNK]);
        assert_eq!(t.decode(&unk), "<unk>");
        // all ids stay below the declared vocab
        assert!(ids.iter().all(|&i| (i as usize) < 256));
    }

    #[test]
    fn vocab_capacity_respected() {
        let texts = ["a b c d e f g h"];
        // byte-fallback layout: 562 - 259 = 303 slots, all 8 words fit
        let t = Tokenizer::build(texts.iter().copied(), 562);
        assert_eq!(t.num_words(), 8);
        // compact layout: 7 - 4 = 3 word slots
        let t = Tokenizer::build(texts.iter().copied(), 7);
        assert_eq!(t.num_words(), 3);
    }
}
