//! Evaluation metrics used by the paper's tables:
//!
//! * perplexity from summed NLL          — Table 4 (WikiText-2)
//! * ROUGE-1 / ROUGE-2 / ROUGE-L (F1)    — Table 3 (XSum, CNN/DailyMail)
//! * `#### n` answer extraction + pass@1 — Tables 4, 5, 10 (GSM8K-style)

use std::collections::HashMap;

/// Perplexity = exp(total_nll / token_count).
pub fn perplexity(sum_nll: f64, token_count: f64) -> f64 {
    if token_count <= 0.0 {
        return f64::INFINITY;
    }
    (sum_nll / token_count).exp()
}

// ---------------------------------------------------------------------------
// ROUGE
// ---------------------------------------------------------------------------

/// ROUGE-1/2/L F1 scores (percent, as the paper reports them).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rouge {
    pub r1: f64,
    pub r2: f64,
    pub rl: f64,
}

fn tokens(text: &str) -> Vec<&str> {
    text.split_whitespace().collect()
}

fn ngram_counts<'a>(toks: &[&'a str], n: usize) -> HashMap<Vec<&'a str>, usize> {
    let mut m = HashMap::new();
    if toks.len() >= n {
        for w in toks.windows(n) {
            *m.entry(w.to_vec()).or_insert(0) += 1;
        }
    }
    m
}

fn f1(overlap: f64, cand: f64, refr: f64) -> f64 {
    if cand == 0.0 || refr == 0.0 || overlap == 0.0 {
        return 0.0;
    }
    let p = overlap / cand;
    let r = overlap / refr;
    2.0 * p * r / (p + r)
}

/// ROUGE-N F1 between candidate and reference (clipped n-gram overlap).
pub fn rouge_n(candidate: &str, reference: &str, n: usize) -> f64 {
    let c = ngram_counts(&tokens(candidate), n);
    let r = ngram_counts(&tokens(reference), n);
    let overlap: usize = c
        .iter()
        .map(|(g, &cc)| cc.min(r.get(g).copied().unwrap_or(0)))
        .sum();
    let cn: usize = c.values().sum();
    let rn: usize = r.values().sum();
    f1(overlap as f64, cn as f64, rn as f64)
}

/// Longest common subsequence length (O(n·m) DP, two rows).
fn lcs_len(a: &[&str], b: &[&str]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y {
                prev[j] + 1
            } else {
                cur[j].max(prev[j + 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// ROUGE-L F1 (sequence-level LCS).
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let c = tokens(candidate);
    let r = tokens(reference);
    f1(lcs_len(&c, &r) as f64, c.len() as f64, r.len() as f64)
}

/// All three ROUGE scores, scaled to percent.
pub fn rouge(candidate: &str, reference: &str) -> Rouge {
    Rouge {
        r1: 100.0 * rouge_n(candidate, reference, 1),
        r2: 100.0 * rouge_n(candidate, reference, 2),
        rl: 100.0 * rouge_l(candidate, reference),
    }
}

/// Corpus-level ROUGE: mean of per-pair F1 (the convention the
/// summarization literature reports).
pub fn rouge_corpus(pairs: &[(String, String)]) -> Rouge {
    assert!(!pairs.is_empty());
    let mut acc = Rouge {
        r1: 0.0,
        r2: 0.0,
        rl: 0.0,
    };
    for (c, r) in pairs {
        let s = rouge(c, r);
        acc.r1 += s.r1;
        acc.r2 += s.r2;
        acc.rl += s.rl;
    }
    let n = pairs.len() as f64;
    Rouge {
        r1: acc.r1 / n,
        r2: acc.r2 / n,
        rl: acc.rl / n,
    }
}

// ---------------------------------------------------------------------------
// Math answers / pass@1
// ---------------------------------------------------------------------------

/// Extract the final answer after the last `####` marker (GSM8K
/// convention; our synthetic corpus emits `#### <n>`).
pub fn extract_answer(text: &str) -> Option<String> {
    let idx = text.rfind("####")?;
    let tail = &text[idx + 4..];
    let ans: String = tail
        .trim_start()
        .chars()
        .take_while(|c| c.is_ascii_digit() || *c == '-' || *c == '.')
        .collect();
    if ans.is_empty() {
        None
    } else {
        Some(ans)
    }
}

/// Exact-match between an extracted answer and the reference.
pub fn exact_match(prediction: &str, reference: &str) -> bool {
    match extract_answer(prediction) {
        Some(a) => a == reference.trim(),
        None => false,
    }
}

/// pass@1 (percent) over (prediction, reference-answer) pairs — first
/// and only attempt per problem, the paper's Table 5 protocol.
pub fn pass_at_1(pairs: &[(String, String)]) -> f64 {
    assert!(!pairs.is_empty());
    let hits = pairs.iter().filter(|(p, r)| exact_match(p, r)).count();
    100.0 * hits as f64 / pairs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform() {
        // NLL = ln(V) per token over V-way uniform => ppl = V
        let v: f64 = 256.0;
        let ppl = perplexity(v.ln() * 100.0, 100.0);
        assert!((ppl - v).abs() < 1e-6);
        assert!(perplexity(1.0, 0.0).is_infinite());
    }

    #[test]
    fn rouge1_identical_is_100() {
        let s = "the river was founded in 1452";
        let r = rouge(s, s);
        assert!((r.r1 - 100.0).abs() < 1e-9);
        assert!((r.r2 - 100.0).abs() < 1e-9);
        assert!((r.rl - 100.0).abs() < 1e-9);
    }

    #[test]
    fn rouge_disjoint_is_0() {
        let r = rouge("aa bb cc", "xx yy zz");
        assert_eq!(r.r1, 0.0);
        assert_eq!(r.r2, 0.0);
        assert_eq!(r.rl, 0.0);
    }

    #[test]
    fn rouge1_known_value() {
        // cand: 4 tokens, ref: 5 tokens, overlap 3 => P=3/4, R=3/5,
        // F1 = 2*0.75*0.6/1.35 = 2/3
        let f = rouge_n("a b c x", "a b c y z", 1);
        assert!((f - 2.0 / 3.0).abs() < 1e-9, "{f}");
    }

    #[test]
    fn rouge2_counts_bigrams() {
        // shared bigrams: "a b", "b c" => overlap 2; cand 3, ref 4
        let f = rouge_n("a b c x", "a b c y z", 2);
        let expect = f1(2.0, 3.0, 4.0);
        assert!((f - expect).abs() < 1e-9);
    }

    #[test]
    fn rouge_l_subsequence_not_substring() {
        // LCS("a x b y c", "a b c") = 3
        let f = rouge_l("a x b y c", "a b c");
        let expect = f1(3.0, 5.0, 3.0);
        assert!((f - expect).abs() < 1e-9);
    }

    #[test]
    fn rouge_clips_repeats() {
        // candidate repeats "the" 4x; reference has it once -> clipped to 1
        let f = rouge_n("the the the the", "the cat", 1);
        let expect = f1(1.0, 4.0, 2.0);
        assert!((f - expect).abs() < 1e-9);
    }

    #[test]
    fn corpus_rouge_averages() {
        let pairs = vec![
            ("a b".to_string(), "a b".to_string()),
            ("x".to_string(), "y".to_string()),
        ];
        let r = rouge_corpus(&pairs);
        assert!((r.r1 - 50.0).abs() < 1e-9);
    }

    #[test]
    fn extracts_final_answer() {
        assert_eq!(
            extract_answer("first 2 + 3 = 5 . #### 5").as_deref(),
            Some("5")
        );
        // takes the LAST marker
        assert_eq!(
            extract_answer("#### 1 nope #### 42").as_deref(),
            Some("42")
        );
        assert_eq!(extract_answer("no marker here"), None);
        assert_eq!(extract_answer("#### "), None);
    }

    #[test]
    fn exact_match_and_pass1() {
        assert!(exact_match("steps ... #### 12", "12"));
        assert!(!exact_match("steps ... #### 13", "12"));
        let pairs = vec![
            ("#### 1".to_string(), "1".to_string()),
            ("#### 2".to_string(), "3".to_string()),
        ];
        assert_eq!(pass_at_1(&pairs), 50.0);
    }

    #[test]
    fn rouge_properties() {
        // F1 is symmetric in (candidate, reference) and bounded in
        // [0, 100]; identical strings score 100.
        crate::testkit::check("rouge f1 properties", 60, |g| {
            let vocab = ["a", "b", "c", "d", "e", "f"];
            let nc = g.usize_in(1, 12);
            let nr = g.usize_in(1, 12);
            let mut mk = |n: usize| -> String {
                (0..n)
                    .map(|_| *g.choose(&vocab))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            let c = mk(nc);
            let r = mk(nr);
            let s1 = rouge(&c, &r);
            let s2 = rouge(&r, &c);
            for (a, b) in [(s1.r1, s2.r1), (s1.r2, s2.r2), (s1.rl, s2.rl)] {
                if (a - b).abs() > 1e-9 {
                    return Err(format!("asymmetric: {a} vs {b}"));
                }
                if !(0.0..=100.0 + 1e-9).contains(&a) {
                    return Err(format!("out of range: {a}"));
                }
            }
            let self_score = rouge(&c, &c);
            if (self_score.r1 - 100.0).abs() > 1e-9 {
                return Err("self score != 100".into());
            }
            Ok(())
        });
    }

    #[test]
    fn rouge_l_bounded_by_rouge_1() {
        // LCS overlap cannot exceed unigram overlap.
        crate::testkit::check("rouge-L <= rouge-1", 60, |g| {
            let vocab = ["x", "y", "z", "w"];
            let nc = g.usize_in(1, 10);
            let nr = g.usize_in(1, 10);
            let mut mk = |n: usize| -> String {
                (0..n)
                    .map(|_| *g.choose(&vocab))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            let c = mk(nc);
            let r = mk(nr);
            let s = rouge(&c, &r);
            if s.rl > s.r1 + 1e-9 {
                return Err(format!("rl {} > r1 {}", s.rl, s.r1));
            }
            Ok(())
        });
    }

    #[test]
    fn negative_and_decimal_answers() {
        assert_eq!(extract_answer("#### -7").as_deref(), Some("-7"));
        assert_eq!(extract_answer("#### 3.5 end").as_deref(), Some("3.5"));
    }
}
