//! Minimal JSON parser + writer (offline substitute for `serde_json`).
//!
//! Covers the full JSON grammar; used for `manifest.json`, metrics
//! output, and bench result files. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}' in object")),
            _ => bail!("expected object while reading key '{key}'"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Shape helper: `[4, 33]` -> `vec![4, 33]`.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at offset {}", p.pos);
    }
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| anyhow!("reading {}: {e}", path.as_ref().display()))?;
    parse(&text)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!(
                "expected '{}' at offset {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x80 => s.push(c as char),
                c => {
                    // re-decode UTF-8 multibyte sequence
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("bad number '{text}' at offset {start}"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": -2.5e3}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("c").unwrap().as_f64().unwrap(), -2500.0);
        let arr = j.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[2].as_str().unwrap(), "x\n");
        // serialize + reparse
        let again = parse(&j.to_string()).unwrap();
        assert_eq!(again, j);
    }

    #[test]
    fn parses_manifest_like() {
        let j = parse(
            r#"{"inputs": {"trainable": [{"name": "q", "shape": [4, 33],
                "dtype": "f32", "init": ["zeros", 0.0]}]}}"#,
        )
        .unwrap();
        let t = &j.get("inputs").unwrap().get("trainable").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.get("shape").unwrap().as_shape().unwrap(), vec![4, 33]);
        assert_eq!(t.get("init").unwrap().as_arr().unwrap()[0].as_str().unwrap(), "zeros");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn unicode_strings() {
        let j = parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn escapes_on_write() {
        let j = Json::str("a\"b\\c\nd");
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integers_stay_integral() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }
}
