//! # oftv2 — Orthogonal Finetuning Made Scalable (EMNLP 2025) in Rust
//!
//! A three-layer reproduction of the OFTv2/QOFT finetuning system:
//!
//! * **L3 (this crate)** — the finetuning *coordinator*: config system,
//!   launcher, synthetic-data pipeline, training loop, evaluation,
//!   checkpointing, quantization, memory accounting, and the benchmark
//!   harness that regenerates every table and figure of the paper.
//! * **L2 (python/compile/model.py)** — a JAX transformer with pluggable
//!   PEFT adapters (LoRA / weight-centric OFT / input-centric OFTv2 /
//!   QLoRA / QOFT), AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the paper's
//!   hot spots (Cayley–Neumann build, block-diagonal input rotation,
//!   NF4/AWQ dequantization), lowered into the same HLO.
//!
//! The [`runtime`] layer is backend-abstracted. By default every graph
//! executes on the pure-Rust **reference engine**
//! ([`runtime::reference`]) — a native implementation of the same
//! model, backward pass, and kernels — so `cargo build && cargo test`
//! works on a clean checkout with no artifacts, no Python, and no
//! accelerator. The original PJRT/HLO path is behind the `pjrt` cargo
//! feature and consumes the AOT artifacts when they exist.
//!
//! See `README.md` for the quickstart and experiment index.

// Index-heavy numeric kernels read better as explicit loops; the model
// forward/backward naturally takes many tensor arguments.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod adapters;
pub mod artifact;
pub mod bench;
pub mod cli;
pub mod comms;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod json;
pub mod memmodel;
pub mod modelspec;
pub mod peft;
pub mod quant;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod tensor;
pub mod testkit;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Locate the repository's artifact directory: `$OFT_ARTIFACTS`, else
/// `./artifacts` relative to the current dir, else relative to the
/// crate manifest (so tests/benches work from any cwd).
pub fn artifacts_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("OFT_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
