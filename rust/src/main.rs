//! `repro` — the OFTv2/QOFT finetuning launcher.
//!
//! Subcommands:
//!   train    finetune one artifact bundle (config file + --set overrides)
//!   eval     evaluate a bundle's initial state on its held-out split
//!   decode   greedy-decode a prompt through a bundle
//!   merge    fold a checkpoint into a deployable merged artifact
//!   params   print the paper's trainable-parameter tables (Tables 3-5)
//!   memory   print the analytic GPU-memory tables (Figs. 1/4, Table 11)
//!   bundles  list available artifact bundles
//!
//! Examples:
//!   repro train --tag tiny_oft_v2 --steps 50
//!   repro train --config run.toml --set optim.lr=1e-4
//!   repro merge --tag tiny_oft_v2 --checkpoint ck.bin --quant nf4
//!   repro params
//!   repro memory --model qwen2.5-7b

use anyhow::{bail, Context, Result};

use oftv2::cli::{parse_raw, Command};
use oftv2::comms::{CommsCfg, RankGroup};
use oftv2::config::RunCfg;
use oftv2::coordinator::Trainer;
use oftv2::memmodel::{finetune_gib, Method, Precision, TrainShape};
use oftv2::modelspec::ModelSpec;
use oftv2::peft::{count_lora, count_oft};
use oftv2::runtime::Engine;
use oftv2::util::{human_count, human_bytes};
use oftv2::{artifacts_root, log_info};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let raw = parse_raw(argv, /*expect_subcommand=*/ true)?;
    match raw.subcommand.as_deref() {
        Some("train") => cmd_train(&argv[1..]),
        Some("eval") => cmd_eval(&argv[1..]),
        Some("decode") => cmd_decode(&argv[1..]),
        Some("merge") => cmd_merge(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("params") => cmd_params(),
        Some("memory") => cmd_memory(&argv[1..]),
        Some("methods") => cmd_methods(&argv[1..]),
        Some("bundles") => cmd_bundles(),
        Some("inspect") => cmd_inspect(&argv[1..]),
        Some(other) => bail!("unknown subcommand '{other}'\n\n{}", usage()),
        None => {
            println!("{}", usage());
            Ok(())
        }
    }
}

fn usage() -> &'static str {
    "repro — OFTv2/QOFT finetuning framework (EMNLP 2025 reproduction)\n\n\
     Subcommands:\n\
     \x20 train    finetune one artifact bundle\n\
     \x20 eval     evaluate a bundle without training\n\
     \x20 decode   greedy-decode a prompt through a bundle\n\
     \x20 merge    fold a finetuned checkpoint into a deployable merged artifact\n\
     \x20 serve    batched multi-adapter serving over one shared base\n\
     \x20 params   trainable-parameter tables (paper Tables 3-5)\n\
     \x20 memory   analytic GPU-memory tables (paper Figs. 1/4, Table 11)\n\
     \x20 methods  list registered PEFT methods with parameter counts\n\
     \x20 bundles  list available artifact bundles\n\
     \x20 inspect  static HLO cost analysis of a bundle's graphs\n\n\
     Adapter lifecycle example (merge -> requantize -> serve hot-load):\n\
     \x20 repro train --tag tiny_oft_v2 --steps 50 --save-checkpoint ck.bin\n\
     \x20 repro merge --tag tiny_oft_v2 --checkpoint ck.bin --quant nf4 --out merged/tiny_oft_v2.oftmerged\n\
     \x20 repro serve --adapters tiny_lora --artifacts merged/\n\n\
     Run `repro <subcommand> --help` for options."
}

/// Shared config assembly: defaults <- --config file <- individual flags
/// <- --set overrides.
fn run_cfg(args: &oftv2::cli::Args) -> Result<RunCfg> {
    let mut cfg = match args.get("config") {
        Some(path) => RunCfg::from_file(path)?,
        None => RunCfg::default(),
    };
    if let Some(tag) = args.get("tag") {
        cfg.tag = tag.to_string();
    }
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.seed = args.get_usize("seed", cfg.seed as usize)? as u64;
    cfg.log_every = args.get_usize("log-every", cfg.log_every)?;
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every)?;
    cfg.optim.lr = args.get_f64("lr", cfg.optim.lr)?;
    if let Some(task) = args.get("task") {
        cfg.data.task = task.to_string();
    }
    cfg.data.documents = args.get_usize("documents", cfg.data.documents)?;
    if let Some(policy) = args.get("grad-checkpoint") {
        cfg.train.grad_checkpoint = oftv2::runtime::CheckpointPolicy::parse(policy)?;
    }
    if let Some(w) = args.get("workers") {
        cfg.set("train.workers", w)?;
    }
    if let Some(r) = args.get("ranks") {
        cfg.set("train.ranks", r)?;
    }
    if let Some(p) = args.get("init-from") {
        cfg.init_from = Some(p.to_string());
    }
    if let Some(d) = args.get("out-dir") {
        cfg.out_dir = Some(d.to_string());
    }
    // Scenario flags route through the same knob grammar the tag
    // suffix and `[scenario]` config section use.
    if args.has_flag("coft") {
        cfg.set("scenario.coft", "true")?;
    }
    if let Some(v) = args.get("eps") {
        cfg.set("scenario.eps", v)?;
    }
    if let Some(v) = args.get("module-dropout") {
        cfg.set("scenario.dropout", v)?;
    }
    if let Some(v) = args.get("dropout-seed") {
        cfg.set("scenario.dropout_seed", v)?;
    }
    if args.has_flag("block-share") {
        cfg.set("scenario.block_share", "true")?;
    }
    if let Some(v) = args.get("oft-r") {
        cfg.set("scenario.r", v)?;
    }
    if let Some(v) = args.get("oft-block-size") {
        cfg.set("scenario.block", v)?;
    }
    if let Some(v) = args.get("target-modules") {
        cfg.set("scenario.target", v)?;
    }
    if let Some(v) = args.get("exclude-modules") {
        cfg.set("scenario.exclude", v)?;
    }
    // --set a.b=v (repeatable via comma separation)
    if let Some(sets) = args.get("set") {
        for kv in sets.split(',') {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("--set expects key=value, got '{kv}'"))?;
            cfg.set(k.trim(), v.trim())?;
        }
    }
    // Canonicalize: overlay the collected scenario knobs onto the tag's
    // existing suffix. The tag is the one carrier of the scenario —
    // trainer, decode, serve, merge, and checkpoints all resolve it
    // through `Manifest::builtin`.
    cfg.tag = oftv2::scenario::apply_to_tag(&cfg.tag, &cfg.scenario)?;
    Ok(cfg)
}

/// Engine from the `--backend` option. An explicit backend name always
/// wins; `auto` (the default) defers to `Engine::cpu`, which honors the
/// `OFT_BACKEND` env var.
fn engine_for(args: &oftv2::cli::Args) -> Result<Engine> {
    match args.get("backend") {
        Some("auto") | None => Engine::cpu(),
        Some(name) => Engine::by_name(name),
    }
}

fn train_command(name: &'static str, about: &'static str) -> Command {
    Command::new(name, about)
        .opt("config", "TOML run config file", None)
        .opt("tag", "artifact bundle tag (e.g. tiny_oft_v2)", None)
        .opt("steps", "optimizer steps", None)
        .opt("seed", "master seed", None)
        .opt("lr", "peak learning rate", None)
        .opt("task", "data task: wiki | math | summarize", None)
        .opt("documents", "synthetic corpus size", None)
        .opt("log-every", "steps between log lines", None)
        .opt("eval-every", "steps between evals (0 = off)", None)
        .opt(
            "grad-checkpoint",
            "gradient checkpointing: none | every-<k> blocks",
            None,
        )
        .opt("workers", "data-parallel training workers", None)
        .opt("ranks", "multi-process training ranks (1 = single-process)", None)
        .opt("rank", "join an existing group as this rank (spawned by the leader)", None)
        .opt("rendezvous", "rank-0 rendezvous address host:port", None)
        .opt("init-from", "checkpoint to initialize from", None)
        .opt("out-dir", "directory for history/checkpoint output", None)
        .opt("eps", "COFT deviation bound (default 6e-5; implies nothing without --coft)", None)
        .opt("module-dropout", "module dropout probability in [0, 1) (default 0)", None)
        .opt("dropout-seed", "module-dropout decision-stream seed (default fixed)", None)
        .opt("oft-r", "rotation blocks per linear (exclusive with --oft-block-size)", None)
        .opt("oft-block-size", "rotation block size override (exclusive with --oft-r)", None)
        .opt("target-modules", "regex: only matching linears are adapted", None)
        .opt("exclude-modules", "regex: matching linears stay frozen", None)
        .opt("set", "comma-separated config overrides a.b=v", None)
        .flag("coft", "COFT: clamp rotation deviation from identity to --eps after every step")
        .flag("block-share", "share one rotation block across each linear (default off)")
        .opt("save-checkpoint", "path to write the final checkpoint", None)
        .opt("backend", "runtime backend: auto | reference | pjrt", Some("auto"))
        .flag("help", "show help")
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cmd = train_command("train", "finetune one artifact bundle");
    let args = cmd.parse(argv)?;
    if args.has_flag("help") {
        println!("{}", cmd.help_text());
        return Ok(());
    }
    let cfg = run_cfg(&args)?;
    let ranks = cfg.train.ranks;

    if ranks <= 1 {
        if args.get("rank").is_some() {
            bail!("--rank requires --ranks > 1 (a single-process run has no group to join)");
        }
        let engine = engine_for(&args)?;
        log_info!("runtime platform: {}", engine.platform());
        let mut trainer = Trainer::new(&engine, &artifacts_root(), cfg)?;
        let history = trainer.train()?;
        let (eval_loss, ppl) = trainer.evaluate()?;
        println!(
            "final: train_loss {:.4} -> {:.4}, eval_loss {eval_loss:.4}, ppl {ppl:.2}",
            history.first_loss().unwrap_or(f64::NAN),
            history.final_loss().unwrap_or(f64::NAN),
        );
        if let Some(path) = args.get("save-checkpoint") {
            trainer.save_checkpoint(path)?;
            println!("checkpoint -> {path}");
        }
        return Ok(());
    }

    if let Some(r) = args.get("rank") {
        // A group member (spawned by the leader below, or launched by
        // hand): join the rendezvous and run this rank's share.
        let rank: usize = r
            .parse()
            .map_err(|_| anyhow::anyhow!("--rank expects an integer, got '{r}'"))?;
        oftv2::comms::validate_topology(rank, ranks)?;
        let rdv = args
            .get("rendezvous")
            .context("--rank requires --rendezvous (the leader passes it when spawning)")?;
        let group = RankGroup::tcp(rank, ranks, rdv, CommsCfg::default())?;
        return run_rank_train(&args, cfg, group);
    }

    // Leader-launcher: bind the rendezvous first (port 0 picks a free
    // one), spawn ranks 1..N pointing at the real address, then run
    // rank 0 in-process.
    let rdv = args.get_or("rendezvous", "127.0.0.1:0");
    let bind_addr = oftv2::comms::parse_rendezvous(rdv)?;
    let listener = std::net::TcpListener::bind(bind_addr)
        .with_context(|| format!("binding rendezvous {bind_addr}"))?;
    let actual = listener.local_addr().context("rendezvous local addr")?.to_string();
    let exe = std::env::current_exe().context("locating the repro binary for rank spawns")?;

    // Children replay the parsed options verbatim (config file, --set,
    // tag, ...) so every rank assembles an identical RunCfg; only the
    // rank identity and the resolved rendezvous address differ.
    let mut child_args: Vec<String> = Vec::new();
    for (k, v) in &args.options {
        if k == "rank" || k == "rendezvous" {
            continue;
        }
        child_args.push(format!("--{k}={v}"));
    }
    for f in &args.flags {
        child_args.push(format!("--{f}"));
    }
    child_args.push(format!("--rendezvous={actual}"));

    let mut children = Vec::new();
    for rank in 1..ranks {
        let child = std::process::Command::new(&exe)
            .arg("train")
            .args(&child_args)
            .arg(format!("--rank={rank}"))
            .stdout(std::process::Stdio::null())
            .spawn()
            .with_context(|| format!("spawning rank {rank} of {ranks}"))?;
        children.push((rank, child));
    }
    log_info!("spawned ranks 1..{ranks} (rendezvous {actual})");

    let group = RankGroup::tcp_leader(listener, ranks, CommsCfg::default());
    // If the rendezvous failed, still reap the children before erroring.
    let lead = group.and_then(|g| run_rank_train(&args, cfg, g));
    let mut failures = Vec::new();
    for (rank, mut child) in children {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => failures.push(format!("rank {rank} exited with {status}")),
            Err(e) => failures.push(format!("rank {rank} not reaped: {e}")),
        }
    }
    lead?;
    if !failures.is_empty() {
        bail!("{} rank(s) failed: {}", failures.len(), failures.join("; "));
    }
    Ok(())
}

/// One rank's training run: connect the trainer to the group, train,
/// write checkpoints (full + this rank's shard), and report on rank 0.
fn run_rank_train(args: &oftv2::cli::Args, mut cfg: RunCfg, group: RankGroup) -> Result<()> {
    let group = std::sync::Arc::new(group);
    let rank = group.rank();
    if rank > 0 {
        // Rank 0 owns the terminal: the loss curve is bitwise-identical
        // on every rank, so member logs and evals are pure duplication.
        oftv2::util::logging::set_level(oftv2::util::logging::Level::Warn);
        cfg.log_every = 0;
        cfg.eval_every = 0;
        cfg.out_dir = None;
    }
    let engine = engine_for(args)?;
    if rank == 0 {
        log_info!("runtime platform: {} ({} ranks)", engine.platform(), group.ranks());
    }
    let mut trainer = Trainer::new(&engine, &artifacts_root(), cfg)?;
    trainer.connect_ranks(std::sync::Arc::clone(&group))?;
    let history = trainer.train()?;
    if let Some(path) = args.get("save-checkpoint") {
        // checkpoint_full() all-gathers the moment shards — a collective
        // every rank must enter, even though only rank 0 writes it.
        let full = trainer.checkpoint_full()?;
        if rank == 0 {
            oftv2::coordinator::checkpoint::save(path, &full)?;
        }
        let shard = trainer.checkpoint_shard()?;
        let shard_path =
            oftv2::coordinator::checkpoint::shard_checkpoint_path(path, rank, group.ranks());
        oftv2::coordinator::checkpoint::save(&shard_path, &shard)?;
        if rank == 0 {
            println!("checkpoint -> {path} (+{} rank shard files)", group.ranks());
        }
    }
    if rank == 0 {
        let (eval_loss, ppl) = trainer.evaluate()?;
        println!(
            "final: train_loss {:.4} -> {:.4}, eval_loss {eval_loss:.4}, ppl {ppl:.2}",
            history.first_loss().unwrap_or(f64::NAN),
            history.final_loss().unwrap_or(f64::NAN),
        );
    }
    // Keep the group alive until everyone has written their shard, so
    // the leader's exit never races a member's file I/O.
    group.barrier()?;
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cmd = train_command("eval", "evaluate a bundle without training");
    let args = cmd.parse(argv)?;
    if args.has_flag("help") {
        println!("{}", cmd.help_text());
        return Ok(());
    }
    let cfg = run_cfg(&args)?;
    let engine = engine_for(&args)?;
    let trainer = Trainer::new(&engine, &artifacts_root(), cfg)?;
    let (eval_loss, ppl) = trainer.evaluate()?;
    println!(
        "{}: eval_loss {eval_loss:.4}, perplexity {ppl:.2} ({} eval examples)",
        trainer.manifest.tag,
        trainer.loader.num_eval()
    );
    Ok(())
}

fn cmd_decode(argv: &[String]) -> Result<()> {
    let cmd = train_command("decode", "greedy-decode a prompt")
        .opt("prompt", "prompt text", Some("question :"))
        .opt("max-new", "max generated tokens", Some("32"));
    let args = cmd.parse(argv)?;
    if args.has_flag("help") {
        println!("{}", cmd.help_text());
        return Ok(());
    }
    let cfg = run_cfg(&args)?;
    let prompt = args.get_or("prompt", "question :").to_string();
    let max_new = args.get_usize("max-new", 32)?;
    let engine = engine_for(&args)?;
    let mut trainer = Trainer::new(&engine, &artifacts_root(), cfg)?;
    let out = trainer.complete(&prompt, max_new)?;
    println!("prompt:    {prompt}");
    println!("generated: {out}");
    Ok(())
}

/// Fold a finetuned checkpoint into a versioned deployable artifact:
/// merge the adapter into the base through the registry's
/// `Adapter::merge_linear` hook, optionally requantize the merged
/// linears, and write one self-contained file `serve --artifacts`
/// hot-loads as a zero-trainable resident.
fn cmd_merge(argv: &[String]) -> Result<()> {
    let cmd = Command::new("merge", "fold a checkpoint into a deployable merged artifact")
        .opt("tag", "bundle tag the checkpoint was trained as", Some("tiny_oft_v2"))
        .opt(
            "checkpoint",
            "full checkpoint to merge (write one with `train --save-checkpoint`)",
            None,
        )
        .opt("quant", "requantize merged linears: none | nf4 | awq", Some("none"))
        .opt("out", "output artifact path", None)
        .opt("seed", "base seed recorded as provenance", Some("42"))
        .flag("help", "show help");
    let args = cmd.parse(argv)?;
    if args.has_flag("help") {
        println!("{}", cmd.help_text());
        return Ok(());
    }
    let tag = args.get_or("tag", "tiny_oft_v2");
    let ckpt_path = args
        .get("checkpoint")
        .context("--checkpoint is required (write one with `repro train --save-checkpoint`)")?;
    let quant = oftv2::quant::requant::QuantKind::parse(args.get_or("quant", "none"))?;
    let seed = args.get_usize("seed", 42)? as u64;
    let man = oftv2::coordinator::Manifest::load_or_builtin(artifacts_root().join(tag))?;
    let ckpt = oftv2::coordinator::checkpoint::load(ckpt_path)?;
    let art = oftv2::artifact::merge_checkpoint(&man, &ckpt, seed, quant)?;
    let out = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => std::path::PathBuf::from(format!("{tag}.oftmerged")),
    };
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    oftv2::artifact::save(&out, &art)?;

    let rows: Vec<Vec<String>> = art
        .stats
        .iter()
        .map(|s| {
            vec![
                s.linear.clone(),
                format!("{:.6}", s.merged_rms),
                format!("{:.6}", s.baseline_rms),
                format!("{:.3}", s.range_inflation),
                format!("{:.4}", s.delta_inf),
            ]
        })
        .collect();
    oftv2::bench::print_table(
        &format!("merge {tag} (method {}, requant {})", art.method, art.quant.name()),
        &["linear", "requant rms", "baseline rms", "∞-inflation", "‖Δ‖∞"],
        &rows,
    );
    let bytes = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    println!(
        "\nartifact -> {} ({}, {} tensors); hot-load with `repro serve --artifacts <dir>`",
        out.display(),
        human_bytes(bytes),
        art.params.len()
    );
    Ok(())
}

/// Batched multi-tenant serving: N adapters (any mix of PEFT methods)
/// over ONE engine-resident base, bounded admission queue, continuous
/// batching, paged KV-cached incremental decode.
fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "batched multi-adapter serving over one shared base")
        .opt(
            "adapters",
            "comma-separated bundle tags sharing one preset",
            Some("tiny_oft_v2,tiny_qoft_nf4"),
        )
        .opt(
            "artifacts",
            "directory of merged artifacts (repro merge) to hot-load alongside",
            None,
        )
        .opt("requests", "total requests to serve", Some("12"))
        .opt("max-new", "max generated tokens per request", Some("16"))
        .opt("max-batch", "max concurrently active sequences", Some("4"))
        .opt("max-queue", "bounded queue depth (backpressure past it)", Some("64"))
        .opt("kv", "KV layout: paged | contiguous", Some("paged"))
        .opt("block-tokens", "tokens per KV block (paged mode)", Some("16"))
        .opt("max-resident", "resident-decoder cap, 0 = unlimited", Some("0"))
        .opt("task", "prompt task: wiki | math | summarize", Some("math"))
        .opt("documents", "synthetic corpus size for prompts", Some("200"))
        .opt("seed", "master seed", Some("7"))
        .opt("backend", "runtime backend: auto | reference | pjrt", Some("auto"))
        .flag("stream", "print tokens as they are generated")
        .flag("help", "show help");
    let args = cmd.parse(argv)?;
    if args.has_flag("help") {
        println!("{}", cmd.help_text());
        return Ok(());
    }
    let tags: Vec<String> = args
        .get_or("adapters", "")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if tags.is_empty() {
        bail!("--adapters needs at least one bundle tag");
    }
    let requests = args.get_usize("requests", 12)?;
    let max_new = args.get_usize("max-new", 16)?;
    let max_batch = args.get_usize("max-batch", 4)?;
    let max_queue = args.get_usize("max-queue", 64)?;
    let block_tokens = args.get_usize("block-tokens", 16)?;
    let max_resident = args.get_usize("max-resident", 0)?;
    let kv_mode = match args.get_or("kv", "paged") {
        "paged" => oftv2::serve::KvMode::Paged,
        "contiguous" => oftv2::serve::KvMode::Contiguous,
        other => bail!("--kv must be 'paged' or 'contiguous', got '{other}'"),
    };
    let stream = args.has_flag("stream");
    let seed = args.get_usize("seed", 7)? as u64;
    let documents = args.get_usize("documents", 200)?;
    let engine = engine_for(&args)?;
    log_info!("runtime platform: {}", engine.platform());

    let manifests: Vec<oftv2::coordinator::Manifest> = tags
        .iter()
        .map(|t| oftv2::coordinator::Manifest::load_or_builtin(artifacts_root().join(t)))
        .collect::<Result<_>>()?;
    let preset = manifests[0].preset.clone();
    for m in &manifests {
        if m.preset != preset {
            bail!(
                "all adapters must share one base preset; got '{}' and '{}'",
                preset,
                m.preset
            );
        }
    }

    // One shared base, uploaded once; every adapter attaches to it.
    let base = oftv2::coordinator::BaseModel::for_preset(&engine, &preset, seed, None)
        .or_else(|_| oftv2::coordinator::BaseModel::from_manifest(&engine, &manifests[0], seed, None))?;
    let uploads_base = engine.upload_count();
    let mut scfg = oftv2::serve::ServeConfig::new(max_batch);
    scfg.max_queue = max_queue;
    scfg.kv = kv_mode;
    scfg.block_tokens = block_tokens;
    scfg.max_resident = if max_resident == 0 { None } else { Some(max_resident) };
    let mut server = oftv2::serve::Server::with_config(&engine, base, scfg);
    let mut names = Vec::new();
    for (i, (tag, man)) in tags.iter().zip(manifests.iter()).enumerate() {
        let name = if names.iter().any(|n: &String| n == tag) {
            format!("{tag}@{i}")
        } else {
            tag.clone()
        };
        server.add_adapter_init(&name, man.clone(), seed, None)?;
        names.push(name);
    }
    log_info!(
        "base '{preset}' resident ({} f32 buffers); {} adapters attached with {} extra uploads",
        server.base().n_buffers(),
        names.len(),
        engine.upload_count() - uploads_base
    );

    // Merged artifacts join the fleet as zero-trainable residents: one
    // upload burst at attach, then page-ins stay upload-free.
    if let Some(dir) = args.get("artifacts") {
        let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("reading --artifacts dir {dir}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.is_file())
            .collect();
        paths.sort();
        if paths.is_empty() {
            bail!("--artifacts dir {dir} holds no files; write one with `repro merge`");
        }
        let uploads_art = engine.upload_count();
        let mut merged = 0usize;
        for p in paths {
            let art = oftv2::artifact::load(&p)
                .with_context(|| format!("loading artifact {}", p.display()))?;
            let stem = p
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "artifact".to_string());
            let name = if names.iter().any(|n: &String| *n == stem) {
                format!("{stem}@merged")
            } else {
                stem
            };
            server.add_artifact(&name, &art)?;
            names.push(name);
            merged += 1;
        }
        log_info!(
            "{merged} merged artifact(s) hot-loaded from {dir} ({} one-time uploads)",
            engine.upload_count() - uploads_art
        );
    }

    // Synthetic prompts over the preset's vocabulary.
    let dims = manifests[0].model;
    let task = oftv2::data::corpus::TaskKind::parse(args.get_or("task", "math"))
        .context("unknown --task")?;
    let loader = oftv2::data::loader::Loader::new(
        task,
        documents,
        seed,
        /*style=*/ 1,
        dims.vocab,
        dims.batch,
        dims.seq_len,
    );
    let examples = loader.eval_examples().to_vec();
    let tok = loader.tokenizer();
    let mut responses = Vec::new();
    let drain_streamed = |server: &mut oftv2::serve::Server<'_>| {
        if stream {
            for ev in server.take_events() {
                let end = if ev.last { " <end>" } else { "" };
                println!(
                    "  stream #{:<3} [{}] tok[{}] = {}{end}",
                    ev.request_id,
                    ev.adapter,
                    ev.index,
                    tok.decode(&[ev.token]).trim()
                );
            }
        }
    };
    for r in 0..requests {
        let adapter = &names[r % names.len()];
        let ex = &examples[r % examples.len()];
        let prompt = loader.encode_prompt(&ex.prompt);
        loop {
            use oftv2::serve::{RejectReason, Submission};
            match server.try_submit(adapter, prompt.clone(), max_new) {
                Submission::Accepted { .. } => break,
                Submission::Rejected(RejectReason::QueueFull { .. }) => {
                    // Backpressure: run one scheduler step to free a
                    // queue slot, then retry the submission.
                    responses.extend(server.run_step()?);
                    drain_streamed(&mut server);
                }
                Submission::Rejected(r) => bail!("request rejected: {r}"),
            }
        }
    }
    while server.queued() > 0 || server.active() > 0 {
        responses.extend(server.run_step()?);
        drain_streamed(&mut server);
    }

    for resp in responses.iter().take(4) {
        println!(
            "#{:<3} [{}] {:>2} tokens in {:>7.1} ms: {}",
            resp.id,
            resp.adapter,
            resp.tokens.len(),
            resp.latency_secs * 1e3,
            tok.decode(&resp.tokens)
        );
    }
    if responses.len() > 4 {
        println!("... ({} more)", responses.len() - 4);
    }

    let m = server.metrics();
    let rows: Vec<Vec<String>> = m
        .per_adapter
        .iter()
        .map(|(name, a)| {
            vec![
                name.clone(),
                a.requests.to_string(),
                a.tokens_out.to_string(),
                format!("{:.1}", a.mean_ttft_secs() * 1e3),
                format!("{:.1}", a.mean_latency_secs() * 1e3),
                format!("{:.1}", a.tokens_per_sec()),
            ]
        })
        .collect();
    oftv2::bench::print_table(
        "serve: per-adapter metrics",
        &["adapter", "reqs", "tokens", "ttft ms", "latency ms", "tok/s"],
        &rows,
    );
    println!(
        "\n{} requests, {} tokens in {:.2}s wall ({:.1} tok/s aggregate, peak batch {})",
        m.total_requests,
        m.total_tokens,
        m.wall_secs,
        m.tokens_per_sec(),
        m.peak_active
    );
    println!(
        "admission: {} rejected (queue full, limit {}), {} truncated request(s) \
         ({} prompt tokens cut at seq_len)",
        m.rejected_queue_full, max_queue, m.truncated_requests, m.truncated_tokens
    );
    println!(
        "adapter paging: {} page-ins, {} evictions, peak {} resident (cap {})",
        m.adapter_page_ins,
        m.adapter_evictions,
        m.peak_resident,
        if max_resident == 0 { "none".to_string() } else { max_resident.to_string() }
    );
    match server.kv_mode() {
        oftv2::serve::KvMode::Paged => println!(
            "kv pool: {} blocks x {} tokens, peak {} in use, {} allocs, \
             {:.2} MiB slab high-water",
            m.kv.capacity_blocks,
            m.kv.block_tokens,
            m.kv.peak_in_use,
            m.kv.total_allocs,
            m.kv.slab_bytes(dims.n_layers, dims.d_model) as f64 / (1024.0 * 1024.0)
        ),
        oftv2::serve::KvMode::Contiguous => println!(
            "kv: contiguous per-session caches ({} x seq_len {} worst case)",
            max_batch, dims.seq_len
        ),
    }
    Ok(())
}

/// The `# Params` columns of Tables 3, 4, 5 from real model specs.
fn cmd_params() -> Result<()> {
    println!("Trainable parameters (paper Tables 3-5)\n");
    println!("{:<18} {:>14} {:>14}", "model", "LoRA r=16", "OFTv2 b=32");
    for spec in [
        ModelSpec::llama2_7b(),
        ModelSpec::llama2_13b(),
        ModelSpec::qwen25("1.5b")?,
        ModelSpec::qwen25("7b")?,
        ModelSpec::qwen25("32b")?,
    ] {
        println!(
            "{:<18} {:>14} {:>14}",
            spec.name,
            human_count(count_lora(&spec, 16)),
            human_count(count_oft(&spec, 32)),
        );
    }
    println!("\nBART-large budget sweep (Table 3):");
    let bart = ModelSpec::bart_large();
    println!("{:<12} {:>10}   {:<12} {:>10}", "LoRA", "params", "OFTv2", "params");
    for (r, b) in [(8usize, 16usize), (16, 32), (32, 64)] {
        println!(
            "{:<12} {:>10}   {:<12} {:>10}",
            format!("r={r}"),
            human_count(count_lora(&bart, r)),
            format!("b={b}"),
            human_count(count_oft(&bart, b)),
        );
    }
    Ok(())
}

fn cmd_memory(argv: &[String]) -> Result<()> {
    let cmd = Command::new("memory", "analytic finetuning-memory tables")
        .opt("model", "qwen2.5-<size> | llama2-7b | sd3.5-<size>", Some("qwen2.5-7b"))
        .opt("ranks", "ZeRO-1 optimizer-sharding ranks", Some("1"))
        .flag("help", "show help");
    let args = cmd.parse(argv)?;
    if args.has_flag("help") {
        println!("{}", cmd.help_text());
        return Ok(());
    }
    let name = args.get_or("model", "qwen2.5-7b");
    let spec = parse_model(name)?;
    let ranks = args.get_usize("ranks", 1)?;
    if !(1..=oftv2::comms::MAX_RANKS).contains(&ranks) {
        bail!("--ranks must be in 1..={}, got {ranks}", oftv2::comms::MAX_RANKS);
    }
    let shape = TrainShape { ranks, ..TrainShape::default() };
    if ranks > 1 {
        println!(
            "Finetuning memory for {} — per-rank view, Adam state sharded {ranks} ways\n",
            spec.name
        );
    } else {
        println!("Finetuning memory for {} (analytic model)\n", spec.name);
    }
    println!("{:<10} {:<6} {:>12}", "method", "prec", "total");
    for (m, p) in [
        (Method::oft_weight_centric(32), Precision::Bf16),
        (Method::oft_input_centric(32), Precision::Bf16),
        (Method::lora(16), Precision::Bf16),
        (Method::oft_input_centric(32), Precision::Nf4),
        (Method::lora(16), Precision::Nf4),
        (Method::oft_input_centric(32), Precision::Awq4),
        (Method::lora(16), Precision::Awq4),
    ] {
        let gib = finetune_gib(&spec, m, p, shape);
        println!(
            "{:<10} {:<6} {:>12}",
            m.label(p != Precision::Bf16),
            p.label(),
            human_bytes((gib * 1024.0 * 1024.0 * 1024.0) as u64)
        );
    }
    Ok(())
}

/// Static HLO cost analysis (op histogram, FLOPs, arithmetic
/// intensity) of one bundle's graphs — the L2 profiling view.
fn cmd_inspect(argv: &[String]) -> Result<()> {
    let cmd = Command::new("inspect", "static HLO cost analysis")
        .opt("tag", "artifact bundle tag", Some("tiny_oft_v2"))
        .flag("help", "show help");
    let args = cmd.parse(argv)?;
    if args.has_flag("help") {
        println!("{}", cmd.help_text());
        return Ok(());
    }
    let tag = args.get_or("tag", "tiny_oft_v2");
    let man = oftv2::coordinator::Manifest::load_or_builtin(artifacts_root().join(tag))?;
    if !man.artifact(&man.train_step_file).exists() {
        bail!(
            "bundle '{tag}' has no HLO artifacts under {} — static cost analysis \
             reads the lowered graphs; run `python -m compile.aot` first \
             (the reference engine itself does not need them)",
            man.dir.display()
        );
    }
    println!("bundle {tag} (method={}, quant={})\n", man.method, man.quant);
    for file in [&man.train_step_file, &man.eval_loss_file, &man.logits_last_file] {
        let cost = oftv2::runtime::hlo_cost::analyze_file(man.artifact(file))?;
        println!("{file}:");
        println!(
            "  dot FLOPs {:>14}   elementwise {:>12}   output bytes {:>12}   intensity {:.2}",
            cost.dot_flops,
            cost.elementwise_flops,
            cost.output_bytes,
            cost.intensity()
        );
        let top: Vec<String> = cost
            .top_ops(6)
            .into_iter()
            .map(|(op, n)| format!("{op} x{n}"))
            .collect();
        println!("  top ops: {}", top.join(", "));
    }
    Ok(())
}

/// List every registered PEFT method with its exact trainable-param
/// count on one preset — the registry made visible. Unknown methods
/// anywhere in the CLI error with this same list.
fn cmd_methods(argv: &[String]) -> Result<()> {
    let cmd = Command::new("methods", "list registered PEFT methods")
        .opt("preset", "model preset for the parameter counts", Some("tiny"))
        .flag("help", "show help");
    let args = cmd.parse(argv)?;
    if args.has_flag("help") {
        println!("{}", cmd.help_text());
        return Ok(());
    }
    let preset = args.get_or("preset", "tiny");
    println!("Registered PEFT methods (preset '{preset}')\n");
    println!(
        "{:<12} {:<6} {:<6} {:<6} {:>12}  {:<22} {:<40} {}",
        "method", "label", "quant", "merge", "trainable", "example tag", "scenario knobs", "about"
    );
    for adapter in oftv2::adapters::all() {
        let tag = oftv2::adapters::bundle_tag(preset, *adapter);
        // One incompatible (method, preset) pair must not hide the
        // rest of the registry from the listing.
        let trainable = match oftv2::coordinator::Manifest::builtin(&tag) {
            Ok(man) => human_count(man.params_trainable),
            Err(e) => format!("(unavailable: {e})"),
        };
        let knobs = adapter.supported_knobs();
        let knobs = if knobs.is_empty() {
            "(none)".to_string()
        } else {
            knobs.iter().map(|k| k.key()).collect::<Vec<_>>().join(",")
        };
        println!(
            "{:<12} {:<6} {:<6} {:<6} {:>12}  {:<22} {:<40} {}",
            adapter.name(),
            adapter.paper_label(adapter.quantized_base()),
            if adapter.quantized_base() { "4-bit" } else { "f32" },
            if adapter.can_merge() { "yes" } else { "no" },
            trainable,
            tag,
            knobs,
            adapter.about()
        );
    }
    println!(
        "\nselect with --tag <preset>_<method>[_<quant>]; append scenario knobs as \
         tag suffixes (e.g. {preset}_oft_v2+coft+target=wq|wv) or `train` flags \
         (--coft, --module-dropout, --target-modules, ...); fold a trained adapter \
         into a deployable base with `repro merge`; \
         see README \"Adding a PEFT method\" to register a new one"
    );
    Ok(())
}

fn parse_model(name: &str) -> Result<ModelSpec> {
    Ok(match name.to_lowercase().as_str() {
        "llama2-7b" => ModelSpec::llama2_7b(),
        "llama2-13b" => ModelSpec::llama2_13b(),
        "bart-large" => ModelSpec::bart_large(),
        n if n.starts_with("qwen2.5-") => ModelSpec::qwen25(&n["qwen2.5-".len()..])?,
        n if n.starts_with("sd3.5-") => ModelSpec::sd35(&n["sd3.5-".len()..])?,
        _ => bail!("unknown model '{name}'"),
    })
}

fn cmd_bundles() -> Result<()> {
    let root = artifacts_root();
    if !root.exists() {
        println!("no artifact tree at {} — builtin bundles (reference engine):\n", root.display());
        println!("{:<22} {:<12} {:<6} {:>12} {:>10}", "tag", "method", "quant", "trainable", "d_model");
        for preset in ["tiny", "small", "bench", "fig1", "e2e", "e2e100m"] {
            // One tag per registered method (quantized methods on both
            // 4-bit backends) — the list grows with the registry.
            let mut suffixes: Vec<String> = Vec::new();
            for adapter in oftv2::adapters::all() {
                if adapter.quantized_base() {
                    suffixes.push(format!("{}_nf4", adapter.name()));
                    suffixes.push(format!("{}_awq", adapter.name()));
                } else {
                    suffixes.push(adapter.name().to_string());
                }
            }
            for suffix in suffixes {
                let tag = format!("{preset}_{suffix}");
                if let Ok(man) = oftv2::coordinator::Manifest::builtin(&tag) {
                    println!(
                        "{:<22} {:<12} {:<6} {:>12} {:>10}",
                        man.tag,
                        man.method,
                        man.quant,
                        human_count(man.params_trainable),
                        man.model.d_model
                    );
                }
            }
        }
        return Ok(());
    }
    println!("{:<22} {:<12} {:<6} {:>12} {:>10}", "tag", "method", "quant", "trainable", "d_model");
    let mut entries: Vec<_> = std::fs::read_dir(&root)?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().join("manifest.json").exists())
        .collect();
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        if e.file_name() == "micro" {
            println!("{:<22} (micro-kernel sweep bundle)", "micro");
            continue;
        }
        let man = oftv2::coordinator::Manifest::load(e.path())?;
        println!(
            "{:<22} {:<12} {:<6} {:>12} {:>10}",
            man.tag,
            man.method,
            man.quant,
            human_count(man.params_trainable),
            man.model.d_model
        );
    }
    Ok(())
}
