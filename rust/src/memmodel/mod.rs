//! Analytic GPU-memory model for finetuning — regenerates the paper's
//! memory results (Fig. 1, Fig. 4a/b/c, Table 11) on a machine with no
//! GPU.
//!
//! The model is an inventory sum, the same arithmetic one does when
//! sizing a training run:
//!
//!   total = base weights + adapter params + adapter grads
//!         + optimizer state + activations + method-specific transients
//!         + framework overhead (CUDA context, allocator slack)
//!
//! The decisive *method-dependent* term is the transient: weight-centric
//! OFT materializes `blockdiag(R)` (din x din) **and** the merged weight
//! `R W` (din x dout) for every adapted linear, and autograd keeps the
//! merged weights alive for the backward pass — that is the 3x Fig. 1
//! gap. Input-centric OFTv2 only keeps the rotated activations, like
//! LoRA keeps its low-rank activations.

use anyhow::Result;

use crate::modelspec::ModelSpec;
use crate::peft::counting::{count, MethodKind};
use crate::runtime::CheckpointPolicy;

/// Weight storage precision of the frozen base model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Bf16,
    Nf4,
    Awq4,
}

impl Precision {
    /// Bytes per parameter including quantization metadata
    /// (NF4: 0.5 + absmax_q 1/64 + scales 4/16384; AWQ: 0.5 + f32 scale
    /// per 64-element group + eq vector, amortized).
    pub fn bytes_per_param(self) -> f64 {
        match self {
            Precision::Bf16 => 2.0,
            Precision::Nf4 => 0.5 + 1.0 / 64.0 + 4.0 / 16384.0,
            Precision::Awq4 => 0.5 + 4.0 / 64.0,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::Bf16 => "BF16",
            Precision::Nf4 => "NF4",
            Precision::Awq4 => "AWQ",
        }
    }
}

/// Finetuning method for memory purposes: a thin view onto the adapter
/// registry (see [`crate::adapters`]). The method-specific pricing —
/// parameter counts and the transient term — lives in each adapter's
/// own module; this struct only carries the registry handle plus the
/// rank/block hyperparameters the paper sweeps.
#[derive(Clone, Copy)]
pub struct Method {
    kind: MethodKind,
}

impl Method {
    /// LoRA / QLoRA with rank `r`.
    pub fn lora(r: usize) -> Method {
        Method { kind: MethodKind::lora(r) }
    }

    /// Weight-centric OFT baseline with block size `b` (the merged
    /// `blockdiag(R) @ W` transient — the Fig. 1 memory cliff).
    pub fn oft_weight_centric(b: usize) -> Method {
        Method {
            kind: MethodKind::oft_merged(b),
        }
    }

    /// Input-centric OFTv2 / QOFT with block size `b`.
    pub fn oft_input_centric(b: usize) -> Method {
        Method { kind: MethodKind::oft(b) }
    }

    /// Any registered method by name, with explicit rank/block
    /// hyperparameters — prices BOFT, HOFT, or a future method without
    /// touching this module.
    pub fn by_name(name: &str, r: usize, b: usize) -> Result<Method> {
        Ok(Method {
            kind: MethodKind::by_name(name, r, b)?,
        })
    }

    pub fn kind(self) -> MethodKind {
        self.kind
    }

    /// Fold a scenario's numeric knobs (`r`/`block`/`block_share`) into
    /// the analysis dims, so parameter counts and transients price the
    /// configured shapes. Targeting is handled by
    /// [`finetune_memory_scenario`] (regexes don't fit in Copy dims).
    pub fn with_scenario(mut self, sc: &crate::scenario::ScenarioCfg) -> Method {
        self.kind.dims.scenario = sc.dims();
        if sc.block > 0 {
            self.kind.dims.block_b = sc.block;
        }
        self
    }

    pub fn label(self, quantized: bool) -> String {
        self.kind.adapter.paper_label(quantized).to_string()
    }
}

/// How a quantized base resides on-device during compute.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BaseResidency {
    /// Fused block-dequant kernels read the packs directly (this
    /// engine's path): residency is the packed bytes only.
    #[default]
    Packed,
    /// Dequantize-at-assembly: the packs are expanded into a full f32
    /// copy of every quantized linear before compute — what this repo
    /// paid before the fused kernels, and what naive engines still pay.
    DequantF32,
}

/// Training-shape knobs.
#[derive(Clone, Copy, Debug)]
pub struct TrainShape {
    pub batch: usize,
    pub seq: usize,
    /// Activation bytes (bf16 autograd saves).
    pub act_bytes: f64,
    /// Gradient-checkpoint policy on transformer blocks (the same
    /// [`CheckpointPolicy`] the reference trainer executes):
    /// `EveryK(1)` is the HF default for large-model finetuning,
    /// `EveryK(k)` keeps one boundary per k blocks at the cost of a
    /// k-block live recompute window, `None` keeps every save.
    pub checkpoint: CheckpointPolicy,
    /// Packed-vs-dequantized residency of a quantized base (ignored at
    /// BF16, which has no packs).
    pub residency: BaseResidency,
    /// Multi-process training ranks (`--ranks`). Adam moments are
    /// ZeRO-1 sharded across the group, so each rank holds only its
    /// `ceil(n/ranks)`-element window of the optimizer state; params,
    /// grads, and activations stay fully replicated.
    pub ranks: usize,
}

impl Default for TrainShape {
    fn default() -> Self {
        TrainShape {
            batch: 1,
            seq: 2048,
            act_bytes: 2.0,
            checkpoint: CheckpointPolicy::EveryK(1),
            residency: BaseResidency::Packed,
            ranks: 1,
        }
    }
}

impl TrainShape {
    /// Whether any per-block saves are dropped and recomputed.
    fn checkpointed(&self) -> bool {
        self.checkpoint.every().is_some()
    }
}

/// Byte breakdown of one finetuning configuration.
#[derive(Clone, Copy, Debug)]
pub struct MemBreakdown {
    pub base_weights: f64,
    pub adapter_params: f64,
    pub adapter_grads: f64,
    pub optimizer: f64,
    pub activations: f64,
    pub transient: f64,
    pub overhead: f64,
}

impl MemBreakdown {
    pub fn total(&self) -> f64 {
        self.base_weights
            + self.adapter_params
            + self.adapter_grads
            + self.optimizer
            + self.activations
            + self.transient
            + self.overhead
    }

    pub fn total_gib(&self) -> f64 {
        self.total() / (1024.0 * 1024.0 * 1024.0)
    }
}

/// Fixed framework overhead (CUDA context, cuBLAS workspaces, allocator
/// slack) — calibrated to the ~1.2 GiB floor real PyTorch runs show.
const FRAMEWORK_OVERHEAD: f64 = 1.2 * 1024.0 * 1024.0 * 1024.0;

/// Estimate finetuning memory for (model, method, precision, shape).
pub fn finetune_memory(
    spec: &ModelSpec,
    method: Method,
    precision: Precision,
    shape: TrainShape,
) -> MemBreakdown {
    let n_adapter = count(spec, method.kind()) as f64;
    // Quantization applies to the big trunk linears only — embeddings,
    // norms, lm_head, and (for SD3.5) the frozen text encoders stay in
    // bf16, exactly as bitsandbytes / AutoAWQ treat them.
    let other_params = (spec.total_params() - spec.linear_params()) as f64;
    let mut base_weights =
        spec.linear_params() as f64 * precision.bytes_per_param() + other_params * 2.0;
    // A dequantize-at-assembly engine holds a full f32 copy of every
    // quantized linear *next to* the packs — the residency the fused
    // block-dequant kernels eliminate.
    if precision != Precision::Bf16 && shape.residency == BaseResidency::DequantF32 {
        base_weights += spec.linear_params() as f64 * 4.0;
    }

    // Adapter trained in f32 master + bf16 compute copy is the common
    // setup; Adam keeps two f32 moments, ZeRO-1 sharded across ranks.
    let adapter_params = n_adapter * 4.0;
    let adapter_grads = n_adapter * 4.0;
    let optimizer = optimizer_shard_bytes(n_adapter, shape.ranks);

    let tokens = (shape.batch * shape.seq) as f64;
    let d = spec.d_model as f64;
    let l = spec.n_layers as f64;
    // Per-block saved activations (bf16), per CheckpointPolicy. A
    // non-checkpointed block keeps ~14 d-wide tensors; a checkpointed
    // run keeps ~2 d-wide tensors per segment boundary (block input +
    // one checkpoint inside) plus, during backward, one live segment
    // of k recomputed blocks at the full 14 — the time/memory
    // trade-off `fig1_time_memory` sweeps.
    // Attention probabilities are never materialized: every stack the
    // paper benchmarks (HF transformers / diffusers) runs SDPA/flash
    // attention, which keeps the seq x seq matrix in registers.
    const BLOCK_VECS_FULL: f64 = 14.0;
    const BLOCK_VECS_BOUNDARY: f64 = 2.0;
    let saved_vecs = match shape.checkpoint.every() {
        None => BLOCK_VECS_FULL * l,
        Some(k) => {
            let k = (k as f64).min(l);
            BLOCK_VECS_BOUNDARY * (l / k).ceil() + BLOCK_VECS_FULL * k
        }
    };
    let mut activations = tokens * d * saved_vecs * shape.act_bytes;
    // logits + embeddings staging
    activations += tokens * (spec.vocab.max(1) as f64).min(160_000.0) * 0.05 * shape.act_bytes
        + tokens * d * 4.0;

    // Every PEFT method saves the adapted linears' *inputs* for the
    // adapter gradient (grad_A for LoRA, grad_Q for OFT) — the frozen
    // base weight itself needs no gradient. Under gradient
    // checkpointing these are recomputed and only one block's saves are
    // live at a time.
    let adapter_input_saves: f64 = if shape.checkpointed() {
        spec.linears_per_layer
            .iter()
            .map(|li| tokens * li.din as f64 * shape.act_bytes)
            .sum::<f64>() // one live block
    } else {
        spec.adapted_linears()
            .map(|li| tokens * li.din as f64 * shape.act_bytes)
            .sum::<f64>()
    };

    // Method-specific transient, priced by the adapter module itself
    // (e.g. LoRA adds its saved low-rank activations; weight-centric
    // OFT adds the materialized blockdiag(R) + merged RW — the paper's
    // memory cliff; input-centric methods add nothing).
    let k = method.kind();
    let transient = k.adapter.mem_transient(
        spec,
        &k.dims,
        tokens,
        shape.act_bytes,
        adapter_input_saves,
    );

    MemBreakdown {
        base_weights,
        adapter_params,
        adapter_grads,
        optimizer,
        activations,
        transient,
        overhead: FRAMEWORK_OVERHEAD,
    }
}

/// Convenience: total GiB.
pub fn finetune_gib(spec: &ModelSpec, method: Method, precision: Precision, shape: TrainShape) -> f64 {
    finetune_memory(spec, method, precision, shape).total_gib()
}

/// Scenario-aware finetuning memory: every adapter-count-derived term
/// (params, grads, optimizer state) is re-priced through
/// [`crate::peft::counting::count_scenario`] — the same targeting
/// resolution and block/`r`/`block_share` shapes `Manifest::builtin`
/// uses — so the memory model and the runtime bundle agree on what is
/// trainable under any scenario. Activation terms are unchanged: the
/// forward still runs every linear; non-targeted ones just carry no
/// adapter state.
pub fn finetune_memory_scenario(
    spec: &ModelSpec,
    method: Method,
    precision: Precision,
    shape: TrainShape,
    sc: &crate::scenario::ScenarioCfg,
) -> Result<MemBreakdown> {
    let method = method.with_scenario(sc);
    let mut m = finetune_memory(spec, method, precision, shape);
    let k = method.kind();
    let n = crate::peft::counting::count_scenario(spec, k.adapter, &k.dims, sc)? as f64;
    m.adapter_params = n * 4.0;
    m.adapter_grads = n * 4.0;
    m.optimizer = optimizer_shard_bytes(n, shape.ranks);
    Ok(m)
}

/// Per-rank Adam-moment residency under ZeRO-1 sharding: two f32
/// moments over the *largest* shard (rank 0's, `ceil(n/ranks)`
/// elements — the same `shard_range` chunking the trainer executes).
/// `ranks == 1` reduces to the classic replicated `8n` bytes.
pub fn optimizer_shard_bytes(n_adapter: f64, ranks: usize) -> f64 {
    8.0 * (n_adapter / ranks.max(1) as f64).ceil()
}

/// KV residency of the serving path (the analytic mirror of
/// [`crate::serve::KvMode`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvPricing {
    /// One contiguous seq-length cache per batch slot, paid whether the
    /// slot is live or not — the worst case the contiguous scheduler
    /// always reserves.
    Contiguous,
    /// Block-pool slab: only blocks actually materialized are paid
    /// (`KvPoolStats::slab_blocks` is the measured counterpart).
    Paged { block_tokens: usize, blocks: usize },
}

/// Multi-tenant serving-shape knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeShape {
    pub max_batch: usize,
    pub seq: usize,
    /// KV element bytes (bf16 inference default).
    pub kv_bytes: f64,
    /// Decoders simultaneously resident under the adapter pager's cap —
    /// attached-but-evicted tenants cost only their (negligible on GPU)
    /// host-side trainables.
    pub resident_adapters: usize,
    /// Merged-artifact residents (`repro merge` + `serve --artifacts`):
    /// each carries a *private* base copy instead of adapter weights on
    /// the shared base — zero per-token adapter work, paid for in
    /// residency. This is the merged-vs-live deployment trade-off.
    pub merged_residents: usize,
    pub kv: KvPricing,
}

impl Default for ServeShape {
    fn default() -> Self {
        ServeShape {
            max_batch: 8,
            seq: 2048,
            kv_bytes: 2.0,
            resident_adapters: 1,
            merged_residents: 0,
            kv: KvPricing::Contiguous,
        }
    }
}

/// Byte breakdown of one serving configuration: no gradients, no
/// optimizer state, no activation tape — the residency is the frozen
/// base + resident adapter weights + KV.
#[derive(Clone, Copy, Debug)]
pub struct ServeBreakdown {
    pub base_weights: f64,
    /// Resolved weights of the resident adapters (evicted tenants pay
    /// nothing here).
    pub adapters: f64,
    /// Private base copies of merged-artifact residents, each at the
    /// shared base's inference precision.
    pub merged_bases: f64,
    pub kv: f64,
    pub overhead: f64,
}

impl ServeBreakdown {
    pub fn total(&self) -> f64 {
        self.base_weights + self.adapters + self.merged_bases + self.kv + self.overhead
    }

    pub fn total_gib(&self) -> f64 {
        self.total() / (1024.0 * 1024.0 * 1024.0)
    }
}

/// Estimate multi-tenant serving memory for (model, method, precision,
/// shape). The base is priced at inference residency (fused kernels
/// read the packs; no dequantized copy), each resident adapter at its
/// bf16 resolved weights, and KV per [`KvPricing`] — the term paged
/// serving turns from `max_batch * seq` worst case into slab occupancy.
pub fn serving_memory(
    spec: &ModelSpec,
    method: Method,
    precision: Precision,
    shape: ServeShape,
) -> ServeBreakdown {
    let other_params = (spec.total_params() - spec.linear_params()) as f64;
    let base_weights =
        spec.linear_params() as f64 * precision.bytes_per_param() + other_params * 2.0;
    let n_adapter = count(spec, method.kind()) as f64;
    let adapters = shape.resident_adapters as f64 * n_adapter * 2.0;
    // A merged artifact has no adapter weights at all — its cost is a
    // whole private base at the same inference precision.
    let merged_bases = shape.merged_residents as f64 * base_weights;
    let kv_row = spec.n_layers as f64 * 2.0 * spec.d_model as f64 * shape.kv_bytes;
    let kv = match shape.kv {
        KvPricing::Contiguous => (shape.max_batch * shape.seq) as f64 * kv_row,
        KvPricing::Paged { block_tokens, blocks } => (blocks * block_tokens) as f64 * kv_row,
    };
    ServeBreakdown {
        base_weights,
        adapters,
        merged_bases,
        kv,
        overhead: FRAMEWORK_OVERHEAD,
    }
}

/// Convenience: serving total GiB.
pub fn serving_gib(spec: &ModelSpec, method: Method, precision: Precision, shape: ServeShape) -> f64 {
    serving_memory(spec, method, precision, shape).total_gib()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelspec::ModelSpec;

    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

    fn shape_7b() -> TrainShape {
        TrainShape {
            batch: 1,
            seq: 2048,
            act_bytes: 2.0,
            checkpoint: CheckpointPolicy::EveryK(1),
            residency: BaseResidency::Packed,
            ranks: 1,
        }
    }

    fn qwen(size: &str) -> ModelSpec {
        ModelSpec::qwen25(size).unwrap()
    }

    #[test]
    fn fig1_oft_vs_oftv2_memory_gap() {
        // Fig. 1: OFT ~3x the memory of OFTv2 on Qwen2.5-7B (H100 80GB:
        // OFT barely fits, OFTv2 comfortable).
        let spec = qwen("7b");
        let oft = finetune_gib(&spec, Method::oft_weight_centric(32), Precision::Bf16, shape_7b());
        let oftv2 = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Bf16, shape_7b());
        let ratio = oft / oftv2;
        assert!(ratio > 2.0 && ratio < 4.5, "ratio {ratio} (oft {oft} GiB, v2 {oftv2} GiB)");
        // OFT must stress an 80GB H100; OFTv2 must not.
        assert!(oft > 40.0, "{oft}");
        assert!(oftv2 < 30.0, "{oftv2}");
    }

    #[test]
    fn fig4a_oftv2_matches_lora_memory() {
        // Fig. 4a: OFTv2 within a few percent of LoRA across scales.
        for size in ["0.5b", "1.5b", "7b", "32b"] {
            let spec = ModelSpec::qwen25(size).unwrap();
            let lora = finetune_gib(&spec, Method::lora(16), Precision::Bf16, shape_7b());
            let v2 = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Bf16, shape_7b());
            let rel = (v2 - lora).abs() / lora;
            assert!(rel < 0.10, "{size}: lora {lora} v2 {v2} rel {rel}");
        }
    }

    #[test]
    fn fig4b_quantization_shrinks_memory() {
        // NF4 must cut total memory vs BF16 markedly for big models.
        let spec = qwen("32b");
        let bf = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Bf16, shape_7b());
        let nf = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Nf4, shape_7b());
        assert!(nf < 0.5 * bf, "bf16 {bf} nf4 {nf}");
        // QOFT ~ QLoRA under NF4
        let ql = finetune_gib(&spec, Method::lora(16), Precision::Nf4, shape_7b());
        assert!((nf - ql).abs() / ql < 0.10, "qlora {ql} qoft {nf}");
    }

    #[test]
    fn memory_monotonic_in_model_size() {
        let shape = shape_7b();
        let mut prev = 0.0;
        for size in ["0.5b", "1.5b", "3b", "7b", "14b", "32b", "72b"] {
            let spec = ModelSpec::qwen25(size).unwrap();
            let m = finetune_gib(&spec, Method::lora(16), Precision::Nf4, shape);
            assert!(m > prev, "{size}: {m} <= {prev}");
            prev = m;
        }
    }

    #[test]
    fn qwen72b_nf4_fits_h100_but_bf16_does_not() {
        // The practical motivation for QOFT: 72B needs quantization.
        let spec = qwen("72b");
        let bf = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Bf16, shape_7b());
        let nf = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Nf4, shape_7b());
        assert!(bf > 94.0, "{bf}");
        assert!(nf < 94.0, "{nf}");
    }

    #[test]
    fn table11_sd35_shape() {
        // Table 11: LoRA ~= OFTv2 and QLoRA ~= QOFT; quantized < full.
        let spec = ModelSpec::sd35("large").unwrap();
        let shape = TrainShape {
            batch: 2,
            seq: 4096,
            act_bytes: 2.0,
            checkpoint: CheckpointPolicy::None,
            residency: BaseResidency::Packed,
            ranks: 1,
        };
        let lora = finetune_gib(&spec, Method::lora(16), Precision::Bf16, shape);
        let v2 = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Bf16, shape);
        let ql = finetune_gib(&spec, Method::lora(16), Precision::Nf4, shape);
        let qo = finetune_gib(&spec, Method::oft_input_centric(32), Precision::Nf4, shape);
        assert!((v2 - lora).abs() / lora < 0.10);
        assert!((qo - ql).abs() / ql < 0.10);
        assert!(qo < lora);
    }

    #[test]
    fn checkpoint_policy_trades_activation_memory() {
        // Any checkpoint policy must beat the full-tape baseline on
        // activation memory at 7B scale, and the boundary count must
        // shrink as k grows (the segment-live term grows instead —
        // that's the trade-off curve fig1_time_memory sweeps).
        let spec = qwen("7b");
        let mem_at = |checkpoint: CheckpointPolicy| {
            let shape = TrainShape { checkpoint, ..shape_7b() };
            finetune_memory(&spec, Method::oft_input_centric(32), Precision::Bf16, shape)
                .activations
        };
        let full = mem_at(CheckpointPolicy::None);
        for k in [1usize, 2, 4] {
            let ck = mem_at(CheckpointPolicy::EveryK(k));
            assert!(ck < full, "every-{k}: {ck} >= full-tape {full}");
        }
        // every-1 keeps strictly more boundaries than every-4 keeps
        // boundaries+window at this depth (l = 28): the curve is not
        // flat in k.
        assert!(mem_at(CheckpointPolicy::EveryK(2)) < mem_at(CheckpointPolicy::EveryK(1)));
    }

    #[test]
    fn packed_residency_prices_the_fused_kernels() {
        // The fused-kernel engine holds only the packs; a
        // dequantize-at-assembly engine holds the packs *plus* a full
        // f32 copy of every quantized linear. At 7B/NF4 that copy
        // dwarfs the packed bytes (~8.7x on base weights) and the
        // totals must differ by exactly linear_params * 4 bytes.
        let spec = qwen("7b");
        let packed = finetune_memory(
            &spec,
            Method::oft_input_centric(32),
            Precision::Nf4,
            shape_7b(),
        );
        let dequant = finetune_memory(
            &spec,
            Method::oft_input_centric(32),
            Precision::Nf4,
            TrainShape { residency: BaseResidency::DequantF32, ..shape_7b() },
        );
        let extra = dequant.base_weights - packed.base_weights;
        let want = spec.linear_params() as f64 * 4.0;
        assert!((extra - want).abs() < 1.0, "extra {extra} want {want}");
        assert!(dequant.base_weights / packed.base_weights > 3.0);
        assert!((dequant.total() - packed.total() - want).abs() < 1.0);
        // BF16 has no packs: residency is a no-op there.
        let bf_p = finetune_gib(&spec, Method::lora(16), Precision::Bf16, shape_7b());
        let bf_d = finetune_gib(
            &spec,
            Method::lora(16),
            Precision::Bf16,
            TrainShape { residency: BaseResidency::DequantF32, ..shape_7b() },
        );
        assert_eq!(bf_p, bf_d);
    }

    #[test]
    fn paged_kv_undercuts_contiguous_at_partial_occupancy() {
        // The block pool only pays for materialized blocks; the
        // contiguous path pays max_batch full sequences up front. At 25%
        // occupancy (typical: most sequences finish at EOS well short of
        // seq_len) the KV term shrinks 4x, and at worst-case occupancy
        // the two layouts price identically.
        let spec = qwen("7b");
        let m = Method::oft_input_centric(32);
        let base = ServeShape { max_batch: 8, seq: 2048, ..ServeShape::default() };
        let contig = serving_memory(&spec, m, Precision::Nf4, base);
        let bt = 16usize;
        let worst_blocks = 8 * 2048usize.div_ceil(bt);
        let paged_full = serving_memory(
            &spec,
            m,
            Precision::Nf4,
            ServeShape { kv: KvPricing::Paged { block_tokens: bt, blocks: worst_blocks }, ..base },
        );
        let paged_quarter = serving_memory(
            &spec,
            m,
            Precision::Nf4,
            ServeShape {
                kv: KvPricing::Paged { block_tokens: bt, blocks: worst_blocks / 4 },
                ..base
            },
        );
        assert!((paged_full.kv - contig.kv).abs() < 1.0, "worst case must match contiguous");
        assert!(
            (paged_quarter.kv - contig.kv / 4.0).abs() < 1.0,
            "paged {} vs contiguous/4 {}",
            paged_quarter.kv,
            contig.kv / 4.0
        );
        assert!(paged_quarter.total() < contig.total());
        // KV is a real term at this shape: batch 8 x 2048 bf16 KV on 7B.
        assert!(contig.kv / GIB > 1.0, "{}", contig.kv / GIB);
    }

    #[test]
    fn serving_is_inference_priced() {
        // Serving drops every training term (grads, optimizer, tape):
        // the non-KV residency is just base + resident adapters +
        // overhead, and 100 resident OFTv2 tenants still cost less than
        // the one base they share — the multi-tenant economics the
        // server exists for.
        let spec = qwen("7b");
        let m = Method::oft_input_centric(32);
        let tune = finetune_memory(&spec, m, Precision::Nf4, TrainShape::default());
        let serve1 = serving_memory(&spec, m, Precision::Nf4, ServeShape::default());
        assert!(
            serve1.total() - serve1.kv
                < tune.total() - tune.activations - tune.transient,
            "serving residency minus KV must undercut finetuning minus tape"
        );
        let serve100 = serving_memory(
            &spec,
            m,
            Precision::Nf4,
            ServeShape { resident_adapters: 100, ..ServeShape::default() },
        );
        assert!(serve100.adapters > serve1.adapters * 99.0);
        assert!(serve100.adapters < serve100.base_weights);
        assert!((serve100.total() - serve100.base_weights - serve100.adapters
            - serve100.merged_bases - serve100.kv - serve100.overhead)
            .abs()
            < 1.0);
    }

    #[test]
    fn merged_residents_price_full_base_copies() {
        // A merged artifact trades per-token adapter work for residency:
        // each one costs a whole private base, so one merged resident
        // outweighs even 100 live OFTv2 tenants on the shared base.
        let spec = qwen("7b");
        let m = Method::oft_input_centric(32);
        let live = serving_memory(&spec, m, Precision::Nf4, ServeShape::default());
        assert_eq!(live.merged_bases, 0.0, "default shape has no merged residents");
        let merged2 = serving_memory(
            &spec,
            m,
            Precision::Nf4,
            ServeShape { merged_residents: 2, ..ServeShape::default() },
        );
        assert!(
            (merged2.merged_bases - 2.0 * merged2.base_weights).abs() < 1.0,
            "each merged resident is one base copy"
        );
        assert_eq!(merged2.total() - merged2.merged_bases, live.total());
        let live100 = serving_memory(
            &spec,
            m,
            Precision::Nf4,
            ServeShape { resident_adapters: 100, ..ServeShape::default() },
        );
        let merged1 = serving_memory(
            &spec,
            m,
            Precision::Nf4,
            ServeShape { merged_residents: 1, ..ServeShape::default() },
        );
        assert!(
            merged1.merged_bases > live100.adapters,
            "one merged base ({}) must outweigh 100 live adapters ({})",
            merged1.merged_bases,
            live100.adapters
        );
    }

    #[test]
    fn zero1_sharding_scales_optimizer_state_down() {
        // Only the optimizer term shards; params/grads/activations are
        // replicated — exactly the trainer's ZeRO-1 contract. The
        // thresholds mirror the rank_scaling bench acceptance bars.
        let spec = qwen("7b");
        let m = Method::oft_input_centric(32);
        let one = finetune_memory(&spec, m, Precision::Bf16, shape_7b());
        for ranks in [2usize, 4, 8, 64] {
            let sharded = finetune_memory(
                &spec,
                m,
                Precision::Bf16,
                TrainShape { ranks, ..shape_7b() },
            );
            // largest shard = ceil(n/ranks) elements, within one
            // 8-byte element of the even split
            let even = one.optimizer / ranks as f64;
            assert!(
                sharded.optimizer >= even - 1e-6 && sharded.optimizer <= even + 8.0,
                "ranks {ranks}: shard {} vs even split {even}",
                sharded.optimizer
            );
            assert_eq!(sharded.adapter_params, one.adapter_params);
            assert_eq!(sharded.adapter_grads, one.adapter_grads);
            assert_eq!(sharded.activations, one.activations);
        }
        let two = optimizer_shard_bytes(1000.0, 2);
        let four = optimizer_shard_bytes(1000.0, 4);
        let full = optimizer_shard_bytes(1000.0, 1);
        assert_eq!(full, 8000.0);
        assert!(two <= 0.6 * full, "{two}");
        assert!(four <= 0.35 * full, "{four}");
        // odd splits round up to the largest shard
        assert_eq!(optimizer_shard_bytes(5.0, 2), 8.0 * 3.0);
    }

    #[test]
    fn breakdown_sums() {
        let spec = qwen("1.5b");
        let b = finetune_memory(&spec, Method::lora(16), Precision::Bf16, shape_7b());
        let total = b.base_weights + b.adapter_params + b.adapter_grads + b.optimizer
            + b.activations + b.transient + b.overhead;
        assert!((b.total() - total).abs() < 1.0);
        assert!(b.base_weights / GIB > 2.0);
    }
}
