//! Architecture specifications of the real foundation models the paper
//! finetunes, used for exact parameter counting (Tables 3-5) and the
//! analytic GPU-memory model (Figs. 1, 4; Table 11).
//!
//! Numbers come from the public HF configs: hidden sizes, layer counts,
//! FFN widths, GQA head groups, vocabularies.

use anyhow::{bail, Result};

/// One adapted linear layer (a weight matrix PEFT attaches to).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Linear {
    pub label: &'static str,
    pub din: usize,
    pub dout: usize,
}

/// A transformer-family model description.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    /// Linears adapted by PEFT, per transformer block.
    pub linears_per_layer: Vec<Linear>,
    /// Embedding / head parameters (input + output unless tied).
    pub embed_params: u64,
    /// Norms, biases, and anything else not in the big matrices.
    pub extra_params: u64,
    /// Default context length used by the memory model.
    pub default_seq: usize,
}

impl ModelSpec {
    /// All adapted linears across layers.
    pub fn adapted_linears(&self) -> impl Iterator<Item = Linear> + '_ {
        self.linears_per_layer
            .iter()
            .copied()
            .cycle()
            .take(self.linears_per_layer.len() * self.n_layers)
    }

    /// Parameters held in the big (adaptable) weight matrices.
    pub fn linear_params(&self) -> u64 {
        self.adapted_linears()
            .map(|l| (l.din * l.dout) as u64)
            .sum()
    }

    /// Total parameter count.
    pub fn total_params(&self) -> u64 {
        self.linear_params() + self.embed_params + self.extra_params
    }

    /// A spec mirroring a runtime bundle's architecture: the six
    /// adapted linears per layer of the builtin presets, labeled with
    /// the same `attn.wq` / `mlp.up` suffixes the manifest's linear
    /// names end with — so scenario targeting regexes resolve
    /// identically against both (see `peft::counting::count_scenario`).
    pub fn from_dims(name: &str, dims: &crate::coordinator::manifest::ModelDims) -> ModelSpec {
        let (d, f) = (dims.d_model, dims.d_ff);
        ModelSpec {
            name: name.into(),
            d_model: d,
            n_layers: dims.n_layers,
            n_heads: dims.n_heads,
            vocab: dims.vocab,
            linears_per_layer: vec![
                Linear { label: "attn.wq", din: d, dout: d },
                Linear { label: "attn.wk", din: d, dout: d },
                Linear { label: "attn.wv", din: d, dout: d },
                Linear { label: "attn.wo", din: d, dout: d },
                Linear { label: "mlp.up", din: d, dout: f },
                Linear { label: "mlp.down", din: f, dout: d },
            ],
            embed_params: ((dims.vocab + dims.seq_len + dims.vocab) * d) as u64,
            extra_params: ((2 * dims.n_layers + 1) * d) as u64,
            default_seq: dims.seq_len,
        }
    }

    // -- concrete models -----------------------------------------------

    /// Llama-2 7B / 13B (MHA, SwiGLU; q,k,v,o,gate,up,down adapted).
    fn llama2(name: &str, d: usize, ffn: usize, layers: usize, heads: usize) -> ModelSpec {
        let vocab = 32_000;
        ModelSpec {
            name: name.into(),
            d_model: d,
            n_layers: layers,
            n_heads: heads,
            vocab,
            linears_per_layer: vec![
                Linear { label: "q_proj", din: d, dout: d },
                Linear { label: "k_proj", din: d, dout: d },
                Linear { label: "v_proj", din: d, dout: d },
                Linear { label: "o_proj", din: d, dout: d },
                Linear { label: "gate_proj", din: d, dout: ffn },
                Linear { label: "up_proj", din: d, dout: ffn },
                Linear { label: "down_proj", din: ffn, dout: d },
            ],
            embed_params: 2 * (vocab * d) as u64, // untied embed + lm_head
            extra_params: ((2 * layers + 1) * d) as u64, // RMSNorm gains
            default_seq: 4096,
        }
    }

    pub fn llama2_7b() -> ModelSpec {
        Self::llama2("Llama-2-7B", 4096, 11008, 32, 32)
    }

    pub fn llama2_13b() -> ModelSpec {
        Self::llama2("Llama-2-13B", 5120, 13824, 40, 40)
    }

    /// Qwen2.5 family (GQA: k/v project to n_kv*head_dim; SwiGLU).
    /// `size` in {"0.5b","1.5b","3b","7b","14b","32b","72b"}; an
    /// unknown size is an error listing the valid spellings (matching
    /// the `Method`/`QuantKind` parse-error style), not a panic.
    pub fn qwen25(size: &str) -> Result<ModelSpec> {
        // (d, ffn, layers, heads, kv_heads, tied_embeddings)
        let (d, ffn, layers, heads, kv, tied) = match size {
            "0.5b" => (896, 4864, 24, 14, 2, true),
            "1.5b" => (1536, 8960, 28, 12, 2, true),
            "3b" => (2048, 11008, 36, 16, 2, true),
            "7b" => (3584, 18944, 28, 28, 4, false),
            "14b" => (5120, 13824, 48, 40, 8, false),
            "32b" => (5120, 27648, 64, 40, 8, false),
            "72b" => (8192, 29568, 80, 64, 8, false),
            other => bail!(
                "unknown qwen2.5 size '{other}'; valid sizes: 0.5b, 1.5b, 3b, 7b, 14b, 32b, 72b"
            ),
        };
        // head_dim = d/heads (64 for 0.5B, 128 for the rest)
        let head_dim = d / heads;
        let kv_dim = kv * head_dim;
        let vocab = 151_936;
        let embeds = if tied { vocab * d } else { 2 * vocab * d };
        Ok(ModelSpec {
            name: format!("Qwen2.5-{}", size.to_uppercase()),
            d_model: d,
            n_layers: layers,
            n_heads: heads,
            vocab,
            linears_per_layer: vec![
                Linear { label: "q_proj", din: d, dout: heads * head_dim },
                Linear { label: "k_proj", din: d, dout: kv_dim },
                Linear { label: "v_proj", din: d, dout: kv_dim },
                Linear { label: "o_proj", din: heads * head_dim, dout: d },
                Linear { label: "gate_proj", din: d, dout: ffn },
                Linear { label: "up_proj", din: d, dout: ffn },
                Linear { label: "down_proj", din: ffn, dout: d },
            ],
            embed_params: embeds as u64,
            // norms + qkv biases (Qwen uses attention biases)
            extra_params: (layers * (2 * d + heads * head_dim + 2 * kv_dim) + d) as u64,
            default_seq: 16_384, // the paper's OpenR1 context window
        })
    }

    /// BART-large encoder-decoder (Table 3): 12 enc + 12 dec layers,
    /// d=1024, ffn=4096. PEFT adapts q,k,v,o of every attention module
    /// (enc self, dec self, dec cross) plus both FFN matrices.
    pub fn bart_large() -> ModelSpec {
        let d = 1024;
        let ffn = 4096;
        // Model as 12 "macro layers", each holding one encoder layer
        // (1 attn + ffn) and one decoder layer (2 attn + ffn).
        let attn = |label| Linear { label, din: d, dout: d };
        let mut lin = Vec::new();
        for _ in 0..3 {
            // enc self, dec self, dec cross
            lin.push(attn("q_proj"));
            lin.push(attn("k_proj"));
            lin.push(attn("v_proj"));
            lin.push(attn("out_proj"));
        }
        for _ in 0..2 {
            // enc ffn, dec ffn
            lin.push(Linear { label: "fc1", din: d, dout: ffn });
            lin.push(Linear { label: "fc2", din: ffn, dout: d });
        }
        let vocab = 50_265;
        ModelSpec {
            name: "BART-large".into(),
            d_model: d,
            n_layers: 12,
            n_heads: 16,
            vocab,
            linears_per_layer: lin,
            embed_params: (vocab * d + 2 * 1026 * d) as u64, // tied + learned pos x2
            extra_params: (12 * 2 * (2 * d) + 12 * 3 * (2 * d)) as u64,
            default_seq: 1024,
        }
    }

    /// Stable Diffusion 3.5 MMDiT approximations (Table 11 memory).
    /// MMDiT totals calibrated to the published sizes (Medium 2.5B,
    /// Large 8.1B); Dreambooth additionally keeps the frozen text
    /// encoders (T5-XXL 4.76B + CLIP-G 0.69B + CLIP-L 0.12B) and the
    /// VAE on-device, so those ride along in `extra_params`.
    pub fn sd35(size: &str) -> Result<ModelSpec> {
        let (d, blocks, mmdit): (usize, usize, u64) = match size {
            "medium" => (1536, 24, 2_500_000_000),
            "large" => (2432, 38, 8_100_000_000),
            other => bail!("unknown sd3.5 size '{other}'; valid sizes: medium, large"),
        };
        const ENCODERS_AND_VAE: u64 = 5_650_000_000;
        let total = mmdit + ENCODERS_AND_VAE;
        // Dual-stream MMDiT block: per stream qkv, proj, mlp up (4x), down.
        let mut lin = Vec::new();
        for _ in 0..2 {
            lin.push(Linear { label: "qkv", din: d, dout: 3 * d });
            lin.push(Linear { label: "proj", din: d, dout: d });
            lin.push(Linear { label: "mlp_up", din: d, dout: 4 * d });
            lin.push(Linear { label: "mlp_down", din: 4 * d, dout: d });
        }
        let linear_total: u64 = lin
            .iter()
            .map(|l| (l.din * l.dout) as u64)
            .sum::<u64>()
            * blocks as u64;
        Ok(ModelSpec {
            name: format!("SD3.5-{}", size),
            d_model: d,
            n_layers: blocks,
            n_heads: d / 64,
            vocab: 0,
            linears_per_layer: lin,
            embed_params: 0,
            // everything else (text encoders kept frozen on-device, VAE,
            // embedders, modulation) folded here to match the total
            extra_params: total.saturating_sub(linear_total),
            default_seq: 4096, // latent + text tokens
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn billions(x: u64) -> f64 {
        x as f64 / 1e9
    }

    #[test]
    fn llama2_totals_match_published() {
        assert!((billions(ModelSpec::llama2_7b().total_params()) - 6.74).abs() < 0.05);
        assert!((billions(ModelSpec::llama2_13b().total_params()) - 13.0).abs() < 0.1);
    }

    #[test]
    fn qwen25_totals_match_published() {
        // HF model cards: 0.49B, 1.54B, 3.09B, 7.62B, 14.7B, 32.8B, 72.7B
        let expect = [
            ("0.5b", 0.49),
            ("1.5b", 1.54),
            ("3b", 3.09),
            ("7b", 7.62),
            ("14b", 14.7),
            ("32b", 32.8),
            ("72b", 72.7),
        ];
        for (size, want) in expect {
            let got = billions(ModelSpec::qwen25(size).unwrap().total_params());
            assert!(
                (got - want).abs() / want < 0.03,
                "qwen2.5-{size}: got {got}B want {want}B"
            );
        }
    }

    #[test]
    fn bart_large_total() {
        // published ~406M
        let got = ModelSpec::bart_large().total_params() as f64 / 1e6;
        assert!((got - 406.0).abs() < 20.0, "{got}");
    }

    #[test]
    fn sd35_totals_pinned() {
        // MMDiT size + frozen encoders/VAE (5.65B) kept on-device
        assert_eq!(
            ModelSpec::sd35("large").unwrap().total_params(),
            8_100_000_000 + 5_650_000_000
        );
        assert_eq!(
            ModelSpec::sd35("medium").unwrap().total_params(),
            2_500_000_000 + 5_650_000_000
        );
    }

    #[test]
    fn unknown_sizes_error_listing_valid_spellings() {
        // The PR 3 parse-error convention: teach the valid spellings
        // instead of panicking.
        let err = match ModelSpec::qwen25("9000b") {
            Err(e) => format!("{e:#}"),
            Ok(m) => panic!("'9000b' parsed as {}", m.name),
        };
        for size in ["0.5b", "1.5b", "3b", "7b", "14b", "32b", "72b"] {
            assert!(err.contains(size), "qwen error should list '{size}': {err}");
        }
        let err = match ModelSpec::sd35("xl") {
            Err(e) => format!("{e:#}"),
            Ok(m) => panic!("'xl' parsed as {}", m.name),
        };
        for size in ["medium", "large"] {
            assert!(err.contains(size), "sd3.5 error should list '{size}': {err}");
        }
    }

    #[test]
    fn adapted_linears_count() {
        let q = ModelSpec::qwen25("7b").unwrap();
        assert_eq!(q.adapted_linears().count(), 7 * 28);
        let b = ModelSpec::bart_large();
        assert_eq!(b.adapted_linears().count(), 16 * 12);
    }
}
