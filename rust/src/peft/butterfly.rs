//! Butterfly-factorized orthogonal finetuning (BOFT, Liu et al. 2024) —
//! the structured-sparsity extension §5 of the OFTv2 paper calls out:
//! "to further enhance the scalability of OFT, more structured sparsity
//! should be exploited, e.g. butterfly factorization".
//!
//! Instead of one block-diagonal orthogonal matrix, BOFT composes m
//! butterfly *factors* B_1 … B_m. Factor i pairs coordinates at stride
//! s_i = b/2 · 2^(i-1) into independent 2×2-like blocks of width b:
//! each factor is block-diagonal **after** a perfect-shuffle permutation,
//! so the product reaches global mixing with only m·(d/b)·b(b−1)/2
//! parameters — denser connectivity than one Diag(R) at the same b.
//!
//! This module is the host-side oracle + analysis implementation (the
//! ablation bench compares parameter efficiency and mixing reach against
//! plain block-diagonal OFT); the L2 graphs keep the paper's primary
//! block-diagonal form.

use anyhow::{ensure, Result};

use crate::peft::oft::{cayley_neumann, packed_dim};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One butterfly factor: a block-diagonal rotation applied under a
/// stride permutation.
#[derive(Clone, Debug)]
pub struct ButterflyFactor {
    /// Packed skew parameters per block: (d/b) × packed_dim(b).
    pub packed: Vec<Vec<f32>>,
    /// Coordinate stride of this factor (1 = adjacent grouping).
    pub stride: usize,
    pub b: usize,
}

/// A full butterfly-orthogonal adapter: the product B_m · … · B_1.
#[derive(Clone, Debug)]
pub struct ButterflyAdapter {
    pub d: usize,
    pub b: usize,
    pub neumann_k: usize,
    pub factors: Vec<ButterflyFactor>,
}

/// The coordinate permutation for a factor of `stride`: position j maps
/// to the block-grouped ordering that gathers {j, j+stride, j+2·stride,
/// …} into contiguous b-wide blocks.
pub fn stride_permutation(d: usize, b: usize, stride: usize) -> Vec<usize> {
    assert_eq!(d % (b * stride), 0, "stride {stride} × b {b} must divide d {d}");
    let mut perm = Vec::with_capacity(d);
    // groups of b*stride coordinates; within each, interleave by stride
    let span = b * stride;
    for g in 0..d / span {
        for off in 0..stride {
            for k in 0..b {
                perm.push(g * span + off + k * stride);
            }
        }
    }
    perm
}

/// Reorder columns: `out[:, new] = x[:, perm[new]]`. The gradient of
/// `permute_cols(·, perm)` is `permute_cols(·, invert_perm(perm))`.
pub fn permute_cols(x: &Tensor, perm: &[usize]) -> Tensor {
    let (m, d) = (x.shape[0], x.shape[1]);
    assert_eq!(perm.len(), d);
    let mut out = vec![0.0f32; m * d];
    for r in 0..m {
        for (new, &old) in perm.iter().enumerate() {
            out[r * d + new] = x.data[r * d + old];
        }
    }
    Tensor::from_vec(&[m, d], out)
}

/// The inverse permutation: `invert_perm(p)[p[i]] == i`.
pub fn invert_perm(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (new, &old) in perm.iter().enumerate() {
        inv[old] = new;
    }
    inv
}

impl ButterflyAdapter {
    /// Identity-initialized adapter with `m` factors (strides b/2·2^i
    /// style doubling, clamped to d).
    pub fn identity(d: usize, b: usize, m: usize, neumann_k: usize) -> Result<ButterflyAdapter> {
        ensure!(d % b == 0, "b {b} must divide d {d}");
        ensure!(m >= 1);
        let nb = d / b;
        let mut factors = Vec::with_capacity(m);
        let mut stride = 1usize;
        for _ in 0..m {
            ensure!(
                d % (b * stride) == 0,
                "butterfly depth too large: stride {stride} × b {b} vs d {d}"
            );
            factors.push(ButterflyFactor {
                packed: vec![vec![0.0; packed_dim(b)]; nb],
                stride,
                b,
            });
            stride *= b; // next factor pairs coordinates one level up
        }
        Ok(ButterflyAdapter {
            d,
            b,
            neumann_k,
            factors,
        })
    }

    /// Random small-Q adapter.
    pub fn random(
        d: usize,
        b: usize,
        m: usize,
        neumann_k: usize,
        std: f32,
        rng: &mut Rng,
    ) -> Result<ButterflyAdapter> {
        let mut a = Self::identity(d, b, m, neumann_k)?;
        for f in &mut a.factors {
            for blk in &mut f.packed {
                *blk = rng.normal_vec(packed_dim(b), std);
            }
        }
        Ok(a)
    }

    /// Trainable parameters: m · (d/b) · b(b−1)/2.
    pub fn num_params(&self) -> usize {
        self.factors.len() * (self.d / self.b) * packed_dim(self.b)
    }

    /// Apply the adapter to rows of x: y = x · (B_1ᵀ … B_mᵀ) — i.e. each
    /// factor rotates under its stride permutation.
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        ensure!(x.rank() == 2 && x.shape[1] == self.d);
        let mut cur = x.clone();
        for f in &self.factors {
            let perm = stride_permutation(self.d, self.b, f.stride);
            let inv = invert_perm(&perm);
            let grouped = permute_cols(&cur, &perm);
            let blocks = f
                .packed
                .iter()
                .map(|p| cayley_neumann(p, self.b, self.neumann_k))
                .collect::<Result<Vec<_>>>()?;
            let rotated = crate::peft::oft::block_rotate(&grouped, &blocks)?;
            cur = permute_cols(&rotated, &inv);
        }
        Ok(cur)
    }

    /// Materialize the full d×d orthogonal matrix (analysis only).
    pub fn dense(&self) -> Result<Tensor> {
        self.forward(&Tensor::eye(self.d))
    }

    /// Mixing reach: after applying the adapter to a one-hot input, how
    /// many coordinates are touched? Block-diagonal OFT reaches b;
    /// butterfly reaches b^m (up to d).
    pub fn mixing_reach(&self) -> Result<usize> {
        let mut probe = Tensor::zeros(&[1, self.d]);
        probe.data[0] = 1.0;
        // use a generic (non-zero) adapter for reach analysis
        let mut rng = Rng::new(0xBF);
        let dense = Self::random(self.d, self.b, self.factors.len(), self.neumann_k, 0.1, &mut rng)?;
        let y = dense.forward(&probe)?;
        Ok(y.data.iter().filter(|v| v.abs() > 1e-9).count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::oft::orthogonality_error;
    use crate::testkit;

    #[test]
    fn stride_permutation_is_a_permutation() {
        testkit::check("stride perm bijective", 30, |g| {
            let b = *g.choose(&[2usize, 4, 8]);
            let levels = g.usize_in(1, 3);
            let stride = b.pow(levels as u32 - 1);
            let d = b * stride * (1 + g.usize_in(0, 3));
            let perm = stride_permutation(d, b, stride);
            let mut seen = vec![false; d];
            for &p in &perm {
                if seen[p] {
                    return Err(format!("duplicate index {p}"));
                }
                seen[p] = true;
            }
            Ok(())
        });
    }

    #[test]
    fn identity_adapter_is_noop() {
        let mut rng = Rng::new(1);
        let a = ButterflyAdapter::identity(16, 4, 2, 5).unwrap();
        let x = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let y = a.forward(&x).unwrap();
        assert!(y.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn product_is_orthogonal() {
        testkit::check("butterfly product orthogonal", 15, |g| {
            let b = *g.choose(&[2usize, 4]);
            let m = g.usize_in(1, 3);
            let d = b.pow(m as u32) * (1 + g.usize_in(0, 2));
            let mut rng = Rng::new(g.rng.next_u64());
            let a = ButterflyAdapter::random(d, b, m, 8, 0.05, &mut rng)
                .map_err(|e| e.to_string())?;
            let dense = a.dense().map_err(|e| e.to_string())?;
            let err = orthogonality_error(&dense);
            if err > 5e-3 {
                return Err(format!("orthogonality error {err} (d={d}, b={b}, m={m})"));
            }
            Ok(())
        });
    }

    #[test]
    fn mixing_reach_grows_with_depth() {
        // §5's point: butterfly composition reaches b^m coordinates from
        // one, vs b for plain block-diagonal OFT.
        let d = 64;
        let b = 4;
        let r1 = ButterflyAdapter::identity(d, b, 1, 5).unwrap().mixing_reach().unwrap();
        let r2 = ButterflyAdapter::identity(d, b, 2, 5).unwrap().mixing_reach().unwrap();
        let r3 = ButterflyAdapter::identity(d, b, 3, 5).unwrap().mixing_reach().unwrap();
        assert_eq!(r1, b);
        assert_eq!(r2, b * b);
        assert_eq!(r3, d.min(b * b * b));
        assert!(r1 < r2 && r2 < r3);
    }

    #[test]
    fn parameter_count_scales_with_factors() {
        let d = 64;
        let b = 8;
        let one = ButterflyAdapter::identity(d, b, 1, 5).unwrap();
        let two = ButterflyAdapter::identity(d, b, 2, 5).unwrap();
        assert_eq!(one.num_params(), (d / b) * packed_dim(b));
        assert_eq!(two.num_params(), 2 * one.num_params());
        // global mixing at d=64 needs m=2 (b^2 = 64): 2·8·28 = 448 params
        // vs a single dense 64-block: 64·63/2 = 2016 — the §5 saving.
        assert!(two.num_params() < packed_dim(d));
    }

    #[test]
    fn rejects_bad_geometry() {
        assert!(ButterflyAdapter::identity(15, 4, 1, 5).is_err());
        // depth 3 at b=4 needs 64 | d
        assert!(ButterflyAdapter::identity(32, 4, 3, 5).is_err());
    }

    #[test]
    fn forward_preserves_row_norms() {
        let mut rng = Rng::new(5);
        let a = ButterflyAdapter::random(16, 4, 2, 8, 0.05, &mut rng).unwrap();
        let x = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let y = a.forward(&x).unwrap();
        for r in 0..4 {
            let nx: f32 = x.data[r * 16..(r + 1) * 16].iter().map(|v| v * v).sum();
            let ny: f32 = y.data[r * 16..(r + 1) * 16].iter().map(|v| v * v).sum();
            assert!((nx.sqrt() - ny.sqrt()).abs() < 1e-2, "row {r}");
        }
    }
}
