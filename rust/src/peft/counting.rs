//! Trainable-parameter counting — reproduces the `# Params` columns of
//! Tables 3, 4 and 5 *exactly* from the real model architectures in
//! [`crate::modelspec`].
//!
//! Counting is derived from the adapter registry: every method's
//! [`crate::adapters::Adapter::linear_trainables`] declaration — the
//! same one that synthesizes runtime bundles — is summed over a
//! [`ModelSpec`]'s adapted linears, so the paper tables and the
//! executable bundles can never disagree about a method's parameter
//! story. [`MethodKind`] is the thin registry view the memory model
//! shares.

use crate::adapters::Adapter;
use crate::coordinator::manifest::ModelDims;
use crate::modelspec::ModelSpec;

/// A registry-backed (method, hyperparameter) view for counting and
/// memory analyses: the adapter plus an analysis [`ModelDims`] carrying
/// its rank/block hyperparameters.
#[derive(Clone, Copy)]
pub struct MethodKind {
    pub adapter: &'static dyn Adapter,
    pub dims: ModelDims,
}

impl MethodKind {
    /// LoRA / QLoRA with rank `r`: `r*(din + dout)` per adapted linear.
    pub fn lora(r: usize) -> MethodKind {
        MethodKind {
            adapter: &crate::adapters::lora::LORA,
            dims: ModelDims::analysis(r, 32),
        }
    }

    /// OFT / OFTv2 / QOFT with block size `b`: `(din/b) * b(b-1)/2` per
    /// adapted linear (packed skew-symmetric storage, §3.3).
    pub fn oft(b: usize) -> MethodKind {
        MethodKind {
            adapter: &crate::adapters::oft_v2::OFT_V2,
            dims: ModelDims::analysis(16, b),
        }
    }

    /// The weight-centric OFT baseline with block size `b` (same packed
    /// parameter count as the input-centric form; the memory model
    /// prices its merged-weight transient differently).
    pub fn oft_merged(b: usize) -> MethodKind {
        MethodKind {
            adapter: &crate::adapters::oft_merged::OFT_MERGED,
            dims: ModelDims::analysis(16, b),
        }
    }

    /// Any registered method by name, with explicit rank/block
    /// hyperparameters.
    pub fn by_name(name: &str, r: usize, b: usize) -> crate::Result<MethodKind> {
        Ok(MethodKind {
            adapter: crate::adapters::get(name)?,
            dims: ModelDims::analysis(r, b),
        })
    }
}

/// Trainable parameters of `adapter` over every adapted linear of
/// `spec`, from the adapter's own spec declaration. Base-training
/// methods count the full model. When a block size does not divide a
/// linear's input dimension the remainder columns are left unadapted
/// (matching the HF PEFT implementation's block truncation).
pub fn count_with(spec: &ModelSpec, adapter: &dyn Adapter, dims: &ModelDims) -> u64 {
    if adapter.trains_base() {
        return spec.total_params();
    }
    spec.adapted_linears()
        .map(|l| {
            adapter
                .linear_trainables("linear", l.din, l.dout, dims)
                .iter()
                .map(|s| s.numel() as u64)
                .sum::<u64>()
        })
        .sum()
}

/// LoRA trainable parameters over every adapted linear of `spec`.
pub fn count_lora(spec: &ModelSpec, r: usize) -> u64 {
    let k = MethodKind::lora(r);
    count_with(spec, k.adapter, &k.dims)
}

/// OFT trainable parameters (packed skew storage) over every adapted
/// linear of `spec`. Blocks sit on the *input* dimension.
pub fn count_oft(spec: &ModelSpec, b: usize) -> u64 {
    let k = MethodKind::oft(b);
    count_with(spec, k.adapter, &k.dims)
}

/// Count for a registry view.
pub fn count(spec: &ModelSpec, m: MethodKind) -> u64 {
    count_with(spec, m.adapter, &m.dims)
}

/// Scenario-aware trainable count: the same registry-declaration sum
/// as [`count_with`], but with the scenario's targeting regexes pruning
/// linears (matched against each linear's label, via the SAME
/// [`crate::scenario::ScenarioCfg::resolve_skipped`] resolution
/// `Manifest::builtin` uses) and its `r`/`block`/`block_share` knobs
/// flowing into the per-linear spec shapes. Analytic counts therefore
/// agree with the runtime bundle under any scenario.
pub fn count_scenario(
    spec: &ModelSpec,
    adapter: &dyn Adapter,
    dims: &ModelDims,
    sc: &crate::scenario::ScenarioCfg,
) -> crate::Result<u64> {
    if adapter.trains_base() {
        return Ok(spec.total_params());
    }
    let mut dims = *dims;
    dims.scenario = sc.dims();
    if sc.block > 0 {
        dims.block_b = sc.block;
    }
    let labels: Vec<String> = spec
        .adapted_linears()
        .map(|l| l.label.to_string())
        .collect();
    let skipped = sc.resolve_skipped(&labels)?;
    let mut total = 0u64;
    for l in spec.adapted_linears() {
        if skipped.iter().any(|s| s == l.label) {
            continue;
        }
        total += adapter
            .linear_trainables(l.label, l.din, l.dout, &dims)
            .iter()
            .map(|s| s.numel() as u64)
            .sum::<u64>();
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelspec::ModelSpec;

    fn mm(x: u64) -> f64 {
        x as f64 / 1e6
    }

    #[test]
    fn table4_llama2_param_counts() {
        // Paper Table 4: Llama-2 7B — LoRA r=16: 39.98M, OFTv2 b=32: 17.65M
        //                Llama-2 13B — LoRA r=16: 62.59M, OFTv2 b=32: 27.62M
        let l7 = ModelSpec::llama2_7b();
        assert!((mm(count_lora(&l7, 16)) - 39.98).abs() < 0.02, "{}", mm(count_lora(&l7, 16)));
        assert!((mm(count_oft(&l7, 32)) - 17.65).abs() < 0.02, "{}", mm(count_oft(&l7, 32)));
        let l13 = ModelSpec::llama2_13b();
        assert!((mm(count_lora(&l13, 16)) - 62.59).abs() < 0.02, "{}", mm(count_lora(&l13, 16)));
        assert!((mm(count_oft(&l13, 32)) - 27.62).abs() < 0.02, "{}", mm(count_oft(&l13, 32)));
    }

    #[test]
    fn table5_qwen25_param_counts() {
        // Paper Table 5: Qwen2.5-1.5B — QLoRA 18.46M / QOFT 7.89M;
        // 7B — 40.37M / 17.55M; 32B — 134.22M / 57.90M.
        let q15 = ModelSpec::qwen25("1.5b").unwrap();
        assert!((mm(count_lora(&q15, 16)) - 18.46).abs() < 0.02, "{}", mm(count_lora(&q15, 16)));
        assert!((mm(count_oft(&q15, 32)) - 7.89).abs() < 0.02, "{}", mm(count_oft(&q15, 32)));
        let q7 = ModelSpec::qwen25("7b").unwrap();
        assert!((mm(count_lora(&q7, 16)) - 40.37).abs() < 0.02, "{}", mm(count_lora(&q7, 16)));
        assert!((mm(count_oft(&q7, 32)) - 17.55).abs() < 0.02, "{}", mm(count_oft(&q7, 32)));
        let q32 = ModelSpec::qwen25("32b").unwrap();
        assert!((mm(count_lora(&q32, 16)) - 134.22).abs() < 0.05, "{}", mm(count_lora(&q32, 16)));
        assert!((mm(count_oft(&q32, 32)) - 57.90).abs() < 0.05, "{}", mm(count_oft(&q32, 32)));
    }

    #[test]
    fn table3_bart_param_counts() {
        // Paper Table 3 budgets: LoRA r=8/16/32 -> 4.33M / 8.65M / 17.30M
        //                        OFTv2 b=16/32/64 -> 2.03M / 4.19M / 8.52M
        let bart = ModelSpec::bart_large();
        assert!((mm(count_lora(&bart, 8)) - 4.33).abs() < 0.01, "{}", mm(count_lora(&bart, 8)));
        assert!((mm(count_lora(&bart, 16)) - 8.65).abs() < 0.01, "{}", mm(count_lora(&bart, 16)));
        assert!((mm(count_lora(&bart, 32)) - 17.30).abs() < 0.01, "{}", mm(count_lora(&bart, 32)));
        assert!((mm(count_oft(&bart, 16)) - 2.03).abs() < 0.01, "{}", mm(count_oft(&bart, 16)));
        assert!((mm(count_oft(&bart, 32)) - 4.19).abs() < 0.01, "{}", mm(count_oft(&bart, 32)));
        assert!((mm(count_oft(&bart, 64)) - 8.52).abs() < 0.01, "{}", mm(count_oft(&bart, 64)));
    }

    #[test]
    fn oft_uses_roughly_half_of_lora() {
        // The paper's "47-53% fewer trainable parameters" claim at b=2r.
        for spec in [ModelSpec::llama2_7b(), ModelSpec::qwen25("7b").unwrap()] {
            let ratio = count_oft(&spec, 32) as f64 / count_lora(&spec, 16) as f64;
            assert!(ratio > 0.40 && ratio < 0.60, "{ratio}");
        }
    }

    #[test]
    fn registry_view_counts_every_method() {
        // Any registered method counts through the same declaration the
        // runtime bundles are synthesized from.
        let spec = ModelSpec::llama2_7b();
        let lora = count(&spec, MethodKind::by_name("lora", 16, 32).unwrap());
        assert_eq!(lora, count_lora(&spec, 16));
        let boft = count(&spec, MethodKind::by_name("boft", 16, 32).unwrap());
        let oft = count_oft(&spec, 32);
        assert!(boft > oft, "butterfly factors add depth: {boft} vs {oft}");
        let hoft = count(&spec, MethodKind::by_name("hoft", 16, 32).unwrap());
        assert!(hoft > 0 && hoft < lora, "{hoft} vs lora {lora}");
        let full = count(&spec, MethodKind::by_name("full", 16, 32).unwrap());
        assert_eq!(full, spec.total_params());
        assert_eq!(count(&spec, MethodKind::by_name("none", 16, 32).unwrap()), 0);
    }
}
