//! Trainable-parameter counting — reproduces the `# Params` columns of
//! Tables 3, 4 and 5 *exactly* from the real model architectures in
//! [`crate::modelspec`].

use crate::modelspec::ModelSpec;

/// PEFT method kind for counting purposes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    /// LoRA / QLoRA with rank r: r*(din + dout) per adapted linear.
    Lora { r: usize },
    /// OFT / OFTv2 / QOFT with block size b: (din/b) * b(b-1)/2 per
    /// adapted linear (packed skew-symmetric storage, §3.3).
    Oft { b: usize },
}

/// LoRA trainable parameters over every adapted linear of `spec`.
pub fn count_lora(spec: &ModelSpec, r: usize) -> u64 {
    spec.adapted_linears()
        .map(|l| (r * (l.din + l.dout)) as u64)
        .sum()
}

/// OFT trainable parameters (packed skew storage) over every adapted
/// linear of `spec`. Blocks sit on the *input* dimension; when b does
/// not divide din the remainder columns are left unadapted (matching the
/// HF PEFT implementation's block truncation).
pub fn count_oft(spec: &ModelSpec, b: usize) -> u64 {
    let p = (b * (b - 1) / 2) as u64;
    spec.adapted_linears().map(|l| (l.din / b) as u64 * p).sum()
}

/// Count for either method.
pub fn count(spec: &ModelSpec, m: MethodKind) -> u64 {
    match m {
        MethodKind::Lora { r } => count_lora(spec, r),
        MethodKind::Oft { b } => count_oft(spec, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelspec::ModelSpec;

    fn mm(x: u64) -> f64 {
        x as f64 / 1e6
    }

    #[test]
    fn table4_llama2_param_counts() {
        // Paper Table 4: Llama-2 7B — LoRA r=16: 39.98M, OFTv2 b=32: 17.65M
        //                Llama-2 13B — LoRA r=16: 62.59M, OFTv2 b=32: 27.62M
        let l7 = ModelSpec::llama2_7b();
        assert!((mm(count_lora(&l7, 16)) - 39.98).abs() < 0.02, "{}", mm(count_lora(&l7, 16)));
        assert!((mm(count_oft(&l7, 32)) - 17.65).abs() < 0.02, "{}", mm(count_oft(&l7, 32)));
        let l13 = ModelSpec::llama2_13b();
        assert!((mm(count_lora(&l13, 16)) - 62.59).abs() < 0.02, "{}", mm(count_lora(&l13, 16)));
        assert!((mm(count_oft(&l13, 32)) - 27.62).abs() < 0.02, "{}", mm(count_oft(&l13, 32)));
    }

    #[test]
    fn table5_qwen25_param_counts() {
        // Paper Table 5: Qwen2.5-1.5B — QLoRA 18.46M / QOFT 7.89M;
        // 7B — 40.37M / 17.55M; 32B — 134.22M / 57.90M.
        let q15 = ModelSpec::qwen25("1.5b").unwrap();
        assert!((mm(count_lora(&q15, 16)) - 18.46).abs() < 0.02, "{}", mm(count_lora(&q15, 16)));
        assert!((mm(count_oft(&q15, 32)) - 7.89).abs() < 0.02, "{}", mm(count_oft(&q15, 32)));
        let q7 = ModelSpec::qwen25("7b").unwrap();
        assert!((mm(count_lora(&q7, 16)) - 40.37).abs() < 0.02, "{}", mm(count_lora(&q7, 16)));
        assert!((mm(count_oft(&q7, 32)) - 17.55).abs() < 0.02, "{}", mm(count_oft(&q7, 32)));
        let q32 = ModelSpec::qwen25("32b").unwrap();
        assert!((mm(count_lora(&q32, 16)) - 134.22).abs() < 0.05, "{}", mm(count_lora(&q32, 16)));
        assert!((mm(count_oft(&q32, 32)) - 57.90).abs() < 0.05, "{}", mm(count_oft(&q32, 32)));
    }

    #[test]
    fn table3_bart_param_counts() {
        // Paper Table 3 budgets: LoRA r=8/16/32 -> 4.33M / 8.65M / 17.30M
        //                        OFTv2 b=16/32/64 -> 2.03M / 4.19M / 8.52M
        let bart = ModelSpec::bart_large();
        assert!((mm(count_lora(&bart, 8)) - 4.33).abs() < 0.01, "{}", mm(count_lora(&bart, 8)));
        assert!((mm(count_lora(&bart, 16)) - 8.65).abs() < 0.01, "{}", mm(count_lora(&bart, 16)));
        assert!((mm(count_lora(&bart, 32)) - 17.30).abs() < 0.01, "{}", mm(count_lora(&bart, 32)));
        assert!((mm(count_oft(&bart, 16)) - 2.03).abs() < 0.01, "{}", mm(count_oft(&bart, 16)));
        assert!((mm(count_oft(&bart, 32)) - 4.19).abs() < 0.01, "{}", mm(count_oft(&bart, 32)));
        assert!((mm(count_oft(&bart, 64)) - 8.52).abs() < 0.01, "{}", mm(count_oft(&bart, 64)));
    }

    #[test]
    fn oft_uses_roughly_half_of_lora() {
        // The paper's "47-53% fewer trainable parameters" claim at b=2r.
        for spec in [ModelSpec::llama2_7b(), ModelSpec::qwen25("7b").unwrap()] {
            let ratio = count_oft(&spec, 32) as f64 / count_lora(&spec, 16) as f64;
            assert!(ratio > 0.40 && ratio < 0.60, "{ratio}");
        }
    }
}
