//! LoRA adapter math (the low-rank baseline the paper compares against).

use anyhow::Result;

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A LoRA adapter for one linear layer: W + (alpha/r) A B.
#[derive(Clone, Debug)]
pub struct LoraAdapter {
    pub a: Tensor, // (din, r)
    pub b: Tensor, // (r, dout)
    pub alpha: f32,
    pub r: usize,
}

impl LoraAdapter {
    /// Standard init: A ~ N(0, std), B = 0 (identity at start).
    pub fn init(din: usize, dout: usize, r: usize, alpha: f32, rng: &mut Rng) -> LoraAdapter {
        LoraAdapter {
            a: Tensor::randn(&[din, r], 0.01, rng),
            b: Tensor::zeros(&[r, dout]),
            alpha,
            r,
        }
    }

    /// Random non-trivial adapter (for analyses).
    pub fn random(din: usize, dout: usize, r: usize, alpha: f32, std: f32, rng: &mut Rng) -> LoraAdapter {
        LoraAdapter {
            a: Tensor::randn(&[din, r], std, rng),
            b: Tensor::randn(&[r, dout], std, rng),
            alpha,
            r,
        }
    }

    pub fn scale(&self) -> f32 {
        self.alpha / self.r as f32
    }

    pub fn num_params(&self) -> usize {
        self.a.numel() + self.b.numel()
    }

    /// The low-rank update Delta = (alpha/r) A B.
    pub fn delta(&self) -> Result<Tensor> {
        Ok(self.a.matmul(&self.b)?.scale(self.scale()))
    }

    /// Forward: y = x W + (alpha/r) (x A) B — the parallel-adaptation path.
    pub fn forward(&self, x: &Tensor, w: &Tensor) -> Result<Tensor> {
        let main = x.matmul(w)?;
        let low = x.matmul(&self.a)?.matmul(&self.b)?.scale(self.scale());
        main.add(&low)
    }

    /// Merged weight W + Delta (what requantization sees; §4).
    pub fn merge(&self, w: &Tensor) -> Result<Tensor> {
        w.add(&self.delta()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn identity_at_init() {
        let mut rng = Rng::new(0);
        let ad = LoraAdapter::init(16, 8, 4, 16.0, &mut rng);
        let x = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let w = Tensor::randn(&[16, 8], 0.2, &mut rng);
        let y = ad.forward(&x, &w).unwrap();
        assert!(y.max_abs_diff(&x.matmul(&w).unwrap()) < 1e-7);
    }

    #[test]
    fn forward_equals_merged() {
        testkit::check("x(W+D) == xW + xD", 25, |g| {
            let din = *g.choose(&[8usize, 16, 32]);
            let dout = *g.choose(&[8usize, 24]);
            let r = g.usize_in(1, 5);
            let mut rng = Rng::new(g.rng.next_u64());
            let ad = LoraAdapter::random(din, dout, r, 16.0, 0.1, &mut rng);
            let x = Tensor::randn(&[4, din], 1.0, &mut rng);
            let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
            let a = ad.forward(&x, &w).map_err(|e| e.to_string())?;
            let b = x.matmul(&ad.merge(&w).map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
            testkit::assert_allclose(&a.data, &b.data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn delta_has_low_rank_structure() {
        let mut rng = Rng::new(5);
        let ad = LoraAdapter::random(16, 16, 2, 16.0, 0.5, &mut rng);
        let d = ad.delta().unwrap();
        // rank <= 2: any 3x3 minor determinant ~ 0. Cheap proxy: the
        // column space is spanned by 2 vectors -> check residual after
        // projecting col 3 onto cols {0, 1} is ~0 for a generic case is
        // fiddly; instead verify via A B factor shapes and a rank bound
        // through Gram spectrum cheapness: ||D||_F^2 <= r * sigma_max^2.
        assert_eq!(ad.a.shape, vec![16, 2]);
        assert_eq!(ad.b.shape, vec![2, 16]);
        assert!(d.fro_norm() > 0.0);
    }

    #[test]
    fn merge_changes_dynamic_range() {
        // §4: W + AB can exceed W's element range — the QLoRA
        // requantization hazard (contrast with peft::oft merge test).
        let mut rng = Rng::new(7);
        let w = Tensor::randn(&[32, 32], 0.1, &mut rng);
        let ad = LoraAdapter::random(32, 32, 8, 32.0, 0.3, &mut rng);
        let merged = ad.merge(&w).unwrap();
        assert!(merged.linf_norm() > w.linf_norm());
    }
}
