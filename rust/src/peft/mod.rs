//! Host-side PEFT mathematics: the Rust mirror of python/compile/kernels.
//!
//! Everything the accelerator graphs compute is re-implemented here in
//! plain Rust so the coordinator can (a) verify runtime outputs against
//! an independent oracle, (b) run the requantization/merging analyses of
//! §4 without a device, and (c) count trainable parameters exactly.

pub mod butterfly;
pub mod counting;
pub mod lora;
pub mod oft;

pub use butterfly::{invert_perm, permute_cols, stride_permutation, ButterflyAdapter};
pub use counting::{count_lora, count_oft, MethodKind};
pub use lora::LoraAdapter;
pub use oft::{
    block_rotate, blockdiag_dense, cayley_exact, cayley_neumann, orthogonality_error,
    packed_dim, skew_from_packed, OftAdapter,
};
