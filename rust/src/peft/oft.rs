//! Orthogonal finetuning math: packed skew storage, Cayley transforms,
//! block-diagonal rotation — the Rust oracle for the L1 Pallas kernels.

use anyhow::{ensure, Result};

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Packed parameter count for a b x b skew-symmetric matrix: b(b-1)/2.
/// This is the §3.3 storage saving (vs b^2 dense).
pub fn packed_dim(b: usize) -> usize {
    b * (b - 1) / 2
}

/// Reconstruct one dense skew-symmetric (b, b) matrix from its packed
/// upper triangle (the paper's custom CUDA kernel, host-side).
pub fn skew_from_packed(packed: &[f32], b: usize) -> Tensor {
    assert_eq!(packed.len(), packed_dim(b));
    let mut q = Tensor::zeros(&[b, b]);
    let mut k = 0;
    for i in 0..b {
        for j in i + 1..b {
            q.set2(i, j, packed[k]);
            q.set2(j, i, -packed[k]);
            k += 1;
        }
    }
    q
}

/// Pack the upper triangle of a dense skew-symmetric matrix.
pub fn packed_from_skew(q: &Tensor) -> Vec<f32> {
    let b = q.shape[0];
    let mut out = Vec::with_capacity(packed_dim(b));
    for i in 0..b {
        for j in i + 1..b {
            out.push(q.at2(i, j));
        }
    }
    out
}

/// Exact Cayley transform R = (I+Q)(I-Q)^{-1} (matrix inverse — the cost
/// and instability the paper's CNP removes).
pub fn cayley_exact(packed: &[f32], b: usize) -> Result<Tensor> {
    let q = skew_from_packed(packed, b);
    let eye = Tensor::eye(b);
    let i_plus = eye.add(&q)?;
    let i_minus = eye.sub(&q)?;
    i_plus.matmul(&i_minus.inverse()?)
}

/// Cayley-Neumann parameterization: R = (I+Q)(I + sum_{i=1..k} Q^i).
pub fn cayley_neumann(packed: &[f32], b: usize, k: usize) -> Result<Tensor> {
    let q = skew_from_packed(packed, b);
    let eye = Tensor::eye(b);
    let mut acc = eye.clone();
    let mut term = eye.clone();
    for _ in 0..k {
        term = term.matmul(&q)?;
        acc = acc.add(&term)?;
    }
    eye.add(&q)?.matmul(&acc)
}

/// ||R^T R - I||_F — approximate-orthogonality error.
pub fn orthogonality_error(r: &Tensor) -> f32 {
    let b = r.shape[0];
    let gram = r.transpose2().matmul(r).unwrap();
    gram.sub(&Tensor::eye(b)).unwrap().fro_norm()
}

/// Materialize the dense block-diagonal matrix Diag(R_1..R_nb).
/// (Weight-centric baseline only.)
pub fn blockdiag_dense(blocks: &[Tensor], d: usize) -> Tensor {
    let b = blocks[0].shape[0];
    assert_eq!(blocks.len() * b, d);
    let mut out = Tensor::zeros(&[d, d]);
    for (bi, blk) in blocks.iter().enumerate() {
        for i in 0..b {
            for j in 0..b {
                out.set2(bi * b + i, bi * b + j, blk.at2(i, j));
            }
        }
    }
    out
}

/// Input-centric block rotation (OFTv2): y[:, ib..ib+b] = x[:, ib..ib+b] @ R_i.
pub fn block_rotate(x: &Tensor, blocks: &[Tensor]) -> Result<Tensor> {
    ensure!(x.rank() == 2);
    let (m, d) = (x.shape[0], x.shape[1]);
    let b = blocks[0].shape[0];
    ensure!(blocks.len() * b == d, "blocks {}x{b} vs d={d}", blocks.len());
    let mut out = Tensor::zeros(&[m, d]);
    for (bi, blk) in blocks.iter().enumerate() {
        for row in 0..m {
            let xoff = row * d + bi * b;
            for j in 0..b {
                let mut acc = 0.0f32;
                for i in 0..b {
                    acc += x.data[xoff + i] * blk.at2(i, j);
                }
                out.data[row * d + bi * b + j] = acc;
            }
        }
    }
    Ok(out)
}

/// A full OFT adapter for one linear layer: nb packed blocks over the
/// input dimension.
#[derive(Clone, Debug)]
pub struct OftAdapter {
    pub b: usize,
    pub nb: usize,
    pub packed: Vec<Vec<f32>>, // nb x packed_dim(b)
    pub neumann_k: usize,
}

impl OftAdapter {
    /// Identity-initialized adapter (Q = 0 -> R = I), the paper's init.
    pub fn identity(din: usize, b: usize, neumann_k: usize) -> OftAdapter {
        assert_eq!(din % b, 0, "block size {b} must divide din {din}");
        OftAdapter {
            b,
            nb: din / b,
            packed: vec![vec![0.0; packed_dim(b)]; din / b],
            neumann_k,
        }
    }

    /// Random small-Q adapter (for tests / analyses).
    pub fn random(din: usize, b: usize, neumann_k: usize, std: f32, rng: &mut Rng) -> OftAdapter {
        let mut a = Self::identity(din, b, neumann_k);
        for blk in &mut a.packed {
            *blk = rng.normal_vec(packed_dim(b), std);
        }
        a
    }

    /// Number of trainable parameters.
    pub fn num_params(&self) -> usize {
        self.nb * packed_dim(self.b)
    }

    /// Build all orthogonal blocks via CNP.
    pub fn blocks(&self) -> Result<Vec<Tensor>> {
        self.packed
            .iter()
            .map(|p| cayley_neumann(p, self.b, self.neumann_k))
            .collect()
    }

    /// Build via exact Cayley (baseline).
    pub fn blocks_exact(&self) -> Result<Vec<Tensor>> {
        self.packed.iter().map(|p| cayley_exact(p, self.b)).collect()
    }

    /// Input-centric forward: y = rotate(x) @ w  (OFTv2, eq. 2).
    pub fn forward_input_centric(&self, x: &Tensor, w: &Tensor) -> Result<Tensor> {
        block_rotate(x, &self.blocks()?)?.matmul(w)
    }

    /// Weight-centric forward: y = x @ (blockdiag(R) @ w)  (OFT, eq. 1).
    pub fn forward_weight_centric(&self, x: &Tensor, w: &Tensor) -> Result<Tensor> {
        let din = w.shape[0];
        let rd = blockdiag_dense(&self.blocks()?, din);
        x.matmul(&rd.matmul(w)?)
    }

    /// The merged weight R W (what you would write back to disk after
    /// finetuning; §4's requantization analysis runs on this).
    pub fn merge(&self, w: &Tensor) -> Result<Tensor> {
        let din = w.shape[0];
        blockdiag_dense(&self.blocks()?, din).matmul(w)
    }

    /// FLOPs per input row for the rotation itself: d*b MACs
    /// (quadratic-in-d total vs the cubic d^2 n merge).
    pub fn rotate_flops_per_row(&self) -> usize {
        self.nb * self.b * self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn packed_roundtrip() {
        testkit::check("skew pack/unpack roundtrip", 50, |g| {
            let b = *g.choose(&[2usize, 3, 4, 8, 16]);
            let packed = g.vec_f32(packed_dim(b), 0.5);
            let q = skew_from_packed(&packed, b);
            // skew-symmetry
            for i in 0..b {
                for j in 0..b {
                    if (q.at2(i, j) + q.at2(j, i)).abs() > 0.0 {
                        return Err(format!("not skew at ({i},{j})"));
                    }
                }
            }
            testkit::assert_allclose(&packed_from_skew(&q), &packed, 0.0, 0.0)
        });
    }

    #[test]
    fn exact_cayley_orthogonal() {
        testkit::check("exact Cayley in O(b)", 30, |g| {
            let b = *g.choose(&[2usize, 4, 8, 16]);
            let packed = g.vec_f32(packed_dim(b), 0.3);
            let r = cayley_exact(&packed, b).map_err(|e| e.to_string())?;
            let err = orthogonality_error(&r);
            if err > 1e-4 {
                return Err(format!("orthogonality error {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn cnp_matches_exact_for_small_q() {
        testkit::check("CNP -> exact Cayley", 30, |g| {
            let b = *g.choose(&[4usize, 8, 16]);
            let packed = g.vec_f32(packed_dim(b), 0.2 / (b as f32).sqrt());
            let exact = cayley_exact(&packed, b).map_err(|e| e.to_string())?;
            let approx = cayley_neumann(&packed, b, 8).map_err(|e| e.to_string())?;
            testkit::assert_allclose(&approx.data, &exact.data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn cnp_identity_at_zero() {
        let r = cayley_neumann(&vec![0.0; packed_dim(16)], 16, 5).unwrap();
        assert_eq!(r, Tensor::eye(16));
    }

    #[test]
    fn input_centric_equals_weight_centric() {
        // Eq. (1) == Eq. (2): the paper's core reformulation argument.
        let mut rng = Rng::new(3);
        let (din, dout, b) = (32, 24, 8);
        let ad = OftAdapter::random(din, b, 6, 0.05, &mut rng);
        let x = Tensor::randn(&[5, din], 1.0, &mut rng);
        let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
        let a = ad.forward_input_centric(&x, &w).unwrap();
        let bb = ad.forward_weight_centric(&x, &w).unwrap();
        assert!(a.max_abs_diff(&bb) < 1e-4, "{}", a.max_abs_diff(&bb));
    }

    #[test]
    fn rotation_preserves_row_norms() {
        testkit::check("hyperspherical energy invariance", 25, |g| {
            let b = *g.choose(&[4usize, 8]);
            let nb = g.usize_in(1, 4);
            let din = nb * b;
            let mut rng = Rng::new(g.rng.next_u64());
            let ad = OftAdapter::random(din, b, 8, 0.02, &mut rng);
            let x = Tensor::randn(&[6, din], 1.0, &mut rng);
            let y = block_rotate(&x, &ad.blocks().map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
            for row in 0..6 {
                let nx: f32 = x.data[row * din..(row + 1) * din].iter().map(|v| v * v).sum();
                let ny: f32 = y.data[row * din..(row + 1) * din].iter().map(|v| v * v).sum();
                if (nx.sqrt() - ny.sqrt()).abs() > 1e-2 * nx.sqrt().max(1.0) {
                    return Err(format!("row {row}: {} vs {}", nx.sqrt(), ny.sqrt()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn merge_preserves_linf_scale() {
        // §4: the merged weight RW keeps per-element dynamic range close
        // to W (orthogonal rows mix but do not amplify); contrast with
        // LoRA's W + AB which adds ||AB||_inf.
        let mut rng = Rng::new(9);
        let (din, dout) = (32, 32);
        let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
        let ad = OftAdapter::random(din, 8, 8, 0.05, &mut rng);
        let merged = ad.merge(&w).unwrap();
        // Orthogonal mixing bound: |(RW)_ij| <= ||R row|| * ||W col|| —
        // check the empirical inflation stays below sqrt(b).
        assert!(merged.linf_norm() <= w.linf_norm() * (8.0f32).sqrt());
    }

    #[test]
    fn identity_adapter_is_noop() {
        let mut rng = Rng::new(11);
        let ad = OftAdapter::identity(16, 4, 5);
        let x = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let w = Tensor::randn(&[16, 8], 0.2, &mut rng);
        let y = ad.forward_input_centric(&x, &w).unwrap();
        assert!(y.max_abs_diff(&x.matmul(&w).unwrap()) < 1e-6);
    }

    #[test]
    fn flops_quadratic_vs_cubic() {
        let ad = OftAdapter::identity(1024, 32, 5);
        let rotate = ad.rotate_flops_per_row(); // 1024*32
        let merge = 1024usize * 1024 * 1024; // d*d*n for n=1024
        assert_eq!(rotate, 1024 * 32);
        assert!(merge / rotate > 30_000 / 32); // >> even per-row
    }
}
