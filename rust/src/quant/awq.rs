//! AWQ-style activation-aware groupwise int4 quantization (Lin et al.
//! 2024), mirrored from python/compile/kernels/ref.py.

use anyhow::{ensure, Result};

use crate::tensor::Tensor;

/// Input-dim rows per quantization group.
pub const AWQ_GROUP: usize = 64;

/// A quantized (din, dout) weight: packed int4 + per-(group, column)
/// scales + per-row activation-aware equalization.
#[derive(Clone, Debug)]
pub struct AwqTensor {
    /// (din/2, dout): rows 2i in the high nibble, 2i+1 in the low.
    pub codes: Vec<u8>,
    /// (din/AWQ_GROUP, dout) symmetric scales.
    pub scales: Vec<f32>,
    /// (din,) equalization factors (sqrt of activation scale).
    pub eq: Vec<f32>,
    pub din: usize,
    pub dout: usize,
}

impl AwqTensor {
    /// Quantize with optional per-input-channel activation magnitudes
    /// (salient channels get scaled up -> finer effective step).
    pub fn quantize(w: &Tensor, act_scale: Option<&[f32]>) -> Result<AwqTensor> {
        ensure!(w.rank() == 2, "awq needs 2-D weights");
        let (din, dout) = (w.shape[0], w.shape[1]);
        ensure!(din % AWQ_GROUP == 0, "din {din} % {AWQ_GROUP} != 0");
        let eq: Vec<f32> = match act_scale {
            Some(a) => {
                ensure!(a.len() == din);
                a.iter().map(|x| x.max(1e-6).sqrt()).collect()
            }
            None => vec![1.0; din],
        };
        let g = din / AWQ_GROUP;
        let mut scales = vec![0f32; g * dout];
        // group absmax of the equalized weights
        for gi in 0..g {
            for c in 0..dout {
                let mut am = 1e-12f32;
                for r in gi * AWQ_GROUP..(gi + 1) * AWQ_GROUP {
                    am = am.max((w.at2(r, c) * eq[r]).abs());
                }
                scales[gi * dout + c] = am / 7.0;
            }
        }
        let mut codes = vec![0u8; din / 2 * dout];
        for r2 in 0..din / 2 {
            for c in 0..dout {
                let qv = |r: usize| -> u8 {
                    let s = scales[(r / AWQ_GROUP) * dout + c];
                    let q = (w.at2(r, c) * eq[r] / s).round().clamp(-8.0, 7.0);
                    (q as i32 + 8) as u8
                };
                codes[r2 * dout + c] = (qv(2 * r2) << 4) | qv(2 * r2 + 1);
            }
        }
        Ok(AwqTensor {
            codes,
            scales,
            eq,
            din,
            dout,
        })
    }

    /// Decode rows `[r0, r0 + rows)` into `out` (row-major
    /// `rows x dout`) — **the** scalar AWQ decode oracle:
    /// `w = (nibble - 8) * scales[group, col] / eq[row]`, per element.
    pub fn decode_rows(&self, r0: usize, rows: usize, out: &mut [f32]) {
        let dout = self.dout;
        debug_assert_eq!(out.len(), rows * dout);
        for (ri, prow) in out.chunks_mut(dout).enumerate() {
            let r = r0 + ri;
            let srow = &self.scales[(r / AWQ_GROUP) * dout..(r / AWQ_GROUP + 1) * dout];
            let crow = &self.codes[(r / 2) * dout..(r / 2 + 1) * dout];
            let hi = r % 2 == 0;
            let eq = self.eq[r];
            for ((v, &byte), &s) in prow.iter_mut().zip(crow).zip(srow) {
                let raw = if hi { byte >> 4 } else { byte & 0xF };
                let nib = raw as i32 - 8;
                *v = nib as f32 * s / eq;
            }
        }
    }

    /// Vectorizable decode, bitwise identical to [`Self::decode_rows`]:
    /// the high/low nibble select is hoisted out of the inner loop (it
    /// is constant per row), leaving a branch-free shift/mask + scale
    /// loop the compiler can lane-block. Every element computes the
    /// exact same IEEE expression (including the division by `eq`).
    pub fn decode_rows_fast(&self, r0: usize, rows: usize, out: &mut [f32]) {
        let dout = self.dout;
        debug_assert_eq!(out.len(), rows * dout);
        for (ri, prow) in out.chunks_mut(dout).enumerate() {
            let r = r0 + ri;
            let srow = &self.scales[(r / AWQ_GROUP) * dout..(r / AWQ_GROUP + 1) * dout];
            let crow = &self.codes[(r / 2) * dout..(r / 2 + 1) * dout];
            let eq = self.eq[r];
            if r % 2 == 0 {
                for ((v, &byte), &s) in prow.iter_mut().zip(crow).zip(srow) {
                    *v = ((byte >> 4) as i32 - 8) as f32 * s / eq;
                }
            } else {
                for ((v, &byte), &s) in prow.iter_mut().zip(crow).zip(srow) {
                    *v = ((byte & 0xF) as i32 - 8) as f32 * s / eq;
                }
            }
        }
    }

    /// Dequantize: w = q * scales[group, col] / eq[row]. (Oracle path —
    /// counted by `quant::dequant_f32_count`.) Delegates to
    /// [`Self::decode_rows`] over all rows so there is exactly one
    /// scalar decode implementation.
    pub fn dequantize(&self) -> Tensor {
        super::note_dequant_f32();
        let (din, dout) = (self.din, self.dout);
        let mut out = vec![0f32; din * dout];
        self.decode_rows(0, din, &mut out);
        Tensor::from_vec(&[din, dout], out)
    }

    /// Storage bytes: codes + scales + eq.
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + 4 * self.scales.len() + 4 * self.eq.len()
    }

    pub fn bytes_per_param(&self) -> f64 {
        self.storage_bytes() as f64 / (self.din * self.dout) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        testkit::check("awq roundtrip error", 20, |g| {
            let din = *g.choose(&[64usize, 128, 256]);
            let dout = *g.choose(&[8usize, 32, 64]);
            let std = g.f32_in(0.01, 2.0);
            let mut rng = Rng::new(g.rng.next_u64());
            let w = Tensor::randn(&[din, dout], std, &mut rng);
            let q = AwqTensor::quantize(&w, None).map_err(|e| e.to_string())?;
            let d = q.dequantize();
            for gi in 0..din / AWQ_GROUP {
                for c in 0..dout {
                    let mut am = 0f32;
                    for r in gi * AWQ_GROUP..(gi + 1) * AWQ_GROUP {
                        am = am.max(w.at2(r, c).abs());
                    }
                    for r in gi * AWQ_GROUP..(gi + 1) * AWQ_GROUP {
                        let err = (w.at2(r, c) - d.at2(r, c)).abs();
                        if err > am / 7.0 / 2.0 * 1.01 + 1e-6 {
                            return Err(format!("({r},{c}): err {err}, absmax {am}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn activation_awareness_reduces_salient_error() {
        let mut rng = Rng::new(4);
        let mut w = Tensor::randn(&[128, 64], 1.0, &mut rng);
        // salient-but-small first group
        for r in 0..AWQ_GROUP {
            for c in 0..64 {
                let v = w.at2(r, c) * 0.05;
                w.set2(r, c, v);
            }
        }
        let plain = AwqTensor::quantize(&w, None).unwrap().dequantize();
        let mut act = vec![1.0f32; 128];
        act[..AWQ_GROUP].iter_mut().for_each(|a| *a = 16.0);
        let tuned = AwqTensor::quantize(&w, Some(&act)).unwrap().dequantize();
        let err = |d: &Tensor| -> f32 {
            (0..AWQ_GROUP)
                .map(|r| (0..64).map(|c| (w.at2(r, c) - d.at2(r, c)).abs()).sum::<f32>())
                .sum()
        };
        assert!(err(&tuned) <= err(&plain));
    }

    #[test]
    fn fast_decode_is_bitwise_equal_to_oracle() {
        let mut rng = Rng::new(18);
        let (din, dout) = (128usize, 33usize);
        let w = Tensor::randn(&[din, dout], 0.5, &mut rng);
        let q = AwqTensor::quantize(&w, None).unwrap();
        for (r0, rows) in [(0usize, din), (1, 1), (2, 1), (63, 3), (din - 1, 1), (4, 0)] {
            let mut a = vec![0.0f32; rows * dout];
            let mut b = vec![f32::NAN; rows * dout];
            q.decode_rows(r0, rows, &mut a);
            q.decode_rows_fast(r0, rows, &mut b);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "r0={r0} rows={rows} i={i}");
            }
        }
    }

    #[test]
    fn rejects_bad_shapes() {
        let w = Tensor::zeros(&[63, 8]);
        assert!(AwqTensor::quantize(&w, None).is_err());
    }

    #[test]
    fn storage_near_half_byte() {
        let mut rng = Rng::new(5);
        let w = Tensor::randn(&[1024, 1024], 0.1, &mut rng);
        let q = AwqTensor::quantize(&w, None).unwrap();
        let bpp = q.bytes_per_param();
        assert!(bpp > 0.5 && bpp < 0.58, "{bpp}");
    }
}
