//! Weight quantization substrate (the bitsandbytes/AutoAWQ role).
//!
//! The Rust side *quantizes* (at model-load time); compute consumes the
//! packs directly through [`QuantWeight`]'s fused block-dequant matmul
//! kernels, so the f32 base matrix is never materialized during train /
//! eval / decode / serve. Packing layouts are byte-identical to
//! python/compile/kernels/ref.py — pytest and the integration tests
//! cross-check the pair; `dequantize()` remains the oracle the fused
//! kernels are locked against.

pub mod awq;
pub mod nf4;
pub mod qweight;
pub mod requant;

pub use awq::{AwqTensor, AWQ_GROUP};
pub use nf4::{Nf4Tensor, NF4_BLOCK, NF4_CODE, NF4_GROUP, NF4_TILE};
pub use qweight::QuantWeight;

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of packed→f32 dequantizations. Every
/// `Nf4Tensor::dequantize` / `AwqTensor::dequantize` call materializes
/// a full f32 copy of a quantized tensor and increments this; the fused
/// compute path never does. End-to-end tests (and the memory benches)
/// assert the counter stays flat across quantized train / eval /
/// decode / serve — the "no f32 base copy" guarantee, in the same
/// spirit as `Engine::upload_count`.
static DEQUANT_F32: AtomicU64 = AtomicU64::new(0);

pub(crate) fn note_dequant_f32() {
    DEQUANT_F32.fetch_add(1, Ordering::Relaxed);
}

/// Number of packed→f32 dequantizations performed by this process.
pub fn dequant_f32_count() -> u64 {
    DEQUANT_F32.load(Ordering::Relaxed)
}
