//! Weight quantization substrate (the bitsandbytes/AutoAWQ role).
//!
//! The Rust side *quantizes* (at model-load time); the AOT graphs
//! *dequantize* (Pallas kernels, every forward). Packing layouts are
//! byte-identical to python/compile/kernels/ref.py — pytest and the
//! integration tests cross-check the pair.

pub mod awq;
pub mod nf4;
pub mod requant;

pub use awq::{AwqTensor, AWQ_GROUP};
pub use nf4::{Nf4Tensor, NF4_BLOCK, NF4_CODE, NF4_GROUP, NF4_TILE};
