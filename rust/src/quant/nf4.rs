//! NF4 (NormalFloat4) quantization with double quantization — the QLoRA
//! storage format (Dettmers et al. 2023), mirrored from
//! python/compile/kernels/ref.py byte-for-byte.

use crate::tensor::Tensor;

/// The 16 NormalFloat4 code levels (bitsandbytes constants).
pub const NF4_CODE: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// Byte -> (high-nibble code, low-nibble code), precomputed at compile
/// time. The fast decoder expands one packed byte to two f32 codes with
/// a single table load instead of two shifts + two 16-entry lookups;
/// the products `code * absmax` are the exact expressions the scalar
/// decoder computes, so the fast path stays bitwise identical.
const fn nf4_pair_lut() -> [[f32; 2]; 256] {
    let mut lut = [[0.0f32; 2]; 256];
    let mut b = 0;
    while b < 256 {
        lut[b][0] = NF4_CODE[b >> 4];
        lut[b][1] = NF4_CODE[b & 0xF];
        b += 1;
    }
    lut
}
static NF4_PAIRS: [[f32; 2]; 256] = nf4_pair_lut();

/// Elements per absmax block.
pub const NF4_BLOCK: usize = 64;
/// Absmax values per double-quantization group.
pub const NF4_GROUP: usize = 256;
/// Flat elements per Pallas dequant program (= one double-quant group).
pub const NF4_TILE: usize = NF4_BLOCK * NF4_GROUP;

/// A quantized tensor: packed 4-bit codes + double-quantized absmax.
#[derive(Clone, Debug)]
pub struct Nf4Tensor {
    /// Two 4-bit codes per byte; even element in the high nibble.
    pub codes: Vec<u8>,
    /// Per-block absmax, int8 double-quantized.
    pub absmax_q: Vec<i8>,
    /// Per-group scale for `absmax_q`.
    pub absmax_s: Vec<f32>,
    /// Double-quantization offset (mean absmax).
    pub offset: f32,
    /// Original element count (before tile padding).
    pub n: usize,
    /// Original shape.
    pub shape: Vec<usize>,
}

fn nearest_code(x: f32) -> u8 {
    let mut best = 0u8;
    let mut bd = f32::INFINITY;
    for (i, &c) in NF4_CODE.iter().enumerate() {
        let d = (x - c).abs();
        if d < bd {
            bd = d;
            best = i as u8;
        }
    }
    best
}

impl Nf4Tensor {
    /// Quantize a float tensor. Pads the flat length to a multiple of
    /// NF4_TILE (so the Pallas kernel sees whole double-quant groups).
    pub fn quantize(t: &Tensor) -> Nf4Tensor {
        let n = t.numel();
        let pad = (NF4_TILE - n % NF4_TILE) % NF4_TILE;
        let mut flat = t.data.clone();
        flat.extend(std::iter::repeat(0.0).take(pad));
        let nb = flat.len() / NF4_BLOCK;

        // per-block absmax
        let mut absmax: Vec<f32> = (0..nb)
            .map(|b| {
                flat[b * NF4_BLOCK..(b + 1) * NF4_BLOCK]
                    .iter()
                    .fold(0.0f32, |m, x| m.max(x.abs()))
                    .max(1e-12)
            })
            .collect();

        // double quantization of absmax
        let offset = absmax.iter().sum::<f32>() / nb as f32;
        let ng = nb / NF4_GROUP;
        let mut absmax_q = vec![0i8; nb];
        let mut absmax_s = vec![0f32; ng];
        for g in 0..ng {
            let grp = &absmax[g * NF4_GROUP..(g + 1) * NF4_GROUP];
            let s = grp
                .iter()
                .fold(0.0f32, |m, a| m.max((a - offset).abs()))
                .max(1e-12);
            absmax_s[g] = s;
            for (i, &a) in grp.iter().enumerate() {
                let q = ((a - offset) / s * 127.0).round().clamp(-127.0, 127.0);
                absmax_q[g * NF4_GROUP + i] = q as i8;
            }
        }
        // quantize codes against the *reconstructed* absmax
        for b in 0..nb {
            let g = b / NF4_GROUP;
            let rec = absmax_q[b] as f32 / 127.0 * absmax_s[g] + offset;
            absmax[b] = if rec.abs() < 1e-12 { 1e-12 } else { rec };
        }
        let mut codes = vec![0u8; flat.len() / 2];
        for (i, pair) in codes.iter_mut().enumerate() {
            let hi = nearest_code(flat[2 * i] / absmax[(2 * i) / NF4_BLOCK]);
            let lo = nearest_code(flat[2 * i + 1] / absmax[(2 * i + 1) / NF4_BLOCK]);
            *pair = (hi << 4) | lo;
        }
        Nf4Tensor {
            codes,
            absmax_q,
            absmax_s,
            offset,
            n,
            shape: t.shape.clone(),
        }
    }

    /// Decode flat elements `[e0, e0 + out.len())` — **the** scalar NF4
    /// decode oracle. Everything else (`dequantize`, the fast decoder,
    /// `QuantWeight::decode_rows`) is defined as equal to this loop.
    /// The per-block absmax is reconstructed with the canonical
    /// `q/127 * s + offset` expression, cached across each 64-elem
    /// block.
    pub fn decode_flat(&self, e0: usize, out: &mut [f32]) {
        let mut e = e0;
        let mut blk = usize::MAX;
        let mut am = 0.0f32;
        for v in out.iter_mut() {
            let b = e / NF4_BLOCK;
            if b != blk {
                blk = b;
                let g = b / NF4_GROUP;
                am = self.absmax_q[b] as f32 / 127.0 * self.absmax_s[g] + self.offset;
            }
            let byte = self.codes[e / 2];
            let nib = if e % 2 == 0 { byte >> 4 } else { byte & 0xF };
            *v = NF4_CODE[nib as usize] * am;
            e += 1;
        }
    }

    /// Vectorizable decode, bitwise identical to [`Self::decode_flat`]:
    /// scalar head/tail at block boundaries, whole blocks expanded
    /// byte -> code pair through the 256-entry [`NF4_PAIRS`] table in a
    /// branch-free inner loop (block starts are even, so the nibble
    /// pairing inside a byte never straddles a block).
    pub fn decode_flat_fast(&self, e0: usize, out: &mut [f32]) {
        let head = ((NF4_BLOCK - e0 % NF4_BLOCK) % NF4_BLOCK).min(out.len());
        self.decode_flat(e0, &mut out[..head]);
        let mut e = e0 + head;
        let mut off = head;
        while out.len() - off >= NF4_BLOCK {
            let b = e / NF4_BLOCK;
            let g = b / NF4_GROUP;
            let am = self.absmax_q[b] as f32 / 127.0 * self.absmax_s[g] + self.offset;
            let bytes = &self.codes[e / 2..e / 2 + NF4_BLOCK / 2];
            let dst = &mut out[off..off + NF4_BLOCK];
            for (pi, &byte) in bytes.iter().enumerate() {
                let pair = NF4_PAIRS[byte as usize];
                dst[2 * pi] = pair[0] * am;
                dst[2 * pi + 1] = pair[1] * am;
            }
            e += NF4_BLOCK;
            off += NF4_BLOCK;
        }
        self.decode_flat(e, &mut out[off..]);
    }

    /// Dequantize back to f32 (host-side oracle for the Pallas kernel
    /// and the fused matmuls; counted by `quant::dequant_f32_count`).
    /// Delegates to [`Self::decode_flat`] over the full range so there
    /// is exactly one scalar decode implementation.
    pub fn dequantize(&self) -> Tensor {
        super::note_dequant_f32();
        let mut out = vec![0.0f32; self.n];
        self.decode_flat(0, &mut out);
        Tensor::from_vec(&self.shape, out)
    }

    /// Storage bytes (codes + absmax + scales + offset) — the memory the
    /// analytic model charges for NF4 weights.
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.absmax_q.len() + 4 * self.absmax_s.len() + 4
    }

    /// Bytes per original parameter (~0.52 for large tensors).
    pub fn bytes_per_param(&self) -> f64 {
        self.storage_bytes() as f64 / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        testkit::check("nf4 roundtrip error", 20, |g| {
            let rows = *g.choose(&[16usize, 64, 100]);
            let cols = *g.choose(&[32usize, 64]);
            let std = g.f32_in(0.01, 2.0);
            let mut rng = Rng::new(g.rng.next_u64());
            let t = Tensor::randn(&[rows, cols], std, &mut rng);
            let q = Nf4Tensor::quantize(&t);
            let d = q.dequantize();
            if d.shape != t.shape {
                return Err("shape".into());
            }
            // error <= block absmax * (max code gap / 2) + slack
            let gap = NF4_CODE
                .windows(2)
                .map(|w| w[1] - w[0])
                .fold(0.0f32, f32::max)
                / 2.0;
            for b in 0..(t.numel() / NF4_BLOCK).max(1) {
                let lo = b * NF4_BLOCK;
                let hi = ((b + 1) * NF4_BLOCK).min(t.numel());
                let am = t.data[lo..hi].iter().fold(0.0f32, |m, x| m.max(x.abs()));
                for i in lo..hi {
                    let err = (t.data[i] - d.data[i]).abs();
                    if err > am * gap * 1.1 + 1e-4 {
                        return Err(format!("elem {i}: err {err} absmax {am}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matches_python_reference_values() {
        // A tiny fixed vector quantized by the python reference
        // (kernels/ref.py) — values regenerated by
        // python -c "... nf4_quantize(np.linspace(-1,1,64)) ..."
        // First byte packs codes of (-1.0, -0.968...) -> both nearest to
        // code 0 -> byte 0x00; middle elements map around code 7/8.
        let xs: Vec<f32> = (0..64).map(|i| -1.0 + 2.0 * i as f32 / 63.0).collect();
        let t = Tensor::from_vec(&[64], xs);
        let q = Nf4Tensor::quantize(&t);
        assert_eq!(q.codes[0], 0x00);
        assert_eq!(q.codes[q.n / 2 - 1] >> 4, 15); // last pair: (~0.968, 1.0)
        assert_eq!(q.codes[q.n / 2 - 1] & 0xF, 15);
        // absmax for the only real block is 1.0
        let g = 0;
        let rec = q.absmax_q[0] as f32 / 127.0 * q.absmax_s[g] + q.offset;
        assert!((rec - 1.0).abs() < 0.02, "{rec}");
    }

    #[test]
    fn storage_is_half_byte_per_param() {
        let mut rng = Rng::new(3);
        let t = Tensor::randn(&[1024, 1024], 0.1, &mut rng);
        let q = Nf4Tensor::quantize(&t);
        let bpp = q.bytes_per_param();
        assert!(bpp > 0.5 && bpp < 0.53, "{bpp}");
    }

    #[test]
    fn fast_decode_is_bitwise_equal_to_oracle() {
        let mut rng = Rng::new(17);
        // 100*33 is odd-width and non-block-aligned end; exercises odd
        // e0 (mid-byte starts), heads, whole blocks, and tails.
        let t = Tensor::randn(&[100, 33], 0.7, &mut rng);
        let q = Nf4Tensor::quantize(&t);
        for (e0, len) in [
            (0usize, q.n),
            (0, 1),
            (1, 130),
            (33, 64),
            (63, 66),
            (64, 128),
            (q.n - 1, 1),
            (5, 0),
        ] {
            let mut a = vec![0.0f32; len];
            let mut b = vec![f32::NAN; len];
            q.decode_flat(e0, &mut a);
            q.decode_flat_fast(e0, &mut b);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "e0={e0} len={len} i={i}");
            }
        }
    }

    #[test]
    fn zero_tensor() {
        let t = Tensor::zeros(&[64, 64]);
        let q = Nf4Tensor::quantize(&t);
        let d = q.dequantize();
        assert!(d.linf_norm() < 1e-6);
    }

    #[test]
    fn preserves_dynamic_range() {
        // §4: NF4 codes are in [-1, 1] so dequantized values never exceed
        // the (reconstructed) block absmax.
        let mut rng = Rng::new(4);
        let t = Tensor::randn(&[256, 64], 0.5, &mut rng);
        let q = Nf4Tensor::quantize(&t);
        let d = q.dequantize();
        assert!(d.linf_norm() <= t.linf_norm() * 1.05 + 1e-5);
    }
}
