//! [`QuantWeight`] — a packed quantized base weight as a first-class
//! compute object.
//!
//! The NF4/AWQ pack buffers (the storage layer) gain the two matmuls a
//! frozen base weight actually needs during train / eval / decode /
//! serve: `y = x @ W` (forward) and `y = g @ W^T` (the backward's
//! `dL/dx`). Both run through the fused kernels in
//! [`crate::tensor::fused`], decoding codes group-by-group into a
//! scratch panel — so a quantized run never materializes the f32 base
//! matrix the old `dequantize`-at-assembly path expanded.
//!
//! `dequantize()` stays available as the oracle the fused kernels are
//! locked against (rust/tests/quant_fused.rs); every oracle call is
//! counted by the process-wide probe in [`crate::quant`] so end-to-end
//! tests can assert the hot paths never take it.

use anyhow::{ensure, Result};

use super::awq::{AwqTensor, AWQ_GROUP};
use super::nf4::{Nf4Tensor, NF4_BLOCK, NF4_GROUP};
use crate::tensor::fused::{fused_matmul, fused_matmul_t};
use crate::tensor::Tensor;

/// A packed `(din, dout)` base weight in either quantization format.
///
/// The representation is private on purpose: every instance goes
/// through [`QuantWeight::nf4`] / [`QuantWeight::awq`], so the pack
/// bounds checks cannot be bypassed and `decode_rows` never indexes
/// out of bounds mid-matmul.
#[derive(Clone, Debug)]
pub struct QuantWeight(Repr);

#[derive(Clone, Debug)]
enum Repr {
    Nf4(Nf4Tensor),
    Awq(AwqTensor),
}

impl QuantWeight {
    /// Wrap an NF4 pack, bounds-checking every pack field against the
    /// weight's shape so a truncated or empty pack surfaces as an error
    /// naming the field instead of an out-of-bounds panic mid-matmul.
    pub fn nf4(q: Nf4Tensor) -> Result<QuantWeight> {
        ensure!(
            q.shape.len() == 2,
            "NF4 weight must be 2-D, got shape {:?}",
            q.shape
        );
        ensure!(
            q.n == q.shape[0] * q.shape[1],
            "NF4 element count {} does not match shape {:?}",
            q.n,
            q.shape
        );
        let npad = q.codes.len() * 2;
        ensure!(
            npad >= q.n && npad % NF4_BLOCK == 0,
            "nf4_codes holds {npad} elements ({} bytes); weight needs {} in whole blocks",
            q.codes.len(),
            q.n
        );
        ensure!(
            q.absmax_q.len() == npad / NF4_BLOCK,
            "nf4_absmax_q has {} entries, codes imply {}",
            q.absmax_q.len(),
            npad / NF4_BLOCK
        );
        ensure!(
            q.absmax_q.len() % NF4_GROUP == 0
                && q.absmax_s.len() == q.absmax_q.len() / NF4_GROUP,
            "nf4_absmax_s has {} entries, absmax blocks imply {}",
            q.absmax_s.len(),
            q.absmax_q.len().div_ceil(NF4_GROUP)
        );
        ensure!(q.offset.is_finite(), "nf4_offset is not finite");
        Ok(QuantWeight(Repr::Nf4(q)))
    }

    /// Wrap an AWQ pack, bounds-checking codes/scales/eq against
    /// `(din, dout)` (same contract as [`QuantWeight::nf4`]).
    pub fn awq(q: AwqTensor) -> Result<QuantWeight> {
        ensure!(
            q.din % 2 == 0 && q.din % AWQ_GROUP == 0,
            "AWQ din {} must be even and divisible by {AWQ_GROUP}",
            q.din
        );
        ensure!(
            q.codes.len() == q.din / 2 * q.dout,
            "awq_codes has {} bytes, ({}, {}) needs {}",
            q.codes.len(),
            q.din,
            q.dout,
            q.din / 2 * q.dout
        );
        ensure!(
            q.scales.len() == q.din / AWQ_GROUP * q.dout,
            "awq_scales has {} entries, ({}, {}) needs {}",
            q.scales.len(),
            q.din,
            q.dout,
            q.din / AWQ_GROUP * q.dout
        );
        ensure!(
            q.eq.len() == q.din,
            "awq_eq has {} entries, din is {}",
            q.eq.len(),
            q.din
        );
        Ok(QuantWeight(Repr::Awq(q)))
    }

    /// `(din, dout)`.
    pub fn shape(&self) -> (usize, usize) {
        match &self.0 {
            Repr::Nf4(q) => (q.shape[0], q.shape[1]),
            Repr::Awq(q) => (q.din, q.dout),
        }
    }

    /// Packed storage bytes (codes + scales + metadata).
    pub fn storage_bytes(&self) -> usize {
        match &self.0 {
            Repr::Nf4(q) => q.storage_bytes(),
            Repr::Awq(q) => q.storage_bytes(),
        }
    }

    /// Full f32 expansion — the *oracle* the fused kernels are locked
    /// against, never the compute path. Counted by
    /// [`crate::quant::dequant_f32_count`].
    pub fn dequantize(&self) -> Tensor {
        match &self.0 {
            Repr::Nf4(q) => q.dequantize(),
            Repr::Awq(q) => q.dequantize(),
        }
    }

    /// Decode rows `[r0, r0 + rows)` of the weight into `panel`
    /// (row-major `rows x dout`), bit-identical to the same rows of
    /// `dequantize()` in **both** dispatch modes: the scalar path *is*
    /// the per-format oracle (`Nf4Tensor::decode_flat` /
    /// `AwqTensor::decode_rows`), and the fast paths compute identical
    /// per-element IEEE expressions with vectorizable loop structure.
    pub fn decode_rows(&self, r0: usize, rows: usize, panel: &mut [f32]) {
        let fast = crate::tensor::simd_kernels_active();
        match &self.0 {
            Repr::Nf4(q) => {
                let dout = q.shape[1];
                debug_assert_eq!(panel.len(), rows * dout);
                if fast {
                    q.decode_flat_fast(r0 * dout, panel);
                } else {
                    q.decode_flat(r0 * dout, panel);
                }
            }
            Repr::Awq(q) => {
                debug_assert_eq!(panel.len(), rows * q.dout);
                if fast {
                    q.decode_rows_fast(r0, rows, panel);
                } else {
                    q.decode_rows(r0, rows, panel);
                }
            }
        }
    }

    /// `y = x @ W`, fused: panels of W are decoded on the fly, the f32
    /// matrix is never materialized. Bit-identical to
    /// `x.matmul(&self.dequantize())` (same accumulation order).
    pub fn matmul(&self, x: &Tensor) -> Result<Tensor> {
        let (din, dout) = self.shape();
        fused_matmul(x, din, dout, |r0, rows, panel| {
            self.decode_rows(r0, rows, panel)
        })
    }

    /// `y = g @ W^T`, fused (the backward's `dL/dx`). Bit-identical to
    /// `g.matmul(&self.dequantize().transpose2())`.
    pub fn matmul_t(&self, g: &Tensor) -> Result<Tensor> {
        let (din, dout) = self.shape();
        fused_matmul_t(g, din, dout, |r0, rows, panel| {
            self.decode_rows(r0, rows, panel)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn nf4_weight(din: usize, dout: usize, seed: u64) -> (QuantWeight, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
        let q = QuantWeight::nf4(Nf4Tensor::quantize(&w)).unwrap();
        (q, w)
    }

    fn awq_weight(din: usize, dout: usize, seed: u64) -> QuantWeight {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
        QuantWeight::awq(AwqTensor::quantize(&w, None).unwrap()).unwrap()
    }

    #[test]
    fn decode_rows_matches_dequantize_bitwise() {
        for (qw, _) in [nf4_weight(96, 40, 1), nf4_weight(64, 64, 2)] {
            let (din, dout) = qw.shape();
            let oracle = qw.dequantize();
            for (r0, rows) in [(0usize, din), (3, 5), (din - 1, 1)] {
                let mut panel = vec![0.0f32; rows * dout];
                qw.decode_rows(r0, rows, &mut panel);
                assert_eq!(&panel[..], &oracle.data[r0 * dout..(r0 + rows) * dout]);
            }
        }
        let qw = awq_weight(128, 48, 3);
        let (din, dout) = qw.shape();
        let oracle = qw.dequantize();
        let mut panel = vec![0.0f32; din * dout];
        qw.decode_rows(0, din, &mut panel);
        assert_eq!(&panel[..], &oracle.data[..]);
    }

    #[test]
    fn fused_matmuls_match_oracle() {
        let mut rng = Rng::new(9);
        for qw in [nf4_weight(128, 48, 4).0, awq_weight(128, 48, 5)] {
            let (din, dout) = qw.shape();
            let d = qw.dequantize();
            for m in [1usize, 6, 33] {
                let x = Tensor::randn(&[m, din], 1.0, &mut rng);
                assert_eq!(qw.matmul(&x).unwrap(), x.matmul(&d).unwrap());
                let g = Tensor::randn(&[m, dout], 1.0, &mut rng);
                assert_eq!(qw.matmul_t(&g).unwrap(), g.matmul(&d.transpose2()).unwrap());
            }
        }
    }

    #[test]
    fn rejects_truncated_packs() {
        let mut rng = Rng::new(7);
        let w = Tensor::randn(&[64, 64], 0.1, &mut rng);
        let mut q = Nf4Tensor::quantize(&w);
        q.codes.truncate(q.codes.len() / 2);
        assert!(QuantWeight::nf4(q).is_err(), "truncated codes must be rejected");

        let w = Tensor::randn(&[128, 32], 0.1, &mut rng);
        let mut a = AwqTensor::quantize(&w, None).unwrap();
        a.scales.pop();
        assert!(QuantWeight::awq(a).is_err(), "truncated scales must be rejected");
    }

    #[test]
    fn shape_and_storage() {
        let (qw, _) = nf4_weight(64, 64, 11);
        assert_eq!(qw.shape(), (64, 64));
        assert!(qw.storage_bytes() > 0);
    }
}
