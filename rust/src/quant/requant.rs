//! Requantization-error analysis — the §4 "QOFT vs QLoRA" discussion —
//! generalized into the one merge→requantize path every registry method
//! shares ([`merge_requant`]).
//!
//! After finetuning a quantized model you may want to merge the adapter
//! back and re-quantize. The paper argues:
//!   * QLoRA's merged weight `W + AB` can change the per-block dynamic
//!     range, inflating requantization error by up to `||AB||_inf`;
//!   * QOFT's merged weight `R W` preserves per-element magnitudes
//!     (orthogonal mixing), so requantization stays benign.
//! The merge itself is method-owned ([`crate::adapters::Adapter::merge_linear`]):
//! orthogonal methods fold by rotation, LoRA by addition, `full`/`none`
//! trivially. The `requant_error` bench regenerates the §4 comparison
//! through this path.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::adapters::Adapter;
use crate::coordinator::manifest::ModelDims;
use crate::quant::awq::AwqTensor;
use crate::quant::nf4::{Nf4Tensor, NF4_CODE};
use crate::runtime::layers::Params;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// RMS + max-abs error between two tensors.
#[derive(Clone, Copy, Debug)]
pub struct ErrStats {
    pub rms: f64,
    pub max: f64,
}

pub fn err_stats(a: &Tensor, b: &Tensor) -> ErrStats {
    assert_eq!(a.shape, b.shape);
    let mut sum = 0f64;
    let mut max = 0f64;
    for (x, y) in a.data.iter().zip(&b.data) {
        assert!(
            x.is_finite() && y.is_finite(),
            "err_stats: non-finite input value"
        );
        let d = (*x - *y) as f64;
        sum += d * d;
        max = max.max(d.abs());
    }
    ErrStats {
        rms: (sum / a.numel().max(1) as f64).sqrt(),
        max,
    }
}

/// Requantization target of a merged deployable weight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantKind {
    /// Keep the merged weight in f32 (no requantization error).
    None,
    Nf4,
    Awq,
}

impl QuantKind {
    pub fn parse(s: &str) -> Result<QuantKind> {
        Ok(match s {
            "none" => QuantKind::None,
            "nf4" => QuantKind::Nf4,
            "awq" => QuantKind::Awq,
            other => bail!("unknown quant kind '{other}' (expected none|nf4|awq)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantKind::None => "none",
            QuantKind::Nf4 => "nf4",
            QuantKind::Awq => "awq",
        }
    }

    /// Quantize→dequantize round trip: the exact values a deployment of
    /// `w` under this packing would serve.
    pub fn roundtrip(self, w: &Tensor) -> Result<Tensor> {
        Ok(match self {
            QuantKind::None => w.clone(),
            QuantKind::Nf4 => Nf4Tensor::quantize(w).dequantize(),
            QuantKind::Awq => AwqTensor::quantize(w, None)?.dequantize(),
        })
    }
}

/// Result of one merge -> requantize experiment.
#[derive(Clone, Copy, Debug)]
pub struct RequantReport {
    /// Error of re-quantizing the *merged* finetuned weight.
    pub merged: ErrStats,
    /// Error of quantizing the original weight (the baseline floor).
    pub baseline: ErrStats,
    /// Range inflation: ||merged||_inf / ||W||_inf.
    pub range_inflation: f64,
    /// ||Delta||_inf (= ||AB||_inf for LoRA, ||RW - W||_inf for OFT).
    pub delta_inf: f64,
}

/// The one trait-driven merge→requantize step (§4, generalized): fold
/// `linear`'s adapter into its dense base weight via
/// [`Adapter::merge_linear`], round-trip the merged weight through the
/// target packing, and report error statistics against both the merged
/// weight and the original-quantization floor. Returns the deployable
/// weight — for quantized targets the round-tripped values, exactly
/// what a packed deployment serves — alongside the report.
pub fn merge_requant(
    adapter: &dyn Adapter,
    linear: &str,
    w: &Tensor,
    trainables: &Params,
    dims: &ModelDims,
    quant: QuantKind,
) -> Result<(Tensor, RequantReport)> {
    if !adapter.can_merge() {
        bail!(
            "method '{}' does not support merging (can_merge() is false)",
            adapter.name()
        );
    }
    let merged = adapter.merge_linear(linear, w, trainables, dims)?;
    let delta_inf = merged.sub(w)?.linf_norm() as f64;
    let deployed = quant.roundtrip(&merged)?;
    let baseline = quant.roundtrip(w)?;
    let report = RequantReport {
        merged: err_stats(&deployed, &merged),
        baseline: err_stats(&baseline, w),
        range_inflation: merged.linf_norm() as f64 / w.linf_norm().max(1e-12) as f64,
        delta_inf,
    };
    Ok((deployed, report))
}

/// Reference absmax/NF4-codebook round-trip with a configurable group
/// size. The production packer fixes `NF4_BLOCK`/`NF4_GROUP` at compile
/// time, so the group-size sweep (requant error shrinks as the group
/// shrinks) runs through this standalone scalar path.
pub fn nf4_roundtrip_grouped(w: &Tensor, group: usize) -> Tensor {
    assert!(group > 0, "nf4_roundtrip_grouped: group must be positive");
    let mut out = Vec::with_capacity(w.numel());
    for chunk in w.data.chunks(group) {
        let absmax = chunk.iter().fold(0f32, |m, v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        for v in chunk {
            let x = v / scale;
            let q = NF4_CODE
                .iter()
                .copied()
                .min_by(|a, b| (a - x).abs().total_cmp(&(b - x).abs()))
                .unwrap();
            out.push(q * scale);
        }
    }
    Tensor::from_vec(&w.shape, out)
}

/// Random "trained-looking" trainables of `adapter` for one standalone
/// linear — the analysis/bench entry into [`merge_requant`] when no
/// real checkpoint is at hand (the declared inits are zero for most
/// methods, which would make every merge an identity).
pub fn analysis_trainables(
    adapter: &dyn Adapter,
    linear: &str,
    din: usize,
    dout: usize,
    dims: &ModelDims,
    std: f32,
    rng: &mut Rng,
) -> Params {
    let mut map = BTreeMap::new();
    for spec in adapter.linear_trainables(linear, din, dout, dims) {
        map.insert(spec.name, Tensor::randn(&spec.shape, std, rng));
    }
    Params {
        map,
        quant: BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters;
    use crate::peft::{LoraAdapter, OftAdapter};
    use crate::testkit;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Tensor, LoraAdapter, OftAdapter) {
        let mut rng = Rng::new(seed);
        let (din, dout) = (128, 128);
        let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
        // comparable adaptation strength: both trained-looking magnitudes
        let lora = LoraAdapter::random(din, dout, 16, 32.0, 0.06, &mut rng);
        let oft = OftAdapter::random(din, 32, 6, 0.04, &mut rng);
        (w, lora, oft)
    }

    #[test]
    fn qoft_preserves_range_better_than_qlora() {
        // §4's core claim: at matched adaptation strength ||ΔW||_F, the
        // low-rank update W + AB concentrates its energy (rank-r
        // outliers -> range inflation), while the orthogonal update RW
        // spreads it (a rotated Gaussian stays Gaussian). Compare mean
        // range inflation across seeds with the LoRA delta rescaled to
        // the OFT delta's Frobenius norm.
        let mut infl_lora = 0.0f64;
        let mut infl_oft = 0.0f64;
        let n_seeds = 10;
        for seed in 0..n_seeds {
            let (w, lora, oft) = setup(seed);
            let d_oft = oft.merge(&w).unwrap().sub(&w).unwrap();
            let d_lora = lora.delta().unwrap().scale(lora.scale());
            let match_scale = d_oft.fro_norm() / d_lora.fro_norm().max(1e-12);
            let merged_lora = w.add(&d_lora.scale(match_scale)).unwrap();
            let merged_oft = w.add(&d_oft).unwrap();
            infl_lora += (merged_lora.linf_norm() / w.linf_norm()) as f64;
            infl_oft += (merged_oft.linf_norm() / w.linf_norm()) as f64;
            // orthogonal merging keeps the range bounded
            let infl = (merged_oft.linf_norm() / w.linf_norm().max(1e-12)) as f64;
            assert!(infl < 1.35, "{infl}");
        }
        infl_lora /= n_seeds as f64;
        infl_oft /= n_seeds as f64;
        assert!(
            infl_oft <= infl_lora + 1e-3,
            "mean range inflation: QOFT {infl_oft:.4} vs QLoRA {infl_lora:.4}"
        );
    }

    #[test]
    fn requant_error_floor_is_baseline() {
        // Trait-driven: for every mergeable dense-base method, the
        // merged requant error can't beat quantizing the original.
        let dims = ModelDims::analysis(16, 32);
        for method in ["lora", "oft_v2", "oft_merged", "boft", "hoft"] {
            let ad = adapters::get(method).unwrap();
            let mut rng = Rng::new(7);
            let w = Tensor::randn(&[128, 128], 0.1, &mut rng);
            let tr = analysis_trainables(ad, "w", 128, 128, &dims, 0.05, &mut rng);
            let (_, r) = merge_requant(ad, "w", &w, &tr, &dims, QuantKind::Nf4).unwrap();
            assert!(
                r.merged.rms >= r.baseline.rms * 0.5,
                "{method}: merged rms {} below baseline floor {}",
                r.merged.rms,
                r.baseline.rms
            );
        }
    }

    #[test]
    fn delta_inf_reported() {
        let dims = ModelDims::analysis(16, 32);
        let ad = adapters::get("lora").unwrap();
        let mut rng = Rng::new(9);
        let w = Tensor::randn(&[128, 128], 0.1, &mut rng);
        let tr = analysis_trainables(ad, "w", 128, 128, &dims, 0.05, &mut rng);
        let (_, r) = merge_requant(ad, "w", &w, &tr, &dims, QuantKind::Nf4).unwrap();
        assert!(r.delta_inf > 0.0);
    }

    #[test]
    fn quant_none_is_exact() {
        // QuantKind::None deploys the merged f32 weight verbatim: zero
        // requant error on both the merged and baseline legs, while the
        // merge delta is still reported.
        let dims = ModelDims::analysis(16, 32);
        let ad = adapters::get("oft_v2").unwrap();
        let mut rng = Rng::new(11);
        let w = Tensor::randn(&[64, 64], 0.1, &mut rng);
        let tr = analysis_trainables(ad, "w", 64, 64, &dims, 0.05, &mut rng);
        let (deployed, r) = merge_requant(ad, "w", &w, &tr, &dims, QuantKind::None).unwrap();
        assert_eq!(r.merged.rms, 0.0);
        assert_eq!(r.merged.max, 0.0);
        assert_eq!(r.baseline.rms, 0.0);
        assert!(r.delta_inf > 0.0);
        let m = ad.merge_linear("w", &w, &tr, &dims).unwrap();
        assert_eq!(deployed.data, m.data);
    }

    #[test]
    fn unmergeable_method_is_rejected() {
        use crate::adapters::{ActExtra, DecodeApply};
        use crate::coordinator::manifest::ParamSpec;
        use crate::runtime::layers::{Ctx, Gradients, LinearAct, WeightRef};

        // A method that keeps the trait defaults: can_merge() is false
        // and merge_linear() bails.
        struct NoMerge;
        impl Adapter for NoMerge {
            fn name(&self) -> &'static str {
                "nomerge"
            }
            fn about(&self) -> &'static str {
                "test stub without a merge path"
            }
            fn paper_label(&self, _quantized: bool) -> &'static str {
                "nomerge"
            }
            fn linear_trainables(
                &self,
                _linear: &str,
                _din: usize,
                _dout: usize,
                _dims: &ModelDims,
            ) -> Vec<ParamSpec> {
                Vec::new()
            }
            fn linear_forward(
                &self,
                _ctx: &Ctx,
                _linear: &str,
                _w: WeightRef,
                _x: &Tensor,
            ) -> anyhow::Result<(Tensor, Option<ActExtra>)> {
                unreachable!("test stub")
            }
            fn linear_backward(
                &self,
                _ctx: &Ctx,
                _linear: &str,
                _w: WeightRef,
                _act: &LinearAct,
                _dy: &Tensor,
                _grads: &mut Gradients,
            ) -> anyhow::Result<Tensor> {
                unreachable!("test stub")
            }
            fn resolve_decode(
                &self,
                _params: &Params,
                _dims: &ModelDims,
                _linear: &str,
                _w: WeightRef,
            ) -> anyhow::Result<Box<dyn DecodeApply>> {
                unreachable!("test stub")
            }
        }

        let dims = ModelDims::analysis(16, 32);
        let w = Tensor::randn(&[64, 64], 0.1, &mut Rng::new(1));
        let tr = Params {
            map: BTreeMap::new(),
            quant: BTreeMap::new(),
        };
        let err = merge_requant(&NoMerge, "w", &w, &tr, &dims, QuantKind::None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not support merging"), "{err}");
    }

    #[test]
    fn err_stats_zero_tensor() {
        let z = Tensor::from_vec(&[4, 4], vec![0.0; 16]);
        let s = err_stats(&z, &z);
        assert_eq!(s.rms, 0.0);
        assert_eq!(s.max, 0.0);
    }

    #[test]
    fn err_stats_identical_tensors() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[8, 8], 0.5, &mut rng);
        let s = err_stats(&a, &a.clone());
        assert_eq!(s.rms, 0.0);
        assert_eq!(s.max, 0.0);
        // and a known nonzero case: constant offset 0.5
        let b = Tensor::from_vec(&[8, 8], a.data.iter().map(|v| v + 0.5).collect());
        let s2 = err_stats(&a, &b);
        assert!((s2.rms - 0.5).abs() < 1e-6);
        assert!((s2.max - 0.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn err_stats_nan_guard() {
        let a = Tensor::from_vec(&[2], vec![0.0, f32::NAN]);
        let b = Tensor::from_vec(&[2], vec![0.0, 0.0]);
        err_stats(&a, &b);
    }

    #[test]
    fn requant_error_shrinks_as_group_shrinks() {
        // Property: a finer quantization group tracks the local dynamic
        // range more closely, so the round-trip error is monotonically
        // nonincreasing as the group shrinks (small multiplicative
        // slack for ties on easy tensors).
        testkit::check("NF4 groupwise error shrinks with group size", 20, |g| {
            let n = *g.choose(&[1024usize, 4096]);
            let std = g.f32_in(0.02, 0.2);
            let mut rng = Rng::new(g.rng.next_u64());
            let w = Tensor::randn(&[n], std, &mut rng);
            let mut prev = f64::INFINITY;
            for group in [256usize, 64, 16] {
                let rms = err_stats(&nf4_roundtrip_grouped(&w, group), &w).rms;
                if rms > prev * 1.02 + 1e-9 {
                    return Err(format!(
                        "group {group}: rms {rms:.6} above coarser group's {prev:.6}"
                    ));
                }
                prev = rms;
            }
            Ok(())
        });
    }

    #[test]
    fn merged_rw_requant_error_below_lora_additive_baseline() {
        // §4 as a *property*, swept over shapes, seeds and adapter
        // strengths: at matched ||Δ||_F, re-quantizing the orthogonal
        // merge R·W never costs (appreciably) more than re-quantizing
        // the additive merge W + AB, and on average costs less — the
        // low-rank update concentrates energy into range-inflating
        // outliers while the rotation spreads it.
        // (sum of LoRA rms, sum of RW rms, cases) across the sweep
        let acc = std::cell::RefCell::new((0.0f64, 0.0f64, 0usize));
        testkit::check("RW requant error <= LoRA additive baseline", 25, |g| {
            let din = *g.choose(&[64usize, 128, 256]);
            let dout = *g.choose(&[64usize, 128]);
            let b = *g.choose(&[16usize, 32]);
            let strength = g.f32_in(0.01, 0.08);
            let mut rng = Rng::new(g.rng.next_u64());
            let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
            let oft = OftAdapter::random(din, b, 6, strength, &mut rng);
            let lora = LoraAdapter::random(din, dout, 16, 32.0, strength, &mut rng);

            // match adaptation strength: rescale the LoRA delta to the
            // OFT delta's Frobenius norm before merging
            let d_oft = oft
                .merge(&w)
                .and_then(|m| m.sub(&w))
                .map_err(|e| e.to_string())?;
            let d_lora_raw = lora.delta().map_err(|e| e.to_string())?;
            let s = d_oft.fro_norm() / d_lora_raw.fro_norm().max(1e-12);
            let merged_lora = w.add(&d_lora_raw.scale(s)).map_err(|e| e.to_string())?;
            let merged_oft = w.add(&d_oft).map_err(|e| e.to_string())?;

            let rq = |m: &Tensor| err_stats(&Nf4Tensor::quantize(m).dequantize(), m);
            let e_lora = rq(&merged_lora).rms;
            let e_oft = rq(&merged_oft).rms;
            // per-case: orthogonal merge never appreciably worse
            if e_oft > e_lora * 1.15 + 1e-6 {
                return Err(format!(
                    "RW rms {e_oft:.6} exceeds LoRA rms {e_lora:.6} (din={din}, b={b})"
                ));
            }
            let mut a = acc.borrow_mut();
            a.0 += e_lora;
            a.1 += e_oft;
            a.2 += 1;
            Ok(())
        });
        let (sum_lora, sum_oft, cases) = *acc.borrow();
        assert!(cases > 0);
        assert!(
            sum_oft <= sum_lora * 1.02,
            "mean RW requant rms {sum_oft} above LoRA baseline {sum_lora}"
        );
    }
}
