//! Requantization-error analysis — the §4 "QOFT vs QLoRA" discussion.
//!
//! After finetuning a quantized model you may want to merge the adapter
//! back and re-quantize. The paper argues:
//!   * QLoRA's merged weight `W + AB` can change the per-block dynamic
//!     range, inflating requantization error by up to `||AB||_inf`;
//!   * QOFT's merged weight `R W` preserves per-element magnitudes
//!     (orthogonal mixing), so requantization stays benign.
//! The `requant_error` bench regenerates this comparison.

use anyhow::Result;

use crate::peft::{LoraAdapter, OftAdapter};
use crate::quant::nf4::Nf4Tensor;
use crate::tensor::Tensor;

/// RMS + max-abs error between two tensors.
#[derive(Clone, Copy, Debug)]
pub struct ErrStats {
    pub rms: f64,
    pub max: f64,
}

pub fn err_stats(a: &Tensor, b: &Tensor) -> ErrStats {
    assert_eq!(a.shape, b.shape);
    let mut sum = 0f64;
    let mut max = 0f64;
    for (x, y) in a.data.iter().zip(&b.data) {
        let d = (*x - *y) as f64;
        sum += d * d;
        max = max.max(d.abs());
    }
    ErrStats {
        rms: (sum / a.numel() as f64).sqrt(),
        max,
    }
}

/// Result of one merge -> requantize experiment.
#[derive(Clone, Copy, Debug)]
pub struct RequantReport {
    /// Error of re-quantizing the *merged* finetuned weight.
    pub merged: ErrStats,
    /// Error of quantizing the original weight (the baseline floor).
    pub baseline: ErrStats,
    /// Range inflation: ||merged||_inf / ||W||_inf.
    pub range_inflation: f64,
    /// ||Delta||_inf (= ||AB||_inf for LoRA, ||RW - W||_inf for OFT).
    pub delta_inf: f64,
}

fn requant_roundtrip(w: &Tensor) -> Tensor {
    Nf4Tensor::quantize(w).dequantize()
}

/// QLoRA: merge W + (alpha/r) A B, requantize, measure.
pub fn qlora_requant(w: &Tensor, adapter: &LoraAdapter) -> Result<RequantReport> {
    let merged = adapter.merge(w)?;
    let delta = adapter.delta()?;
    Ok(report(w, &merged, delta.linf_norm() as f64))
}

/// QOFT: merge R W, requantize, measure.
pub fn qoft_requant(w: &Tensor, adapter: &OftAdapter) -> Result<RequantReport> {
    let merged = adapter.merge(w)?;
    let delta = merged.sub(w)?;
    Ok(report(w, &merged, delta.linf_norm() as f64))
}

fn report(w: &Tensor, merged: &Tensor, delta_inf: f64) -> RequantReport {
    let mq = requant_roundtrip(merged);
    let bq = requant_roundtrip(w);
    RequantReport {
        merged: err_stats(&mq, merged),
        baseline: err_stats(&bq, w),
        range_inflation: merged.linf_norm() as f64 / w.linf_norm().max(1e-12) as f64,
        delta_inf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Tensor, LoraAdapter, OftAdapter) {
        let mut rng = Rng::new(seed);
        let (din, dout) = (128, 128);
        let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
        // comparable adaptation strength: both trained-looking magnitudes
        let lora = LoraAdapter::random(din, dout, 16, 32.0, 0.06, &mut rng);
        let oft = OftAdapter::random(din, 32, 6, 0.04, &mut rng);
        (w, lora, oft)
    }

    #[test]
    fn qoft_preserves_range_better_than_qlora() {
        // §4's core claim: at matched adaptation strength ||ΔW||_F, the
        // low-rank update W + AB concentrates its energy (rank-r
        // outliers -> range inflation), while the orthogonal update RW
        // spreads it (a rotated Gaussian stays Gaussian). Compare mean
        // range inflation across seeds with the LoRA delta rescaled to
        // the OFT delta's Frobenius norm.
        let mut infl_lora = 0.0f64;
        let mut infl_oft = 0.0f64;
        let n_seeds = 10;
        for seed in 0..n_seeds {
            let (w, lora, oft) = setup(seed);
            let d_oft = oft.merge(&w).unwrap().sub(&w).unwrap();
            let d_lora = lora.delta().unwrap().scale(lora.scale());
            let match_scale = d_oft.fro_norm() / d_lora.fro_norm().max(1e-12);
            let merged_lora = w.add(&d_lora.scale(match_scale)).unwrap();
            let merged_oft = w.add(&d_oft).unwrap();
            infl_lora += (merged_lora.linf_norm() / w.linf_norm()) as f64;
            infl_oft += (merged_oft.linf_norm() / w.linf_norm()) as f64;
            // orthogonal merging keeps the range bounded
            let ro = qoft_requant(&w, &oft).unwrap();
            assert!(ro.range_inflation < 1.35, "{}", ro.range_inflation);
        }
        infl_lora /= n_seeds as f64;
        infl_oft /= n_seeds as f64;
        assert!(
            infl_oft <= infl_lora + 1e-3,
            "mean range inflation: QOFT {infl_oft:.4} vs QLoRA {infl_lora:.4}"
        );
    }

    #[test]
    fn requant_error_floor_is_baseline() {
        let (w, lora, oft) = setup(7);
        let rl = qlora_requant(&w, &lora).unwrap();
        let ro = qoft_requant(&w, &oft).unwrap();
        // merged requant error can't beat quantizing the original
        assert!(rl.merged.rms >= rl.baseline.rms * 0.5);
        assert!(ro.merged.rms >= ro.baseline.rms * 0.5);
    }

    #[test]
    fn delta_inf_reported() {
        let (w, lora, _) = setup(9);
        let r = qlora_requant(&w, &lora).unwrap();
        assert!(r.delta_inf > 0.0);
    }

    #[test]
    fn merged_rw_requant_error_below_lora_additive_baseline() {
        // §4 as a *property*, swept over shapes, seeds and adapter
        // strengths: at matched ||Δ||_F, re-quantizing the orthogonal
        // merge R·W never costs (appreciably) more than re-quantizing
        // the additive merge W + AB, and on average costs less — the
        // low-rank update concentrates energy into range-inflating
        // outliers while the rotation spreads it.
        // (sum of LoRA rms, sum of RW rms, cases) across the sweep
        let acc = std::cell::RefCell::new((0.0f64, 0.0f64, 0usize));
        testkit::check("RW requant error <= LoRA additive baseline", 25, |g| {
            let din = *g.choose(&[64usize, 128, 256]);
            let dout = *g.choose(&[64usize, 128]);
            let b = *g.choose(&[16usize, 32]);
            let strength = g.f32_in(0.01, 0.08);
            let mut rng = Rng::new(g.rng.next_u64());
            let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
            let oft = OftAdapter::random(din, b, 6, strength, &mut rng);
            let lora = LoraAdapter::random(din, dout, 16, 32.0, strength, &mut rng);

            // match adaptation strength: rescale the LoRA delta to the
            // OFT delta's Frobenius norm before merging
            let d_oft = oft
                .merge(&w)
                .and_then(|m| m.sub(&w))
                .map_err(|e| e.to_string())?;
            let d_lora_raw = lora.delta().map_err(|e| e.to_string())?;
            let s = d_oft.fro_norm() / d_lora_raw.fro_norm().max(1e-12);
            let merged_lora = w.add(&d_lora_raw.scale(s)).map_err(|e| e.to_string())?;
            let merged_oft = w.add(&d_oft).map_err(|e| e.to_string())?;

            let rq = |m: &Tensor| err_stats(&Nf4Tensor::quantize(m).dequantize(), m);
            let e_lora = rq(&merged_lora).rms;
            let e_oft = rq(&merged_oft).rms;
            // per-case: orthogonal merge never appreciably worse
            if e_oft > e_lora * 1.15 + 1e-6 {
                return Err(format!(
                    "RW rms {e_oft:.6} exceeds LoRA rms {e_lora:.6} (din={din}, b={b})"
                ));
            }
            let mut a = acc.borrow_mut();
            a.0 += e_lora;
            a.1 += e_oft;
            a.2 += 1;
            Ok(())
        });
        let (sum_lora, sum_oft, cases) = *acc.borrow();
        assert!(cases > 0);
        assert!(
            sum_oft <= sum_lora * 1.02,
            "mean RW requant rms {sum_oft} above LoRA baseline {sum_lora}"
        );
    }
}
