//! Static HLO cost analysis: parse HLO text into an op histogram and a
//! FLOP/byte estimate — the L2 profiling tool the perf pass uses
//! (DESIGN.md §7: "JAX tracer / HLO cost analysis on the lowered
//! module") and the `repro inspect` subcommand exposes.
//!
//! Coverage is deliberately the 95% that matters for transformers:
//! `dot` contributes 2·M·N·K FLOPs, elementwise/reduce ops contribute
//! one FLOP per output element, and every instruction contributes its
//! output bytes to the traffic estimate. Fusion is invisible in
//! pre-optimization HLO text, so treat numbers as *upper bounds* on
//! memory traffic and *exact* for matmul FLOPs.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

/// Aggregate cost summary of one HLO module.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HloCost {
    /// Instruction count per opcode.
    pub ops: BTreeMap<String, usize>,
    /// 2·M·N·K summed over all `dot` instructions.
    pub dot_flops: u64,
    /// One per output element over non-dot compute ops.
    pub elementwise_flops: u64,
    /// Sum of output-buffer bytes over all instructions.
    pub output_bytes: u64,
}

impl HloCost {
    pub fn total_flops(&self) -> u64 {
        self.dot_flops + self.elementwise_flops
    }

    /// Arithmetic intensity (FLOPs per byte of instruction output) —
    /// the roofline x-axis.
    pub fn intensity(&self) -> f64 {
        self.total_flops() as f64 / (self.output_bytes.max(1)) as f64
    }

    /// The opcodes with the most instructions, descending.
    pub fn top_ops(&self, n: usize) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self.ops.clone().into_iter().collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v.truncate(n);
        v
    }
}

/// A parsed `f32[128,256]{1,0}`-style shape: dtype + dims.
#[derive(Clone, Debug, PartialEq)]
struct ShapeInfo {
    dtype: String,
    dims: Vec<u64>,
}

impl ShapeInfo {
    fn elements(&self) -> u64 {
        self.dims.iter().product::<u64>().max(1)
    }

    fn bytes(&self) -> u64 {
        let per = match self.dtype.as_str() {
            "f64" | "s64" | "u64" | "c64" => 8,
            "f32" | "s32" | "u32" => 4,
            "f16" | "bf16" | "s16" | "u16" => 2,
            "pred" | "s8" | "u8" => 1,
            _ => 4,
        };
        self.elements() * per
    }
}

/// Parse `dtype[d0,d1,...]` from the start of `s`.
fn parse_shape(s: &str) -> Option<ShapeInfo> {
    let open = s.find('[')?;
    let dtype = s[..open].trim().to_string();
    if dtype.is_empty() || !dtype.chars().all(|c| c.is_ascii_alphanumeric()) {
        return None;
    }
    let close = s[open..].find(']')? + open;
    let inner = &s[open + 1..close];
    let dims = if inner.trim().is_empty() {
        vec![]
    } else {
        inner
            .split(',')
            .map(|d| d.trim().parse::<u64>().ok())
            .collect::<Option<Vec<_>>>()?
    };
    Some(ShapeInfo { dtype, dims })
}

/// Opcodes counted as one-FLOP-per-element compute.
const ELEMENTWISE: &[&str] = &[
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential", "log",
    "rsqrt", "sqrt", "power", "tanh", "negate", "select", "compare", "convert", "reduce",
    "and", "or", "xor",
];

/// Analyze one HLO-text module.
pub fn analyze(text: &str) -> HloCost {
    let mut cost = HloCost::default();
    for line in text.lines() {
        let line = line.trim();
        // instruction lines look like: `%name = f32[..]{..} opcode(...)`
        let Some(eq) = line.find(" = ") else { continue };
        let rhs = &line[eq + 3..];
        let Some(shape) = parse_shape(rhs) else { continue };
        // opcode comes after the shape spec (and optional layout `{..}`)
        let after_shape = &rhs[rhs.find(']').map(|i| i + 1).unwrap_or(0)..];
        let after_layout = after_shape
            .trim_start()
            .trim_start_matches(|c| c == '{' || c == '}' || c == ',' || char::is_numeric(c));
        let opcode: String = after_layout
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
            .collect();
        if opcode.is_empty() || opcode == "parameter" {
            continue;
        }
        *cost.ops.entry(opcode.clone()).or_insert(0) += 1;
        cost.output_bytes += shape.bytes();

        if opcode == "dot" {
            // FLOPs = 2 * output_elems * K; K from lhs contracting dim
            let k = dot_contraction_size(rhs).unwrap_or(1);
            cost.dot_flops += 2 * shape.elements() * k;
        } else if ELEMENTWISE.contains(&opcode.as_str()) {
            cost.elementwise_flops += shape.elements();
        }
    }
    cost
}

/// For a dot instruction line, extract the contracted-dimension size
/// from the lhs operand's shape + `lhs_contracting_dims={i}`.
fn dot_contraction_size(rhs: &str) -> Option<u64> {
    let open = rhs.find('(')?;
    let args = &rhs[open + 1..];
    // first operand shape, e.g. `f32[16,16]{1,0} %x` or `dot(add.1, ...)`
    // in full HLO text operands are `f32[16,16]{1,0} name`; find the
    // first shape in the argument list.
    let lhs_shape = parse_shape(args.trim_start())?;
    let idx_key = "lhs_contracting_dims={";
    let at = rhs.find(idx_key)? + idx_key.len();
    let end = rhs[at..].find('}')? + at;
    let dim: usize = rhs[at..end].split(',').next()?.trim().parse().ok()?;
    lhs_shape.dims.get(dim).copied()
}

/// Analyze an artifact file.
pub fn analyze_file(path: impl AsRef<Path>) -> Result<HloCost> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    Ok(analyze(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_f, entry_computation_layout={(f32[2,4]{1,0})->(f32[2,8]{1,0})}

ENTRY main {
  Arg_0.1 = f32[2,4]{1,0} parameter(0)
  constant.1 = f32[4,8]{1,0} constant({...})
  dot.1 = f32[2,8]{1,0} dot(f32[2,4]{1,0} Arg_0.1, f32[4,8]{1,0} constant.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  add.1 = f32[2,8]{1,0} add(dot.1, dot.1)
  ROOT tuple.1 = (f32[2,8]{1,0}) tuple(add.1)
}
"#;

    #[test]
    fn counts_ops_and_flops() {
        let c = analyze(SAMPLE);
        assert_eq!(c.ops.get("dot"), Some(&1));
        assert_eq!(c.ops.get("add"), Some(&1));
        assert_eq!(c.ops.get("parameter"), None);
        // dot: 2 * (2*8) * 4 = 128 FLOPs
        assert_eq!(c.dot_flops, 128);
        assert_eq!(c.elementwise_flops, 16);
        assert_eq!(c.total_flops(), 144);
        assert!(c.output_bytes > 0);
    }

    #[test]
    fn shape_parsing() {
        let s = parse_shape("f32[128,256]{1,0} dot(...)").unwrap();
        assert_eq!(s.dims, vec![128, 256]);
        assert_eq!(s.bytes(), 128 * 256 * 4);
        let s = parse_shape("pred[] parameter(0)").unwrap();
        assert_eq!(s.elements(), 1);
        assert_eq!(s.bytes(), 1);
        assert!(parse_shape("no shape here").is_none());
    }

    #[test]
    fn top_ops_ordering() {
        let c = analyze(SAMPLE);
        let top = c.top_ops(2);
        assert_eq!(top.len(), 2);
        assert!(top[0].1 >= top[1].1);
    }

    #[test]
    fn real_artifact_has_dots() {
        let path = crate::artifacts_root().join("tiny_oft_v2/train_step.hlo.txt");
        if !path.exists() {
            return;
        }
        let c = analyze_file(path).unwrap();
        assert!(c.dot_flops > 1_000_000, "train step should be GEMM-heavy");
        assert!(c.ops.get("dot").copied().unwrap_or(0) > 10);
        // pre-fusion HLO inflates output bytes, so intensity is a
        // lower bound; it should still be clearly non-trivial
        assert!(c.intensity() > 0.05, "intensity {}", c.intensity());
    }

    #[test]
    fn merge_graph_costs_more_than_rotate() {
        // The §3.2 claim, statically: the weight-centric micro kernel
        // carries more dot FLOPs than the input-centric one at equal d.
        let root = crate::artifacts_root().join("micro");
        let (m, r) = (
            root.join("merge_w_d1024.hlo.txt"),
            root.join("rotate_w_d1024.hlo.txt"),
        );
        if !m.exists() || !r.exists() {
            return;
        }
        let cm = analyze_file(m).unwrap();
        let cr = analyze_file(r).unwrap();
        assert!(
            cm.dot_flops > 2 * cr.dot_flops,
            "merge {} vs rotate {}",
            cm.dot_flops,
            cr.dot_flops
        );
    }
}
