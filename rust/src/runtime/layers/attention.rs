//! Causal multi-head attention.

use crate::tensor::Tensor;

/// Attention over already-projected q/k/v planes. Takes three inputs,
/// so it keeps the layer forward/backward shape with a bespoke
/// signature instead of implementing the single-input [`super::Layer`]
/// trait.
pub struct Attention {
    pub n_heads: usize,
}

/// The q/k/v planes (moved in, not cloned) plus the softmax
/// probabilities the backward reuses.
pub struct AttentionAct {
    pub q: Tensor,
    pub k: Tensor,
    pub v: Tensor,
    /// (bsz, heads, T, T) flattened; future positions exactly zero.
    pub att: Vec<f32>,
}

impl Attention {
    pub fn new(n_heads: usize) -> Attention {
        Attention { n_heads }
    }

    pub fn forward(
        &self,
        q: Tensor,
        k: Tensor,
        v: Tensor,
        bsz: usize,
        t: usize,
    ) -> (Tensor, AttentionAct) {
        let h = self.n_heads;
        let hd = q.shape[1] / h;
        let (o, att) = attention_fwd(&q, &k, &v, bsz, t, h, hd);
        (o, AttentionAct { q, k, v, att })
    }

    /// Returns (dq, dk, dv).
    pub fn backward(
        &self,
        act: &AttentionAct,
        do_: &Tensor,
        bsz: usize,
        t: usize,
    ) -> (Tensor, Tensor, Tensor) {
        let h = self.n_heads;
        let hd = act.q.shape[1] / h;
        attention_bwd(&act.q, &act.k, &act.v, &act.att, do_, bsz, t, h, hd)
    }
}

/// Causal multi-head attention forward. Returns (output (M, D), softmax
/// probabilities (bsz*h*t*t, future positions exactly zero)).
pub fn attention_fwd(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    bsz: usize,
    t: usize,
    h: usize,
    hd: usize,
) -> (Tensor, Vec<f32>) {
    let d = h * hd;
    let m = bsz * t;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut att = vec![0f32; bsz * h * t * t];
    let mut o = Tensor::zeros(&[m, d]);
    for b in 0..bsz {
        for hh in 0..h {
            for t1 in 0..t {
                let qoff = (b * t + t1) * d + hh * hd;
                let mut row = vec![0f32; t1 + 1];
                let mut maxv = f32::NEG_INFINITY;
                for (t2, rv) in row.iter_mut().enumerate() {
                    let koff = (b * t + t2) * d + hh * hd;
                    let mut acc = 0f32;
                    for c in 0..hd {
                        acc += q.data[qoff + c] * k.data[koff + c];
                    }
                    *rv = acc * scale;
                    maxv = maxv.max(*rv);
                }
                let mut sum = 0f32;
                for rv in &mut row {
                    *rv = (*rv - maxv).exp();
                    sum += *rv;
                }
                let abase = ((b * h + hh) * t + t1) * t;
                let ooff = (b * t + t1) * d + hh * hd;
                for (t2, rv) in row.iter().enumerate() {
                    let a = rv / sum;
                    att[abase + t2] = a;
                    let voff = (b * t + t2) * d + hh * hd;
                    for c in 0..hd {
                        o.data[ooff + c] += a * v.data[voff + c];
                    }
                }
            }
        }
    }
    (o, att)
}

/// Causal attention backward: returns (dq, dk, dv).
pub fn attention_bwd(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    att: &[f32],
    do_: &Tensor,
    bsz: usize,
    t: usize,
    h: usize,
    hd: usize,
) -> (Tensor, Tensor, Tensor) {
    let d = h * hd;
    let m = bsz * t;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dq = Tensor::zeros(&[m, d]);
    let mut dk = Tensor::zeros(&[m, d]);
    let mut dv = Tensor::zeros(&[m, d]);
    for b in 0..bsz {
        for hh in 0..h {
            for t1 in 0..t {
                let abase = ((b * h + hh) * t + t1) * t;
                let ooff = (b * t + t1) * d + hh * hd;
                let mut dpost = vec![0f32; t1 + 1];
                for (t2, dp) in dpost.iter_mut().enumerate() {
                    let voff = (b * t + t2) * d + hh * hd;
                    let a = att[abase + t2];
                    let mut acc = 0f32;
                    for c in 0..hd {
                        let g = do_.data[ooff + c];
                        acc += g * v.data[voff + c];
                        dv.data[voff + c] += a * g;
                    }
                    *dp = acc;
                }
                let mut dot = 0f32;
                for (t2, dp) in dpost.iter().enumerate() {
                    dot += dp * att[abase + t2];
                }
                let qoff = ooff;
                for (t2, dp) in dpost.iter().enumerate() {
                    let da = att[abase + t2] * (dp - dot) * scale;
                    if da == 0.0 {
                        continue;
                    }
                    let koff = (b * t + t2) * d + hh * hd;
                    for c in 0..hd {
                        dq.data[qoff + c] += da * k.data[koff + c];
                        dk.data[koff + c] += da * q.data[qoff + c];
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}
