//! One transformer block: attention branch + MLP branch with residual
//! adds, composed from the per-layer objects.

use anyhow::Result;

use super::attention::{Attention, AttentionAct};
use super::linear::{LinearAct, PeftLinear};
use super::mlp::{Mlp, MlpAct};
use super::rmsnorm::{RmsNorm, RmsNormAct};
use super::{Ctx, Gradients, Layer};
use crate::tensor::Tensor;

pub struct TransformerBlock {
    pub attn_norm: RmsNorm,
    pub wq: PeftLinear,
    pub wk: PeftLinear,
    pub wv: PeftLinear,
    pub wo: PeftLinear,
    pub attn: Attention,
    pub mlp: Mlp,
}

/// Activation records of one block, in sub-layer order. The residual
/// skip paths need no saved tensors (their backward is the identity);
/// the block input lives inside the attention norm's record.
pub struct BlockAct {
    pub norm1: RmsNormAct,
    pub cq: LinearAct,
    pub ck: LinearAct,
    pub cv: LinearAct,
    pub attn: AttentionAct,
    pub co: LinearAct,
    pub mlp: MlpAct,
}

impl TransformerBlock {
    pub fn new(prefix: &str, n_heads: usize) -> TransformerBlock {
        TransformerBlock {
            attn_norm: RmsNorm::new(&format!("{prefix}.attn.norm")),
            wq: PeftLinear::new(&format!("{prefix}.attn.wq")),
            wk: PeftLinear::new(&format!("{prefix}.attn.wk")),
            wv: PeftLinear::new(&format!("{prefix}.attn.wv")),
            wo: PeftLinear::new(&format!("{prefix}.attn.wo")),
            attn: Attention::new(n_heads),
            mlp: Mlp::new(prefix),
        }
    }

    pub fn forward(&self, ctx: &Ctx, x: &Tensor, bsz: usize) -> Result<(Tensor, BlockAct)> {
        let t = ctx.dims.seq_len;
        let (xn1, norm1) = self.attn_norm.forward(ctx, x)?;
        let (q, cq) = self.wq.forward(ctx, &xn1)?;
        let (k, ck) = self.wk.forward(ctx, &xn1)?;
        let (v, cv) = self.wv.forward(ctx, &xn1)?;
        let (o, attn) = self.attn.forward(q, k, v, bsz, t);
        let (ywo, co) = self.wo.forward(ctx, &o)?;
        let x_mid = x.add(&ywo)?;
        let (ydown, mlp) = self.mlp.forward(ctx, &x_mid)?;
        let out = x_mid.add(&ydown)?;
        Ok((
            out,
            BlockAct {
                norm1,
                cq,
                ck,
                cv,
                attn,
                co,
                mlp,
            },
        ))
    }

    pub fn backward(
        &self,
        ctx: &Ctx,
        act: &BlockAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let t = ctx.dims.seq_len;
        let bsz = dy.shape[0] / t;
        let dxmid = dy.add(&self.mlp.backward(ctx, &act.mlp, dy, grads)?)?;
        let do_ = self.wo.backward(ctx, &act.co, &dxmid, grads)?;
        let (dq, dk, dv) = self.attn.backward(&act.attn, &do_, bsz, t);
        let dxn1 = self
            .wq
            .backward(ctx, &act.cq, &dq, grads)?
            .add(&self.wk.backward(ctx, &act.ck, &dk, grads)?)?
            .add(&self.wv.backward(ctx, &act.cv, &dv, grads)?)?;
        let dxin_n = self.attn_norm.backward(ctx, &act.norm1, &dxn1, grads)?;
        dxmid.add(&dxin_n)
    }
}
