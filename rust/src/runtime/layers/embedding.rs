//! Token + learned positional embedding (`embed.tok` / `embed.pos`).

use anyhow::{ensure, Result};

use super::{accumulate, Ctx, Gradients};
use crate::tensor::Tensor;

/// The embedding lookup. Its "activation record" is just the input ids,
/// which the tape stores anyway, so forward/backward take them
/// directly instead of a record struct.
pub struct Embedding;

impl Embedding {
    pub fn new() -> Embedding {
        Embedding
    }

    /// ids (bsz * T) -> x (bsz * T, D): token embedding + positional
    /// embedding at `row % T`.
    pub fn forward(&self, ctx: &Ctx, input_ids: &[i32], bsz: usize) -> Result<Tensor> {
        let d = ctx.dims.d_model;
        let t = ctx.dims.seq_len;
        let vocab = ctx.dims.vocab;
        let m = bsz * t;
        ensure!(input_ids.len() == m, "input ids length mismatch");
        let tok_emb = ctx.params.get("embed.tok")?;
        let pos_emb = ctx.params.get("embed.pos")?;
        let mut x = Tensor::zeros(&[m, d]);
        for (row, &id) in input_ids.iter().enumerate() {
            ensure!((id as usize) < vocab, "token id {id} out of vocab {vocab}");
            let tpos = row % t;
            let dst = &mut x.data[row * d..(row + 1) * d];
            let te = &tok_emb.data[id as usize * d..(id as usize + 1) * d];
            let pe = &pos_emb.data[tpos * d..(tpos + 1) * d];
            for j in 0..d {
                dst[j] = te[j] + pe[j];
            }
        }
        Ok(x)
    }

    /// Scatter `dx` back into the embedding tables (only the `full`
    /// method trains them).
    pub fn backward(
        &self,
        ctx: &Ctx,
        input_ids: &[i32],
        dx: &Tensor,
        grads: &mut Gradients,
    ) -> Result<()> {
        if !ctx.adapter.trains_base() {
            return Ok(());
        }
        let d = ctx.dims.d_model;
        let t = ctx.dims.seq_len;
        let vocab = ctx.dims.vocab;
        let mut dtok = Tensor::zeros(&[vocab, d]);
        let mut dpos = Tensor::zeros(&[t, d]);
        for (row, &id) in input_ids.iter().enumerate() {
            let tpos = row % t;
            let src = &dx.data[row * d..(row + 1) * d];
            let te = &mut dtok.data[id as usize * d..(id as usize + 1) * d];
            for j in 0..d {
                te[j] += src[j];
            }
            let pe = &mut dpos.data[tpos * d..(tpos + 1) * d];
            for j in 0..d {
                pe[j] += src[j];
            }
        }
        accumulate(grads, "embed.tok", dtok);
        accumulate(grads, "embed.pos", dpos);
        Ok(())
    }
}

impl Default for Embedding {
    fn default() -> Self {
        Embedding::new()
    }
}
