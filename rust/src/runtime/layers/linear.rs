//! The PEFT-adapted linear: resolves its base weight by name and hands
//! the method-specific work to the context's registered
//! [`crate::adapters::Adapter`] — the arms that used to be matched
//! here live in each method's own module. This file keeps the CNP
//! block kernels the OFT-family adapters (and the decode path and
//! micro kernels) share.

use std::any::Any;

use anyhow::{ensure, Context, Result};

use super::{Ctx, Gradients, Layer};
use crate::peft;
use crate::tensor::Tensor;

/// One adapted linear, resolving its base weight (and any adapter
/// parameters) by name from the context's parameter map.
pub struct PeftLinear {
    pub name: String,
}

/// Activation record of one adapted linear: the saved input plus the
/// owning adapter's extras (downcast by that adapter's backward).
/// Parameters are *not* copied here — backward re-reads them from the
/// parameter map, and shared per-step state lives in the
/// [`super::AdapterPlan`]; records only own what was derived inline.
pub struct LinearAct {
    pub x: Tensor,
    pub extra: Option<Box<dyn Any + Send>>,
}

impl LinearAct {
    /// The adapter's extras, downcast to its record type.
    pub fn extra<T: 'static>(&self) -> Result<&T> {
        self.extra
            .as_ref()
            .and_then(|e| e.downcast_ref::<T>())
            .context("missing or mistyped adapter activation record")
    }
}

impl PeftLinear {
    pub fn new(name: &str) -> PeftLinear {
        PeftLinear { name: name.into() }
    }
}

impl Layer for PeftLinear {
    type Act = LinearAct;

    fn forward(&self, ctx: &Ctx, x: &Tensor) -> Result<(Tensor, LinearAct)> {
        // Packed (quantized) bases multiply through the fused
        // block-dequant kernels; dense bases through Tensor::matmul.
        let w = ctx.params.weight(&self.name)?;
        // Scenario targeting / module dropout: deselected linears run
        // the frozen base path (identity adapter) with no extras, so
        // no adapter grads accumulate for them this pass.
        if !ctx.adapts(&self.name) {
            return Ok((w.matmul(x)?, LinearAct { x: x.clone(), extra: None }));
        }
        let (y, extra) = ctx.adapter.linear_forward(ctx, &self.name, w, x)?;
        Ok((y, LinearAct { x: x.clone(), extra }))
    }

    /// Accumulates parameter grads and returns d(loss)/d(input).
    fn backward(
        &self,
        ctx: &Ctx,
        act: &LinearAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let w = ctx.params.weight(&self.name)?;
        if !ctx.adapts(&self.name) {
            return w.matmul_t(dy);
        }
        ctx.adapter
            .linear_backward(ctx, &self.name, w, act, dy, grads)
    }
}

// ---------------------------------------------------------------------------
// CNP / block-rotation kernels (shared with the decode path and the
// reference engine's micro kernels)
// ---------------------------------------------------------------------------

/// Build all CNP blocks R_i = (I+Q_i)(I + sum Q_i^j) from packed rows.
pub fn build_cnp_blocks(packed: &Tensor, b: usize, k: usize) -> Result<Vec<Tensor>> {
    let p = peft::packed_dim(b);
    ensure!(
        packed.shape.len() == 2 && packed.shape[1] == p,
        "packed Q must be (nb, {p}), got {:?}",
        packed.shape
    );
    let nb = packed.shape[0];
    let mut out = Vec::with_capacity(nb);
    for i in 0..nb {
        out.push(peft::cayley_neumann(&packed.data[i * p..(i + 1) * p], b, k)?);
    }
    Ok(out)
}

/// Fused block rotation y[:, ib:(i+1)b] = x[:, ib:(i+1)b] @ R_i — one
/// pass over x, parallel over rows (the OFTv2 hot path).
pub fn block_rotate_fast(x: &Tensor, blocks: &[Tensor]) -> Result<Tensor> {
    ensure!(x.rank() == 2, "block_rotate_fast needs 2-D input");
    let (m, d) = (x.shape[0], x.shape[1]);
    ensure!(!blocks.is_empty(), "no rotation blocks");
    let b = blocks[0].shape[0];
    ensure!(blocks.len() * b == d, "blocks {}x{b} vs d={d}", blocks.len());
    // One dispatch decision per call; equivalence contract vs the
    // scalar loop is <= 1e-5 rel (FMA + lane blocking reassociate the
    // b-term contraction).
    let fast = crate::tensor::simd_kernels_active();
    let mut out = vec![0f32; m * d];
    crate::tensor::parallel_over_rows(&mut out, m, d, |row, dst| {
        let src = &x.data[row * d..(row + 1) * d];
        for (bi, blk) in blocks.iter().enumerate() {
            let xoff = bi * b;
            if fast {
                // dst starts zeroed and each block span is written by
                // exactly one worker, so accumulate == assign.
                crate::tensor::simd::fma_row_block(
                    &mut dst[xoff..xoff + b],
                    &src[xoff..xoff + b],
                    &blk.data,
                    b,
                );
            } else {
                for j in 0..b {
                    let mut acc = 0f32;
                    for i in 0..b {
                        acc += src[xoff + i] * blk.data[i * b + j];
                    }
                    dst[xoff + j] = acc;
                }
            }
        }
    });
    Ok(Tensor::from_vec(&[m, d], out))
}

/// Rotate by the transposed blocks (the backward direction dz @ R^T).
pub fn block_rotate_transposed(dz: &Tensor, blocks: &[Tensor]) -> Result<Tensor> {
    let (m, d) = (dz.shape[0], dz.shape[1]);
    let b = blocks[0].shape[0];
    ensure!(blocks.len() * b == d, "blocks {}x{b} vs d={d}", blocks.len());
    let fast = crate::tensor::simd_kernels_active();
    // For the SIMD path, transpose each (small) block once up front so
    // dz @ R^T runs through the same row-major `fma_row_block`
    // microkernel as the forward — amortized over all m rows.
    let tblocks: Vec<Vec<f32>> = if fast {
        blocks
            .iter()
            .map(|blk| {
                let mut t = vec![0f32; b * b];
                for i in 0..b {
                    for j in 0..b {
                        t[j * b + i] = blk.data[i * b + j];
                    }
                }
                t
            })
            .collect()
    } else {
        Vec::new()
    };
    let mut out = vec![0f32; m * d];
    crate::tensor::parallel_over_rows(&mut out, m, d, |row, dst| {
        let src = &dz.data[row * d..(row + 1) * d];
        if fast {
            for (bi, tblk) in tblocks.iter().enumerate() {
                let off = bi * b;
                crate::tensor::simd::fma_row_block(
                    &mut dst[off..off + b],
                    &src[off..off + b],
                    tblk,
                    b,
                );
            }
        } else {
            for (bi, blk) in blocks.iter().enumerate() {
                let off = bi * b;
                for i in 0..b {
                    let mut acc = 0f32;
                    for j in 0..b {
                        acc += src[off + j] * blk.data[i * b + j];
                    }
                    dst[off + i] = acc;
                }
            }
        }
    });
    Ok(Tensor::from_vec(&[m, d], out))
}

/// dR_i = x_i^T @ dz_i summed over rows; returns one (b, b) per block.
///
/// Stays scalar in both dispatch modes: the inner j-loop is already
/// branch-free (the `xi == 0.0` skip is per-outer-i, so it doesn't
/// block autovectorization), and keeping one implementation preserves
/// bitwise-identical gradients across feature flags.
pub fn block_rotate_grad_r(x: &Tensor, dz: &Tensor, b: usize) -> Vec<Tensor> {
    let (m, d) = (x.shape[0], x.shape[1]);
    let nb = d / b;
    let mut dr: Vec<Tensor> = (0..nb).map(|_| Tensor::zeros(&[b, b])).collect();
    for row in 0..m {
        let xr = &x.data[row * d..(row + 1) * d];
        let dzr = &dz.data[row * d..(row + 1) * d];
        for (bi, g) in dr.iter_mut().enumerate() {
            let off = bi * b;
            for i in 0..b {
                let xi = xr[off + i];
                if xi == 0.0 {
                    continue;
                }
                let grow = &mut g.data[i * b..(i + 1) * b];
                for j in 0..b {
                    grow[j] += xi * dzr[off + j];
                }
            }
        }
    }
    dr
}

/// d(loss)/d(packed) for one CNP block, given G = d(loss)/dR.
///
/// R = (I+Q) S with S = sum_{i=0..k} Q^i:
///   dQ = G S^T + sum_{i=1..k} sum_{j=0..i-1} (Q^T)^j H (Q^T)^{i-1-j},
/// with H = (I+Q)^T G; then project onto the packed skew coordinates
/// (dp_ij = dQ_ij - dQ_ji for i < j). Locked against jax.grad by
/// python/tests/test_ref_backward.py::test_cnp_backward_matches_jax.
pub fn cnp_backward(packed: &[f32], b: usize, k: usize, g: &Tensor) -> Result<Vec<f32>> {
    let q = peft::skew_from_packed(packed, b);
    let eye = Tensor::eye(b);
    let mut acc = eye.clone();
    let mut term = eye.clone();
    for _ in 0..k {
        term = term.matmul(&q)?;
        acc = acc.add(&term)?;
    }
    let mut dq = g.matmul(&acc.transpose2())?;
    let h = eye.add(&q)?.transpose2().matmul(g)?;
    let qt = q.transpose2();
    let mut powers = vec![eye];
    for _ in 1..k.max(1) {
        let next = powers.last().unwrap().matmul(&qt)?;
        powers.push(next);
    }
    for i in 1..=k {
        for j in 0..i {
            let t = powers[j].matmul(&h)?.matmul(&powers[i - 1 - j])?;
            dq = dq.add(&t)?;
        }
    }
    let mut dp = vec![0f32; peft::packed_dim(b)];
    let mut idx = 0;
    for i in 0..b {
        for j in i + 1..b {
            dp[idx] = dq.at2(i, j) - dq.at2(j, i);
            idx += 1;
        }
    }
    Ok(dp)
}

/// CNP backward over all blocks; returns the (nb, p) packed gradient.
pub fn cnp_backward_all(packed: &Tensor, b: usize, k: usize, dr: &[Tensor]) -> Result<Tensor> {
    let p = peft::packed_dim(b);
    let nb = packed.shape[0];
    ensure!(dr.len() == nb, "expected {nb} block grads, got {}", dr.len());
    let mut out = vec![0f32; nb * p];
    for i in 0..nb {
        let dp = cnp_backward(&packed.data[i * p..(i + 1) * p], b, k, &dr[i])?;
        out[i * p..(i + 1) * p].copy_from_slice(&dp);
    }
    Ok(Tensor::from_vec(&[nb, p], out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rotate_fast_matches_naive_oracle() {
        let mut rng = Rng::new(9);
        let (m, b, nb) = (13, 8, 4);
        let d = b * nb;
        let packed = Tensor::randn(&[nb, peft::packed_dim(b)], 0.1, &mut rng);
        let blocks = build_cnp_blocks(&packed, b, 6).unwrap();
        let x = Tensor::randn(&[m, d], 1.0, &mut rng);
        let fast = block_rotate_fast(&x, &blocks).unwrap();
        let naive = peft::block_rotate(&x, &blocks).unwrap();
        assert!(fast.max_abs_diff(&naive) < 1e-5);
    }

    #[test]
    fn rotate_transposed_inverts_for_orthogonal_blocks() {
        // R^T is the inverse of an (approximately) orthogonal R.
        let mut rng = Rng::new(10);
        let (m, b, nb) = (6, 8, 2);
        let packed = Tensor::randn(&[nb, peft::packed_dim(b)], 0.02, &mut rng);
        let blocks = build_cnp_blocks(&packed, b, 8).unwrap();
        let x = Tensor::randn(&[m, b * nb], 1.0, &mut rng);
        let y = block_rotate_fast(&x, &blocks).unwrap();
        let back = block_rotate_transposed(&y, &blocks).unwrap();
        assert!(back.max_abs_diff(&x) < 1e-3, "{}", back.max_abs_diff(&x));
    }

    /// Worst per-row relative norm distortion of the CNP rotation over
    /// random inputs: |‖y_row‖ − ‖x_row‖| / ‖x_row‖.
    fn max_norm_err(b: usize, nb: usize, k: usize, q_std: f32, seed: u64) -> f32 {
        let mut rng = Rng::new(seed);
        let d = b * nb;
        let packed = Tensor::randn(&[nb, peft::packed_dim(b)], q_std, &mut rng);
        let blocks = build_cnp_blocks(&packed, b, k).unwrap();
        let m = 16usize;
        let x = Tensor::randn(&[m, d], 1.0, &mut rng);
        let y = block_rotate_fast(&x, &blocks).unwrap();
        let mut worst = 0f32;
        for row in 0..m {
            let xr = &x.data[row * d..(row + 1) * d];
            let yr = &y.data[row * d..(row + 1) * d];
            let nx = xr.iter().map(|v| v * v).sum::<f32>().sqrt();
            let ny = yr.iter().map(|v| v * v).sum::<f32>().sqrt();
            worst = worst.max((ny - nx).abs() / nx.max(1e-12));
        }
        worst
    }

    #[test]
    fn cnp_rotation_preserves_norm_across_blocks_and_terms() {
        // Property: a CNP rotation is orthogonal up to the Neumann
        // truncation error O(‖Q‖^{k+1}), so vector norms are preserved
        // to a k-dependent tolerance. At the paper's operating point
        // (small ‖Q‖ — adapters start at Q = 0 and stay small) the
        // documented tolerances are:
        //   k >= 6 : 1e-4   (effectively exact in f32)
        //   k >= 3 : 2e-3
        //   k >= 2 : 1e-2
        //   k == 1 : 5e-2   (graceful degradation, not collapse)
        let tol = |k: usize| -> f32 {
            match k {
                0 => unreachable!("k >= 1 in every bundle"),
                1 => 5e-2,
                2 => 1e-2,
                3..=5 => 2e-3,
                _ => 1e-4,
            }
        };
        for &b in &[4usize, 8, 16, 32] {
            for &k in &[1usize, 2, 3, 4, 6, 8] {
                for seed in 0..3u64 {
                    let err = max_norm_err(b, 64 / b.min(64), k, 0.02, 100 + seed);
                    assert!(
                        err < tol(k),
                        "b={b} k={k} seed={seed}: norm error {err} > {}",
                        tol(k)
                    );
                }
            }
        }
    }

    #[test]
    fn cnp_norm_error_shrinks_with_more_neumann_terms() {
        // Graceful degradation: truncating the series earlier costs
        // accuracy smoothly — more terms must never be (meaningfully)
        // worse, and the k=8 error must be orders of magnitude below
        // the k=1 error.
        for &b in &[8usize, 16] {
            let errs: Vec<f32> = [1usize, 2, 4, 8]
                .iter()
                .map(|&k| max_norm_err(b, 4, k, 0.05, 7))
                .collect();
            for w in errs.windows(2) {
                assert!(
                    w[1] <= w[0] * 1.5 + 1e-6,
                    "b={b}: error increased with more terms: {errs:?}"
                );
            }
            assert!(
                errs[3] < errs[0] / 50.0,
                "b={b}: k=8 ({}) should be far below k=1 ({})",
                errs[3],
                errs[0]
            );
        }
    }
}
