//! The LM head projection plus the masked-NLL loss head it feeds.

use anyhow::Result;

use super::{accumulate, Ctx, Gradients, Layer};
use crate::tensor::Tensor;

/// Final projection onto vocabulary logits.
pub struct LmHead {
    pub name: String,
}

pub struct LmHeadAct {
    /// Final-normed activations (M, D) — the head's input.
    pub xf: Tensor,
}

impl LmHead {
    pub fn new(name: &str) -> LmHead {
        LmHead { name: name.into() }
    }
}

impl Layer for LmHead {
    type Act = LmHeadAct;

    fn forward(&self, ctx: &Ctx, xf: &Tensor) -> Result<(Tensor, LmHeadAct)> {
        let head = ctx.params.get(&self.name)?;
        let logits = xf.matmul(head)?;
        Ok((logits, LmHeadAct { xf: xf.clone() }))
    }

    fn backward(
        &self,
        ctx: &Ctx,
        act: &LmHeadAct,
        dlogits: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let head = ctx.params.get(&self.name)?;
        if ctx.adapter.trains_base() {
            accumulate(grads, &self.name, act.xf.transpose2().matmul(dlogits)?);
        }
        dlogits.matmul(&head.transpose2())
    }
}

// ---------------------------------------------------------------------------
// Loss head
// ---------------------------------------------------------------------------

/// Split a (bsz, T+1) token plane into next-token (inputs, targets).
pub fn split_tokens(tokens: &[i32], bsz: usize, t: usize) -> (Vec<i32>, Vec<i32>) {
    let mut inputs = Vec::with_capacity(bsz * t);
    let mut targets = Vec::with_capacity(bsz * t);
    for b in 0..bsz {
        let row = &tokens[b * (t + 1)..(b + 1) * (t + 1)];
        inputs.extend_from_slice(&row[..t]);
        targets.extend_from_slice(&row[1..]);
    }
    (inputs, targets)
}

/// Per-row NLL over masked targets: returns (sum_nll, mask_count, logp).
pub fn nll_stats(logits: &Tensor, targets: &[i32], mask: &[f32]) -> (f32, f32, Tensor) {
    let m = logits.shape[0];
    let v = logits.shape[1];
    let mut logp = Tensor::zeros(&[m, v]);
    let mut sum_nll = 0f32;
    let mut count = 0f32;
    for row in 0..m {
        let lr = &logits.data[row * v..(row + 1) * v];
        let maxv = lr.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
        let mut sum = 0f32;
        for &x in lr {
            sum += (x - maxv).exp();
        }
        let lse = maxv + sum.ln();
        let out = &mut logp.data[row * v..(row + 1) * v];
        for j in 0..v {
            out[j] = lr[j] - lse;
        }
        sum_nll += -out[targets[row] as usize] * mask[row];
        count += mask[row];
    }
    (sum_nll, count, logp)
}

/// d(loss)/d(logits) for mean masked NLL: (softmax - onehot) * mask /
/// count, with `inv_count` = 1 / count supplied by the caller (the
/// count is global across microbatches).
pub fn nll_dlogits(logp: &Tensor, targets: &[i32], mask: &[f32], inv_count: f32) -> Tensor {
    let m = logp.shape[0];
    let v = logp.shape[1];
    let mut dlogits = Tensor::zeros(&[m, v]);
    for row in 0..m {
        let scale = mask[row] * inv_count;
        if scale == 0.0 {
            continue;
        }
        let lp = &logp.data[row * v..(row + 1) * v];
        let dl = &mut dlogits.data[row * v..(row + 1) * v];
        for j in 0..v {
            dl[j] = lp[j].exp() * scale;
        }
        dl[targets[row] as usize] -= scale;
    }
    dlogits
}
