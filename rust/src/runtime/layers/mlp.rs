//! The feed-forward branch: norm → up-projection → GELU → down.

use anyhow::Result;

use super::linear::{LinearAct, PeftLinear};
use super::rmsnorm::{RmsNorm, RmsNormAct};
use super::{Ctx, Gradients, Layer};
use crate::tensor::Tensor;

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const GELU_A: f32 = 0.044715;

/// Tanh-approximate GELU (JAX's default `approximate=True`).
pub struct Gelu;

pub struct GeluAct {
    /// Pre-activation input (the up-projection output).
    pub x: Tensor,
}

impl Layer for Gelu {
    type Act = GeluAct;

    fn forward(&self, _ctx: &Ctx, x: &Tensor) -> Result<(Tensor, GeluAct)> {
        Ok((gelu_fwd(x), GeluAct { x: x.clone() }))
    }

    fn backward(
        &self,
        _ctx: &Ctx,
        act: &GeluAct,
        dy: &Tensor,
        _grads: &mut Gradients,
    ) -> Result<Tensor> {
        Ok(gelu_bwd(&act.x, dy))
    }
}

/// The full MLP branch of one block (residual add stays in the block).
pub struct Mlp {
    pub norm: RmsNorm,
    pub up: PeftLinear,
    pub act: Gelu,
    pub down: PeftLinear,
}

pub struct MlpAct {
    pub norm: RmsNormAct,
    pub up: LinearAct,
    pub gelu: GeluAct,
    pub down: LinearAct,
}

impl Mlp {
    pub fn new(prefix: &str) -> Mlp {
        Mlp {
            norm: RmsNorm::new(&format!("{prefix}.mlp.norm")),
            up: PeftLinear::new(&format!("{prefix}.mlp.up")),
            act: Gelu,
            down: PeftLinear::new(&format!("{prefix}.mlp.down")),
        }
    }
}

impl Layer for Mlp {
    type Act = MlpAct;

    fn forward(&self, ctx: &Ctx, x_mid: &Tensor) -> Result<(Tensor, MlpAct)> {
        let (xn, a_norm) = self.norm.forward(ctx, x_mid)?;
        let (up_pre, a_up) = self.up.forward(ctx, &xn)?;
        let (act, a_gelu) = self.act.forward(ctx, &up_pre)?;
        let (y, a_down) = self.down.forward(ctx, &act)?;
        Ok((
            y,
            MlpAct {
                norm: a_norm,
                up: a_up,
                gelu: a_gelu,
                down: a_down,
            },
        ))
    }

    /// Returns the branch's contribution to d(x_mid) (the caller adds
    /// the residual term).
    fn backward(
        &self,
        ctx: &Ctx,
        act: &MlpAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let dact = self.down.backward(ctx, &act.down, dy, grads)?;
        let dup = self.act.backward(ctx, &act.gelu, &dact, grads)?;
        let dxn = self.up.backward(ctx, &act.up, &dup, grads)?;
        self.norm.backward(ctx, &act.norm, &dxn, grads)
    }
}

/// Tanh-approximate GELU (JAX's default `approximate=True`).
pub fn gelu_fwd(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    for v in &mut y.data {
        let u = GELU_C * (*v + GELU_A * *v * *v * *v);
        *v = 0.5 * *v * (1.0 + u.tanh());
    }
    y
}

pub fn gelu_bwd(x: &Tensor, dy: &Tensor) -> Tensor {
    let mut dx = x.clone();
    for (v, &dyv) in dx.data.iter_mut().zip(&dy.data) {
        let xv = *v;
        let u = GELU_C * (xv + GELU_A * xv * xv * xv);
        let th = u.tanh();
        *v = dyv
            * (0.5 * (1.0 + th)
                + 0.5 * xv * (1.0 - th * th) * GELU_C * (1.0 + 3.0 * GELU_A * xv * xv));
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_matches_reference_points() {
        // gelu(0) = 0, gelu(large) ~ x, gelu(-large) ~ 0
        let x = Tensor::from_vec(&[4], vec![0.0, 5.0, -5.0, 1.0]);
        let y = gelu_fwd(&x);
        assert!(y.data[0].abs() < 1e-7);
        assert!((y.data[1] - 5.0).abs() < 1e-3);
        assert!(y.data[2].abs() < 1e-3);
        assert!((y.data[3] - 0.8412).abs() < 1e-3); // known value
    }
}
