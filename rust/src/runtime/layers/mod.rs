//! The reference model as an explicit layer stack over a forward tape.
//!
//! `refmodel.rs` used to run the whole decoder-only transformer as one
//! monolithic forward/backward pair. This module tree breaks it into
//! per-layer objects — [`embedding::Embedding`], [`rmsnorm::RmsNorm`],
//! [`attention::Attention`], the PEFT-adapted [`linear::PeftLinear`],
//! [`mlp::Mlp`]/[`mlp::Gelu`], and [`lmhead::LmHead`] — each with a
//! `forward` that returns its output plus an activation record, and a
//! `backward` that consumes that record and a cotangent. The records
//! collect into an explicit [`tape::Tape`], which is what makes
//! gradient checkpointing possible: a [`tape::CheckpointPolicy`] can
//! drop inner block records on the way forward and recompute them
//! (bitwise identically — every kernel is deterministic) during the
//! backward walk.
//!
//! Every gradient formula is the same 1:1 transcription of the JAX
//! model locked by `python/tests/test_ref_backward.py`; only the code
//! layout changed.

pub mod attention;
pub mod block;
pub mod embedding;
pub mod linear;
pub mod lmhead;
pub mod mlp;
pub mod rmsnorm;
pub mod tape;

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::refmodel::Method;
use crate::coordinator::manifest::ModelDims;
use crate::tensor::Tensor;

pub use self::tape::{CheckpointPolicy, Tape};

/// Name-keyed parameter map (trainables + frozen + dequantized bases).
pub struct Params {
    pub map: BTreeMap<String, Tensor>,
}

impl Params {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .with_context(|| format!("missing parameter '{name}'"))
    }
}

/// Name-keyed parameter gradients, summed across every use site.
pub type Gradients = BTreeMap<String, Tensor>;

/// Add `g` into `grads[name]` (elementwise; inserts on first use).
pub fn accumulate(grads: &mut Gradients, name: &str, g: Tensor) {
    match grads.get_mut(name) {
        Some(t) => {
            for (a, b) in t.data.iter_mut().zip(&g.data) {
                *a += b;
            }
        }
        None => {
            grads.insert(name.to_string(), g);
        }
    }
}

/// Per-step adapter state resolved once and shared read-only by every
/// microbatch (and worker thread) of a training step: CNP rotation
/// blocks per adapted linear, plus the merged `blockdiag(R) @ W` for
/// the weight-centric baseline. Without this, per-sequence
/// microbatching would re-pay the block build (and, for weight-centric
/// OFT, the cubic merge) once per sequence instead of once per step —
/// exactly the amortization real frameworks have.
#[derive(Default)]
pub struct AdapterPlan {
    /// Adapted-linear name -> CNP rotation blocks (OFT-family methods).
    pub blocks: BTreeMap<String, Vec<Tensor>>,
    /// Adapted-linear name -> merged weight (weight-centric OFT only).
    pub merged: BTreeMap<String, Tensor>,
}

/// Everything a layer needs besides its direct input: the resolved
/// parameter map, the bundle's dims and PEFT method, and the step's
/// shared [`AdapterPlan`] (absent for paths that resolve adapters
/// elsewhere, e.g. the decode models).
pub struct Ctx<'a> {
    pub params: &'a Params,
    pub dims: &'a ModelDims,
    pub method: Method,
    pub plan: Option<&'a AdapterPlan>,
}

/// The surface shared by the plain `x -> y` layers (RMSNorm, the PEFT
/// linear, GELU, the LM head). `forward` returns the output plus this
/// layer's activation record; `backward` consumes the record and the
/// output cotangent, accumulates parameter gradients, and returns the
/// input cotangent. Layers with a different arity (token embedding,
/// attention over q/k/v) keep the same forward/backward shape with
/// bespoke signatures.
pub trait Layer {
    type Act;
    fn forward(&self, ctx: &Ctx, x: &Tensor) -> Result<(Tensor, Self::Act)>;
    fn backward(
        &self,
        ctx: &Ctx,
        act: &Self::Act,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor>;
}

/// The decomposed reference model: embedding, N transformer blocks,
/// final norm, LM head. Built once per bundle; stateless apart from
/// the layer names it resolves against a [`Params`] map at run time.
pub struct LayerStack {
    pub embed: embedding::Embedding,
    pub blocks: Vec<block::TransformerBlock>,
    pub final_norm: rmsnorm::RmsNorm,
    pub head: lmhead::LmHead,
}

impl LayerStack {
    /// Layer objects for `dims` (names mirror the manifest contract).
    pub fn build(dims: &ModelDims) -> LayerStack {
        LayerStack {
            embed: embedding::Embedding::new(),
            blocks: (0..dims.n_layers)
                .map(|i| block::TransformerBlock::new(&format!("layers.{i}"), dims.n_heads))
                .collect(),
            final_norm: rmsnorm::RmsNorm::new("final_norm"),
            head: lmhead::LmHead::new("lm_head"),
        }
    }

    /// Full forward pass; the returned [`Tape`] holds what `policy`
    /// decided to keep (all block records for `CheckpointPolicy::None`,
    /// only segment-boundary inputs for `EveryK`).
    pub fn forward(
        &self,
        ctx: &Ctx,
        input_ids: &[i32],
        bsz: usize,
        policy: CheckpointPolicy,
    ) -> Result<Tape> {
        let mut x = self.embed.forward(ctx, input_ids, bsz)?;
        let mut boundaries = Vec::new();
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for (i, blk) in self.blocks.iter().enumerate() {
            match policy.every() {
                None => {
                    let (y, act) = blk.forward(ctx, &x, bsz)?;
                    blocks.push(Some(act));
                    x = y;
                }
                Some(k) => {
                    if i % k == 0 {
                        boundaries.push(x.clone());
                    }
                    // The record is dropped immediately: only the
                    // boundary inputs survive the forward pass.
                    let (y, _act) = blk.forward(ctx, &x, bsz)?;
                    blocks.push(None);
                    x = y;
                }
            }
        }
        let (xf, final_norm) = self.final_norm.forward(ctx, &x)?;
        let (logits, head) = self.head.forward(ctx, &xf)?;
        Ok(Tape {
            bsz,
            input_ids: input_ids.to_vec(),
            policy,
            boundaries,
            blocks,
            final_norm,
            head,
            logits,
        })
    }

    /// Backward pass over `tape`. Checkpointed segments are re-forwarded
    /// from their boundary input first — the recompute runs the exact
    /// deterministic kernels of the original forward, so the rebuilt
    /// records (and therefore every gradient) are bitwise identical to
    /// the non-checkpointed path.
    pub fn backward(&self, ctx: &Ctx, tape: &Tape, dlogits: &Tensor) -> Result<Gradients> {
        let mut grads = Gradients::new();
        let dxf = self.head.backward(ctx, &tape.head, dlogits, &mut grads)?;
        let mut dx = self
            .final_norm
            .backward(ctx, &tape.final_norm, &dxf, &mut grads)?;

        match tape.policy.every() {
            None => {
                for (blk, act) in self.blocks.iter().zip(&tape.blocks).rev() {
                    let act = act.as_ref().context("tape record missing")?;
                    dx = blk.backward(ctx, act, &dx, &mut grads)?;
                }
            }
            Some(k) => {
                let n = self.blocks.len();
                let n_segs = n.div_ceil(k);
                for seg in (0..n_segs).rev() {
                    let start = seg * k;
                    let end = (start + k).min(n);
                    // Recompute this segment's records from its
                    // checkpointed input.
                    let mut x = tape.boundaries[seg].clone();
                    let mut acts = Vec::with_capacity(end - start);
                    for blk in &self.blocks[start..end] {
                        let (y, act) = blk.forward(ctx, &x, tape.bsz)?;
                        acts.push(act);
                        x = y;
                    }
                    for (blk, act) in self.blocks[start..end].iter().zip(&acts).rev() {
                        dx = blk.backward(ctx, act, &dx, &mut grads)?;
                    }
                }
            }
        }

        self.embed.backward(ctx, &tape.input_ids, &dx, &mut grads)?;
        Ok(grads)
    }
}
