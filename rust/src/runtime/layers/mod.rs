//! The reference model as an explicit layer stack over a forward tape.
//!
//! `refmodel.rs` used to run the whole decoder-only transformer as one
//! monolithic forward/backward pair. This module tree breaks it into
//! per-layer objects — [`embedding::Embedding`], [`rmsnorm::RmsNorm`],
//! [`attention::Attention`], the PEFT-adapted [`linear::PeftLinear`],
//! [`mlp::Mlp`]/[`mlp::Gelu`], and [`lmhead::LmHead`] — each with a
//! `forward` that returns its output plus an activation record, and a
//! `backward` that consumes that record and a cotangent. The records
//! collect into an explicit [`tape::Tape`], which is what makes
//! gradient checkpointing possible: a [`tape::CheckpointPolicy`] can
//! drop inner block records on the way forward and recompute them
//! (bitwise identically — every kernel is deterministic) during the
//! backward walk.
//!
//! Every gradient formula is the same 1:1 transcription of the JAX
//! model locked by `python/tests/test_ref_backward.py`; only the code
//! layout changed.

pub mod attention;
pub mod block;
pub mod embedding;
pub mod linear;
pub mod lmhead;
pub mod mlp;
pub mod rmsnorm;
pub mod tape;

use std::any::Any;
use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::adapters::Adapter;
use crate::coordinator::manifest::ModelDims;
use crate::quant::QuantWeight;
use crate::tensor::Tensor;

pub use self::linear::LinearAct;
pub use self::tape::{CheckpointPolicy, Tape};

/// Name-keyed parameter map: dense f32 tensors (trainables, frozen
/// norms/embeddings, full-precision bases) plus *packed* quantized base
/// weights, which stay in their NF4/AWQ packs end-to-end.
pub struct Params {
    pub map: BTreeMap<String, Tensor>,
    /// Quantized base weights (QLoRA/QOFT), consumed by the fused
    /// block-dequant matmul kernels — never expanded to f32.
    pub quant: BTreeMap<String, QuantWeight>,
}

impl Params {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        if let Some(t) = self.map.get(name) {
            return Ok(t);
        }
        if self.quant.contains_key(name) {
            bail!(
                "parameter '{name}' is packed (quantized) and has no dense f32 form; \
                 use Params::weight for fused compute"
            );
        }
        bail!("missing parameter '{name}'")
    }

    /// The base weight under `name`, packed or dense — what the PEFT
    /// linear multiplies against, so quantized bases never need a
    /// dequantization step.
    pub fn weight(&self, name: &str) -> Result<WeightRef<'_>> {
        if let Some(q) = self.quant.get(name) {
            return Ok(WeightRef::Quant(q));
        }
        Ok(WeightRef::Dense(self.get(name)?))
    }
}

/// A borrowed base linear weight: dense f32 or packed quantized.
/// Matmuls against the packed form run the fused block-dequant kernels
/// (`tensor::fused`), which reproduce dequantize-then-matmul bit for
/// bit without materializing the f32 matrix.
#[derive(Clone, Copy)]
pub enum WeightRef<'a> {
    Dense(&'a Tensor),
    Quant(&'a QuantWeight),
}

impl<'a> WeightRef<'a> {
    /// `(din, dout)`.
    pub fn shape2(&self) -> (usize, usize) {
        match *self {
            WeightRef::Dense(t) => (t.shape[0], t.shape[1]),
            WeightRef::Quant(q) => q.shape(),
        }
    }

    /// `y = x @ W`.
    pub fn matmul(&self, x: &Tensor) -> Result<Tensor> {
        match *self {
            WeightRef::Dense(t) => x.matmul(t),
            WeightRef::Quant(q) => q.matmul(x),
        }
    }

    /// `y = dy @ W^T` (the backward's `dL/dx` through a frozen base).
    pub fn matmul_t(&self, dy: &Tensor) -> Result<Tensor> {
        match *self {
            WeightRef::Dense(t) => dy.matmul(&t.transpose2()),
            WeightRef::Quant(q) => q.matmul_t(dy),
        }
    }

    /// The dense tensor, for the paths that genuinely need the full
    /// matrix (weight-centric OFT's cubic merge). Packed weights refuse
    /// rather than silently dequantizing.
    pub fn dense(&self) -> Result<&'a Tensor> {
        match *self {
            WeightRef::Dense(t) => Ok(t),
            WeightRef::Quant(_) => {
                bail!("weight is packed (quantized); refusing to materialize it in f32")
            }
        }
    }

    /// Owned clone (decode models resolve weights once at build time).
    pub fn cloned(&self) -> BaseWeight {
        match *self {
            WeightRef::Dense(t) => BaseWeight::Dense(t.clone()),
            WeightRef::Quant(q) => BaseWeight::Quant(q.clone()),
        }
    }
}

/// An owned base linear weight (see [`WeightRef`]): what the decode
/// models hold so KV-cached decoding over a quantized base stays packed
/// per token.
#[derive(Clone)]
pub enum BaseWeight {
    Dense(Tensor),
    Quant(QuantWeight),
}

impl BaseWeight {
    /// Borrowed view (avoids the std `AsRef` name on purpose — the
    /// return type is an enum, not a reference).
    pub fn as_weight(&self) -> WeightRef<'_> {
        match self {
            BaseWeight::Dense(t) => WeightRef::Dense(t),
            BaseWeight::Quant(q) => WeightRef::Quant(q),
        }
    }

    /// `y = x @ W`.
    pub fn matmul(&self, x: &Tensor) -> Result<Tensor> {
        self.as_weight().matmul(x)
    }
}

/// Name-keyed parameter gradients, summed across every use site.
pub type Gradients = BTreeMap<String, Tensor>;

/// Add `g` into `grads[name]` (elementwise; inserts on first use).
pub fn accumulate(grads: &mut Gradients, name: &str, g: Tensor) {
    match grads.get_mut(name) {
        Some(t) => {
            for (a, b) in t.data.iter_mut().zip(&g.data) {
                *a += b;
            }
        }
        None => {
            grads.insert(name.to_string(), g);
        }
    }
}

/// Per-step adapter state resolved once and shared read-only by every
/// microbatch (and worker thread) of a training step, keyed by
/// adapted-linear name. Each entry is an adapter-defined payload (CNP
/// rotation blocks, a merged `blockdiag(R) @ W`, normalized
/// Householder directions, ...) built by that method's
/// [`Adapter::plan_linear`] and downcast back by its own hooks — the
/// plan itself knows nothing about any method. Without it,
/// per-sequence microbatching would re-pay per-step costs (block
/// builds, cubic merges) once per sequence instead of once per step.
#[derive(Default)]
pub struct AdapterPlan {
    entries: BTreeMap<String, Box<dyn Any + Send + Sync>>,
}

impl AdapterPlan {
    /// Store one linear's plan entry.
    pub fn insert(&mut self, linear: String, entry: Box<dyn Any + Send + Sync>) {
        self.entries.insert(linear, entry);
    }

    /// This linear's entry, downcast to the owning adapter's type.
    pub fn get<T: 'static>(&self, linear: &str) -> Option<&T> {
        self.entries.get(linear).and_then(|e| e.downcast_ref::<T>())
    }
}

/// Everything a layer needs besides its direct input: the resolved
/// parameter map, the bundle's dims, the registered PEFT [`Adapter`]
/// driving the adapted linears, and the step's shared [`AdapterPlan`]
/// (absent for paths that resolve adapters elsewhere, e.g. the decode
/// models).
pub struct Ctx<'a> {
    pub params: &'a Params,
    pub dims: &'a ModelDims,
    pub adapter: &'static dyn Adapter,
    pub plan: Option<&'a AdapterPlan>,
    /// Linears the scenario's targeting regexes deselected (from
    /// `Manifest::skipped`); they run the frozen base path.
    pub skipped: Option<&'a std::collections::BTreeSet<String>>,
    /// The optimizer step, present only on training forwards/backwards
    /// — the module-dropout decision input. Eval and decode leave it
    /// `None` (dropout is a training-time regularizer, as in PEFT).
    pub step: Option<u64>,
}

impl Ctx<'_> {
    /// Whether `linear` runs its adapter this pass: not deselected by
    /// targeting, and not dropped by module dropout at this step. The
    /// dropout decision is a pure function of (seed, step, name) —
    /// bitwise identical across workers, ranks, recomputes, resume.
    pub fn adapts(&self, linear: &str) -> bool {
        if self.skipped.is_some_and(|s| s.contains(linear)) {
            return false;
        }
        match self.step {
            Some(step) => !crate::scenario::dropped(linear, step, &self.dims.scenario),
            None => true,
        }
    }
}

/// The surface shared by the plain `x -> y` layers (RMSNorm, the PEFT
/// linear, GELU, the LM head). `forward` returns the output plus this
/// layer's activation record; `backward` consumes the record and the
/// output cotangent, accumulates parameter gradients, and returns the
/// input cotangent. Layers with a different arity (token embedding,
/// attention over q/k/v) keep the same forward/backward shape with
/// bespoke signatures.
pub trait Layer {
    type Act;
    fn forward(&self, ctx: &Ctx, x: &Tensor) -> Result<(Tensor, Self::Act)>;
    fn backward(
        &self,
        ctx: &Ctx,
        act: &Self::Act,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor>;
}

/// The decomposed reference model: embedding, N transformer blocks,
/// final norm, LM head. Built once per bundle; stateless apart from
/// the layer names it resolves against a [`Params`] map at run time.
pub struct LayerStack {
    pub embed: embedding::Embedding,
    pub blocks: Vec<block::TransformerBlock>,
    pub final_norm: rmsnorm::RmsNorm,
    pub head: lmhead::LmHead,
}

impl LayerStack {
    /// Layer objects for `dims` (names mirror the manifest contract).
    pub fn build(dims: &ModelDims) -> LayerStack {
        LayerStack {
            embed: embedding::Embedding::new(),
            blocks: (0..dims.n_layers)
                .map(|i| block::TransformerBlock::new(&format!("layers.{i}"), dims.n_heads))
                .collect(),
            final_norm: rmsnorm::RmsNorm::new("final_norm"),
            head: lmhead::LmHead::new("lm_head"),
        }
    }

    /// Full forward pass; the returned [`Tape`] holds what `policy`
    /// decided to keep (all block records for `CheckpointPolicy::None`,
    /// only segment-boundary inputs for `EveryK`).
    pub fn forward(
        &self,
        ctx: &Ctx,
        input_ids: &[i32],
        bsz: usize,
        policy: CheckpointPolicy,
    ) -> Result<Tape> {
        let mut x = self.embed.forward(ctx, input_ids, bsz)?;
        let mut boundaries = Vec::new();
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for (i, blk) in self.blocks.iter().enumerate() {
            match policy.every() {
                None => {
                    let (y, act) = blk.forward(ctx, &x, bsz)?;
                    blocks.push(Some(act));
                    x = y;
                }
                Some(k) => {
                    if i % k == 0 {
                        boundaries.push(x.clone());
                    }
                    // The record is dropped immediately: only the
                    // boundary inputs survive the forward pass.
                    let (y, _act) = blk.forward(ctx, &x, bsz)?;
                    blocks.push(None);
                    x = y;
                }
            }
        }
        let (xf, final_norm) = self.final_norm.forward(ctx, &x)?;
        let (logits, head) = self.head.forward(ctx, &xf)?;
        Ok(Tape {
            bsz,
            input_ids: input_ids.to_vec(),
            policy,
            boundaries,
            blocks,
            final_norm,
            head,
            logits,
        })
    }

    /// Backward pass over `tape`. Checkpointed segments are re-forwarded
    /// from their boundary input first — the recompute runs the exact
    /// deterministic kernels of the original forward, so the rebuilt
    /// records (and therefore every gradient) are bitwise identical to
    /// the non-checkpointed path.
    pub fn backward(&self, ctx: &Ctx, tape: &Tape, dlogits: &Tensor) -> Result<Gradients> {
        let mut grads = Gradients::new();
        let dxf = self.head.backward(ctx, &tape.head, dlogits, &mut grads)?;
        let mut dx = self
            .final_norm
            .backward(ctx, &tape.final_norm, &dxf, &mut grads)?;

        match tape.policy.every() {
            None => {
                for (blk, act) in self.blocks.iter().zip(&tape.blocks).rev() {
                    let act = act.as_ref().context("tape record missing")?;
                    dx = blk.backward(ctx, act, &dx, &mut grads)?;
                }
            }
            Some(k) => {
                let n = self.blocks.len();
                let n_segs = n.div_ceil(k);
                for seg in (0..n_segs).rev() {
                    let start = seg * k;
                    let end = (start + k).min(n);
                    // Recompute this segment's records from its
                    // checkpointed input.
                    let mut x = tape.boundaries[seg].clone();
                    let mut acts = Vec::with_capacity(end - start);
                    for blk in &self.blocks[start..end] {
                        let (y, act) = blk.forward(ctx, &x, tape.bsz)?;
                        acts.push(act);
                        x = y;
                    }
                    for (blk, act) in self.blocks[start..end].iter().zip(&acts).rev() {
                        dx = blk.backward(ctx, act, &dx, &mut grads)?;
                    }
                }
            }
        }

        self.embed.backward(ctx, &tape.input_ids, &dx, &mut grads)?;
        Ok(grads)
    }
}
