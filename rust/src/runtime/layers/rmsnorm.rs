//! RMSNorm with a trainable per-channel gain.

use anyhow::Result;

use super::{accumulate, Ctx, Gradients, Layer};
use crate::tensor::Tensor;

/// One RMSNorm instance, resolving its gain by parameter name.
pub struct RmsNorm {
    pub name: String,
}

/// Saved input plus the per-row rsqrt factors the backward reuses.
pub struct RmsNormAct {
    pub x: Tensor,
    pub r: Vec<f32>,
}

impl RmsNorm {
    pub fn new(name: &str) -> RmsNorm {
        RmsNorm { name: name.into() }
    }
}

impl Layer for RmsNorm {
    type Act = RmsNormAct;

    fn forward(&self, ctx: &Ctx, x: &Tensor) -> Result<(Tensor, RmsNormAct)> {
        let g = ctx.params.get(&self.name)?;
        let (y, r) = rmsnorm_fwd(x, &g.data);
        Ok((y, RmsNormAct { x: x.clone(), r }))
    }

    fn backward(
        &self,
        ctx: &Ctx,
        act: &RmsNormAct,
        dy: &Tensor,
        grads: &mut Gradients,
    ) -> Result<Tensor> {
        let g = ctx.params.get(&self.name)?;
        let (dx, dg) = rmsnorm_bwd(&act.x, &g.data, &act.r, dy);
        if ctx.adapter.trains_base() {
            accumulate(grads, &self.name, dg);
        }
        Ok(dx)
    }
}

/// RMSNorm forward: y = x * rsqrt(mean(x^2) + 1e-6) * g. Returns the
/// per-row rsqrt factors for the backward pass.
pub fn rmsnorm_fwd(x: &Tensor, g: &[f32]) -> (Tensor, Vec<f32>) {
    let (m, d) = (x.shape[0], x.shape[1]);
    let mut y = Tensor::zeros(&[m, d]);
    let mut rs = vec![0f32; m];
    for row in 0..m {
        let xr = &x.data[row * d..(row + 1) * d];
        let mut s = 0f32;
        for &v in xr {
            s += v * v;
        }
        let r = 1.0 / (s / d as f32 + 1e-6).sqrt();
        rs[row] = r;
        let yr = &mut y.data[row * d..(row + 1) * d];
        for j in 0..d {
            yr[j] = xr[j] * r * g[j];
        }
    }
    (y, rs)
}

/// RMSNorm backward: returns (dx, dg).
pub fn rmsnorm_bwd(x: &Tensor, g: &[f32], r: &[f32], dy: &Tensor) -> (Tensor, Tensor) {
    let (m, d) = (x.shape[0], x.shape[1]);
    let mut dx = Tensor::zeros(&[m, d]);
    let mut dg = Tensor::zeros(&[d]);
    for row in 0..m {
        let xr = &x.data[row * d..(row + 1) * d];
        let dyr = &dy.data[row * d..(row + 1) * d];
        let rr = r[row];
        let mut s = 0f32;
        for j in 0..d {
            s += dyr[j] * g[j] * xr[j];
            dg.data[j] += dyr[j] * xr[j] * rr;
        }
        let f = rr * rr * rr / d as f32 * s;
        let dxr = &mut dx.data[row * d..(row + 1) * d];
        for j in 0..d {
            dxr[j] = dyr[j] * g[j] * rr - xr[j] * f;
        }
    }
    (dx, dg)
}
