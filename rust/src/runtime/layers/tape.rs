//! The forward tape and the gradient-checkpointing policy that decides
//! how much of it survives the forward pass.

use anyhow::{bail, Result};

use super::block::BlockAct;
use super::lmhead::LmHeadAct;
use super::rmsnorm::RmsNormAct;
use crate::tensor::Tensor;

/// What the tape keeps for the transformer blocks.
///
/// * `None` — every block's full activation record is stored (fastest
///   backward, highest activation memory).
/// * `EveryK(k)` — only the block *input* at every k-th block boundary
///   is stored; the records inside each k-block segment are recomputed
///   from that boundary during backward. Because every kernel is
///   deterministic, the recomputed records — and therefore the
///   gradients — are bitwise identical to the non-checkpointed path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CheckpointPolicy {
    #[default]
    None,
    EveryK(usize),
}

impl CheckpointPolicy {
    /// Parse a CLI/config spelling: `none` or `every-<k>` (k >= 1).
    pub fn parse(s: &str) -> Result<CheckpointPolicy> {
        if s == "none" {
            return Ok(CheckpointPolicy::None);
        }
        if let Some(k) = s.strip_prefix("every-") {
            match k.parse::<usize>() {
                Ok(k) if k >= 1 => return Ok(CheckpointPolicy::EveryK(k)),
                _ => {}
            }
        }
        bail!(
            "unknown checkpoint policy '{s}'; valid policies: none, every-<k> \
             (e.g. every-1, every-2)"
        )
    }

    /// The segment length, or `None` when checkpointing is off.
    pub fn every(self) -> Option<usize> {
        match self {
            CheckpointPolicy::None => None,
            CheckpointPolicy::EveryK(k) => Some(k.max(1)),
        }
    }

    /// Canonical spelling (inverse of [`CheckpointPolicy::parse`]).
    pub fn label(self) -> String {
        match self {
            CheckpointPolicy::None => "none".into(),
            CheckpointPolicy::EveryK(k) => format!("every-{k}"),
        }
    }
}

/// Activation records of one forward pass, in layer order: what the
/// backward pass consumes, and the unit the checkpoint policy trades
/// against recompute time.
pub struct Tape {
    pub bsz: usize,
    pub input_ids: Vec<i32>,
    pub policy: CheckpointPolicy,
    /// Block inputs at segment boundaries (`EveryK` only; empty under
    /// `None`).
    pub boundaries: Vec<Tensor>,
    /// Per-block records; `None` where the policy dropped them.
    pub blocks: Vec<Option<BlockAct>>,
    pub final_norm: RmsNormAct,
    pub head: LmHeadAct,
    /// (bsz * seq_len, vocab) output logits.
    pub logits: Tensor,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        assert_eq!(CheckpointPolicy::parse("none").unwrap(), CheckpointPolicy::None);
        assert_eq!(
            CheckpointPolicy::parse("every-2").unwrap(),
            CheckpointPolicy::EveryK(2)
        );
        assert_eq!(CheckpointPolicy::parse("every-1").unwrap().label(), "every-1");
        assert_eq!(CheckpointPolicy::None.label(), "none");
        for bad in ["", "every-0", "every-x", "all", "every"] {
            let err = match CheckpointPolicy::parse(bad) {
                Err(e) => format!("{e:#}"),
                Ok(p) => panic!("'{bad}' parsed as {p:?}"),
            };
            assert!(err.contains("every-<k>"), "error should list options: {err}");
        }
    }

    #[test]
    fn policy_every_accessor() {
        assert_eq!(CheckpointPolicy::None.every(), None);
        assert_eq!(CheckpointPolicy::EveryK(3).every(), Some(3));
    }
}
