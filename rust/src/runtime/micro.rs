//! Micro-kernel catalog: standalone graphs (rotate / merge / CNP /
//! dequant at swept sizes) used by the complexity-scaling and ablation
//! benches (Fig. 1, §3.2, §3.3).
//!
//! Two sources of truth, same kernel names either way:
//!
//! * `artifacts/micro/manifest.json` (written by `python -m
//!   compile.aot`) when an artifact tree exists — each entry also names
//!   an HLO file for the PJRT backend;
//! * [`MicroCatalog::builtin`] otherwise — the same specs synthesized
//!   in Rust, executed natively by the reference engine.

use std::path::Path;

use anyhow::{Context, Result};

use super::{lit_f32, lit_i32, lit_i8, lit_u8, Dtype, Engine, Graph, Value};
use crate::json::{self, Json};
use crate::util::rng::Rng;

/// Input rows for the linear-layer micro benches (aot.MICRO_ROWS).
pub const MICRO_ROWS: usize = 128;
/// Block size of the rotate/merge sweep kernels (aot.MICRO_B).
pub const MICRO_B: usize = 32;
/// Neumann terms of the sweep kernels (aot.MICRO_K).
pub const MICRO_K: usize = 5;
/// LoRA rank of the lora_w kernels (aot.MICRO_LORA_R).
pub const MICRO_LORA_R: usize = 16;
/// Hidden sizes of the scaling sweep (aot.MICRO_DIMS).
pub const MICRO_DIMS: [usize; 4] = [256, 512, 1024, 2048];

/// One input spec of a micro kernel.
#[derive(Clone, Debug)]
pub struct MicroInput {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// A loadable micro kernel.
#[derive(Clone, Debug)]
pub struct MicroSpec {
    pub name: String,
    pub artifact: String,
    pub inputs: Vec<MicroInput>,
    /// Free-form metadata (d, b, k, ...).
    pub meta: Json,
}

impl MicroSpec {
    /// Integer metadata accessor (e.g. `d`, `b`, `k`).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.opt(key).and_then(|v| v.as_usize().ok())
    }
}

/// The kernel catalog (parsed manifest or builtin synthesis).
pub struct MicroCatalog {
    pub root: std::path::PathBuf,
    pub specs: Vec<MicroSpec>,
}

fn packed_dim(b: usize) -> usize {
    b * (b - 1) / 2
}

impl MicroCatalog {
    /// Parse `<artifacts>/micro/manifest.json`.
    pub fn load(artifacts_root: impl AsRef<Path>) -> Result<MicroCatalog> {
        let root = artifacts_root.as_ref().join("micro");
        let man = json::parse_file(root.join("manifest.json"))
            .context("reading micro manifest (run `make artifacts`)")?;
        let mut specs = Vec::new();
        for (name, entry) in man.as_obj()? {
            let mut inputs = Vec::new();
            for inp in entry.get("inputs")?.as_arr()? {
                inputs.push(MicroInput {
                    name: inp.get("name")?.as_str()?.to_string(),
                    shape: inp.get("shape")?.as_shape()?,
                    dtype: Dtype::parse(inp.get("dtype")?.as_str()?)?,
                });
            }
            specs.push(MicroSpec {
                name: name.clone(),
                artifact: entry.get("artifact")?.as_str()?.to_string(),
                inputs,
                meta: entry.get("meta")?.clone(),
            });
        }
        Ok(MicroCatalog { root, specs })
    }

    /// The artifact-free catalog: the exact kernel set
    /// `python/compile/aot.py::micro_defs` lowers, synthesized in Rust
    /// for the reference engine.
    pub fn builtin() -> MicroCatalog {
        let mut specs = Vec::new();
        let f32_in = |name: &str, shape: Vec<usize>| MicroInput {
            name: name.to_string(),
            shape,
            dtype: Dtype::F32,
        };
        let p = packed_dim(MICRO_B);
        for d in MICRO_DIMS {
            let nb = d / MICRO_B;
            let x = f32_in("x", vec![MICRO_ROWS, d]);
            let q = f32_in("q", vec![nb, p]);
            let w = f32_in("w", vec![d, d]);
            let meta = Json::obj(vec![("d", Json::num(d as f64))]);
            let push = |specs: &mut Vec<MicroSpec>, name: String, inputs: Vec<MicroInput>| {
                specs.push(MicroSpec {
                    artifact: format!("{name}.hlo.txt"),
                    name,
                    inputs,
                    meta: meta.clone(),
                });
            };
            push(&mut specs, format!("rotate_d{d}"), vec![x.clone(), q.clone()]);
            push(
                &mut specs,
                format!("rotate_w_d{d}"),
                vec![x.clone(), q.clone(), w.clone()],
            );
            push(
                &mut specs,
                format!("merge_w_d{d}"),
                vec![x.clone(), q.clone(), w.clone()],
            );
            push(&mut specs, format!("base_w_d{d}"), vec![x.clone(), w.clone()]);
            push(
                &mut specs,
                format!("lora_w_d{d}"),
                vec![
                    x.clone(),
                    f32_in("a", vec![d, MICRO_LORA_R]),
                    f32_in("b", vec![MICRO_LORA_R, d]),
                    w.clone(),
                ],
            );
        }
        for b in [16usize, 32, 64] {
            let q = f32_in("q", vec![32, packed_dim(b)]);
            specs.push(MicroSpec {
                name: format!("cnp_b{b}"),
                artifact: format!("cnp_b{b}.hlo.txt"),
                inputs: vec![q.clone()],
                meta: Json::obj(vec![
                    ("b", Json::num(b as f64)),
                    ("k", Json::num(MICRO_K as f64)),
                ]),
            });
            specs.push(MicroSpec {
                name: format!("cayley_schulz_b{b}"),
                artifact: format!("cayley_schulz_b{b}.hlo.txt"),
                inputs: vec![q],
                meta: Json::obj(vec![("b", Json::num(b as f64))]),
            });
        }
        for k in 1..=8usize {
            specs.push(MicroSpec {
                name: format!("cnp_b{MICRO_B}_k{k}"),
                artifact: format!("cnp_b{MICRO_B}_k{k}.hlo.txt"),
                inputs: vec![f32_in("q", vec![32, p])],
                meta: Json::obj(vec![
                    ("b", Json::num(MICRO_B as f64)),
                    ("k", Json::num(k as f64)),
                ]),
            });
        }
        // quant dequant kernels at a fixed realistic size
        let n = 1024 * 1024usize;
        let (nbytes, nblocks, ngroups) = (n / 2, n / 64, n / 64 / 256);
        specs.push(MicroSpec {
            name: "nf4_dequant_1m".to_string(),
            artifact: "nf4_dequant_1m.hlo.txt".to_string(),
            inputs: vec![
                MicroInput {
                    name: "codes".into(),
                    shape: vec![nbytes],
                    dtype: Dtype::U8,
                },
                MicroInput {
                    name: "absmax_q".into(),
                    shape: vec![nblocks],
                    dtype: Dtype::I8,
                },
                MicroInput {
                    name: "absmax_s".into(),
                    shape: vec![ngroups],
                    dtype: Dtype::F32,
                },
                MicroInput {
                    name: "offset".into(),
                    shape: vec![1],
                    dtype: Dtype::F32,
                },
            ],
            meta: Json::obj(vec![("n", Json::num(n as f64))]),
        });
        let dq = 1024usize;
        specs.push(MicroSpec {
            name: "awq_dequant_1m".to_string(),
            artifact: "awq_dequant_1m.hlo.txt".to_string(),
            inputs: vec![
                MicroInput {
                    name: "codes".into(),
                    shape: vec![dq / 2, dq],
                    dtype: Dtype::U8,
                },
                MicroInput {
                    name: "scales".into(),
                    shape: vec![dq / 64, dq],
                    dtype: Dtype::F32,
                },
                MicroInput {
                    name: "eq".into(),
                    shape: vec![dq],
                    dtype: Dtype::F32,
                },
            ],
            meta: Json::obj(vec![
                ("din", Json::num(dq as f64)),
                ("dout", Json::num(dq as f64)),
            ]),
        });
        MicroCatalog {
            root: std::path::PathBuf::from("builtin"),
            specs,
        }
    }

    /// The artifact catalog when present, the builtin one otherwise —
    /// what benches should use.
    pub fn load_or_builtin(artifacts_root: impl AsRef<Path>) -> Result<MicroCatalog> {
        let root = artifacts_root.as_ref();
        if root.join("micro/manifest.json").exists() {
            MicroCatalog::load(root)
        } else {
            Ok(MicroCatalog::builtin())
        }
    }

    pub fn get(&self, name: &str) -> Result<&MicroSpec> {
        self.specs
            .iter()
            .find(|s| s.name == name)
            .with_context(|| format!("micro kernel '{name}' not in manifest"))
    }

    /// Names matching a prefix (e.g. `rotate_d` for the scaling sweep).
    pub fn names_with_prefix(&self, prefix: &str) -> Vec<String> {
        let mut v: Vec<String> = self
            .specs
            .iter()
            .filter(|s| s.name.starts_with(prefix))
            .map(|s| s.name.clone())
            .collect();
        v.sort();
        v
    }

    /// Load one kernel through the engine.
    pub fn compile(&self, engine: &Engine, name: &str) -> Result<MicroKernel> {
        let spec = self.get(name)?.clone();
        let graph = engine.load_micro_kernel(&self.root, &spec)?;
        Ok(MicroKernel { spec, graph })
    }
}

/// A loaded micro kernel ready to execute.
pub struct MicroKernel {
    pub spec: MicroSpec,
    pub graph: Graph,
}

impl MicroKernel {
    /// Fabricate seeded inputs matching the declared specs. f32 inputs
    /// are N(0, std); integer/code inputs are uniform over their domain.
    pub fn random_inputs(&self, seed: u64, std: f32) -> Result<Vec<Value>> {
        let mut rng = Rng::new(seed);
        self.spec
            .inputs
            .iter()
            .map(|inp| {
                let n: usize = inp.shape.iter().product();
                match inp.dtype {
                    Dtype::F32 => lit_f32(&inp.shape, &rng.normal_vec(n, std)),
                    Dtype::I32 => {
                        let v: Vec<i32> = (0..n).map(|_| rng.below(16) as i32).collect();
                        lit_i32(&inp.shape, &v)
                    }
                    Dtype::U8 => {
                        let v: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                        lit_u8(&inp.shape, &v)
                    }
                    Dtype::I8 => {
                        let v: Vec<i8> = (0..n).map(|_| rng.below(255) as i32 as i8).collect();
                        lit_i8(&inp.shape, &v)
                    }
                }
            })
            .collect()
    }

    /// Execute once with the given inputs.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        self.graph.run(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_micro_manifest_shape() {
        let doc = r#"{
            "rotate_d256": {
                "artifact": "rotate_d256.hlo.txt",
                "inputs": [
                    {"name": "x", "shape": [128, 256], "dtype": "f32"},
                    {"name": "q", "shape": [8, 496], "dtype": "f32"}
                ],
                "meta": {"d": 256}
            }
        }"#;
        let dir = std::env::temp_dir().join(format!("oft_micro_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("micro")).unwrap();
        std::fs::write(dir.join("micro/manifest.json"), doc).unwrap();
        let cat = MicroCatalog::load(&dir).unwrap();
        assert_eq!(cat.specs.len(), 1);
        let s = cat.get("rotate_d256").unwrap();
        assert_eq!(s.meta_usize("d"), Some(256));
        assert_eq!(s.inputs[0].shape, vec![128, 256]);
        assert_eq!(cat.names_with_prefix("rotate_d"), vec!["rotate_d256"]);
        assert!(cat.get("nope").is_err());
        // load_or_builtin prefers the on-disk manifest...
        let via = MicroCatalog::load_or_builtin(&dir).unwrap();
        assert_eq!(via.specs.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
        // ...and falls back to the builtin set otherwise.
        let fallback = MicroCatalog::load_or_builtin(&dir).unwrap();
        assert!(fallback.specs.len() > 20);
    }

    #[test]
    fn builtin_catalog_covers_the_sweeps() {
        let cat = MicroCatalog::builtin();
        for d in MICRO_DIMS {
            for prefix in ["rotate_d", "rotate_w_d", "merge_w_d", "base_w_d", "lora_w_d"] {
                assert!(cat.get(&format!("{prefix}{d}")).is_ok(), "{prefix}{d}");
            }
        }
        for b in [16, 32, 64] {
            assert!(cat.get(&format!("cnp_b{b}")).is_ok());
            assert!(cat.get(&format!("cayley_schulz_b{b}")).is_ok());
        }
        for k in 1..=8 {
            let s = cat.get(&format!("cnp_b32_k{k}")).unwrap();
            assert_eq!(s.meta_usize("k"), Some(k));
        }
        assert!(cat.get("nf4_dequant_1m").is_ok());
        assert!(cat.get("awq_dequant_1m").is_ok());
        // shapes mirror aot.py: rotate_d256 has q (8, 496)
        let s = cat.get("rotate_d256").unwrap();
        assert_eq!(s.inputs[1].shape, vec![8, 496]);
    }

    #[test]
    fn builtin_kernels_execute_on_reference_engine() {
        let cat = MicroCatalog::builtin();
        let e = Engine::reference();
        let k = cat.compile(&e, "cnp_b16").unwrap();
        let inputs = k.random_inputs(1, 0.02).unwrap();
        let out = k.run(&inputs).unwrap();
        assert_eq!(out[0].shape, vec![32, 16, 16]);
    }
}
