//! Micro-kernel artifact loader: `artifacts/micro/` holds standalone
//! HLO graphs (rotate / merge / CNP / dequant at swept sizes) used by
//! the complexity-scaling and ablation benches (Fig. 1, §3.2, §3.3).
//!
//! `manifest.json` maps kernel name -> {artifact, inputs, meta}; this
//! module loads a kernel, fabricates seeded random inputs matching the
//! declared specs, and executes through the same [`Engine`] as the
//! training path.

use std::path::Path;

use anyhow::{Context, Result};
use xla::Literal;

use super::{lit_f32, lit_i32, lit_i8, lit_u8, Dtype, Engine, Graph};
use crate::json::{self, Json};
use crate::util::rng::Rng;

/// One input spec of a micro kernel.
#[derive(Clone, Debug)]
pub struct MicroInput {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// A loadable micro kernel.
#[derive(Clone, Debug)]
pub struct MicroSpec {
    pub name: String,
    pub artifact: String,
    pub inputs: Vec<MicroInput>,
    /// Free-form metadata (d, b, k, ...).
    pub meta: Json,
}

impl MicroSpec {
    /// Integer metadata accessor (e.g. `d`, `b`, `k`).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.opt(key).and_then(|v| v.as_usize().ok())
    }
}

/// The parsed micro manifest.
pub struct MicroCatalog {
    pub root: std::path::PathBuf,
    pub specs: Vec<MicroSpec>,
}

impl MicroCatalog {
    /// Parse `<artifacts>/micro/manifest.json`.
    pub fn load(artifacts_root: impl AsRef<Path>) -> Result<MicroCatalog> {
        let root = artifacts_root.as_ref().join("micro");
        let man = json::parse_file(root.join("manifest.json"))
            .context("reading micro manifest (run `make artifacts`)")?;
        let mut specs = Vec::new();
        for (name, entry) in man.as_obj()? {
            let mut inputs = Vec::new();
            for inp in entry.get("inputs")?.as_arr()? {
                inputs.push(MicroInput {
                    name: inp.get("name")?.as_str()?.to_string(),
                    shape: inp.get("shape")?.as_shape()?,
                    dtype: Dtype::parse(inp.get("dtype")?.as_str()?)?,
                });
            }
            specs.push(MicroSpec {
                name: name.clone(),
                artifact: entry.get("artifact")?.as_str()?.to_string(),
                inputs,
                meta: entry.get("meta")?.clone(),
            });
        }
        Ok(MicroCatalog { root, specs })
    }

    pub fn get(&self, name: &str) -> Result<&MicroSpec> {
        self.specs
            .iter()
            .find(|s| s.name == name)
            .with_context(|| format!("micro kernel '{name}' not in manifest"))
    }

    /// Names matching a prefix (e.g. `rotate_d` for the scaling sweep).
    pub fn names_with_prefix(&self, prefix: &str) -> Vec<String> {
        let mut v: Vec<String> = self
            .specs
            .iter()
            .filter(|s| s.name.starts_with(prefix))
            .map(|s| s.name.clone())
            .collect();
        v.sort();
        v
    }

    /// Compile one kernel.
    pub fn compile(&self, engine: &Engine, name: &str) -> Result<MicroKernel> {
        let spec = self.get(name)?.clone();
        let graph = engine.load_graph(self.root.join(&spec.artifact))?;
        Ok(MicroKernel { spec, graph })
    }
}

/// A compiled micro kernel ready to execute.
pub struct MicroKernel {
    pub spec: MicroSpec,
    pub graph: Graph,
}

impl MicroKernel {
    /// Fabricate seeded inputs matching the declared specs. f32 inputs
    /// are N(0, std); integer/code inputs are uniform over their domain.
    pub fn random_inputs(&self, seed: u64, std: f32) -> Result<Vec<Literal>> {
        let mut rng = Rng::new(seed);
        self.spec
            .inputs
            .iter()
            .map(|inp| {
                let n: usize = inp.shape.iter().product();
                match inp.dtype {
                    Dtype::F32 => lit_f32(&inp.shape, &rng.normal_vec(n, std)),
                    Dtype::I32 => {
                        let v: Vec<i32> = (0..n).map(|_| rng.below(16) as i32).collect();
                        lit_i32(&inp.shape, &v)
                    }
                    Dtype::U8 => {
                        let v: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                        lit_u8(&inp.shape, &v)
                    }
                    Dtype::I8 => {
                        let v: Vec<i8> =
                            (0..n).map(|_| rng.below(255) as i32 as i8).collect();
                        lit_i8(&inp.shape, &v)
                    }
                }
            })
            .collect()
    }

    /// Execute once with the given inputs.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        self.graph.run(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Catalog parsing is covered here; execution tests live in
    // rust/tests/ (they need compiled artifacts).

    #[test]
    fn parses_micro_manifest_shape() {
        let doc = r#"{
            "rotate_d256": {
                "artifact": "rotate_d256.hlo.txt",
                "inputs": [
                    {"name": "x", "shape": [128, 256], "dtype": "f32"},
                    {"name": "q", "shape": [8, 496], "dtype": "f32"}
                ],
                "meta": {"d": 256}
            }
        }"#;
        let dir = std::env::temp_dir().join(format!("oft_micro_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("micro")).unwrap();
        std::fs::write(dir.join("micro/manifest.json"), doc).unwrap();
        let cat = MicroCatalog::load(&dir).unwrap();
        assert_eq!(cat.specs.len(), 1);
        let s = cat.get("rotate_d256").unwrap();
        assert_eq!(s.meta_usize("d"), Some(256));
        assert_eq!(s.inputs[0].shape, vec![128, 256]);
        assert_eq!(cat.names_with_prefix("rotate_d"), vec!["rotate_d256"]);
        assert!(cat.get("nope").is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
