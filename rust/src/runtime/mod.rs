//! Backend-abstracted runtime: the coordinator executes *graphs* (train
//! step, eval loss, last-position logits, micro kernels) through an
//! [`Engine`] without knowing what implements them.
//!
//! Two engines exist:
//!
//! * [`reference`] — the default pure-Rust engine. It executes the
//!   manifest's graphs natively via the host `tensor`/`peft`/`quant`
//!   oracles (matrix-free OFTv2 rotation included), so the whole test
//!   and bench suite runs on a clean checkout with no artifacts, no
//!   Python, and no accelerator.
//! * [`pjrt`] (cargo feature `pjrt`) — the original PJRT/HLO path: load
//!   AOT-compiled HLO text produced by `python -m compile.aot`, compile
//!   once through the `xla` crate, execute many times. See DESIGN notes
//!   in the module.
//!
//! The interchange currency is the host [`Value`] (a shaped, typed
//! tensor) plus the opaque device [`Buffer`] handle for inputs that
//! should be uploaded once and reused across steps.

pub mod hlo_cost;
pub mod layers;
pub mod micro;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod refmodel;

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::manifest::Manifest;
use self::micro::MicroSpec;

pub use self::layers::CheckpointPolicy;
pub use self::refmodel::{KvBlockPool, KvPoolStats, SharedKvPool};

/// Training execution options carried alongside the train-step graph:
/// the gradient-checkpoint policy, the data-parallel worker count, and
/// the rank topology for multi-process sharded training.
/// The reference engine guarantees bitwise-identical step outputs for
/// every combination (see [`refmodel::RefBundle::loss_and_grads_opts`]);
/// backends without native support reject non-default options instead
/// of silently ignoring them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrainOpts {
    pub checkpoint: CheckpointPolicy,
    pub workers: usize,
    /// This process's rank in `0..ranks` (always 0 single-process).
    pub rank: usize,
    /// Total rank count of the training group (1 = single-process).
    pub ranks: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            checkpoint: CheckpointPolicy::None,
            workers: 1,
            rank: 0,
            ranks: 1,
        }
    }
}

/// The contiguous slice `[lo, hi)` of `n` items owned by `rank` out of
/// `ranks`, chunked `div_ceil`-style — the SAME rule `run_sharded` uses
/// for worker chunks. Every distributed ownership decision (microbatch
/// leaves, Adam-moment elements) goes through this one function, so the
/// reduction tree and the ZeRO-1 shards agree across every process.
/// Rank 0 always owns item 0 whenever `n > 0`.
pub fn shard_range(n: usize, rank: usize, ranks: usize) -> (usize, usize) {
    let ranks = ranks.max(1);
    let per = n.div_ceil(ranks);
    let lo = (rank * per).min(n);
    let hi = ((rank + 1) * per).min(n);
    (lo, hi)
}

/// Combine two microbatch partials (`a` from the lower microbatch
/// index) — the reduction operator of the fixed-order pairwise tree,
/// shared verbatim by the in-process and socket reducers so a combine
/// executes the identical float expressions wherever it runs.
pub fn combine_microbatches(
    a: (f32, layers::Gradients),
    b: (f32, layers::Gradients),
) -> (f32, layers::Gradients) {
    let (nll_a, mut ga) = a;
    let (nll_b, gb) = b;
    for (name, g) in gb {
        layers::accumulate(&mut ga, &name, g);
    }
    (nll_a + nll_b, ga)
}

/// All-reduce/all-gather primitives the sharded train step drives. The
/// in-process [`LocalReducer`] is the rank-0-of-1 degenerate case; the
/// socket implementation (`comms::SocketReducer`) runs the *same*
/// fixed-order pairwise tree distributed over a rank group, so both
/// produce bitwise-identical results.
pub trait GradReducer: Send + Sync {
    fn rank(&self) -> usize;
    fn ranks(&self) -> usize;

    /// Tree-all-reduce microbatch partials. `n_leaves` is the global
    /// microbatch count; `mine` holds this rank's leaves — the indices
    /// `shard_range(n_leaves, rank, ranks)` — in leaf order. Every rank
    /// returns the identical combined `(sum_nll, grads)`.
    fn reduce(
        &self,
        n_leaves: usize,
        mine: Vec<(f32, layers::Gradients)>,
    ) -> Result<(f32, layers::Gradients)>;

    /// Rank-ordered all-gather of f32 slices (raw little-endian bits on
    /// the wire — bit-exact). Returns every rank's contribution.
    fn all_gather_f32(&self, mine: &[f32]) -> Result<Vec<Vec<f32>>>;
}

/// The in-process reducer: rank 0 of 1. `reduce` IS the local
/// fixed-order pairwise tree — the single-process oracle every
/// distributed run is locked against.
pub struct LocalReducer;

impl GradReducer for LocalReducer {
    fn rank(&self) -> usize {
        0
    }

    fn ranks(&self) -> usize {
        1
    }

    fn reduce(
        &self,
        n_leaves: usize,
        mine: Vec<(f32, layers::Gradients)>,
    ) -> Result<(f32, layers::Gradients)> {
        ensure!(
            mine.len() == n_leaves,
            "local reduce expected {n_leaves} leaves, got {}",
            mine.len()
        );
        refmodel::tree_reduce(mine, combine_microbatches).context("batch has no sequences")
    }

    fn all_gather_f32(&self, mine: &[f32]) -> Result<Vec<Vec<f32>>> {
        Ok(vec![mine.to_vec()])
    }
}

/// Dtype names used by manifest.json.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U8,
    I8,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u8" => Dtype::U8,
            "i8" => Dtype::I8,
            _ => bail!("unknown dtype '{s}'"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 | Dtype::I8 => 1,
        }
    }
}

// ---------------------------------------------------------------------------
// Host values (the backend-agnostic literal)
// ---------------------------------------------------------------------------

/// Typed storage behind a [`Value`].
#[derive(Clone, Debug, PartialEq)]
pub enum ValueData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
    I8(Vec<i8>),
}

/// A shaped host tensor — what graphs consume and produce.
#[derive(Clone, Debug, PartialEq)]
pub struct Value {
    /// Row-major dimensions; empty for scalars.
    pub shape: Vec<usize>,
    pub data: ValueData,
}

impl Value {
    pub fn dtype(&self) -> Dtype {
        match &self.data {
            ValueData::F32(_) => Dtype::F32,
            ValueData::I32(_) => Dtype::I32,
            ValueData::U8(_) => Dtype::U8,
            ValueData::I8(_) => Dtype::I8,
        }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            ValueData::F32(v) => v.len(),
            ValueData::I32(v) => v.len(),
            ValueData::U8(v) => v.len(),
            ValueData::I8(v) => v.len(),
        }
    }

    /// Extract the elements as a vector of `T` (dtype must match).
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// First element of a scalar/1-element value.
    pub fn get_first_element<T: Element>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        match v.first() {
            Some(x) => Ok(*x),
            None => bail!("empty value"),
        }
    }

    /// Reinterpret with a new shape of the same element count (used to
    /// restore manifest shapes on flat graph outputs).
    pub fn with_shape(mut self, shape: &[usize]) -> Result<Value> {
        check_shape(shape, self.element_count())?;
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Borrow the f32 payload.
    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            ValueData::F32(v) => Ok(v),
            other => bail!("expected f32 value, got {:?}", dtype_of(other)),
        }
    }

    /// Borrow the i32 payload.
    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            ValueData::I32(v) => Ok(v),
            other => bail!("expected i32 value, got {:?}", dtype_of(other)),
        }
    }

    /// Borrow the u8 payload.
    pub fn u8s(&self) -> Result<&[u8]> {
        match &self.data {
            ValueData::U8(v) => Ok(v),
            other => bail!("expected u8 value, got {:?}", dtype_of(other)),
        }
    }

    /// Borrow the i8 payload.
    pub fn i8s(&self) -> Result<&[i8]> {
        match &self.data {
            ValueData::I8(v) => Ok(v),
            other => bail!("expected i8 value, got {:?}", dtype_of(other)),
        }
    }
}

fn dtype_of(d: &ValueData) -> Dtype {
    match d {
        ValueData::F32(_) => Dtype::F32,
        ValueData::I32(_) => Dtype::I32,
        ValueData::U8(_) => Dtype::U8,
        ValueData::I8(_) => Dtype::I8,
    }
}

/// Element types a [`Value`] can hold.
pub trait Element: Copy {
    fn extract(v: &Value) -> Result<Vec<Self>>;
}

impl Element for f32 {
    fn extract(v: &Value) -> Result<Vec<f32>> {
        Ok(v.f32s()?.to_vec())
    }
}

impl Element for i32 {
    fn extract(v: &Value) -> Result<Vec<i32>> {
        Ok(v.i32s()?.to_vec())
    }
}

impl Element for u8 {
    fn extract(v: &Value) -> Result<Vec<u8>> {
        Ok(v.u8s()?.to_vec())
    }
}

impl Element for i8 {
    fn extract(v: &Value) -> Result<Vec<i8>> {
        Ok(v.i8s()?.to_vec())
    }
}

fn check_shape(shape: &[usize], len: usize) -> Result<()> {
    let want: usize = shape.iter().product();
    if want != len {
        bail!("shape {shape:?} wants {want} elements, got {len}");
    }
    Ok(())
}

/// f32 value of the given shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Value> {
    check_shape(shape, data.len())?;
    Ok(Value {
        shape: shape.to_vec(),
        data: ValueData::F32(data.to_vec()),
    })
}

/// i32 value of the given shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Value> {
    check_shape(shape, data.len())?;
    Ok(Value {
        shape: shape.to_vec(),
        data: ValueData::I32(data.to_vec()),
    })
}

/// u8 value (quantized code packs).
pub fn lit_u8(shape: &[usize], data: &[u8]) -> Result<Value> {
    check_shape(shape, data.len())?;
    Ok(Value {
        shape: shape.to_vec(),
        data: ValueData::U8(data.to_vec()),
    })
}

/// i8 value (NF4 double-quantized absmax).
pub fn lit_i8(shape: &[usize], data: &[i8]) -> Result<Value> {
    check_shape(shape, data.len())?;
    Ok(Value {
        shape: shape.to_vec(),
        data: ValueData::I8(data.to_vec()),
    })
}

/// Scalar f32 value.
pub fn lit_scalar_f32(x: f32) -> Value {
    Value {
        shape: Vec::new(),
        data: ValueData::F32(vec![x]),
    }
}

/// Scalar i32 value.
pub fn lit_scalar_i32(x: i32) -> Value {
    Value {
        shape: Vec::new(),
        data: ValueData::I32(vec![x]),
    }
}

/// Fetch an f32 vector from a value.
pub fn to_vec_f32(v: &Value) -> Result<Vec<f32>> {
    v.to_vec::<f32>()
}

/// Fetch the single f32 in a scalar/1-element value.
pub fn scalar_f32(v: &Value) -> Result<f32> {
    v.get_first_element::<f32>()
}

// ---------------------------------------------------------------------------
// Device buffers
// ---------------------------------------------------------------------------

pub(crate) enum BufferRepr {
    /// Host-resident (reference engine): the value itself.
    Host(Value),
    /// Device-resident PJRT buffer.
    #[cfg(feature = "pjrt")]
    Device(xla::PjRtBuffer),
}

/// An engine-owned input handle: long-lived inputs (frozen weights,
/// quantized packs) are uploaded once and reused across executions.
pub struct Buffer {
    pub(crate) repr: BufferRepr,
}

impl Buffer {
    pub(crate) fn host(v: Value) -> Buffer {
        Buffer {
            repr: BufferRepr::Host(v),
        }
    }

    /// Borrow the host value (reference engine buffers only).
    pub(crate) fn as_host(&self) -> Result<&Value> {
        match &self.repr {
            BufferRepr::Host(v) => Ok(v),
            #[cfg(feature = "pjrt")]
            BufferRepr::Device(_) => bail!("buffer is device-resident, not a host value"),
        }
    }
}

// ---------------------------------------------------------------------------
// Engine / graph abstraction
// ---------------------------------------------------------------------------

/// The three graphs every artifact bundle exports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BundleRole {
    TrainStep,
    EvalLoss,
    LogitsLast,
}

impl BundleRole {
    pub fn label(self) -> &'static str {
        match self {
            BundleRole::TrainStep => "train_step",
            BundleRole::EvalLoss => "eval_loss",
            BundleRole::LogitsLast => "logits_last",
        }
    }
}

/// One runtime implementation (reference or PJRT).
pub trait EngineBackend {
    fn platform(&self) -> String;
    fn upload(&self, v: &Value) -> Result<Buffer>;
    fn load_bundle_graph(&self, man: &Manifest, role: BundleRole) -> Result<Box<dyn GraphBackend>>;
    /// Load the train-step graph with explicit [`TrainOpts`]. Backends
    /// without native checkpointing / data-parallel support inherit
    /// this default, which serves the plain graph for default options
    /// and rejects anything else rather than silently ignoring it.
    fn load_train_step(&self, man: &Manifest, opts: TrainOpts) -> Result<Box<dyn GraphBackend>> {
        ensure!(
            opts == TrainOpts::default(),
            "backend '{}' supports none of --grad-checkpoint, --workers, \
             or --ranks (use the reference backend)",
            self.platform()
        );
        self.load_bundle_graph(man, BundleRole::TrainStep)
    }
    /// Load the ZeRO-1 sharded train-step graph, which reduces
    /// gradients and all-gathers updated params through `reducer`.
    /// Backends without message-passing support inherit this default.
    fn load_train_step_sharded(
        &self,
        _man: &Manifest,
        _opts: TrainOpts,
        _reducer: std::sync::Arc<dyn GradReducer>,
    ) -> Result<Box<dyn GraphBackend>> {
        bail!(
            "backend '{}' does not support multi-process sharded training \
             (--ranks); use the reference backend",
            self.platform()
        )
    }
    fn load_micro_kernel(&self, micro_root: &Path, spec: &MicroSpec)
        -> Result<Box<dyn GraphBackend>>;
    /// Build an adapter-bound incremental decoder: trainables + fixed
    /// inputs are resolved once (pack assembly, CNP block build, LoRA
    /// scaling), then any number of KV-cached sessions decode token by
    /// token without re-running the prefix.
    fn load_decoder(
        &self,
        man: &Manifest,
        trainables: &[&Value],
        fixed: &[&Buffer],
    ) -> Result<Box<dyn DecoderBackend>>;
}

/// One executable graph.
pub trait GraphBackend {
    fn run_refs(&self, inputs: &[&Value]) -> Result<Vec<Value>>;
    fn run_buffers(&self, inputs: &[&Buffer]) -> Result<Vec<Value>>;
}

/// An adapter-bound incremental decoder (see [`EngineBackend::load_decoder`]).
pub trait DecoderBackend {
    /// Start a fresh sequence with an empty KV cache.
    fn begin(&self) -> Result<Box<dyn DecodeSessionBackend>>;
    /// Start a fresh sequence whose KV rows come from a shared block
    /// pool instead of a private contiguous cache. Backends without a
    /// paged path report so instead of silently falling back — the
    /// caller decides whether contiguous is acceptable.
    fn begin_paged(&self, _pool: &SharedKvPool) -> Result<Box<dyn DecodeSessionBackend>> {
        bail!("this backend does not support paged KV decode")
    }
    /// (n_layers, d_model) of the KV rows this decoder writes — the
    /// shape a shared pool must be built with. `None` when the backend
    /// has no paged path.
    fn kv_layout(&self) -> Option<(usize, usize)> {
        None
    }
    /// Maximum positions a session can consume (the model's seq_len).
    fn max_positions(&self) -> usize;
    fn vocab(&self) -> usize;
}

/// One in-flight sequence: owns its KV cache, consumes one token per
/// step, and returns next-token logits.
pub trait DecodeSessionBackend {
    fn step(&mut self, token: i32) -> Result<Vec<f32>>;
    /// Positions consumed so far.
    fn position(&self) -> usize;
}

/// Names `Engine::by_name` accepts, with a one-line description each
/// (used for `--backend` error/help text).
pub fn backend_catalog() -> Vec<(&'static str, &'static str)> {
    let pjrt_about = if cfg!(feature = "pjrt") {
        "PJRT/HLO engine over the xla crate"
    } else {
        "PJRT/HLO engine (unavailable: build with --features pjrt)"
    };
    vec![
        ("reference", "pure-Rust host engine (aliases: host, auto)"),
        ("pjrt", pjrt_about),
    ]
}

fn backend_list() -> String {
    backend_catalog()
        .iter()
        .map(|(name, about)| format!("  {name:<10} {about}"))
        .collect::<Vec<_>>()
        .join("\n")
}

/// The process-wide runtime handle. One per process is plenty.
pub struct Engine {
    backend: Box<dyn EngineBackend>,
    uploads: AtomicU64,
    upload_bytes: AtomicU64,
}

impl Engine {
    fn wrap(backend: Box<dyn EngineBackend>) -> Engine {
        Engine {
            backend,
            uploads: AtomicU64::new(0),
            upload_bytes: AtomicU64::new(0),
        }
    }

    /// The pure-Rust reference engine (always available).
    pub fn reference() -> Engine {
        Engine::wrap(Box::new(reference::ReferenceEngine::new()))
    }

    /// The PJRT engine over the `xla` crate (feature `pjrt`).
    #[cfg(feature = "pjrt")]
    pub fn pjrt() -> Result<Engine> {
        Ok(Engine::wrap(Box::new(pjrt::PjrtEngine::cpu()?)))
    }

    /// The default CPU engine: honors the `OFT_BACKEND` env var, else
    /// the reference engine — logging why PJRT was skipped instead of
    /// silently picking reference.
    pub fn cpu() -> Result<Engine> {
        match std::env::var("OFT_BACKEND") {
            Ok(name) if !name.is_empty() => Engine::by_name(&name),
            _ => Engine::auto(),
        }
    }

    fn auto() -> Result<Engine> {
        #[cfg(feature = "pjrt")]
        {
            crate::log_debug!(
                "auto backend: using the reference engine (PJRT needs AOT artifacts; \
                 opt in explicitly with --backend pjrt or OFT_BACKEND=pjrt)"
            );
        }
        #[cfg(not(feature = "pjrt"))]
        {
            crate::log_debug!(
                "auto backend: PJRT skipped (crate built without the `pjrt` feature); \
                 using the reference engine"
            );
        }
        Ok(Engine::reference())
    }

    /// Select a backend by name: `reference` (alias `host`, `auto`) or
    /// `pjrt`.
    pub fn by_name(name: &str) -> Result<Engine> {
        match name {
            "" | "auto" => Engine::auto(),
            "reference" | "host" => Ok(Engine::reference()),
            "pjrt" => pjrt_engine(),
            other => bail!(
                "unknown backend '{other}'; valid backends:\n{}",
                backend_list()
            ),
        }
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Number of `upload` calls served so far — lets tests prove that
    /// shared frozen/quantized buffers really are uploaded once.
    pub fn upload_count(&self) -> u64 {
        self.uploads.load(Ordering::Relaxed)
    }

    /// Total bytes moved through `upload` so far.
    pub fn upload_bytes(&self) -> u64 {
        self.upload_bytes.load(Ordering::Relaxed)
    }

    /// Upload a host value to an engine-owned buffer (done once for
    /// frozen weights / quantized packs).
    pub fn upload(&self, v: &Value) -> Result<Buffer> {
        self.uploads.fetch_add(1, Ordering::Relaxed);
        self.upload_bytes.fetch_add(
            (v.element_count() * v.dtype().size_bytes()) as u64,
            Ordering::Relaxed,
        );
        self.backend.upload(v)
    }

    /// Upload many values.
    pub fn upload_all(&self, vs: &[Value]) -> Result<Vec<Buffer>> {
        vs.iter().map(|v| self.upload(v)).collect()
    }

    /// Load one of a bundle's graphs (train step / eval loss / logits).
    pub fn load_bundle_graph(&self, man: &Manifest, role: BundleRole) -> Result<Graph> {
        Ok(Graph {
            name: format!("{}/{}", man.tag, role.label()),
            inner: self.backend.load_bundle_graph(man, role)?,
        })
    }

    /// Load the train-step graph with explicit gradient-checkpoint /
    /// data-parallel options (see [`TrainOpts`]).
    pub fn load_train_step(&self, man: &Manifest, opts: TrainOpts) -> Result<Graph> {
        Ok(Graph {
            name: format!("{}/train_step[{},w{}]", man.tag, opts.checkpoint.label(), opts.workers),
            inner: self.backend.load_train_step(man, opts)?,
        })
    }

    /// Load the ZeRO-1 sharded train-step graph: full trainables in,
    /// flat Adam-moment *shards* in/out, gradients all-reduced and
    /// updated params all-gathered through `reducer` (see
    /// [`refmodel::RefBundle::train_step_sharded`]).
    pub fn load_train_step_sharded(
        &self,
        man: &Manifest,
        opts: TrainOpts,
        reducer: std::sync::Arc<dyn GradReducer>,
    ) -> Result<Graph> {
        Ok(Graph {
            name: format!(
                "{}/train_step[{},w{},rank{}of{}]",
                man.tag,
                opts.checkpoint.label(),
                opts.workers,
                opts.rank,
                opts.ranks
            ),
            inner: self.backend.load_train_step_sharded(man, opts, reducer)?,
        })
    }

    /// Load a standalone micro kernel.
    pub fn load_micro_kernel(&self, micro_root: &Path, spec: &MicroSpec) -> Result<Graph> {
        Ok(Graph {
            name: spec.name.clone(),
            inner: self.backend.load_micro_kernel(micro_root, spec)?,
        })
    }

    /// Build an adapter-bound incremental decoder over engine-resident
    /// fixed buffers. See [`Decoder`].
    pub fn load_decoder(
        &self,
        man: &Manifest,
        trainables: &[&Value],
        fixed: &[&Buffer],
    ) -> Result<Decoder> {
        Ok(Decoder {
            name: man.tag.clone(),
            inner: self.backend.load_decoder(man, trainables, fixed)?,
        })
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_engine() -> Result<Engine> {
    Engine::pjrt()
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_engine() -> Result<Engine> {
    bail!("backend 'pjrt' requires building with `--features pjrt`")
}

/// A loaded executable graph.
pub struct Graph {
    pub name: String,
    inner: Box<dyn GraphBackend>,
}

impl Graph {
    /// Execute with host values (simplest path).
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        let refs: Vec<&Value> = inputs.iter().collect();
        self.inner.run_refs(&refs)
    }

    /// Execute with borrowed host values (no cloning of inputs).
    pub fn run_refs(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        self.inner.run_refs(inputs)
    }

    /// Execute with engine-owned buffers (the hot path: frozen weights
    /// stay resident across steps).
    pub fn run_b(&self, inputs: &[&Buffer]) -> Result<Vec<Value>> {
        self.inner.run_buffers(inputs)
    }
}

/// An adapter-bound incremental decoder: the adapter's merged state
/// (base weights — kept packed when quantized — CNP rotation blocks,
/// LoRA factors) is resolved once at load, then [`Decoder::begin`]
/// spawns independent KV-cached sessions — the unit the `serve`
/// subsystem schedules.
pub struct Decoder {
    pub name: String,
    inner: Box<dyn DecoderBackend>,
}

impl Decoder {
    /// Start a fresh sequence (empty KV cache).
    pub fn begin(&self) -> Result<DecodeSession> {
        Ok(DecodeSession {
            inner: self.inner.begin()?,
        })
    }

    /// Start a fresh sequence over a shared KV block pool (see
    /// [`KvBlockPool`]); errors when the backend has no paged path.
    pub fn begin_paged(&self, pool: &SharedKvPool) -> Result<DecodeSession> {
        Ok(DecodeSession {
            inner: self.inner.begin_paged(pool)?,
        })
    }

    /// (n_layers, d_model) a shared KV pool must be built with, or
    /// `None` when the backend cannot decode paged.
    pub fn kv_layout(&self) -> Option<(usize, usize)> {
        self.inner.kv_layout()
    }

    /// Maximum positions a session can consume (model seq_len).
    pub fn max_positions(&self) -> usize {
        self.inner.max_positions()
    }

    pub fn vocab(&self) -> usize {
        self.inner.vocab()
    }
}

/// One in-flight decode sequence over a [`Decoder`].
pub struct DecodeSession {
    inner: Box<dyn DecodeSessionBackend>,
}

impl DecodeSession {
    /// Consume `token` at the next position; returns next-token logits.
    pub fn step(&mut self, token: i32) -> Result<Vec<f32>> {
        self.inner.step(token)
    }

    /// Positions consumed so far.
    pub fn position(&self) -> usize {
        self.inner.position()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parsing() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap(), Dtype::I32);
        assert_eq!(Dtype::parse("u8").unwrap(), Dtype::U8);
        assert_eq!(Dtype::parse("i8").unwrap(), Dtype::I8);
        assert!(Dtype::parse("f64").is_err());
        assert_eq!(Dtype::F32.size_bytes(), 4);
        assert_eq!(Dtype::U8.size_bytes(), 1);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let lit = lit_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.dtype(), Dtype::F32);
        assert_eq!(to_vec_f32(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let lit = lit_i32(&[4], &[7, -1, 0, 2]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, -1, 0, 2]);
        assert!(lit.to_vec::<f32>().is_err(), "dtype mismatch must fail");
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(lit_f32(&[2, 2], &[1.0]).is_err());
        assert!(lit_u8(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn scalar_literals() {
        assert_eq!(scalar_f32(&lit_scalar_f32(2.5)).unwrap(), 2.5);
        assert_eq!(lit_scalar_i32(7).get_first_element::<i32>().unwrap(), 7);
        assert!(lit_scalar_f32(0.0).shape.is_empty());
    }

    #[test]
    fn engine_selection() {
        let e = Engine::reference();
        assert_eq!(e.platform(), "host-reference");
        assert!(Engine::by_name("reference").is_ok());
        assert!(Engine::by_name("bogus").is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(Engine::by_name("pjrt").is_err());
    }

    #[test]
    fn buffer_roundtrip() {
        let e = Engine::reference();
        let b = e.upload(&lit_f32(&[2], &[1.0, 2.0]).unwrap()).unwrap();
        assert_eq!(b.as_host().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn unknown_backend_error_lists_valid_backends() {
        // (match instead of unwrap_err: Engine has no Debug impl)
        let err = match Engine::by_name("bogus") {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("bogus backend should fail"),
        };
        assert!(err.contains("reference"), "error should list backends: {err}");
        assert!(err.contains("pjrt"), "error should list backends: {err}");
    }

    #[test]
    fn upload_counter_tracks_calls_and_bytes() {
        let e = Engine::reference();
        assert_eq!(e.upload_count(), 0);
        e.upload(&lit_f32(&[3], &[1.0, 2.0, 3.0]).unwrap()).unwrap();
        e.upload(&lit_u8(&[2], &[1, 2]).unwrap()).unwrap();
        assert_eq!(e.upload_count(), 2);
        assert_eq!(e.upload_bytes(), 3 * 4 + 2);
    }
}
