//! PJRT runtime: load AOT-compiled HLO text, compile once, execute many
//! times — the only place the process touches the accelerator API.
//!
//! The interchange format is HLO *text* (see DESIGN.md §4 and
//! /opt/xla-example/README.md): jax>=0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids cleanly.
//!
//! All AOT graphs are lowered with `return_tuple=True`, so every
//! execution returns exactly one tuple buffer; [`Graph`] unpacks it into
//! per-output [`Literal`]s. Long-lived inputs (frozen weights, quantized
//! packs) are uploaded once as [`PjRtBuffer`]s and reused across steps.

pub mod hlo_cost;
pub mod micro;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// Dtype names used by manifest.json.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U8,
    I8,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u8" => Dtype::U8,
            "i8" => Dtype::I8,
            _ => bail!("unknown dtype '{s}'"),
        })
    }

    pub fn element_type(self) -> ElementType {
        match self {
            Dtype::F32 => ElementType::F32,
            Dtype::I32 => ElementType::S32,
            Dtype::U8 => ElementType::U8,
            Dtype::I8 => ElementType::S8,
        }
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 | Dtype::I8 => 1,
        }
    }
}

// ---------------------------------------------------------------------------
// Literal constructors (host -> XLA)
// ---------------------------------------------------------------------------

fn bytes_of<T: Copy>(xs: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs)) }
}

/// f32 literal of the given shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        shape,
        bytes_of(data),
    )?)
}

/// i32 literal of the given shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        shape,
        bytes_of(data),
    )?)
}

/// u8 literal (quantized code packs).
pub fn lit_u8(shape: &[usize], data: &[u8]) -> Result<Literal> {
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::U8,
        shape,
        data,
    )?)
}

/// i8 literal (NF4 double-quantized absmax).
pub fn lit_i8(shape: &[usize], data: &[i8]) -> Result<Literal> {
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S8,
        shape,
        bytes_of(data),
    )?)
}

/// Scalar literals.
pub fn lit_scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

pub fn lit_scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// A PJRT client plus compile/upload helpers. One per process.
pub struct Engine {
    client: PjRtClient,
}

impl Engine {
    /// Create the CPU PJRT client (the testbed backend; see DESIGN.md
    /// §Substitutions for how GPU claims are reproduced analytically).
    pub fn cpu() -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load_graph(&self, path: impl AsRef<Path>) -> Result<Graph> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Graph {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            path: path.to_path_buf(),
        })
    }

    /// Upload a host literal to a device-resident buffer (done once for
    /// frozen weights / quantized packs).
    pub fn upload(&self, lit: &Literal) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Upload many literals.
    pub fn upload_all(&self, lits: &[Literal]) -> Result<Vec<PjRtBuffer>> {
        lits.iter().map(|l| self.upload(l)).collect()
    }
}

/// A compiled executable for one AOT artifact.
pub struct Graph {
    exe: PjRtLoadedExecutable,
    pub name: String,
    pub path: PathBuf,
}

impl Graph {
    /// Execute with host literals (uploads everything; simplest path).
    /// Returns the decomposed output tuple.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let out = self.exe.execute::<Literal>(inputs)?;
        Self::unpack(out)
    }

    /// Execute with device-resident buffers (the hot path: frozen
    /// weights stay on device across steps).
    pub fn run_b(&self, inputs: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let out = self.exe.execute_b::<&PjRtBuffer>(inputs)?;
        Self::unpack(out)
    }

    /// Execute with buffers and keep the result on device: returns the
    /// raw (tuple) output buffers for timing loops that fetch only once
    /// at the end.
    pub fn run_b_raw(&self, inputs: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let mut out = self.exe.execute_b::<&PjRtBuffer>(inputs)?;
        if out.is_empty() || out[0].is_empty() {
            bail!("{}: empty execution result", self.name);
        }
        Ok(out.remove(0))
    }

    fn unpack(mut out: Vec<Vec<PjRtBuffer>>) -> Result<Vec<Literal>> {
        if out.is_empty() || out[0].is_empty() {
            bail!("empty execution result");
        }
        let replica = out.remove(0);
        // return_tuple=True => exactly one tuple-typed output buffer.
        let lit = replica[0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

// ---------------------------------------------------------------------------
// Host-literal helpers
// ---------------------------------------------------------------------------

/// Fetch an f32 vector from a literal.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Fetch the single f32 in a scalar/1-element literal.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    if v.is_empty() {
        bail!("empty literal");
    }
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    // Graph-level integration tests live in rust/tests/ (they need
    // artifacts); these cover the host-side helpers.

    #[test]
    fn dtype_parsing() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap(), Dtype::I32);
        assert_eq!(Dtype::parse("u8").unwrap(), Dtype::U8);
        assert_eq!(Dtype::parse("i8").unwrap(), Dtype::I8);
        assert!(Dtype::parse("f64").is_err());
        assert_eq!(Dtype::F32.size_bytes(), 4);
        assert_eq!(Dtype::U8.size_bytes(), 1);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let lit = lit_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_vec_f32(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let lit = lit_i32(&[4], &[7, -1, 0, 2]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, -1, 0, 2]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(lit_f32(&[2, 2], &[1.0]).is_err());
    }

    #[test]
    fn scalar_literals() {
        assert_eq!(scalar_f32(&lit_scalar_f32(2.5)).unwrap(), 2.5);
        assert_eq!(lit_scalar_i32(7).get_first_element::<i32>().unwrap(), 7);
    }
}
