//! PJRT backend (cargo feature `pjrt`): load AOT-compiled HLO text,
//! compile once through the `xla` crate, execute many times — the only
//! place the process touches the accelerator API.
//!
//! The interchange format is HLO *text* (see DESIGN notes in
//! python/compile/aot.py): jax>=0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids cleanly.
//!
//! All AOT graphs are lowered with `return_tuple=True`, so every
//! execution returns exactly one tuple buffer which is unpacked into
//! per-output [`Value`]s. Long-lived inputs (frozen weights, quantized
//! packs) are uploaded once as PJRT buffers and reused across steps.
//!
//! Note: the workspace vendors a *stub* `xla` crate so this module
//! compiles offline; executing requires patching in the real crate
//! (see rust/vendor/xla/src/lib.rs).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::micro::MicroSpec;
use super::{
    Buffer, BufferRepr, BundleRole, DecoderBackend, Dtype, EngineBackend, GraphBackend, Value,
    ValueData,
};
use crate::coordinator::manifest::Manifest;

fn element_type(d: Dtype) -> xla::ElementType {
    match d {
        Dtype::F32 => xla::ElementType::F32,
        Dtype::I32 => xla::ElementType::S32,
        Dtype::U8 => xla::ElementType::U8,
        Dtype::I8 => xla::ElementType::S8,
    }
}

fn bytes_of<T: Copy>(xs: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs)) }
}

fn value_to_literal(v: &Value) -> Result<xla::Literal> {
    let ty = element_type(v.dtype());
    let lit = match &v.data {
        ValueData::F32(d) => {
            xla::Literal::create_from_shape_and_untyped_data(ty, &v.shape, bytes_of(d))?
        }
        ValueData::I32(d) => {
            xla::Literal::create_from_shape_and_untyped_data(ty, &v.shape, bytes_of(d))?
        }
        ValueData::U8(d) => xla::Literal::create_from_shape_and_untyped_data(ty, &v.shape, d)?,
        ValueData::I8(d) => {
            xla::Literal::create_from_shape_and_untyped_data(ty, &v.shape, bytes_of(d))?
        }
    };
    Ok(lit)
}

/// Graph outputs are f32 in every exported graph; shapes are restored
/// by the coordinator from the manifest where they matter.
fn literal_to_value(lit: &xla::Literal) -> Result<Value> {
    let data = lit.to_vec::<f32>()?;
    Ok(Value {
        shape: vec![data.len()],
        data: ValueData::F32(data),
    })
}

/// A PJRT client plus compile/upload helpers. One per process.
pub struct PjrtEngine {
    client: xla::PjRtClient,
}

impl PjrtEngine {
    /// Create the CPU PJRT client (the testbed backend; GPU claims are
    /// reproduced analytically — see memmodel).
    pub fn cpu() -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine { client })
    }

    fn compile_file(&self, path: &Path) -> Result<PjrtGraph> {
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-UTF8 artifact path")?)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(PjrtGraph {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            path: path.to_path_buf(),
        })
    }
}

impl EngineBackend for PjrtEngine {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn upload(&self, v: &Value) -> Result<Buffer> {
        let lit = value_to_literal(v)?;
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        Ok(Buffer {
            repr: BufferRepr::Device(buf),
        })
    }

    fn load_bundle_graph(&self, man: &Manifest, role: BundleRole) -> Result<Box<dyn GraphBackend>> {
        let file = match role {
            BundleRole::TrainStep => &man.train_step_file,
            BundleRole::EvalLoss => &man.eval_loss_file,
            BundleRole::LogitsLast => &man.logits_last_file,
        };
        Ok(Box::new(self.compile_file(&man.artifact(file))?))
    }

    fn load_micro_kernel(
        &self,
        micro_root: &Path,
        spec: &MicroSpec,
    ) -> Result<Box<dyn GraphBackend>> {
        Ok(Box::new(self.compile_file(&micro_root.join(&spec.artifact))?))
    }

    fn load_decoder(
        &self,
        man: &Manifest,
        _trainables: &[&Value],
        _fixed: &[&Buffer],
    ) -> Result<Box<dyn DecoderBackend>> {
        // The AOT bundles export whole-sequence graphs only; a KV-cached
        // HLO decode graph is future work. Serve on the reference engine.
        bail!(
            "bundle '{}': the PJRT backend has no incremental decoder; \
             use `--backend reference` for KV-cached decoding/serving",
            man.tag
        )
    }
}

/// A compiled executable for one AOT artifact.
pub struct PjrtGraph {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub path: PathBuf,
}

impl PjrtGraph {
    fn unpack(&self, mut out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Value>> {
        if out.is_empty() || out[0].is_empty() {
            bail!("{}: empty execution result", self.name);
        }
        let replica = out.remove(0);
        // return_tuple=True => exactly one tuple-typed output buffer.
        let lit = replica[0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.iter().map(literal_to_value).collect()
    }
}

impl GraphBackend for PjrtGraph {
    fn run_refs(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| value_to_literal(v))
            .collect::<Result<_>>()?;
        let out = self.exe.execute::<xla::Literal>(&lits)?;
        self.unpack(out)
    }

    fn run_buffers(&self, inputs: &[&Buffer]) -> Result<Vec<Value>> {
        let bufs: Vec<&xla::PjRtBuffer> = inputs
            .iter()
            .map(|b| match &b.repr {
                BufferRepr::Device(d) => Ok(d),
                BufferRepr::Host(_) => {
                    bail!("host buffer passed to a PJRT graph (mixed engines?)")
                }
            })
            .collect::<Result<_>>()?;
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?;
        self.unpack(out)
    }
}
