//! The reference engine: executes bundle graphs and micro kernels
//! natively on the host via [`super::refmodel`] and the `tensor`/
//! `peft`/`quant` oracles. Always available — no artifacts, no Python,
//! no accelerator — and the default backend for tests and benches.
//!
//! Micro kernels are dispatched by catalog name (the same names
//! `python/compile/aot.py` lowers to HLO), so the scaling and ablation
//! benches measure the *engine's* fused kernels: the cache-blocked
//! multithreaded matmul and the fused CNP-build + block-rotate path.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::micro::MicroSpec;
use super::refmodel::{self, DecodeModel, KvCache, PagedKv, RefBundle, SharedKvPool};
use super::{
    lit_f32, Buffer, BundleRole, DecodeSessionBackend, DecoderBackend, EngineBackend,
    GradReducer, GraphBackend, TrainOpts, Value,
};
use crate::coordinator::manifest::Manifest;
use crate::peft;
use crate::quant::{AwqTensor, Nf4Tensor};
use crate::tensor::Tensor;

/// The host backend (stateless; all state lives in graphs and buffers).
pub(crate) struct ReferenceEngine;

impl ReferenceEngine {
    pub(crate) fn new() -> ReferenceEngine {
        ReferenceEngine
    }
}

impl EngineBackend for ReferenceEngine {
    fn platform(&self) -> String {
        "host-reference".to_string()
    }

    fn upload(&self, v: &Value) -> Result<Buffer> {
        Ok(Buffer::host(v.clone()))
    }

    fn load_bundle_graph(&self, man: &Manifest, role: BundleRole) -> Result<Box<dyn GraphBackend>> {
        let bundle = RefBundle::from_manifest(man)?;
        Ok(Box::new(RefBundleGraph {
            bundle,
            role,
            opts: TrainOpts::default(),
        }))
    }

    /// The reference engine executes any [`TrainOpts`] natively; the
    /// per-sequence microbatch decomposition makes every combination
    /// bitwise identical (see `refmodel::loss_and_grads_opts`).
    fn load_train_step(&self, man: &Manifest, opts: TrainOpts) -> Result<Box<dyn GraphBackend>> {
        ensure!(
            opts.ranks <= 1,
            "--ranks {} needs the sharded train step: load it through \
             Engine::load_train_step_sharded with a connected rank group",
            opts.ranks
        );
        let bundle = RefBundle::from_manifest(man)?;
        Ok(Box::new(RefBundleGraph {
            bundle,
            role: BundleRole::TrainStep,
            opts,
        }))
    }

    /// The ZeRO-1 sharded step: the same microbatch decomposition with
    /// gradients all-reduced and updated params all-gathered through
    /// `reducer` (see `refmodel::RefBundle::train_step_sharded`).
    fn load_train_step_sharded(
        &self,
        man: &Manifest,
        opts: TrainOpts,
        reducer: Arc<dyn GradReducer>,
    ) -> Result<Box<dyn GraphBackend>> {
        let bundle = RefBundle::from_manifest(man)?;
        Ok(Box::new(RefShardedGraph {
            bundle,
            opts,
            reducer,
        }))
    }

    fn load_micro_kernel(
        &self,
        _micro_root: &Path,
        spec: &MicroSpec,
    ) -> Result<Box<dyn GraphBackend>> {
        // Validate the name up-front so unknown kernels fail at load
        // time (as an HLO parse would), not mid-bench.
        kernel_kind(&spec.name)?;
        Ok(Box::new(RefMicroKernel { spec: spec.clone() }))
    }

    fn load_decoder(
        &self,
        man: &Manifest,
        trainables: &[&Value],
        fixed: &[&Buffer],
    ) -> Result<Box<dyn DecoderBackend>> {
        let bundle = RefBundle::from_manifest(man)?;
        let fixed_vals = buffers_to_values(fixed)?;
        let model = bundle.decode_model(trainables, &fixed_vals)?;
        Ok(Box::new(RefDecoder {
            model: Arc::new(model),
        }))
    }
}

/// Adapter-resolved decoder: sessions share the merged state via `Arc`.
struct RefDecoder {
    model: Arc<DecodeModel>,
}

impl DecoderBackend for RefDecoder {
    fn begin(&self) -> Result<Box<dyn DecodeSessionBackend>> {
        Ok(Box::new(RefDecodeSession {
            cache: self.model.new_cache(),
            model: Arc::clone(&self.model),
        }))
    }

    fn begin_paged(&self, pool: &SharedKvPool) -> Result<Box<dyn DecodeSessionBackend>> {
        {
            let p = pool.lock().expect("KV pool poisoned");
            ensure!(
                p.matches(self.model.dims()),
                "KV pool shape does not match this decoder's model"
            );
        }
        Ok(Box::new(RefPagedSession {
            model: Arc::clone(&self.model),
            pool: Arc::clone(pool),
            blocks: Vec::new(),
            len: 0,
        }))
    }

    fn kv_layout(&self) -> Option<(usize, usize)> {
        let d = self.model.dims();
        Some((d.n_layers, d.d_model))
    }

    fn max_positions(&self) -> usize {
        self.model.seq_len()
    }

    fn vocab(&self) -> usize {
        self.model.vocab()
    }
}

struct RefDecodeSession {
    model: Arc<DecodeModel>,
    cache: KvCache,
}

impl DecodeSessionBackend for RefDecodeSession {
    fn step(&mut self, token: i32) -> Result<Vec<f32>> {
        self.model.forward_incremental(&mut self.cache, token)
    }

    fn position(&self) -> usize {
        self.cache.position()
    }
}

/// A decode session whose KV rows live in fixed-size blocks drawn from
/// a [`SharedKvPool`]. Runs the same `forward_step` arithmetic as the
/// contiguous [`RefDecodeSession`], so emitted logits are bitwise
/// identical; only where the rows live differs. Blocks return to the
/// pool's free list when the session drops.
struct RefPagedSession {
    model: Arc<DecodeModel>,
    pool: SharedKvPool,
    blocks: Vec<u32>,
    len: usize,
}

impl DecodeSessionBackend for RefPagedSession {
    fn step(&mut self, token: i32) -> Result<Vec<f32>> {
        let mut pool = self.pool.lock().expect("KV pool poisoned");
        // Grow the block table *before* stepping into a new block so
        // row writes inside the forward stay infallible.
        if self.len >= self.blocks.len() * pool.block_tokens() {
            self.blocks.push(pool.alloc()?);
        }
        let mut view = PagedKv::new(&mut pool, &self.blocks);
        let logits = self.model.forward_step(&mut view, self.len, token)?;
        self.len += 1;
        Ok(logits)
    }

    fn position(&self) -> usize {
        self.len
    }
}

impl Drop for RefPagedSession {
    fn drop(&mut self) {
        if let Ok(mut pool) = self.pool.lock() {
            for &id in &self.blocks {
                pool.release(id);
            }
        }
    }
}

fn buffers_to_values<'a>(inputs: &[&'a Buffer]) -> Result<Vec<&'a Value>> {
    inputs.iter().map(|b| b.as_host()).collect()
}

// ---------------------------------------------------------------------------
// Bundle graphs
// ---------------------------------------------------------------------------

struct RefBundleGraph {
    bundle: RefBundle,
    role: BundleRole,
    opts: TrainOpts,
}

impl GraphBackend for RefBundleGraph {
    fn run_refs(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        match self.role {
            BundleRole::TrainStep => self.bundle.train_step_opts(inputs, self.opts),
            BundleRole::EvalLoss => self.bundle.eval_loss(inputs),
            BundleRole::LogitsLast => self.bundle.logits_last(inputs),
        }
    }

    fn run_buffers(&self, inputs: &[&Buffer]) -> Result<Vec<Value>> {
        self.run_refs(&buffers_to_values(inputs)?)
    }
}

/// The sharded train-step graph: holds the rank group's reducer so
/// every `run` call exchanges gradients/params with the peer ranks.
struct RefShardedGraph {
    bundle: RefBundle,
    opts: TrainOpts,
    reducer: Arc<dyn GradReducer>,
}

impl GraphBackend for RefShardedGraph {
    fn run_refs(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        self.bundle
            .train_step_sharded(inputs, self.opts, self.reducer.as_ref())
    }

    fn run_buffers(&self, inputs: &[&Buffer]) -> Result<Vec<Value>> {
        self.run_refs(&buffers_to_values(inputs)?)
    }
}

// ---------------------------------------------------------------------------
// Micro kernels
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum KernelKind {
    Cnp,
    CayleySchulz,
    RotateW,
    MergeW,
    BaseW,
    LoraW,
    Rotate,
    Nf4Dequant,
    AwqDequant,
}

fn kernel_kind(name: &str) -> Result<KernelKind> {
    // Longest-prefix first: `rotate_w_` before `rotate_`.
    let table: [(&str, KernelKind); 9] = [
        ("cayley_schulz_b", KernelKind::CayleySchulz),
        ("cnp_b", KernelKind::Cnp),
        ("rotate_w_d", KernelKind::RotateW),
        ("merge_w_d", KernelKind::MergeW),
        ("base_w_d", KernelKind::BaseW),
        ("lora_w_d", KernelKind::LoraW),
        ("rotate_d", KernelKind::Rotate),
        ("nf4_dequant", KernelKind::Nf4Dequant),
        ("awq_dequant", KernelKind::AwqDequant),
    ];
    for (prefix, kind) in table {
        if name.starts_with(prefix) {
            return Ok(kind);
        }
    }
    bail!("reference engine has no micro kernel named '{name}'")
}

struct RefMicroKernel {
    spec: MicroSpec,
}

impl GraphBackend for RefMicroKernel {
    fn run_refs(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        run_micro(&self.spec, inputs)
    }

    fn run_buffers(&self, inputs: &[&Buffer]) -> Result<Vec<Value>> {
        self.run_refs(&buffers_to_values(inputs)?)
    }
}

fn tensor_of(v: &Value) -> Result<Tensor> {
    Ok(Tensor::from_vec(&v.shape, v.f32s()?.to_vec()))
}

/// Blocks for a packed (nb, p) input, with block size inferred from the
/// rotated dimension d (nb * b == d).
fn blocks_for(q: &Value, d: usize, k: usize) -> Result<Vec<Tensor>> {
    ensure!(q.shape.len() == 2, "packed Q must be 2-D, got {:?}", q.shape);
    let nb = q.shape[0];
    ensure!(nb > 0 && d % nb == 0, "cannot split d={d} into {nb} blocks");
    let b = d / nb;
    ensure!(
        q.shape[1] == peft::packed_dim(b),
        "packed dim {} does not match block size {b}",
        q.shape[1]
    );
    refmodel::build_cnp_blocks(&tensor_of(q)?, b, k)
}

fn stack_blocks(blocks: &[Tensor]) -> Value {
    let b = blocks[0].shape[0];
    let mut data = Vec::with_capacity(blocks.len() * b * b);
    for blk in blocks {
        data.extend_from_slice(&blk.data);
    }
    lit_f32(&[blocks.len(), b, b], &data).expect("stacked block shape")
}

/// Newton–Schulz iteration X <- X (2I - A X) for A^{-1} — the
/// matmul-only "exact" Cayley baseline (mirrors model.schulz_inverse).
fn schulz_inverse(a: &Tensor, iters: usize) -> Result<Tensor> {
    let n = a.shape[0];
    let eye2 = Tensor::eye(n).scale(2.0);
    let mut x = Tensor::eye(n);
    for _ in 0..iters {
        let ax = a.matmul(&x)?;
        x = x.matmul(&eye2.sub(&ax)?)?;
    }
    Ok(x)
}

fn run_micro(spec: &MicroSpec, inputs: &[&Value]) -> Result<Vec<Value>> {
    ensure!(
        inputs.len() == spec.inputs.len(),
        "kernel '{}' expected {} inputs, got {}",
        spec.name,
        spec.inputs.len(),
        inputs.len()
    );
    let kind = kernel_kind(&spec.name)?;
    let meta_k = spec.meta_usize("k").unwrap_or(5);
    match kind {
        KernelKind::Cnp => {
            let b = spec
                .meta_usize("b")
                .context("cnp kernel missing meta 'b'")?;
            let q = tensor_of(inputs[0])?;
            let blocks = refmodel::build_cnp_blocks(&q, b, meta_k)?;
            Ok(vec![stack_blocks(&blocks)])
        }
        KernelKind::CayleySchulz => {
            let b = spec
                .meta_usize("b")
                .context("cayley_schulz kernel missing meta 'b'")?;
            let q = tensor_of(inputs[0])?;
            let p = peft::packed_dim(b);
            ensure!(q.shape.len() == 2 && q.shape[1] == p, "bad packed shape");
            let mut blocks = Vec::with_capacity(q.shape[0]);
            for i in 0..q.shape[0] {
                let skew = peft::skew_from_packed(&q.data[i * p..(i + 1) * p], b);
                let eye = Tensor::eye(b);
                let inv = schulz_inverse(&eye.sub(&skew)?, 12)?;
                blocks.push(eye.add(&skew)?.matmul(&inv)?);
            }
            Ok(vec![stack_blocks(&blocks)])
        }
        KernelKind::Rotate => {
            let d = spec.meta_usize("d").context("rotate missing meta 'd'")?;
            let x = tensor_of(inputs[0])?;
            let blocks = blocks_for(inputs[1], d, meta_k)?;
            let y = refmodel::block_rotate_fast(&x, &blocks)?;
            Ok(vec![lit_f32(&y.shape, &y.data)?])
        }
        KernelKind::RotateW => {
            let d = spec.meta_usize("d").context("rotate_w missing meta 'd'")?;
            let x = tensor_of(inputs[0])?;
            let blocks = blocks_for(inputs[1], d, meta_k)?;
            let w = tensor_of(inputs[2])?;
            let y = refmodel::block_rotate_fast(&x, &blocks)?.matmul(&w)?;
            Ok(vec![lit_f32(&y.shape, &y.data)?])
        }
        KernelKind::MergeW => {
            // The weight-centric baseline: build blockdiag(R) and pay
            // the cubic d^2 * n merge before the layer matmul.
            let d = spec.meta_usize("d").context("merge_w missing meta 'd'")?;
            let x = tensor_of(inputs[0])?;
            let blocks = blocks_for(inputs[1], d, meta_k)?;
            let w = tensor_of(inputs[2])?;
            let rd = peft::blockdiag_dense(&blocks, d);
            let y = x.matmul(&rd.matmul(&w)?)?;
            Ok(vec![lit_f32(&y.shape, &y.data)?])
        }
        KernelKind::BaseW => {
            let x = tensor_of(inputs[0])?;
            let w = tensor_of(inputs[1])?;
            let y = x.matmul(&w)?;
            Ok(vec![lit_f32(&y.shape, &y.data)?])
        }
        KernelKind::LoraW => {
            let x = tensor_of(inputs[0])?;
            let a = tensor_of(inputs[1])?;
            let b = tensor_of(inputs[2])?;
            let w = tensor_of(inputs[3])?;
            let r = a.shape[1].max(1);
            let scale = 16.0 / r as f32;
            let y = x.matmul(&w)?.add(&x.matmul(&a)?.matmul(&b)?.scale(scale))?;
            Ok(vec![lit_f32(&y.shape, &y.data)?])
        }
        KernelKind::Nf4Dequant => {
            let n = spec
                .meta_usize("n")
                .context("nf4_dequant missing meta 'n'")?;
            let q = Nf4Tensor {
                codes: inputs[0].u8s()?.to_vec(),
                absmax_q: inputs[1].i8s()?.to_vec(),
                absmax_s: inputs[2].f32s()?.to_vec(),
                offset: inputs[3].f32s()?[0],
                n,
                shape: vec![n],
            };
            let t = q.dequantize();
            Ok(vec![lit_f32(&[n], &t.data)?])
        }
        KernelKind::AwqDequant => {
            let codes = inputs[0];
            ensure!(codes.shape.len() == 2, "awq codes must be 2-D");
            let din = codes.shape[0] * 2;
            let dout = codes.shape[1];
            let q = AwqTensor {
                codes: codes.u8s()?.to_vec(),
                scales: inputs[1].f32s()?.to_vec(),
                eq: inputs[2].f32s()?.to_vec(),
                din,
                dout,
            };
            let t = q.dequantize();
            Ok(vec![lit_f32(&[din, dout], &t.data)?])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Json;
    use crate::runtime::micro::MicroInput;
    use crate::runtime::Dtype;
    use crate::util::rng::Rng;

    fn spec(name: &str, inputs: Vec<(&str, Vec<usize>, Dtype)>, meta: Vec<(&str, f64)>) -> MicroSpec {
        MicroSpec {
            name: name.to_string(),
            artifact: format!("{name}.hlo.txt"),
            inputs: inputs
                .into_iter()
                .map(|(n, shape, dtype)| MicroInput {
                    name: n.to_string(),
                    shape,
                    dtype,
                })
                .collect(),
            meta: Json::obj(meta.into_iter().map(|(k, v)| (k, Json::num(v))).collect()),
        }
    }

    #[test]
    fn kernel_name_dispatch() {
        assert_eq!(kernel_kind("cnp_b32").unwrap(), KernelKind::Cnp);
        assert_eq!(kernel_kind("cnp_b32_k8").unwrap(), KernelKind::Cnp);
        assert_eq!(
            kernel_kind("cayley_schulz_b16").unwrap(),
            KernelKind::CayleySchulz
        );
        assert_eq!(kernel_kind("rotate_d256").unwrap(), KernelKind::Rotate);
        assert_eq!(kernel_kind("rotate_w_d512").unwrap(), KernelKind::RotateW);
        assert_eq!(kernel_kind("merge_w_d512").unwrap(), KernelKind::MergeW);
        assert_eq!(kernel_kind("nf4_dequant_1m").unwrap(), KernelKind::Nf4Dequant);
        assert!(kernel_kind("mystery_k").is_err());
    }

    #[test]
    fn schulz_inverse_converges() {
        let mut rng = Rng::new(2);
        let p = peft::packed_dim(8);
        let packed = rng.normal_vec(p, 0.1);
        let q = peft::skew_from_packed(&packed, 8);
        let a = Tensor::eye(8).sub(&q).unwrap();
        let inv = schulz_inverse(&a, 12).unwrap();
        let prod = a.matmul(&inv).unwrap();
        assert!(prod.max_abs_diff(&Tensor::eye(8)) < 1e-4);
    }

    #[test]
    fn cayley_schulz_kernel_matches_exact_cayley() {
        let mut rng = Rng::new(3);
        let b = 16usize;
        let p = peft::packed_dim(b);
        let nb = 4usize;
        let q = rng.normal_vec(nb * p, 0.05);
        let s = spec(
            "cayley_schulz_b16",
            vec![("q", vec![nb, p], Dtype::F32)],
            vec![("b", b as f64)],
        );
        let out = run_micro(&s, &[&lit_f32(&[nb, p], &q).unwrap()]).unwrap();
        let got = out[0].f32s().unwrap();
        for i in 0..nb {
            let exact = peft::cayley_exact(&q[i * p..(i + 1) * p], b).unwrap();
            let blk = &got[i * b * b..(i + 1) * b * b];
            let diff = blk
                .iter()
                .zip(&exact.data)
                .fold(0.0f32, |m, (a, e)| m.max((a - e).abs()));
            assert!(diff < 1e-4, "block {i}: diff {diff}");
        }
    }

    #[test]
    fn base_and_lora_kernels() {
        let mut rng = Rng::new(4);
        let (m, d, r) = (4usize, 8usize, 2usize);
        let x = rng.normal_vec(m * d, 1.0);
        let w = rng.normal_vec(d * d, 0.1);
        let a = rng.normal_vec(d * r, 0.1);
        let b = vec![0.0f32; r * d];
        let sb = spec(
            "base_w_d8",
            vec![("x", vec![m, d], Dtype::F32), ("w", vec![d, d], Dtype::F32)],
            vec![("d", d as f64)],
        );
        let base = run_micro(
            &sb,
            &[&lit_f32(&[m, d], &x).unwrap(), &lit_f32(&[d, d], &w).unwrap()],
        )
        .unwrap();
        let sl = spec(
            "lora_w_d8",
            vec![
                ("x", vec![m, d], Dtype::F32),
                ("a", vec![d, r], Dtype::F32),
                ("b", vec![r, d], Dtype::F32),
                ("w", vec![d, d], Dtype::F32),
            ],
            vec![("d", d as f64)],
        );
        let lora = run_micro(
            &sl,
            &[
                &lit_f32(&[m, d], &x).unwrap(),
                &lit_f32(&[d, r], &a).unwrap(),
                &lit_f32(&[r, d], &b).unwrap(),
                &lit_f32(&[d, d], &w).unwrap(),
            ],
        )
        .unwrap();
        // B = 0 => LoRA == base
        let diff = base[0]
            .f32s()
            .unwrap()
            .iter()
            .zip(lora[0].f32s().unwrap())
            .fold(0.0f32, |acc, (p, q)| acc.max((p - q).abs()));
        assert!(diff < 1e-6);
    }
}
